"""AOT pipeline tests: lowering emits parseable HLO text + sane manifest."""

import os

import pytest

from compile import aot


class TestLowering:
    @pytest.fixture(scope="class")
    def tiny_artifacts(self):
        return list(aot.lower_variant("tiny", 2048, 8192, 512))

    def test_emits_three_artifacts(self, tiny_artifacts):
        names = [n for n, _, _ in tiny_artifacts]
        assert names == [
            "pagerank_shard_tiny",
            "relax_min_shard_tiny",
            "pagerank_power_tiny",
        ]

    def test_hlo_text_is_module(self, tiny_artifacts):
        for name, text, _ in tiny_artifacts:
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_shapes_in_entry_signature(self, tiny_artifacts):
        name, text, _ = tiny_artifacts[0]
        # src f32[2048], col s32[8192], output tuple (f32[512])
        assert "f32[2048]" in text
        assert "s32[8192]" in text
        assert "f32[512]" in text

    def test_no_custom_calls(self, tiny_artifacts):
        """interpret=True must lower to plain HLO ops (no Mosaic)."""
        for name, text, _ in tiny_artifacts:
            assert "custom-call" not in text, name

    def test_power_iters_recorded(self, tiny_artifacts):
        _, _, extra = tiny_artifacts[2]
        assert extra == {"iters": aot.POWER_VARIANTS["tiny"]}


class TestManifest:
    def test_round_trip(self, tmp_path):
        import subprocess
        import sys

        out = tmp_path / "arts"
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot",
             "--out-dir", str(out), "--variants", "tiny"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True,
        )
        assert r.returncode == 0, r.stderr
        manifest = (out / "manifest.txt").read_text().strip().splitlines()
        assert len(manifest) == 3
        for line in manifest:
            fields = line.split()
            assert fields[0] == "artifact"
            kv = dict(f.split("=", 1) for f in fields[2:])
            assert (out / kv["path"]).exists()
            assert int(kv["vc"]) == 2048
            assert int(kv["ec"]) == 8192
            assert int(kv["rc"]) == 512

    def test_variant_table_block_aligned(self):
        from compile.kernels.spmv import DEFAULT_BLOCK_E

        for name, vc, ec, rc in aot.VARIANTS:
            assert ec % min(DEFAULT_BLOCK_E, ec) == 0, name
            assert rc <= vc, name
