"""Layer-2 model tests: shard updates compose into whole-app numerics.

Builds a small random graph in numpy, shards it exactly like the rust
preprocessor (destination-interval CSR + padding), runs the L2 shard
updates until convergence, and checks against dense references.  This is
the contract test for the rust coordinator: if these invariants hold here,
the rust side only has to reproduce the same padding/layout.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels.ref import pagerank_dense_ref

INF = np.float32(np.inf)


def _random_graph(rng, n, m):
    """Random directed multigraph-free edge list."""
    edges = set()
    while len(edges) < m:
        u, v = rng.integers(0, n, 2)
        if u != v:
            edges.add((int(u), int(v)))
    return sorted(edges, key=lambda e: (e[1], e[0]))  # sorted by destination


def _shard(edges, n, num_shards, ec, weights=None):
    """Destination-interval sharding with padding, mirroring rust prep/."""
    bounds = np.linspace(0, n, num_shards + 1).astype(int)
    shards = []
    for s in range(num_shards):
        lo, hi = bounds[s], bounds[s + 1]
        es = [(u, v) for (u, v) in edges if lo <= v < hi]
        col = np.full(ec, 0, np.int32)
        seg = np.full(ec, 0, np.int32)
        w = np.zeros(ec, np.float32)
        wmin = np.full(ec, INF, np.float32)
        for i, (u, v) in enumerate(es):
            col[i] = u
            seg[i] = v - lo
            w[i] = 1.0
            wmin[i] = 1.0 if weights is None else weights[(u, v)]
        shards.append((lo, hi, col, seg, w, wmin))
    return shards


class TestPageRankShardComposition:
    def test_matches_dense_reference(self):
        rng = np.random.default_rng(0)
        n, m, vc, ec, rc = 24, 80, 32, 128, 16
        edges = _random_graph(rng, n, m)
        out_deg = np.zeros(vc, np.float32)
        for u, _ in edges:
            out_deg[u] += 1
        inv = np.where(out_deg > 0, 1.0 / np.maximum(out_deg, 1), 0.0).astype(
            np.float32
        )
        shards = _shard(edges, n, 3, ec)

        src = np.full(vc, 1.0 / n, np.float32)
        src[n:] = 0.0
        base = jnp.asarray([0.15 / n], jnp.float32)
        for _ in range(25):
            dst = src.copy()
            for lo, hi, col, seg, w, _ in shards:
                out = model.pagerank_shard(
                    jnp.asarray(src), jnp.asarray(inv),
                    jnp.asarray(col), jnp.asarray(seg), jnp.asarray(w),
                    base, rows=rc,
                )
                dst[lo:hi] = np.asarray(out)[: hi - lo]
            src = dst

        adj = np.zeros((n, n), np.float32)
        for u, v in edges:
            adj[u, v] = 1
        ref = pagerank_dense_ref(jnp.asarray(adj), jnp.asarray(adj.sum(1)), 25)
        np.testing.assert_allclose(src[:n], np.asarray(ref), rtol=1e-4)

    def test_rank_mass_conserved_without_dangling(self):
        """With no dangling vertices, total rank mass stays 1."""
        rng = np.random.default_rng(1)
        n, vc, ec, rc = 16, 16, 64, 16
        # ring + random chords: every vertex has out-degree >= 1
        edges = sorted(
            {(i, (i + 1) % n) for i in range(n)}
            | {tuple(map(int, rng.integers(0, n, 2))) for _ in range(30)},
            key=lambda e: (e[1], e[0]),
        )
        edges = [(u, v) for u, v in edges if u != v]
        out_deg = np.zeros(vc, np.float32)
        for u, _ in edges:
            out_deg[u] += 1
        inv = (1.0 / np.maximum(out_deg, 1)).astype(np.float32)
        shards = _shard(edges, n, 2, ec)
        src = np.full(vc, 1.0 / n, np.float32)
        base = jnp.asarray([0.15 / n], jnp.float32)
        for _ in range(10):
            dst = src.copy()
            for lo, hi, col, seg, w, _ in shards:
                out = model.pagerank_shard(
                    jnp.asarray(src), jnp.asarray(inv),
                    jnp.asarray(col), jnp.asarray(seg), jnp.asarray(w),
                    base, rows=rc,
                )
                dst[lo:hi] = np.asarray(out)[: hi - lo]
            src = dst
        assert float(np.sum(src[:n])) == pytest.approx(1.0, rel=1e-4)


class TestRelaxMinComposition:
    def test_sssp_matches_bellman_ford(self):
        rng = np.random.default_rng(2)
        n, vc, ec = 20, 32, 128
        edges = _random_graph(rng, n, 60)
        weights = {e: float(rng.integers(1, 10)) for e in edges}
        shards = _shard(edges, n, 2, ec, weights)

        dist = np.full(vc, INF, np.float32)
        dist[0] = 0.0
        for _ in range(n):
            new = dist.copy()
            for lo, hi, col, seg, _, wmin in shards:
                cur = jnp.asarray(new[lo : lo + len(wmin[:0]) + (hi - lo)])
                # pad cur to rc = hi-lo rows exactly
                out = model.relax_min_shard(
                    jnp.asarray(dist), jnp.asarray(col), jnp.asarray(seg),
                    jnp.asarray(wmin), jnp.asarray(dist[lo:hi]),
                )
                new[lo:hi] = np.asarray(out)
            dist = new

        # Bellman-Ford reference
        ref = np.full(n, np.inf)
        ref[0] = 0
        for _ in range(n):
            for (u, v), w in weights.items():
                if ref[u] + w < ref[v]:
                    ref[v] = ref[u] + w
        np.testing.assert_allclose(dist[:n], ref.astype(np.float32))

    def test_cc_label_propagation_converges(self):
        """Two disjoint cliques -> two distinct final labels (min-label)."""
        n, vc, ec = 8, 8, 64
        cliq1 = [(u, v) for u in range(4) for v in range(4) if u != v]
        cliq2 = [(u, v) for u in range(4, 8) for v in range(4, 8) if u != v]
        edges = sorted(cliq1 + cliq2, key=lambda e: (e[1], e[0]))
        weights = {e: 0.0 for e in edges}
        shards = _shard(edges, n, 2, ec, weights)
        lab = np.arange(vc, dtype=np.float32)
        for _ in range(5):
            new = lab.copy()
            for lo, hi, col, seg, _, wmin in shards:
                out = model.relax_min_shard(
                    jnp.asarray(lab), jnp.asarray(col), jnp.asarray(seg),
                    jnp.asarray(wmin), jnp.asarray(lab[lo:hi]),
                )
                new[lo:hi] = np.asarray(out)
            lab = new
        assert set(lab[:4]) == {0.0}
        assert set(lab[4:8]) == {4.0}


class TestPagerankPower:
    def test_matches_iterated_shard_updates(self):
        rng = np.random.default_rng(3)
        n, vc, ec = 24, 32, 128
        edges = _random_graph(rng, n, 80)
        col = np.zeros(ec, np.int32)
        seg = np.zeros(ec, np.int32)
        w = np.zeros(ec, np.float32)
        for i, (u, v) in enumerate(edges):
            col[i], seg[i], w[i] = u, v, 1.0
        out_deg = np.zeros(vc, np.float32)
        for u, _ in edges:
            out_deg[u] += 1
        inv = np.where(out_deg > 0, 1.0 / np.maximum(out_deg, 1), 0.0).astype(
            np.float32
        )
        ranks = model.pagerank_power(
            jnp.asarray(col), jnp.asarray(seg), jnp.asarray(w),
            jnp.asarray(inv), num_iters=10, num_vertices=n,
        )
        adj = np.zeros((n, n), np.float32)
        for u, v in edges:
            adj[u, v] = 1
        ref = pagerank_dense_ref(jnp.asarray(adj), jnp.asarray(adj.sum(1)), 10)
        np.testing.assert_allclose(np.asarray(ranks)[:n], np.asarray(ref), rtol=1e-4)
