"""Pallas kernels vs pure-jnp oracle: the core L1 correctness signal.

Hypothesis sweeps shapes, index patterns and value ranges; fixed cases pin
the padding conventions the rust coordinator relies on.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.spmv import (
    DEFAULT_BLOCK_E,
    seg_min_gather,
    seg_sum_gather,
    vmem_footprint_bytes,
)
from compile.kernels.ref import (
    pagerank_dense_ref,
    seg_min_gather_ref,
    seg_sum_gather_ref,
)

INF = np.float32(np.inf)


def _mk(rng, vc, ec, rc, w_mode="unit"):
    src = jnp.asarray(rng.random(vc, dtype=np.float32))
    deg = jnp.asarray(rng.random(vc, dtype=np.float32))
    col = jnp.asarray(rng.integers(0, vc, ec).astype(np.int32))
    seg = jnp.asarray(rng.integers(0, rc, ec).astype(np.int32))
    if w_mode == "unit":
        w = jnp.ones((ec,), jnp.float32)
    else:
        w = jnp.asarray(rng.random(ec, dtype=np.float32))
    return src, deg, col, seg, w


# ---------------------------------------------------------------- sum kernel


class TestSegSumGather:
    def test_single_block(self):
        rng = np.random.default_rng(1)
        src, deg, col, seg, w = _mk(rng, 32, 64, 8, "rand")
        out = seg_sum_gather(src, deg, col, seg, w, rows=8, block_e=64)
        ref = seg_sum_gather_ref(src, deg, col, seg, w, rows=8)
        np.testing.assert_allclose(out, ref, rtol=2e-5)

    def test_multi_block_accumulation(self):
        """Grid revisiting the output block must accumulate, not overwrite."""
        rng = np.random.default_rng(2)
        src, deg, col, seg, w = _mk(rng, 128, 4 * DEFAULT_BLOCK_E, 64, "rand")
        out = seg_sum_gather(src, deg, col, seg, w, rows=64)
        ref = seg_sum_gather_ref(src, deg, col, seg, w, rows=64)
        np.testing.assert_allclose(out, ref, rtol=2e-4)

    def test_padding_is_identity(self):
        """w=0 edges must contribute exactly nothing, whatever col/seg say."""
        rng = np.random.default_rng(3)
        src, deg, col, seg, w = _mk(rng, 32, 64, 8, "rand")
        col_pad = jnp.concatenate([col, jnp.full((64,), 31, jnp.int32)])
        seg_pad = jnp.concatenate([seg, jnp.full((64,), 7, jnp.int32)])
        w_pad = jnp.concatenate([w, jnp.zeros((64,), jnp.float32)])
        out = seg_sum_gather(src, deg, col_pad, seg_pad, w_pad, rows=8, block_e=128)
        ref = seg_sum_gather_ref(src, deg, col, seg, w, rows=8)
        np.testing.assert_allclose(out, ref, rtol=2e-5)

    def test_empty_segment_is_zero(self):
        src = jnp.ones((4,), jnp.float32)
        deg = jnp.ones((4,), jnp.float32)
        col = jnp.zeros((8,), jnp.int32)
        seg = jnp.zeros((8,), jnp.int32)  # only row 0 touched
        w = jnp.ones((8,), jnp.float32)
        out = seg_sum_gather(src, deg, col, seg, w, rows=4, block_e=8)
        assert float(out[0]) == pytest.approx(8.0)
        assert np.all(np.asarray(out[1:]) == 0.0)

    def test_all_edges_one_row(self):
        """Max-skew: every edge lands in one destination row."""
        rng = np.random.default_rng(4)
        vc, ec = 64, 2 * DEFAULT_BLOCK_E
        src = jnp.asarray(rng.random(vc, dtype=np.float32))
        deg = jnp.ones((vc,), jnp.float32)
        col = jnp.asarray(rng.integers(0, vc, ec).astype(np.int32))
        seg = jnp.full((ec,), 3, jnp.int32)
        w = jnp.ones((ec,), jnp.float32)
        out = seg_sum_gather(src, deg, col, seg, w, rows=8)
        ref = seg_sum_gather_ref(src, deg, col, seg, w, rows=8)
        np.testing.assert_allclose(out, ref, rtol=2e-4)

    def test_rejects_non_multiple_block(self):
        src = jnp.ones((4,), jnp.float32)
        with pytest.raises(ValueError, match="multiple"):
            seg_sum_gather(
                src, src,
                jnp.zeros((10,), jnp.int32),
                jnp.zeros((10,), jnp.int32),
                jnp.ones((10,), jnp.float32),
                rows=4,
                block_e=4,
            )

    @settings(max_examples=25, deadline=None)
    @given(
        vc=st.integers(2, 200),
        rc=st.integers(1, 64),
        log_e=st.integers(3, 10),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, vc, rc, log_e, seed):
        rng = np.random.default_rng(seed)
        ec = 2**log_e
        src, deg, col, seg, w = _mk(rng, vc, ec, rc, "rand")
        out = seg_sum_gather(src, deg, col, seg, w, rows=rc, block_e=min(ec, 256))
        ref = seg_sum_gather_ref(src, deg, col, seg, w, rows=rc)
        np.testing.assert_allclose(out, ref, rtol=5e-4, atol=1e-6)


# ---------------------------------------------------------------- min kernel


class TestSegMinGather:
    def test_single_block(self):
        rng = np.random.default_rng(5)
        src, _, col, seg, w = _mk(rng, 32, 64, 8, "rand")
        cur = jnp.asarray(rng.random(8, dtype=np.float32))
        out = seg_min_gather(src, col, seg, w, cur, block_e=64)
        ref = seg_min_gather_ref(src, col, seg, w, cur)
        np.testing.assert_allclose(out, ref)

    def test_multi_block(self):
        rng = np.random.default_rng(6)
        ec = 3 * DEFAULT_BLOCK_E
        src, _, col, seg, w = _mk(rng, 100, ec, 32, "rand")
        cur = jnp.asarray(rng.random(32, dtype=np.float32))
        out = seg_min_gather(src, col, seg, w, cur)
        ref = seg_min_gather_ref(src, col, seg, w, cur)
        np.testing.assert_allclose(out, ref)

    def test_inf_padding_is_identity(self):
        rng = np.random.default_rng(7)
        src, _, col, seg, w = _mk(rng, 32, 64, 8, "rand")
        cur = jnp.asarray(rng.random(8, dtype=np.float32))
        col_pad = jnp.concatenate([col, jnp.zeros((64,), jnp.int32)])
        seg_pad = jnp.concatenate([seg, jnp.zeros((64,), jnp.int32)])
        w_pad = jnp.concatenate([w, jnp.full((64,), INF)])
        out = seg_min_gather(src, col_pad, seg_pad, w_pad, cur, block_e=128)
        ref = seg_min_gather_ref(src, col, seg, w, cur)
        np.testing.assert_allclose(out, ref)

    def test_untouched_rows_keep_cur(self):
        """SSSP invariant: rows with no incoming active edge keep cur."""
        src = jnp.full((4,), INF)
        col = jnp.zeros((8,), jnp.int32)
        seg = jnp.zeros((8,), jnp.int32)
        w = jnp.ones((8,), jnp.float32)
        cur = jnp.asarray([0.0, 5.0, 7.0, INF], jnp.float32)
        out = seg_min_gather(src, col, seg, w, cur, block_e=8)
        np.testing.assert_allclose(out, cur)

    def test_sssp_relax_step(self):
        """Hand case: source at 0, edges 0->1 (w=2), 0->2 (w=5), 1->2 (w=1)."""
        src = jnp.asarray([0.0, INF, INF], jnp.float32)
        # shard covering rows {1, 2} locally {0, 1}
        col = jnp.asarray([0, 0, 1, 0], jnp.int32)
        seg = jnp.asarray([0, 1, 1, 0], jnp.int32)
        w = jnp.asarray([2.0, 5.0, 1.0, INF], jnp.float32)
        cur = jnp.asarray([INF, INF], jnp.float32)
        out = seg_min_gather(src, col, seg, w, cur, block_e=4)
        np.testing.assert_allclose(out, [2.0, 5.0])

    @settings(max_examples=25, deadline=None)
    @given(
        vc=st.integers(2, 200),
        rc=st.integers(1, 64),
        log_e=st.integers(3, 10),
        seed=st.integers(0, 2**31 - 1),
        inf_frac=st.floats(0.0, 0.9),
    )
    def test_hypothesis_matches_ref(self, vc, rc, log_e, seed, inf_frac):
        rng = np.random.default_rng(seed)
        ec = 2**log_e
        src, _, col, seg, w = _mk(rng, vc, ec, rc, "rand")
        # mix of +inf (unreached / padding) sources, the SSSP steady state
        src = jnp.where(jnp.asarray(rng.random(vc) < inf_frac), INF, src)
        cur = jnp.asarray(rng.random(rc, dtype=np.float32))
        out = seg_min_gather(src, col, seg, w, cur, block_e=min(ec, 256))
        ref = seg_min_gather_ref(src, col, seg, w, cur)
        np.testing.assert_allclose(out, ref)


# ------------------------------------------------------------------- dtypes


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_sum_dtype_sweep(dtype):
    if dtype == jnp.float64 and not jax.config.jax_enable_x64:
        pytest.skip("x64 disabled")
    rng = np.random.default_rng(8)
    src = jnp.asarray(rng.random(16), dtype)
    deg = jnp.ones((16,), dtype)
    col = jnp.asarray(rng.integers(0, 16, 32).astype(np.int32))
    seg = jnp.asarray(rng.integers(0, 4, 32).astype(np.int32))
    w = jnp.ones((32,), dtype)
    out = seg_sum_gather(src, deg, col, seg, w, rows=4, block_e=32)
    ref = seg_sum_gather_ref(src, deg, col, seg, w, rows=4)
    np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_vmem_footprint_within_budget():
    """DESIGN.md §Perf: every AOT variant's working set fits 16MiB VMEM."""
    from compile.aot import VARIANTS

    for name, vc, ec, rc in VARIANTS:
        for kern in ("sum", "min"):
            fp = vmem_footprint_bytes(vc, min(DEFAULT_BLOCK_E, ec), rc, kern)
            assert fp < 16 * 1024 * 1024, (name, kern, fp)


def test_pagerank_dense_ref_sums_to_one():
    rng = np.random.default_rng(9)
    n = 16
    adj = (rng.random((n, n)) < 0.3).astype(np.float32)
    np.fill_diagonal(adj, 0)
    deg = adj.sum(axis=1)
    # patch dangling vertices: paper's formulation just drops their mass,
    # so total sum < 1 when any out_deg == 0; give each a self-loop-free out
    ranks = pagerank_dense_ref(jnp.asarray(adj), jnp.asarray(deg), iters=30)
    assert np.all(np.asarray(ranks) > 0)
