"""Layer-2 JAX model: GraphMP's per-shard vertex updates.

Each function here is one fixed-shape compute graph that `aot.py` lowers to
HLO text for the rust runtime.  They all call the Layer-1 Pallas kernels in
``kernels/`` so kernel and surrounding arithmetic lower into a single HLO
module (one PJRT executable per shard update, no host round-trips inside).

Shapes are static per AOT *variant* (tiny/small/medium...):
  Vc -- padded vertex capacity (graph |V| rounded up; last slot is the
        sentinel: value 0 for sums, +inf for mins),
  Ec -- edge capacity of one shard (multiple of the kernel block size),
  Rc -- row capacity of one shard (max interval width).

The rust coordinator pads every shard to (Ec, Rc) with identity edges and
never recompiles at runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import seg_min_gather, seg_sum_gather


def pagerank_shard(src, inv_out_deg, col, seg, w, base, *, rows: int):
    """One VSW PageRank shard update (Algorithm 3, PR_Update).

    Args:
      src:          f32[Vc]  SrcVertexArray (current ranks).
      inv_out_deg:  f32[Vc]  1/out_degree (0 for dangling vertices).
      col, seg:     i32[Ec]  CSR edges of the shard (per-edge source id,
                             local destination row).
      w:            f32[Ec]  1 for real edges, 0 for padding.
      base:         f32[1]   (1-d)/|V| teleport term (|V| = real count).
      rows:         static row capacity Rc.
    Returns:
      f32[Rc] updated ranks for the shard's destination interval.
    """
    s = seg_sum_gather(src, inv_out_deg, col, seg, w, rows=rows)
    return base[0] + 0.85 * s


def relax_min_shard(src, col, seg, w, cur):
    """One VSW min-relaxation shard update (SSSP_Update / CC_Update).

    SSSP: src = distances, w = edge weights (+inf padding).
    CC:   src = component labels as f32, w = 0 (+inf padding).
    Returns f32[Rc] = min(cur, segment-min of src[col]+w).
    """
    return seg_min_gather(src, col, seg, w, cur)


def pagerank_power(col, seg, w, inv_out_deg, num_iters: int, num_vertices: int):
    """Full-graph fixed-iteration power PageRank (GraphMat-like baseline).

    The in-memory SpMV view: the whole edge list is one "shard" with
    seg = destination vertex id, iterated with lax.scan.  Used by the
    fig9/fig10 baseline path to show L2 can also host the entire app when
    the graph fits in memory.
    """
    n = num_vertices
    ranks0 = jnp.full((inv_out_deg.shape[0],), 1.0 / n, dtype=jnp.float32)
    base = (1.0 - 0.85) / n

    def step(ranks, _):
        s = seg_sum_gather(ranks, inv_out_deg, col, seg, w, rows=inv_out_deg.shape[0])
        new = base + 0.85 * s
        return new.astype(jnp.float32), ()

    ranks, _ = jax.lax.scan(step, ranks0, None, length=num_iters)
    return ranks


def build_pagerank_shard(rows: int):
    """Bind the static row capacity Rc into pagerank_shard for lowering."""

    def fn(src, inv_out_deg, col, seg, w, base):
        return (pagerank_shard(src, inv_out_deg, col, seg, w, base, rows=rows),)

    return fn


def build_relax_min_shard():
    def fn(src, col, seg, w, cur):
        return (seg_min_gather(src, col, seg, w, cur),)

    return fn


def build_pagerank_power(num_iters: int, num_vertices: int):
    def fn(col, seg, w, inv_out_deg):
        return (
            pagerank_power(col, seg, w, inv_out_deg, num_iters, num_vertices),
        )

    return fn
