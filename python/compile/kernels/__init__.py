"""Layer-1 Pallas kernels for GraphMP shard updates.

The per-shard vertex update of the VSW model is a sparse gather + segment
reduction over a CSR edge shard.  Two kernels cover the paper's three
applications:

- :func:`spmv.seg_sum_gather` -- PageRank's weighted neighbour sum.
- :func:`spmv.seg_min_gather` -- the min-relaxation shared by SSSP and CC.

Both are written with ``pallas_call(..., interpret=True)`` so they lower to
plain HLO and run on any PJRT backend (the rust CPU client included).  See
``ref.py`` for the pure-jnp oracles they are tested against.
"""

from .spmv import seg_min_gather, seg_sum_gather  # noqa: F401
