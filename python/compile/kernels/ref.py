"""Pure-jnp oracles for the Pallas shard kernels.

These are the CORE correctness signal: ``pytest python/tests`` sweeps the
Pallas kernels against these references over shapes, dtypes and adversarial
index patterns (hypothesis).  Keep them boring and obviously right.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def seg_sum_gather_ref(src, deg, col, seg, w, *, rows: int):
    """out[r] = sum over edges e with seg[e]==r of src[col[e]]*deg[col[e]]*w[e]."""
    contrib = src[col] * deg[col] * w
    return jax.ops.segment_sum(contrib, seg, num_segments=rows)


def seg_min_gather_ref(src, col, seg, w, cur):
    """out[r] = min(cur[r], min over edges e with seg[e]==r of src[col[e]]+w[e])."""
    rows = cur.shape[0]
    cand = src[col] + w
    relaxed = jax.ops.segment_min(cand, seg, num_segments=rows)
    return jnp.minimum(cur, relaxed)


def pagerank_dense_ref(out_adj, out_deg, iters: int, damping: float = 0.85):
    """Dense power-iteration PageRank on a tiny adjacency matrix.

    ``out_adj[u, v] = 1`` iff edge u->v.  Used to cross-check the full
    pipeline (kernel -> shard update -> iteration) on hand-sized graphs.
    """
    n = out_adj.shape[0]
    ranks = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    safe_deg = jnp.where(out_deg > 0, out_deg, 1.0)
    for _ in range(iters):
        contrib = ranks / safe_deg
        ranks = (1.0 - damping) / n + damping * (out_adj.T @ contrib)
    return ranks
