"""Pallas kernels: gather + segment reduction over an edge shard.

GraphMP stores each shard as CSR over the shard's destination-vertex
interval.  The rust coordinator flattens CSR ``row`` into a per-edge
segment id (``seg[e] = local row of edge e``), so the kernels only see
three flat arrays per shard:

- ``col[e]``  -- global source-vertex id of edge ``e`` (the CSR col array),
- ``seg[e]``  -- local destination row of edge ``e`` in ``[0, rows)``,
- ``w[e]``    -- edge weight (PageRank uses the gathered ``inv_out_deg``
                 instead; SSSP uses real weights; CC uses zeros).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the edge axis is blocked
with ``BlockSpec`` -- each grid step streams one ``block_e``-sized slab of
``col``/``seg``/``w`` from HBM into VMEM, while the full source-vertex
array and the output rows stay VMEM-resident across all grid steps (the
same "keep vertices in fast memory, stream edges" insight the paper applies
at the RAM/disk level).  The output block index map is constant, so the
segment accumulation revisits the same VMEM tile each step.

Padding convention (reduction identities, fixed AOT shapes):
- sum kernel: padding edges carry ``w = 0`` (contribution 0, any seg/col),
- min kernel: padding edges carry ``w = +inf``.

``interpret=True`` everywhere: real-TPU lowering emits Mosaic custom-calls
the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default edge-block size. 8192 edges * (4B col + 4B seg + 4B w) = 96KiB of
# streamed VMEM per step -- small next to the resident src array, and large
# enough that the gather dominates the block-switch overhead.
DEFAULT_BLOCK_E = 8192


def _sum_kernel(src_ref, deg_ref, col_ref, seg_ref, w_ref, out_ref):
    """One grid step: accumulate one edge block into the output rows.

    out[r] += sum_{e in block: seg[e]=r} src[col[e]] * deg[col[e]] * w[e]
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cols = col_ref[...]
    segs = seg_ref[...]
    # Gather from the VMEM-resident source-vertex arrays.
    src = src_ref[...]
    deg = deg_ref[...]
    contrib = src[cols] * deg[cols] * w_ref[...]
    out_ref[...] += jnp.zeros_like(out_ref).at[segs].add(contrib)


def _min_kernel(src_ref, col_ref, seg_ref, w_ref, cur_ref, out_ref):
    """One grid step of the min relaxation.

    out[r] = min(cur[r], min_{e in block: seg[e]=r} src[col[e]] + w[e])
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = cur_ref[...]

    cols = col_ref[...]
    segs = seg_ref[...]
    src = src_ref[...]
    cand = src[cols] + w_ref[...]
    inf = jnp.full_like(out_ref, jnp.inf)
    out_ref[...] = jnp.minimum(out_ref[...], inf.at[segs].min(cand))


def _edge_grid(num_edges: int, block_e: int) -> int:
    if num_edges % block_e != 0:
        raise ValueError(
            f"num_edges={num_edges} must be a multiple of block_e={block_e}; "
            "the rust coordinator pads shards to the artifact's edge capacity"
        )
    return num_edges // block_e


@functools.partial(jax.jit, static_argnames=("rows", "block_e"))
def seg_sum_gather(src, deg, col, seg, w, *, rows: int, block_e: int = DEFAULT_BLOCK_E):
    """PageRank shard reduction: ``out[r] = Σ src[col[e]]·deg[col[e]]·w[e]``.

    Args:
      src:  f32[Vc]  source-vertex values (SrcVertexArray slice-free: whole
            array; VSW keeps every vertex in memory).
      deg:  f32[Vc]  per-vertex multiplier, ``1/out_degree`` for PageRank.
      col:  i32[Ec]  per-edge source vertex ids.
      seg:  i32[Ec]  per-edge local destination rows, in ``[0, rows)``.
      w:    f32[Ec]  per-edge weight; 0 marks padding.
      rows: static number of destination rows (the artifact's Rc).
    Returns:
      f32[rows] summed contributions per destination row.
    """
    num_edges = col.shape[0]
    block_e = min(block_e, num_edges)
    grid = _edge_grid(num_edges, block_e)
    return pl.pallas_call(
        _sum_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(src.shape, lambda i: (0,)),          # resident
            pl.BlockSpec(deg.shape, lambda i: (0,)),          # resident
            pl.BlockSpec((block_e,), lambda i: (i,)),         # streamed
            pl.BlockSpec((block_e,), lambda i: (i,)),         # streamed
            pl.BlockSpec((block_e,), lambda i: (i,)),         # streamed
        ],
        out_specs=pl.BlockSpec((rows,), lambda i: (0,)),      # revisited
        out_shape=jax.ShapeDtypeStruct((rows,), src.dtype),
        interpret=True,
    )(src, deg, col, seg, w)


@functools.partial(jax.jit, static_argnames=("block_e",))
def seg_min_gather(src, col, seg, w, cur, *, block_e: int = DEFAULT_BLOCK_E):
    """SSSP/CC shard relaxation: ``out[r] = min(cur[r], min src[col[e]]+w[e])``.

    Args:
      src: f32[Vc] source-vertex values (distances / component labels).
      col: i32[Ec] per-edge source vertex ids.
      seg: i32[Ec] per-edge local destination rows.
      w:   f32[Ec] edge weights; +inf marks padding; zeros for CC.
      cur: f32[Rc] current values of the shard's destination rows.
    Returns:
      f32[Rc] relaxed values.
    """
    num_edges = col.shape[0]
    rows = cur.shape[0]
    block_e = min(block_e, num_edges)
    grid = _edge_grid(num_edges, block_e)
    return pl.pallas_call(
        _min_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(src.shape, lambda i: (0,)),          # resident
            pl.BlockSpec((block_e,), lambda i: (i,)),         # streamed
            pl.BlockSpec((block_e,), lambda i: (i,)),         # streamed
            pl.BlockSpec((block_e,), lambda i: (i,)),         # streamed
            pl.BlockSpec((rows,), lambda i: (0,)),            # resident
        ],
        out_specs=pl.BlockSpec((rows,), lambda i: (0,)),      # revisited
        out_shape=jax.ShapeDtypeStruct((rows,), src.dtype),
        interpret=True,
    )(src, col, seg, w, cur)


def vmem_footprint_bytes(vc: int, ec_block: int, rows: int, kernel: str) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf).

    Resident: src (+deg for sum) f32[Vc] and the f32[rows] output tile;
    streamed: one block of col/seg (i32) and w (f32).
    """
    resident = vc * 4 * (2 if kernel == "sum" else 1) + rows * 4
    if kernel == "min":
        resident += rows * 4  # cur tile
    streamed = ec_block * 4 * 3
    return resident + streamed
