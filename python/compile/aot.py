"""AOT-lower the Layer-2 shard updates to HLO text for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from /root/repo/python):
    python -m compile.aot --out-dir ../artifacts

Emits, per size variant:
    pagerank_shard_<v>.hlo.txt     (src, inv_out_deg, col, seg, w, base) -> (f32[Rc],)
    relax_min_shard_<v>.hlo.txt    (src, col, seg, w, cur)               -> (f32[Rc],)
    pagerank_power_<v>.hlo.txt     (col, seg, w, inv_out_deg)            -> (f32[Vc],)
plus ``manifest.txt`` -- one record per line, parsed by rust/src/runtime:
    artifact <name> variant=<v> vc=<Vc> ec=<Ec> rc=<Rc> iters=<n> path=<file>
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.spmv import vmem_footprint_bytes

# (name, Vc, Ec, Rc).  Vc covers the padded vertex count of the target
# graph; Ec/Rc are per-shard capacities.  Ec must be a multiple of the
# kernel block (8192).  Sized for the sim datasets in rust/src/graph.
VARIANTS = [
    ("tiny", 2_048, 8_192, 512),
    # "smalltight" trades chunking (shards wider than Ec are split and
    # partials combined) for 4x less gather padding per call — measured
    # ~2x faster on the pjrt backend for uk2007-sim-shaped shards (§Perf).
    ("smalltight", 65_536, 65_536, 8_192),
    ("small", 65_536, 262_144, 8_192),
    ("medium", 262_144, 1_048_576, 16_384),
    ("large", 1_048_576, 2_097_152, 32_768),
]

# Fixed-iteration in-memory power PageRank (GraphMat-like path): variant ->
# (edge capacity, iterations).  Only lowered for sizes small enough that a
# whole sim graph fits one executable.
POWER_VARIANTS = {"tiny": 10, "small": 10}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str, vc: int, ec: int, rc: int):
    """Yield (artifact_name, hlo_text, extra_manifest_fields) records."""
    f32 = jnp.float32
    i32 = jnp.int32
    sv = jax.ShapeDtypeStruct((vc,), f32)
    se = jax.ShapeDtypeStruct((ec,), i32)
    sw = jax.ShapeDtypeStruct((ec,), f32)
    sr = jax.ShapeDtypeStruct((rc,), f32)
    s1 = jax.ShapeDtypeStruct((1,), f32)

    pr = jax.jit(model.build_pagerank_shard(rc)).lower(sv, sv, se, se, sw, s1)
    yield f"pagerank_shard_{name}", to_hlo_text(pr), {}

    relax = jax.jit(model.build_relax_min_shard()).lower(sv, se, se, sw, sr)
    yield f"relax_min_shard_{name}", to_hlo_text(relax), {}

    if name in POWER_VARIANTS:
        iters = POWER_VARIANTS[name]
        power = jax.jit(model.build_pagerank_power(iters, vc)).lower(
            se, se, sw, sv
        )
        yield f"pagerank_power_{name}", to_hlo_text(power), {"iters": iters}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="tiny,smalltight,small,medium",
        help="comma list from {tiny,smalltight,small,medium,large}",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    wanted = set(args.variants.split(","))

    manifest_lines = []
    for name, vc, ec, rc in VARIANTS:
        if name not in wanted:
            continue
        for art_name, text, extra in lower_variant(name, vc, ec, rc):
            fname = f"{art_name}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            fields = [
                f"artifact {art_name}",
                f"variant={name}",
                f"vc={vc}",
                f"ec={ec}",
                f"rc={rc}",
            ]
            fields += [f"{k}={v}" for k, v in extra.items()]
            fields.append(f"path={fname}")
            manifest_lines.append(" ".join(fields))
            print(f"wrote {path} ({len(text)} chars)")
        for kern in ("sum", "min"):
            fp = vmem_footprint_bytes(vc, min(8192, ec), rc, kern)
            print(f"  variant={name} kernel={kern} est. VMEM/step = {fp/1024:.0f} KiB")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
