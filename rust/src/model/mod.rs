//! Analytical I/O cost models — Table 3 of the paper, in closed form.
//!
//! For each computation model (PSW/GraphChi, ESG/X-Stream, VSP/VENUS,
//! DSW/GridGraph, VSW/GraphMP) this gives per-iteration data read/write,
//! memory usage, and preprocessing I/O as functions of the graph
//! parameters.  `C` = vertex record size, `D` = edge record size, `P` =
//! shard/partition count, `d_avg` = average degree, `N` = CPU cores,
//! `θ` = GraphMP cache miss ratio.

/// Graph + system parameters feeding the closed forms.
#[derive(Clone, Copy, Debug)]
pub struct ModelParams {
    pub num_vertices: u64,
    pub num_edges: u64,
    /// Vertex record bytes (paper's C).
    pub c: u64,
    /// Edge record bytes (paper's D).
    pub d: u64,
    /// Number of shards / partitions (P).
    pub p: u64,
    /// CPU cores (N).
    pub n_cores: u64,
    /// GraphMP cache miss ratio θ ∈ [0,1].
    pub theta: f64,
}

impl ModelParams {
    pub fn new(num_vertices: u64, num_edges: u64, p: u64) -> Self {
        ModelParams {
            num_vertices,
            num_edges,
            c: 8, // paper's PageRank value type: double
            d: 8, // (src,dst) u32 pair
            p: p.max(1),
            n_cores: 12,
            theta: 1.0,
        }
    }

    pub fn d_avg(&self) -> f64 {
        self.num_edges as f64 / self.num_vertices.max(1) as f64
    }

    /// δ ≈ (1 − e^(−d_avg/P))·P  (VENUS v-shard expansion, Table 3).
    pub fn delta(&self) -> f64 {
        let p = self.p as f64;
        (1.0 - (-self.d_avg() / p).exp()) * p
    }
}

/// One row of Table 3 (bytes per iteration / resident bytes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostRow {
    pub data_read: f64,
    pub data_write: f64,
    pub memory: f64,
    pub prep_io: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ComputeModel {
    /// GraphChi's parallel sliding windows.
    Psw,
    /// X-Stream's edge-centric scatter-gather.
    Esg,
    /// VENUS's vertex-centric streamlined processing.
    Vsp,
    /// GridGraph's dual sliding windows.
    Dsw,
    /// GraphMP's vertex-centric sliding window.
    Vsw,
}

pub const ALL_MODELS: [ComputeModel; 5] = [
    ComputeModel::Psw,
    ComputeModel::Esg,
    ComputeModel::Vsp,
    ComputeModel::Dsw,
    ComputeModel::Vsw,
];

impl ComputeModel {
    pub fn name(&self) -> &'static str {
        match self {
            ComputeModel::Psw => "PSW (GraphChi)",
            ComputeModel::Esg => "ESG (X-Stream)",
            ComputeModel::Vsp => "VSP (VENUS)",
            ComputeModel::Dsw => "DSW (GridGraph)",
            ComputeModel::Vsw => "VSW (GraphMP)",
        }
    }

    /// The Table 3 closed forms.
    pub fn cost(&self, mp: &ModelParams) -> CostRow {
        let v = mp.num_vertices as f64;
        let e = mp.num_edges as f64;
        let c = mp.c as f64;
        let d = mp.d as f64;
        let p = mp.p as f64;
        let n = mp.n_cores as f64;
        match self {
            ComputeModel::Psw => CostRow {
                data_read: c * v + 2.0 * (c + d) * e,
                data_write: c * v + 2.0 * (c + d) * e,
                memory: (c * v + 2.0 * (c + d) * e) / p,
                prep_io: (c + 5.0 * d) * e,
            },
            ComputeModel::Esg => CostRow {
                data_read: c * v + (c + d) * e,
                data_write: c * v + c * e,
                memory: c * v / p,
                prep_io: 2.0 * d * e,
            },
            ComputeModel::Vsp => {
                let delta = mp.delta();
                CostRow {
                    data_read: c * (1.0 + delta) * v + d * e,
                    data_write: c * v,
                    memory: c * (2.0 + delta) * v / p,
                    prep_io: 4.0 * d * e,
                }
            }
            ComputeModel::Dsw => {
                let sqrt_p = p.sqrt();
                CostRow {
                    data_read: c * sqrt_p * v + d * e,
                    data_write: c * sqrt_p * v,
                    memory: 2.0 * c * v / sqrt_p,
                    prep_io: 6.0 * d * e,
                }
            }
            ComputeModel::Vsw => CostRow {
                data_read: mp.theta * d * e,
                data_write: 0.0,
                memory: 2.0 * c * v + n * d * e / p,
                prep_io: 5.0 * d * e,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ModelParams {
        // UK-2007-ish: 134M vertices, 5.5B edges, 256 shards
        ModelParams::new(134_000_000, 5_500_000_000, 256)
    }

    #[test]
    fn vsw_reads_least_writes_nothing() {
        let mp = params();
        let vsw = ComputeModel::Vsw.cost(&mp);
        assert_eq!(vsw.data_write, 0.0);
        for m in [ComputeModel::Psw, ComputeModel::Esg, ComputeModel::Vsp, ComputeModel::Dsw] {
            let row = m.cost(&mp);
            assert!(
                vsw.data_read <= row.data_read,
                "{}: VSW reads {} > {}",
                m.name(),
                vsw.data_read,
                row.data_read
            );
            assert!(row.data_write > 0.0);
        }
    }

    #[test]
    fn vsw_cache_scales_reads() {
        let mut mp = params();
        mp.theta = 0.2;
        let miss20 = ComputeModel::Vsw.cost(&mp).data_read;
        mp.theta = 1.0;
        let nocache = ComputeModel::Vsw.cost(&mp).data_read;
        assert!((miss20 - 0.2 * nocache).abs() < 1.0);
    }

    #[test]
    fn vsw_memory_higher_than_streaming_models() {
        // the paper's trade-off: VSW buys low I/O with more memory
        let mp = params();
        let vsw = ComputeModel::Vsw.cost(&mp).memory;
        let esg = ComputeModel::Esg.cost(&mp).memory;
        assert!(vsw > esg);
    }

    #[test]
    fn psw_heaviest_io() {
        let mp = params();
        let psw = ComputeModel::Psw.cost(&mp);
        for m in ALL_MODELS {
            let row = m.cost(&mp);
            assert!(psw.data_read + psw.data_write >= row.data_read + row.data_write);
        }
    }

    #[test]
    fn delta_bounded_by_p() {
        let mp = params();
        assert!(mp.delta() > 0.0);
        assert!(mp.delta() <= mp.p as f64);
    }

    #[test]
    fn prep_costs_match_paper_constants() {
        let mp = params();
        let e = mp.num_edges as f64;
        let d = mp.d as f64;
        assert_eq!(ComputeModel::Esg.cost(&mp).prep_io, 2.0 * d * e);
        assert_eq!(ComputeModel::Vsw.cost(&mp).prep_io, 5.0 * d * e);
        assert_eq!(ComputeModel::Dsw.cost(&mp).prep_io, 6.0 * d * e);
    }
}
