//! Graph core: edge lists, CSR, degree statistics, dataset registry.

pub mod datasets;
pub mod rmat;
pub mod stats;

/// Vertex ids are dense `u32` (the paper's datasets are relabelled the same
/// way by the LAW framework).
pub type VertexId = u32;

/// A directed edge `(src, dst)` with optional weight (SSSP uses weights;
/// PageRank/CC ignore them).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub src: VertexId,
    pub dst: VertexId,
    pub weight: f32,
}

impl Edge {
    pub fn new(src: VertexId, dst: VertexId) -> Self {
        Edge { src, dst, weight: 1.0 }
    }

    pub fn weighted(src: VertexId, dst: VertexId, weight: f32) -> Self {
        Edge { src, dst, weight }
    }
}

/// An in-memory edge list plus the vertex count.  The generators produce
/// this; the preprocessor consumes it (or its CSV serialisation).
#[derive(Clone, Debug, Default)]
pub struct EdgeList {
    pub num_vertices: u32,
    pub edges: Vec<Edge>,
}

impl EdgeList {
    pub fn new(num_vertices: u32) -> Self {
        EdgeList { num_vertices, edges: Vec::new() }
    }

    pub fn num_edges(&self) -> u64 {
        self.edges.len() as u64
    }

    /// Per-vertex in-degrees (preprocessing step 1 of the paper).
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices as usize];
        for e in &self.edges {
            deg[e.dst as usize] += 1;
        }
        deg
    }

    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices as usize];
        for e in &self.edges {
            deg[e.src as usize] += 1;
        }
        deg
    }

    /// Symmetrise for CC: the paper converts directed inputs to undirected
    /// graphs before running CC.  Self-duplicates are not removed (CSR
    /// min-reduction is idempotent, duplicates only cost I/O, matching how
    /// X-Stream/GridGraph treat symmetrised inputs).
    pub fn to_undirected(&self) -> EdgeList {
        let mut edges = Vec::with_capacity(self.edges.len() * 2);
        for e in &self.edges {
            edges.push(*e);
            edges.push(Edge::weighted(e.dst, e.src, e.weight));
        }
        EdgeList { num_vertices: self.num_vertices, edges }
    }

    /// Serialise as the CSV the paper's preprocessing pipelines ingest.
    pub fn to_csv(&self) -> String {
        let mut s = String::with_capacity(self.edges.len() * 16);
        for e in &self.edges {
            s.push_str(&format!("{},{}\n", e.src, e.dst));
        }
        s
    }

    /// Parse `src,dst[,weight]` CSV lines.
    pub fn from_csv(text: &str, num_vertices: u32) -> anyhow::Result<EdgeList> {
        let mut edges = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split(',');
            let parse = |s: Option<&str>| -> anyhow::Result<u32> {
                Ok(s.ok_or_else(|| anyhow::anyhow!("line {}: missing field", i + 1))?
                    .trim()
                    .parse()?)
            };
            let src = parse(it.next())?;
            let dst = parse(it.next())?;
            let weight = match it.next() {
                Some(w) => w.trim().parse()?,
                None => 1.0,
            };
            anyhow::ensure!(
                src < num_vertices && dst < num_vertices,
                "line {}: vertex id out of range",
                i + 1
            );
            edges.push(Edge::weighted(src, dst, weight));
        }
        Ok(EdgeList { num_vertices, edges })
    }
}

/// Compressed Sparse Row over destination rows — the in-memory form of one
/// edge shard (Figure 3 of the paper).  `row_offsets.len() == rows + 1`;
/// edge `e` of local row `r` has source `col[e]` for
/// `e in row_offsets[r]..row_offsets[r+1]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub row_offsets: Vec<u32>,
    pub col: Vec<VertexId>,
    /// Present only for weighted graphs (paper: unweighted graphs skip the
    /// val array entirely).
    pub weights: Option<Vec<f32>>,
}

impl Csr {
    pub fn rows(&self) -> usize {
        self.row_offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.col.len()
    }

    /// Build CSR from edges already restricted to destination interval
    /// `[start, start+rows)`.  Edges need not be pre-sorted.
    pub fn from_edges(edges: &[Edge], start: VertexId, rows: usize, weighted: bool) -> Csr {
        let mut counts = vec![0u32; rows];
        for e in edges {
            let r = (e.dst - start) as usize;
            assert!(r < rows, "edge dst {} outside interval", e.dst);
            counts[r] += 1;
        }
        let mut row_offsets = vec![0u32; rows + 1];
        for r in 0..rows {
            row_offsets[r + 1] = row_offsets[r] + counts[r];
        }
        let mut col = vec![0u32; edges.len()];
        let mut w = if weighted { vec![0.0f32; edges.len()] } else { Vec::new() };
        let mut cursor = row_offsets.clone();
        for e in edges {
            let r = (e.dst - start) as usize;
            let i = cursor[r] as usize;
            col[i] = e.src;
            if weighted {
                w[i] = e.weight;
            }
            cursor[r] += 1;
        }
        Csr {
            row_offsets,
            col,
            weights: if weighted { Some(w) } else { None },
        }
    }

    /// In-memory size in bytes (row + col + val arrays).
    pub fn size_bytes(&self) -> usize {
        self.row_offsets.len() * 4
            + self.col.len() * 4
            + self.weights.as_ref().map_or(0, |w| w.len() * 4)
    }

    /// Borrow the CSR arrays — see [`CsrRef`].
    pub fn slices(&self) -> CsrRef<'_> {
        CsrRef::from(self)
    }

    /// Iterate `(local_row, src, weight)` triples.
    pub fn iter_edges(&self) -> impl Iterator<Item = (u32, VertexId, f32)> + '_ {
        (0..self.rows()).flat_map(move |r| {
            let lo = self.row_offsets[r] as usize;
            let hi = self.row_offsets[r + 1] as usize;
            (lo..hi).map(move |i| {
                let w = self.weights.as_ref().map_or(1.0, |ws| ws[i]);
                (r as u32, self.col[i], w)
            })
        })
    }
}

/// Borrowed CSR arrays — the zero-copy counterpart of [`Csr`], produced
/// either from an owned `Csr` or straight out of a shard file buffer
/// (`storage::view::ShardView::csr_ref`).  The kernel hot loops consume
/// this form so owned and memory-mapped-style shards share one code path.
#[derive(Clone, Copy, Debug)]
pub struct CsrRef<'a> {
    pub row_offsets: &'a [u32],
    pub col: &'a [VertexId],
    pub weights: Option<&'a [f32]>,
}

impl CsrRef<'_> {
    pub fn rows(&self) -> usize {
        self.row_offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.col.len()
    }
}

impl<'a> From<&'a Csr> for CsrRef<'a> {
    fn from(c: &'a Csr) -> CsrRef<'a> {
        CsrRef {
            row_offsets: &c.row_offsets,
            col: &c.col,
            weights: c.weights.as_deref(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> EdgeList {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        EdgeList {
            num_vertices: 4,
            edges: vec![
                Edge::new(0, 1),
                Edge::new(0, 2),
                Edge::new(1, 3),
                Edge::new(2, 3),
            ],
        }
    }

    #[test]
    fn degrees() {
        let g = diamond();
        assert_eq!(g.in_degrees(), vec![0, 1, 1, 2]);
        assert_eq!(g.out_degrees(), vec![2, 1, 1, 0]);
    }

    #[test]
    fn undirected_doubles_edges() {
        let g = diamond().to_undirected();
        assert_eq!(g.num_edges(), 8);
        assert_eq!(g.in_degrees(), vec![2, 2, 2, 2]);
    }

    #[test]
    fn csv_round_trip() {
        let g = diamond();
        let parsed = EdgeList::from_csv(&g.to_csv(), 4).unwrap();
        assert_eq!(parsed.edges, g.edges);
    }

    #[test]
    fn csv_rejects_out_of_range() {
        assert!(EdgeList::from_csv("0,9\n", 4).is_err());
    }

    #[test]
    fn csv_weighted_and_comments() {
        let g = EdgeList::from_csv("# header\n0,1,2.5\n\n1,0\n", 2).unwrap();
        assert_eq!(g.edges[0].weight, 2.5);
        assert_eq!(g.edges[1].weight, 1.0);
    }

    #[test]
    fn csr_matches_figure3_shape() {
        // Figure 3 of the paper: row = [0,2,4,7,9]
        let edges = vec![
            Edge::new(5, 0), Edge::new(7, 0),
            Edge::new(1, 1), Edge::new(2, 1),
            Edge::new(0, 2), Edge::new(3, 2), Edge::new(9, 2),
            Edge::new(4, 3), Edge::new(8, 3),
        ];
        let csr = Csr::from_edges(&edges, 0, 4, false);
        assert_eq!(csr.row_offsets, vec![0, 2, 4, 7, 9]);
        assert_eq!(csr.col, vec![5, 7, 1, 2, 0, 3, 9, 4, 8]);
        assert!(csr.weights.is_none());
    }

    #[test]
    fn csr_interval_offset() {
        let edges = vec![Edge::new(0, 10), Edge::new(1, 11), Edge::new(2, 10)];
        let csr = Csr::from_edges(&edges, 10, 2, false);
        assert_eq!(csr.row_offsets, vec![0, 2, 3]);
        assert_eq!(csr.rows(), 2);
        let all: Vec<_> = csr.iter_edges().collect();
        assert_eq!(all, vec![(0, 0, 1.0), (0, 2, 1.0), (1, 1, 1.0)]);
    }

    #[test]
    fn csr_unsorted_input_ok() {
        let edges = vec![Edge::new(3, 1), Edge::new(2, 0), Edge::new(1, 1)];
        let csr = Csr::from_edges(&edges, 0, 2, false);
        assert_eq!(csr.row_offsets, vec![0, 1, 3]);
        assert_eq!(csr.col[0], 2);
    }

    #[test]
    fn csr_size_accounts_weights() {
        let edges = vec![Edge::weighted(0, 0, 2.0)];
        let a = Csr::from_edges(&edges, 0, 1, false).size_bytes();
        let b = Csr::from_edges(&edges, 0, 1, true).size_bytes();
        assert_eq!(b - a, 4);
    }
}
