//! Synthetic graph generators.
//!
//! The paper's datasets (Twitter, UK-2007/2014, EU-2015) are 25GB–1.7TB
//! crawls we cannot download; all four are power-law (Fig 6), and the
//! recursive-matrix (R-MAT, Chakrabarti et al.) generator reproduces that
//! skew, which is what drives shard balance, Bloom-filter selectivity and
//! edge compressibility.  See DESIGN.md "Substitutions".

use super::{Edge, EdgeList, VertexId};
use crate::util::rng::Xoshiro256;

/// R-MAT parameters. `(a, b, c)` are the quadrant probabilities
/// (`d = 1-a-b-c`); the classic power-law setting is `(0.57, 0.19, 0.19)`.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Perturbation of quadrant probabilities per level, avoids exact
    /// self-similarity artifacts.
    pub noise: f64,
    /// Weight range for SSSP inputs (uniform in `[1, max_weight]`).
    pub max_weight: f32,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19, noise: 0.1, max_weight: 16.0 }
    }
}

/// Generate an R-MAT graph with `2^scale` vertices and `num_edges` edges.
/// Self-loops are redirected; duplicate edges are kept (real crawls contain
/// parallel link structure after relabelling too).
pub fn rmat(scale: u32, num_edges: u64, seed: u64, params: RmatParams) -> EdgeList {
    assert!(scale > 0 && scale < 32, "scale must be in (0, 32)");
    let n: u64 = 1 << scale;
    let mut rng = Xoshiro256::new(seed);
    let mut edges = Vec::with_capacity(num_edges as usize);
    for _ in 0..num_edges {
        let (mut u, mut v) = (0u64, 0u64);
        let mut half = n >> 1;
        // per-edge jitter of the quadrant probabilities
        let jitter = |p: f64, r: &mut Xoshiro256, noise: f64| {
            p * (1.0 - noise + 2.0 * noise * r.next_f64())
        };
        while half > 0 {
            let a = jitter(params.a, &mut rng, params.noise);
            let b = jitter(params.b, &mut rng, params.noise);
            let c = jitter(params.c, &mut rng, params.noise);
            let d = (1.0 - params.a - params.b - params.c).max(0.0);
            let d = jitter(d, &mut rng, params.noise);
            let total = a + b + c + d;
            let r = rng.next_f64() * total;
            if r < a {
                // top-left: nothing to add
            } else if r < a + b {
                v += half;
            } else if r < a + b + c {
                u += half;
            } else {
                u += half;
                v += half;
            }
            half >>= 1;
        }
        if u == v {
            v = (v + 1) % n; // redirect self-loop
        }
        let weight = 1.0 + rng.next_below(params.max_weight as u64) as f32;
        edges.push(Edge::weighted(u as VertexId, v as VertexId, weight));
    }
    EdgeList { num_vertices: n as u32, edges }
}

/// Erdős–Rényi-style uniform random graph (non-power-law control for the
/// ablation benches).
pub fn uniform(num_vertices: u32, num_edges: u64, seed: u64) -> EdgeList {
    assert!(num_vertices >= 2);
    let mut rng = Xoshiro256::new(seed);
    let mut edges = Vec::with_capacity(num_edges as usize);
    for _ in 0..num_edges {
        let u = rng.next_below(num_vertices as u64) as VertexId;
        let mut v = rng.next_below(num_vertices as u64) as VertexId;
        if v == u {
            v = (v + 1) % num_vertices;
        }
        let weight = 1.0 + rng.next_below(16) as f32;
        edges.push(Edge::weighted(u, v, weight));
    }
    EdgeList { num_vertices, edges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn rmat_deterministic() {
        let a = rmat(8, 1000, 1, RmatParams::default());
        let b = rmat(8, 1000, 1, RmatParams::default());
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn rmat_counts() {
        let g = rmat(10, 5000, 2, RmatParams::default());
        assert_eq!(g.num_vertices, 1024);
        assert_eq!(g.num_edges(), 5000);
    }

    #[test]
    fn rmat_no_self_loops() {
        let g = rmat(9, 4000, 3, RmatParams::default());
        assert!(g.edges.iter().all(|e| e.src != e.dst));
    }

    #[test]
    fn rmat_ids_in_range() {
        let g = rmat(7, 2000, 4, RmatParams::default());
        assert!(g.edges.iter().all(|e| e.src < 128 && e.dst < 128));
    }

    #[test]
    fn rmat_is_skewed_vs_uniform() {
        // Power-law check: RMAT's max in-degree far exceeds uniform's.
        let r = rmat(12, 40_000, 5, RmatParams::default());
        let u = uniform(4096, 40_000, 5);
        let rmax = *r.in_degrees().iter().max().unwrap();
        let umax = *u.in_degrees().iter().max().unwrap();
        assert!(
            rmax > 3 * umax,
            "rmat max in-degree {rmax} not ≫ uniform {umax}"
        );
        // and a heavier tail in the log-binned histogram
        let hist = stats::degree_histogram(&r.in_degrees());
        assert!(hist.len() >= 6, "expected a long-tailed histogram");
    }

    #[test]
    fn weights_in_declared_range() {
        let g = rmat(8, 3000, 6, RmatParams::default());
        assert!(g.edges.iter().all(|e| (1.0..=16.0).contains(&e.weight)));
    }

    #[test]
    fn uniform_counts() {
        let g = uniform(100, 1000, 7);
        assert_eq!(g.num_vertices, 100);
        assert_eq!(g.num_edges(), 1000);
        assert!(g.edges.iter().all(|e| e.src != e.dst));
    }
}
