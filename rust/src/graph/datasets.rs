//! Registry of the sim datasets standing in for the paper's four graphs.
//!
//! Scaled so the whole evaluation runs on one core in minutes while keeping
//! the paper's *ratios*: average degrees (35/41/60/86), the ~1.8×
//! vertex-count step Twitter→UK-2007, and the size ordering that makes
//! UK-2014/EU-2015 exceed the simulated RAM budget (so the cache-mode and
//! out-of-memory effects reproduce).  See `storage::disk` for the RAM/disk
//! model that pairs with these.

use super::rmat::{rmat, RmatParams};
use super::EdgeList;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    TwitterSim,
    Uk2007Sim,
    Uk2014Sim,
    Eu2015Sim,
}

pub const ALL: [Dataset; 4] = [
    Dataset::TwitterSim,
    Dataset::Uk2007Sim,
    Dataset::Uk2014Sim,
    Dataset::Eu2015Sim,
];

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::TwitterSim => "twitter-sim",
            Dataset::Uk2007Sim => "uk2007-sim",
            Dataset::Uk2014Sim => "uk2014-sim",
            Dataset::Eu2015Sim => "eu2015-sim",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        ALL.into_iter().find(|d| d.name() == s)
    }

    /// (scale, edges, avg-degree target). Paper: Twitter 42M/1.5B (d̄ 35),
    /// UK-2007 134M/5.5B (41), UK-2014 788M/47.6B (60), EU-2015 1.1B/91.8B
    /// (86).  We scale vertices by ~2¹², keeping d̄.
    pub fn spec(&self) -> (u32, u64, u64) {
        match self {
            Dataset::TwitterSim => (14, 560_000, 101),   // 16K vertices, d̄≈34
            Dataset::Uk2007Sim => (15, 1_340_000, 102),  // 32K vertices, d̄≈41
            Dataset::Uk2014Sim => (17, 7_800_000, 103),  // 131K vertices, d̄≈60
            Dataset::Eu2015Sim => (18, 22_400_000, 104), // 262K vertices, d̄≈85
        }
    }

    /// Generate the dataset (deterministic per-dataset seed).
    pub fn generate(&self) -> EdgeList {
        let (scale, edges, seed) = self.spec();
        rmat(scale, edges, seed, RmatParams::default())
    }

    /// A scaled-down twin (same degree structure, ~8x fewer edges) used by
    /// unit/integration tests to stay fast.
    pub fn generate_small(&self) -> EdgeList {
        let (scale, edges, seed) = self.spec();
        rmat(scale.saturating_sub(3).max(8), edges / 8, seed, RmatParams::default())
    }

    /// AOT artifact variant whose Vc covers this dataset's vertex count.
    pub fn artifact_variant(&self) -> &'static str {
        match self {
            Dataset::TwitterSim | Dataset::Uk2007Sim => "small",
            Dataset::Uk2014Sim | Dataset::Eu2015Sim => "medium",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for d in ALL {
            assert_eq!(Dataset::parse(d.name()), Some(d));
        }
        assert_eq!(Dataset::parse("nope"), None);
    }

    #[test]
    fn average_degrees_match_paper_ratios() {
        // paper avg degrees: 35.3, 41.2, 60.4, 85.7
        let want = [34.0, 41.0, 59.0, 85.0];
        for (d, w) in ALL.iter().zip(want) {
            let (scale, edges, _) = d.spec();
            let avg = edges as f64 / (1u64 << scale) as f64;
            assert!((avg - w).abs() < 3.0, "{}: avg degree {avg} vs {w}", d.name());
        }
    }

    #[test]
    fn sizes_strictly_increase() {
        let sizes: Vec<u64> = ALL.iter().map(|d| d.spec().1).collect();
        assert!(sizes.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn small_twin_generates() {
        let g = Dataset::TwitterSim.generate_small();
        assert!(g.num_edges() > 10_000);
    }
}
