//! Degree statistics — regenerates Table 4 rows and Figure 6 series.

use super::EdgeList;

/// Summary row matching Table 4 of the paper.
#[derive(Clone, Debug)]
pub struct GraphStats {
    pub num_vertices: u32,
    pub num_edges: u64,
    pub avg_degree: f64,
    pub max_in_degree: u32,
    pub max_out_degree: u32,
    pub csv_bytes: u64,
}

pub fn stats(g: &EdgeList) -> GraphStats {
    let ind = g.in_degrees();
    let outd = g.out_degrees();
    // CSV size estimated from actual digit counts, no materialisation.
    let csv_bytes: u64 = g
        .edges
        .iter()
        .map(|e| digits(e.src) + digits(e.dst) + 2)
        .sum();
    GraphStats {
        num_vertices: g.num_vertices,
        num_edges: g.num_edges(),
        avg_degree: g.num_edges() as f64 / g.num_vertices.max(1) as f64,
        max_in_degree: ind.iter().copied().max().unwrap_or(0),
        max_out_degree: outd.iter().copied().max().unwrap_or(0),
        csv_bytes,
    }
}

fn digits(x: u32) -> u64 {
    let mut n = 1;
    let mut x = x;
    while x >= 10 {
        x /= 10;
        n += 1;
    }
    n
}

/// Log₂-binned degree histogram: `hist[b] = #vertices with degree in
/// [2^b, 2^(b+1))`; degree-0 vertices are dropped (log axis, as in Fig 6).
pub fn degree_histogram(degrees: &[u32]) -> Vec<(u32, u64)> {
    let mut bins: Vec<u64> = Vec::new();
    for &d in degrees {
        if d == 0 {
            continue;
        }
        let b = 31 - d.leading_zeros();
        if bins.len() <= b as usize {
            bins.resize(b as usize + 1, 0);
        }
        bins[b as usize] += 1;
    }
    bins.into_iter()
        .enumerate()
        .filter(|(_, c)| *c > 0)
        .map(|(b, c)| (1u32 << b, c))
        .collect()
}

/// Least-squares slope of `log(count)` vs `log(degree)` over the histogram
/// — a power law shows up as a clearly negative slope (Fig 6's straight
/// line in log-log space).
pub fn powerlaw_slope(hist: &[(u32, u64)]) -> f64 {
    let pts: Vec<(f64, f64)> = hist
        .iter()
        .map(|&(d, c)| ((d as f64).ln(), (c as f64).ln()))
        .collect();
    let n = pts.len() as f64;
    if pts.len() < 2 {
        return 0.0;
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::Edge;

    #[test]
    fn stats_of_small_graph() {
        let g = EdgeList {
            num_vertices: 3,
            edges: vec![Edge::new(0, 1), Edge::new(0, 2), Edge::new(1, 2)],
        };
        let s = stats(&g);
        assert_eq!(s.num_edges, 3);
        assert_eq!(s.max_out_degree, 2);
        assert_eq!(s.max_in_degree, 2);
        assert!((s.avg_degree - 1.0).abs() < 1e-9);
        // "0,1\n" = 4 bytes per edge here
        assert_eq!(s.csv_bytes, 12);
    }

    #[test]
    fn histogram_bins() {
        let hist = degree_histogram(&[0, 1, 1, 2, 3, 4, 9]);
        assert_eq!(hist, vec![(1, 2), (2, 2), (4, 1), (8, 1)]);
    }

    #[test]
    fn rmat_slope_is_negative() {
        let g = rmat(12, 60_000, 8, RmatParams::default());
        let hist = degree_histogram(&g.in_degrees());
        let slope = powerlaw_slope(&hist);
        assert!(slope < -0.5, "slope {slope} not power-law-like");
    }
}
