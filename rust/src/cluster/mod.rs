//! Cluster simulator — the distributed baselines of Tables 5–7.
//!
//! The paper runs Pregel+, PowerGraph, PowerLyra (distributed in-memory)
//! and GraphD, Chaos (distributed out-of-core) on 9 R720 servers over
//! 10Gbps Ethernet.  We cannot run those systems, so this module
//! *simulates* each on the same workload: the graph is actually
//! partitioned, per-machine compute is really executed (same vertex math
//! as every other engine), cross-machine messages are really counted, and
//! iteration time is modelled as
//!
//! `t = max_m(compute_m) + bytes_network / net_bw + barrier`
//!
//! plus per-machine streamed-disk time for the out-of-core engines.  This
//! preserves what Tables 5–7 need: the *relative standing* (distributed
//! in-memory ≈ GraphMP on small graphs, OOM-crash on big ones; distributed
//! out-of-core completes but loses to GraphMP-cache by ~8–27×).

use std::time::Instant;

use anyhow::Result;

use crate::apps::{EdgeCost, EdgeGather, ShardKernel, VertexProgram};
use crate::baselines::{count_updates_lane, inv_out_degrees, sweep_lane, C_VERTEX, D_EDGE};
use crate::exec::LaneVec;
use crate::graph::{Edge, EdgeList};
use crate::metrics::{IterationMetrics, RunMetrics};

/// Cluster hardware model (defaults = the paper's 9-node testbed).
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub machines: u32,
    /// Per-machine RAM in bytes (paper: 128GB each → scaled by the bench).
    pub ram_per_machine: u64,
    /// Network bandwidth in bytes/s (10Gbps).
    pub net_bw: u64,
    /// Per-iteration synchronisation barrier cost in seconds.
    pub barrier_seconds: f64,
    /// Per-machine disk bandwidth for out-of-core engines (bytes/s).
    pub disk_bw: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            machines: 9,
            ram_per_machine: u64::MAX,
            net_bw: 10 * 1024 * 1024 * 1024 / 8,
            // BSP synchronisation on 10GbE with stragglers: ~20ms/round
            barrier_seconds: 0.020,
            // per-core share of each machine's RAID array (same scaling
            // argument as benchutil::scale::bench_disk)
            disk_bw: 310 * 1024 * 1024 / 12,
        }
    }
}

/// Which distributed system is being simulated.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DistSystem {
    /// Pregel-like: hash vertex partitioning, messages along edges.
    PregelPlus,
    /// GAS vertex-cut: better balance on power-law, replica sync traffic.
    PowerGraph,
    /// GAS with differentiated (hybrid) cuts: lower replication.
    PowerLyra,
    /// Distributed out-of-core, vertex-centric (edges streamed from disk).
    GraphD,
    /// Distributed out-of-core, edge-centric (X-Stream scaled out; edges
    /// also shuffled over the network).
    Chaos,
}

pub const ALL_SYSTEMS: [DistSystem; 5] = [
    DistSystem::PregelPlus,
    DistSystem::PowerGraph,
    DistSystem::PowerLyra,
    DistSystem::GraphD,
    DistSystem::Chaos,
];

impl DistSystem {
    pub fn name(&self) -> &'static str {
        match self {
            DistSystem::PregelPlus => "pregel+",
            DistSystem::PowerGraph => "powergraph",
            DistSystem::PowerLyra => "powerlyra",
            DistSystem::GraphD => "graphd",
            DistSystem::Chaos => "chaos",
        }
    }

    pub fn is_in_memory(&self) -> bool {
        matches!(
            self,
            DistSystem::PregelPlus | DistSystem::PowerGraph | DistSystem::PowerLyra
        )
    }

    /// Per-edge processing cost in seconds per machine, calibrated from
    /// the paper's measured Table 5 throughputs (e.g. Pregel+ on Twitter:
    /// 6.9 s/iteration × 9 machines / 1.5B edges ≈ 41 ns/edge).  These are
    /// framework costs (message construction, (de)serialisation, vertex
    /// dispatch) — far above a bare SpMV loop, which is why distributed
    /// engines need 9 machines to match one tight single-machine engine.
    /// The calibration workload is PageRank; other kernels scale through
    /// [`per_edge_cost_for`](Self::per_edge_cost_for).
    pub fn per_edge_cost(&self) -> f64 {
        match self {
            DistSystem::PregelPlus => 41e-9,
            DistSystem::PowerGraph => 33e-9,
            DistSystem::PowerLyra => 28e-9,
            DistSystem::GraphD => 41e-9, // Pregel-style compute + disk below
            DistSystem::Chaos => 33e-9,  // X-Stream-style streaming compute
        }
    }

    /// Kernel-adjusted per-edge cost: the PageRank-calibrated base times
    /// the kernel's gather-class factor ([`kernel_cost_factor`]).
    pub fn per_edge_cost_for(&self, kernel: &ShardKernel) -> f64 {
        self.per_edge_cost() * kernel_cost_factor(kernel)
    }

    /// Whether compute scales with the active fraction (vertex-level
    /// selective execution: Pregel+/GraphD process only active vertices;
    /// the GAS engines and Chaos sweep everything each round).
    pub fn active_scaled(&self) -> bool {
        matches!(self, DistSystem::PregelPlus | DistSystem::GraphD)
    }
}

/// Relative per-edge compute cost of a kernel against the PageRank-family
/// gather the Table 5 calibration anchors.  PPR shares PageRank's gather
/// (`DegreeMass`) exactly — only its teleport differs, and that is
/// per-vertex, not per-edge — so it inherits factor 1.  Unweighted path
/// relaxations (BFS/CC) skip the degree lookup; weighted ones (SSSP)
/// fetch the edge weight; capacity gathers (widest path) fetch the
/// weight *and* take the extra `min` of the max–min relaxation.
pub fn kernel_cost_factor(kernel: &ShardKernel) -> f64 {
    match kernel.gather {
        EdgeGather::DegreeMass => 1.0,
        EdgeGather::AddCost(EdgeCost::Weights) => 1.05,
        EdgeGather::AddCost(_) => 0.9,
        EdgeGather::MinCapacity(_) => 1.2,
        // alive-flag test, no weight fetch — as cheap as an unweighted add
        EdgeGather::Indicator => 0.9,
    }
}

/// Per-message payload bytes of a kernel's updates: rank mass travels as
/// the paper's C-byte (double) vertex record; path/capacity relaxations
/// ship one f32 candidate.
pub fn message_payload_bytes(kernel: &ShardKernel) -> f64 {
    match kernel.gather {
        EdgeGather::DegreeMass => C_VERTEX as f64,
        // relaxation candidates and alive indicators both ship 4 bytes
        // (f32 or u32 — same width on the wire)
        EdgeGather::AddCost(_) | EdgeGather::MinCapacity(_) | EdgeGather::Indicator => 4.0,
    }
}

/// A simulated distributed engine bound to one partitioned workload.
pub struct DistEngine {
    pub system: DistSystem,
    pub cfg: ClusterConfig,
    g: EdgeList,
    inv_out_deg: Vec<f32>,
    /// machine of each vertex (hash partitioning).
    owner: Vec<u32>,
    /// per-machine edge count (edges live with their destination owner for
    /// Pregel-like, balanced for GAS).
    machine_edges: Vec<u64>,
    /// edges whose source and destination live on different machines.
    cross_edges: u64,
    values: LaneVec,
    /// estimated replication factor (GAS systems).
    replication: f64,
}

impl DistEngine {
    pub fn new(system: DistSystem, cfg: ClusterConfig, g: EdgeList) -> Result<DistEngine> {
        let m = cfg.machines.max(1);
        let inv_out_deg = inv_out_degrees(&g);
        // hash partitioning (Pregel's default): owner = id % machines
        let owner: Vec<u32> = (0..g.num_vertices).map(|v| v % m).collect();
        let mut machine_edges = vec![0u64; m as usize];
        let mut cross_edges = 0u64;
        for e in &g.edges {
            machine_edges[owner[e.dst as usize] as usize] += 1;
            if owner[e.src as usize] != owner[e.dst as usize] {
                cross_edges += 1;
            }
        }
        // GAS replication factor: expected #machines holding a replica of
        // a vertex ≈ Σ_v min(deg_v, M) / |V| — computed exactly here.
        let mut repl_sum = 0u64;
        let ind = g.in_degrees();
        let outd = g.out_degrees();
        for v in 0..g.num_vertices as usize {
            let deg = ind[v] as u64 + outd[v] as u64;
            repl_sum += deg.min(m as u64).max(1);
        }
        let replication = repl_sum as f64 / g.num_vertices.max(1) as f64;

        let eng = DistEngine {
            system,
            cfg,
            inv_out_deg,
            owner,
            machine_edges,
            cross_edges,
            values: LaneVec::from(Vec::<f32>::new()),
            replication,
            g,
        };
        eng.check_memory()?;
        Ok(eng)
    }

    /// Per-machine residency model; OOM reproduces the paper's crashes of
    /// Pregel+/PowerGraph/PowerLyra on UK-2014 and EU-2015.
    fn check_memory(&self) -> Result<()> {
        if !self.system.is_in_memory() {
            return Ok(()); // out-of-core engines stream from disk
        }
        let m = self.cfg.machines as u64;
        let v = self.g.num_vertices as u64;
        let e = self.g.num_edges();
        let per_machine = match self.system {
            // vertices + their edges + message buffers
            DistSystem::PregelPlus => (C_VERTEX * v + (C_VERTEX + D_EDGE) * e * 2) / m,
            // replicated vertices + edges
            DistSystem::PowerGraph => {
                ((C_VERTEX as f64 * v as f64 * self.replication) as u64 + D_EDGE * e * 2) / m
            }
            DistSystem::PowerLyra => {
                ((C_VERTEX as f64 * v as f64 * (1.0 + 0.7 * (self.replication - 1.0))) as u64
                    + D_EDGE * e * 2)
                    / m
            }
            _ => unreachable!(),
        };
        anyhow::ensure!(
            per_machine <= self.cfg.ram_per_machine,
            "OOM: {} needs {} bytes/machine, budget {}",
            self.system.name(),
            per_machine,
            self.cfg.ram_per_machine
        );
        Ok(())
    }

    /// Simulated network seconds for one iteration, given how many values
    /// actually changed (message-generating vertices) and the kernel
    /// (payload size differs: rank records vs f32 relaxation candidates).
    fn network_seconds(&self, active_frac: f64, kernel: &ShardKernel) -> f64 {
        let msg_bytes = match self.system {
            // one message per cross-partition edge whose source is active:
            // 4B destination id + the kernel's payload
            DistSystem::PregelPlus | DistSystem::GraphD => {
                (self.cross_edges as f64 * active_frac) * (4.0 + message_payload_bytes(kernel))
            }
            // GAS: gather+apply+scatter sync per replica
            DistSystem::PowerGraph => {
                self.g.num_vertices as f64 * (self.replication - 1.0).max(0.0)
                    * C_VERTEX as f64
                    * 2.0
                    * active_frac.max(0.05)
            }
            DistSystem::PowerLyra => {
                self.g.num_vertices as f64 * 0.7 * (self.replication - 1.0).max(0.0)
                    * C_VERTEX as f64
                    * 2.0
                    * active_frac.max(0.05)
            }
            // Chaos streams edges over the network too (storage/compute
            // disaggregation)
            DistSystem::Chaos => (self.g.num_edges() as f64) * D_EDGE as f64,
        };
        msg_bytes / self.cfg.net_bw as f64
    }

    /// Simulated per-machine disk seconds per iteration (out-of-core only).
    fn disk_seconds(&self, active_frac: f64, kernel: &ShardKernel) -> f64 {
        let per_machine_edges =
            self.machine_edges.iter().copied().max().unwrap_or(0) as f64;
        match self.system {
            DistSystem::GraphD => {
                // stream edges + write/read the recoverable message
                // streams (message volume tracks the active frontier and
                // the kernel's payload size)
                let bytes = per_machine_edges
                    * (D_EDGE as f64
                        + 2.0 * message_payload_bytes(kernel) * active_frac.max(0.05));
                bytes / self.cfg.disk_bw as f64
            }
            DistSystem::Chaos => {
                // scatter + gather passes over edge/update files
                let bytes =
                    per_machine_edges * (D_EDGE as f64 + message_payload_bytes(kernel));
                bytes / self.cfg.disk_bw as f64
            }
            _ => 0.0,
        }
    }

    /// One-time load/initialisation charged to the first iteration (the
    /// paper's Tables 5–7 include data loading in iteration 1 for every
    /// system): each machine reads its partition from disk and builds its
    /// in-memory/stream structures.
    fn load_seconds(&self) -> f64 {
        let per_machine_edges =
            self.machine_edges.iter().copied().max().unwrap_or(0) as f64;
        let read = per_machine_edges * D_EDGE as f64 / self.cfg.disk_bw as f64;
        // structure build ≈ 2 passes at the framework's per-edge rate
        let build = per_machine_edges * self.system.per_edge_cost() * 2.0;
        read + build
    }

    /// Run `app` for `iters` iterations, returning per-iteration simulated
    /// times.  The vertex math runs for real (values are exact and
    /// cross-checked against the single-machine engines); iteration *time*
    /// is simulated from per-edge framework costs calibrated to the
    /// paper's published numbers plus real message counts, the network
    /// model and the streamed-disk model.
    pub fn run(&mut self, app: &dyn VertexProgram, iters: u32) -> Result<RunMetrics> {
        let n = self.g.num_vertices;
        let kernel = app.kernel();
        let (mut src, active0) = app.init(n);
        let mut active = active0.len() as u64;
        let mut run = RunMetrics::default();
        let start = Instant::now();
        // effective parallelism: M * (avg edges per machine / max edges)
        let max_e = self.machine_edges.iter().copied().max().unwrap_or(1) as f64;
        let avg_e = self.g.num_edges() as f64 / self.cfg.machines.max(1) as f64;
        let balance = (avg_e / max_e.max(1.0)).min(1.0);
        let eff_machines = match self.system {
            // GAS systems split high-degree vertices → near-perfect balance
            DistSystem::PowerGraph | DistSystem::PowerLyra => self.cfg.machines as f64,
            _ => (self.cfg.machines as f64 * balance).max(1.0),
        };
        for iter in 0..iters {
            if active == 0 {
                run.converged = true;
                break;
            }
            let t0 = Instant::now();
            let active_frac = active as f64 / n.max(1) as f64;
            let dst = sweep_lane(
                adapt_kind(kernel),
                &self.g.edges,
                n,
                &self.inv_out_deg,
                &src,
            );
            let compute_wall = t0.elapsed().as_secs_f64();
            let compute_scale = if self.system.active_scaled() {
                active_frac.max(0.01)
            } else {
                1.0
            };
            let compute_sim = self.g.num_edges() as f64
                * self.system.per_edge_cost_for(&kernel)
                * compute_scale
                / eff_machines;
            let mut sim = compute_sim
                + self.network_seconds(active_frac, &kernel)
                + self.disk_seconds(active_frac, &kernel)
                + self.cfg.barrier_seconds;
            if iter == 0 {
                sim += self.load_seconds();
            }
            active = count_updates_lane(app, &src, &dst);
            src = dst;
            run.iterations.push(IterationMetrics {
                iteration: iter,
                wall: std::time::Duration::from_secs_f64(compute_wall),
                sim_disk_seconds: sim - compute_wall, // report sim − wall so
                // elapsed_seconds() == simulated cluster time
                active_vertices: active,
                active_ratio: active as f64 / n.max(1) as f64,
                shards_processed: self.cfg.machines,
                shards_skipped: 0,
                io: Default::default(),
                cache: Default::default(),
                ..Default::default()
            });
        }
        if active == 0 {
            run.converged = true;
        }
        run.total_wall = start.elapsed();
        run.total_sim_disk_seconds =
            run.iterations.iter().map(|m| m.sim_disk_seconds).sum();
        run.memory_bytes = 0;
        self.values = src;
        Ok(run)
    }

    pub fn values(&self) -> &[f32] {
        self.values.f32s()
    }

    /// Final values in the app's lane type (integer apps included).
    pub fn values_lane(&self) -> &LaneVec {
        &self.values
    }

    pub fn replication_factor(&self) -> f64 {
        self.replication
    }

    pub fn cross_edge_ratio(&self) -> f64 {
        self.cross_edges as f64 / self.g.num_edges().max(1) as f64
    }
}

/// Distributed engines run the same math; kernels pass through unchanged
/// (hook point for system-specific semantics, e.g. combiner rounding).
fn adapt_kind(kernel: ShardKernel) -> ShardKernel {
    kernel
}

/// Convenience: partition quality diagnostics used by the benches.
pub fn partition_stats(g: &EdgeList, machines: u32) -> (f64, f64) {
    let m = machines.max(1);
    let owner: Vec<u32> = (0..g.num_vertices).map(|v| v % m).collect();
    let mut per = vec![0u64; m as usize];
    let mut cross = 0u64;
    for e in &g.edges {
        per[owner[e.dst as usize] as usize] += 1;
        if owner[e.src as usize] != owner[e.dst as usize] {
            cross += 1;
        }
    }
    let max = *per.iter().max().unwrap() as f64;
    let avg = g.num_edges() as f64 / m as f64;
    (max / avg.max(1.0), cross as f64 / g.num_edges().max(1) as f64)
}

/// Extra: edges for the undirected CC variant with weight zero cost.
pub fn symmetrized(edges: &[Edge]) -> Vec<Edge> {
    let mut out = Vec::with_capacity(edges.len() * 2);
    for e in edges {
        out.push(*e);
        out.push(Edge::weighted(e.dst, e.src, e.weight));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Bfs, PageRank, Ppr, Sssp, Widest};
    use crate::graph::rmat::{rmat, RmatParams};

    fn graph() -> EdgeList {
        rmat(9, 4_000, 127, RmatParams::default())
    }

    #[test]
    fn in_memory_oom_on_small_budget() {
        let cfg = ClusterConfig { ram_per_machine: 1000, ..Default::default() };
        for sys in [DistSystem::PregelPlus, DistSystem::PowerGraph, DistSystem::PowerLyra] {
            let err = match DistEngine::new(sys, cfg, graph()) {
                Err(e) => e.to_string(),
                Ok(_) => panic!("{sys:?}: expected OOM"),
            };
            assert!(err.contains("OOM"), "{sys:?}: {err}");
        }
    }

    #[test]
    fn out_of_core_survives_small_budget() {
        let cfg = ClusterConfig { ram_per_machine: 1000, ..Default::default() };
        for sys in [DistSystem::GraphD, DistSystem::Chaos] {
            assert!(DistEngine::new(sys, cfg, graph()).is_ok(), "{sys:?}");
        }
    }

    #[test]
    fn values_match_single_machine_sweep() {
        let g = graph();
        let mut eng =
            DistEngine::new(DistSystem::PregelPlus, ClusterConfig::default(), g.clone()).unwrap();
        eng.run(&PageRank::new(), 5).unwrap();
        let inv = inv_out_degrees(&g);
        let (init, _) = PageRank::new().init(g.num_vertices);
        let mut src = init.f32s().to_vec();
        for _ in 0..5 {
            src = crate::baselines::sweep(
                PageRank::new().kernel(),
                &g.edges,
                g.num_vertices,
                &inv,
                &src,
            );
        }
        assert_eq!(eng.values(), &src[..]);
    }

    #[test]
    fn chaos_slower_than_pregel_per_iteration() {
        // Chaos streams all edges over the network every iteration; on a
        // graph that fits in cluster RAM, Pregel+ must win (Table 5).
        let g = graph();
        let mut chaos =
            DistEngine::new(DistSystem::Chaos, ClusterConfig::default(), g.clone()).unwrap();
        let mut pregel =
            DistEngine::new(DistSystem::PregelPlus, ClusterConfig::default(), g).unwrap();
        let rc = chaos.run(&PageRank::new(), 3).unwrap();
        let rp = pregel.run(&PageRank::new(), 3).unwrap();
        assert!(rc.first_n_seconds(3) > rp.first_n_seconds(3));
    }

    #[test]
    fn sssp_converges_and_matches() {
        let g = graph();
        let mut eng =
            DistEngine::new(DistSystem::GraphD, ClusterConfig::default(), g.clone()).unwrap();
        let run = eng.run(&Sssp::new(0), 100).unwrap();
        assert!(run.converged);
        // Bellman-Ford reference
        let n = g.num_vertices as usize;
        let mut d = vec![f32::INFINITY; n];
        d[0] = 0.0;
        loop {
            let mut ch = false;
            for e in &g.edges {
                let c = d[e.src as usize] + e.weight;
                if c < d[e.dst as usize] {
                    d[e.dst as usize] = c;
                    ch = true;
                }
            }
            if !ch {
                break;
            }
        }
        assert_eq!(eng.values(), &d[..]);
    }

    #[test]
    fn kernel_cost_models_are_ordered_and_anchored() {
        let pr = PageRank::new().kernel();
        let ppr = Ppr::new(1).kernel();
        let ss = Sssp::new(0).kernel();
        let bf = Bfs::new(0).kernel();
        let wd = Widest::new(0).kernel();
        // PPR shares PageRank's gather: identical per-edge model
        assert_eq!(kernel_cost_factor(&pr), 1.0, "PageRank is the anchor");
        assert_eq!(kernel_cost_factor(&ppr), kernel_cost_factor(&pr));
        // widest path's weight fetch + extra min is the priciest gather
        for sys in ALL_SYSTEMS {
            assert!(sys.per_edge_cost_for(&wd) > sys.per_edge_cost_for(&pr), "{sys:?}");
            assert!(sys.per_edge_cost_for(&bf) < sys.per_edge_cost_for(&pr), "{sys:?}");
            assert!(sys.per_edge_cost_for(&ss) > sys.per_edge_cost_for(&bf), "{sys:?}");
        }
        // rank mass ships C-byte records; relaxations ship f32 candidates
        assert_eq!(message_payload_bytes(&pr), C_VERTEX as f64);
        assert_eq!(message_payload_bytes(&ppr), C_VERTEX as f64);
        assert_eq!(message_payload_bytes(&wd), 4.0);
        assert_eq!(message_payload_bytes(&bf), 4.0);
    }

    #[test]
    fn ppr_and_widest_run_and_match_sweep_reference() {
        let g = graph();
        let inv = inv_out_degrees(&g);
        for (app, iters) in [
            (&Ppr::new(2) as &dyn crate::apps::VertexProgram, 6u32),
            (&Widest::new(0), 40),
        ] {
            let mut eng =
                DistEngine::new(DistSystem::GraphD, ClusterConfig::default(), g.clone())
                    .unwrap();
            let run = eng.run(app, iters).unwrap();
            let (init, _) = app.init(g.num_vertices);
            let mut src = init.f32s().to_vec();
            for _ in 0..run.iterations.len() {
                src = crate::baselines::sweep(app.kernel(), &g.edges, g.num_vertices, &inv, &src);
            }
            assert_eq!(eng.values(), &src[..], "{}", app.name());
            for m in &run.iterations {
                assert!(m.sim_disk_seconds > 0.0, "{}: no simulated cost", app.name());
            }
        }
    }

    #[test]
    fn wcc_matches_oracle_on_the_cluster_sim() {
        use crate::apps::{oracle, Wcc};
        let g = graph().to_undirected();
        let mut eng =
            DistEngine::new(DistSystem::PregelPlus, ClusterConfig::default(), g.clone()).unwrap();
        let run = eng.run(&Wcc, 200).unwrap();
        assert!(run.converged);
        assert_eq!(
            eng.values_lane().u32s(),
            oracle::wcc_labels(&g.edges, g.num_vertices).as_slice()
        );
    }

    #[test]
    fn replication_exceeds_one_on_powerlaw() {
        let eng =
            DistEngine::new(DistSystem::PowerGraph, ClusterConfig::default(), graph()).unwrap();
        assert!(eng.replication_factor() > 1.5, "{}", eng.replication_factor());
    }

    #[test]
    fn partition_stats_sane() {
        let (skew, cross) = partition_stats(&graph(), 9);
        assert!(skew >= 1.0);
        assert!((0.0..=1.0).contains(&cross));
        assert!(cross > 0.5, "hash partitioning should cut most edges");
    }
}
