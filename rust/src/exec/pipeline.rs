//! The generic schedule→prefetch→compute worklist pipeline.
//!
//! Dedicated I/O threads walk an iteration's scheduled worklist, load
//! each unit (read + decompress + parse for VSW shards; model-charged
//! streaming for the baselines) and push the result into a small bounded
//! ready queue ahead of the compute workers.  (Simulated) disk time
//! thereby overlaps compute instead of serialising with it
//! (NXgraph-style streaming, PAPERS.md), and workers never load on the
//! critical path.
//!
//! The queue is a `sync_channel`: its depth bounds how many loaded units
//! can be in flight, which bounds the pipeline's extra memory to
//! `depth + workers` units.  The producer side never blocks indefinitely
//! — [`io_thread`] polls the abort flag while the queue is full, so a
//! dead consumer (worker error *or panic*, flagged by [`AbortOnPanic`])
//! lets `thread::scope` join and propagate instead of hanging.
//!
//! [`run_worklist`] is the engine-agnostic driver used by
//! [`crate::exec::ExecCore`] for every engine: with `depth == 0` the
//! pipeline is off and workers load inline (the sequential reference
//! path); otherwise stages 2+3 run concurrently.  Per-stage busy time is
//! measured so the adaptive prefetch mode can size the queue from the
//! observed load-vs-compute rate.
//!
//! Scan-shared batches hand each loaded unit to several member jobs.
//! [`FanOut`] controls how those (unit × job) sub-tasks execute: serially
//! on the claiming worker (the long-worklist default, zero coordination),
//! or — when the union worklist is shorter than the worker count and
//! cores would otherwise idle — *split* across workers through a shared
//! condvar-backed sub-task queue ([`FanQueue`]), each worker computing
//! one (unit, job) pair (the item is `Clone`, an `Arc` for real shards,
//! so the hand-off is cheap).  In split mode workers never park in a
//! blocking ready-queue receive: they poll the ready queue and wait on
//! the fan queue's condvar, so a worker idling while a slow load is in
//! flight wakes *immediately* when a sibling fans sub-tasks out — fanned
//! work no longer waits for the ready queue to close when I/O is slow
//! and jobs ≫ units.  Either way every sub-task writes job-isolated
//! state, so results are bit-identical between the execution shapes.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::{Condvar, Mutex, TryLockError};
use std::time::{Duration, Instant};

use anyhow::Result;

/// How a loaded unit's sub-tasks (one per member job of a scan-shared
/// batch) are executed — see the module docs.
#[derive(Clone, Copy, Debug)]
pub struct FanOut<'a> {
    /// Per-worklist-index sub-task counts (empty ⇒ one per unit, the
    /// single-job shape).  A count of 0 skips the unit's compute.
    pub counts: &'a [u32],
    /// Split sub-tasks across workers instead of running them serially on
    /// the worker that claimed the unit.  Worth it only when the worklist
    /// is shorter than the worker pool; identical results either way.
    pub split: bool,
}

impl FanOut<'_> {
    /// No fanning: every unit is one task on its claiming worker.
    pub const NONE: FanOut<'static> = FanOut { counts: &[], split: false };

    #[inline]
    fn of(&self, index: usize) -> u32 {
        if self.counts.is_empty() {
            1
        } else {
            self.counts[index]
        }
    }
}

/// One loaded unit travelling from an I/O thread to a compute worker:
/// the worklist position, the scheduled unit id, and the load result
/// (errors ride the queue so the first failure reaches the barrier).
pub type Fetched<T> = (usize, u32, Result<T>);

/// Shared counters of one iteration's pipeline (atomics: touched from
/// both I/O and compute threads).
#[derive(Debug, Default)]
pub struct PipelineCounters {
    /// Units fetched ahead by the I/O threads.
    pub prefetched: AtomicU32,
    /// Worker requests served without waiting (item staged, queue lock
    /// uncontended).
    pub ready_hits: AtomicU32,
    /// Worker requests that waited — on the prefetcher directly, or on a
    /// sibling worker that was itself parked waiting for the prefetcher.
    pub ready_misses: AtomicU32,
    /// Nanoseconds the I/O threads (or inline loads) spent loading.
    pub io_busy_nanos: AtomicU64,
    /// Nanoseconds the compute workers spent inside `consume`.
    pub compute_busy_nanos: AtomicU64,
}

/// Sets the abort flag when dropped during a panic.  Compute workers hold
/// one so an unwinding worker releases the I/O threads (which poll the
/// flag) — otherwise `thread::scope` would wait forever on producers
/// blocked against a queue nobody drains.
pub struct AbortOnPanic<'a>(pub &'a AtomicBool);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Relaxed);
        }
    }
}

/// The consumer side of the ready queue, shareable across workers.
pub struct ReadyQueue<T> {
    rx: Mutex<Receiver<Fetched<T>>>,
}

impl<T> ReadyQueue<T> {
    /// Build a queue of the given depth (≥ 1) and return it with the
    /// producer handle; clone the sender once per I/O thread and drop the
    /// original so the queue closes when the last thread finishes.
    pub fn with_sender(depth: usize) -> (ReadyQueue<T>, SyncSender<Fetched<T>>) {
        let (tx, rx) = sync_channel(depth.max(1));
        (ReadyQueue { rx: Mutex::new(rx) }, tx)
    }

    /// Next loaded unit for a compute worker, recording whether it was
    /// already staged (ready hit) or the worker had to wait (miss).
    /// Contention on the queue lock counts as a miss too: it means a
    /// sibling worker is parked inside `recv`, i.e. the prefetcher is
    /// behind for everyone.  `None` once the queue is closed and drained.
    pub fn next(&self, counters: &PipelineCounters) -> Option<Fetched<T>> {
        let (rx, waited) = match self.rx.try_lock() {
            Ok(guard) => (guard, false),
            Err(TryLockError::WouldBlock) => (self.rx.lock().unwrap(), true),
            Err(TryLockError::Poisoned(e)) => (e.into_inner(), true),
        };
        match rx.try_recv() {
            Ok(item) => {
                if waited {
                    counters.ready_misses.fetch_add(1, Ordering::Relaxed);
                } else {
                    counters.ready_hits.fetch_add(1, Ordering::Relaxed);
                }
                Some(item)
            }
            Err(TryRecvError::Empty) => match rx.recv() {
                Ok(item) => {
                    counters.ready_misses.fetch_add(1, Ordering::Relaxed);
                    Some(item)
                }
                Err(_) => None,
            },
            Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Non-blocking variant of [`Self::next`] for split-mode workers,
    /// which must stay responsive to the fan queue instead of parking
    /// inside `recv`.  `waited` is per-worker state threaded across
    /// calls so the hit/miss accounting matches `next`: a delivery
    /// counts as a hit only if this worker never came up empty (or
    /// lock-contended) since its previous delivery.
    pub fn poll(&self, counters: &PipelineCounters, waited: &mut bool) -> Polled<Fetched<T>> {
        let rx = match self.rx.try_lock() {
            Ok(guard) => guard,
            Err(TryLockError::WouldBlock) => {
                // a sibling holds the lock — the prefetcher is behind
                // for everyone, same signal as lock contention in `next`
                *waited = true;
                return Polled::Empty;
            }
            Err(TryLockError::Poisoned(e)) => e.into_inner(),
        };
        match rx.try_recv() {
            Ok(item) => {
                if *waited {
                    counters.ready_misses.fetch_add(1, Ordering::Relaxed);
                } else {
                    counters.ready_hits.fetch_add(1, Ordering::Relaxed);
                }
                *waited = false;
                Polled::Item(item)
            }
            Err(TryRecvError::Empty) => {
                *waited = true;
                Polled::Empty
            }
            Err(TryRecvError::Disconnected) => Polled::Closed,
        }
    }
}

/// Outcome of one [`ReadyQueue::poll`].
pub enum Polled<T> {
    /// A loaded unit was staged and is now this worker's.
    Item(T),
    /// Nothing staged right now; the queue may still produce.
    Empty,
    /// The queue is closed and drained — no more units will arrive.
    Closed,
}

/// Split-mode sub-task queue: sub-tasks 1..k of a claimed unit wait here
/// for any idle worker.  `pending` counts queued *plus in-flight*
/// (popped but not yet finished) entries, so `drained` only reports true
/// once every fanned sub-task has actually run.  The condvar is the
/// hand-off that lets queue-blocked workers steal while the ready queue
/// is still open: pushers `notify_all`, idle workers wait here (with a
/// short timeout so they also re-poll the ready queue) instead of
/// parking in a blocking `recv`.
struct FanQueue<T> {
    state: Mutex<FanState<T>>,
    work: Condvar,
}

struct FanState<T> {
    queue: VecDeque<(usize, u32, u32, T)>,
    pending: usize,
}

impl<T> FanQueue<T> {
    fn new() -> Self {
        FanQueue {
            state: Mutex::new(FanState { queue: VecDeque::new(), pending: 0 }),
            work: Condvar::new(),
        }
    }

    /// Enqueue a unit's fanned sub-tasks and wake every waiting worker.
    fn push_subs(&self, subs: impl Iterator<Item = (usize, u32, u32, T)>) {
        let mut state = self.state.lock().unwrap();
        let before = state.queue.len();
        state.queue.extend(subs);
        state.pending += state.queue.len() - before;
        self.work.notify_all();
    }

    fn try_pop(&self) -> Option<(usize, u32, u32, T)> {
        self.state.lock().unwrap().queue.pop_front()
    }

    /// A popped sub-task finished (or was discarded under abort).  The
    /// last one wakes waiters so they can observe `drained`.
    fn task_done(&self) {
        let mut state = self.state.lock().unwrap();
        state.pending -= 1;
        if state.pending == 0 {
            self.work.notify_all();
        }
    }

    fn drained(&self) -> bool {
        self.state.lock().unwrap().pending == 0
    }

    /// Park until fanned work may be available: returns on a push, when
    /// the last in-flight sub-task completes, or after a 100 µs timeout
    /// (matching the I/O threads' poll cadence) so callers re-check
    /// their other wake sources — the ready queue and the abort flag.
    fn wait_for_work(&self) {
        let state = self.state.lock().unwrap();
        if state.queue.is_empty() {
            let _woken = self.work.wait_timeout(state, Duration::from_micros(100)).unwrap();
        }
    }
}

/// Fetch loop run by each dedicated I/O thread: claim the next worklist
/// index, load the unit, push it to the ready queue.  Stops at worklist
/// end, on the abort signal (a unit failed or a worker died), or when
/// the queue closes (all consumers gone).
pub fn io_thread<T, L>(
    load: L,
    worklist: &[u32],
    next: &AtomicUsize,
    abort: &AtomicBool,
    tx: SyncSender<Fetched<T>>,
    counters: &PipelineCounters,
) where
    L: Fn(u32) -> Result<T>,
{
    loop {
        if abort.load(Ordering::Relaxed) {
            return;
        }
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= worklist.len() {
            return;
        }
        let id = worklist[i];
        let t = Instant::now();
        let res = load(id);
        counters
            .io_busy_nanos
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        counters.prefetched.fetch_add(1, Ordering::Relaxed);
        // bounded-blocking send: poll the abort flag while the queue is
        // full so a vanished consumer can't strand this thread in `send`
        let mut item = (i, id, res);
        loop {
            match tx.try_send(item) {
                Ok(()) => break,
                Err(TrySendError::Full(back)) => {
                    if abort.load(Ordering::Relaxed) {
                        return;
                    }
                    item = back;
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(TrySendError::Disconnected(_)) => return,
            }
        }
    }
}

/// Aggregated result of one worklist pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorklistOutcome {
    /// Sub-tasks (unit × member job) consumed successfully — equals
    /// `units` outside scan-shared batches.
    pub processed: u32,
    /// Distinct units delivered to the compute stage (each loaded once).
    pub units: u32,
    /// Sub-tasks dispatched through the shared fan-out queue (0 when
    /// sub-tasks run serially on the claiming worker).
    pub fanned: u32,
    pub prefetched: u32,
    pub ready_hits: u32,
    pub ready_misses: u32,
    /// Aggregate load time across I/O threads (or inline loads).
    pub io_busy: Duration,
    /// Aggregate `consume` time across compute workers.
    pub compute_busy: Duration,
}

/// Run one iteration's worklist through the pipeline: `load` runs on
/// `io_threads` dedicated threads feeding a depth-bounded ready queue
/// (or inline on the workers when `depth == 0` — the sequential
/// reference path), `consume` runs on `workers` compute workers, each
/// with its own `mk_worker()` state (e.g. a [`super::RangeMarker`],
/// flushed on drop).  Each loaded unit is consumed once per sub-task
/// (`fan`, one per member job of a scan-shared batch; `sub` identifies
/// which), serially on the claiming worker or split across workers —
/// see [`FanOut`].  The first error from either stage aborts the sweep
/// and is returned after all threads join.
#[allow(clippy::too_many_arguments)]
pub fn run_worklist<T, W, L, MK, C>(
    worklist: &[u32],
    fan: FanOut<'_>,
    workers: usize,
    depth: usize,
    io_threads: usize,
    load: L,
    mk_worker: MK,
    consume: C,
) -> Result<WorklistOutcome>
where
    T: Send + Clone,
    L: Fn(u32) -> Result<T> + Sync,
    MK: Fn() -> W + Sync,
    C: Fn(&mut W, usize, u32, u32, T) -> Result<()> + Sync,
{
    assert!(
        fan.counts.is_empty() || fan.counts.len() == worklist.len(),
        "fan counts must cover the worklist"
    );
    let workers = workers.max(1);
    let pipelined = depth > 0 && io_threads > 0;
    let counters = PipelineCounters::default();
    let next_fetch = AtomicUsize::new(0);
    let processed = AtomicU32::new(0);
    let units = AtomicU32::new(0);
    let fanned = AtomicU32::new(0);
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let abort = AtomicBool::new(false);
    let fan_queue: FanQueue<T> = FanQueue::new();

    // first error wins and raises the abort flag (load and compute
    // failures share this one path)
    let record_err = |e: anyhow::Error| {
        let mut fe = first_err.lock().unwrap();
        if fe.is_none() {
            *fe = Some(e);
        }
        abort.store(true, Ordering::Relaxed);
    };
    let record_err = &record_err;

    // one sub-task: execute it or route its error to the barrier.  One
    // copy shared by every acquisition mode, so the pipelined and split
    // paths can never drift from the sequential reference.
    let consume_one = |state: &mut W, index: usize, id: u32, sub: u32, item: T| {
        let t = Instant::now();
        let outcome = consume(state, index, id, sub, item);
        counters
            .compute_busy_nanos
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        match outcome {
            Ok(()) => {
                processed.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => record_err(e),
        }
    };
    let consume_one = &consume_one;

    // a delivered unit: fan its sub-tasks out (split) or run them here
    let handle_unit = |state: &mut W, index: usize, id: u32, res: Result<T>| {
        units.fetch_add(1, Ordering::Relaxed);
        let k = fan.of(index);
        let item = match res {
            Ok(item) => item,
            Err(e) => {
                record_err(e);
                return;
            }
        };
        if k == 0 {
            return; // loaded for no member (shouldn't happen, but harmless)
        }
        if fan.split && k > 1 {
            fanned.fetch_add(k - 1, Ordering::Relaxed);
            fan_queue.push_subs((1..k).map(|sub| (index, id, sub, item.clone())));
            consume_one(state, index, id, 0, item);
        } else {
            let mut item = Some(item);
            for sub in 0..k {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let it = if sub + 1 == k {
                    item.take().expect("item moved once")
                } else {
                    item.as_ref().expect("item present").clone()
                };
                consume_one(state, index, id, sub, it);
            }
        }
    };
    let handle_unit = &handle_unit;

    // pop one fanned sub-task and run it; returns false when none queued
    let steal_fanned = |state: &mut W| -> bool {
        if !fan.split {
            return false;
        }
        match fan_queue.try_pop() {
            Some((index, id, sub, item)) => {
                if !abort.load(Ordering::Relaxed) {
                    consume_one(state, index, id, sub, item);
                }
                // mark done even when aborted so waiters can exit
                fan_queue.task_done();
                true
            }
            None => false,
        }
    };
    let steal_fanned = &steal_fanned;
    let fan_drained = || !fan.split || fan_queue.drained() || abort.load(Ordering::Relaxed);

    let (queue_opt, tx_opt) = if pipelined {
        let (q, tx) = ReadyQueue::with_sender(depth);
        (Some(q), Some(tx))
    } else {
        (None, None)
    };
    std::thread::scope(|scope| {
        if let (Some(queue), Some(tx)) = (&queue_opt, tx_opt) {
            for _ in 0..io_threads.max(1) {
                let tx = tx.clone();
                let (load, worklist, next_fetch, abort, counters) =
                    (&load, worklist, &next_fetch, &abort, &counters);
                scope.spawn(move || {
                    io_thread(load, worklist, next_fetch, abort, tx, counters);
                });
            }
            // queue closes when the last I/O thread finishes (tx_opt was
            // moved into this branch and its clones die with the threads)
            for _ in 0..workers {
                let (mk_worker, abort, counters, fan_drained, fan_queue) =
                    (&mk_worker, &abort, &counters, &fan_drained, &fan_queue);
                scope.spawn(move || {
                    let _guard = AbortOnPanic(abort);
                    let mut state = mk_worker();
                    let mut queue_open = true;
                    if fan.split {
                        // split mode: never park in a blocking recv —
                        // poll the ready queue and wait on the fan
                        // queue's condvar, so fanned sub-tasks pushed by
                        // a sibling are stolen immediately even while a
                        // slow load keeps the ready queue open but empty
                        let mut waited = false;
                        loop {
                            // fanned sub-tasks first: ready compute, no I/O
                            if steal_fanned(&mut state) {
                                continue;
                            }
                            if queue_open {
                                match queue.poll(counters, &mut waited) {
                                    Polled::Item((index, id, res)) => {
                                        if abort.load(Ordering::Relaxed) {
                                            // keep draining so I/O threads
                                            // never block on a full queue
                                            continue;
                                        }
                                        handle_unit(&mut state, index, id, res);
                                    }
                                    Polled::Closed => queue_open = false,
                                    Polled::Empty => fan_queue.wait_for_work(),
                                }
                                continue;
                            }
                            // queue drained; wait out in-flight fanned work
                            if fan_drained() {
                                break;
                            }
                            fan_queue.wait_for_work();
                        }
                    } else {
                        // no fanning: the blocking receive is the
                        // cheapest wait (no polling, OS wakes us)
                        while let Some((index, id, res)) = queue.next(counters) {
                            if abort.load(Ordering::Relaxed) {
                                // keep draining so I/O threads never
                                // block forever on a full queue
                                continue;
                            }
                            handle_unit(&mut state, index, id, res);
                        }
                    }
                });
            }
        } else {
            for _ in 0..workers {
                let (load, mk_worker, worklist, next_fetch) =
                    (&load, &mk_worker, worklist, &next_fetch);
                let (abort, counters, fan_drained, fan_queue) =
                    (&abort, &counters, &fan_drained, &fan_queue);
                scope.spawn(move || {
                    // a panicking worker raises abort so siblings waiting
                    // on fanned sub-tasks can exit and the scope can join
                    let _guard = AbortOnPanic(abort);
                    let mut state = mk_worker();
                    loop {
                        if steal_fanned(&mut state) {
                            continue;
                        }
                        // an error recorded by any worker stops the sweep
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next_fetch.fetch_add(1, Ordering::Relaxed);
                        if i >= worklist.len() {
                            // worklist exhausted; wait out fanned work
                            if fan_drained() {
                                break;
                            }
                            fan_queue.wait_for_work();
                            continue;
                        }
                        let id = worklist[i];
                        let t = Instant::now();
                        let res = load(id);
                        counters
                            .io_busy_nanos
                            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        handle_unit(&mut state, i, id, res);
                    }
                });
            }
        }
    });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    Ok(WorklistOutcome {
        processed: processed.load(Ordering::Relaxed),
        units: units.load(Ordering::Relaxed),
        fanned: fanned.load(Ordering::Relaxed),
        prefetched: counters.prefetched.load(Ordering::Relaxed),
        ready_hits: counters.ready_hits.load(Ordering::Relaxed),
        ready_misses: counters.ready_misses.load(Ordering::Relaxed),
        io_busy: Duration::from_nanos(counters.io_busy_nanos.load(Ordering::Relaxed)),
        compute_busy: Duration::from_nanos(counters.compute_busy_nanos.load(Ordering::Relaxed)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32 as TestCounter;

    #[test]
    fn io_threads_deliver_every_scheduled_unit_once() {
        let worklist: Vec<u32> = (0..37).collect();
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let counters = PipelineCounters::default();
        let (queue, tx) = ReadyQueue::with_sender(4);
        let mut got = Vec::new();
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let tx = tx.clone();
                let (worklist, next, abort, counters) = (&worklist, &next, &abort, &counters);
                scope.spawn(move || {
                    io_thread(|id| Ok(id * 10), worklist, next, abort, tx, counters);
                });
            }
            drop(tx);
            while let Some((index, id, res)) = queue.next(&counters) {
                assert_eq!(res.unwrap(), id * 10);
                assert_eq!(worklist[index], id);
                got.push(id);
            }
        });
        got.sort_unstable();
        assert_eq!(got, worklist);
        assert_eq!(counters.prefetched.load(Ordering::Relaxed), 37);
        let hits = counters.ready_hits.load(Ordering::Relaxed);
        let misses = counters.ready_misses.load(Ordering::Relaxed);
        assert_eq!(hits + misses, 37, "every delivery counts exactly once");
    }

    #[test]
    fn errors_ride_the_queue() {
        let worklist = vec![0u32, 1, 2];
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let counters = PipelineCounters::default();
        let (queue, tx) = ReadyQueue::with_sender(2);
        std::thread::scope(|scope| {
            let (worklist, next, abort, counters) = (&worklist, &next, &abort, &counters);
            scope.spawn(move || {
                io_thread(
                    |id| {
                        if id == 1 {
                            anyhow::bail!("boom on unit {id}")
                        } else {
                            Ok(id)
                        }
                    },
                    worklist,
                    next,
                    abort,
                    tx,
                    counters,
                );
            });
            let mut errs = 0;
            let mut oks = 0;
            while let Some((_, _, res)) = queue.next(counters) {
                match res {
                    Ok(_) => oks += 1,
                    Err(e) => {
                        assert!(e.to_string().contains("boom"));
                        errs += 1;
                    }
                }
            }
            assert_eq!((oks, errs), (2, 1));
        });
    }

    #[test]
    fn abort_stops_fetching() {
        let worklist: Vec<u32> = (0..1000).collect();
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(true); // pre-aborted
        let counters = PipelineCounters::default();
        let (_queue, tx) = ReadyQueue::<u32>::with_sender(1);
        io_thread(|id| Ok(id), &worklist, &next, &abort, tx, &counters);
        assert_eq!(counters.prefetched.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn abort_unblocks_a_full_queue() {
        // a producer stuck against a full queue with no consumer must
        // exit once abort is raised — this is what keeps a panicking
        // worker from deadlocking thread::scope
        let worklist: Vec<u32> = (0..100).collect();
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let counters = PipelineCounters::default();
        let (queue, tx) = ReadyQueue::with_sender(1);
        std::thread::scope(|scope| {
            let (worklist, next, abort, counters) = (&worklist, &next, &abort, &counters);
            scope.spawn(move || {
                io_thread(|id| Ok(id), worklist, next, abort, tx, counters);
            });
            // let it fill the depth-1 queue, then abort without consuming
            std::thread::sleep(Duration::from_millis(20));
            abort.store(true, Ordering::Relaxed);
            // scope joins here: hangs if the producer ignores abort
        });
        assert!(counters.prefetched.load(Ordering::Relaxed) >= 1);
        drop(queue);
    }

    #[test]
    fn abort_on_panic_fires_only_during_unwind() {
        let flag = AtomicBool::new(false);
        {
            let _g = AbortOnPanic(&flag);
        }
        assert!(!flag.load(Ordering::Relaxed), "normal drop must not abort");
        let flag2 = std::sync::Arc::new(AtomicBool::new(false));
        let f2 = std::sync::Arc::clone(&flag2);
        let res = std::thread::spawn(move || {
            let _g = AbortOnPanic(&f2);
            panic!("boom");
        })
        .join();
        assert!(res.is_err());
        assert!(flag2.load(Ordering::Relaxed), "panic must raise the flag");
    }

    #[test]
    fn run_worklist_pipelined_and_inline_agree() {
        let worklist: Vec<u32> = (0..53).collect();
        for depth in [0usize, 3] {
            let sum = TestCounter::new(0);
            let out = run_worklist(
                &worklist,
                FanOut::NONE,
                4,
                depth,
                2,
                |id| Ok(id + 1),
                || (),
                |_, index, id, sub, item| {
                    assert_eq!(worklist[index], id);
                    assert_eq!(sub, 0, "no fanning means one sub-task per unit");
                    sum.fetch_add(item, Ordering::Relaxed);
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(out.processed, 53);
            assert_eq!(out.units, 53);
            assert_eq!(out.fanned, 0);
            assert_eq!(sum.load(Ordering::Relaxed), (1..=53).sum::<u32>());
            if depth == 0 {
                assert_eq!(out.prefetched, 0, "inline loads are not prefetches");
                assert_eq!(out.ready_hits + out.ready_misses, 0);
            } else {
                assert_eq!(out.prefetched, 53);
                assert_eq!(out.ready_hits + out.ready_misses, 53);
            }
        }
    }

    #[test]
    fn run_worklist_routes_first_error() {
        let worklist: Vec<u32> = (0..20).collect();
        let err = run_worklist(
            &worklist,
            FanOut::NONE,
            2,
            2,
            1,
            |id| {
                if id == 7 {
                    anyhow::bail!("load failed on {id}")
                } else {
                    Ok(id)
                }
            },
            || (),
            |_, _, _, _, _| Ok(()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("load failed"));
    }

    #[test]
    fn fanned_sub_tasks_each_run_exactly_once() {
        // 3 units with fan counts 4/1/3: every (unit, sub) pair must be
        // consumed exactly once — serial, split-pipelined, and
        // split-inline all agree.  One unit is loaded per index either
        // way (that's the scan-sharing I/O contract).
        let worklist: Vec<u32> = vec![10, 20, 30];
        let fan_counts = vec![4u32, 1, 3];
        for (depth, split) in [(0usize, false), (3, false), (0, true), (3, true)] {
            let seen: Mutex<Vec<(usize, u32)>> = Mutex::new(Vec::new());
            let loads = TestCounter::new(0);
            let out = run_worklist(
                &worklist,
                FanOut { counts: &fan_counts, split },
                8,
                depth,
                2,
                |id| {
                    loads.fetch_add(1, Ordering::Relaxed);
                    Ok(id)
                },
                || (),
                |_, index, id, sub, item| {
                    assert_eq!(worklist[index], id);
                    assert_eq!(item, id);
                    seen.lock().unwrap().push((index, sub));
                    Ok(())
                },
            )
            .unwrap();
            let mut got = seen.into_inner().unwrap();
            got.sort_unstable();
            let want: Vec<(usize, u32)> = fan_counts
                .iter()
                .enumerate()
                .flat_map(|(i, &k)| (0..k).map(move |s| (i, s)))
                .collect();
            assert_eq!(got, want, "depth {depth} split {split}");
            assert_eq!(out.processed, 8, "depth {depth} split {split}");
            assert_eq!(out.units, 3);
            assert_eq!(loads.load(Ordering::Relaxed), 3, "each unit loads once");
            if split {
                assert_eq!(out.fanned, 5, "subs 1.. of units 0 and 2 are fanned");
            } else {
                assert_eq!(out.fanned, 0);
            }
        }
    }

    #[test]
    fn queue_blocked_workers_steal_fanned_subtasks() {
        // the condvar hand-off contract: while the ready queue is still
        // OPEN (a slow load is in flight), idle workers must wake and
        // steal fanned sub-tasks instead of parking until the queue
        // closes.  Unit 0 fans 6 sub-tasks that each block until ≥ 2 run
        // concurrently — only stealing siblings can make that happen,
        // because the claiming worker runs its sub-tasks one at a time.
        // Unit 1's load (the only I/O thread) blocks until the overlap
        // is observed, pinning the queue open the whole time.  Deadlines
        // bound the failure mode to a slow assert, never a hang.
        let worklist: Vec<u32> = vec![0, 1];
        let fan_counts = vec![6u32, 1];
        let inflight = TestCounter::new(0);
        let peak_ok = AtomicBool::new(false);
        let deadline = Instant::now() + Duration::from_secs(5);
        let out = run_worklist(
            &worklist,
            FanOut { counts: &fan_counts, split: true },
            8,
            2,
            1,
            |id| {
                if id == 1 {
                    // keep the ready queue open until sub-tasks overlapped
                    while !peak_ok.load(Ordering::SeqCst) && Instant::now() < deadline {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                }
                Ok(id)
            },
            || (),
            |_, index, _, _, _| {
                if index == 0 {
                    let cur = inflight.fetch_add(1, Ordering::SeqCst) + 1;
                    if cur >= 2 {
                        peak_ok.store(true, Ordering::SeqCst);
                    }
                    while !peak_ok.load(Ordering::SeqCst) && Instant::now() < deadline {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                    inflight.fetch_sub(1, Ordering::SeqCst);
                }
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(out.processed, 7);
        assert_eq!(out.units, 2);
        assert_eq!(out.fanned, 5);
        assert!(
            peak_ok.load(Ordering::SeqCst),
            "idle workers must steal fanned sub-tasks while the ready queue is open"
        );
    }

    #[test]
    fn split_mode_routes_sub_task_errors() {
        let worklist: Vec<u32> = vec![0, 1];
        let err = run_worklist(
            &worklist,
            FanOut { counts: &[3, 3], split: true },
            4,
            2,
            1,
            |id| Ok(id),
            || (),
            |_, _, id, sub, _| {
                if id == 1 && sub == 2 {
                    anyhow::bail!("sub-task failed")
                }
                Ok(())
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("sub-task failed"));
    }

    #[test]
    fn zero_fan_units_load_but_skip_compute() {
        let worklist: Vec<u32> = vec![0, 1, 2];
        let out = run_worklist(
            &worklist,
            FanOut { counts: &[1, 0, 2], split: false },
            2,
            0,
            0,
            |id| Ok(id),
            || (),
            |_, _, id, _, _| {
                assert_ne!(id, 1, "fan count 0 must skip the unit");
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(out.processed, 3);
        assert_eq!(out.units, 3);
    }
}
