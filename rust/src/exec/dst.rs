//! Lock-free disjoint writes into the `DstVertexArray`.
//!
//! The paper's central no-synchronisation claim (§2.3): because every
//! in-edge of a vertex lives in exactly one shard, `DstVertexArray[v]` is
//! written by exactly one worker per iteration — so unlike GridGraph no
//! locks or atomics are needed.  [`SharedDst`] encodes that invariant: it
//! hands out mutable [`LaneSliceMut`] windows over one type-erased value
//! array to multiple threads, `debug_assert`ing that claimed intervals
//! never overlap.  Since PR 10 the array carries any [`LaneVec`] lane
//! type (f32 mass, u32 labels/levels, u64), so one `SharedDst` per job
//! serves heterogeneously-typed batches.

use std::cell::UnsafeCell;
use std::sync::Mutex;

use super::lane::{LaneSliceMut, LaneType, LaneVec};

/// A vertex-value array writable concurrently on *disjoint* intervals.
pub struct SharedDst {
    data: UnsafeCell<LaneVec>,
    /// Debug-only overlap registry of claimed `[start, end)` intervals.
    claims: Mutex<Vec<(usize, usize)>>,
}

// SAFETY: concurrent access is confined to disjoint index ranges, enforced
// by the claim registry in debug builds and by the preprocessing invariant
// (intervals partition the vertex space) in release builds.
unsafe impl Sync for SharedDst {}

impl SharedDst {
    pub fn new(init: LaneVec) -> Self {
        SharedDst { data: UnsafeCell::new(init), claims: Mutex::new(Vec::new()) }
    }

    pub fn len(&self) -> usize {
        unsafe { (*self.data.get()).len() }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn lane_type(&self) -> LaneType {
        unsafe { (*self.data.get()).lane_type() }
    }

    /// Claim `[start, start+len)` for exclusive writing.
    ///
    /// # Safety
    /// Callers must guarantee no two live claims overlap. The VSW engine
    /// derives claims from the disjoint shard intervals of the property
    /// file, which `prep::compute_intervals` guarantees (and tests).
    pub unsafe fn claim(&self, start: usize, len: usize) -> LaneSliceMut<'_> {
        debug_assert!(start + len <= self.len(), "claim out of bounds");
        #[cfg(debug_assertions)]
        {
            let mut claims = self.claims.lock().unwrap();
            for &(a, b) in claims.iter() {
                assert!(
                    start + len <= a || b <= start,
                    "overlapping dst claim [{start},{}) vs [{a},{b})",
                    start + len
                );
            }
            claims.push((start, start + len));
        }
        match &mut *self.data.get() {
            LaneVec::F32(v) => LaneSliceMut::F32(&mut v[start..start + len]),
            LaneVec::U32(v) => LaneSliceMut::U32(&mut v[start..start + len]),
            LaneVec::U64(v) => LaneSliceMut::U64(&mut v[start..start + len]),
        }
    }

    /// Clear the debug claim registry at an iteration barrier.
    pub fn release_all(&self) {
        #[cfg(debug_assertions)]
        self.claims.lock().unwrap().clear();
        #[cfg(not(debug_assertions))]
        {
            let _ = &self.claims;
        }
    }

    /// Take the array back out (single-threaded phase).
    pub fn into_inner(self) -> LaneVec {
        self.data.into_inner()
    }

    /// Read-only copy; callers must ensure no concurrent writers (the
    /// engine only reads at iteration barriers).
    pub fn snapshot(&self) -> LaneVec {
        unsafe { (*self.data.get()).clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_claims_write_independently() {
        let dst = SharedDst::new(vec![0.0f32; 10].into());
        std::thread::scope(|s| {
            let d = &dst;
            s.spawn(move || {
                let a = unsafe { d.claim(0, 5) };
                a.f32s().fill(1.0);
            });
            s.spawn(move || {
                let b = unsafe { d.claim(5, 5) };
                b.f32s().fill(2.0);
            });
        });
        let v = dst.into_inner();
        assert_eq!(&v.f32s()[..5], &[1.0; 5]);
        assert_eq!(&v.f32s()[5..], &[2.0; 5]);
    }

    #[test]
    fn integer_lanes_claim_typed_windows() {
        let dst = SharedDst::new(vec![7u32; 6].into());
        assert_eq!(dst.lane_type(), LaneType::U32);
        match unsafe { dst.claim(2, 2) } {
            LaneSliceMut::U32(w) => w.fill(9),
            other => panic!("u32 array must hand out u32 claims, got {other:?}"),
        }
        dst.release_all();
        assert_eq!(dst.into_inner().u32s(), &[7, 7, 9, 9, 7, 7]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "overlapping dst claim")]
    fn overlap_detected_in_debug() {
        let dst = SharedDst::new(vec![0.0f32; 10].into());
        unsafe {
            let _a = dst.claim(0, 6);
            let _b = dst.claim(5, 5);
        }
    }

    #[test]
    fn release_allows_reclaim() {
        let dst = SharedDst::new(vec![0.0f32; 4].into());
        unsafe {
            dst.claim(0, 4).f32s()[0] = 3.0;
        }
        dst.release_all();
        unsafe {
            assert_eq!(dst.claim(0, 4).f32s()[0], 3.0);
        }
    }

    #[test]
    fn snapshot_reflects_writes() {
        let dst = SharedDst::new(vec![1.0f32; 3].into());
        unsafe {
            dst.claim(1, 1).f32s()[0] = 9.0;
        }
        dst.release_all();
        assert_eq!(dst.snapshot(), vec![1.0, 9.0, 1.0]);
    }
}
