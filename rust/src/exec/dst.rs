//! Lock-free disjoint writes into the `DstVertexArray`.
//!
//! The paper's central no-synchronisation claim (§2.3): because every
//! in-edge of a vertex lives in exactly one shard, `DstVertexArray[v]` is
//! written by exactly one worker per iteration — so unlike GridGraph no
//! locks or atomics are needed.  [`SharedDst`] encodes that invariant: it
//! hands out `&mut [f32]` windows over one array to multiple threads,
//! `debug_assert`ing that claimed intervals never overlap.

use std::cell::UnsafeCell;
use std::sync::Mutex;

/// A vertex-value array writable concurrently on *disjoint* intervals.
pub struct SharedDst {
    data: UnsafeCell<Vec<f32>>,
    /// Debug-only overlap registry of claimed `[start, end)` intervals.
    claims: Mutex<Vec<(usize, usize)>>,
}

// SAFETY: concurrent access is confined to disjoint index ranges, enforced
// by the claim registry in debug builds and by the preprocessing invariant
// (intervals partition the vertex space) in release builds.
unsafe impl Sync for SharedDst {}

impl SharedDst {
    pub fn new(init: Vec<f32>) -> Self {
        SharedDst { data: UnsafeCell::new(init), claims: Mutex::new(Vec::new()) }
    }

    pub fn len(&self) -> usize {
        unsafe { (*self.data.get()).len() }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Claim `[start, start+len)` for exclusive writing.
    ///
    /// # Safety
    /// Callers must guarantee no two live claims overlap. The VSW engine
    /// derives claims from the disjoint shard intervals of the property
    /// file, which `prep::compute_intervals` guarantees (and tests).
    pub unsafe fn claim(&self, start: usize, len: usize) -> &mut [f32] {
        debug_assert!(start + len <= self.len(), "claim out of bounds");
        #[cfg(debug_assertions)]
        {
            let mut claims = self.claims.lock().unwrap();
            for &(a, b) in claims.iter() {
                assert!(
                    start + len <= a || b <= start,
                    "overlapping dst claim [{start},{}) vs [{a},{b})",
                    start + len
                );
            }
            claims.push((start, start + len));
        }
        let v = &mut *self.data.get();
        &mut v[start..start + len]
    }

    /// Clear the debug claim registry at an iteration barrier.
    pub fn release_all(&self) {
        #[cfg(debug_assertions)]
        self.claims.lock().unwrap().clear();
        #[cfg(not(debug_assertions))]
        {
            let _ = &self.claims;
        }
    }

    /// Take the array back out (single-threaded phase).
    pub fn into_inner(self) -> Vec<f32> {
        self.data.into_inner()
    }

    /// Read-only view; callers must ensure no concurrent writers (the
    /// engine only reads at iteration barriers).
    pub fn snapshot(&self) -> Vec<f32> {
        unsafe { (*self.data.get()).clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_claims_write_independently() {
        let dst = SharedDst::new(vec![0.0; 10]);
        std::thread::scope(|s| {
            let d = &dst;
            s.spawn(move || {
                let a = unsafe { d.claim(0, 5) };
                a.fill(1.0);
            });
            s.spawn(move || {
                let b = unsafe { d.claim(5, 5) };
                b.fill(2.0);
            });
        });
        let v = dst.into_inner();
        assert_eq!(&v[..5], &[1.0; 5]);
        assert_eq!(&v[5..], &[2.0; 5]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "overlapping dst claim")]
    fn overlap_detected_in_debug() {
        let dst = SharedDst::new(vec![0.0; 10]);
        unsafe {
            let _a = dst.claim(0, 6);
            let _b = dst.claim(5, 5);
        }
    }

    #[test]
    fn release_allows_reclaim() {
        let dst = SharedDst::new(vec![0.0; 4]);
        unsafe {
            dst.claim(0, 4)[0] = 3.0;
        }
        dst.release_all();
        unsafe {
            assert_eq!(dst.claim(0, 4)[0], 3.0);
        }
    }

    #[test]
    fn snapshot_reflects_writes() {
        let dst = SharedDst::new(vec![1.0; 3]);
        unsafe {
            dst.claim(1, 1)[0] = 9.0;
        }
        dst.release_all();
        assert_eq!(dst.snapshot(), vec![1.0, 9.0, 1.0]);
    }
}
