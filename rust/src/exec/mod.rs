//! The unified execution core: one schedule→prefetch→compute pipeline
//! for every engine.
//!
//! GraphMP's headline comparison (Tables 5–7, Figs 9–10) only holds up
//! when the *execution loop* is identical across systems and just the
//! I/O schedule differs — NXgraph (arXiv:1510.06916) shows that loop
//! differences otherwise dominate the measured gaps.  This module is
//! that shared loop:
//!
//! - [`ShardSource`] is the engine-specific half: what to load per
//!   iteration (schedule + load, with the engine's model I/O charged on
//!   the load path), how a loaded unit computes, and what residency to
//!   charge.  The VSW engine, GraphChi-PSW, X-Stream-ESG, GridGraph-DSW
//!   and the GraphMat-like in-memory engine all implement it.
//! - [`ExecCore`] is the engine-agnostic half: the iteration loop
//!   (convergence, active-set rebuild through [`schedule::ActiveBits`]),
//!   the contribution pre-fold for sum kernels, the bounded prefetch
//!   pipeline ([`pipeline::run_worklist`]), deterministic gathering of
//!   scatter-style units, iteration accounting (wall + simulated disk +
//!   overlap), cache-delta attachment, and the adaptive prefetch depth.
//!
//! Determinism: in-place units write disjoint [`SharedDst`] intervals;
//! scatter units ([`UnitOutput::Updates`]) are folded at the barrier in
//! worklist order regardless of completion order — so results are
//! bit-identical in worker count, prefetch depth, and engine (see
//! `rust/tests/cross_engine.rs`).
//!
//! Scan sharing (PR 4): [`ExecCore::run_batch`] runs a [`BatchJob`] set
//! of concurrent jobs over one shard pass per iteration — the per-pass
//! worklist is the **union** of the member jobs' active-shard worklists
//! (each job's own Bloom/`ActiveBits` selection still skips units
//! *within* the pass), every loaded unit is handed to each member job
//! whose worklist contains it, and per-job vertex lanes / scratch /
//! convergence stay isolated (a converged job drops out of the union
//! mid-batch).  Each unit's I/O is charged once per pass, so disk bytes
//! per job fall as ~1/N while per-job results stay bit-identical to N
//! back-to-back solo runs (`rust/tests/scan_sharing.rs`).
//! [`ExecCore::run`] is the single-job special case.
//!
//! Interactive scheduling (PR 5): [`ExecCore::run_batch_interactive`]
//! additionally polls an *intake* at every pass boundary, so new jobs
//! can join a batch already in flight — the admitted job's lanes
//! warm-start at that boundary with their own local iteration clock
//! (its trajectory is bit-identical to a solo run started then), and
//! running jobs are undisturbed.  When the union worklist is shorter
//! than the worker pool, (unit × job) sub-tasks are split across idle
//! workers ([`pipeline::FanOut`]); and each job's kernel time, served
//! units and processed edges are metered per (unit, job) into
//! [`crate::metrics::JobMetrics`] for fair per-query billing.

pub mod arena;
pub mod dst;
pub mod kernel;
pub mod lane;
pub mod pipeline;
pub mod schedule;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::apps::{Combine, ShardKernel, VertexProgram};
use crate::cache::EdgeCache;
use crate::graph::{Edge, VertexId};
use crate::metrics::{BatchMetrics, IterationMetrics, JobMetrics, RunMetrics};
use crate::storage::disk::Disk;
use arena::AlignedArena;
use lane::{with_lane, Lane};
pub use dst::SharedDst;
pub use lane::{LaneSlice, LaneSliceMut, LaneType, LaneVec};
pub use schedule::{ActiveBits, RangeMarker};

/// Execution knobs shared by every engine (the paper's settings).
#[derive(Clone, Copy, Debug)]
pub struct ExecConfig {
    /// Compute worker threads (paper: one shard per CPU core at a time).
    pub workers: usize,
    /// Ready-queue depth of the prefetcher: how many loaded units the
    /// I/O threads may stage ahead of the compute workers.  0 turns the
    /// pipeline off (units load inline on the worker — the sequential
    /// reference path and the determinism baseline).
    pub prefetch_depth: usize,
    /// Adapt the queue depth each iteration from the measured
    /// load-vs-compute rate of the previous one (`prefetch_depth` then
    /// only seeds iteration 0).
    pub prefetch_auto: bool,
    /// Dedicated I/O threads feeding the ready queue.  1–2 is enough to
    /// keep the *simulated* disk continuously busy (its cost model is
    /// depth-independent); a real backend rewards fan-in up to its
    /// submission depth, so arbitrary N is honored here and clamped to
    /// [`io_depth`](Self::io_depth) at pass setup (PR 9 — lifts the PR 1
    /// doc-level 1–2 cap).
    pub prefetch_threads: usize,
    /// The I/O backend's sustained submission depth (from
    /// [`Disk::submission_depth`]): upper bound for both the I/O thread
    /// fan-in and the adaptive prefetch depth.  Engines fill this from
    /// the disk they open; the default matches the sim backend.
    pub io_depth: usize,
    /// Split (unit × job) sub-tasks of a scan-shared pass across idle
    /// workers when the union worklist is shorter than the worker pool
    /// (jobs ≫ units).  Results are bit-identical either way; off means
    /// a unit's member jobs always compute serially on the claiming
    /// worker (the PR-4 behaviour, kept as the comparison baseline).
    pub fan_out: bool,
    /// Contain load/compute errors to the affected member jobs: the lane
    /// records the failure ([`crate::metrics::RunMetrics::failed`]) and
    /// drops out at the next boundary while the rest of the batch keeps
    /// running.  Off (the default), the first error aborts the whole
    /// batch — the single-job and historical semantics.
    pub isolate_failures: bool,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            // capped at the paper's core count: more workers than that
            // only adds context switches with no modelled benefit
            workers: std::thread::available_parallelism()
                .map_or(1, |n| n.get())
                .min(12),
            prefetch_depth: 4,
            prefetch_auto: false,
            prefetch_threads: 2,
            io_depth: 64,
            fan_out: true,
            isolate_failures: false,
        }
    }
}

/// Hard cap on the adaptive queue depth (bounds in-flight unit memory).
pub const MAX_AUTO_DEPTH: usize = 16;

/// Hard cap on the jobs one scan-shared batch may hold: unit membership
/// travels as a 64-bit mask.  [`crate::runtime::jobs::JobSet`] chunks
/// larger queues into successive batches.
pub const MAX_BATCH_JOBS: usize = 64;

/// One member of a scan-shared batch: the vertex program plus its own
/// iteration budget.  All members run over the same graph through the
/// same [`ShardSource`].
pub struct BatchJob<'a> {
    pub app: &'a dyn VertexProgram,
    pub max_iters: u32,
}

/// One job's outcome: final vertex values (in the job kernel's lane
/// type) plus its run metrics.
pub type JobOutput = (LaneVec, RunMetrics);

/// Warm-start state for one founding job of [`ExecCore::run_batch_with`]:
/// the lane exactly as a checkpoint captured it at a pass boundary.  A
/// resumed lane continues its own iteration clock at `iters_done`, so the
/// remainder of the run is bit-identical to the uninterrupted one.
#[derive(Clone, Debug, Default)]
pub struct ResumeState {
    pub values: LaneVec,
    pub active: Vec<VertexId>,
    /// Iterations the lane completed before the checkpoint.
    pub iters_done: u32,
    pub done: bool,
    pub converged: bool,
    pub failed: Option<String>,
}

/// Read-only view of one lane at a pass boundary, in admission order —
/// what a [`PassObserver`] (the checkpoint writer) gets to persist.
pub struct LaneSnapshot<'a> {
    pub values: LaneSlice<'a>,
    pub active: &'a [VertexId],
    /// Job-local iterations completed so far (the lane's clock).
    pub iters_done: u32,
    pub done: bool,
    pub converged: bool,
    pub failed: Option<&'a str>,
}

/// Pass-boundary hook of [`ExecCore::run_batch_with`]: called at every
/// boundary (pass 0 included) after lane lifecycle and admission, with
/// every lane admitted so far.  An `Err` aborts the batch — which is
/// exactly how the kill-at-iteration fault hook simulates a crash.
pub trait PassObserver {
    fn at_boundary(&mut self, pass: u32, lanes: &[LaneSnapshot<'_>]) -> Result<()>;
}

/// Verdict of a [`LaneArbiter`] for one still-running lane at a pass
/// boundary.
pub enum LaneVerdict {
    /// Keep running.
    Continue,
    /// End the lane at this boundary: it keeps its current values and
    /// job-local clock (the PR 6 lane-snapshot state), drops out of the
    /// union worklist before the next pass, and surfaces the reason in
    /// [`crate::metrics::RunMetrics::evicted`].  Surviving lanes are
    /// untouched — lane isolation makes their remainder bit-identical to
    /// a run without the evicted member.
    Evict(String),
}

/// Admission-control hook of [`ExecCore::run_batch_with`] (PR 8): lets a
/// scheduler end individual lanes (deadlines, wall-clock timeouts,
/// cancellations) or freeze the whole batch (graceful daemon shutdown) at
/// pass boundaries, without aborting like a [`PassObserver`] error does.
pub trait LaneArbiter {
    /// Per-lane decision, called for every lane that would otherwise run
    /// the next pass (admission order, before the boundary observer — an
    /// eviction is visible in the same boundary's checkpoint).
    fn decide(&mut self, _pass: u32, _lane: usize, _snap: &LaneSnapshot<'_>) -> LaneVerdict {
        LaneVerdict::Continue
    }

    /// Batch-level stop, checked after the boundary observer ran: `true`
    /// ends the batch cleanly with every unfinished lane frozen at its
    /// current state and marked evicted (reason "batch stopped …").  A
    /// checkpoint written at this same boundary captured those lanes
    /// *unfinished*, so a resumed batch continues them.
    fn stop_batch(&mut self, _pass: u32) -> bool {
        false
    }
}

/// Extra controls for [`ExecCore::run_batch_with`] beyond the interactive
/// intake: per-founder warm-start state, the boundary observer, and the
/// eviction arbiter.
#[derive(Default)]
pub struct BatchOptions<'o> {
    /// Entry `i` warm-starts `jobs[i]`; missing/`None` entries start fresh.
    pub resume: Vec<Option<ResumeState>>,
    /// Checkpoint/kill hook, called at every pass boundary.
    pub observer: Option<&'o mut dyn PassObserver>,
    /// Eviction/stop hook, consulted at every pass boundary.
    pub arbiter: Option<&'o mut dyn LaneArbiter>,
}

/// Per-iteration read-only context handed to [`ShardSource::compute`].
pub struct IterCtx<'a> {
    pub kernel: ShardKernel,
    pub num_vertices: u32,
    /// The previous iteration's vertex values (read-only this iteration),
    /// type-erased; the kernels extract the typed slice once per unit.
    pub src: LaneSlice<'a>,
    pub inv_out_deg: &'a [f32],
    /// Pre-folded `src · inv_out_deg` for sum kernels (|V| multiplies
    /// once, instead of |E| per-edge products); empty otherwise.
    pub contrib: &'a [f32],
    pub iteration: u32,
}

impl IterCtx<'_> {
    /// One edge's gathered contribution.  Degree-mass kernels read the
    /// pre-folded array; everything else folds from `src` + weight.
    /// `T` must be the kernel's lane type.
    #[inline]
    pub fn edge_value<T: Lane>(&self, e: &Edge) -> T {
        if self.kernel.uses_contrib() {
            T::from_mass(self.contrib[e.src as usize])
        } else {
            self.kernel.edge_value_t(T::of_slice(self.src)[e.src as usize], 0.0, e.weight)
        }
    }
}

/// A deferred write produced by scatter-style units (X-Stream's update
/// stream): folded deterministically at the iteration barrier.  The
/// value travels as its raw bit pattern (zero-extended to 64 bits) so
/// one update stream type serves every lane; the barrier types it back
/// out with [`Update::val`] under the kernel's lane.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Update {
    pub dst: VertexId,
    pub bits: u64,
}

impl Update {
    #[inline]
    pub fn new<T: Lane>(dst: VertexId, val: T) -> Update {
        Update { dst, bits: val.to_bits64() }
    }

    #[inline]
    pub fn val<T: Lane>(&self) -> T {
        T::from_bits64(self.bits)
    }
}

/// What one unit's compute produced.
pub enum UnitOutput {
    /// The unit wrote its exclusive destination rows in place (and marked
    /// activations itself).
    InPlace,
    /// Scatter-style updates for the barrier to fold in worklist order.
    Updates(Vec<Update>),
}

/// Run-scoped free lists backing the per-worker [`Scratch`] arenas.
///
/// The steady-state iteration used to allocate per *unit*: a fresh
/// `vec![0.0; rows]` sum accumulator in the list fold and a fresh
/// `Vec<Update>` per scatter unit.  The pool keeps those buffers alive
/// across units *and iterations*: workers lease a [`Scratch`] at spawn
/// (buffers return on drop), and the barrier recycles drained scatter
/// buffers — so after warm-up the compute path performs no per-unit heap
/// allocation.  Fold scratch is backed by 64-byte-aligned
/// [`AlignedArena`]s (one value arena + one cursor arena per lease) so
/// the chunked kernels' accumulators sit on cache-line boundaries, and
/// arenas are recycled at that same alignment.
#[derive(Default)]
pub struct ScratchPool {
    arenas: Mutex<Vec<AlignedArena>>,
    update_bufs: Mutex<Vec<Vec<Update>>>,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lease a worker scratch; its arenas return to the pool on drop.
    pub fn scratch(&self) -> Scratch<'_> {
        let (vals, idx) = self.take_arenas();
        Scratch { pool: self, vals, idx }
    }

    /// Pop a (value, cursor) arena pair — shared by worker leases and
    /// the barrier's update fold.
    fn take_arenas(&self) -> (AlignedArena, AlignedArena) {
        let mut arenas = self.arenas.lock().unwrap();
        let vals = arenas.pop().unwrap_or_default();
        let idx = arenas.pop().unwrap_or_default();
        (vals, idx)
    }

    fn put_arenas(&self, vals: AlignedArena, idx: AlignedArena) {
        let mut arenas = self.arenas.lock().unwrap();
        arenas.push(vals);
        arenas.push(idx);
    }

    /// Return a drained scatter buffer for reuse (capacity preserved).
    pub fn recycle_updates(&self, mut buf: Vec<Update>) {
        buf.clear();
        self.update_bufs.lock().unwrap().push(buf);
    }
}

/// Per-worker reusable buffers, threaded through `run_worklist`'s worker
/// state into every [`ShardSource::compute`] call.
pub struct Scratch<'p> {
    pool: &'p ScratchPool,
    vals: AlignedArena,
    idx: AlignedArena,
}

impl Scratch<'_> {
    /// The fold's 64-byte-aligned scratch arenas — value buckets and
    /// counting-sort cursors, sized by the fold that uses them.
    fn arenas(&mut self) -> (&mut AlignedArena, &mut AlignedArena) {
        (&mut self.vals, &mut self.idx)
    }

    /// Take an empty scatter buffer (capacity reused across iterations);
    /// hand it back through [`UnitOutput::Updates`] — the barrier
    /// recycles it after folding.
    pub fn take_updates(&self) -> Vec<Update> {
        self.pool.update_bufs.lock().unwrap().pop().unwrap_or_default()
    }
}

impl Drop for Scratch<'_> {
    fn drop(&mut self) {
        self.pool.put_arenas(std::mem::take(&mut self.vals), std::mem::take(&mut self.idx));
    }
}

/// The engine-specific half of the execution core: an I/O schedule over
/// loadable units plus the per-unit compute.
pub trait ShardSource: Sync {
    /// A loaded unit travelling from the I/O stage to a compute worker.
    /// `Clone` is the multi-consumer contract of scan sharing: a unit in
    /// several member jobs' worklists is loaded once and handed to each
    /// of them (engines stage cheaply-cloneable items — the VSW engine an
    /// `Arc<ShardView>`, the modelled baselines unit markers).
    type Item: Send + Clone;

    /// Schedule stage: this iteration's unit worklist plus the number of
    /// units skipped (selective scheduling; engines without it return
    /// the full worklist and 0).
    fn schedule(&self, iteration: u32, active: &[VertexId]) -> (Vec<u32>, u32);

    /// Load stage — runs on the dedicated I/O threads when pipelined,
    /// inline on workers otherwise.  Engines charge their per-unit read
    /// model (or perform real reads) here so (simulated) disk time
    /// overlaps compute.
    fn load(&self, id: u32) -> Result<Self::Item>;

    /// Compute stage — runs on the compute workers.  In-place units
    /// claim their exclusive rows from `dst` and mark activations into
    /// `marker`; scatter units return their update stream (take the
    /// buffer from `scratch` so its capacity is reused).  Per-unit
    /// write-back charges belong here (they are part of processing the
    /// unit, not of the barrier).
    fn compute(
        &self,
        id: u32,
        item: Self::Item,
        ctx: &IterCtx<'_>,
        dst: &SharedDst,
        marker: &mut RangeMarker<'_>,
        scratch: &mut Scratch<'_>,
    ) -> Result<UnitOutput>;

    /// Edges one loaded unit holds — drives the per-job
    /// `edges_processed` meter ([`crate::metrics::JobMetrics`]).  Engines
    /// that don't track per-unit edge counts keep the default 0.
    fn unit_edges(&self, _id: u32, _item: &Self::Item) -> u64 {
        0
    }

    /// On-disk bytes of one loaded unit — weighs the per-job share of
    /// [`crate::metrics::JobMetrics::effective_bytes_read`] by the bytes
    /// each serving actually cost, not by serving counts (shards can
    /// differ in size by orders of magnitude).  Engines without a
    /// per-unit byte model keep the default 0, which falls back to
    /// serving-count attribution.
    fn unit_bytes(&self, _id: u32, _item: &Self::Item) -> u64 {
        0
    }

    /// Barrier stage: residual per-iteration charges (e.g. the gather
    /// phase's update-stream read and vertex write-back).
    fn end_iteration(&self, _ctx: &IterCtx<'_>, _updates_folded: u64) {}

    /// The engine's resident-memory model in bytes (Fig 11 / Table 3's
    /// memory column) — recorded on the run's metrics.
    fn residency_bytes(&self) -> u64;
}

/// Fold destination-grouped `edges` into `out`, which covers the vertex
/// rows `[lo, lo + out.len())` and enters holding their current values.
/// Dispatches into the monomorphized, chunk-vectorized
/// [`kernel::fold_list`] (branch-free per edge; sums bucket values by
/// destination into the worker's 64-byte-aligned scratch arenas and run
/// the canonical chunked row sum).  Bit-identical to the CSR row loop
/// (`engine::native_update`) as long as each destination's edges arrive
/// in the same order — the repo-wide canonical layout is ascending
/// source id.
pub fn fold_edges_interval(
    ctx: &IterCtx<'_>,
    edges: &[Edge],
    lo: u32,
    out: LaneSliceMut<'_>,
    scratch: &mut Scratch<'_>,
) {
    let (vals, idx) = scratch.arenas();
    kernel::fold_list(ctx, edges, lo, out, vals, idx);
}

/// Mark every row of `[lo, lo + out.len())` whose new value activates it.
pub fn mark_interval(ctx: &IterCtx<'_>, lo: u32, out: LaneSlice<'_>, marker: &mut RangeMarker<'_>) {
    kernel::mark_rows(ctx, lo, out, marker);
}

/// The engine-agnostic execution driver.  Holds the run-scoped state the
/// iterations share: the disk (for I/O deltas), an optional attached
/// cache (for cache-counter deltas), and the adaptive prefetch depth.
pub struct ExecCore<'a> {
    cfg: ExecConfig,
    disk: &'a Disk,
    cache: Option<&'a EdgeCache>,
    auto_depth: usize,
    /// Worker scratch arenas, reused across units and iterations.
    scratch: ScratchPool,
}

impl<'a> ExecCore<'a> {
    pub fn new(cfg: ExecConfig, disk: &'a Disk, cache: Option<&'a EdgeCache>) -> Self {
        let seed = cfg.prefetch_depth.clamp(1, MAX_AUTO_DEPTH);
        ExecCore { cfg, disk, cache, auto_depth: seed, scratch: ScratchPool::new() }
    }

    /// Run `app` through `source` for at most `max_iters` iterations
    /// (stopping early once no vertex is active, Algorithm 2 line 2) and
    /// return the final vertex values with the run's metrics.  The
    /// single-job special case of [`run_batch`](Self::run_batch).
    pub fn run<S: ShardSource>(
        &mut self,
        source: &S,
        app: &dyn VertexProgram,
        num_vertices: u32,
        inv_out_deg: &[f32],
        max_iters: u32,
    ) -> Result<JobOutput> {
        let (mut outs, _) =
            self.run_batch(source, &[BatchJob { app, max_iters }], num_vertices, inv_out_deg)?;
        Ok(outs.pop().expect("one job in, one result out"))
    }

    /// Run a scan-shared batch: every pass loads the **union** of the
    /// member jobs' active-shard worklists exactly once and hands each
    /// loaded unit to every job whose own worklist contains it, while
    /// per-job vertex lanes, activation bitsets and convergence stay
    /// isolated.  Returns per-job `(values, metrics)` in submission
    /// order (bit-identical to solo runs) plus the batch aggregate.
    /// For mid-batch admission see
    /// [`run_batch_interactive`](Self::run_batch_interactive).
    pub fn run_batch<S: ShardSource>(
        &mut self,
        source: &S,
        jobs: &[BatchJob<'_>],
        num_vertices: u32,
        inv_out_deg: &[f32],
    ) -> Result<(Vec<JobOutput>, BatchMetrics)> {
        anyhow::ensure!(!jobs.is_empty(), "empty job batch");
        self.run_batch_interactive(source, jobs, num_vertices, inv_out_deg, |_, _| Vec::new())
    }

    /// [`run_batch`](Self::run_batch) plus **interactive admission**: at
    /// every pass boundary `intake(pass, running)` is polled for newly
    /// arrived jobs, which warm-start at that boundary — fresh lanes
    /// (`SharedDst`, activation bitset, scatter slots), a job-local
    /// iteration clock starting at 0, and their schedules folded into
    /// the union worklist from the next pass on.  Admission never
    /// perturbs running jobs: their per-lane state is isolated, so an
    /// admitted job's trajectory is bit-identical to a solo run started
    /// at its admission, and running jobs' trajectories are unchanged.
    ///
    /// Admission control: at most [`MAX_BATCH_JOBS`] jobs run
    /// concurrently (unit membership travels as a 64-bit mask).  Arrivals
    /// beyond the cap wait, FIFO, for a boundary where capacity freed up
    /// (counted in [`BatchMetrics::admissions_deferred`]).  The batch
    /// ends at a boundary where nothing is running, nothing is waiting,
    /// and the intake returns no new jobs — callers replaying a finite
    /// arrival schedule should release overdue arrivals when `running`
    /// is 0 so a fully converged batch fast-forwards to them.
    ///
    /// Per-job outputs are returned in admission order: the initial
    /// `jobs` first, then mid-batch admissions as they were admitted.
    pub fn run_batch_interactive<'j, S, F>(
        &mut self,
        source: &S,
        jobs: &[BatchJob<'j>],
        num_vertices: u32,
        inv_out_deg: &[f32],
        intake: F,
    ) -> Result<(Vec<JobOutput>, BatchMetrics)>
    where
        S: ShardSource,
        F: FnMut(u32, usize) -> Vec<BatchJob<'j>>,
    {
        self.run_batch_with(
            source,
            jobs,
            num_vertices,
            inv_out_deg,
            intake,
            BatchOptions::default(),
        )
    }

    /// [`run_batch_interactive`](Self::run_batch_interactive) plus crash
    /// recovery plumbing: founding jobs may warm-start from
    /// [`ResumeState`] (their lanes continue the job-local iteration
    /// clock a checkpoint captured), and a [`PassObserver`] is called at
    /// every pass boundary to persist checkpoints or inject a kill.
    pub fn run_batch_with<'j, S, F>(
        &mut self,
        source: &S,
        jobs: &[BatchJob<'j>],
        num_vertices: u32,
        inv_out_deg: &[f32],
        mut intake: F,
        mut opts: BatchOptions<'_>,
    ) -> Result<(Vec<JobOutput>, BatchMetrics)>
    where
        S: ShardSource,
        F: FnMut(u32, usize) -> Vec<BatchJob<'j>>,
    {
        anyhow::ensure!(
            jobs.len() <= MAX_BATCH_JOBS,
            "at most {MAX_BATCH_JOBS} jobs per batch (got {})",
            jobs.len()
        );
        let n = num_vertices;
        let mut lanes: Vec<JobLane> = Vec::with_capacity(jobs.len());
        for (i, job) in jobs.iter().enumerate() {
            let mut lane = JobLane::new(job, n, inv_out_deg)?;
            if let Some(Some(rs)) = opts.resume.get_mut(i) {
                lane.restore(std::mem::take(rs), n)?;
            }
            lanes.push(lane);
        }

        let run_start = Instant::now();
        let sim_start = self.disk.snapshot().sim_nanos;
        let mut batch = BatchMetrics { jobs: jobs.len() as u32, ..Default::default() };
        // arrivals validated but waiting for a boundary with capacity
        let mut waiting: VecDeque<JobLane> = VecDeque::new();
        let mut pass = 0u32;
        loop {
            // lane lifecycle at the pass boundary: converged jobs (empty
            // active set) and exhausted budgets drop out of the union
            let mut running = Vec::new();
            for (l, lane) in lanes.iter_mut().enumerate() {
                if lane.done {
                    continue;
                }
                if lane.failed.is_some() {
                    lane.done = true;
                } else if lane.active.is_empty() {
                    lane.run.converged = true;
                    lane.done = true;
                } else if lane.iters_done >= lane.max_iters {
                    lane.done = true;
                } else {
                    // arbiter check: deadlines / timeouts / cancellations
                    // end the lane here, its snapshot state preserved
                    let verdict = match opts.arbiter.as_mut() {
                        Some(arb) => {
                            let snap = LaneSnapshot {
                                values: lane.src.as_slice(),
                                active: &lane.active,
                                iters_done: lane.iters_done,
                                done: false,
                                converged: false,
                                failed: None,
                            };
                            arb.decide(pass, l, &snap)
                        }
                        None => LaneVerdict::Continue,
                    };
                    match verdict {
                        LaneVerdict::Continue => running.push(l),
                        LaneVerdict::Evict(reason) => {
                            lane.evicted = Some(reason);
                            lane.done = true;
                            batch.jobs_evicted += 1;
                        }
                    }
                }
            }
            // interactive admission: poll the intake, then warm-start as
            // many waiting arrivals as fit under the concurrency cap
            for job in intake(pass, running.len()) {
                batch.jobs += 1;
                waiting.push_back(JobLane::new(&job, n, inv_out_deg)?);
            }
            while running.len() < MAX_BATCH_JOBS {
                let Some(mut lane) = waiting.pop_front() else { break };
                lane.admit_pass = pass;
                if pass > 0 {
                    batch.admitted_mid_batch += 1;
                }
                if lane.active.is_empty() {
                    // degenerate: converged at init
                    lane.run.converged = true;
                    lane.done = true;
                } else if lane.max_iters == 0 {
                    lane.done = true;
                }
                lanes.push(lane);
                if !lanes.last().unwrap().done {
                    running.push(lanes.len() - 1);
                }
            }
            for lane in waiting.iter_mut() {
                if !lane.deferred {
                    lane.deferred = true;
                    batch.admissions_deferred += 1;
                }
            }
            // boundary hook: the checkpoint writer persists every lane's
            // post-admission state here (and the kill hook aborts here)
            if let Some(obs) = opts.observer.as_mut() {
                let snaps: Vec<LaneSnapshot<'_>> = lanes
                    .iter()
                    .map(|lane| LaneSnapshot {
                        values: lane.src.as_slice(),
                        active: &lane.active,
                        iters_done: lane.iters_done,
                        done: lane.done,
                        converged: lane.run.converged,
                        failed: lane.failed.as_deref(),
                    })
                    .collect();
                obs.at_boundary(pass, &snaps)?;
            }
            // batch-level stop (graceful shutdown): freeze every unfinished
            // lane — the observer above already persisted them *unfinished*,
            // so a resumed batch picks them up at exactly this boundary
            if opts.arbiter.as_mut().is_some_and(|arb| arb.stop_batch(pass)) {
                let reason = format!("batch stopped at pass boundary {pass}");
                for lane in lanes.iter_mut() {
                    if !lane.done {
                        lane.evicted = Some(reason.clone());
                        lane.done = true;
                        batch.jobs_evicted += 1;
                    }
                }
                // arrivals still waiting for capacity were persisted as
                // pending; surface them as evicted outputs too so callers
                // get one output per admitted job
                while let Some(mut lane) = waiting.pop_front() {
                    lane.admit_pass = pass;
                    lane.evicted = Some(reason.clone());
                    lane.done = true;
                    batch.jobs_evicted += 1;
                    lanes.push(lane);
                }
                batch.stopped_at_pass = Some(pass);
                break;
            }
            if running.is_empty() {
                debug_assert!(waiting.is_empty(), "capacity exists, so waiting drained");
                break;
            }
            let stats = self.run_pass(source, &mut lanes, &running, inv_out_deg)?;
            batch.shard_loads += stats.loads;
            batch.shard_servings += stats.servings;
            batch.shard_servings_fanned += stats.fanned;
            batch.bytes_read += stats.bytes_read;
            pass += 1;
        }
        batch.passes = pass;
        batch.total_wall = run_start.elapsed();
        batch.total_sim_disk_seconds =
            (self.disk.snapshot().sim_nanos - sim_start) as f64 / 1e9;

        let total_servings = batch.shard_servings.max(1);
        // byte-weighted attribution: each serving is weighed by the bytes
        // it actually cost (`ShardSource::unit_bytes`); engines without a
        // per-unit byte model fall back to serving counts
        let total_byte_weight: u64 = lanes.iter().map(|l| l.meter_bytes).sum();
        batch.jobs_failed = lanes.iter().filter(|l| l.failed.is_some()).count() as u32;
        let outs = lanes
            .into_iter()
            .map(|mut lane| {
                lane.run.total_wall = batch.total_wall;
                lane.run.total_sim_disk_seconds = batch.total_sim_disk_seconds;
                lane.run.total_overlapped_sim_seconds =
                    lane.run.iterations.iter().map(|m| m.overlapped_sim_seconds).sum();
                lane.run.memory_bytes = source.residency_bytes();
                // per-job attribution: this job's weighted share of the
                // batch's disk bytes plus its metered kernel time
                lane.run.job = JobMetrics {
                    admitted_pass: lane.admit_pass,
                    iterations: lane.run.iterations.len() as u32,
                    compute: lane.meter_compute,
                    units_served: lane.meter_units,
                    edges_processed: lane.meter_edges,
                    effective_bytes_read: if total_byte_weight > 0 {
                        batch.bytes_read as f64 * lane.meter_bytes as f64
                            / total_byte_weight as f64
                    } else {
                        batch.bytes_read as f64 * lane.meter_units as f64
                            / total_servings as f64
                    },
                };
                lane.run.failed = lane.failed;
                lane.run.evicted = lane.evicted;
                batch.per_job.push(lane.run.job);
                (lane.src, lane.run)
            })
            .collect();
        Ok((outs, batch))
    }

    /// One shard pass of Algorithm 2 over the `running` lanes: per-job
    /// schedules merged into the union worklist, one schedule → prefetch
    /// → compute pipeline over it (each loaded unit fanned out to its
    /// member jobs — serially on the claiming worker, or split across
    /// idle workers when the union is short), then a per-job barrier
    /// swap.  Lanes admitted mid-batch see their *local* iteration
    /// number everywhere (schedule, kernel context, metrics), so their
    /// trajectory matches a solo run started at their admission.
    fn run_pass<S: ShardSource>(
        &mut self,
        source: &S,
        lanes: &mut [JobLane],
        running: &[usize],
        inv_out_deg: &[f32],
    ) -> Result<PassStats> {
        let n = lanes[running[0]].src.len();
        let nr = running.len();
        let io_before = self.disk.snapshot();
        let cache_before = self.cache.map(|c| c.snapshot()).unwrap_or_default();
        let t0 = Instant::now();

        // stage 1: each job's scheduler decides its own worklist (per-job
        // Bloom/active selection), then the scan-sharing union merges them
        let mut wls: Vec<Vec<u32>> = Vec::with_capacity(nr);
        let mut skips: Vec<u32> = Vec::with_capacity(nr);
        for &l in running {
            let lane = &lanes[l];
            let (wl, sk) = source.schedule(lane.iters_done, &lane.active);
            wls.push(wl);
            skips.push(sk);
        }
        let (union_wl, members) = schedule::union_worklists(&wls);
        let servings: u64 = members.iter().map(|m| u64::from(m.count_ones())).sum();

        // §Perf: for sum kernels, fold src·inv_out_deg once per iteration
        // (|V| multiplies) instead of once per edge (|E| ≫ |V| gathers).
        // The per-lane buffer keeps its capacity across passes.
        for &l in running {
            let lane = &mut lanes[l];
            if lane.kernel.uses_contrib() {
                lane.contrib.clear();
                lane.contrib
                    .extend(lane.src.f32s().iter().zip(inv_out_deg).map(|(&v, &d)| v * d));
            }
        }

        let depth = if self.cfg.prefetch_depth == 0 {
            0 // pipeline off: the sequential reference path wins outright
        } else if self.cfg.prefetch_auto {
            self.auto_depth
        } else {
            self.cfg.prefetch_depth
        }
        // staging past the backend's sustained submission depth only
        // parks loaded units in RAM (no-op on sim: io_depth 64 > caps)
        .min(self.cfg.io_depth.max(1));
        // I/O fan-in beyond the submission depth would just queue inside
        // the backend's ring; arbitrary N below that is honored (PR 9)
        let io_threads = self.cfg.prefetch_threads.min(self.cfg.io_depth.max(1));

        let lanes_ro: &[JobLane] = lanes;
        let ctxs: Vec<IterCtx<'_>> = running
            .iter()
            .map(|&l| {
                let lane = &lanes_ro[l];
                IterCtx {
                    kernel: lane.kernel,
                    num_vertices: n as u32,
                    src: lane.src.as_slice(),
                    inv_out_deg,
                    contrib: &lane.contrib,
                    iteration: lane.iters_done,
                }
            })
            .collect();
        let dsts: Vec<SharedDst> = running
            .iter()
            .map(|&l| SharedDst::new(lanes_ro[l].src.clone()))
            .collect();
        let bits: Vec<ActiveBits> = (0..nr).map(|_| ActiveBits::new(n)).collect();
        // scatter-unit outputs, slot-indexed by (union position × job) so
        // each job's barrier fold is deterministic in completion order
        let slots: Mutex<Vec<Option<Vec<Update>>>> =
            Mutex::new((0..union_wl.len() * nr).map(|_| None).collect());
        // per-(unit, job) meters, indexed by running position (atomics:
        // sub-tasks of one job may run on several workers at once)
        let meters: Vec<PassMeter> = (0..nr).map(|_| PassMeter::default()).collect();

        // (unit × job) fan-out: when the union worklist can't occupy the
        // worker pool on its own, member-job sub-tasks spread to idle
        // workers instead of queueing behind the claiming one
        let fan_counts: Vec<u32> = members.iter().map(|m| m.count_ones()).collect();
        let split = self.cfg.fan_out && nr > 1 && union_wl.len() < self.cfg.workers.max(1);

        // stages 2+3: I/O threads stage each union unit into the bounded
        // ready queue exactly once; the pipeline hands it to every member
        // job as a (unit, job) sub-task (see `pipeline::FanOut`).
        //
        // Load results travel through the ready queue as `Result` items:
        // a failed load reaches every member job of the unit, where it
        // either aborts the batch (the historical first-error semantics)
        // or, with `isolate_failures`, marks just those lanes failed and
        // lets the pass finish for everyone else.
        let isolate = self.cfg.isolate_failures;
        let fails: Mutex<Vec<(usize, u32, String)>> = Mutex::new(Vec::new());
        let pool = &self.scratch;
        let outcome = pipeline::run_worklist(
            &union_wl,
            pipeline::FanOut { counts: &fan_counts, split },
            self.cfg.workers,
            depth,
            io_threads,
            |id| Ok(source.load(id).map_err(std::sync::Arc::new)),
            || pool.scratch(),
            |scratch, index, id, sub, item: Result<S::Item, std::sync::Arc<anyhow::Error>>| {
                let r = nth_member(members[index], sub);
                let item = match item {
                    Ok(item) => item,
                    Err(e) => {
                        let msg = format!("load unit {id}: {e:#}");
                        if isolate {
                            fails.lock().unwrap().push((r, id, msg));
                            return Ok(());
                        }
                        return Err(anyhow::anyhow!("{msg}"));
                    }
                };
                let edges = source.unit_edges(id, &item);
                let bytes = source.unit_bytes(id, &item);
                let t = Instant::now();
                let mut marker = bits[r].marker();
                let out =
                    match source.compute(id, item, &ctxs[r], &dsts[r], &mut marker, scratch) {
                        Ok(out) => out,
                        Err(e) => {
                            drop(marker);
                            let msg = format!("compute unit {id}: {e:#}");
                            if isolate {
                                fails.lock().unwrap().push((r, id, msg));
                                return Ok(());
                            }
                            return Err(anyhow::anyhow!("{msg}"));
                        }
                    };
                drop(marker);
                let dt = t.elapsed().as_nanos() as u64;
                match out {
                    UnitOutput::InPlace => {}
                    UnitOutput::Updates(u) => {
                        slots.lock().unwrap()[index * nr + r] = Some(u);
                    }
                }
                let m = &meters[r];
                m.compute_nanos.fetch_add(dt, Ordering::Relaxed);
                m.units.fetch_add(1, Ordering::Relaxed);
                m.edges.fetch_add(edges, Ordering::Relaxed);
                m.bytes.fetch_add(bytes, Ordering::Relaxed);
                Ok(())
            },
        )?;

        let mut nexts: Vec<LaneVec> = dsts
            .into_iter()
            .map(|d| {
                d.release_all();
                d.into_inner()
            })
            .collect();
        // Snapshot at the end of the pipeline phase: only simulated disk
        // time charged while the load/compute stages were running can
        // overlap compute.  Barrier-stage charges (a scatter engine's
        // gather read + write-back in `end_iteration`) happen after all
        // compute finished and stay on the critical path.
        let io_pipeline = self.disk.snapshot();
        let wall_pipeline = t0.elapsed();
        // barrier: per job, fold its scatter streams (union-worklist
        // order) and charge the engine's residual iteration I/O
        let mut slots = slots.into_inner().unwrap();
        for r in 0..nr {
            let mine: Vec<Option<Vec<Update>>> =
                (0..union_wl.len()).map(|i| slots[i * nr + r].take()).collect();
            let updates_folded = if mine.iter().any(Option::is_some) {
                fold_updates(&ctxs[r], mine, &mut nexts[r], &bits[r], pool)
            } else {
                0
            };
            source.end_iteration(&ctxs[r], updates_folded);
        }
        // per-job cache attribution: one admission/probe served `servings`
        // job-consumptions this pass
        if let Some(c) = self.cache {
            c.note_job_servings(servings);
        }
        drop(ctxs);

        let wall = t0.elapsed();
        let io_after = self.disk.snapshot();
        let sim_disk_seconds = (io_after.sim_nanos - io_before.sim_nanos) as f64 / 1e9;
        // Pipeline overlap model: with dedicated I/O threads the (simulated)
        // device streams concurrently with compute, so the pipeline phase
        // costs max(wall, sim) instead of wall + sim — i.e. min(wall, sim)
        // of the device time charged *during that phase* is hidden.
        // Without prefetching every charge sits on the critical path,
        // exactly the pre-pipeline accounting.
        let sim_pipeline_seconds =
            (io_pipeline.sim_nanos - io_before.sim_nanos) as f64 / 1e9;
        let pipelined = depth > 0 && io_threads > 0;
        let overlapped_sim_seconds = if pipelined {
            sim_pipeline_seconds.min(wall_pipeline.as_secs_f64())
        } else {
            0.0
        };

        if self.cfg.prefetch_auto {
            // On a real backend `io_busy`/`compute_busy` are measured
            // device/kernel wall times, so auto depth calibrates against
            // hardware; on sim they track the profiled model.  Either
            // way the result cannot exceed the backend's queue depth.
            self.auto_depth = adaptive_depth(&outcome, self.cfg.workers, self.auto_depth)
                .min(self.cfg.io_depth.max(1));
        }

        let io_delta = io_after.since(&io_before);
        let cache_delta = match self.cache {
            Some(c) => {
                let after = c.snapshot();
                crate::cache::CacheSnapshot {
                    hits: after.hits - cache_before.hits,
                    misses: after.misses - cache_before.misses,
                    admitted: after.admitted - cache_before.admitted,
                    rejected: after.rejected - cache_before.rejected,
                    used_bytes: after.used_bytes,
                    decodes: after.decodes - cache_before.decodes,
                    decode_skips: after.decode_skips - cache_before.decode_skips,
                    crc_verifies: after.crc_verifies - cache_before.crc_verifies,
                    crc_verifies_skipped: after.crc_verifies_skipped
                        - cache_before.crc_verifies_skipped,
                    memo_bytes: after.memo_bytes,
                    job_servings: after.job_servings - cache_before.job_servings,
                }
            }
            None => Default::default(),
        };

        for (r, &l) in running.iter().enumerate() {
            let lane = &mut lanes[l];
            let m = &meters[r];
            let compute_nanos = m.compute_nanos.load(Ordering::Relaxed);
            lane.meter_compute += Duration::from_nanos(compute_nanos);
            lane.meter_units += m.units.load(Ordering::Relaxed);
            lane.meter_edges += m.edges.load(Ordering::Relaxed);
            lane.meter_bytes += m.bytes.load(Ordering::Relaxed);
            lane.src = std::mem::take(&mut nexts[r]);
            lane.active = bits[r].to_sorted_vec();
            lane.run.iterations.push(IterationMetrics {
                iteration: lane.iters_done,
                wall,
                sim_disk_seconds,
                overlapped_sim_seconds,
                active_vertices: lane.active.len() as u64,
                active_ratio: lane.active.len() as f64 / n.max(1) as f64,
                shards_processed: wls[r].len() as u32,
                shards_skipped: skips[r],
                shards_prefetched: outcome.prefetched,
                ready_hits: outcome.ready_hits,
                ready_misses: outcome.ready_misses,
                prefetch_depth_used: depth as u32,
                jobs_in_pass: nr as u32,
                shard_servings: servings as u32,
                shard_servings_fanned: outcome.fanned,
                job_compute_seconds: compute_nanos as f64 / 1e9,
                io: io_delta,
                cache: cache_delta,
            });
            lane.iters_done += 1;
        }
        // apply contained failures (isolate_failures): the affected lanes
        // keep their first failure by deterministic (lane, unit) order and
        // drop out at the next boundary; everyone else is untouched
        let mut failed_now = fails.into_inner().unwrap();
        failed_now.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        for (r, _, msg) in failed_now {
            let lane = &mut lanes[running[r]];
            if lane.failed.is_none() {
                lane.failed = Some(msg);
            }
        }
        Ok(PassStats {
            loads: u64::from(outcome.units),
            servings,
            fanned: u64::from(outcome.fanned),
            bytes_read: io_delta.bytes_read,
        })
    }
}

/// Per-job state of a scan-shared batch: its own vertex lane, active
/// set, pre-folded contribution buffer, metrics and per-job meter.
struct JobLane {
    kernel: ShardKernel,
    src: LaneVec,
    active: Vec<VertexId>,
    contrib: Vec<f32>,
    run: RunMetrics,
    max_iters: u32,
    /// Pass boundary this lane joined the batch at (0 = founding member).
    admit_pass: u32,
    /// The lane's own iteration clock: job-local iterations completed so
    /// far.  Resumed lanes start it at the checkpointed value, so
    /// `max_iters` stays a total budget across the interruption.
    iters_done: u32,
    done: bool,
    /// First contained failure (isolated mode): the lane drops out at the
    /// next boundary and surfaces this in [`RunMetrics::failed`].
    failed: Option<String>,
    /// Eviction reason when a [`LaneArbiter`] ended this lane at a pass
    /// boundary (surfaced in [`RunMetrics::evicted`]).
    evicted: Option<String>,
    /// Whether the lane ever waited for admission capacity (counted once
    /// in [`BatchMetrics::admissions_deferred`]).
    deferred: bool,
    meter_compute: Duration,
    meter_units: u64,
    meter_edges: u64,
    /// Byte-weight of the servings this lane consumed (see
    /// [`ShardSource::unit_bytes`]).
    meter_bytes: u64,
}

impl JobLane {
    /// Validate and warm-start a lane for `job` (fresh vertex values and
    /// activation set from the app's `init`).
    fn new(job: &BatchJob<'_>, n: u32, inv_out_deg: &[f32]) -> Result<JobLane> {
        let kernel = job.app.kernel();
        if kernel.uses_contrib() {
            anyhow::ensure!(
                inv_out_deg.len() == n as usize,
                "{} needs the out-degree array",
                job.app.name()
            );
        }
        // only f32 lanes carry vertex ids as values imprecisely; integer
        // lanes are exact at any id, so the guard is per lane type
        if kernel.lane == LaneType::F32 {
            anyhow::ensure!(n < (1 << 24), "f32 vertex values require ids < 2^24 (got {n})");
        }
        let (src, active) = job.app.init(n);
        anyhow::ensure!(src.len() == n as usize, "init length mismatch");
        anyhow::ensure!(
            src.lane_type() == kernel.lane,
            "{}: init lane {} does not match kernel lane {}",
            job.app.name(),
            src.lane_type().name(),
            kernel.lane.name()
        );
        Ok(JobLane {
            kernel,
            src,
            active,
            contrib: Vec::new(),
            run: RunMetrics::default(),
            max_iters: job.max_iters,
            admit_pass: 0,
            iters_done: 0,
            done: false,
            failed: None,
            evicted: None,
            deferred: false,
            meter_compute: Duration::ZERO,
            meter_units: 0,
            meter_edges: 0,
            meter_bytes: 0,
        })
    }

    /// Overwrite the fresh `init` state with a checkpointed lane: values,
    /// active set, the job-local clock, and terminal flags.  The lane then
    /// replays exactly the remainder of the interrupted run.
    fn restore(&mut self, rs: ResumeState, n: u32) -> Result<()> {
        anyhow::ensure!(
            rs.values.len() == n as usize,
            "resume state holds {} vertex values, graph has {n}",
            rs.values.len()
        );
        anyhow::ensure!(
            rs.values.lane_type() == self.kernel.lane,
            "resume state lane {} does not match kernel lane {}",
            rs.values.lane_type().name(),
            self.kernel.lane.name()
        );
        if let Some(&v) = rs.active.iter().max() {
            anyhow::ensure!(v < n, "resume state activates vertex {v} >= {n}");
        }
        self.src = rs.values;
        self.active = rs.active;
        self.iters_done = rs.iters_done;
        self.done = rs.done;
        self.run.converged = rs.converged;
        self.failed = rs.failed;
        Ok(())
    }
}

/// One pass's per-job meter: kernel time, units and edges served to the
/// job at this running position (atomics — split sub-tasks of one job
/// may run on several workers concurrently).
#[derive(Default)]
struct PassMeter {
    compute_nanos: AtomicU64,
    units: AtomicU64,
    edges: AtomicU64,
    bytes: AtomicU64,
}

/// Position of the `sub`-th set bit of a membership mask — which running
/// lane a (unit, sub) sub-task belongs to.  `sub` < `mask.count_ones()`
/// is the pipeline's contract.
#[inline]
fn nth_member(mut mask: u64, sub: u32) -> usize {
    debug_assert!(sub < mask.count_ones());
    for _ in 0..sub {
        mask &= mask - 1;
    }
    mask.trailing_zeros() as usize
}

/// What one pass contributed to the batch aggregate.
struct PassStats {
    loads: u64,
    servings: u64,
    fanned: u64,
    bytes_read: u64,
}

/// Fold scatter-unit update streams into `out` in worklist order,
/// marking activated vertices.  Sum kernels bucket the update values by
/// destination (counting sort into the pool's 64-byte-aligned arenas —
/// slots arrive in worklist order, so each destination's bucket keeps
/// the canonical ascending-source order) and rebuild every lane through
/// the same chunked sum the CSR fold uses, keeping the scatter engines
/// bit-identical to the in-place ones; monotone kernels meet each
/// update into the current value (order-insensitive).  Drained buffers
/// and the barrier arenas go back to the scratch pool so the next
/// iteration reuses their capacity.
fn fold_updates(
    ctx: &IterCtx<'_>,
    slots: Vec<Option<Vec<Update>>>,
    out: &mut LaneVec,
    bits: &ActiveBits,
    pool: &ScratchPool,
) -> u64 {
    with_lane!(ctx.kernel.lane, T => {
        fold_updates_t::<T>(ctx, slots, T::of_mut(out.as_mut()), bits, pool)
    })
}

fn fold_updates_t<T: Lane>(
    ctx: &IterCtx<'_>,
    slots: Vec<Option<Vec<Update>>>,
    out: &mut [T],
    bits: &ActiveBits,
    pool: &ScratchPool,
) -> u64 {
    let kernel = ctx.kernel;
    let src = T::of_slice(ctx.src);
    let mut folded = 0u64;
    let mut marker = bits.marker();
    match kernel.combine {
        Combine::Sum => {
            let (mut vals_a, mut idx_a) = pool.take_arenas();
            let total: usize = slots.iter().flatten().map(|s| s.len()).sum();
            // counting sort by destination: count (offset by one), …
            let idx = idx_a.u32s(out.len() + 1);
            for slot in slots.iter().flatten() {
                for u in slot {
                    idx[u.dst as usize + 1] += 1;
                }
            }
            // … exclusive prefix (idx[v] = start of vertex v's bucket), …
            for v in 0..out.len() {
                idx[v + 1] += idx[v];
            }
            // … then fill, advancing idx[v] to the bucket's end
            let vals = T::arena_slice(&mut vals_a, total);
            for mut slot in slots.into_iter().flatten() {
                folded += slot.len() as u64;
                for u in slot.drain(..) {
                    let v = u.dst as usize;
                    vals[idx[v] as usize] = u.val();
                    idx[v] += 1;
                }
                pool.recycle_updates(slot);
            }
            for v in 0..out.len() {
                let start = if v == 0 { 0 } else { idx[v - 1] as usize };
                let a = crate::exec::kernel::chunked_sum(&vals[start..idx[v] as usize]);
                let old = src[v];
                let new = kernel.apply_t(v as u32, ctx.num_vertices, old, a);
                if kernel.is_update_t(old, new) {
                    marker.mark(v as u32);
                }
                out[v] = new;
            }
            pool.put_arenas(vals_a, idx_a);
        }
        Combine::Min | Combine::Max => {
            for mut slot in slots.into_iter().flatten() {
                folded += slot.len() as u64;
                for u in slot.drain(..) {
                    let cur = out[u.dst as usize];
                    let new = kernel.combine_t(cur, u.val());
                    if new != cur {
                        out[u.dst as usize] = new;
                        marker.mark(u.dst);
                    }
                }
                pool.recycle_updates(slot);
            }
        }
    }
    folded
}

/// Size the next iteration's ready queue from the measured load-vs-
/// compute rate: with per-unit load time `t_io` and per-unit compute
/// time `t_c`, the workers drain roughly `t_io / t_c` units while one
/// load is in flight per worker, so that ratio (× workers, bounded)
/// keeps the queue from starving without hoarding decoded units.
fn adaptive_depth(
    outcome: &pipeline::WorklistOutcome,
    workers: usize,
    previous: usize,
) -> usize {
    let loads = outcome.prefetched.max(outcome.units).max(1) as f64;
    // per-task compute rate: sub-tasks are the unit of worker occupancy
    let tasks = outcome.processed.max(1) as f64;
    let t_io = outcome.io_busy.as_secs_f64() / loads;
    let t_c = outcome.compute_busy.as_secs_f64() / tasks;
    if t_c <= 0.0 || !t_io.is_finite() {
        return previous;
    }
    let ratio = (t_io / t_c) * workers.max(1) as f64;
    (ratio.ceil() as usize).clamp(1, MAX_AUTO_DEPTH)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{EdgeCost, PageRank, Sssp};
    use std::time::Duration;

    /// A miniature in-memory source: one unit per destination interval,
    /// in-place compute via the shared fold helper.
    struct ToySource {
        intervals: Vec<(u32, u32)>,
        edges: Vec<Vec<Edge>>,
    }

    impl ShardSource for ToySource {
        type Item = usize;

        fn schedule(&self, _iter: u32, _active: &[VertexId]) -> (Vec<u32>, u32) {
            ((0..self.intervals.len() as u32).collect(), 0)
        }

        fn load(&self, id: u32) -> Result<usize> {
            Ok(id as usize)
        }

        fn compute(
            &self,
            id: u32,
            item: usize,
            ctx: &IterCtx<'_>,
            dst: &SharedDst,
            marker: &mut RangeMarker<'_>,
            scratch: &mut Scratch<'_>,
        ) -> Result<UnitOutput> {
            assert_eq!(id as usize, item);
            let (lo, hi) = self.intervals[item];
            let mut out = unsafe { dst.claim(lo as usize, (hi - lo) as usize) };
            fold_edges_interval(ctx, &self.edges[item], lo, out.rb(), scratch);
            mark_interval(ctx, lo, out.shared(), marker);
            Ok(UnitOutput::InPlace)
        }

        fn unit_edges(&self, _id: u32, item: &usize) -> u64 {
            self.edges[*item].len() as u64
        }

        fn residency_bytes(&self) -> u64 {
            42
        }
    }

    /// Scatter flavour of the same graph (ESG-shaped).
    struct ToyScatter {
        parts: Vec<Vec<Edge>>,
    }

    impl ShardSource for ToyScatter {
        type Item = usize;

        fn schedule(&self, _iter: u32, _active: &[VertexId]) -> (Vec<u32>, u32) {
            ((0..self.parts.len() as u32).collect(), 0)
        }

        fn load(&self, id: u32) -> Result<usize> {
            Ok(id as usize)
        }

        fn compute(
            &self,
            _id: u32,
            item: usize,
            ctx: &IterCtx<'_>,
            _dst: &SharedDst,
            _marker: &mut RangeMarker<'_>,
            scratch: &mut Scratch<'_>,
        ) -> Result<UnitOutput> {
            let mut updates = scratch.take_updates();
            kernel::scatter_list(ctx, &self.parts[item], &mut updates);
            Ok(UnitOutput::Updates(updates))
        }

        fn residency_bytes(&self) -> u64 {
            7
        }
    }

    fn toy_graph() -> (u32, Vec<Edge>) {
        // 6 vertices, a little DAG with weights
        let edges = vec![
            Edge::weighted(0, 1, 2.0),
            Edge::weighted(0, 2, 5.0),
            Edge::weighted(1, 3, 1.0),
            Edge::weighted(2, 3, 1.0),
            Edge::weighted(3, 4, 4.0),
            Edge::weighted(1, 5, 9.0),
        ];
        (6, edges)
    }

    fn interval_source(n: u32, edges: &[Edge]) -> ToySource {
        let intervals = vec![(0u32, 3u32), (3, n)];
        let mut per = vec![Vec::new(), Vec::new()];
        for e in edges {
            per[if e.dst < 3 { 0 } else { 1 }].push(*e);
        }
        for p in &mut per {
            p.sort_unstable_by_key(|e| e.src);
        }
        ToySource { intervals, edges: per }
    }

    #[test]
    fn inplace_and_scatter_sources_agree_bitwise() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inv = vec![0.5f32, 0.5, 1.0, 1.0, 0.0, 0.0];
        let inplace = interval_source(n, &edges);
        let mut parts = vec![Vec::new(), Vec::new()];
        for e in &edges {
            parts[if e.src < 3 { 0 } else { 1 }].push(*e);
        }
        for p in &mut parts {
            p.sort_unstable_by_key(|e| e.src);
        }
        let scatter = ToyScatter { parts };
        for app in [&Sssp::new(0) as &dyn VertexProgram, &PageRank::new()] {
            let mut c1 = ExecCore::new(ExecConfig::default(), &disk, None);
            let (v1, r1) = c1.run(&inplace, app, n, &inv, 5).unwrap();
            let mut c2 = ExecCore::new(ExecConfig::default(), &disk, None);
            let (v2, r2) = c2.run(&scatter, app, n, &inv, 5).unwrap();
            assert_eq!(v1, v2, "{}: scatter diverged from in-place", app.name());
            assert_eq!(
                r1.iterations.len(),
                r2.iterations.len(),
                "{}: iteration counts differ",
                app.name()
            );
            for (a, b) in r1.iterations.iter().zip(&r2.iterations) {
                assert_eq!(a.active_vertices, b.active_vertices, "{}", app.name());
            }
        }
    }

    #[test]
    fn sequential_and_pipelined_agree_bitwise() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let seq = ExecConfig { workers: 1, prefetch_depth: 0, ..Default::default() };
        let pipe = ExecConfig { workers: 4, prefetch_depth: 3, ..Default::default() };
        let (v1, _) = ExecCore::new(seq, &disk, None)
            .run(&src, &Sssp::new(0), n, &[], 10)
            .unwrap();
        let (v2, _) = ExecCore::new(pipe, &disk, None)
            .run(&src, &Sssp::new(0), n, &[], 10)
            .unwrap();
        assert_eq!(v1, v2);
    }

    #[test]
    fn batched_jobs_match_solo_runs_bitwise() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inv = vec![0.5f32, 0.5, 1.0, 1.0, 0.0, 0.0];
        let src = interval_source(n, &edges);
        let (v_sssp, r_sssp) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &Sssp::new(0), n, &inv, 10)
            .unwrap();
        let (v_pr, r_pr) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &PageRank::new(), n, &inv, 5)
            .unwrap();
        let (outs, batch) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run_batch(
                &src,
                &[
                    BatchJob { app: &Sssp::new(0), max_iters: 10 },
                    BatchJob { app: &PageRank::new(), max_iters: 5 },
                ],
                n,
                &inv,
            )
            .unwrap();
        assert_eq!(outs[0].0, v_sssp, "batched SSSP diverged");
        assert_eq!(outs[1].0, v_pr, "batched PageRank diverged");
        assert_eq!(outs[0].1.iterations.len(), r_sssp.iterations.len());
        assert_eq!(outs[1].1.iterations.len(), r_pr.iterations.len());
        assert!(outs[0].1.converged, "SSSP must converge in-batch");
        assert_eq!(outs[1].1.converged, r_pr.converged);
        assert_eq!(batch.jobs, 2);
        assert_eq!(
            batch.passes as usize,
            r_sssp.iterations.len().max(r_pr.iterations.len())
        );
        // while both jobs run, every unit serves both; the amortization
        // sits strictly between 1x (solo) and 2x (full overlap) because
        // one job outlives the other
        let am = batch.shard_loads_amortized();
        assert!(am > 1.0 && am <= 2.0, "amortization {am}");
        // both jobs are members of the first pass
        assert_eq!(outs[1].1.iterations[0].jobs_in_pass, 2);
        assert_eq!(outs[1].1.iterations[0].shard_servings, 4, "2 units x 2 jobs");
    }

    #[test]
    fn batched_scatter_jobs_fold_independently() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inv = vec![0.5f32, 0.5, 1.0, 1.0, 0.0, 0.0];
        let mut parts = vec![Vec::new(), Vec::new()];
        for e in &edges {
            parts[if e.src < 3 { 0 } else { 1 }].push(*e);
        }
        for p in &mut parts {
            p.sort_unstable_by_key(|e| e.src);
        }
        let scatter = ToyScatter { parts };
        let (v_solo, _) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&scatter, &PageRank::new(), n, &inv, 4)
            .unwrap();
        let (outs, _) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run_batch(
                &scatter,
                &[
                    BatchJob { app: &PageRank::new(), max_iters: 4 },
                    BatchJob { app: &PageRank::new(), max_iters: 4 },
                ],
                n,
                &inv,
            )
            .unwrap();
        for (v, _) in &outs {
            assert_eq!(v, &v_solo, "batched scatter job diverged from solo");
        }
    }

    #[test]
    fn run_batch_rejects_bad_batches() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let err = ExecCore::new(ExecConfig::default(), &disk, None)
            .run_batch(&src, &[], n, &[])
            .unwrap_err();
        assert!(err.to_string().contains("empty job batch"), "{err}");
        let apps: Vec<Sssp> = (0..MAX_BATCH_JOBS + 1).map(|_| Sssp::new(0)).collect();
        let jobs: Vec<BatchJob<'_>> = apps
            .iter()
            .map(|a| BatchJob { app: a, max_iters: 1 })
            .collect();
        let err = ExecCore::new(ExecConfig::default(), &disk, None)
            .run_batch(&src, &jobs, n, &[])
            .unwrap_err();
        assert!(err.to_string().contains("per batch"), "{err}");
    }

    #[test]
    fn mid_batch_admission_is_bit_identical_and_isolated() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inv = vec![0.5f32, 0.5, 1.0, 1.0, 0.0, 0.0];
        let src = interval_source(n, &edges);
        let (v_pr_solo, r_pr_solo) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &PageRank::new(), n, &inv, 6)
            .unwrap();
        let (v_sssp_solo, r_sssp_solo) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &Sssp::new(0), n, &inv, 10)
            .unwrap();
        let sssp = Sssp::new(0);
        let (outs, batch) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run_batch_interactive(
                &src,
                &[BatchJob { app: &PageRank::new(), max_iters: 6 }],
                n,
                &inv,
                |pass, _running| {
                    if pass == 2 {
                        vec![BatchJob { app: &sssp, max_iters: 10 }]
                    } else {
                        Vec::new()
                    }
                },
            )
            .unwrap();
        assert_eq!(outs.len(), 2, "founding job + one admission");
        let (v_pr, r_pr) = &outs[0];
        let (v_sssp, r_sssp) = &outs[1];
        // the admitted job's trajectory equals a solo run from its
        // admission: same values, same iteration count, local clock
        assert_eq!(v_sssp, &v_sssp_solo, "admitted job diverged from solo");
        assert_eq!(r_sssp.iterations.len(), r_sssp_solo.iterations.len());
        assert_eq!(r_sssp.iterations[0].iteration, 0, "job-local iteration clock");
        assert_eq!(r_sssp.job.admitted_pass, 2);
        assert_eq!(r_sssp.converged, r_sssp_solo.converged);
        // the running job is undisturbed by the admission
        assert_eq!(v_pr, &v_pr_solo, "running job perturbed by admission");
        assert_eq!(r_pr.iterations.len(), r_pr_solo.iterations.len());
        for (a, b) in r_pr.iterations.iter().zip(&r_pr_solo.iterations) {
            assert_eq!(a.active_vertices, b.active_vertices);
            assert_eq!(a.shards_processed, b.shards_processed);
        }
        assert_eq!(batch.jobs, 2);
        assert_eq!(batch.admitted_mid_batch, 1);
        assert_eq!(
            batch.passes as usize,
            r_pr_solo.iterations.len().max(2 + r_sssp_solo.iterations.len())
        );
        assert_eq!(batch.per_job.len(), 2);
        assert_eq!(batch.per_job[1].admitted_pass, 2);
    }

    #[test]
    fn fan_out_split_matches_serial_member_compute() {
        // 2 units, 3 jobs, 8 workers: the union worklist is shorter than
        // the worker pool, so fan-out splits (unit, job) sub-tasks across
        // workers — results must be bit-identical to serial member compute
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inv = vec![0.5f32, 0.5, 1.0, 1.0, 0.0, 0.0];
        let src = interval_source(n, &edges);
        let pr = PageRank::new();
        let s0 = Sssp::new(0);
        let s1 = Sssp::new(1);
        let run_with = |fan_out: bool| {
            let cfg = ExecConfig { workers: 8, fan_out, ..Default::default() };
            ExecCore::new(cfg, &disk, None)
                .run_batch(
                    &src,
                    &[
                        BatchJob { app: &pr, max_iters: 8 },
                        BatchJob { app: &s0, max_iters: 8 },
                        BatchJob { app: &s1, max_iters: 8 },
                    ],
                    n,
                    &inv,
                )
                .unwrap()
        };
        let (o_fan, b_fan) = run_with(true);
        let (o_serial, b_serial) = run_with(false);
        for (j, ((v1, r1), (v2, r2))) in o_fan.iter().zip(&o_serial).enumerate() {
            assert_eq!(v1, v2, "job {j}: fan-out changed results");
            assert_eq!(r1.iterations.len(), r2.iterations.len(), "job {j}");
        }
        assert!(b_fan.shard_servings_fanned > 0, "2 units < 8 workers must fan out");
        assert_eq!(b_serial.shard_servings_fanned, 0, "fan_out=false stays serial");
        assert_eq!(b_fan.shard_servings, b_serial.shard_servings);
    }

    #[test]
    fn per_job_meter_accounts_units_and_edges() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let (_, run) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &Sssp::new(0), n, &[], 20)
            .unwrap();
        // ToySource schedules both units every pass, so the job is served
        // 2 units (and all 6 edges) per iteration
        let iters = run.iterations.len() as u64;
        assert!(iters > 0);
        assert_eq!(run.job.units_served, 2 * iters);
        assert_eq!(run.job.edges_processed, edges.len() as u64 * iters);
        assert_eq!(run.job.iterations as u64, iters);
        assert_eq!(run.job.admitted_pass, 0);
        assert_eq!(
            run.job.units_served,
            run.iterations.iter().map(|m| m.shards_processed as u64).sum::<u64>()
        );
        // nothing read from disk → no effective bytes to attribute
        assert_eq!(run.job.effective_bytes_read, 0.0);
        // per-pass compute attribution is recorded
        assert!(run.iterations.iter().all(|m| m.job_compute_seconds >= 0.0));
    }

    #[test]
    fn admission_defers_past_the_batch_cap() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let apps: Vec<Sssp> = (0..MAX_BATCH_JOBS).map(|_| Sssp::new(0)).collect();
        let jobs: Vec<BatchJob<'_>> = apps
            .iter()
            .map(|a| BatchJob { app: a, max_iters: 20 })
            .collect();
        let extra = Sssp::new(0);
        let (v_solo, r_solo) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &extra, n, &[], 20)
            .unwrap();
        let (outs, batch) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run_batch_interactive(&src, &jobs, n, &[], |pass, _running| {
                if pass == 0 {
                    vec![BatchJob { app: &extra, max_iters: 20 }]
                } else {
                    Vec::new()
                }
            })
            .unwrap();
        assert_eq!(outs.len(), MAX_BATCH_JOBS + 1);
        assert_eq!(batch.jobs as usize, MAX_BATCH_JOBS + 1);
        assert_eq!(batch.admissions_deferred, 1, "the 65th job must wait, once");
        assert_eq!(batch.admitted_mid_batch, 1);
        let (v_last, r_last) = &outs[MAX_BATCH_JOBS];
        assert_eq!(v_last, &v_solo, "deferred job diverged from solo");
        assert_eq!(r_last.iterations.len(), r_solo.iterations.len());
        assert!(
            r_last.job.admitted_pass > 0,
            "the deferred job can only start after capacity frees"
        );
    }

    #[test]
    fn nth_member_picks_set_bits_in_order() {
        let mask = 0b1011_0100u64;
        assert_eq!(nth_member(mask, 0), 2);
        assert_eq!(nth_member(mask, 1), 4);
        assert_eq!(nth_member(mask, 2), 5);
        assert_eq!(nth_member(mask, 3), 7);
        assert_eq!(nth_member(1u64 << 63, 0), 63);
    }

    #[test]
    fn residency_recorded_and_convergence_detected() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let (_, run) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &Sssp::new(0), n, &[], 100)
            .unwrap();
        assert!(run.converged);
        assert_eq!(run.memory_bytes, 42);
        assert!(run.iterations.len() < 100);
    }

    #[test]
    fn rejects_sum_kernel_without_degrees() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let err = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &PageRank::new(), n, &[], 3)
            .unwrap_err();
        assert!(err.to_string().contains("out-degree"), "{err}");
    }

    #[test]
    fn fold_edges_interval_matches_manual_relax() {
        let (_, edges) = toy_graph();
        let src = vec![0.0f32, 2.0, 5.0, 3.0, f32::INFINITY, f32::INFINITY];
        let kernel = ShardKernel::relax_min(EdgeCost::Weights);
        let ctx = IterCtx {
            kernel,
            num_vertices: 6,
            src: (&src).into(),
            inv_out_deg: &[],
            contrib: &[],
            iteration: 0,
        };
        let mut out = src[3..6].to_vec();
        let mut es: Vec<Edge> = edges.iter().filter(|e| e.dst >= 3).copied().collect();
        es.sort_unstable_by_key(|e| e.src);
        let pool = ScratchPool::new();
        let mut scratch = pool.scratch();
        fold_edges_interval(&ctx, &es, 3, (&mut out).into(), &mut scratch);
        assert_eq!(out, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn integer_lane_jobs_run_through_both_source_shapes() {
        use crate::apps::{BfsLevels, KCore, Wcc};
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inplace = interval_source(n, &edges);
        let mut parts = vec![Vec::new(), Vec::new()];
        for e in &edges {
            parts[if e.src < 3 { 0 } else { 1 }].push(*e);
        }
        for p in &mut parts {
            p.sort_unstable_by_key(|e| e.src);
        }
        let scatter = ToyScatter { parts };
        // everything is reachable from 0 → one component, known levels
        let want_wcc = vec![0u32; n as usize];
        let want_lvl = vec![0u32, 1, 1, 2, 3, 2];
        // in-degrees: 1:1, 2:1, 3:2, 4:1, 5:1 → only vertex 3 survives
        // k=2 at first, then dies once its in-neighbors are gone
        let want_core = vec![0u32; n as usize];
        for (app, want) in [
            (&Wcc as &dyn VertexProgram, &want_wcc),
            (&BfsLevels::new(0), &want_lvl),
            (&KCore::new(2), &want_core),
        ] {
            let (v1, r1) = ExecCore::new(ExecConfig::default(), &disk, None)
                .run(&inplace, app, n, &[], 20)
                .unwrap();
            assert!(r1.converged, "{} must converge", app.name());
            assert_eq!(v1.u32s(), &want[..], "{} in-place values", app.name());
            let (v2, _) = ExecCore::new(ExecConfig::default(), &disk, None)
                .run(&scatter, app, n, &[], 20)
                .unwrap();
            assert_eq!(v1, v2, "{}: scatter diverged from in-place", app.name());
        }
    }

    #[test]
    fn mixed_lane_batch_matches_solo_runs_bitwise() {
        use crate::apps::Wcc;
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inv = vec![0.5f32, 0.5, 1.0, 1.0, 0.0, 0.0];
        let src = interval_source(n, &edges);
        let (v_pr, _) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &PageRank::new(), n, &inv, 5)
            .unwrap();
        let (v_wcc, _) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &Wcc, n, &inv, 20)
            .unwrap();
        let (outs, batch) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run_batch(
                &src,
                &[
                    BatchJob { app: &PageRank::new(), max_iters: 5 },
                    BatchJob { app: &Wcc, max_iters: 20 },
                ],
                n,
                &inv,
            )
            .unwrap();
        assert_eq!(outs[0].0, v_pr, "f32 member diverged in a mixed batch");
        assert_eq!(outs[1].0, v_wcc, "u32 member diverged in a mixed batch");
        assert_eq!(outs[0].0.lane_type(), LaneType::F32);
        assert_eq!(outs[1].0.lane_type(), LaneType::U32);
        assert_eq!(batch.jobs, 2);
        // both jobs scan-share the same shard pass while running
        assert_eq!(outs[1].1.iterations[0].jobs_in_pass, 2);
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let pool = ScratchPool::new();
        {
            let mut s = pool.scratch();
            let (vals, idx) = s.arenas();
            assert_eq!(vals.f32s(100).as_ptr() as usize % 64, 0);
            assert_eq!(idx.u32s(100).as_ptr() as usize % 64, 0);
            let u = s.take_updates();
            assert!(u.is_empty());
            let mut u = u;
            u.reserve(64);
            pool.recycle_updates(u);
        }
        // the dropped scratch returned its arenas (still 64B-capable,
        // capacity retained); the recycled update buffer kept its
        // capacity
        let mut s2 = pool.scratch();
        let (vals, idx) = s2.arenas();
        assert!(vals.capacity_bytes() >= 400, "value arena must be recycled");
        assert!(idx.capacity_bytes() >= 400, "cursor arena must be recycled");
        assert_eq!(vals.f32s(100).as_ptr() as usize % 64, 0);
        assert_eq!(idx.u32s(100).as_ptr() as usize % 64, 0);
        assert!(s2.take_updates().capacity() >= 64);
    }

    #[test]
    fn adaptive_depth_tracks_io_to_compute_ratio() {
        let mk = |io_ms: u64, c_ms: u64| pipeline::WorklistOutcome {
            processed: 10,
            prefetched: 10,
            io_busy: Duration::from_millis(io_ms),
            compute_busy: Duration::from_millis(c_ms),
            ..Default::default()
        };
        // I/O-bound: deep queue (capped)
        assert_eq!(adaptive_depth(&mk(1000, 10), 4, 4), MAX_AUTO_DEPTH);
        // compute-bound: shallow queue
        assert_eq!(adaptive_depth(&mk(1, 100), 4, 4), 1);
        // balanced-ish: a few units per worker
        let d = adaptive_depth(&mk(10, 10), 4, 4);
        assert!((1..=MAX_AUTO_DEPTH).contains(&d));
        // degenerate measurements keep the previous depth
        assert_eq!(adaptive_depth(&mk(0, 0), 4, 7), 7);
    }
}
