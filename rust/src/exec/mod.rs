//! The unified execution core: one schedule→prefetch→compute pipeline
//! for every engine.
//!
//! GraphMP's headline comparison (Tables 5–7, Figs 9–10) only holds up
//! when the *execution loop* is identical across systems and just the
//! I/O schedule differs — NXgraph (arXiv:1510.06916) shows that loop
//! differences otherwise dominate the measured gaps.  This module is
//! that shared loop:
//!
//! - [`ShardSource`] is the engine-specific half: what to load per
//!   iteration (schedule + load, with the engine's model I/O charged on
//!   the load path), how a loaded unit computes, and what residency to
//!   charge.  The VSW engine, GraphChi-PSW, X-Stream-ESG, GridGraph-DSW
//!   and the GraphMat-like in-memory engine all implement it.
//! - [`ExecCore`] is the engine-agnostic half: the iteration loop
//!   (convergence, active-set rebuild through [`schedule::ActiveBits`]),
//!   the contribution pre-fold for sum kernels, the bounded prefetch
//!   pipeline ([`pipeline::run_worklist`]), deterministic gathering of
//!   scatter-style units, iteration accounting (wall + simulated disk +
//!   overlap), cache-delta attachment, and the adaptive prefetch depth.
//!
//! Determinism: in-place units write disjoint [`SharedDst`] intervals;
//! scatter units ([`UnitOutput::Updates`]) are folded at the barrier in
//! worklist order regardless of completion order — so results are
//! bit-identical in worker count, prefetch depth, and engine (see
//! `rust/tests/cross_engine.rs`).

pub mod dst;
pub mod kernel;
pub mod pipeline;
pub mod schedule;

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::apps::{Combine, ShardKernel, VertexProgram};
use crate::cache::EdgeCache;
use crate::graph::{Edge, VertexId};
use crate::metrics::{IterationMetrics, RunMetrics};
use crate::storage::disk::Disk;
pub use dst::SharedDst;
pub use schedule::{ActiveBits, RangeMarker};

/// Execution knobs shared by every engine (the paper's settings).
#[derive(Clone, Copy, Debug)]
pub struct ExecConfig {
    /// Compute worker threads (paper: one shard per CPU core at a time).
    pub workers: usize,
    /// Ready-queue depth of the prefetcher: how many loaded units the
    /// I/O threads may stage ahead of the compute workers.  0 turns the
    /// pipeline off (units load inline on the worker — the sequential
    /// reference path and the determinism baseline).
    pub prefetch_depth: usize,
    /// Adapt the queue depth each iteration from the measured
    /// load-vs-compute rate of the previous one (`prefetch_depth` then
    /// only seeds iteration 0).
    pub prefetch_auto: bool,
    /// Dedicated I/O threads feeding the ready queue; 1–2 is enough to
    /// keep the (simulated) disk continuously busy.
    pub prefetch_threads: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            // capped at the paper's core count: more workers than that
            // only adds context switches with no modelled benefit
            workers: std::thread::available_parallelism()
                .map_or(1, |n| n.get())
                .min(12),
            prefetch_depth: 4,
            prefetch_auto: false,
            prefetch_threads: 2,
        }
    }
}

/// Hard cap on the adaptive queue depth (bounds in-flight unit memory).
pub const MAX_AUTO_DEPTH: usize = 16;

/// Per-iteration read-only context handed to [`ShardSource::compute`].
pub struct IterCtx<'a> {
    pub kernel: ShardKernel,
    pub num_vertices: u32,
    /// The previous iteration's vertex values (read-only this iteration).
    pub src: &'a [f32],
    pub inv_out_deg: &'a [f32],
    /// Pre-folded `src · inv_out_deg` for sum kernels (|V| multiplies
    /// once, instead of |E| per-edge products); empty otherwise.
    pub contrib: &'a [f32],
    pub iteration: u32,
}

impl IterCtx<'_> {
    /// One edge's gathered contribution.  Degree-mass kernels read the
    /// pre-folded array; everything else folds from `src` + weight.
    #[inline]
    pub fn edge_value(&self, e: &Edge) -> f32 {
        if self.kernel.uses_contrib() {
            self.contrib[e.src as usize]
        } else {
            self.kernel.edge_value(self.src[e.src as usize], 0.0, e.weight)
        }
    }
}

/// A deferred write produced by scatter-style units (X-Stream's update
/// stream): folded deterministically at the iteration barrier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Update {
    pub dst: VertexId,
    pub val: f32,
}

/// What one unit's compute produced.
pub enum UnitOutput {
    /// The unit wrote its exclusive destination rows in place (and marked
    /// activations itself).
    InPlace,
    /// Scatter-style updates for the barrier to fold in worklist order.
    Updates(Vec<Update>),
}

/// Run-scoped free lists backing the per-worker [`Scratch`] arenas.
///
/// The steady-state iteration used to allocate per *unit*: a fresh
/// `vec![0.0; rows]` sum accumulator in the list fold and a fresh
/// `Vec<Update>` per scatter unit.  The pool keeps those buffers alive
/// across units *and iterations*: workers lease a [`Scratch`] at spawn
/// (buffers return on drop), and the barrier recycles drained scatter
/// buffers — so after warm-up the compute path performs no per-unit heap
/// allocation.
#[derive(Default)]
pub struct ScratchPool {
    accs: Mutex<Vec<Vec<f32>>>,
    update_bufs: Mutex<Vec<Vec<Update>>>,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lease a worker scratch; its buffers return to the pool on drop.
    pub fn scratch(&self) -> Scratch<'_> {
        Scratch {
            pool: self,
            acc: self.accs.lock().unwrap().pop().unwrap_or_default(),
        }
    }

    /// Return a drained scatter buffer for reuse (capacity preserved).
    pub fn recycle_updates(&self, mut buf: Vec<Update>) {
        buf.clear();
        self.update_bufs.lock().unwrap().push(buf);
    }
}

/// Per-worker reusable buffers, threaded through `run_worklist`'s worker
/// state into every [`ShardSource::compute`] call.
pub struct Scratch<'p> {
    pool: &'p ScratchPool,
    acc: Vec<f32>,
}

impl Scratch<'_> {
    /// The sum-kernel accumulator arena (sized by the fold that uses it).
    fn acc_buf(&mut self) -> &mut Vec<f32> {
        &mut self.acc
    }

    /// Take an empty scatter buffer (capacity reused across iterations);
    /// hand it back through [`UnitOutput::Updates`] — the barrier
    /// recycles it after folding.
    pub fn take_updates(&self) -> Vec<Update> {
        self.pool.update_bufs.lock().unwrap().pop().unwrap_or_default()
    }
}

impl Drop for Scratch<'_> {
    fn drop(&mut self) {
        self.pool.accs.lock().unwrap().push(std::mem::take(&mut self.acc));
    }
}

/// The engine-specific half of the execution core: an I/O schedule over
/// loadable units plus the per-unit compute.
pub trait ShardSource: Sync {
    /// A loaded unit travelling from the I/O stage to a compute worker.
    type Item: Send;

    /// Schedule stage: this iteration's unit worklist plus the number of
    /// units skipped (selective scheduling; engines without it return
    /// the full worklist and 0).
    fn schedule(&self, iteration: u32, active: &[VertexId]) -> (Vec<u32>, u32);

    /// Load stage — runs on the dedicated I/O threads when pipelined,
    /// inline on workers otherwise.  Engines charge their per-unit read
    /// model (or perform real reads) here so (simulated) disk time
    /// overlaps compute.
    fn load(&self, id: u32) -> Result<Self::Item>;

    /// Compute stage — runs on the compute workers.  In-place units
    /// claim their exclusive rows from `dst` and mark activations into
    /// `marker`; scatter units return their update stream (take the
    /// buffer from `scratch` so its capacity is reused).  Per-unit
    /// write-back charges belong here (they are part of processing the
    /// unit, not of the barrier).
    fn compute(
        &self,
        id: u32,
        item: Self::Item,
        ctx: &IterCtx<'_>,
        dst: &SharedDst,
        marker: &mut RangeMarker<'_>,
        scratch: &mut Scratch<'_>,
    ) -> Result<UnitOutput>;

    /// Barrier stage: residual per-iteration charges (e.g. the gather
    /// phase's update-stream read and vertex write-back).
    fn end_iteration(&self, _ctx: &IterCtx<'_>, _updates_folded: u64) {}

    /// The engine's resident-memory model in bytes (Fig 11 / Table 3's
    /// memory column) — recorded on the run's metrics.
    fn residency_bytes(&self) -> u64;
}

/// Fold destination-grouped `edges` into `out`, which covers the vertex
/// rows `[lo, lo + out.len())` and enters holding their current values.
/// Dispatches into the monomorphized [`kernel::fold_list`] (branch-free
/// per edge, sum accumulator from the worker's scratch arena).
/// Bit-identical to the CSR row loop (`engine::native_update`) as long as
/// each destination's edges arrive in the same order — the repo-wide
/// canonical layout is ascending source id.
pub fn fold_edges_interval(
    ctx: &IterCtx<'_>,
    edges: &[Edge],
    lo: u32,
    out: &mut [f32],
    scratch: &mut Scratch<'_>,
) {
    kernel::fold_list(ctx, edges, lo, out, scratch.acc_buf());
}

/// Mark every row of `[lo, lo + out.len())` whose new value activates it.
pub fn mark_interval(ctx: &IterCtx<'_>, lo: u32, out: &[f32], marker: &mut RangeMarker<'_>) {
    kernel::mark_rows(ctx, lo, out, marker);
}

/// The engine-agnostic execution driver.  Holds the run-scoped state the
/// iterations share: the disk (for I/O deltas), an optional attached
/// cache (for cache-counter deltas), and the adaptive prefetch depth.
pub struct ExecCore<'a> {
    cfg: ExecConfig,
    disk: &'a Disk,
    cache: Option<&'a EdgeCache>,
    auto_depth: usize,
    /// Worker scratch arenas, reused across units and iterations.
    scratch: ScratchPool,
}

impl<'a> ExecCore<'a> {
    pub fn new(cfg: ExecConfig, disk: &'a Disk, cache: Option<&'a EdgeCache>) -> Self {
        let seed = cfg.prefetch_depth.clamp(1, MAX_AUTO_DEPTH);
        ExecCore { cfg, disk, cache, auto_depth: seed, scratch: ScratchPool::new() }
    }

    /// Run `app` through `source` for at most `max_iters` iterations
    /// (stopping early once no vertex is active, Algorithm 2 line 2) and
    /// return the final vertex values with the run's metrics.
    pub fn run<S: ShardSource>(
        &mut self,
        source: &S,
        app: &dyn VertexProgram,
        num_vertices: u32,
        inv_out_deg: &[f32],
        max_iters: u32,
    ) -> Result<(Vec<f32>, RunMetrics)> {
        let n = num_vertices;
        anyhow::ensure!(
            n < (1 << 24),
            "f32 vertex values require ids < 2^24 (got {n})"
        );
        let kernel = app.kernel();
        if kernel.uses_contrib() {
            anyhow::ensure!(
                inv_out_deg.len() == n as usize,
                "{} needs the out-degree array",
                app.name()
            );
        }
        let (mut src, mut active) = app.init(n);
        anyhow::ensure!(src.len() == n as usize, "init length mismatch");

        let mut run = RunMetrics::default();
        let run_start = Instant::now();
        let sim_start = self.disk.snapshot().sim_nanos;

        for iter in 0..max_iters {
            if active.is_empty() {
                run.converged = true;
                break;
            }
            let m = self.run_iteration(source, kernel, iter, &mut src, &mut active, inv_out_deg)?;
            run.iterations.push(m);
        }
        if active.is_empty() {
            run.converged = true;
        }
        run.total_wall = run_start.elapsed();
        run.total_sim_disk_seconds =
            (self.disk.snapshot().sim_nanos - sim_start) as f64 / 1e9;
        run.total_overlapped_sim_seconds =
            run.iterations.iter().map(|m| m.overlapped_sim_seconds).sum();
        run.memory_bytes = source.residency_bytes();
        Ok((src, run))
    }

    /// One iteration of Algorithm 2 as a schedule → prefetch → compute
    /// pipeline with a barrier swap at the end.
    fn run_iteration<S: ShardSource>(
        &mut self,
        source: &S,
        kernel: ShardKernel,
        iter: u32,
        src: &mut Vec<f32>,
        active: &mut Vec<VertexId>,
        inv_out_deg: &[f32],
    ) -> Result<IterationMetrics> {
        let n = src.len();
        let io_before = self.disk.snapshot();
        let cache_before = self.cache.map(|c| c.snapshot()).unwrap_or_default();
        let t0 = Instant::now();

        // stage 1: the scheduler decides the whole unit worklist up front
        let (worklist, skipped) = source.schedule(iter, active);

        // §Perf: for sum kernels, fold src·inv_out_deg once per iteration
        // (|V| multiplies) instead of once per edge (|E| ≫ |V| gathers).
        let contrib: Vec<f32> = if kernel.uses_contrib() {
            src.iter().zip(inv_out_deg).map(|(&v, &d)| v * d).collect()
        } else {
            Vec::new()
        };
        let ctx = IterCtx {
            kernel,
            num_vertices: n as u32,
            src: src.as_slice(),
            inv_out_deg,
            contrib: &contrib,
            iteration: iter,
        };

        let depth = if self.cfg.prefetch_depth == 0 {
            0 // pipeline off: the sequential reference path wins outright
        } else if self.cfg.prefetch_auto {
            self.auto_depth
        } else {
            self.cfg.prefetch_depth
        };

        let dst = SharedDst::new(src.clone());
        let bits = ActiveBits::new(n);
        // scatter-unit outputs, slot-indexed by worklist position so the
        // barrier fold is deterministic in completion order
        let slots: Mutex<Vec<Option<Vec<Update>>>> =
            Mutex::new((0..worklist.len()).map(|_| None).collect());

        // stages 2+3: I/O threads stage units into the bounded ready
        // queue; compute workers drain it.  Each worker leases a scratch
        // arena alongside its activation marker.
        let pool = &self.scratch;
        let outcome = pipeline::run_worklist(
            &worklist,
            self.cfg.workers,
            depth,
            self.cfg.prefetch_threads,
            |id| source.load(id),
            || (bits.marker(), pool.scratch()),
            |state, index, id, item| {
                let (marker, scratch) = state;
                match source.compute(id, item, &ctx, &dst, marker, scratch)? {
                    UnitOutput::InPlace => {}
                    UnitOutput::Updates(u) => {
                        slots.lock().unwrap()[index] = Some(u);
                    }
                }
                Ok(())
            },
        )?;

        dst.release_all();
        let mut next = dst.into_inner();
        // Snapshot at the end of the pipeline phase: only simulated disk
        // time charged while the load/compute stages were running can
        // overlap compute.  Barrier-stage charges (a scatter engine's
        // gather read + write-back in `end_iteration`) happen after all
        // compute finished and stay on the critical path.
        let io_pipeline = self.disk.snapshot();
        let wall_pipeline = t0.elapsed();
        // barrier: fold scatter streams (worklist order) and charge the
        // engine's residual iteration I/O
        let slots = slots.into_inner().unwrap();
        let updates_folded = if slots.iter().any(Option::is_some) {
            fold_updates(&ctx, slots, &mut next, &bits, pool)
        } else {
            0
        };
        source.end_iteration(&ctx, updates_folded);

        *src = next;
        *active = bits.to_sorted_vec();

        let wall = t0.elapsed();
        let io_after = self.disk.snapshot();
        let sim_disk_seconds = (io_after.sim_nanos - io_before.sim_nanos) as f64 / 1e9;
        // Pipeline overlap model: with dedicated I/O threads the (simulated)
        // device streams concurrently with compute, so the pipeline phase
        // costs max(wall, sim) instead of wall + sim — i.e. min(wall, sim)
        // of the device time charged *during that phase* is hidden.
        // Without prefetching every charge sits on the critical path,
        // exactly the pre-pipeline accounting.
        let sim_pipeline_seconds =
            (io_pipeline.sim_nanos - io_before.sim_nanos) as f64 / 1e9;
        let pipelined = depth > 0 && self.cfg.prefetch_threads > 0;
        let overlapped_sim_seconds = if pipelined {
            sim_pipeline_seconds.min(wall_pipeline.as_secs_f64())
        } else {
            0.0
        };

        if self.cfg.prefetch_auto {
            self.auto_depth = adaptive_depth(&outcome, self.cfg.workers, self.auto_depth);
        }

        Ok(IterationMetrics {
            iteration: iter,
            wall,
            sim_disk_seconds,
            overlapped_sim_seconds,
            active_vertices: active.len() as u64,
            active_ratio: active.len() as f64 / n.max(1) as f64,
            shards_processed: outcome.processed,
            shards_skipped: skipped,
            shards_prefetched: outcome.prefetched,
            ready_hits: outcome.ready_hits,
            ready_misses: outcome.ready_misses,
            prefetch_depth_used: depth as u32,
            io: io_after.since(&io_before),
            cache: match self.cache {
                Some(c) => {
                    let after = c.snapshot();
                    crate::cache::CacheSnapshot {
                        hits: after.hits - cache_before.hits,
                        misses: after.misses - cache_before.misses,
                        admitted: after.admitted - cache_before.admitted,
                        rejected: after.rejected - cache_before.rejected,
                        used_bytes: after.used_bytes,
                        decodes: after.decodes - cache_before.decodes,
                        decode_skips: after.decode_skips - cache_before.decode_skips,
                        crc_verifies: after.crc_verifies - cache_before.crc_verifies,
                        crc_verifies_skipped: after.crc_verifies_skipped
                            - cache_before.crc_verifies_skipped,
                        memo_bytes: after.memo_bytes,
                    }
                }
                None => Default::default(),
            },
        })
    }
}

/// Fold scatter-unit update streams into `out` in worklist order,
/// marking activated vertices.  Sum kernels rebuild every lane from the
/// folded accumulator (X-Stream's gather recomputes all vertices);
/// monotone kernels meet each update into the current value.  Drained
/// buffers (and the barrier accumulator) go back to the scratch pool so
/// the next iteration's scatter units reuse their capacity.
fn fold_updates(
    ctx: &IterCtx<'_>,
    slots: Vec<Option<Vec<Update>>>,
    out: &mut [f32],
    bits: &ActiveBits,
    pool: &ScratchPool,
) -> u64 {
    let kernel = ctx.kernel;
    let mut folded = 0u64;
    let mut marker = bits.marker();
    match kernel.combine {
        Combine::Sum => {
            let mut acc = pool.accs.lock().unwrap().pop().unwrap_or_default();
            acc.clear();
            acc.resize(out.len(), 0.0);
            for mut slot in slots.into_iter().flatten() {
                folded += slot.len() as u64;
                for u in slot.drain(..) {
                    acc[u.dst as usize] += u.val;
                }
                pool.recycle_updates(slot);
            }
            for (v, a) in acc.iter().enumerate() {
                let old = ctx.src[v];
                let new = kernel.apply(v as u32, ctx.num_vertices, old, *a);
                if kernel.is_update(old, new) {
                    marker.mark(v as u32);
                }
                out[v] = new;
            }
            pool.accs.lock().unwrap().push(acc);
        }
        Combine::Min | Combine::Max => {
            for mut slot in slots.into_iter().flatten() {
                folded += slot.len() as u64;
                for u in slot.drain(..) {
                    let cur = out[u.dst as usize];
                    let new = kernel.combine(cur, u.val);
                    if new != cur {
                        out[u.dst as usize] = new;
                        marker.mark(u.dst);
                    }
                }
                pool.recycle_updates(slot);
            }
        }
    }
    folded
}

/// Size the next iteration's ready queue from the measured load-vs-
/// compute rate: with per-unit load time `t_io` and per-unit compute
/// time `t_c`, the workers drain roughly `t_io / t_c` units while one
/// load is in flight per worker, so that ratio (× workers, bounded)
/// keeps the queue from starving without hoarding decoded units.
fn adaptive_depth(
    outcome: &pipeline::WorklistOutcome,
    workers: usize,
    previous: usize,
) -> usize {
    let loads = outcome.prefetched.max(outcome.processed).max(1) as f64;
    let units = outcome.processed.max(1) as f64;
    let t_io = outcome.io_busy.as_secs_f64() / loads;
    let t_c = outcome.compute_busy.as_secs_f64() / units;
    if t_c <= 0.0 || !t_io.is_finite() {
        return previous;
    }
    let ratio = (t_io / t_c) * workers.max(1) as f64;
    (ratio.ceil() as usize).clamp(1, MAX_AUTO_DEPTH)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{EdgeCost, PageRank, Sssp};
    use std::time::Duration;

    /// A miniature in-memory source: one unit per destination interval,
    /// in-place compute via the shared fold helper.
    struct ToySource {
        intervals: Vec<(u32, u32)>,
        edges: Vec<Vec<Edge>>,
    }

    impl ShardSource for ToySource {
        type Item = usize;

        fn schedule(&self, _iter: u32, _active: &[VertexId]) -> (Vec<u32>, u32) {
            ((0..self.intervals.len() as u32).collect(), 0)
        }

        fn load(&self, id: u32) -> Result<usize> {
            Ok(id as usize)
        }

        fn compute(
            &self,
            id: u32,
            item: usize,
            ctx: &IterCtx<'_>,
            dst: &SharedDst,
            marker: &mut RangeMarker<'_>,
            scratch: &mut Scratch<'_>,
        ) -> Result<UnitOutput> {
            assert_eq!(id as usize, item);
            let (lo, hi) = self.intervals[item];
            let out = unsafe { dst.claim(lo as usize, (hi - lo) as usize) };
            fold_edges_interval(ctx, &self.edges[item], lo, out, scratch);
            mark_interval(ctx, lo, out, marker);
            Ok(UnitOutput::InPlace)
        }

        fn residency_bytes(&self) -> u64 {
            42
        }
    }

    /// Scatter flavour of the same graph (ESG-shaped).
    struct ToyScatter {
        parts: Vec<Vec<Edge>>,
    }

    impl ShardSource for ToyScatter {
        type Item = usize;

        fn schedule(&self, _iter: u32, _active: &[VertexId]) -> (Vec<u32>, u32) {
            ((0..self.parts.len() as u32).collect(), 0)
        }

        fn load(&self, id: u32) -> Result<usize> {
            Ok(id as usize)
        }

        fn compute(
            &self,
            _id: u32,
            item: usize,
            ctx: &IterCtx<'_>,
            _dst: &SharedDst,
            _marker: &mut RangeMarker<'_>,
            scratch: &mut Scratch<'_>,
        ) -> Result<UnitOutput> {
            let mut updates = scratch.take_updates();
            kernel::scatter_list(ctx, &self.parts[item], &mut updates);
            Ok(UnitOutput::Updates(updates))
        }

        fn residency_bytes(&self) -> u64 {
            7
        }
    }

    fn toy_graph() -> (u32, Vec<Edge>) {
        // 6 vertices, a little DAG with weights
        let edges = vec![
            Edge::weighted(0, 1, 2.0),
            Edge::weighted(0, 2, 5.0),
            Edge::weighted(1, 3, 1.0),
            Edge::weighted(2, 3, 1.0),
            Edge::weighted(3, 4, 4.0),
            Edge::weighted(1, 5, 9.0),
        ];
        (6, edges)
    }

    fn interval_source(n: u32, edges: &[Edge]) -> ToySource {
        let intervals = vec![(0u32, 3u32), (3, n)];
        let mut per = vec![Vec::new(), Vec::new()];
        for e in edges {
            per[if e.dst < 3 { 0 } else { 1 }].push(*e);
        }
        for p in &mut per {
            p.sort_unstable_by_key(|e| e.src);
        }
        ToySource { intervals, edges: per }
    }

    #[test]
    fn inplace_and_scatter_sources_agree_bitwise() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let inv = vec![0.5f32, 0.5, 1.0, 1.0, 0.0, 0.0];
        let inplace = interval_source(n, &edges);
        let mut parts = vec![Vec::new(), Vec::new()];
        for e in &edges {
            parts[if e.src < 3 { 0 } else { 1 }].push(*e);
        }
        for p in &mut parts {
            p.sort_unstable_by_key(|e| e.src);
        }
        let scatter = ToyScatter { parts };
        for app in [&Sssp::new(0) as &dyn VertexProgram, &PageRank::new()] {
            let mut c1 = ExecCore::new(ExecConfig::default(), &disk, None);
            let (v1, r1) = c1.run(&inplace, app, n, &inv, 5).unwrap();
            let mut c2 = ExecCore::new(ExecConfig::default(), &disk, None);
            let (v2, r2) = c2.run(&scatter, app, n, &inv, 5).unwrap();
            assert_eq!(v1, v2, "{}: scatter diverged from in-place", app.name());
            assert_eq!(
                r1.iterations.len(),
                r2.iterations.len(),
                "{}: iteration counts differ",
                app.name()
            );
            for (a, b) in r1.iterations.iter().zip(&r2.iterations) {
                assert_eq!(a.active_vertices, b.active_vertices, "{}", app.name());
            }
        }
    }

    #[test]
    fn sequential_and_pipelined_agree_bitwise() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let seq = ExecConfig { workers: 1, prefetch_depth: 0, ..Default::default() };
        let pipe = ExecConfig { workers: 4, prefetch_depth: 3, ..Default::default() };
        let (v1, _) = ExecCore::new(seq, &disk, None)
            .run(&src, &Sssp::new(0), n, &[], 10)
            .unwrap();
        let (v2, _) = ExecCore::new(pipe, &disk, None)
            .run(&src, &Sssp::new(0), n, &[], 10)
            .unwrap();
        assert_eq!(v1, v2);
    }

    #[test]
    fn residency_recorded_and_convergence_detected() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let (_, run) = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &Sssp::new(0), n, &[], 100)
            .unwrap();
        assert!(run.converged);
        assert_eq!(run.memory_bytes, 42);
        assert!(run.iterations.len() < 100);
    }

    #[test]
    fn rejects_sum_kernel_without_degrees() {
        let (n, edges) = toy_graph();
        let disk = Disk::unthrottled();
        let src = interval_source(n, &edges);
        let err = ExecCore::new(ExecConfig::default(), &disk, None)
            .run(&src, &PageRank::new(), n, &[], 3)
            .unwrap_err();
        assert!(err.to_string().contains("out-degree"), "{err}");
    }

    #[test]
    fn fold_edges_interval_matches_manual_relax() {
        let (_, edges) = toy_graph();
        let src = vec![0.0f32, 2.0, 5.0, 3.0, f32::INFINITY, f32::INFINITY];
        let kernel = ShardKernel::relax_min(EdgeCost::Weights);
        let ctx = IterCtx {
            kernel,
            num_vertices: 6,
            src: &src,
            inv_out_deg: &[],
            contrib: &[],
            iteration: 0,
        };
        let mut out = src[3..6].to_vec();
        let mut es: Vec<Edge> = edges.iter().filter(|e| e.dst >= 3).copied().collect();
        es.sort_unstable_by_key(|e| e.src);
        let pool = ScratchPool::new();
        let mut scratch = pool.scratch();
        fold_edges_interval(&ctx, &es, 3, &mut out, &mut scratch);
        assert_eq!(out, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let pool = ScratchPool::new();
        {
            let mut s = pool.scratch();
            s.acc_buf().resize(100, 0.0);
            let u = s.take_updates();
            assert!(u.is_empty());
            let mut u = u;
            u.reserve(64);
            pool.recycle_updates(u);
        }
        // the dropped scratch returned its accumulator; the recycled
        // update buffer kept its capacity
        let mut s2 = pool.scratch();
        assert!(s2.acc_buf().capacity() >= 100);
        assert!(s2.take_updates().capacity() >= 64);
    }

    #[test]
    fn adaptive_depth_tracks_io_to_compute_ratio() {
        let mk = |io_ms: u64, c_ms: u64| pipeline::WorklistOutcome {
            processed: 10,
            prefetched: 10,
            io_busy: Duration::from_millis(io_ms),
            compute_busy: Duration::from_millis(c_ms),
            ..Default::default()
        };
        // I/O-bound: deep queue (capped)
        assert_eq!(adaptive_depth(&mk(1000, 10), 4, 4), MAX_AUTO_DEPTH);
        // compute-bound: shallow queue
        assert_eq!(adaptive_depth(&mk(1, 100), 4, 4), 1);
        // balanced-ish: a few units per worker
        let d = adaptive_depth(&mk(10, 10), 4, 4);
        assert!((1..=MAX_AUTO_DEPTH).contains(&d));
        // degenerate measurements keep the previous depth
        assert_eq!(adaptive_depth(&mk(0, 0), 4, 7), 7);
    }
}
