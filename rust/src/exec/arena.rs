//! 64-byte-aligned scratch arenas for the kernel accumulators.
//!
//! The chunked kernels in [`super::kernel`] fold rows through
//! fixed-width lane accumulators ([`super::kernel::LANES`] f32 lanes per
//! step).  Backing the per-worker fold scratch with cache-line-aligned
//! storage keeps every lane block inside one line and satisfies the
//! 64-byte alignment the `simd` feature's `f32x8` path prefers — the
//! kernel entry points `debug_assert` it.
//!
//! A [`Line`] is one 64-byte cache line; [`AlignedArena`] hands out
//! zeroed `f32`/`u32` slice views over a reusable `Vec<Line>`, so
//! steady-state folds never reallocate and every view is 64-byte
//! aligned at its base.  Arenas are recycled through
//! [`super::ScratchPool`] at that same alignment (the alignment is a
//! property of the `Line` type, not of any particular allocation).

/// One zeroed cache line: sixteen 32-bit words, 64-byte aligned.
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct Line([u32; 16]);

const WORDS_PER_LINE: usize = 16;

/// A reusable 64-byte-aligned scratch buffer handing out zeroed
/// `f32` / `u32` slice views.  Each `f32s`/`u32s` call resets the
/// arena, so only one view is live at a time (enforced by the `&mut`
/// borrow).
#[derive(Default)]
pub struct AlignedArena {
    lines: Vec<Line>,
}

impl AlignedArena {
    pub fn new() -> Self {
        AlignedArena { lines: Vec::new() }
    }

    /// Zero exactly the lines needed for `words` 32-bit words, reusing
    /// the existing capacity (same cost shape as the pre-arena
    /// `acc.clear(); acc.resize(len, 0.0)` pattern).
    fn reset(&mut self, words: usize) {
        let need = words.div_ceil(WORDS_PER_LINE);
        self.lines.clear();
        self.lines.resize(need, Line([0; WORDS_PER_LINE]));
    }

    /// A zeroed `len`-element `f32` view, 64-byte aligned at its base.
    pub fn f32s(&mut self, len: usize) -> &mut [f32] {
        self.reset(len);
        debug_assert_eq!(self.lines.as_ptr() as usize % 64, 0);
        // SAFETY: the Vec holds at least `len` zeroed 32-bit words
        // (zeroed bits are a valid f32), `Line` is `repr(C, align(64))`
        // so the cast only lowers the alignment requirement, and the
        // `&mut self` borrow pins the backing store for the view's
        // lifetime.
        unsafe { std::slice::from_raw_parts_mut(self.lines.as_mut_ptr() as *mut f32, len) }
    }

    /// A zeroed `len`-element `u32` view, 64-byte aligned at its base.
    pub fn u32s(&mut self, len: usize) -> &mut [u32] {
        self.reset(len);
        debug_assert_eq!(self.lines.as_ptr() as usize % 64, 0);
        // SAFETY: as in `f32s` — zeroed words, alignment only lowered.
        unsafe { std::slice::from_raw_parts_mut(self.lines.as_mut_ptr() as *mut u32, len) }
    }

    /// A zeroed `len`-element `u64` view, 64-byte aligned at its base
    /// (two 32-bit words per element; `Line`'s 64-byte alignment is a
    /// multiple of `u64`'s 8, so the cast only lowers the requirement).
    pub fn u64s(&mut self, len: usize) -> &mut [u64] {
        self.reset(len * 2);
        debug_assert_eq!(self.lines.as_ptr() as usize % 64, 0);
        // SAFETY: as in `f32s` — `2 * len` zeroed 32-bit words back
        // `len` zeroed u64s, alignment only lowered.
        unsafe { std::slice::from_raw_parts_mut(self.lines.as_mut_ptr() as *mut u64, len) }
    }

    /// Backing capacity in bytes (reuse assertions + memory accounting).
    pub fn capacity_bytes(&self) -> usize {
        self.lines.capacity() * std::mem::size_of::<Line>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_are_zeroed_aligned_and_reused() {
        let mut a = AlignedArena::new();
        {
            let f = a.f32s(100);
            assert_eq!(f.len(), 100);
            assert!(f.iter().all(|&x| x == 0.0));
            assert_eq!(f.as_ptr() as usize % 64, 0, "f32 view must be line-aligned");
            f[99] = 7.0;
        }
        let cap = a.capacity_bytes();
        assert!(cap >= 400, "arena must retain its backing store");
        // a smaller request reuses the backing store and re-zeroes it
        let u = a.u32s(64);
        assert_eq!(u.len(), 64);
        assert_eq!(u.as_ptr() as usize % 64, 0, "u32 view must be line-aligned");
        assert!(u.iter().all(|&x| x == 0), "views are re-zeroed on reset");
        assert_eq!(a.capacity_bytes(), cap, "shrinking request must not reallocate");
    }

    #[test]
    fn empty_views_are_valid() {
        let mut a = AlignedArena::new();
        assert_eq!(a.f32s(0).len(), 0);
        assert_eq!(a.u32s(0).len(), 0);
        assert_eq!(a.u64s(0).len(), 0);
        assert_eq!(a.capacity_bytes(), 0);
    }

    #[test]
    fn u64_views_are_zeroed_aligned_and_sized() {
        let mut a = AlignedArena::new();
        let w = a.u64s(33);
        assert_eq!(w.len(), 33);
        assert_eq!(w.as_ptr() as usize % 64, 0, "u64 view must be line-aligned");
        assert!(w.iter().all(|&x| x == 0));
        w[32] = u64::MAX;
        // the next view re-zeroes the same backing store
        assert!(a.u64s(33).iter().all(|&x| x == 0));
    }
}
