//! Per-iteration shard scheduling and the bitset-backed active set.
//!
//! Selective scheduling (paper §2.4.1) used to run as inline per-worker
//! Bloom probes on the critical path; the scheduler instead computes the
//! iteration's active-shard worklist up front with one batched pass
//! ([`BloomSet::probe_active`]), so the prefetcher knows exactly which
//! shards to stage and workers never touch a filter.
//!
//! The active set itself is rebuilt through [`ActiveBits`]: workers mark
//! activated vertices into a shared atomic bitset (word-buffered, one
//! atomic OR per 64 contiguous rows) and the barrier scans it into a
//! sorted `Vec` — replacing the old `Mutex<Vec<VertexId>>` append plus
//! global sort, and making the rebuild deterministic in worker count.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::bloom::BloomSet;
use crate::graph::VertexId;

/// Compute the iteration's shard worklist (ascending shard ids) and the
/// number of shards skipped.  With selective scheduling off every shard
/// is scheduled; on, a shard is scheduled iff its Bloom filter (possibly)
/// contains an active vertex — identical semantics to the old inline
/// `contains_any` probes, decided once instead of per worker.
pub fn shard_worklist(
    blooms: &BloomSet,
    num_shards: usize,
    active: &[VertexId],
    selective_on: bool,
) -> (Vec<u32>, u32) {
    if !selective_on {
        return ((0..num_shards as u32).collect(), 0);
    }
    let hot = blooms.probe_active(active);
    let worklist: Vec<u32> = (0..num_shards as u32)
        .filter(|&s| hot[s as usize])
        .collect();
    let skipped = num_shards as u32 - worklist.len() as u32;
    (worklist, skipped)
}

/// Merge per-job ascending unit worklists into one deduplicated union
/// worklist (ascending) plus a per-unit membership bitmask: bit `j` of
/// `members[i]` is set iff job `j`'s worklist contains `union[i]`.
///
/// This is the scan-sharing merge (PR 4): the pipeline loads each unit
/// of the union exactly once and hands it to every member job, while a
/// job still computes *only* the units its own (Bloom-filtered) worklist
/// selected — so per-job results stay bit-identical to a solo run.
pub fn union_worklists(lists: &[Vec<u32>]) -> (Vec<u32>, Vec<u64>) {
    assert!(lists.len() <= 64, "membership masks hold at most 64 jobs");
    let mut map: std::collections::BTreeMap<u32, u64> = std::collections::BTreeMap::new();
    for (j, wl) in lists.iter().enumerate() {
        for &u in wl {
            *map.entry(u).or_insert(0) |= 1u64 << j;
        }
    }
    map.into_iter().unzip()
}

/// A fixed-size atomic bitset over the vertex space.  Workers mark
/// activated vertices concurrently (shard intervals are disjoint, so
/// contention is limited to boundary words); the iteration barrier scans
/// it into a sorted, duplicate-free vertex list.
pub struct ActiveBits {
    words: Vec<AtomicU64>,
}

impl ActiveBits {
    pub fn new(num_vertices: usize) -> Self {
        ActiveBits {
            words: (0..num_vertices.div_ceil(64)).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Mark a single vertex active.
    pub fn mark(&self, v: VertexId) {
        self.words[(v / 64) as usize].fetch_or(1 << (v % 64), Ordering::Relaxed);
    }

    /// Word-buffered marker: one atomic OR per touched word instead of one
    /// per activation — the fast path for a worker walking a shard's
    /// contiguous ascending rows.
    pub fn marker(&self) -> RangeMarker<'_> {
        RangeMarker { bits: self, word: usize::MAX, acc: 0 }
    }

    /// Scan into the sorted active-vertex list (ascending, no duplicates).
    pub fn to_sorted_vec(&self) -> Vec<VertexId> {
        let mut out = Vec::new();
        for (wi, w) in self.words.iter().enumerate() {
            let mut bits = w.load(Ordering::Relaxed);
            while bits != 0 {
                let b = bits.trailing_zeros();
                out.push((wi as u32) * 64 + b);
                bits &= bits - 1;
            }
        }
        out
    }
}

/// See [`ActiveBits::marker`].  The buffered word is published by
/// [`flush`](Self::flush) or automatically on drop (worker exit).
pub struct RangeMarker<'a> {
    bits: &'a ActiveBits,
    word: usize,
    acc: u64,
}

impl Drop for RangeMarker<'_> {
    fn drop(&mut self) {
        self.flush();
    }
}

impl RangeMarker<'_> {
    pub fn mark(&mut self, v: VertexId) {
        let w = (v / 64) as usize;
        if w != self.word {
            self.flush();
            self.word = w;
        }
        self.acc |= 1 << (v % 64);
    }

    /// Publish the buffered word (no-op when nothing is pending).
    pub fn flush(&mut self) {
        if self.word != usize::MAX && self.acc != 0 {
            self.bits.words[self.word].fetch_or(self.acc, Ordering::Relaxed);
        }
        self.word = usize::MAX;
        self.acc = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bloom::BloomFilter;

    fn bloom_set() -> BloomSet {
        let mut filters = Vec::new();
        for s in 0..3u32 {
            let mut f = BloomFilter::with_rate(32, 0.0001);
            for v in 0..16u32 {
                f.insert(s * 100 + v);
            }
            filters.push(f);
        }
        BloomSet { filters }
    }

    #[test]
    fn worklist_all_shards_when_not_selective() {
        let (wl, skipped) = shard_worklist(&bloom_set(), 3, &[], false);
        assert_eq!(wl, vec![0, 1, 2]);
        assert_eq!(skipped, 0);
    }

    #[test]
    fn worklist_matches_per_shard_probes() {
        let set = bloom_set();
        for active in [vec![], vec![5u32], vec![105, 205], vec![999]] {
            let (wl, skipped) = shard_worklist(&set, 3, &active, true);
            let expect: Vec<u32> = (0..3u32)
                .filter(|&s| set.filters[s as usize].contains_any(&active))
                .collect();
            assert_eq!(wl, expect, "active {active:?}");
            assert_eq!(skipped as usize, 3 - expect.len());
        }
    }

    #[test]
    fn union_worklists_merges_and_tracks_membership() {
        let (u, m) = union_worklists(&[vec![0, 2, 5], vec![2, 3], vec![]]);
        assert_eq!(u, vec![0, 2, 3, 5]);
        assert_eq!(m, vec![0b001, 0b011, 0b010, 0b001]);
        // single-job union is the worklist itself, all bits = job 0
        let (u, m) = union_worklists(&[vec![4, 7]]);
        assert_eq!(u, vec![4, 7]);
        assert_eq!(m, vec![1, 1]);
        let (u, m) = union_worklists(&[]);
        assert!(u.is_empty() && m.is_empty());
    }

    #[test]
    fn union_worklists_empty_member_schedules_cost_nothing() {
        // a member with an empty schedule (converged frontier this pass)
        // contributes no units but keeps its mask position
        let (u, m) = union_worklists(&[vec![], vec![3, 8], vec![]]);
        assert_eq!(u, vec![3, 8]);
        assert_eq!(m, vec![0b010, 0b010]);
        // all members empty: an empty pass
        let (u, m) = union_worklists(&[vec![], vec![]]);
        assert!(u.is_empty() && m.is_empty());
    }

    #[test]
    fn union_worklists_mask_holds_exactly_64_jobs() {
        // job 63 sets the top bit without overflow…
        let lists: Vec<Vec<u32>> = (0..64)
            .map(|j| if j == 63 { vec![9] } else { Vec::new() })
            .collect();
        let (u, m) = union_worklists(&lists);
        assert_eq!(u, vec![9]);
        assert_eq!(m, vec![1u64 << 63]);
        // …and a shared unit across all 64 jobs fills the mask
        let lists: Vec<Vec<u32>> = (0..64).map(|_| vec![5]).collect();
        let (u, m) = union_worklists(&lists);
        assert_eq!(u, vec![5]);
        assert_eq!(m, vec![u64::MAX]);
    }

    #[test]
    #[should_panic(expected = "membership masks hold at most 64 jobs")]
    fn union_worklists_rejects_more_than_64_jobs() {
        let lists: Vec<Vec<u32>> = (0..65).map(|_| vec![0]).collect();
        let _ = union_worklists(&lists);
    }

    #[test]
    fn active_bits_sorted_and_deduplicated() {
        let bits = ActiveBits::new(300);
        for v in [299u32, 0, 64, 63, 65, 0, 130] {
            bits.mark(v);
        }
        assert_eq!(bits.to_sorted_vec(), vec![0, 63, 64, 65, 130, 299]);
    }

    #[test]
    fn range_marker_flushes_word_boundaries() {
        let bits = ActiveBits::new(256);
        let mut m = bits.marker();
        for v in [10u32, 11, 63, 64, 65, 200] {
            m.mark(v);
        }
        m.flush();
        assert_eq!(bits.to_sorted_vec(), vec![10, 11, 63, 64, 65, 200]);
        // flush with nothing pending is a no-op
        let mut m2 = bits.marker();
        m2.flush();
        assert_eq!(bits.to_sorted_vec().len(), 6);
    }

    #[test]
    fn concurrent_marking_is_exact() {
        let bits = ActiveBits::new(64 * 8);
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                let bits = &bits;
                scope.spawn(move || {
                    let mut m = bits.marker();
                    // overlapping word ranges across threads
                    for v in (t * 96)..(t * 96 + 96) {
                        m.mark(v % 512);
                    }
                    m.flush();
                });
            }
        });
        let got = bits.to_sorted_vec();
        assert_eq!(got.len(), 384); // 4 disjoint 96-wide ranges mod 512
        assert!(got.windows(2).all(|w| w[0] < w[1]));
    }
}
