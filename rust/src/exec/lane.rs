//! Generic value lanes (PR 10).
//!
//! GraphMP's VSW model keeps all vertex values RAM-resident and streams
//! only edge shards, so the value type is a free parameter of the
//! design.  This module generalizes the previously f32-only lane into a
//! [`Lane`] trait with three concrete carriers:
//!
//! - `f32` — PageRank/PPR mass, SSSP/widest distances (the original lane);
//! - `u32` — WCC labels, BFS levels, k-core alive flags;
//! - `u64` — wide labels / costs (no shipped app yet; exercised by the
//!   kernel property sweeps so the monomorphization can't rot).
//!
//! The contract every lane obeys (see `docs/ARCHITECTURE.md`, "Generic
//! lanes"):
//!
//! - **Sum** combine is `+` for f32 and *saturating* add for the integer
//!   lanes.  Saturating add of non-negative integers is associative and
//!   commutative (`min(true_sum, MAX)` under any association), so the
//!   chunked width-8 folds are **bitwise** identical to the sequential
//!   scalar oracle for u32/u64 — integer sums get no epsilon carve-out.
//!   f32 sums keep the documented relative-epsilon gate (reassociation).
//! - **Min/Max** meets are exact for every lane.
//! - Identities: min-identity is `INFINITY`/`MAX`, max-identity is
//!   `NEG_INFINITY`/`0` (integer lanes carry non-negative values only).
//!
//! Type-erased carriers ([`LaneVec`], [`LaneSlice`], [`LaneSliceMut`])
//! move values across the untyped layers (batch runtime, checkpoints,
//! serve protocol); the [`with_lane!`] macro dispatches back into the
//! monomorphized kernels at the hot-loop boundary.

use super::arena::AlignedArena;
use super::kernel::LANES;
use crate::apps::EdgeCost;

/// The runtime tag for a lane's concrete type.  Threaded through
/// `ShardKernel`, checkpoint lane headers (v2) and the serve protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaneType {
    F32,
    U32,
    U64,
}

impl LaneType {
    pub fn name(self) -> &'static str {
        match self {
            LaneType::F32 => "f32",
            LaneType::U32 => "u32",
            LaneType::U64 => "u64",
        }
    }

    pub fn parse(s: &str) -> Option<LaneType> {
        match s {
            "f32" => Some(LaneType::F32),
            "u32" => Some(LaneType::U32),
            "u64" => Some(LaneType::U64),
            _ => None,
        }
    }

    /// Bytes per value when serialized (checkpoint lane format v2).
    pub fn bytes(self) -> usize {
        match self {
            LaneType::F32 | LaneType::U32 => 4,
            LaneType::U64 => 8,
        }
    }

    /// Stable wire tag (checkpoint lane header field).
    pub fn tag(self) -> u32 {
        match self {
            LaneType::F32 => 0,
            LaneType::U32 => 1,
            LaneType::U64 => 2,
        }
    }

    pub fn from_tag(t: u32) -> Option<LaneType> {
        match t {
            0 => Some(LaneType::F32),
            1 => Some(LaneType::U32),
            2 => Some(LaneType::U64),
            _ => None,
        }
    }
}

/// A concrete value-lane type.  Everything the kernels, the batch
/// runtime and the apps need from a vertex value, behind one trait so
/// `fold_csr`/`fold_list`/`scatter_list` monomorphize per type while
/// keeping the exact width-8 chunked scheme of the f32 original.
pub trait Lane:
    Copy + Send + Sync + PartialEq + PartialOrd + std::fmt::Debug + Default + 'static
{
    const TYPE: LaneType;
    const ZERO: Self;
    const ONE: Self;
    /// Identity of the min-combine (`meet_min(MIN_IDENTITY, x) == x`).
    const MIN_IDENTITY: Self;
    /// Identity of the max-combine over the lane's value domain.
    const MAX_IDENTITY: Self;

    /// Sum combine: `+` for f32, saturating add for integer lanes (which
    /// keeps the chunked fold bitwise-associative — see module docs).
    fn add(self, other: Self) -> Self;
    fn meet_min(self, other: Self) -> Self;
    fn meet_max(self, other: Self) -> Self;

    /// An edge weight as a lane value (costs/capacities).
    fn from_weight(w: f32) -> Self;
    /// An [`EdgeCost`] as a lane value.  For f32 this is exactly the
    /// historical `EdgeCost::apply` (`w` / `1.0` / `0.0`).
    fn cost(c: EdgeCost, w: f32) -> Self {
        match c {
            EdgeCost::Weights => Self::from_weight(w),
            EdgeCost::Unit => Self::ONE,
            EdgeCost::Zero => Self::ZERO,
        }
    }
    /// A pre-folded contribution (`src * inv_out_deg`) read back as a
    /// lane value.  Degree-normalized mass only exists on f32 lanes.
    fn from_mass(m: f32) -> Self;
    /// `src * inv_out_deg` for the degree-mass gather (f32 lanes only).
    fn degree_mass(self, inv_out_deg: f32) -> Self;
    /// `base + scale * acc` for the affine apply (f32 lanes only).
    fn affine(acc: Self, scale: f32, base: f32) -> Self;
    /// `ONE` if non-zero else `ZERO` (k-core alive gather).
    fn indicator(self) -> Self;
    /// Threshold test for the k-core apply: `self >= k`.
    fn count_ge(self, k: u32) -> bool;

    fn to_bits64(self) -> u64;
    fn from_bits64(bits: u64) -> Self;
    fn to_f64(self) -> f64;

    /// One width-[`LANES`] accumulate step.  For f32 this is the only
    /// `cfg(feature = "simd")`-switched function in the crate (the
    /// `std::simd::f32x8` add performs the same lane arithmetic in the
    /// same order, so results are bit-identical to the default build);
    /// integer lanes use the scalar loop in both builds.
    fn add_lanes(acc: &mut [Self; LANES], vals: &[Self; LANES]);

    /// A zeroed, 64-byte-aligned scratch view of `len` values.
    fn arena_slice(arena: &mut AlignedArena, len: usize) -> &mut [Self];

    /// Extract this lane's typed slice from an erased slice; panics on a
    /// lane-type mismatch (a kernel/value-vector pairing bug).
    fn of_slice<'a>(s: LaneSlice<'a>) -> &'a [Self];
    fn of_mut<'a>(s: LaneSliceMut<'a>) -> &'a mut [Self];
    fn of_vec(v: &LaneVec) -> &[Self];
    fn into_vec(v: LaneVec) -> Vec<Self>;
    fn wrap(v: Vec<Self>) -> LaneVec;
}

#[cold]
fn lane_mismatch(want: LaneType, got: LaneType) -> ! {
    panic!("lane type mismatch: expected {} got {}", want.name(), got.name())
}

impl Lane for f32 {
    const TYPE: LaneType = LaneType::F32;
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const MIN_IDENTITY: Self = f32::INFINITY;
    const MAX_IDENTITY: Self = f32::NEG_INFINITY;

    #[inline(always)]
    fn add(self, other: Self) -> Self {
        self + other
    }
    #[inline(always)]
    fn meet_min(self, other: Self) -> Self {
        self.min(other)
    }
    #[inline(always)]
    fn meet_max(self, other: Self) -> Self {
        self.max(other)
    }
    #[inline(always)]
    fn from_weight(w: f32) -> Self {
        w
    }
    #[inline(always)]
    fn from_mass(m: f32) -> Self {
        m
    }
    #[inline(always)]
    fn degree_mass(self, inv_out_deg: f32) -> Self {
        self * inv_out_deg
    }
    #[inline(always)]
    fn affine(acc: Self, scale: f32, base: f32) -> Self {
        base + scale * acc
    }
    #[inline(always)]
    fn indicator(self) -> Self {
        if self != 0.0 {
            1.0
        } else {
            0.0
        }
    }
    #[inline(always)]
    fn count_ge(self, k: u32) -> bool {
        self >= k as f32
    }
    #[inline(always)]
    fn to_bits64(self) -> u64 {
        self.to_bits() as u64
    }
    #[inline(always)]
    fn from_bits64(bits: u64) -> Self {
        f32::from_bits(bits as u32)
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        f64::from(self)
    }

    #[cfg(not(feature = "simd"))]
    #[inline(always)]
    fn add_lanes(acc: &mut [Self; LANES], vals: &[Self; LANES]) {
        for i in 0..LANES {
            acc[i] += vals[i];
        }
    }

    #[cfg(feature = "simd")]
    #[inline(always)]
    fn add_lanes(acc: &mut [Self; LANES], vals: &[Self; LANES]) {
        use std::simd::f32x8;
        let a = f32x8::from_array(*acc);
        let v = f32x8::from_array(*vals);
        *acc = (a + v).to_array();
    }

    #[inline]
    fn arena_slice(arena: &mut AlignedArena, len: usize) -> &mut [Self] {
        arena.f32s(len)
    }

    #[inline(always)]
    fn of_slice<'a>(s: LaneSlice<'a>) -> &'a [Self] {
        match s {
            LaneSlice::F32(v) => v,
            other => lane_mismatch(LaneType::F32, other.lane_type()),
        }
    }
    #[inline(always)]
    fn of_mut<'a>(s: LaneSliceMut<'a>) -> &'a mut [Self] {
        match s {
            LaneSliceMut::F32(v) => v,
            other => lane_mismatch(LaneType::F32, other.lane_type()),
        }
    }
    fn of_vec(v: &LaneVec) -> &[Self] {
        v.f32s()
    }
    fn into_vec(v: LaneVec) -> Vec<Self> {
        match v {
            LaneVec::F32(v) => v,
            other => lane_mismatch(LaneType::F32, other.lane_type()),
        }
    }
    fn wrap(v: Vec<Self>) -> LaneVec {
        LaneVec::F32(v)
    }
}

impl Lane for u32 {
    const TYPE: LaneType = LaneType::U32;
    const ZERO: Self = 0;
    const ONE: Self = 1;
    const MIN_IDENTITY: Self = u32::MAX;
    const MAX_IDENTITY: Self = 0;

    #[inline(always)]
    fn add(self, other: Self) -> Self {
        self.saturating_add(other)
    }
    #[inline(always)]
    fn meet_min(self, other: Self) -> Self {
        self.min(other)
    }
    #[inline(always)]
    fn meet_max(self, other: Self) -> Self {
        self.max(other)
    }
    #[inline(always)]
    fn from_weight(w: f32) -> Self {
        w as u32
    }
    fn from_mass(_m: f32) -> Self {
        unreachable!("degree-normalized mass requires f32 lanes")
    }
    fn degree_mass(self, _inv_out_deg: f32) -> Self {
        unreachable!("degree-mass gather requires f32 lanes")
    }
    fn affine(_acc: Self, _scale: f32, _base: f32) -> Self {
        unreachable!("affine apply requires f32 lanes")
    }
    #[inline(always)]
    fn indicator(self) -> Self {
        (self != 0) as u32
    }
    #[inline(always)]
    fn count_ge(self, k: u32) -> bool {
        self >= k
    }
    #[inline(always)]
    fn to_bits64(self) -> u64 {
        self as u64
    }
    #[inline(always)]
    fn from_bits64(bits: u64) -> Self {
        bits as u32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        f64::from(self)
    }

    #[inline(always)]
    fn add_lanes(acc: &mut [Self; LANES], vals: &[Self; LANES]) {
        for i in 0..LANES {
            acc[i] = acc[i].saturating_add(vals[i]);
        }
    }

    #[inline]
    fn arena_slice(arena: &mut AlignedArena, len: usize) -> &mut [Self] {
        arena.u32s(len)
    }

    #[inline(always)]
    fn of_slice<'a>(s: LaneSlice<'a>) -> &'a [Self] {
        match s {
            LaneSlice::U32(v) => v,
            other => lane_mismatch(LaneType::U32, other.lane_type()),
        }
    }
    #[inline(always)]
    fn of_mut<'a>(s: LaneSliceMut<'a>) -> &'a mut [Self] {
        match s {
            LaneSliceMut::U32(v) => v,
            other => lane_mismatch(LaneType::U32, other.lane_type()),
        }
    }
    fn of_vec(v: &LaneVec) -> &[Self] {
        v.u32s()
    }
    fn into_vec(v: LaneVec) -> Vec<Self> {
        match v {
            LaneVec::U32(v) => v,
            other => lane_mismatch(LaneType::U32, other.lane_type()),
        }
    }
    fn wrap(v: Vec<Self>) -> LaneVec {
        LaneVec::U32(v)
    }
}

impl Lane for u64 {
    const TYPE: LaneType = LaneType::U64;
    const ZERO: Self = 0;
    const ONE: Self = 1;
    const MIN_IDENTITY: Self = u64::MAX;
    const MAX_IDENTITY: Self = 0;

    #[inline(always)]
    fn add(self, other: Self) -> Self {
        self.saturating_add(other)
    }
    #[inline(always)]
    fn meet_min(self, other: Self) -> Self {
        self.min(other)
    }
    #[inline(always)]
    fn meet_max(self, other: Self) -> Self {
        self.max(other)
    }
    #[inline(always)]
    fn from_weight(w: f32) -> Self {
        w as u64
    }
    fn from_mass(_m: f32) -> Self {
        unreachable!("degree-normalized mass requires f32 lanes")
    }
    fn degree_mass(self, _inv_out_deg: f32) -> Self {
        unreachable!("degree-mass gather requires f32 lanes")
    }
    fn affine(_acc: Self, _scale: f32, _base: f32) -> Self {
        unreachable!("affine apply requires f32 lanes")
    }
    #[inline(always)]
    fn indicator(self) -> Self {
        (self != 0) as u64
    }
    #[inline(always)]
    fn count_ge(self, k: u32) -> bool {
        self >= k as u64
    }
    #[inline(always)]
    fn to_bits64(self) -> u64 {
        self
    }
    #[inline(always)]
    fn from_bits64(bits: u64) -> Self {
        bits
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn add_lanes(acc: &mut [Self; LANES], vals: &[Self; LANES]) {
        for i in 0..LANES {
            acc[i] = acc[i].saturating_add(vals[i]);
        }
    }

    #[inline]
    fn arena_slice(arena: &mut AlignedArena, len: usize) -> &mut [Self] {
        arena.u64s(len)
    }

    #[inline(always)]
    fn of_slice<'a>(s: LaneSlice<'a>) -> &'a [Self] {
        match s {
            LaneSlice::U64(v) => v,
            other => lane_mismatch(LaneType::U64, other.lane_type()),
        }
    }
    #[inline(always)]
    fn of_mut<'a>(s: LaneSliceMut<'a>) -> &'a mut [Self] {
        match s {
            LaneSliceMut::U64(v) => v,
            other => lane_mismatch(LaneType::U64, other.lane_type()),
        }
    }
    fn of_vec(v: &LaneVec) -> &[Self] {
        v.u64s()
    }
    fn into_vec(v: LaneVec) -> Vec<Self> {
        match v {
            LaneVec::U64(v) => v,
            other => lane_mismatch(LaneType::U64, other.lane_type()),
        }
    }
    fn wrap(v: Vec<Self>) -> LaneVec {
        LaneVec::U64(v)
    }
}

/// An owned, type-erased value vector: one job's vertex values.
#[derive(Clone, Debug, PartialEq)]
pub enum LaneVec {
    F32(Vec<f32>),
    U32(Vec<u32>),
    U64(Vec<u64>),
}

impl Default for LaneVec {
    fn default() -> Self {
        LaneVec::F32(Vec::new())
    }
}

impl LaneVec {
    pub fn len(&self) -> usize {
        match self {
            LaneVec::F32(v) => v.len(),
            LaneVec::U32(v) => v.len(),
            LaneVec::U64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn lane_type(&self) -> LaneType {
        match self {
            LaneVec::F32(_) => LaneType::F32,
            LaneVec::U32(_) => LaneType::U32,
            LaneVec::U64(_) => LaneType::U64,
        }
    }

    pub fn as_slice(&self) -> LaneSlice<'_> {
        match self {
            LaneVec::F32(v) => LaneSlice::F32(v),
            LaneVec::U32(v) => LaneSlice::U32(v),
            LaneVec::U64(v) => LaneSlice::U64(v),
        }
    }

    pub fn as_mut(&mut self) -> LaneSliceMut<'_> {
        match self {
            LaneVec::F32(v) => LaneSliceMut::F32(v),
            LaneVec::U32(v) => LaneSliceMut::U32(v),
            LaneVec::U64(v) => LaneSliceMut::U64(v),
        }
    }

    /// Typed accessors; panic on a lane-type mismatch.
    pub fn f32s(&self) -> &[f32] {
        match self {
            LaneVec::F32(v) => v,
            other => lane_mismatch(LaneType::F32, other.lane_type()),
        }
    }
    pub fn u32s(&self) -> &[u32] {
        match self {
            LaneVec::U32(v) => v,
            other => lane_mismatch(LaneType::U32, other.lane_type()),
        }
    }
    pub fn u64s(&self) -> &[u64] {
        match self {
            LaneVec::U64(v) => v,
            other => lane_mismatch(LaneType::U64, other.lane_type()),
        }
    }

    /// Value `i` widened to f64 (lossless for every lane except u64
    /// values above 2^53; serve results and CLI printing only).
    pub fn get_f64(&self, i: usize) -> f64 {
        match self {
            LaneVec::F32(v) => f64::from(v[i]),
            LaneVec::U32(v) => f64::from(v[i]),
            LaneVec::U64(v) => v[i] as f64,
        }
    }

    /// Value `i`'s raw bit pattern, zero-extended to 64 bits.
    pub fn bits64(&self, i: usize) -> u64 {
        match self {
            LaneVec::F32(v) => v[i].to_bits() as u64,
            LaneVec::U32(v) => v[i] as u64,
            LaneVec::U64(v) => v[i],
        }
    }
}

impl From<Vec<f32>> for LaneVec {
    fn from(v: Vec<f32>) -> Self {
        LaneVec::F32(v)
    }
}
impl From<Vec<u32>> for LaneVec {
    fn from(v: Vec<u32>) -> Self {
        LaneVec::U32(v)
    }
}
impl From<Vec<u64>> for LaneVec {
    fn from(v: Vec<u64>) -> Self {
        LaneVec::U64(v)
    }
}

// Mixed-type equality against plain f32 vectors keeps the pre-PR-10
// test idiom (`assert_eq!(engine_values, reference_vec)`) working.
impl PartialEq<Vec<f32>> for LaneVec {
    fn eq(&self, other: &Vec<f32>) -> bool {
        matches!(self, LaneVec::F32(v) if v == other)
    }
}
impl PartialEq<LaneVec> for Vec<f32> {
    fn eq(&self, other: &LaneVec) -> bool {
        other == self
    }
}
impl PartialEq<[f32]> for LaneVec {
    fn eq(&self, other: &[f32]) -> bool {
        matches!(self, LaneVec::F32(v) if v[..] == *other)
    }
}

/// A borrowed, type-erased view of a value vector.
#[derive(Clone, Copy, Debug)]
pub enum LaneSlice<'a> {
    F32(&'a [f32]),
    U32(&'a [u32]),
    U64(&'a [u64]),
}

impl<'a> LaneSlice<'a> {
    pub fn len(&self) -> usize {
        match self {
            LaneSlice::F32(v) => v.len(),
            LaneSlice::U32(v) => v.len(),
            LaneSlice::U64(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn lane_type(&self) -> LaneType {
        match self {
            LaneSlice::F32(_) => LaneType::F32,
            LaneSlice::U32(_) => LaneType::U32,
            LaneSlice::U64(_) => LaneType::U64,
        }
    }
    pub fn to_lane_vec(self) -> LaneVec {
        match self {
            LaneSlice::F32(v) => LaneVec::F32(v.to_vec()),
            LaneSlice::U32(v) => LaneVec::U32(v.to_vec()),
            LaneSlice::U64(v) => LaneVec::U64(v.to_vec()),
        }
    }
    pub fn f32s(self) -> &'a [f32] {
        match self {
            LaneSlice::F32(v) => v,
            other => lane_mismatch(LaneType::F32, other.lane_type()),
        }
    }
}

impl<'a> From<&'a [f32]> for LaneSlice<'a> {
    fn from(v: &'a [f32]) -> Self {
        LaneSlice::F32(v)
    }
}
impl<'a> From<&'a Vec<f32>> for LaneSlice<'a> {
    fn from(v: &'a Vec<f32>) -> Self {
        LaneSlice::F32(v)
    }
}

/// A mutable, type-erased view of a value vector (a `SharedDst` claim).
#[derive(Debug)]
pub enum LaneSliceMut<'a> {
    F32(&'a mut [f32]),
    U32(&'a mut [u32]),
    U64(&'a mut [u64]),
}

impl<'a> LaneSliceMut<'a> {
    pub fn len(&self) -> usize {
        match self {
            LaneSliceMut::F32(v) => v.len(),
            LaneSliceMut::U32(v) => v.len(),
            LaneSliceMut::U64(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn lane_type(&self) -> LaneType {
        match self {
            LaneSliceMut::F32(_) => LaneType::F32,
            LaneSliceMut::U32(_) => LaneType::U32,
            LaneSliceMut::U64(_) => LaneType::U64,
        }
    }
    /// Reborrow: a shorter-lived mutable view of the same values.
    pub fn rb(&mut self) -> LaneSliceMut<'_> {
        match self {
            LaneSliceMut::F32(v) => LaneSliceMut::F32(v),
            LaneSliceMut::U32(v) => LaneSliceMut::U32(v),
            LaneSliceMut::U64(v) => LaneSliceMut::U64(v),
        }
    }
    /// A shared view of the same values.
    pub fn shared(&self) -> LaneSlice<'_> {
        match self {
            LaneSliceMut::F32(v) => LaneSlice::F32(v),
            LaneSliceMut::U32(v) => LaneSlice::U32(v),
            LaneSliceMut::U64(v) => LaneSlice::U64(v),
        }
    }
    pub fn f32s(self) -> &'a mut [f32] {
        match self {
            LaneSliceMut::F32(v) => v,
            other => lane_mismatch(LaneType::F32, other.lane_type()),
        }
    }
}

impl<'a> From<&'a mut [f32]> for LaneSliceMut<'a> {
    fn from(v: &'a mut [f32]) -> Self {
        LaneSliceMut::F32(v)
    }
}
impl<'a> From<&'a mut Vec<f32>> for LaneSliceMut<'a> {
    fn from(v: &'a mut Vec<f32>) -> Self {
        LaneSliceMut::F32(v)
    }
}

/// Dispatch an expression over a [`LaneType`], binding `$T` to the
/// concrete lane type in each arm.
macro_rules! with_lane {
    ($lane:expr, $T:ident => $body:expr) => {
        match $lane {
            $crate::exec::lane::LaneType::F32 => {
                type $T = f32;
                $body
            }
            $crate::exec::lane::LaneType::U32 => {
                type $T = u32;
                $body
            }
            $crate::exec::lane::LaneType::U64 => {
                type $T = u64;
                $body
            }
        }
    };
}
pub(crate) use with_lane;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_type_tags_and_names_round_trip() {
        for t in [LaneType::F32, LaneType::U32, LaneType::U64] {
            assert_eq!(LaneType::from_tag(t.tag()), Some(t));
            assert_eq!(LaneType::parse(t.name()), Some(t));
        }
        assert_eq!(LaneType::from_tag(3), None);
        assert_eq!(LaneType::parse("i16"), None);
        assert_eq!(LaneType::U64.bytes(), 8);
        assert_eq!(LaneType::U32.bytes(), 4);
    }

    #[test]
    fn integer_sum_saturates_instead_of_wrapping() {
        assert_eq!(u32::MAX.add(1), u32::MAX);
        assert_eq!(u64::MAX.add(u64::MAX), u64::MAX);
        // saturating add stays associative at the boundary: min(sum, MAX)
        let (a, b, c) = (u32::MAX - 1, 3u32, 5u32);
        assert_eq!(a.add(b).add(c), a.add(b.add(c)));
    }

    #[test]
    fn bits64_round_trips_every_lane() {
        assert_eq!(f32::from_bits64((-1.5f32).to_bits64()), -1.5);
        assert_eq!(u32::from_bits64(7u32.to_bits64()), 7);
        assert_eq!(u64::from_bits64(u64::MAX.to_bits64()), u64::MAX);
    }

    #[test]
    fn erased_vectors_compare_against_f32_vecs() {
        let v = LaneVec::from(vec![1.0f32, 2.0]);
        assert_eq!(v, vec![1.0f32, 2.0]);
        assert_ne!(v, vec![1.0f32, 2.5]);
        let u = LaneVec::from(vec![1u32, 2]);
        assert!(u != vec![1.0f32, 2.0]);
        assert_eq!(u.get_f64(1), 2.0);
        assert_eq!(u.bits64(0), 1);
        assert_eq!(u.lane_type(), LaneType::U32);
    }

    #[test]
    #[should_panic(expected = "lane type mismatch")]
    fn typed_accessor_panics_on_mismatch() {
        LaneVec::from(vec![1u32]).f32s();
    }

    #[test]
    fn dispatch_macro_binds_the_concrete_type() {
        for t in [LaneType::F32, LaneType::U32, LaneType::U64] {
            let bytes = with_lane!(t, T => std::mem::size_of::<T>());
            assert_eq!(bytes, t.bytes());
        }
    }
}
