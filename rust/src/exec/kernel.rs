//! Monomorphized, chunk-vectorized kernel hot loops.
//!
//! [`ShardKernel`](crate::apps::ShardKernel) is a runtime value, so
//! folding edges through its enum methods pays a gather `match` (and,
//! via `IterCtx::edge_value`, a `uses_contrib` branch) **per edge**.
//! This module removes both costs: the `with_gather!` macro dispatches
//! the (combine × gather) pair **once per unit** so the inner loops
//! compile to straight-line arithmetic, and the associative combines
//! process edges in fixed-width chunks of [`LANES`] with explicit
//! multi-lane accumulators, so the per-row fold carries [`LANES`]
//! independent dependency chains instead of one serial chain.
//!
//! Since PR 10 every fold is additionally **monomorphized over the
//! value-lane type** ([`Lane`]: `f32`, `u32`, `u64`).  The erased entry
//! points ([`fold_csr`], [`fold_list`], [`scatter_list`], [`mark_rows`])
//! dispatch once per unit on `kernel.lane` and hand typed slices to the
//! generic bodies — the hot loops themselves are branch- and
//! erasure-free for every lane type.
//!
//! ## The chunked combine scheme
//!
//! Every sum folds with the same fixed scheme, everywhere:
//!
//! - lane `j` of a `[T; LANES]` accumulator adds elements
//!   `j, j+LANES, j+2·LANES, …` of the row (via `chunks_exact`);
//! - the final partial chunk lands in lanes `0..rem` of a zero-padded
//!   tail block (skipped entirely when the row length is a multiple of
//!   [`LANES`]);
//! - lanes reduce through the fixed tree
//!   `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))`.
//!
//! The default build writes this as plain `chunks_exact` loops the
//! autovectorizer turns into vector code; with `--features simd`
//! (nightly only) the f32 lane-wise accumulate is a single portable
//! [`std::simd`] `f32x8` add.  Both builds perform *bit-identical*
//! arithmetic by construction — the only `cfg`-switched operation is
//! `Lane::add_lanes` for f32, and a vertical lane add is the same eight
//! f32 additions either way.  Integer lanes use the scalar lane loop in
//! both builds.
//!
//! ## Where bit-identity is relaxed, and where it is not
//!
//! f32 addition is not associative, so the chunked f32 sum
//! **reassociates**: a row of `k ≥ 4` edges generally differs from the
//! sequential left-to-right sum in the last few ulps (rows with `k ≤ 3`
//! are exact: the zero-padded lanes vanish and the reduction tree
//! degenerates to the sequential order).  Consequently:
//!
//! - **Across engines and build modes the gates stay exact.** All five
//!   engines, both `chunks_exact` and `simd` builds, and every
//!   worker/prefetch/batch shape run the *same* chunked scheme over the
//!   *same* canonical ascending-source per-destination edge order, so
//!   `determinism.rs` / `cross_engine.rs` / `scan_sharing.rs` /
//!   `recovery.rs` still assert `==` on every app.
//! - **f32 sum comparisons against *sequential* references are epsilon
//!   gated.** [`scalar_fold_csr`] (the sequential monomorphized path)
//!   and [`reference_fold_csr`] (the per-edge enum-dispatch oracle)
//!   remain bit-identical to each other; the chunked [`fold_csr`] is
//!   compared to them with a documented epsilon for f32 `Combine::Sum`
//!   (kernel tests, `rust/tests/kernel_equivalence.rs`,
//!   `benches/hot_loop.rs`, and the dense references in engine tests).
//! - **Integer sums are bitwise everywhere.** The u32/u64 lanes sum
//!   with *saturating* adds of non-negative values — associative and
//!   commutative (`min(true_sum, MAX)` under any association) — so the
//!   chunked fold equals the sequential oracle `==`, with no epsilon
//!   carve-out (`rust/tests/kernel_equivalence.rs` gates this).
//! - **Min/max stay strictly bit-identical to the scalar oracle.** The
//!   chunked meet initializes every lane with the row's current value
//!   (the meet is idempotent) and reduces with the same `min`/`max`, so
//!   for NaN-free lanes — all app value domains here are NaN-free and
//!   signed-zero-free — the result is the multiset extremum regardless
//!   of association.  SSSP/BFS/CC/widest/WCC/BFS-levels assert `==`
//!   everywhere.
//!
//! Three fold shapes cover every engine:
//!
//! - [`fold_csr`] — CSR rows (VSW shards, the in-memory engine);
//! - [`fold_list`] — destination-grouped edge lists (PSW intervals, DSW
//!   grid columns): sums bucket edge values per destination row into a
//!   64-byte-aligned [`AlignedArena`] (counting sort by destination),
//!   then run the same chunked row sum — bit-identical to [`fold_csr`]
//!   over the same edge order;
//! - [`scatter_list`] — X-Stream-style update streams (ESG), gathered
//!   in [`LANES`] blocks into the caller's reusable buffer (per-edge
//!   values are exact; the chunked fold happens at the barrier, see
//!   `fold_updates` in [`super`]).

use super::arena::AlignedArena;
use super::lane::{with_lane, Lane, LaneSlice, LaneSliceMut};
use super::{IterCtx, Update};
use crate::apps::{Combine, EdgeCost, EdgeGather};
use crate::exec::schedule::RangeMarker;
use crate::graph::{CsrRef, Edge};

/// Fixed chunk width of the vectorized combines: eight 32-bit lanes —
/// two SSE vectors, one AVX2 vector, half a cache line (u64 lanes span
/// a full line per chunk; the scheme is the same).
pub const LANES: usize = 8;

/// Bind `$g` to a gather closure specialized for `$ctx.kernel.gather`
/// over lane type `$T`, and evaluate `$body` once per variant — the
/// single dispatch point that keeps the edge loops branch-free.  Each
/// closure mirrors `ShardKernel::edge_value_t` (with `DegreeMass`
/// reading the pre-folded `contrib` array, as `IterCtx::edge_value`
/// does) bit-for-bit; for f32 the lane ops lower to exactly the
/// pre-PR-10 arithmetic (`+ w`, `+ 1.0`, `+ 0.0`, `.min(...)`).
macro_rules! with_gather {
    ($ctx:expr, $T:ty, $g:ident => $body:expr) => {{
        let src: &[$T] = <$T as Lane>::of_slice($ctx.src);
        let contrib = $ctx.contrib;
        match $ctx.kernel.gather {
            EdgeGather::DegreeMass => {
                let $g = |u: u32, _w: f32| <$T as Lane>::from_mass(contrib[u as usize]);
                $body
            }
            EdgeGather::AddCost(EdgeCost::Weights) => {
                let $g = |u: u32, w: f32| src[u as usize].add(<$T as Lane>::from_weight(w));
                $body
            }
            EdgeGather::AddCost(EdgeCost::Unit) => {
                let $g = |u: u32, _w: f32| src[u as usize].add(<$T as Lane>::ONE);
                $body
            }
            EdgeGather::AddCost(EdgeCost::Zero) => {
                let $g = |u: u32, _w: f32| src[u as usize].add(<$T as Lane>::ZERO);
                $body
            }
            EdgeGather::MinCapacity(EdgeCost::Weights) => {
                let $g = |u: u32, w: f32| src[u as usize].meet_min(<$T as Lane>::from_weight(w));
                $body
            }
            EdgeGather::MinCapacity(EdgeCost::Unit) => {
                let $g = |u: u32, _w: f32| src[u as usize].meet_min(<$T as Lane>::ONE);
                $body
            }
            EdgeGather::MinCapacity(EdgeCost::Zero) => {
                let $g = |u: u32, _w: f32| src[u as usize].meet_min(<$T as Lane>::ZERO);
                $body
            }
            EdgeGather::Indicator => {
                let $g = |u: u32, _w: f32| src[u as usize].indicator();
                $body
            }
        }
    }};
}

/// The fixed lane-reduction tree — part of the repo-wide canonical sum
/// order, so it must never change shape.
#[inline(always)]
fn reduce_sum<T: Lane>(acc: [T; LANES]) -> T {
    (acc[0].add(acc[4]).add(acc[1].add(acc[5]))).add(acc[2].add(acc[6]).add(acc[3].add(acc[7])))
}

/// The canonical chunked sum over a contiguous value slice: full
/// [`LANES`] chunks accumulate lane-wise (`Lane::add_lanes`), the
/// remainder lands in lanes `0..rem` of a zero-padded tail, lanes
/// reduce via [`reduce_sum`].  Every sum in the system that feeds a
/// `Combine::Sum` kernel reduces through this exact scheme (directly,
/// or element-for-element in the fused gather loops of [`fold_csr`]).
#[inline]
pub(crate) fn chunked_sum<T: Lane>(vals: &[T]) -> T {
    let mut acc = [T::ZERO; LANES];
    let mut chunks = vals.chunks_exact(LANES);
    for c in &mut chunks {
        let c: &[T; LANES] = c.try_into().expect("chunks_exact yields LANES");
        T::add_lanes(&mut acc, c);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tail = [T::ZERO; LANES];
        tail[..rem.len()].copy_from_slice(rem);
        T::add_lanes(&mut acc, &tail);
    }
    reduce_sum(acc)
}

/// The paper's `Update` loop over one shard's CSR rows, monomorphized
/// and chunk-vectorized.  `out` must enter holding the current values of
/// rows `[start_vertex, start_vertex + out.len())`, in the kernel's lane
/// type.
pub fn fold_csr(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start_vertex: u32, out: LaneSliceMut<'_>) {
    with_lane!(ctx.kernel.lane, T => fold_csr_t::<T>(ctx, csr, start_vertex, T::of_mut(out)))
}

fn fold_csr_t<T: Lane>(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start_vertex: u32, out: &mut [T]) {
    debug_assert_eq!(out.len(), csr.rows());
    match ctx.kernel.combine {
        Combine::Sum => with_gather!(ctx, T, g => sum_csr(ctx, g, csr, start_vertex, out)),
        Combine::Min => {
            with_gather!(ctx, T, g => meet_csr(g, |a: T, b: T| a.meet_min(b), csr, out))
        }
        Combine::Max => {
            with_gather!(ctx, T, g => meet_csr(g, |a: T, b: T| a.meet_max(b), csr, out))
        }
    }
}

/// One row's chunked sum with the gather fused into the chunk loop:
/// element-for-element the same adds as `chunked_sum` over the gathered
/// values (the gather itself is exact per edge).
#[inline]
fn sum_row_weighted<T: Lane, G: Fn(u32, f32) -> T>(g: &G, col: &[u32], ws: &[f32]) -> T {
    let mut acc = [T::ZERO; LANES];
    let mut vals = [T::ZERO; LANES];
    let mut cc = col.chunks_exact(LANES);
    let mut cw = ws.chunks_exact(LANES);
    for (c, w) in (&mut cc).zip(&mut cw) {
        for j in 0..LANES {
            vals[j] = g(c[j], w[j]);
        }
        T::add_lanes(&mut acc, &vals);
    }
    let rc = cc.remainder();
    if !rc.is_empty() {
        let mut tail = [T::ZERO; LANES];
        for (j, (&u, &w)) in rc.iter().zip(cw.remainder()).enumerate() {
            tail[j] = g(u, w);
        }
        T::add_lanes(&mut acc, &tail);
    }
    reduce_sum(acc)
}

#[inline]
fn sum_row_unweighted<T: Lane, G: Fn(u32, f32) -> T>(g: &G, col: &[u32]) -> T {
    let mut acc = [T::ZERO; LANES];
    let mut vals = [T::ZERO; LANES];
    let mut cc = col.chunks_exact(LANES);
    for c in &mut cc {
        for j in 0..LANES {
            vals[j] = g(c[j], 1.0);
        }
        T::add_lanes(&mut acc, &vals);
    }
    let rc = cc.remainder();
    if !rc.is_empty() {
        let mut tail = [T::ZERO; LANES];
        for (j, &u) in rc.iter().enumerate() {
            tail[j] = g(u, 1.0);
        }
        T::add_lanes(&mut acc, &tail);
    }
    reduce_sum(acc)
}

fn sum_csr<T: Lane, G: Fn(u32, f32) -> T>(
    ctx: &IterCtx<'_>,
    g: G,
    csr: CsrRef<'_>,
    start_vertex: u32,
    out: &mut [T],
) {
    let kernel = ctx.kernel;
    let src = T::of_slice(ctx.src);
    let ro = csr.row_offsets;
    match csr.weights {
        Some(ws) => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let sum = sum_row_weighted(&g, &csr.col[lo..hi], &ws[lo..hi]);
                let v = start_vertex + r as u32;
                *o = kernel.apply_t(v, ctx.num_vertices, src[v as usize], sum);
            }
        }
        None => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let sum = sum_row_unweighted(&g, &csr.col[lo..hi]);
                let v = start_vertex + r as u32;
                *o = kernel.apply_t(v, ctx.num_vertices, src[v as usize], sum);
            }
        }
    }
}

/// Chunked meets.  Every lane starts at the row's current value
/// (`min`/`max` are idempotent, so the extra copies are identities),
/// the remainder folds into lane 0, and the lanes reduce with the same
/// meet — for NaN-free, signed-zero-free values (all app value domains
/// here; integer meets trivially qualify) the result is the multiset
/// extremum, bit-identical to the sequential fold regardless of
/// association.  No `simd` variant: the scalar lane loop
/// autovectorizes, and one code path keeps the bit-identity argument
/// trivial.
fn meet_csr<T, G, C>(g: G, cb: C, csr: CsrRef<'_>, out: &mut [T])
where
    T: Lane,
    G: Fn(u32, f32) -> T,
    C: Fn(T, T) -> T,
{
    let ro = csr.row_offsets;
    match csr.weights {
        Some(ws) => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let cur = *o; // current value (== src of this row)
                let mut acc = [cur; LANES];
                let mut vals = [T::ZERO; LANES];
                let mut cc = csr.col[lo..hi].chunks_exact(LANES);
                let mut cw = ws[lo..hi].chunks_exact(LANES);
                for (c, w) in (&mut cc).zip(&mut cw) {
                    for j in 0..LANES {
                        vals[j] = g(c[j], w[j]);
                    }
                    for j in 0..LANES {
                        acc[j] = cb(acc[j], vals[j]);
                    }
                }
                for (&u, &w) in cc.remainder().iter().zip(cw.remainder()) {
                    acc[0] = cb(acc[0], g(u, w));
                }
                *o = reduce_meet(&cb, acc);
            }
        }
        None => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let cur = *o;
                let mut acc = [cur; LANES];
                let mut vals = [T::ZERO; LANES];
                let mut cc = csr.col[lo..hi].chunks_exact(LANES);
                for c in &mut cc {
                    for j in 0..LANES {
                        vals[j] = g(c[j], 1.0);
                    }
                    for j in 0..LANES {
                        acc[j] = cb(acc[j], vals[j]);
                    }
                }
                for &u in cc.remainder() {
                    acc[0] = cb(acc[0], g(u, 1.0));
                }
                *o = reduce_meet(&cb, acc);
            }
        }
    }
}

#[inline(always)]
fn reduce_meet<T: Lane, C: Fn(T, T) -> T>(cb: &C, acc: [T; LANES]) -> T {
    cb(
        cb(cb(acc[0], acc[4]), cb(acc[1], acc[5])),
        cb(cb(acc[2], acc[6]), cb(acc[3], acc[7])),
    )
}

/// Destination-grouped edge-list fold (PSW intervals, DSW grid columns,
/// the toy sources).  `out` covers rows `[lo, lo + out.len())` and
/// enters holding their current values.  `vals`/`idx` are the caller's
/// reusable 64-byte-aligned scratch arenas (reset here, allocated at
/// most once per worker lifetime): sums counting-sort the gathered edge
/// values by destination row into `vals` (cursor offsets in `idx`),
/// then run the canonical [`chunked_sum`] per row — **bit-identical**
/// to [`fold_csr`] over the same per-destination edge order
/// (canonically ascending source id), which the kernel tests assert
/// with `==`.
pub fn fold_list(
    ctx: &IterCtx<'_>,
    edges: &[Edge],
    lo: u32,
    out: LaneSliceMut<'_>,
    vals: &mut AlignedArena,
    idx: &mut AlignedArena,
) {
    with_lane!(ctx.kernel.lane, T => fold_list_t::<T>(ctx, edges, lo, T::of_mut(out), vals, idx))
}

fn fold_list_t<T: Lane>(
    ctx: &IterCtx<'_>,
    edges: &[Edge],
    lo: u32,
    out: &mut [T],
    vals: &mut AlignedArena,
    idx: &mut AlignedArena,
) {
    let kernel = ctx.kernel;
    match kernel.combine {
        Combine::Sum => {
            let nr = out.len();
            let src = T::of_slice(ctx.src);
            // counting sort by destination row: count (offset by one) …
            let idx = idx.u32s(nr + 1);
            debug_assert_eq!(idx.as_ptr() as usize % 64, 0, "fold scratch must be 64B-aligned");
            for e in edges {
                idx[(e.dst - lo) as usize + 1] += 1;
            }
            // … exclusive prefix sum: idx[r] = start of row r …
            for r in 0..nr {
                idx[r + 1] += idx[r];
            }
            // … then fill, advancing idx[r] to the end of row r.  The
            // fill is in edge order, so each row keeps the caller's
            // per-destination order (canonical ascending source).
            let vals = T::arena_slice(vals, edges.len());
            debug_assert_eq!(vals.as_ptr() as usize % 64, 0, "fold scratch must be 64B-aligned");
            with_gather!(ctx, T, g => {
                for e in edges {
                    let r = (e.dst - lo) as usize;
                    vals[idx[r] as usize] = g(e.src, e.weight);
                    idx[r] += 1;
                }
            });
            for (r, o) in out.iter_mut().enumerate() {
                let start = if r == 0 { 0 } else { idx[r - 1] as usize };
                let sum = chunked_sum(&vals[start..idx[r] as usize]);
                let v = lo + r as u32;
                *o = kernel.apply_t(v, ctx.num_vertices, src[v as usize], sum);
            }
        }
        Combine::Min => {
            with_gather!(ctx, T, g => meet_list(g, |a: T, b: T| a.meet_min(b), edges, lo, out))
        }
        Combine::Max => {
            with_gather!(ctx, T, g => meet_list(g, |a: T, b: T| a.meet_max(b), edges, lo, out))
        }
    }
}

/// Sequential meet over a destination-grouped list.  Destinations
/// interleave, so there is no per-row chunk to vectorize; order
/// insensitivity of NaN-free meets keeps this bit-identical to the
/// chunked [`fold_csr`] meets.
fn meet_list<T, G, C>(g: G, cb: C, edges: &[Edge], lo: u32, out: &mut [T])
where
    T: Lane,
    G: Fn(u32, f32) -> T,
    C: Fn(T, T) -> T,
{
    for e in edges {
        let r = (e.dst - lo) as usize;
        out[r] = cb(out[r], g(e.src, e.weight));
    }
}

/// Scatter one unit's edges into deferred updates (X-Stream's scatter
/// phase), monomorphized and gathered in [`LANES`] blocks; `out` is the
/// caller's reusable buffer.  Per-edge values are exact (no combine
/// happens here — the barrier's `fold_updates` runs the chunked sum);
/// each update carries the value's raw bits, typed back out by the
/// barrier via `Update::val::<T>()`.
pub fn scatter_list(ctx: &IterCtx<'_>, edges: &[Edge], out: &mut Vec<Update>) {
    with_lane!(ctx.kernel.lane, T => scatter_list_t::<T>(ctx, edges, out))
}

fn scatter_list_t<T: Lane>(ctx: &IterCtx<'_>, edges: &[Edge], out: &mut Vec<Update>) {
    out.reserve(edges.len());
    with_gather!(ctx, T, g => {
        let mut chunks = edges.chunks_exact(LANES);
        let mut vals = [T::ZERO; LANES];
        for c in &mut chunks {
            for j in 0..LANES {
                vals[j] = g(c[j].src, c[j].weight);
            }
            for j in 0..LANES {
                out.push(Update::new(c[j].dst, vals[j]));
            }
        }
        for e in chunks.remainder() {
            out.push(Update::new(e.dst, g(e.src, e.weight)));
        }
    });
}

/// The sequential monomorphized fold — the pre-vectorization [`fold_csr`]
/// body, kept verbatim as the scalar oracle and bench baseline.
/// Bit-identical to [`reference_fold_csr`] for every combine; the
/// chunked [`fold_csr`] matches it exactly for min/max and integer
/// lanes, and within a documented epsilon for f32 sums (reassociation).
/// Not part of the public API.
#[doc(hidden)]
pub fn scalar_fold_csr(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start_vertex: u32, out: LaneSliceMut<'_>) {
    with_lane!(ctx.kernel.lane, T => scalar_fold_csr_t::<T>(ctx, csr, start_vertex, T::of_mut(out)))
}

fn scalar_fold_csr_t<T: Lane>(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start_vertex: u32, out: &mut [T]) {
    debug_assert_eq!(out.len(), csr.rows());
    match ctx.kernel.combine {
        Combine::Sum => {
            with_gather!(ctx, T, g => scalar_sum_csr(ctx, g, csr, start_vertex, out))
        }
        Combine::Min => {
            with_gather!(ctx, T, g => scalar_meet_csr(g, |a: T, b: T| a.meet_min(b), csr, out))
        }
        Combine::Max => {
            with_gather!(ctx, T, g => scalar_meet_csr(g, |a: T, b: T| a.meet_max(b), csr, out))
        }
    }
}

fn scalar_sum_csr<T: Lane, G: Fn(u32, f32) -> T>(
    ctx: &IterCtx<'_>,
    g: G,
    csr: CsrRef<'_>,
    start_vertex: u32,
    out: &mut [T],
) {
    let kernel = ctx.kernel;
    let src = T::of_slice(ctx.src);
    let ro = csr.row_offsets;
    match csr.weights {
        Some(ws) => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut sum = T::ZERO;
                for (&u, &w) in csr.col[lo..hi].iter().zip(&ws[lo..hi]) {
                    sum = sum.add(g(u, w));
                }
                let v = start_vertex + r as u32;
                *o = kernel.apply_t(v, ctx.num_vertices, src[v as usize], sum);
            }
        }
        None => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut sum = T::ZERO;
                for &u in &csr.col[lo..hi] {
                    sum = sum.add(g(u, 1.0));
                }
                let v = start_vertex + r as u32;
                *o = kernel.apply_t(v, ctx.num_vertices, src[v as usize], sum);
            }
        }
    }
}

fn scalar_meet_csr<T, G, C>(g: G, cb: C, csr: CsrRef<'_>, out: &mut [T])
where
    T: Lane,
    G: Fn(u32, f32) -> T,
    C: Fn(T, T) -> T,
{
    let ro = csr.row_offsets;
    match csr.weights {
        Some(ws) => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut m = *o; // current value (== src of this row)
                for (&u, &w) in csr.col[lo..hi].iter().zip(&ws[lo..hi]) {
                    m = cb(m, g(u, w));
                }
                *o = m;
            }
        }
        None => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut m = *o;
                for &u in &csr.col[lo..hi] {
                    m = cb(m, g(u, 1.0));
                }
                *o = m;
            }
        }
    }
}

/// The pre-monomorphization fold: per-edge enum dispatch through the
/// [`crate::apps::ShardKernel`] methods (`uses_contrib` branch + gather
/// `match` per edge), in the exact shape of the old `native_update`.
/// Kept as the enum-dispatch oracle — bit-identical to
/// [`scalar_fold_csr`], epsilon-compared to the chunked [`fold_csr`]
/// for f32 sums — and measured by `benches/hot_loop.rs` as the dispatch
/// baseline.  Not part of the public API.
#[doc(hidden)]
pub fn reference_fold_csr(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start: u32, out: LaneSliceMut<'_>) {
    with_lane!(ctx.kernel.lane, T => reference_fold_csr_t::<T>(ctx, csr, start, T::of_mut(out)))
}

fn reference_fold_csr_t<T: Lane>(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start: u32, out: &mut [T]) {
    let kernel = ctx.kernel;
    let src = T::of_slice(ctx.src);
    let ro = csr.row_offsets;
    for r in 0..out.len() {
        let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
        match kernel.combine {
            Combine::Sum => {
                let mut sum = T::ZERO;
                for i in lo..hi {
                    let u = csr.col[i];
                    let w = csr.weights.map_or(1.0, |ws| ws[i]);
                    sum = sum.add(if kernel.uses_contrib() {
                        T::from_mass(ctx.contrib[u as usize])
                    } else {
                        kernel.edge_value_t(src[u as usize], 0.0, w)
                    });
                }
                let v = start + r as u32;
                out[r] = kernel.apply_t(v, ctx.num_vertices, src[v as usize], sum);
            }
            Combine::Min | Combine::Max => {
                let mut m = out[r]; // current value (== src of this row)
                for i in lo..hi {
                    let u = csr.col[i];
                    let w = csr.weights.map_or(1.0, |ws| ws[i]);
                    m = kernel.combine_t(m, kernel.edge_value_t(src[u as usize], 0.0, w));
                }
                out[r] = m;
            }
        }
    }
}

/// Activation marking for rows `[lo, lo + out.len())`, with the
/// activation predicate dispatched once per unit instead of per row.
pub fn mark_rows(ctx: &IterCtx<'_>, lo: u32, out: LaneSlice<'_>, marker: &mut RangeMarker<'_>) {
    with_lane!(ctx.kernel.lane, T => mark_rows_t::<T>(ctx, lo, T::of_slice(out), marker))
}

fn mark_rows_t<T: Lane>(ctx: &IterCtx<'_>, lo: u32, out: &[T], marker: &mut RangeMarker<'_>) {
    match ctx.kernel.combine {
        Combine::Sum => mark_if(|old: T, new: T| old != new, ctx, lo, out, marker),
        Combine::Min => mark_if(|old: T, new: T| new < old, ctx, lo, out, marker),
        Combine::Max => mark_if(|old: T, new: T| new > old, ctx, lo, out, marker),
    }
}

fn mark_if<T: Lane, F: Fn(T, T) -> bool>(
    activates: F,
    ctx: &IterCtx<'_>,
    lo: u32,
    out: &[T],
    marker: &mut RangeMarker<'_>,
) {
    let src = T::of_slice(ctx.src);
    for (r, &new) in out.iter().enumerate() {
        let v = lo + r as u32;
        if activates(src[v as usize], new) {
            marker.mark(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{ShardKernel, VertexProgram};
    use crate::exec::lane::LaneType;
    use crate::graph::Csr;

    fn all_kernels() -> Vec<ShardKernel> {
        vec![
            crate::apps::PageRank::new().kernel(),
            crate::apps::Ppr::new(2).kernel(),
            crate::apps::Sssp::new(0).kernel(),
            crate::apps::Bfs::new(0).kernel(),
            crate::apps::Cc.kernel(),
            crate::apps::Widest::new(0).kernel(),
        ]
    }

    fn fixture(n: u32, seed: u64) -> (Vec<Edge>, Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        let mut edges = Vec::new();
        for _ in 0..(n as usize * 4) {
            edges.push(Edge::weighted(
                rng.next_below(n as u64) as u32,
                rng.next_below(n as u64) as u32,
                rng.next_range_f32(0.1, 9.0),
            ));
        }
        edges.sort_unstable_by_key(|e| (e.dst, e.src));
        let src: Vec<f32> = (0..n).map(|v| 0.25 + (v % 7) as f32).collect();
        let inv: Vec<f32> = (0..n).map(|v| 1.0 / (1.0 + (v % 5) as f32)).collect();
        (edges, src, inv)
    }

    /// The documented sum gate: reassociation of a k-edge row perturbs
    /// the last few ulps, so chunked-vs-sequential sum comparisons use
    /// a small relative epsilon.  Everything else stays `==`.
    fn assert_sum_close(a: &[f32], b: &[f32], what: &str) {
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-5 * x.abs().max(1.0),
                "{what}: vertex {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn monomorphized_folds_match_enum_dispatch_bitwise() {
        let n = 64u32;
        let (edges, src, inv) = fixture(n, 99);
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let csr = Csr::from_edges(&edges, 0, n as usize, true);
        for kernel in all_kernels() {
            let ctx = IterCtx {
                kernel,
                num_vertices: n,
                src: (&src).into(),
                inv_out_deg: &inv,
                contrib: &contrib,
                iteration: 0,
            };
            // the sequential monomorphized path is bit-identical to the
            // per-edge enum-dispatch oracle, for every combine
            let mut s = src.clone();
            let mut b = src.clone();
            scalar_fold_csr(&ctx, csr.slices(), 0, (&mut s).into());
            reference_fold_csr(&ctx, csr.slices(), 0, (&mut b).into());
            assert_eq!(s, b, "scalar_fold_csr diverged for {kernel:?}");

            // the chunked fold: bit-identical for min/max, epsilon for
            // sums (documented reassociation)
            let mut a = src.clone();
            fold_csr(&ctx, csr.slices(), 0, (&mut a).into());
            match kernel.combine {
                Combine::Sum => assert_sum_close(&a, &s, "fold_csr (sum)"),
                Combine::Min | Combine::Max => {
                    assert_eq!(a, s, "fold_csr meet diverged for {kernel:?}")
                }
            }

            // the list fold over the same destination-grouped order is
            // bit-identical to the chunked CSR fold — same chunked
            // scheme, same per-row value order
            let mut c = src.clone();
            let (mut vals, mut idx) = (AlignedArena::new(), AlignedArena::new());
            fold_list(&ctx, &edges, 0, (&mut c).into(), &mut vals, &mut idx);
            assert_eq!(c, a, "fold_list diverged for {kernel:?}");

            // scatter gathers the same per-edge values, exactly
            let mut ups = Vec::new();
            scatter_list(&ctx, &edges, &mut ups);
            assert_eq!(ups.len(), edges.len());
            for (e, u) in edges.iter().zip(&ups) {
                assert_eq!(u.dst, e.dst);
                assert_eq!(
                    u.val::<f32>(),
                    ctx.edge_value::<f32>(e),
                    "scatter diverged for {kernel:?}"
                );
            }
        }
    }

    #[test]
    fn integer_folds_are_bitwise_across_all_paths() {
        // the u32 relax-min (BFS levels) and the u32 indicator sum
        // (k-core) must agree across chunked/scalar/reference/list
        // paths with `==` — integer combines have no epsilon carve-out
        let n = 40u32;
        let (edges, _, inv) = fixture(n, 11);
        let contrib = vec![0.0f32; n as usize];
        let csr = Csr::from_edges(&edges, 0, n as usize, true);
        let cases: Vec<(ShardKernel, Vec<u32>)> = vec![
            (
                crate::apps::BfsLevels::new(0).kernel(),
                (0..n).map(|v| if v % 3 == 0 { v } else { u32::MAX }).collect(),
            ),
            (ShardKernel::kcore(2), (0..n).map(|v| u32::from(v % 4 != 1)).collect()),
            (
                ShardKernel::relax_min(EdgeCost::Zero).with_lane(LaneType::U32),
                (0..n).collect(),
            ),
        ];
        for (kernel, src) in cases {
            let ctx = IterCtx {
                kernel,
                num_vertices: n,
                src: LaneSlice::U32(&src),
                inv_out_deg: &inv,
                contrib: &contrib,
                iteration: 0,
            };
            let mut a = src.clone();
            let mut s = src.clone();
            let mut b = src.clone();
            fold_csr(&ctx, csr.slices(), 0, LaneSliceMut::U32(&mut a));
            scalar_fold_csr(&ctx, csr.slices(), 0, LaneSliceMut::U32(&mut s));
            reference_fold_csr(&ctx, csr.slices(), 0, LaneSliceMut::U32(&mut b));
            assert_eq!(a, s, "chunked vs scalar diverged for {kernel:?}");
            assert_eq!(s, b, "scalar vs reference diverged for {kernel:?}");
            let mut l = src.clone();
            let (mut vals, mut idx) = (AlignedArena::new(), AlignedArena::new());
            fold_list(&ctx, &edges, 0, LaneSliceMut::U32(&mut l), &mut vals, &mut idx);
            assert_eq!(l, a, "fold_list diverged for {kernel:?}");
            let mut ups = Vec::new();
            scatter_list(&ctx, &edges, &mut ups);
            for (e, u) in edges.iter().zip(&ups) {
                assert_eq!(u.val::<u32>(), ctx.edge_value::<u32>(e));
            }
        }
    }

    #[test]
    fn short_rows_sum_exactly_like_the_scalar_path() {
        // rows with ≤ 3 in-edges take the zero-padded tail block whose
        // reduction tree degenerates to the sequential order — chunked
        // sums of such rows are bit-identical to the scalar oracle
        let n = 8u32;
        let mut edges = Vec::new();
        for r in 0..n {
            for k in 0..(r % 4) {
                edges.push(Edge::weighted((r + k + 1) % n, r, 0.3 + k as f32));
            }
        }
        edges.sort_unstable_by_key(|e| (e.dst, e.src));
        let src: Vec<f32> = (0..n).map(|v| 0.25 + (v % 7) as f32).collect();
        let inv: Vec<f32> = (0..n).map(|v| 1.0 / (1.0 + (v % 5) as f32)).collect();
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let csr = Csr::from_edges(&edges, 0, n as usize, true);
        for kernel in all_kernels() {
            let ctx = IterCtx {
                kernel,
                num_vertices: n,
                src: (&src).into(),
                inv_out_deg: &inv,
                contrib: &contrib,
                iteration: 0,
            };
            let mut a = src.clone();
            let mut s = src.clone();
            fold_csr(&ctx, csr.slices(), 0, (&mut a).into());
            scalar_fold_csr(&ctx, csr.slices(), 0, (&mut s).into());
            assert_eq!(a, s, "short rows must be exact for {kernel:?}");
        }
    }

    #[test]
    fn unweighted_csr_defaults_to_unit_weight() {
        let n = 16u32;
        let (edges, src, inv) = fixture(n, 7);
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let csr = Csr::from_edges(&edges, 0, n as usize, false);
        for kernel in [
            crate::apps::Bfs::new(0).kernel(),
            crate::apps::Cc.kernel(),
            crate::apps::PageRank::new().kernel(),
        ] {
            let ctx = IterCtx {
                kernel,
                num_vertices: n,
                src: (&src).into(),
                inv_out_deg: &inv,
                contrib: &contrib,
                iteration: 0,
            };
            let mut a = src.clone();
            let mut b = src.clone();
            fold_csr(&ctx, csr.slices(), 0, (&mut a).into());
            reference_fold_csr(&ctx, csr.slices(), 0, (&mut b).into());
            match kernel.combine {
                Combine::Sum => assert_sum_close(&a, &b, "unweighted fold (sum)"),
                Combine::Min | Combine::Max => {
                    assert_eq!(a, b, "unweighted fold diverged for {kernel:?}")
                }
            }
        }
    }

    #[test]
    fn fold_list_reuses_the_scratch_arenas() {
        let n = 8u32;
        let (edges, src, inv) = fixture(n, 3);
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let ctx = IterCtx {
            kernel: crate::apps::PageRank::new().kernel(),
            num_vertices: n,
            src: (&src).into(),
            inv_out_deg: &inv,
            contrib: &contrib,
            iteration: 0,
        };
        let (mut vals, mut idx) = (AlignedArena::new(), AlignedArena::new());
        let mut out1 = src.clone();
        fold_list(&ctx, &edges, 0, (&mut out1).into(), &mut vals, &mut idx);
        let (cv, ci) = (vals.capacity_bytes(), idx.capacity_bytes());
        assert!(cv >= edges.len() * 4);
        let mut out2 = src.clone();
        fold_list(&ctx, &edges, 0, (&mut out2).into(), &mut vals, &mut idx);
        assert_eq!(vals.capacity_bytes(), cv, "second fold must not reallocate");
        assert_eq!(idx.capacity_bytes(), ci, "second fold must not reallocate");
        assert_eq!(out1, out2);
    }
}
