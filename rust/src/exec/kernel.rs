//! Monomorphized kernel hot loops.
//!
//! [`ShardKernel`](crate::apps::ShardKernel) is a runtime value, so
//! folding edges through its enum methods pays a gather `match` (and,
//! via `IterCtx::edge_value`, a `uses_contrib` branch) **per edge**.
//! GridGraph's edge loop wins by being branch-free; this module gets the
//! same shape by dispatching the (combine × gather) pair **once per
//! unit**: the `with_gather!` macro maps the runtime kernel onto a closure whose
//! type monomorphizes the generic fold bodies, so the inner loops compile
//! to straight-line arithmetic.
//!
//! Every specialized instance performs the *same f32 operations in the
//! same order* as the enum-dispatch reference (`ShardKernel::combine` /
//! `edge_value` / `apply`), so results stay bit-identical — gated by
//! `rust/tests/determinism.rs` and `rust/tests/cross_engine.rs`, and
//! cross-checked against an enum-dispatch fold in `benches/hot_loop.rs`.
//!
//! Three fold shapes cover every engine:
//!
//! - [`fold_csr`] — CSR rows (VSW shards, the in-memory engine);
//! - [`fold_list`] — destination-grouped edge lists (PSW intervals, DSW
//!   grid columns), with the caller's reusable sum-accumulator arena;
//! - [`scatter_list`] — X-Stream-style update streams (ESG), into the
//!   caller's reusable buffer.

use super::{IterCtx, Update};
use crate::apps::{Combine, EdgeCost, EdgeGather};
use crate::exec::schedule::RangeMarker;
use crate::graph::{CsrRef, Edge};

/// Bind `$g` to a gather closure specialized for `$ctx.kernel.gather`
/// and evaluate `$body` once per variant — the single dispatch point
/// that keeps the edge loops branch-free.  Each closure mirrors
/// `ShardKernel::edge_value` (with `DegreeMass` reading the pre-folded
/// `contrib` array, as `IterCtx::edge_value` does) bit-for-bit.
macro_rules! with_gather {
    ($ctx:expr, $g:ident => $body:expr) => {{
        let src = $ctx.src;
        let contrib = $ctx.contrib;
        match $ctx.kernel.gather {
            EdgeGather::DegreeMass => {
                let $g = |u: u32, _w: f32| contrib[u as usize];
                $body
            }
            EdgeGather::AddCost(EdgeCost::Weights) => {
                let $g = |u: u32, w: f32| src[u as usize] + w;
                $body
            }
            EdgeGather::AddCost(EdgeCost::Unit) => {
                let $g = |u: u32, _w: f32| src[u as usize] + 1.0;
                $body
            }
            EdgeGather::AddCost(EdgeCost::Zero) => {
                let $g = |u: u32, _w: f32| src[u as usize] + 0.0;
                $body
            }
            EdgeGather::MinCapacity(EdgeCost::Weights) => {
                let $g = |u: u32, w: f32| src[u as usize].min(w);
                $body
            }
            EdgeGather::MinCapacity(EdgeCost::Unit) => {
                let $g = |u: u32, _w: f32| src[u as usize].min(1.0);
                $body
            }
            EdgeGather::MinCapacity(EdgeCost::Zero) => {
                let $g = |u: u32, _w: f32| src[u as usize].min(0.0);
                $body
            }
        }
    }};
}

/// The paper's `Update` loop over one shard's CSR rows, monomorphized.
/// `out` must enter holding the current values of rows
/// `[start_vertex, start_vertex + out.len())`.
pub fn fold_csr(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start_vertex: u32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), csr.rows());
    match ctx.kernel.combine {
        Combine::Sum => with_gather!(ctx, g => sum_csr(ctx, g, csr, start_vertex, out)),
        Combine::Min => {
            with_gather!(ctx, g => meet_csr(g, |a: f32, b: f32| a.min(b), csr, out))
        }
        Combine::Max => {
            with_gather!(ctx, g => meet_csr(g, |a: f32, b: f32| a.max(b), csr, out))
        }
    }
}

fn sum_csr<G: Fn(u32, f32) -> f32>(
    ctx: &IterCtx<'_>,
    g: G,
    csr: CsrRef<'_>,
    start_vertex: u32,
    out: &mut [f32],
) {
    let kernel = ctx.kernel;
    let ro = csr.row_offsets;
    match csr.weights {
        Some(ws) => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut sum = 0.0f32;
                for (&u, &w) in csr.col[lo..hi].iter().zip(&ws[lo..hi]) {
                    sum += g(u, w);
                }
                let v = start_vertex + r as u32;
                *o = kernel.apply(v, ctx.num_vertices, ctx.src[v as usize], sum);
            }
        }
        None => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut sum = 0.0f32;
                for &u in &csr.col[lo..hi] {
                    sum += g(u, 1.0);
                }
                let v = start_vertex + r as u32;
                *o = kernel.apply(v, ctx.num_vertices, ctx.src[v as usize], sum);
            }
        }
    }
}

fn meet_csr<G, C>(g: G, cb: C, csr: CsrRef<'_>, out: &mut [f32])
where
    G: Fn(u32, f32) -> f32,
    C: Fn(f32, f32) -> f32,
{
    let ro = csr.row_offsets;
    match csr.weights {
        Some(ws) => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut m = *o; // current value (== src of this row)
                for (&u, &w) in csr.col[lo..hi].iter().zip(&ws[lo..hi]) {
                    m = cb(m, g(u, w));
                }
                *o = m;
            }
        }
        None => {
            for (r, o) in out.iter_mut().enumerate() {
                let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
                let mut m = *o;
                for &u in &csr.col[lo..hi] {
                    m = cb(m, g(u, 1.0));
                }
                *o = m;
            }
        }
    }
}

/// Destination-grouped edge-list fold (PSW intervals, DSW grid columns,
/// the toy sources).  `out` covers rows `[lo, lo + out.len())` and enters
/// holding their current values; `acc` is the caller's reusable
/// sum-accumulator arena (cleared and resized here, allocated at most
/// once per worker lifetime).  Bit-identical to [`fold_csr`] over the
/// same per-destination edge order — canonically ascending source id.
pub fn fold_list(
    ctx: &IterCtx<'_>,
    edges: &[Edge],
    lo: u32,
    out: &mut [f32],
    acc: &mut Vec<f32>,
) {
    let kernel = ctx.kernel;
    match kernel.combine {
        Combine::Sum => {
            // fold into per-row accumulators first, then apply: rows with
            // no in-edges still get their base mass
            acc.clear();
            acc.resize(out.len(), 0.0);
            with_gather!(ctx, g => {
                for e in edges {
                    acc[(e.dst - lo) as usize] += g(e.src, e.weight);
                }
            });
            for (r, (o, a)) in out.iter_mut().zip(acc.iter()).enumerate() {
                let v = lo + r as u32;
                *o = kernel.apply(v, ctx.num_vertices, ctx.src[v as usize], *a);
            }
        }
        Combine::Min => {
            with_gather!(ctx, g => meet_list(g, |a: f32, b: f32| a.min(b), edges, lo, out))
        }
        Combine::Max => {
            with_gather!(ctx, g => meet_list(g, |a: f32, b: f32| a.max(b), edges, lo, out))
        }
    }
}

fn meet_list<G, C>(g: G, cb: C, edges: &[Edge], lo: u32, out: &mut [f32])
where
    G: Fn(u32, f32) -> f32,
    C: Fn(f32, f32) -> f32,
{
    for e in edges {
        let r = (e.dst - lo) as usize;
        out[r] = cb(out[r], g(e.src, e.weight));
    }
}

/// Scatter one unit's edges into deferred updates (X-Stream's scatter
/// phase), monomorphized; `out` is the caller's reusable buffer.
pub fn scatter_list(ctx: &IterCtx<'_>, edges: &[Edge], out: &mut Vec<Update>) {
    out.reserve(edges.len());
    with_gather!(ctx, g => {
        for e in edges {
            out.push(Update { dst: e.dst, val: g(e.src, e.weight) });
        }
    });
}

/// The pre-monomorphization fold: per-edge enum dispatch through the
/// [`crate::apps::ShardKernel`] methods (`uses_contrib` branch + gather
/// `match` per edge), in the exact shape of the old `native_update`.
/// Kept as the single bit-identity oracle — the kernel unit tests assert
/// against it and `benches/hot_loop.rs` measures it as the baseline.
/// Not part of the public API.
#[doc(hidden)]
pub fn reference_fold_csr(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start: u32, out: &mut [f32]) {
    let kernel = ctx.kernel;
    let ro = csr.row_offsets;
    for r in 0..out.len() {
        let (lo, hi) = (ro[r] as usize, ro[r + 1] as usize);
        match kernel.combine {
            Combine::Sum => {
                let mut sum = 0.0f32;
                for i in lo..hi {
                    let u = csr.col[i];
                    let w = csr.weights.map_or(1.0, |ws| ws[i]);
                    sum += if kernel.uses_contrib() {
                        ctx.contrib[u as usize]
                    } else {
                        kernel.edge_value(ctx.src[u as usize], 0.0, w)
                    };
                }
                let v = start + r as u32;
                out[r] = kernel.apply(v, ctx.num_vertices, ctx.src[v as usize], sum);
            }
            Combine::Min | Combine::Max => {
                let mut m = out[r]; // current value (== src of this row)
                for i in lo..hi {
                    let u = csr.col[i];
                    let w = csr.weights.map_or(1.0, |ws| ws[i]);
                    m = kernel.combine(m, kernel.edge_value(ctx.src[u as usize], 0.0, w));
                }
                out[r] = m;
            }
        }
    }
}

/// Activation marking for rows `[lo, lo + out.len())`, with the
/// activation predicate dispatched once per unit instead of per row.
pub fn mark_rows(ctx: &IterCtx<'_>, lo: u32, out: &[f32], marker: &mut RangeMarker<'_>) {
    match ctx.kernel.combine {
        Combine::Sum => mark_if(|old, new| old != new, ctx, lo, out, marker),
        Combine::Min => mark_if(|old, new| new < old, ctx, lo, out, marker),
        Combine::Max => mark_if(|old, new| new > old, ctx, lo, out, marker),
    }
}

fn mark_if<F: Fn(f32, f32) -> bool>(
    activates: F,
    ctx: &IterCtx<'_>,
    lo: u32,
    out: &[f32],
    marker: &mut RangeMarker<'_>,
) {
    for (r, &new) in out.iter().enumerate() {
        let v = lo + r as u32;
        if activates(ctx.src[v as usize], new) {
            marker.mark(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{ShardKernel, VertexProgram};
    use crate::graph::Csr;

    fn all_kernels() -> Vec<ShardKernel> {
        vec![
            crate::apps::PageRank::new().kernel(),
            crate::apps::Ppr::new(2).kernel(),
            crate::apps::Sssp::new(0).kernel(),
            crate::apps::Bfs::new(0).kernel(),
            crate::apps::Cc.kernel(),
            crate::apps::Widest::new(0).kernel(),
        ]
    }

    fn fixture(n: u32, seed: u64) -> (Vec<Edge>, Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        let mut edges = Vec::new();
        for _ in 0..(n as usize * 4) {
            edges.push(Edge::weighted(
                rng.next_below(n as u64) as u32,
                rng.next_below(n as u64) as u32,
                rng.next_range_f32(0.1, 9.0),
            ));
        }
        edges.sort_unstable_by_key(|e| (e.dst, e.src));
        let src: Vec<f32> = (0..n).map(|v| 0.25 + (v % 7) as f32).collect();
        let inv: Vec<f32> = (0..n).map(|v| 1.0 / (1.0 + (v % 5) as f32)).collect();
        (edges, src, inv)
    }

    #[test]
    fn monomorphized_folds_match_enum_dispatch_bitwise() {
        let n = 64u32;
        let (edges, src, inv) = fixture(n, 99);
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let csr = Csr::from_edges(&edges, 0, n as usize, true);
        for kernel in all_kernels() {
            let ctx = IterCtx {
                kernel,
                num_vertices: n,
                src: &src,
                inv_out_deg: &inv,
                contrib: &contrib,
                iteration: 0,
            };
            let mut a = src.clone();
            let mut b = src.clone();
            fold_csr(&ctx, csr.slices(), 0, &mut a);
            reference_fold_csr(&ctx, csr.slices(), 0, &mut b);
            assert_eq!(a, b, "fold_csr diverged for {kernel:?}");

            // list fold over the same destination-grouped order
            let mut c = src.clone();
            let mut acc = Vec::new();
            fold_list(&ctx, &edges, 0, &mut c, &mut acc);
            assert_eq!(c, a, "fold_list diverged for {kernel:?}");

            // scatter gathers the same per-edge values
            let mut ups = Vec::new();
            scatter_list(&ctx, &edges, &mut ups);
            assert_eq!(ups.len(), edges.len());
            for (e, u) in edges.iter().zip(&ups) {
                assert_eq!(u.dst, e.dst);
                assert_eq!(u.val, ctx.edge_value(e), "scatter diverged for {kernel:?}");
            }
        }
    }

    #[test]
    fn unweighted_csr_defaults_to_unit_weight() {
        let n = 16u32;
        let (edges, src, inv) = fixture(n, 7);
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let csr = Csr::from_edges(&edges, 0, n as usize, false);
        for kernel in [
            crate::apps::Bfs::new(0).kernel(),
            crate::apps::Cc.kernel(),
            crate::apps::PageRank::new().kernel(),
        ] {
            let ctx = IterCtx {
                kernel,
                num_vertices: n,
                src: &src,
                inv_out_deg: &inv,
                contrib: &contrib,
                iteration: 0,
            };
            let mut a = src.clone();
            let mut b = src.clone();
            fold_csr(&ctx, csr.slices(), 0, &mut a);
            reference_fold_csr(&ctx, csr.slices(), 0, &mut b);
            assert_eq!(a, b, "unweighted fold diverged for {kernel:?}");
        }
    }

    #[test]
    fn fold_list_reuses_the_acc_arena() {
        let n = 8u32;
        let (edges, src, inv) = fixture(n, 3);
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let ctx = IterCtx {
            kernel: crate::apps::PageRank::new().kernel(),
            num_vertices: n,
            src: &src,
            inv_out_deg: &inv,
            contrib: &contrib,
            iteration: 0,
        };
        let mut acc = Vec::new();
        let mut out1 = src.clone();
        fold_list(&ctx, &edges, 0, &mut out1, &mut acc);
        let cap = acc.capacity();
        assert!(cap >= n as usize);
        let mut out2 = src.clone();
        fold_list(&ctx, &edges, 0, &mut out2, &mut acc);
        assert_eq!(acc.capacity(), cap, "second fold must not reallocate");
        assert_eq!(out1, out2);
    }
}
