//! Naive single-threaded reference implementations of all nine apps.
//!
//! Every oracle is a plain edge-list sweep over the in-memory graph —
//! no `ShardKernel`, no chunking, no scratch arenas, no engine — so a
//! bug in the shared kernel machinery cannot cancel out of an
//! oracle-vs-engine comparison.  `rust/tests/oracle.rs` cross-checks
//! every app on every engine against these on seeded random graphs.
//!
//! Comparison contract (mirrors the kernel-equivalence gates):
//!
//! - PageRank/PPR accumulate here in **f64**, so engine results agree
//!   only to a relative epsilon (the engines reassociate f32 sums);
//! - the monotone f32 relaxations (SSSP, BFS, CC, widest) converge to a
//!   unique least fixpoint built from the same f32 operations, so
//!   converged engine results must match **bit-for-bit**;
//! - the integer apps (WCC, BFS levels, k-core) are exact by
//!   construction — any deviation is a bug.

use crate::graph::{Edge, VertexId};

fn out_degrees(edges: &[Edge], n: u32) -> Vec<u32> {
    let mut deg = vec![0u32; n as usize];
    for e in edges {
        deg[e.src as usize] += 1;
    }
    deg
}

/// PageRank: `iters` synchronous sweeps of
/// `rank'[v] = (1-d)/n + d · Σ rank[u]/outdeg(u)` in f64.
pub fn pagerank(edges: &[Edge], n: u32, damping: f32, iters: u32) -> Vec<f32> {
    power_iterate(edges, n, damping, iters, |_| 1.0 / n.max(1) as f64, |_| 1.0 / n.max(1) as f64)
}

/// Personalized PageRank: all walk mass starts at — and teleports back
/// to — the seed vertex.
pub fn ppr(edges: &[Edge], n: u32, damping: f32, seed: VertexId, iters: u32) -> Vec<f32> {
    power_iterate(
        edges,
        n,
        damping,
        iters,
        |v| if v == seed { 1.0 } else { 0.0 },
        |v| if v == seed { 1.0 } else { 0.0 },
    )
}

fn power_iterate(
    edges: &[Edge],
    n: u32,
    damping: f32,
    iters: u32,
    init: impl Fn(VertexId) -> f64,
    reset: impl Fn(VertexId) -> f64,
) -> Vec<f32> {
    let deg = out_degrees(edges, n);
    let d = f64::from(damping);
    let mut rank: Vec<f64> = (0..n).map(&init).collect();
    for _ in 0..iters {
        let mut acc = vec![0.0f64; n as usize];
        for e in edges {
            let u = e.src as usize;
            if deg[u] > 0 {
                acc[e.dst as usize] += rank[u] / f64::from(deg[u]);
            }
        }
        rank = (0..n).map(|v| (1.0 - d) * reset(v) + d * acc[v as usize]).collect();
    }
    rank.into_iter().map(|x| x as f32).collect()
}

/// Asynchronous relaxation to the least fixpoint of
/// `val[dst] = meet(val[dst], gather(val[src], w))`.
fn relax_f32(
    edges: &[Edge],
    mut val: Vec<f32>,
    gather: impl Fn(f32, f32) -> f32,
    better: impl Fn(f32, f32) -> bool,
) -> Vec<f32> {
    loop {
        let mut changed = false;
        for e in edges {
            let cand = gather(val[e.src as usize], e.weight);
            if better(cand, val[e.dst as usize]) {
                val[e.dst as usize] = cand;
                changed = true;
            }
        }
        if !changed {
            return val;
        }
    }
}

/// Single-source shortest paths (Bellman-Ford to fixpoint, f32 sums).
pub fn sssp(edges: &[Edge], n: u32, source: VertexId) -> Vec<f32> {
    let mut d = vec![f32::INFINITY; n as usize];
    if source < n {
        d[source as usize] = 0.0;
    }
    relax_f32(edges, d, |s, w| s + w, |cand, cur| cand < cur)
}

/// BFS hop counts carried as f32 (the historical `bfs` app).
pub fn bfs_hops(edges: &[Edge], n: u32, source: VertexId) -> Vec<f32> {
    let mut d = vec![f32::INFINITY; n as usize];
    if source < n {
        d[source as usize] = 0.0;
    }
    relax_f32(edges, d, |s, _| s + 1.0, |cand, cur| cand < cur)
}

/// Min-label propagation over the directed edge set, f32 labels (the
/// historical `cc` app; components when the graph is symmetrised).
pub fn cc_labels(edges: &[Edge], n: u32) -> Vec<f32> {
    let init: Vec<f32> = (0..n).map(|v| v as f32).collect();
    relax_f32(edges, init, |s, _| s, |cand, cur| cand < cur)
}

/// Widest (maximum-bottleneck) paths from one source.
pub fn widest(edges: &[Edge], n: u32, source: VertexId) -> Vec<f32> {
    let mut wd = vec![0.0f32; n as usize];
    if source < n {
        wd[source as usize] = f32::INFINITY;
    }
    relax_f32(edges, wd, |s, w| s.min(w), |cand, cur| cand > cur)
}

/// Min-label propagation over exact u32 labels (the `wcc` app).
pub fn wcc_labels(edges: &[Edge], n: u32) -> Vec<u32> {
    let mut label: Vec<u32> = (0..n).collect();
    loop {
        let mut changed = false;
        for e in edges {
            let cand = label[e.src as usize];
            if cand < label[e.dst as usize] {
                label[e.dst as usize] = cand;
                changed = true;
            }
        }
        if !changed {
            return label;
        }
    }
}

/// BFS levels over exact u32 hop counts; unreachable stays `u32::MAX`
/// (the saturating `MAX ⊕ 1 = MAX` mirrors the engine's lane add).
pub fn bfs_levels(edges: &[Edge], n: u32, source: VertexId) -> Vec<u32> {
    let mut level = vec![u32::MAX; n as usize];
    if source < n {
        level[source as usize] = 0;
    }
    loop {
        let mut changed = false;
        for e in edges {
            let cand = level[e.src as usize].saturating_add(1);
            if cand < level[e.dst as usize] {
                level[e.dst as usize] = cand;
                changed = true;
            }
        }
        if !changed {
            return level;
        }
    }
}

/// k-core membership via the synchronous peel: every vertex starts
/// alive; each round keeps a vertex alive iff at least `k` of its
/// in-neighbors are alive.  Returns the fixpoint indicator vector.
pub fn kcore(edges: &[Edge], n: u32, k: u32) -> Vec<u32> {
    let mut alive = vec![1u32; n as usize];
    loop {
        let mut cnt = vec![0u32; n as usize];
        for e in edges {
            if alive[e.src as usize] != 0 {
                cnt[e.dst as usize] = cnt[e.dst as usize].saturating_add(1);
            }
        }
        let next: Vec<u32> = (0..n as usize)
            .map(|v| u32::from(alive[v] != 0 && cnt[v] >= k))
            .collect();
        if next == alive {
            return alive;
        }
        alive = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // a diamond with a pendant: 0→1, 0→2, 1→3, 2→3, 3→4 (weighted)
    fn diamond() -> Vec<Edge> {
        vec![
            Edge::weighted(0, 1, 2.0),
            Edge::weighted(0, 2, 5.0),
            Edge::weighted(1, 3, 1.0),
            Edge::weighted(2, 3, 1.0),
            Edge::weighted(3, 4, 4.0),
        ]
    }

    #[test]
    fn sssp_and_bfs_fixpoints_on_the_diamond() {
        let e = diamond();
        assert_eq!(sssp(&e, 5, 0), vec![0.0, 2.0, 5.0, 3.0, 7.0]);
        assert_eq!(bfs_hops(&e, 5, 0), vec![0.0, 1.0, 1.0, 2.0, 3.0]);
        assert_eq!(bfs_levels(&e, 5, 0), vec![0, 1, 1, 2, 3]);
        // unreachable saturates
        assert_eq!(bfs_levels(&e, 5, 4), vec![u32::MAX; 4].into_iter().chain([0]).collect::<Vec<_>>());
    }

    #[test]
    fn widest_takes_the_fat_branch() {
        // to 3: via 1 width min(2,1)=1, via 2 width min(5,1)=1 → 1
        let w = widest(&diamond(), 5, 0);
        assert_eq!(w, vec![f32::INFINITY, 2.0, 5.0, 1.0, 1.0]);
    }

    #[test]
    fn labels_propagate_to_the_minimum() {
        let e = diamond();
        assert_eq!(cc_labels(&e, 5), vec![0.0; 5]);
        assert_eq!(wcc_labels(&e, 5), vec![0; 5]);
        // an isolated vertex keeps its own label
        assert_eq!(wcc_labels(&e, 6)[5], 5);
    }

    #[test]
    fn kcore_peels_the_pendant_chain() {
        // symmetrize a triangle plus a pendant: every triangle vertex has
        // 2 in-neighbors, the pendant has 1 → 2-core = the triangle
        let mut e = vec![
            Edge::new(0, 1),
            Edge::new(1, 2),
            Edge::new(2, 0),
            Edge::new(2, 3),
        ];
        let rev: Vec<Edge> = e.iter().map(|x| Edge::new(x.dst, x.src)).collect();
        e.extend(rev);
        assert_eq!(kcore(&e, 4, 2), vec![1, 1, 1, 0]);
        // the 3-core is empty — and the peel cascades to kill everything
        assert_eq!(kcore(&e, 4, 3), vec![0, 0, 0, 0]);
    }

    #[test]
    fn pagerank_mass_is_conserved_without_danglers() {
        // a 3-cycle: stationary distribution is uniform
        let e = vec![Edge::new(0, 1), Edge::new(1, 2), Edge::new(2, 0)];
        let r = pagerank(&e, 3, 0.85, 50);
        for &x in &r {
            assert!((x - 1.0 / 3.0).abs() < 1e-6, "{r:?}");
        }
        let p = ppr(&e, 3, 0.85, 0, 50);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[0] > p[1] && p[0] > p[2], "seed keeps the most mass: {p:?}");
    }
}
