//! Vertex programs (paper Algorithm 3) and the generalized shard kernel.
//!
//! The paper's `Init`/`Update` API specialises to a small algebra: every
//! evaluated application folds each vertex's in-edges with an
//! **associative combine** (sum, min or max) over per-edge **gathered**
//! contributions, then **applies** the folded accumulator to the old
//! value, and activates the vertex when the app's **activation
//! predicate** fires.  [`ShardKernel`] captures exactly that triple over
//! `f32` lanes, so one execution core ([`crate::exec`]) runs every app on
//! every engine:
//!
//! | app          | combine | gather                      | apply                      |
//! |--------------|---------|-----------------------------|----------------------------|
//! | PageRank     | sum     | `src[u] · 1/outdeg(u)`      | `(1-d)/n + d·acc`          |
//! | PPR          | sum     | `src[u] · 1/outdeg(u)`      | `(1-d)·reset(v) + d·acc`   |
//! | SSSP         | min     | `src[u] + w`                | `min(old, acc)`            |
//! | BFS          | min     | `src[u] + 1`                | `min(old, acc)`            |
//! | CC           | min     | `src[u]`                    | `min(old, acc)`            |
//! | widest path  | max     | `min(src[u], w)`            | `max(old, acc)`            |
//!
//! A [`VertexProgram`] therefore declares its kernel plus init rules; the
//! engines execute the kernel on either backend (native rust or PJRT).

use crate::graph::VertexId;

/// The per-edge cost fed to path-style gathers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeCost {
    /// Use the shard's edge weights (SSSP, widest path).
    Weights,
    /// Unit cost per hop (BFS levels).
    Unit,
    /// Zero cost (CC label propagation).
    Zero,
}

impl EdgeCost {
    #[inline]
    pub fn apply(&self, w: f32) -> f32 {
        match self {
            EdgeCost::Weights => w,
            EdgeCost::Unit => 1.0,
            EdgeCost::Zero => 0.0,
        }
    }
}

/// The associative reduction folding a vertex's in-edge contributions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Combine {
    Sum,
    Min,
    Max,
}

/// How one edge `(u → v, w)` turns into a contribution for `v`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeGather {
    /// `src[u] · inv_out_deg[u]` — degree-normalised rank mass.  The
    /// execution core pre-folds this product once per iteration into the
    /// `contrib` array (|V| multiplies instead of |E|).
    DegreeMass,
    /// `src[u] + cost(w)` — path length (SSSP/BFS) or raw label (CC).
    AddCost(EdgeCost),
    /// `min(src[u], cost(w))` — path bottleneck width (widest path).
    MinCapacity(EdgeCost),
}

/// Where a sum kernel's teleport/base mass lands.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BaseMass {
    /// `mass / n` at every vertex (PageRank).
    Uniform { mass: f32 },
    /// All of `mass` at one reset vertex (personalized PageRank).
    Single { vertex: VertexId, mass: f32 },
}

impl BaseMass {
    /// The base value of vertex `v` in an `n`-vertex graph.
    #[inline]
    pub fn at(&self, v: VertexId, n: u32) -> f32 {
        match *self {
            BaseMass::Uniform { mass } => mass / n as f32,
            BaseMass::Single { vertex, mass } => {
                if v == vertex {
                    mass
                } else {
                    0.0
                }
            }
        }
    }
}

/// How the folded accumulator becomes the vertex's next value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Apply {
    /// `base(v) + scale · acc` — sum kernels (PageRank family).
    Affine { scale: f32, base: BaseMass },
    /// `combine(old, acc)` — monotone relaxations keep their best value.
    MeetOld,
}

/// A generalized shard update: associative combine + per-edge gather +
/// apply + activation predicate over `f32` vertex lanes.  Copyable and
/// engine-agnostic — the whole contract between an app and the execution
/// core.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardKernel {
    pub combine: Combine,
    pub gather: EdgeGather,
    pub apply: Apply,
}

impl ShardKernel {
    /// The classic PageRank kernel.
    pub fn pagerank(damping: f32) -> ShardKernel {
        ShardKernel {
            combine: Combine::Sum,
            gather: EdgeGather::DegreeMass,
            apply: Apply::Affine { scale: damping, base: BaseMass::Uniform { mass: 1.0 - damping } },
        }
    }

    /// Personalized PageRank: teleport mass concentrated on one vertex.
    pub fn personalized_pagerank(damping: f32, seed: VertexId) -> ShardKernel {
        ShardKernel {
            combine: Combine::Sum,
            gather: EdgeGather::DegreeMass,
            apply: Apply::Affine {
                scale: damping,
                base: BaseMass::Single { vertex: seed, mass: 1.0 - damping },
            },
        }
    }

    /// Min-relaxation over `src[u] + cost(w)` (SSSP/BFS/CC).
    pub fn relax_min(cost: EdgeCost) -> ShardKernel {
        ShardKernel {
            combine: Combine::Min,
            gather: EdgeGather::AddCost(cost),
            apply: Apply::MeetOld,
        }
    }

    /// Max–min relaxation: widest (bottleneck) paths.
    pub fn widest_path(cost: EdgeCost) -> ShardKernel {
        ShardKernel {
            combine: Combine::Max,
            gather: EdgeGather::MinCapacity(cost),
            apply: Apply::MeetOld,
        }
    }

    /// Identity element of the combine.
    #[inline]
    pub fn identity(&self) -> f32 {
        match self.combine {
            Combine::Sum => 0.0,
            Combine::Min => f32::INFINITY,
            Combine::Max => f32::NEG_INFINITY,
        }
    }

    /// Fold one contribution into the accumulator.
    #[inline]
    pub fn combine(&self, acc: f32, contribution: f32) -> f32 {
        match self.combine {
            Combine::Sum => acc + contribution,
            Combine::Min => acc.min(contribution),
            Combine::Max => acc.max(contribution),
        }
    }

    /// One edge's contribution, from the source value (`src_val`), the
    /// source's out-degree inverse and the edge weight.  Degree-mass
    /// kernels normally read the pre-folded `contrib` array instead —
    /// `src_val * inv_u` here rounds identically, so both paths agree
    /// bit-for-bit.
    #[inline]
    pub fn edge_value(&self, src_val: f32, inv_u: f32, w: f32) -> f32 {
        match self.gather {
            EdgeGather::DegreeMass => src_val * inv_u,
            EdgeGather::AddCost(cost) => src_val + cost.apply(w),
            EdgeGather::MinCapacity(cost) => src_val.min(cost.apply(w)),
        }
    }

    /// Produce the vertex's next value from the folded accumulator.
    #[inline]
    pub fn apply(&self, v: VertexId, n: u32, old: f32, acc: f32) -> f32 {
        match self.apply {
            Apply::Affine { scale, base } => base.at(v, n) + scale * acc,
            Apply::MeetOld => self.combine(old, acc),
        }
    }

    /// Activation predicate: sum kernels re-activate on any change,
    /// monotone kernels only on strict improvement.
    #[inline]
    pub fn is_update(&self, old: f32, new: f32) -> bool {
        match self.combine {
            Combine::Sum => old != new,
            Combine::Min => new < old,
            Combine::Max => new > old,
        }
    }

    /// Whether the execution core should pre-fold the per-vertex
    /// `src · inv_out_deg` contribution array for this kernel.
    #[inline]
    pub fn uses_contrib(&self) -> bool {
        matches!(self.gather, EdgeGather::DegreeMass)
    }

    /// Whether shard weights must be present on disk.
    #[inline]
    pub fn needs_weights(&self) -> bool {
        matches!(
            self.gather,
            EdgeGather::AddCost(EdgeCost::Weights) | EdgeGather::MinCapacity(EdgeCost::Weights)
        )
    }
}

/// A vertex-centric application (paper §2.3 `Init` + `Update`).
pub trait VertexProgram: Sync {
    fn name(&self) -> &'static str;

    /// Initial vertex values and the initially-active vertex set.
    fn init(&self, num_vertices: u32) -> (Vec<f32>, Vec<VertexId>);

    /// The shard kernel driving `Update`.
    fn kernel(&self) -> ShardKernel;

    /// Does a value change count as "activation"?
    #[inline]
    fn is_update(&self, old: f32, new: f32) -> bool {
        self.kernel().is_update(old, new)
    }

    /// Whether the app needs the out-degree array (sum kernels only).
    fn uses_out_degrees(&self) -> bool {
        self.kernel().uses_contrib()
    }

    /// Whether shard weights must be present on disk.
    fn needs_weights(&self) -> bool {
        self.kernel().needs_weights()
    }
}

/// PageRank (Algorithm 3 lines 1–11).
#[derive(Clone, Copy, Debug)]
pub struct PageRank {
    pub damping: f32,
}

impl PageRank {
    pub fn new() -> Self {
        PageRank { damping: 0.85 }
    }
}

impl Default for PageRank {
    fn default() -> Self {
        Self::new()
    }
}

impl VertexProgram for PageRank {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        let v = vec![1.0 / n.max(1) as f32; n as usize];
        (v, (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::pagerank(self.damping)
    }
}

/// Personalized PageRank: random walks teleport back to one seed vertex
/// instead of the uniform reset vector — the same sum kernel as PageRank
/// with a different base-mass distribution.
#[derive(Clone, Copy, Debug)]
pub struct Ppr {
    pub damping: f32,
    pub seed: VertexId,
}

impl Ppr {
    pub fn new(seed: VertexId) -> Self {
        Ppr { damping: 0.85, seed }
    }
}

impl VertexProgram for Ppr {
    fn name(&self) -> &'static str {
        "ppr"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        // walk mass starts entirely at the seed
        let mut v = vec![0.0f32; n as usize];
        if self.seed < n {
            v[self.seed as usize] = 1.0;
        }
        (v, (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::personalized_pagerank(self.damping, self.seed)
    }
}

/// Single-source shortest paths (Algorithm 3 lines 12–25).
#[derive(Clone, Copy, Debug)]
pub struct Sssp {
    pub source: VertexId,
}

impl Sssp {
    pub fn new(source: VertexId) -> Self {
        Sssp { source }
    }
}

impl VertexProgram for Sssp {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        let mut v = vec![f32::INFINITY; n as usize];
        if self.source < n {
            v[self.source as usize] = 0.0;
        }
        (v, vec![self.source])
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Weights)
    }
}

/// Weakly connected components via min-label propagation (Algorithm 3
/// lines 26–36; run on the symmetrised graph).  Labels are carried as f32
/// — exact for ids < 2²⁴, asserted by the execution core.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cc;

impl VertexProgram for Cc {
    fn name(&self) -> &'static str {
        "cc"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        ((0..n).map(|i| i as f32).collect(), (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Zero)
    }
}

/// BFS levels — the same min-relaxation with unit costs.
#[derive(Clone, Copy, Debug)]
pub struct Bfs {
    pub source: VertexId,
}

impl Bfs {
    pub fn new(source: VertexId) -> Self {
        Bfs { source }
    }
}

impl VertexProgram for Bfs {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        let mut v = vec![f32::INFINITY; n as usize];
        if self.source < n {
            v[self.source as usize] = 0.0;
        }
        (v, vec![self.source])
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Unit)
    }
}

/// Widest path (maximum-bottleneck path) from one source: the max–min
/// dual of SSSP.  A path's width is its narrowest edge; each vertex keeps
/// the widest width over all paths from the source.
#[derive(Clone, Copy, Debug)]
pub struct Widest {
    pub source: VertexId,
}

impl Widest {
    pub fn new(source: VertexId) -> Self {
        Widest { source }
    }
}

impl VertexProgram for Widest {
    fn name(&self) -> &'static str {
        "widest"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        // unreachable vertices stay at width 0 (capacities are positive);
        // the source itself has unconstrained width
        let mut v = vec![0.0f32; n as usize];
        if self.source < n {
            v[self.source as usize] = f32::INFINITY;
        }
        (v, vec![self.source])
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::widest_path(EdgeCost::Weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pagerank_init_uniform_all_active() {
        let (v, active) = PageRank::new().init(4);
        assert_eq!(v, vec![0.25; 4]);
        assert_eq!(active.len(), 4);
    }

    #[test]
    fn sssp_init_source_only() {
        let (v, active) = Sssp::new(2).init(4);
        assert_eq!(v[2], 0.0);
        assert!(v[0].is_infinite());
        assert_eq!(active, vec![2]);
    }

    #[test]
    fn cc_init_identity_labels() {
        let (v, active) = Cc.init(3);
        assert_eq!(v, vec![0.0, 1.0, 2.0]);
        assert_eq!(active.len(), 3);
    }

    #[test]
    fn ppr_init_mass_at_seed() {
        let (v, active) = Ppr::new(1).init(3);
        assert_eq!(v, vec![0.0, 1.0, 0.0]);
        assert_eq!(active.len(), 3);
    }

    #[test]
    fn widest_init_source_unbounded() {
        let (v, active) = Widest::new(0).init(3);
        assert!(v[0].is_infinite());
        assert_eq!(v[1], 0.0);
        assert_eq!(active, vec![0]);
    }

    #[test]
    fn update_semantics() {
        let pr = PageRank::new();
        assert!(pr.is_update(0.5, 0.6));
        assert!(pr.is_update(0.6, 0.5));
        assert!(!pr.is_update(0.5, 0.5));
        let ss = Sssp::new(0);
        assert!(ss.is_update(5.0, 3.0));
        assert!(!ss.is_update(3.0, 5.0));
        assert!(!ss.is_update(3.0, 3.0));
        let wd = Widest::new(0);
        assert!(wd.is_update(3.0, 5.0));
        assert!(!wd.is_update(5.0, 3.0));
    }

    #[test]
    fn edge_cost_apply() {
        assert_eq!(EdgeCost::Weights.apply(2.5), 2.5);
        assert_eq!(EdgeCost::Unit.apply(2.5), 1.0);
        assert_eq!(EdgeCost::Zero.apply(2.5), 0.0);
    }

    #[test]
    fn kernel_algebra() {
        let pr = ShardKernel::pagerank(0.85);
        assert_eq!(pr.identity(), 0.0);
        assert_eq!(pr.combine(1.0, 2.0), 3.0);
        assert_eq!(pr.edge_value(0.5, 0.25, 7.0), 0.125);
        // apply = 0.15/4 + 0.85*acc
        let n = 4;
        assert!((pr.apply(0, n, 0.0, 1.0) - (0.15 / 4.0 + 0.85)).abs() < 1e-7);

        let ss = ShardKernel::relax_min(EdgeCost::Weights);
        assert_eq!(ss.identity(), f32::INFINITY);
        assert_eq!(ss.combine(3.0, 5.0), 3.0);
        assert_eq!(ss.edge_value(1.0, 0.0, 2.0), 3.0);
        assert_eq!(ss.apply(0, n, 2.5, 3.0), 2.5);

        let wd = ShardKernel::widest_path(EdgeCost::Weights);
        assert_eq!(wd.identity(), f32::NEG_INFINITY);
        assert_eq!(wd.combine(3.0, 5.0), 5.0);
        assert_eq!(wd.edge_value(4.0, 0.0, 2.0), 2.0);
        assert_eq!(wd.apply(0, n, 3.0, 2.0), 3.0);
    }

    #[test]
    fn base_mass_distribution() {
        let u = BaseMass::Uniform { mass: 0.15 };
        assert!((u.at(0, 3) - 0.05).abs() < 1e-7);
        let s = BaseMass::Single { vertex: 2, mass: 0.15 };
        assert_eq!(s.at(2, 3), 0.15);
        assert_eq!(s.at(0, 3), 0.0);
    }

    #[test]
    fn aux_requirements() {
        assert!(PageRank::new().uses_out_degrees());
        assert!(Ppr::new(0).uses_out_degrees());
        assert!(!Sssp::new(0).uses_out_degrees());
        assert!(Sssp::new(0).needs_weights());
        assert!(!Cc.needs_weights());
        assert!(!Bfs::new(0).needs_weights());
        assert!(Widest::new(0).needs_weights());
    }
}
