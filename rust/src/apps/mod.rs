//! Vertex programs: PageRank, SSSP, CC (paper Algorithm 3) + BFS extension.
//!
//! The paper's `Init`/`Update` API specialises, for all three evaluated
//! applications, to one of two shard reductions — a weighted neighbour sum
//! (PageRank) or a min-relaxation (SSSP, CC) — which is exactly the pair of
//! AOT-compiled L2 artifacts.  A [`VertexProgram`] therefore declares its
//! [`ShardCompute`] kind plus init/activation rules; the engine executes
//! the kind on either backend (native rust or PJRT).

use crate::graph::VertexId;

/// The per-edge cost fed to the min-relaxation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeCost {
    /// Use the shard's edge weights (SSSP).
    Weights,
    /// Unit cost per hop (BFS levels).
    Unit,
    /// Zero cost (CC label propagation).
    Zero,
}

impl EdgeCost {
    #[inline]
    pub fn apply(&self, w: f32) -> f32 {
        match self {
            EdgeCost::Weights => w,
            EdgeCost::Unit => 1.0,
            EdgeCost::Zero => 0.0,
        }
    }
}

/// The two shard-update shapes the engine (and the AOT artifacts) know.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ShardCompute {
    /// `dst[r] = base + damping * Σ_{e→r} src[col_e] * inv_out_deg[col_e]`
    PageRankSum { damping: f32 },
    /// `dst[r] = min(src[r], min_{e→r} src[col_e] + cost(w_e))`
    RelaxMin { cost: EdgeCost },
}

/// A vertex-centric application (paper §2.3 `Init` + `Update`).
pub trait VertexProgram: Sync {
    fn name(&self) -> &'static str;

    /// Initial vertex values and the initially-active vertex set.
    fn init(&self, num_vertices: u32) -> (Vec<f32>, Vec<VertexId>);

    /// Which shard reduction drives `Update`.
    fn compute(&self) -> ShardCompute;

    /// Does a value change count as "activation"? PageRank: any change;
    /// min-apps: strict decrease (monotone lattice).
    #[inline]
    fn is_update(&self, old: f32, new: f32) -> bool {
        match self.compute() {
            ShardCompute::PageRankSum { .. } => old != new,
            ShardCompute::RelaxMin { .. } => new < old,
        }
    }

    /// Whether the app needs the out-degree array (PageRank only).
    fn uses_out_degrees(&self) -> bool {
        matches!(self.compute(), ShardCompute::PageRankSum { .. })
    }

    /// Whether shard weights must be present on disk.
    fn needs_weights(&self) -> bool {
        matches!(
            self.compute(),
            ShardCompute::RelaxMin { cost: EdgeCost::Weights }
        )
    }
}

/// PageRank (Algorithm 3 lines 1–11).
#[derive(Clone, Copy, Debug)]
pub struct PageRank {
    pub damping: f32,
}

impl PageRank {
    pub fn new() -> Self {
        PageRank { damping: 0.85 }
    }
}

impl Default for PageRank {
    fn default() -> Self {
        Self::new()
    }
}

impl VertexProgram for PageRank {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        let v = vec![1.0 / n.max(1) as f32; n as usize];
        (v, (0..n).collect())
    }

    fn compute(&self) -> ShardCompute {
        ShardCompute::PageRankSum { damping: self.damping }
    }
}

/// Single-source shortest paths (Algorithm 3 lines 12–25).
#[derive(Clone, Copy, Debug)]
pub struct Sssp {
    pub source: VertexId,
}

impl Sssp {
    pub fn new(source: VertexId) -> Self {
        Sssp { source }
    }
}

impl VertexProgram for Sssp {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        let mut v = vec![f32::INFINITY; n as usize];
        if self.source < n {
            v[self.source as usize] = 0.0;
        }
        (v, vec![self.source])
    }

    fn compute(&self) -> ShardCompute {
        ShardCompute::RelaxMin { cost: EdgeCost::Weights }
    }
}

/// Weakly connected components via min-label propagation (Algorithm 3
/// lines 26–36; run on the symmetrised graph).  Labels are carried as f32
/// — exact for ids < 2²⁴, asserted by the engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cc;

impl VertexProgram for Cc {
    fn name(&self) -> &'static str {
        "cc"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        ((0..n).map(|i| i as f32).collect(), (0..n).collect())
    }

    fn compute(&self) -> ShardCompute {
        ShardCompute::RelaxMin { cost: EdgeCost::Zero }
    }
}

/// BFS levels — a paper-adjacent extension app exercising the same
/// min-relaxation with unit costs.
#[derive(Clone, Copy, Debug)]
pub struct Bfs {
    pub source: VertexId,
}

impl Bfs {
    pub fn new(source: VertexId) -> Self {
        Bfs { source }
    }
}

impl VertexProgram for Bfs {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn init(&self, n: u32) -> (Vec<f32>, Vec<VertexId>) {
        let mut v = vec![f32::INFINITY; n as usize];
        if self.source < n {
            v[self.source as usize] = 0.0;
        }
        (v, vec![self.source])
    }

    fn compute(&self) -> ShardCompute {
        ShardCompute::RelaxMin { cost: EdgeCost::Unit }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pagerank_init_uniform_all_active() {
        let (v, active) = PageRank::new().init(4);
        assert_eq!(v, vec![0.25; 4]);
        assert_eq!(active.len(), 4);
    }

    #[test]
    fn sssp_init_source_only() {
        let (v, active) = Sssp::new(2).init(4);
        assert_eq!(v[2], 0.0);
        assert!(v[0].is_infinite());
        assert_eq!(active, vec![2]);
    }

    #[test]
    fn cc_init_identity_labels() {
        let (v, active) = Cc.init(3);
        assert_eq!(v, vec![0.0, 1.0, 2.0]);
        assert_eq!(active.len(), 3);
    }

    #[test]
    fn update_semantics() {
        let pr = PageRank::new();
        assert!(pr.is_update(0.5, 0.6));
        assert!(pr.is_update(0.6, 0.5));
        assert!(!pr.is_update(0.5, 0.5));
        let ss = Sssp::new(0);
        assert!(ss.is_update(5.0, 3.0));
        assert!(!ss.is_update(3.0, 5.0));
        assert!(!ss.is_update(3.0, 3.0));
    }

    #[test]
    fn edge_cost_apply() {
        assert_eq!(EdgeCost::Weights.apply(2.5), 2.5);
        assert_eq!(EdgeCost::Unit.apply(2.5), 1.0);
        assert_eq!(EdgeCost::Zero.apply(2.5), 0.0);
    }

    #[test]
    fn aux_requirements() {
        assert!(PageRank::new().uses_out_degrees());
        assert!(!Sssp::new(0).uses_out_degrees());
        assert!(Sssp::new(0).needs_weights());
        assert!(!Cc.needs_weights());
        assert!(!Bfs::new(0).needs_weights());
    }
}
