//! Vertex programs (paper Algorithm 3) and the generalized shard kernel.
//!
//! The paper's `Init`/`Update` API specialises to a small algebra: every
//! evaluated application folds each vertex's in-edges with an
//! **associative combine** (sum, min or max) over per-edge **gathered**
//! contributions, then **applies** the folded accumulator to the old
//! value, and activates the vertex when the app's **activation
//! predicate** fires.  [`ShardKernel`] captures exactly that triple over
//! a typed value lane ([`crate::exec::lane::Lane`]: `f32`, `u32` or
//! `u64`), so one execution core ([`crate::exec`]) runs every app on
//! every engine:
//!
//! | app          | lane | combine | gather                  | apply                    |
//! |--------------|------|---------|-------------------------|--------------------------|
//! | PageRank     | f32  | sum     | `src[u] · 1/outdeg(u)`  | `(1-d)/n + d·acc`        |
//! | PPR          | f32  | sum     | `src[u] · 1/outdeg(u)`  | `(1-d)·reset(v) + d·acc` |
//! | SSSP         | f32  | min     | `src[u] + w`            | `min(old, acc)`          |
//! | BFS          | f32  | min     | `src[u] + 1`            | `min(old, acc)`          |
//! | CC           | f32  | min     | `src[u]`                | `min(old, acc)`          |
//! | widest path  | f32  | max     | `min(src[u], w)`        | `max(old, acc)`          |
//! | WCC          | u32  | min     | `src[u]`                | `min(old, acc)`          |
//! | BFS levels   | u32  | min     | `src[u] ⊕ 1` (sat.)     | `min(old, acc)`          |
//! | k-core       | u32  | sum     | `src[u] != 0`           | `old != 0 ∧ acc ≥ k`     |
//!
//! A [`VertexProgram`] therefore declares its kernel plus init rules; the
//! engines execute the kernel on either backend (native rust or PJRT —
//! the PJRT artifacts cover f32 lanes only).
//!
//! Naive single-threaded reference implementations of all nine apps live
//! in [`oracle`]; `rust/tests/oracle.rs` cross-checks every engine
//! against them on seeded random graphs.

pub mod oracle;

use crate::exec::lane::{Lane, LaneType, LaneVec};
use crate::graph::VertexId;

/// The per-edge cost fed to path-style gathers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeCost {
    /// Use the shard's edge weights (SSSP, widest path).
    Weights,
    /// Unit cost per hop (BFS levels).
    Unit,
    /// Zero cost (CC/WCC label propagation).
    Zero,
}

impl EdgeCost {
    #[inline]
    pub fn apply(&self, w: f32) -> f32 {
        match self {
            EdgeCost::Weights => w,
            EdgeCost::Unit => 1.0,
            EdgeCost::Zero => 0.0,
        }
    }
}

/// The associative reduction folding a vertex's in-edge contributions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Combine {
    Sum,
    Min,
    Max,
}

/// How one edge `(u → v, w)` turns into a contribution for `v`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeGather {
    /// `src[u] · inv_out_deg[u]` — degree-normalised rank mass (f32
    /// lanes only).  The execution core pre-folds this product once per
    /// iteration into the `contrib` array (|V| multiplies instead of
    /// |E|).
    DegreeMass,
    /// `src[u] + cost(w)` — path length (SSSP/BFS) or raw label (CC);
    /// integer lanes add saturating, so unreached `u32::MAX` stays put.
    AddCost(EdgeCost),
    /// `min(src[u], cost(w))` — path bottleneck width (widest path).
    MinCapacity(EdgeCost),
    /// `1` if `src[u] != 0` else `0` — alive-neighbor counting (k-core).
    Indicator,
}

/// Where a sum kernel's teleport/base mass lands.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BaseMass {
    /// `mass / n` at every vertex (PageRank).
    Uniform { mass: f32 },
    /// All of `mass` at one reset vertex (personalized PageRank).
    Single { vertex: VertexId, mass: f32 },
}

impl BaseMass {
    /// The base value of vertex `v` in an `n`-vertex graph.
    #[inline]
    pub fn at(&self, v: VertexId, n: u32) -> f32 {
        match *self {
            BaseMass::Uniform { mass } => mass / n as f32,
            BaseMass::Single { vertex, mass } => {
                if v == vertex {
                    mass
                } else {
                    0.0
                }
            }
        }
    }
}

/// How the folded accumulator becomes the vertex's next value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Apply {
    /// `base(v) + scale · acc` — sum kernels (PageRank family, f32 only).
    Affine { scale: f32, base: BaseMass },
    /// `combine(old, acc)` — monotone relaxations keep their best value.
    MeetOld,
    /// `old != 0 ∧ acc ≥ k` — the synchronous k-core peel: a vertex
    /// stays alive while at least `k` in-neighbors are alive.
    Threshold { k: u32 },
}

/// A generalized shard update: associative combine + per-edge gather +
/// apply + activation predicate over a typed value lane.  Copyable and
/// engine-agnostic — the whole contract between an app and the execution
/// core.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardKernel {
    pub combine: Combine,
    pub gather: EdgeGather,
    pub apply: Apply,
    /// The concrete value-lane type the kernel folds over.  The erased
    /// entry points in [`crate::exec::kernel`] dispatch on this tag.
    pub lane: LaneType,
}

impl ShardKernel {
    /// The classic PageRank kernel.
    pub fn pagerank(damping: f32) -> ShardKernel {
        ShardKernel {
            combine: Combine::Sum,
            gather: EdgeGather::DegreeMass,
            apply: Apply::Affine { scale: damping, base: BaseMass::Uniform { mass: 1.0 - damping } },
            lane: LaneType::F32,
        }
    }

    /// Personalized PageRank: teleport mass concentrated on one vertex.
    pub fn personalized_pagerank(damping: f32, seed: VertexId) -> ShardKernel {
        ShardKernel {
            combine: Combine::Sum,
            gather: EdgeGather::DegreeMass,
            apply: Apply::Affine {
                scale: damping,
                base: BaseMass::Single { vertex: seed, mass: 1.0 - damping },
            },
            lane: LaneType::F32,
        }
    }

    /// Min-relaxation over `src[u] + cost(w)` (SSSP/BFS/CC).
    pub fn relax_min(cost: EdgeCost) -> ShardKernel {
        ShardKernel {
            combine: Combine::Min,
            gather: EdgeGather::AddCost(cost),
            apply: Apply::MeetOld,
            lane: LaneType::F32,
        }
    }

    /// Max–min relaxation: widest (bottleneck) paths.
    pub fn widest_path(cost: EdgeCost) -> ShardKernel {
        ShardKernel {
            combine: Combine::Max,
            gather: EdgeGather::MinCapacity(cost),
            apply: Apply::MeetOld,
            lane: LaneType::F32,
        }
    }

    /// Synchronous k-core peel over u32 alive flags: count alive
    /// in-neighbors, keep the vertex alive while the count stays ≥ k.
    pub fn kcore(k: u32) -> ShardKernel {
        ShardKernel {
            combine: Combine::Sum,
            gather: EdgeGather::Indicator,
            apply: Apply::Threshold { k },
            lane: LaneType::U32,
        }
    }

    /// The same kernel over a different value lane.
    pub fn with_lane(mut self, lane: LaneType) -> ShardKernel {
        self.lane = lane;
        self
    }

    /// Identity element of the combine, in lane type `T`.
    #[inline]
    pub fn identity_t<T: Lane>(&self) -> T {
        match self.combine {
            Combine::Sum => T::ZERO,
            Combine::Min => T::MIN_IDENTITY,
            Combine::Max => T::MAX_IDENTITY,
        }
    }

    /// Fold one contribution into the accumulator, in lane type `T`.
    #[inline]
    pub fn combine_t<T: Lane>(&self, acc: T, contribution: T) -> T {
        match self.combine {
            Combine::Sum => acc.add(contribution),
            Combine::Min => acc.meet_min(contribution),
            Combine::Max => acc.meet_max(contribution),
        }
    }

    /// One edge's contribution, from the source value (`src_val`), the
    /// source's out-degree inverse and the edge weight.  Degree-mass
    /// kernels normally read the pre-folded `contrib` array instead —
    /// `src_val * inv_u` here rounds identically, so both paths agree
    /// bit-for-bit.
    #[inline]
    pub fn edge_value_t<T: Lane>(&self, src_val: T, inv_u: f32, w: f32) -> T {
        match self.gather {
            EdgeGather::DegreeMass => src_val.degree_mass(inv_u),
            EdgeGather::AddCost(cost) => src_val.add(T::cost(cost, w)),
            EdgeGather::MinCapacity(cost) => src_val.meet_min(T::cost(cost, w)),
            EdgeGather::Indicator => src_val.indicator(),
        }
    }

    /// Produce the vertex's next value from the folded accumulator.
    #[inline]
    pub fn apply_t<T: Lane>(&self, v: VertexId, n: u32, old: T, acc: T) -> T {
        match self.apply {
            Apply::Affine { scale, base } => T::affine(acc, scale, base.at(v, n)),
            Apply::MeetOld => self.combine_t(old, acc),
            Apply::Threshold { k } => {
                if old != T::ZERO && acc.count_ge(k) {
                    T::ONE
                } else {
                    T::ZERO
                }
            }
        }
    }

    /// Activation predicate: sum kernels re-activate on any change,
    /// monotone kernels only on strict improvement.
    #[inline]
    pub fn is_update_t<T: Lane>(&self, old: T, new: T) -> bool {
        match self.combine {
            Combine::Sum => old != new,
            Combine::Min => new < old,
            Combine::Max => new > old,
        }
    }

    /// f32 conveniences — the historical single-lane API, kept for the
    /// float apps, the baseline sweeps and the PJRT backend.
    #[inline]
    pub fn identity(&self) -> f32 {
        self.identity_t::<f32>()
    }
    #[inline]
    pub fn combine(&self, acc: f32, contribution: f32) -> f32 {
        self.combine_t::<f32>(acc, contribution)
    }
    #[inline]
    pub fn edge_value(&self, src_val: f32, inv_u: f32, w: f32) -> f32 {
        self.edge_value_t::<f32>(src_val, inv_u, w)
    }
    #[inline]
    pub fn apply(&self, v: VertexId, n: u32, old: f32, acc: f32) -> f32 {
        self.apply_t::<f32>(v, n, old, acc)
    }
    #[inline]
    pub fn is_update(&self, old: f32, new: f32) -> bool {
        self.is_update_t::<f32>(old, new)
    }

    /// Whether the execution core should pre-fold the per-vertex
    /// `src · inv_out_deg` contribution array for this kernel.
    #[inline]
    pub fn uses_contrib(&self) -> bool {
        matches!(self.gather, EdgeGather::DegreeMass)
    }

    /// Whether shard weights must be present on disk.
    #[inline]
    pub fn needs_weights(&self) -> bool {
        matches!(
            self.gather,
            EdgeGather::AddCost(EdgeCost::Weights) | EdgeGather::MinCapacity(EdgeCost::Weights)
        )
    }
}

/// A vertex-centric application (paper §2.3 `Init` + `Update`).
pub trait VertexProgram: Sync {
    fn name(&self) -> &'static str;

    /// Initial vertex values (in the kernel's lane type) and the
    /// initially-active vertex set.
    fn init(&self, num_vertices: u32) -> (LaneVec, Vec<VertexId>);

    /// The shard kernel driving `Update`.
    fn kernel(&self) -> ShardKernel;

    /// Does a value change count as "activation"?  (f32 lanes; integer
    /// apps go through `ShardKernel::is_update_t`.)
    #[inline]
    fn is_update(&self, old: f32, new: f32) -> bool {
        self.kernel().is_update(old, new)
    }

    /// Whether the app needs the out-degree array (sum kernels only).
    fn uses_out_degrees(&self) -> bool {
        self.kernel().uses_contrib()
    }

    /// Whether shard weights must be present on disk.
    fn needs_weights(&self) -> bool {
        self.kernel().needs_weights()
    }
}

/// PageRank (Algorithm 3 lines 1–11).
#[derive(Clone, Copy, Debug)]
pub struct PageRank {
    pub damping: f32,
}

impl PageRank {
    pub fn new() -> Self {
        PageRank { damping: 0.85 }
    }
}

impl Default for PageRank {
    fn default() -> Self {
        Self::new()
    }
}

impl VertexProgram for PageRank {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        let v = vec![1.0 / n.max(1) as f32; n as usize];
        (v.into(), (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::pagerank(self.damping)
    }
}

/// Personalized PageRank: random walks teleport back to one seed vertex
/// instead of the uniform reset vector — the same sum kernel as PageRank
/// with a different base-mass distribution.
#[derive(Clone, Copy, Debug)]
pub struct Ppr {
    pub damping: f32,
    pub seed: VertexId,
}

impl Ppr {
    pub fn new(seed: VertexId) -> Self {
        Ppr { damping: 0.85, seed }
    }
}

impl VertexProgram for Ppr {
    fn name(&self) -> &'static str {
        "ppr"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        // walk mass starts entirely at the seed
        let mut v = vec![0.0f32; n as usize];
        if self.seed < n {
            v[self.seed as usize] = 1.0;
        }
        (v.into(), (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::personalized_pagerank(self.damping, self.seed)
    }
}

/// Single-source shortest paths (Algorithm 3 lines 12–25).
#[derive(Clone, Copy, Debug)]
pub struct Sssp {
    pub source: VertexId,
}

impl Sssp {
    pub fn new(source: VertexId) -> Self {
        Sssp { source }
    }
}

impl VertexProgram for Sssp {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        let mut v = vec![f32::INFINITY; n as usize];
        if self.source < n {
            v[self.source as usize] = 0.0;
        }
        (v.into(), vec![self.source])
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Weights)
    }
}

/// Weakly connected components via min-label propagation (Algorithm 3
/// lines 26–36; run on the symmetrised graph).  Labels are carried as f32
/// — exact for ids < 2²⁴, asserted by the execution core.  [`Wcc`] is
/// the same fixpoint over exact u32 labels with no id ceiling.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cc;

impl VertexProgram for Cc {
    fn name(&self) -> &'static str {
        "cc"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        let v: Vec<f32> = (0..n).map(|i| i as f32).collect();
        (v.into(), (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Zero)
    }
}

/// BFS levels — the same min-relaxation with unit costs.
#[derive(Clone, Copy, Debug)]
pub struct Bfs {
    pub source: VertexId,
}

impl Bfs {
    pub fn new(source: VertexId) -> Self {
        Bfs { source }
    }
}

impl VertexProgram for Bfs {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        let mut v = vec![f32::INFINITY; n as usize];
        if self.source < n {
            v[self.source as usize] = 0.0;
        }
        (v.into(), vec![self.source])
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Unit)
    }
}

/// Widest path (maximum-bottleneck path) from one source: the max–min
/// dual of SSSP.  A path's width is its narrowest edge; each vertex keeps
/// the widest width over all paths from the source.
#[derive(Clone, Copy, Debug)]
pub struct Widest {
    pub source: VertexId,
}

impl Widest {
    pub fn new(source: VertexId) -> Self {
        Widest { source }
    }
}

impl VertexProgram for Widest {
    fn name(&self) -> &'static str {
        "widest"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        // unreachable vertices stay at width 0 (capacities are positive);
        // the source itself has unconstrained width
        let mut v = vec![0.0f32; n as usize];
        if self.source < n {
            v[self.source as usize] = f32::INFINITY;
        }
        (v.into(), vec![self.source])
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::widest_path(EdgeCost::Weights)
    }
}

/// Weakly connected components / label propagation over exact u32
/// labels: each vertex starts labelled with its own id and keeps the
/// minimum label seen over its in-edges until fixpoint.  On a
/// symmetrised graph the fixpoint labels components; on a directed
/// graph it is min-label reachability (identical semantics to [`Cc`],
/// without the f32 2²⁴ id ceiling).
#[derive(Clone, Copy, Debug, Default)]
pub struct Wcc;

impl VertexProgram for Wcc {
    fn name(&self) -> &'static str {
        "wcc"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        let v: Vec<u32> = (0..n).collect();
        (v.into(), (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Zero).with_lane(LaneType::U32)
    }
}

/// BFS levels over exact u32 hop counts.  Unreached vertices sit at
/// `u32::MAX`; the saturating lane add keeps them there (`MAX ⊕ 1 =
/// MAX`), so no sentinel check is needed in the hot loop.
#[derive(Clone, Copy, Debug)]
pub struct BfsLevels {
    pub source: VertexId,
}

impl BfsLevels {
    pub fn new(source: VertexId) -> Self {
        BfsLevels { source }
    }
}

impl VertexProgram for BfsLevels {
    fn name(&self) -> &'static str {
        "bfs_levels"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        let mut v = vec![u32::MAX; n as usize];
        if self.source < n {
            v[self.source as usize] = 0;
        }
        (v.into(), vec![self.source])
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::relax_min(EdgeCost::Unit).with_lane(LaneType::U32)
    }
}

/// k-core decomposition membership via the synchronous peel: every
/// vertex starts alive (`1`), and each iteration keeps a vertex alive
/// iff at least `k` of its in-neighbors are alive.  Alive flags only
/// ever fall, so the fixpoint is the k-core indicator (run on the
/// symmetrised graph for the classic undirected k-core).  The peel is
/// selective-scheduling-safe: a vertex whose in-neighborhood did not
/// change cannot change either.
#[derive(Clone, Copy, Debug)]
pub struct KCore {
    pub k: u32,
}

impl KCore {
    pub fn new(k: u32) -> Self {
        KCore { k }
    }
}

impl VertexProgram for KCore {
    fn name(&self) -> &'static str {
        "kcore"
    }

    fn init(&self, n: u32) -> (LaneVec, Vec<VertexId>) {
        (vec![1u32; n as usize].into(), (0..n).collect())
    }

    fn kernel(&self) -> ShardKernel {
        ShardKernel::kcore(self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pagerank_init_uniform_all_active() {
        let (v, active) = PageRank::new().init(4);
        assert_eq!(v, vec![0.25; 4]);
        assert_eq!(active.len(), 4);
    }

    #[test]
    fn sssp_init_source_only() {
        let (v, active) = Sssp::new(2).init(4);
        assert_eq!(v.f32s()[2], 0.0);
        assert!(v.f32s()[0].is_infinite());
        assert_eq!(active, vec![2]);
    }

    #[test]
    fn cc_init_identity_labels() {
        let (v, active) = Cc.init(3);
        assert_eq!(v, vec![0.0, 1.0, 2.0]);
        assert_eq!(active.len(), 3);
    }

    #[test]
    fn ppr_init_mass_at_seed() {
        let (v, active) = Ppr::new(1).init(3);
        assert_eq!(v, vec![0.0, 1.0, 0.0]);
        assert_eq!(active.len(), 3);
    }

    #[test]
    fn widest_init_source_unbounded() {
        let (v, active) = Widest::new(0).init(3);
        assert!(v.f32s()[0].is_infinite());
        assert_eq!(v.f32s()[1], 0.0);
        assert_eq!(active, vec![0]);
    }

    #[test]
    fn wcc_init_own_labels_u32() {
        let (v, active) = Wcc.init(3);
        assert_eq!(v, LaneVec::from(vec![0u32, 1, 2]));
        assert_eq!(v.lane_type(), LaneType::U32);
        assert_eq!(active.len(), 3);
        assert_eq!(Wcc.kernel().lane, LaneType::U32);
        assert!(!Wcc.needs_weights());
    }

    #[test]
    fn bfs_levels_init_saturating_frontier() {
        let (v, active) = BfsLevels::new(1).init(3);
        assert_eq!(v, LaneVec::from(vec![u32::MAX, 0, u32::MAX]));
        assert_eq!(active, vec![1]);
        let k = BfsLevels::new(1).kernel();
        assert_eq!(k.lane, LaneType::U32);
        // unreached stays unreached: MAX ⊕ 1 saturates
        assert_eq!(k.edge_value_t::<u32>(u32::MAX, 0.0, 7.0), u32::MAX);
        assert_eq!(k.edge_value_t::<u32>(2, 0.0, 7.0), 3);
    }

    #[test]
    fn kcore_peel_semantics() {
        let (v, active) = KCore::new(2).init(4);
        assert_eq!(v, LaneVec::from(vec![1u32; 4]));
        assert_eq!(active.len(), 4);
        let k = ShardKernel::kcore(2);
        assert_eq!(k.lane, LaneType::U32);
        // gather counts alive in-neighbors
        assert_eq!(k.edge_value_t::<u32>(0, 0.0, 3.0), 0);
        assert_eq!(k.edge_value_t::<u32>(5, 0.0, 3.0), 1);
        // apply: dead stays dead, alive needs >= k alive neighbors
        assert_eq!(k.apply_t::<u32>(0, 4, 0, 99), 0);
        assert_eq!(k.apply_t::<u32>(0, 4, 1, 1), 0);
        assert_eq!(k.apply_t::<u32>(0, 4, 1, 2), 1);
    }

    #[test]
    fn update_semantics() {
        let pr = PageRank::new();
        assert!(pr.is_update(0.5, 0.6));
        assert!(pr.is_update(0.6, 0.5));
        assert!(!pr.is_update(0.5, 0.5));
        let ss = Sssp::new(0);
        assert!(ss.is_update(5.0, 3.0));
        assert!(!ss.is_update(3.0, 5.0));
        assert!(!ss.is_update(3.0, 3.0));
        let wd = Widest::new(0);
        assert!(wd.is_update(3.0, 5.0));
        assert!(!wd.is_update(5.0, 3.0));
        // integer activation mirrors the float rules exactly
        let wk = Wcc.kernel();
        assert!(wk.is_update_t::<u32>(5, 3));
        assert!(!wk.is_update_t::<u32>(3, 5));
        let kk = ShardKernel::kcore(2);
        assert!(kk.is_update_t::<u32>(1, 0));
        assert!(!kk.is_update_t::<u32>(1, 1));
    }

    #[test]
    fn edge_cost_apply() {
        assert_eq!(EdgeCost::Weights.apply(2.5), 2.5);
        assert_eq!(EdgeCost::Unit.apply(2.5), 1.0);
        assert_eq!(EdgeCost::Zero.apply(2.5), 0.0);
    }

    #[test]
    fn kernel_algebra() {
        let pr = ShardKernel::pagerank(0.85);
        assert_eq!(pr.identity(), 0.0);
        assert_eq!(pr.combine(1.0, 2.0), 3.0);
        assert_eq!(pr.edge_value(0.5, 0.25, 7.0), 0.125);
        // apply = 0.15/4 + 0.85*acc
        let n = 4;
        assert!((pr.apply(0, n, 0.0, 1.0) - (0.15 / 4.0 + 0.85)).abs() < 1e-7);

        let ss = ShardKernel::relax_min(EdgeCost::Weights);
        assert_eq!(ss.identity(), f32::INFINITY);
        assert_eq!(ss.combine(3.0, 5.0), 3.0);
        assert_eq!(ss.edge_value(1.0, 0.0, 2.0), 3.0);
        assert_eq!(ss.apply(0, n, 2.5, 3.0), 2.5);

        let wd = ShardKernel::widest_path(EdgeCost::Weights);
        assert_eq!(wd.identity(), f32::NEG_INFINITY);
        assert_eq!(wd.combine(3.0, 5.0), 5.0);
        assert_eq!(wd.edge_value(4.0, 0.0, 2.0), 2.0);
        assert_eq!(wd.apply(0, n, 3.0, 2.0), 3.0);
    }

    #[test]
    fn integer_kernel_algebra_saturates() {
        let ss64 = ShardKernel::relax_min(EdgeCost::Unit).with_lane(LaneType::U64);
        assert_eq!(ss64.lane, LaneType::U64);
        assert_eq!(ss64.identity_t::<u64>(), u64::MAX);
        assert_eq!(ss64.combine_t::<u64>(3, 5), 3);
        assert_eq!(ss64.edge_value_t::<u64>(u64::MAX, 0.0, 2.0), u64::MAX);
        let sum32 = ShardKernel::kcore(1);
        assert_eq!(sum32.identity_t::<u32>(), 0);
        assert_eq!(sum32.combine_t::<u32>(u32::MAX, 1), u32::MAX);
    }

    #[test]
    fn base_mass_distribution() {
        let u = BaseMass::Uniform { mass: 0.15 };
        assert!((u.at(0, 3) - 0.05).abs() < 1e-7);
        let s = BaseMass::Single { vertex: 2, mass: 0.15 };
        assert_eq!(s.at(2, 3), 0.15);
        assert_eq!(s.at(0, 3), 0.0);
    }

    #[test]
    fn aux_requirements() {
        assert!(PageRank::new().uses_out_degrees());
        assert!(Ppr::new(0).uses_out_degrees());
        assert!(!Sssp::new(0).uses_out_degrees());
        assert!(Sssp::new(0).needs_weights());
        assert!(!Cc.needs_weights());
        assert!(!Bfs::new(0).needs_weights());
        assert!(Widest::new(0).needs_weights());
        assert!(!Wcc.uses_out_degrees());
        assert!(!BfsLevels::new(0).needs_weights());
        assert!(!KCore::new(2).needs_weights());
        assert!(!KCore::new(2).uses_out_degrees());
    }
}
