//! On-disk shard format: one CSR edge shard per file, CRC-protected.
//!
//! Layout (all little-endian):
//! ```text
//! magic  "GMPS"            4B
//! shard_id                 u32
//! start_vertex             u32
//! rows                     u32
//! num_edges                u32
//! flags (bit0 = weighted)  u32
//! row_offsets              (rows+1) * u32
//! col                      num_edges * u32
//! weights                  num_edges * f32   (if weighted)
//! crc32 of everything above  u32
//! ```

use std::path::Path;

use anyhow::Result;

use crate::graph::{Csr, VertexId};
use crate::util::{bytes_as_f32s, bytes_as_u32s, f32s_as_bytes, u32s_as_bytes};

use super::disk::Disk;

pub(crate) const MAGIC: &[u8; 4] = b"GMPS";

/// A fully materialised shard: interval metadata + CSR edges.
#[derive(Clone, Debug, PartialEq)]
pub struct Shard {
    pub id: u32,
    /// Destination interval is `[start_vertex, start_vertex + rows)`.
    pub start_vertex: VertexId,
    pub csr: Csr,
}

impl Shard {
    pub fn rows(&self) -> usize {
        self.csr.rows()
    }

    pub fn num_edges(&self) -> usize {
        self.csr.num_edges()
    }

    pub fn end_vertex(&self) -> VertexId {
        self.start_vertex + self.rows() as u32
    }

    /// Serialise to the on-disk format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let weighted = self.csr.weights.is_some();
        let mut out = Vec::with_capacity(24 + self.csr.size_bytes() + 4);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&self.id.to_le_bytes());
        out.extend_from_slice(&self.start_vertex.to_le_bytes());
        out.extend_from_slice(&(self.rows() as u32).to_le_bytes());
        out.extend_from_slice(&(self.num_edges() as u32).to_le_bytes());
        out.extend_from_slice(&(weighted as u32).to_le_bytes());
        out.extend_from_slice(&u32s_as_bytes(&self.csr.row_offsets));
        out.extend_from_slice(&u32s_as_bytes(&self.csr.col));
        if let Some(w) = &self.csr.weights {
            out.extend_from_slice(&f32s_as_bytes(w));
        }
        let crc = crc32fast::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse + verify CRC.
    pub fn from_bytes(b: &[u8]) -> Result<Shard> {
        anyhow::ensure!(b.len() >= 28, "shard file too small ({}B)", b.len());
        anyhow::ensure!(&b[..4] == MAGIC, "bad shard magic");
        let body = &b[..b.len() - 4];
        let stored_crc = u32::from_le_bytes(b[b.len() - 4..].try_into().unwrap());
        let crc = crc32fast::hash(body);
        anyhow::ensure!(crc == stored_crc, "shard CRC mismatch: {crc:08x} != {stored_crc:08x}");
        let rd_u32 = |off: usize| u32::from_le_bytes(b[off..off + 4].try_into().unwrap());
        let id = rd_u32(4);
        let start_vertex = rd_u32(8);
        let rows = rd_u32(12) as usize;
        let num_edges = rd_u32(16) as usize;
        let weighted = rd_u32(20) != 0;
        let mut off = 24;
        let expect = 24 + (rows + 1) * 4 + num_edges * 4 * (1 + weighted as usize) + 4;
        anyhow::ensure!(b.len() == expect, "shard length {} != expected {}", b.len(), expect);
        let row_offsets = bytes_as_u32s(&b[off..off + (rows + 1) * 4]);
        off += (rows + 1) * 4;
        let col = bytes_as_u32s(&b[off..off + num_edges * 4]);
        off += num_edges * 4;
        let weights = if weighted {
            Some(bytes_as_f32s(&b[off..off + num_edges * 4]))
        } else {
            None
        };
        anyhow::ensure!(
            *row_offsets.last().unwrap() as usize == num_edges,
            "row_offsets end {} != num_edges {}",
            row_offsets.last().unwrap(),
            num_edges
        );
        Ok(Shard { id, start_vertex, csr: Csr { row_offsets, col, weights } })
    }

    pub fn write(&self, disk: &Disk, path: &Path) -> Result<()> {
        disk.write_file(path, &self.to_bytes())
    }

    pub fn read(disk: &Disk, path: &Path) -> Result<Shard> {
        Shard::from_bytes(&disk.read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn sample(weighted: bool) -> Shard {
        let edges = vec![
            Edge::weighted(5, 10, 2.0),
            Edge::weighted(7, 10, 3.0),
            Edge::weighted(1, 11, 1.0),
        ];
        Shard {
            id: 3,
            start_vertex: 10,
            csr: Csr::from_edges(&edges, 10, 2, weighted),
        }
    }

    #[test]
    fn round_trip_unweighted() {
        let s = sample(false);
        assert_eq!(Shard::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn round_trip_weighted() {
        let s = sample(true);
        assert_eq!(Shard::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn crc_detects_corruption() {
        let mut b = sample(true).to_bytes();
        b[30] ^= 0xff;
        let err = Shard::from_bytes(&b).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn rejects_truncation() {
        let b = sample(false).to_bytes();
        assert!(Shard::from_bytes(&b[..b.len() - 8]).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample(false).to_bytes();
        b[0] = b'X';
        assert!(Shard::from_bytes(&b).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("graphmp_shard_test");
        let _ = std::fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let s = sample(true);
        let p = dir.join("s.bin");
        s.write(&disk, &p).unwrap();
        assert_eq!(Shard::read(&disk, &p).unwrap(), s);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn interval_accessors() {
        let s = sample(false);
        assert_eq!(s.start_vertex, 10);
        assert_eq!(s.end_vertex(), 12);
        assert_eq!(s.num_edges(), 3);
    }
}
