//! Storage layer: shard files, graph metadata files, and the throttled
//! disk model that restores the paper's disk-bound regime at sim scale.

pub mod disk;
pub mod io_backend;
pub mod shard;
pub mod view;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::graph::VertexId;
use crate::util::{bytes_as_u32s, u32s_as_bytes};
use disk::Disk;

/// The property file: global info of the partitioned graph (paper §2.2).
/// Stored as a simple line format — `key value` or `interval start end`.
#[derive(Clone, Debug, PartialEq)]
pub struct Property {
    pub num_vertices: u32,
    pub num_edges: u64,
    pub num_shards: u32,
    pub weighted: bool,
    /// Shard `s` owns destination interval `[intervals[s].0, intervals[s].1)`.
    pub intervals: Vec<(VertexId, VertexId)>,
}

impl Property {
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("num_vertices {}\n", self.num_vertices));
        s.push_str(&format!("num_edges {}\n", self.num_edges));
        s.push_str(&format!("num_shards {}\n", self.num_shards));
        s.push_str(&format!("weighted {}\n", self.weighted as u8));
        for (a, b) in &self.intervals {
            s.push_str(&format!("interval {} {}\n", a, b));
        }
        s
    }

    pub fn from_text(text: &str) -> Result<Property> {
        let mut p = Property {
            num_vertices: 0,
            num_edges: 0,
            num_shards: 0,
            weighted: false,
            intervals: Vec::new(),
        };
        for line in text.lines() {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("num_vertices") => p.num_vertices = it.next().context("missing")?.parse()?,
                Some("num_edges") => p.num_edges = it.next().context("missing")?.parse()?,
                Some("num_shards") => p.num_shards = it.next().context("missing")?.parse()?,
                Some("weighted") => p.weighted = it.next().context("missing")? == "1",
                Some("interval") => {
                    let a = it.next().context("missing")?.parse()?;
                    let b = it.next().context("missing")?.parse()?;
                    p.intervals.push((a, b));
                }
                _ => {}
            }
        }
        anyhow::ensure!(
            p.intervals.len() == p.num_shards as usize,
            "interval count {} != num_shards {}",
            p.intervals.len(),
            p.num_shards
        );
        Ok(p)
    }
}

/// The vertex information file: per-vertex in/out-degree arrays plus the
/// (initial or updated) value array (paper §2.2).
#[derive(Clone, Debug, PartialEq)]
pub struct VertexInfo {
    pub in_degree: Vec<u32>,
    pub out_degree: Vec<u32>,
}

impl VertexInfo {
    pub fn to_bytes(&self) -> Vec<u8> {
        let n = self.in_degree.len() as u32;
        let mut out = Vec::with_capacity(8 + self.in_degree.len() * 8);
        out.extend_from_slice(b"GMPV");
        out.extend_from_slice(&n.to_le_bytes());
        out.extend_from_slice(&u32s_as_bytes(&self.in_degree));
        out.extend_from_slice(&u32s_as_bytes(&self.out_degree));
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<VertexInfo> {
        anyhow::ensure!(b.len() >= 8 && &b[..4] == b"GMPV", "bad vertex info magic");
        let n = u32::from_le_bytes([b[4], b[5], b[6], b[7]]) as usize;
        anyhow::ensure!(b.len() == 8 + n * 8, "vertex info truncated");
        let in_degree = bytes_as_u32s(&b[8..8 + n * 4]);
        let out_degree = bytes_as_u32s(&b[8 + n * 4..]);
        Ok(VertexInfo { in_degree, out_degree })
    }
}

/// Filesystem layout of one partitioned graph directory.
#[derive(Clone, Debug)]
pub struct GraphDir {
    pub root: PathBuf,
}

impl GraphDir {
    pub fn new<P: AsRef<Path>>(root: P) -> Self {
        GraphDir { root: root.as_ref().to_path_buf() }
    }

    pub fn property_path(&self) -> PathBuf {
        self.root.join("property.txt")
    }

    pub fn vertex_info_path(&self) -> PathBuf {
        self.root.join("vertices.bin")
    }

    pub fn shard_path(&self, shard_id: u32) -> PathBuf {
        self.root.join(format!("shard_{shard_id:05}.bin"))
    }

    pub fn bloom_path(&self) -> PathBuf {
        self.root.join("blooms.bin")
    }

    pub fn write_property(&self, disk: &Disk, p: &Property) -> Result<()> {
        disk.write_file(&self.property_path(), p.to_text().as_bytes())
    }

    pub fn read_property(&self, disk: &Disk) -> Result<Property> {
        let b = disk.read_file(&self.property_path())?;
        Property::from_text(std::str::from_utf8(&b)?)
    }

    pub fn write_vertex_info(&self, disk: &Disk, v: &VertexInfo) -> Result<()> {
        disk.write_file(&self.vertex_info_path(), &v.to_bytes())
    }

    pub fn read_vertex_info(&self, disk: &Disk) -> Result<VertexInfo> {
        VertexInfo::from_bytes(&disk.read_file(&self.vertex_info_path())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_round_trip() {
        let p = Property {
            num_vertices: 100,
            num_edges: 500,
            num_shards: 2,
            weighted: true,
            intervals: vec![(0, 50), (50, 100)],
        };
        assert_eq!(Property::from_text(&p.to_text()).unwrap(), p);
    }

    #[test]
    fn property_rejects_bad_interval_count() {
        let txt = "num_vertices 10\nnum_edges 5\nnum_shards 2\ninterval 0 10\n";
        assert!(Property::from_text(txt).is_err());
    }

    #[test]
    fn vertex_info_round_trip() {
        let v = VertexInfo {
            in_degree: vec![1, 2, 3],
            out_degree: vec![3, 2, 1],
        };
        assert_eq!(VertexInfo::from_bytes(&v.to_bytes()).unwrap(), v);
    }

    #[test]
    fn vertex_info_rejects_garbage() {
        assert!(VertexInfo::from_bytes(b"nope").is_err());
        let mut b = VertexInfo { in_degree: vec![1], out_degree: vec![1] }.to_bytes();
        b.truncate(b.len() - 1);
        assert!(VertexInfo::from_bytes(&b).is_err());
    }

    #[test]
    fn graph_dir_paths() {
        let d = GraphDir::new("/tmp/g");
        assert!(d.shard_path(3).to_str().unwrap().ends_with("shard_00003.bin"));
    }
}
