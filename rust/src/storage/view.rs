//! Zero-copy shard views over cache-line-aligned file buffers.
//!
//! `Shard::from_bytes` materialises three fresh `Vec`s (row offsets,
//! columns, weights) out of every shard file — at steady state that copy
//! is the dominant per-shard decode cost once I/O is overlapped (PR 1)
//! and the pipeline unified (PR 2).  NXgraph (PAPERS.md) streams
//! pre-laid-out binary blocks with no per-block parse; [`ShardView`] is
//! that idea for the GraphMP shard format: the on-disk layout has a
//! 24-byte header followed by `u32`/`f32` sections, so when the whole
//! file sits in an aligned buffer ([`AlignedBuf`]) every section can be
//! *borrowed* as a typed slice instead of copied.
//!
//! Alignment contract: the buffer *base* is 64-byte aligned (one cache
//! line, same contract as `exec::arena`), so streaming a shard never
//! splits its first bytes across lines and whole-buffer reads start
//! line-aligned.  The borrowed *sections* are only guaranteed 4-byte
//! alignment — the 24-byte header shifts them off the line — which is
//! exactly what the chunked kernels assume: they gather CSR values
//! scalarly and run their lane arithmetic on the 64-byte-aligned
//! accumulator arenas, not on these borrowed slices.
//!
//! Decode-once lifecycle (see `cache.rs`):
//!
//! 1. **load** — `Disk::read_file_aligned` fills an `AlignedBuf`;
//!    [`ShardView::parse`] validates structure **and CRC** exactly once.
//! 2. **admission** — the cache stores the view (mode 1) or the
//!    compressed bytes plus a memoized view (compressed modes).
//! 3. **hit** — an `Arc<ShardView>` clone: no allocation, no parse, no
//!    CRC pass ([`ShardView::parse_unverified`] on the rare memo-miss
//!    decode path, since the bytes were verified at admission).
//!
//! All targets this repo builds for are little-endian (see
//! `util::bytes_as_u32s`); the views reinterpret file bytes directly, so
//! that assumption is enforced at compile time here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::graph::{Csr, CsrRef, VertexId};
use crate::storage::shard::{Shard, MAGIC};

#[cfg(target_endian = "big")]
compile_error!("ShardView reinterprets little-endian shard files in place");

/// One 64-byte cache line of backing storage (mirrors `exec::arena`:
/// the alignment is a property of the type, so recycled buffers keep it).
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct Line([u32; 16]);

const LINE_BYTES: usize = 64;

/// A free list of [`AlignedBuf`] backing stores.
///
/// Mode-0 runs (no edge cache) re-read every scheduled shard from disk
/// each iteration, and each read used to allocate a fresh buffer that
/// died at the iteration barrier.  Buffers taken through
/// [`BufPool::take`] return their backing words here when the last
/// `Arc<ShardView>` holding them drops, so steady-state mode-0
/// iterations recycle at most `workers + prefetch_depth` buffers
/// instead of allocating one per shard.  Idle capacity is bounded
/// (`max_idle` buffers) and visible to the memory accounting via
/// [`idle_bytes`](Self::idle_bytes).
pub struct BufPool {
    bufs: Mutex<Vec<Vec<Line>>>,
    max_idle: usize,
    reused: AtomicU64,
    fresh: AtomicU64,
}

impl BufPool {
    /// A pool keeping at most `max_idle` buffers on the free list.
    pub fn new(max_idle: usize) -> Arc<BufPool> {
        Arc::new(BufPool {
            bufs: Mutex::new(Vec::new()),
            max_idle,
            reused: AtomicU64::new(0),
            fresh: AtomicU64::new(0),
        })
    }

    /// A pooled buffer of `len` bytes: reuses a free-listed backing
    /// store when one exists, allocating (zeroed) otherwise.  The buffer
    /// returns its words to `pool` on drop.
    ///
    /// Unlike [`AlignedBuf::with_len`], a *recycled* buffer's contents
    /// are unspecified — the caller must fill all `len` bytes before
    /// reading (the disk read path does, via `read_exact`).  Re-zeroing
    /// a recycled shard-sized buffer would cost a full memset per read,
    /// most of what the pool exists to save.
    pub fn take(pool: &Arc<BufPool>, len: usize) -> AlignedBuf {
        let lines_len = len.div_ceil(LINE_BYTES);
        let recycled = pool.bufs.lock().unwrap().pop();
        let lines = match recycled {
            Some(mut w) => {
                pool.reused.fetch_add(1, Ordering::Relaxed);
                // grow-with-zeros / truncate only: the live prefix is
                // overwritten by the caller, and bytes past `len` are
                // never exposed
                w.resize(lines_len, Line([0; 16]));
                w
            }
            None => {
                pool.fresh.fetch_add(1, Ordering::Relaxed);
                vec![Line([0; 16]); lines_len]
            }
        };
        AlignedBuf { lines, len, pool: Some(Arc::clone(pool)) }
    }

    fn put(&self, lines: Vec<Line>) {
        if lines.capacity() == 0 {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.max_idle {
            bufs.push(lines);
        }
    }

    /// Bytes held by idle free-listed buffers (charged by the engine's
    /// memory account — pooled capacity is real resident RAM).
    pub fn idle_bytes(&self) -> u64 {
        self.bufs
            .lock()
            .unwrap()
            .iter()
            .map(|w| (LINE_BYTES * w.capacity()) as u64)
            .sum()
    }

    /// `(reused, fresh)` take counts.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.reused.load(Ordering::Relaxed),
            self.fresh.load(Ordering::Relaxed),
        )
    }
}

/// A byte buffer whose base address is 64-byte (cache-line) aligned, so
/// `u32`/`f32` sections at 4-byte offsets can be borrowed as typed
/// slices and whole-buffer operations start line-aligned.
///
/// Backed by a `Vec<Line>` (alignment 64 guaranteed by the `Line` type,
/// for fresh and recycled allocations alike); the logical byte length
/// may be shorter than the backing lines.  Buffers handed out by a
/// [`BufPool`] return their backing store to it on drop.
pub struct AlignedBuf {
    lines: Vec<Line>,
    len: usize,
    pool: Option<Arc<BufPool>>,
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        AlignedBuf { lines: self.lines.clone(), len: self.len, pool: self.pool.clone() }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put(std::mem::take(&mut self.lines));
        }
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedBuf").field("len", &self.len).finish()
    }
}

impl AlignedBuf {
    /// A zero-filled buffer of `len` bytes (fill via
    /// [`as_bytes_mut`](Self::as_bytes_mut)).
    pub fn with_len(len: usize) -> AlignedBuf {
        AlignedBuf { lines: vec![Line([0; 16]); len.div_ceil(LINE_BYTES)], len, pool: None }
    }

    /// Copy `b` into a fresh aligned buffer.
    pub fn from_bytes(b: &[u8]) -> AlignedBuf {
        let mut buf = AlignedBuf::with_len(b.len());
        buf.as_bytes_mut().copy_from_slice(b);
        buf
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_bytes(&self) -> &[u8] {
        // SAFETY: the Vec<Line> allocation covers >= len bytes and u8
        // has no alignment or validity requirements.
        unsafe { std::slice::from_raw_parts(self.lines.as_ptr().cast::<u8>(), self.len) }
    }

    pub fn as_bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: as for `as_bytes`, plus `&mut self` guarantees
        // exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.lines.as_mut_ptr().cast::<u8>(), self.len) }
    }

    /// Borrow `n` little-endian `u32`s starting at `byte_off`.
    fn u32s(&self, byte_off: usize, n: usize) -> &[u32] {
        assert!(byte_off % 4 == 0, "unaligned u32 view at {byte_off}");
        assert!(byte_off + n * 4 <= self.len, "u32 view out of bounds");
        // SAFETY: in bounds (asserted), 4-byte aligned (base is
        // 64-aligned and byte_off % 4 == 0), and every bit pattern is a
        // valid u32.
        unsafe {
            std::slice::from_raw_parts(
                self.lines.as_ptr().cast::<u8>().add(byte_off).cast::<u32>(),
                n,
            )
        }
    }

    /// Borrow `n` little-endian `f32`s starting at `byte_off`.
    fn f32s(&self, byte_off: usize, n: usize) -> &[f32] {
        assert!(byte_off % 4 == 0, "unaligned f32 view at {byte_off}");
        assert!(byte_off + n * 4 <= self.len, "f32 view out of bounds");
        // SAFETY: as for `u32s`; every bit pattern is a valid f32 (NaN
        // payloads included).
        unsafe {
            std::slice::from_raw_parts(
                self.lines.as_ptr().cast::<u8>().add(byte_off).cast::<f32>(),
                n,
            )
        }
    }
}

/// A parsed-but-not-copied shard: header fields decoded once, the CSR
/// sections borrowed straight out of the owned [`AlignedBuf`].
///
/// Layout (must match `storage::shard`):
/// ```text
/// header  24B   magic/id/start/rows/edges/flags
/// row_offsets   (rows+1) * u32
/// col           num_edges * u32
/// weights       num_edges * f32   (if weighted)
/// crc32         4B
/// ```
pub struct ShardView {
    buf: AlignedBuf,
    id: u32,
    start_vertex: VertexId,
    rows: usize,
    num_edges: usize,
    weighted: bool,
}

impl std::fmt::Debug for ShardView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardView")
            .field("id", &self.id)
            .field("start_vertex", &self.start_vertex)
            .field("rows", &self.rows)
            .field("num_edges", &self.num_edges)
            .field("weighted", &self.weighted)
            .finish()
    }
}

impl ShardView {
    /// Parse the header, validate the structure **and verify the CRC** —
    /// the once-per-shard verification of the decode-once lifecycle.
    pub fn parse(buf: AlignedBuf) -> Result<ShardView> {
        Self::parse_impl(buf, true)
    }

    /// Parse with structural validation only, skipping the CRC pass.
    /// For buffers whose bytes were already verified (cache admission /
    /// first load) — re-hashing them on every decode is pure waste.
    pub fn parse_unverified(buf: AlignedBuf) -> Result<ShardView> {
        Self::parse_impl(buf, false)
    }

    fn parse_impl(buf: AlignedBuf, verify_crc: bool) -> Result<ShardView> {
        let b = buf.as_bytes();
        anyhow::ensure!(b.len() >= 28, "shard file too small ({}B)", b.len());
        anyhow::ensure!(&b[..4] == MAGIC, "bad shard magic");
        if verify_crc {
            let body = &b[..b.len() - 4];
            let stored = u32::from_le_bytes(b[b.len() - 4..].try_into().unwrap());
            let crc = crc32fast::hash(body);
            anyhow::ensure!(crc == stored, "shard CRC mismatch: {crc:08x} != {stored:08x}");
        }
        let rd = |off: usize| u32::from_le_bytes(b[off..off + 4].try_into().unwrap());
        let id = rd(4);
        let start_vertex = rd(8);
        let rows = rd(12) as usize;
        let num_edges = rd(16) as usize;
        let weighted = rd(20) != 0;
        let expect = 24 + (rows + 1) * 4 + num_edges * 4 * (1 + weighted as usize) + 4;
        anyhow::ensure!(b.len() == expect, "shard length {} != expected {}", b.len(), expect);
        let view = ShardView { buf, id, start_vertex, rows, num_edges, weighted };
        anyhow::ensure!(
            *view.row_offsets().last().unwrap() as usize == view.num_edges,
            "row_offsets end {} != num_edges {}",
            view.row_offsets().last().unwrap(),
            view.num_edges
        );
        Ok(view)
    }

    pub fn id(&self) -> u32 {
        self.id
    }

    /// Destination interval is `[start_vertex, start_vertex + rows)`.
    pub fn start_vertex(&self) -> VertexId {
        self.start_vertex
    }

    pub fn end_vertex(&self) -> VertexId {
        self.start_vertex + self.rows as u32
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    pub fn weighted(&self) -> bool {
        self.weighted
    }

    /// The whole on-disk image (header + sections + CRC): what the cache
    /// compresses and what the memory accounting charges.
    pub fn bytes(&self) -> &[u8] {
        self.buf.as_bytes()
    }

    pub fn size_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Row-offset section, borrowed in place.
    pub fn row_offsets(&self) -> &[u32] {
        self.buf.u32s(24, self.rows + 1)
    }

    /// Column (source id) section, borrowed in place.
    pub fn col(&self) -> &[u32] {
        self.buf.u32s(24 + (self.rows + 1) * 4, self.num_edges)
    }

    /// Weight section, borrowed in place (weighted shards only).
    pub fn weights(&self) -> Option<&[f32]> {
        if self.weighted {
            Some(
                self.buf
                    .f32s(24 + (self.rows + 1) * 4 + self.num_edges * 4, self.num_edges),
            )
        } else {
            None
        }
    }

    /// The borrowed-CSR form the kernel hot loops consume.
    pub fn csr_ref(&self) -> CsrRef<'_> {
        CsrRef {
            row_offsets: self.row_offsets(),
            col: self.col(),
            weights: self.weights(),
        }
    }

    /// Deep-copy into the owned [`Shard`] form (tests / compatibility;
    /// the hot path never calls this).
    pub fn to_shard(&self) -> Shard {
        Shard {
            id: self.id,
            start_vertex: self.start_vertex,
            csr: Csr {
                row_offsets: self.row_offsets().to_vec(),
                col: self.col().to_vec(),
                weights: self.weights().map(|w| w.to_vec()),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn sample(weighted: bool) -> Shard {
        let edges = vec![
            Edge::weighted(5, 10, 2.0),
            Edge::weighted(7, 10, 3.0),
            Edge::weighted(1, 11, 1.0),
        ];
        Shard {
            id: 3,
            start_vertex: 10,
            csr: Csr::from_edges(&edges, 10, 2, weighted),
        }
    }

    #[test]
    fn aligned_buf_round_trips_bytes() {
        for len in [0usize, 1, 3, 4, 5, 28, 1027] {
            let data: Vec<u8> = (0..len).map(|i| (i * 7) as u8).collect();
            let buf = AlignedBuf::from_bytes(&data);
            assert_eq!(buf.len(), len);
            assert_eq!(buf.as_bytes(), &data[..]);
        }
    }

    #[test]
    fn buf_pool_recycles_backing_stores() {
        let pool = BufPool::new(4);
        {
            let mut a = BufPool::take(&pool, 100);
            a.as_bytes_mut()[0] = 7;
            assert_eq!(a.len(), 100);
        } // drop returns the words
        assert!(pool.idle_bytes() >= 100);
        let mut b = BufPool::take(&pool, 60);
        assert_eq!(b.len(), 60);
        // recycled contents are unspecified: the caller fills them
        b.as_bytes_mut().fill(9);
        assert_eq!(b.as_bytes(), &[9u8; 60][..]);
        let (reused, fresh) = pool.stats();
        assert_eq!((reused, fresh), (1, 1));
        assert_eq!(pool.idle_bytes(), 0, "the only idle buffer was taken");
        drop(b);

        // a pooled buffer behaves exactly like a plain one
        let data: Vec<u8> = (0..97u8).collect();
        let mut c = BufPool::take(&pool, data.len());
        c.as_bytes_mut().copy_from_slice(&data);
        assert_eq!(c.as_bytes(), &data[..]);
        assert_eq!(
            c.as_bytes().as_ptr() as usize % 64,
            0,
            "pooled buffers keep the 64-byte base alignment"
        );
    }

    #[test]
    fn buf_pool_bounds_idle_buffers() {
        let pool = BufPool::new(2);
        let bufs: Vec<AlignedBuf> = (0..5).map(|_| BufPool::take(&pool, 64)).collect();
        drop(bufs);
        assert!(pool.idle_bytes() <= 2 * 64 + 8, "idle list must stay bounded");
        let n_idle = { pool.bufs.lock().unwrap().len() };
        assert_eq!(n_idle, 2);
    }

    #[test]
    fn pooled_shard_view_round_trips() {
        let pool = BufPool::new(4);
        let s = sample(true);
        let bytes = s.to_bytes();
        let mut buf = BufPool::take(&pool, bytes.len());
        buf.as_bytes_mut().copy_from_slice(&bytes);
        let v = ShardView::parse(buf).unwrap();
        assert_eq!(v.to_shard(), s);
        drop(v);
        assert!(pool.idle_bytes() > 0, "view drop must return the buffer");
    }

    #[test]
    fn base_is_line_aligned_sections_are_4_byte_aligned() {
        let s = sample(true);
        let v = ShardView::parse(AlignedBuf::from_bytes(&s.to_bytes())).unwrap();
        // buffer base: one cache line (the 24-byte header then shifts
        // the sections off the line, so they only guarantee 4 bytes)
        assert_eq!(v.bytes().as_ptr() as usize % 64, 0, "buffer base must be line-aligned");
        assert_eq!(v.row_offsets().as_ptr() as usize % 4, 0);
        assert_eq!(v.col().as_ptr() as usize % 4, 0);
        assert_eq!(v.weights().unwrap().as_ptr() as usize % 4, 0);
    }

    #[test]
    fn round_trips_match_deep_parse() {
        for weighted in [false, true] {
            let s = sample(weighted);
            let b = s.to_bytes();
            let v = ShardView::parse(AlignedBuf::from_bytes(&b)).unwrap();
            assert_eq!(v.to_shard(), Shard::from_bytes(&b).unwrap());
            assert_eq!(v.id(), s.id);
            assert_eq!(v.start_vertex(), s.start_vertex);
            assert_eq!(v.end_vertex(), s.end_vertex());
            assert_eq!(v.rows(), s.rows());
            assert_eq!(v.num_edges(), s.num_edges());
            assert_eq!(v.weighted(), weighted);
            assert_eq!(v.row_offsets(), &s.csr.row_offsets[..]);
            assert_eq!(v.col(), &s.csr.col[..]);
            assert_eq!(v.weights().map(|w| w.to_vec()), s.csr.weights);
        }
    }

    #[test]
    fn crc_detects_corruption_when_verifying() {
        let mut b = sample(true).to_bytes();
        b[30] ^= 0xff;
        let err = ShardView::parse(AlignedBuf::from_bytes(&b))
            .unwrap_err()
            .to_string();
        assert!(err.contains("CRC"), "{err}");
        // unverified parse accepts payload corruption (caller verified at
        // admission) but the structure is still checked
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b)).is_ok());
    }

    #[test]
    fn rejects_truncation_even_unverified() {
        let b = sample(false).to_bytes();
        assert!(ShardView::parse(AlignedBuf::from_bytes(&b[..b.len() - 8])).is_err());
        assert!(
            ShardView::parse_unverified(AlignedBuf::from_bytes(&b[..b.len() - 8])).is_err()
        );
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b[..10])).is_err());
    }

    #[test]
    fn rejects_bad_magic_and_header_lies() {
        let good = sample(false).to_bytes();
        let mut b = good.clone();
        b[0] = b'X';
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b)).is_err());
        // inflate the claimed edge count: length check must fire before
        // any section is borrowed
        let mut b = good.clone();
        b[16] = b[16].wrapping_add(1);
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b)).is_err());
    }

    #[test]
    fn csr_ref_matches_sections() {
        let s = sample(true);
        let v = ShardView::parse(AlignedBuf::from_bytes(&s.to_bytes())).unwrap();
        let r = v.csr_ref();
        assert_eq!(r.rows(), 2);
        assert_eq!(r.num_edges(), 3);
        assert_eq!(r.row_offsets, v.row_offsets());
        assert_eq!(r.col, v.col());
        assert_eq!(r.weights.unwrap(), v.weights().unwrap());
    }
}
