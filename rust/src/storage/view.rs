//! Zero-copy shard views over aligned file buffers.
//!
//! `Shard::from_bytes` materialises three fresh `Vec`s (row offsets,
//! columns, weights) out of every shard file — at steady state that copy
//! is the dominant per-shard decode cost once I/O is overlapped (PR 1)
//! and the pipeline unified (PR 2).  NXgraph (PAPERS.md) streams
//! pre-laid-out binary blocks with no per-block parse; [`ShardView`] is
//! that idea for the GraphMP shard format: the on-disk layout has a
//! 24-byte header followed by `u32`/`f32` sections, so when the whole
//! file sits in an aligned buffer ([`AlignedBuf`]) every section can be
//! *borrowed* as a typed slice instead of copied.
//!
//! Alignment contract (PR 9: backend-declared): the buffer *base* is
//! aligned to the I/O backend's requirement — at least 64 bytes (one
//! cache line, the historic contract shared with `exec::arena`), and
//! 4096 bytes for the direct-I/O backend so `O_DIRECT` can DMA straight
//! into the pooled buffer with no bounce copy.  Capacity is padded to
//! the same alignment ([`AlignedBuf::padded_capacity`]), which is what
//! block-granular direct reads transfer into.  The borrowed *sections*
//! are only guaranteed 4-byte alignment — the 24-byte header shifts
//! them off the line — which is exactly what the chunked kernels
//! assume: they gather CSR values scalarly and run their lane
//! arithmetic on the 64-byte-aligned accumulator arenas, not on these
//! borrowed slices.
//!
//! Decode-once lifecycle (see `cache.rs`):
//!
//! 1. **load** — `Disk::read_file_aligned` fills an `AlignedBuf`;
//!    [`ShardView::parse`] validates structure **and CRC** exactly once.
//! 2. **admission** — the cache stores the view (mode 1) or the
//!    compressed bytes plus a memoized view (compressed modes).
//! 3. **hit** — an `Arc<ShardView>` clone: no allocation, no parse, no
//!    CRC pass ([`ShardView::parse_unverified`] on the rare memo-miss
//!    decode path, since the bytes were verified at admission).
//!
//! All targets this repo builds for are little-endian (see
//! `util::bytes_as_u32s`); the views reinterpret file bytes directly, so
//! that assumption is enforced at compile time here.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::graph::{Csr, CsrRef, VertexId};
use crate::storage::shard::{Shard, MAGIC};

#[cfg(target_endian = "big")]
compile_error!("ShardView reinterprets little-endian shard files in place");

/// The minimum base alignment every [`AlignedBuf`] provides (one cache
/// line — the historic contract; backends may demand more, see
/// `storage::io_backend`).
pub const MIN_ALIGN: usize = 64;

/// One raw heap allocation: `cap` bytes at `align`.  `cap == 0` uses a
/// dangling (but aligned) pointer and owns no memory.
struct RawBuf {
    ptr: NonNull<u8>,
    cap: usize,
    align: usize,
}

// SAFETY: RawBuf is an owned, uniquely-referenced heap allocation — the
// raw pointer never aliases.
unsafe impl Send for RawBuf {}
unsafe impl Sync for RawBuf {}

impl RawBuf {
    /// Zeroed allocation of `cap` bytes (rounded up to `align` by the
    /// caller) at `align`.
    fn alloc_zeroed(cap: usize, align: usize) -> RawBuf {
        debug_assert!(align.is_power_of_two());
        debug_assert!(cap % align == 0);
        if cap == 0 {
            return RawBuf { ptr: NonNull::new(align as *mut u8).unwrap(), cap: 0, align };
        }
        let layout = Layout::from_size_align(cap, align).expect("aligned buffer layout");
        // SAFETY: layout has non-zero size.
        let p = unsafe { alloc_zeroed(layout) };
        let ptr = NonNull::new(p).unwrap_or_else(|| handle_alloc_error(layout));
        RawBuf { ptr, cap, align }
    }
}

impl Drop for RawBuf {
    fn drop(&mut self) {
        if self.cap > 0 {
            // SAFETY: allocated by alloc_zeroed with this exact layout.
            unsafe {
                dealloc(
                    self.ptr.as_ptr(),
                    Layout::from_size_align(self.cap, self.align).unwrap(),
                )
            };
            self.cap = 0;
        }
    }
}

/// A free list of [`AlignedBuf`] backing stores.
///
/// Mode-0 runs (no edge cache) re-read every scheduled shard from disk
/// each iteration, and each read used to allocate a fresh buffer that
/// died at the iteration barrier.  Buffers taken through
/// [`BufPool::take`] return their backing allocation here when the last
/// `Arc<ShardView>` holding them drops, so steady-state mode-0
/// iterations recycle at most `workers + prefetch_depth` buffers
/// instead of allocating one per shard.  Idle capacity is bounded
/// (`max_idle` buffers) and visible to the memory accounting via
/// [`idle_bytes`](Self::idle_bytes).
///
/// Every buffer in one pool shares the pool's base alignment
/// ([`align`](Self::align)), set to the I/O backend's requirement by the
/// engine ([`with_alignment`](Self::with_alignment)) so pooled reads are
/// `O_DIRECT`-eligible without copies.
pub struct BufPool {
    bufs: Mutex<Vec<RawBuf>>,
    align: usize,
    max_idle: usize,
    reused: AtomicU64,
    fresh: AtomicU64,
}

impl BufPool {
    /// A pool keeping at most `max_idle` buffers on the free list, at
    /// the default [`MIN_ALIGN`] base alignment.
    pub fn new(max_idle: usize) -> Arc<BufPool> {
        Self::with_alignment(max_idle, MIN_ALIGN)
    }

    /// A pool whose buffers are base-aligned (and capacity-padded) to
    /// `align` — the backend-declared value (64 for sim, 4096 for
    /// direct).  Clamped up to [`MIN_ALIGN`]; must be a power of two.
    pub fn with_alignment(max_idle: usize, align: usize) -> Arc<BufPool> {
        let align = align.max(MIN_ALIGN);
        assert!(align.is_power_of_two(), "pool alignment must be a power of two");
        Arc::new(BufPool {
            bufs: Mutex::new(Vec::new()),
            align,
            max_idle,
            reused: AtomicU64::new(0),
            fresh: AtomicU64::new(0),
        })
    }

    /// The base/padding alignment of every buffer this pool hands out.
    pub fn align(&self) -> usize {
        self.align
    }

    /// A pooled buffer of `len` bytes: reuses a free-listed backing
    /// store when one exists, allocating (zeroed) otherwise.  The buffer
    /// returns its allocation to `pool` on drop.
    ///
    /// Unlike [`AlignedBuf::with_len`], a *recycled* buffer's contents
    /// are unspecified — the caller must fill all `len` bytes before
    /// reading (the disk read path does, via `read_exact`).  Re-zeroing
    /// a recycled shard-sized buffer would cost a full memset per read,
    /// most of what the pool exists to save.
    pub fn take(pool: &Arc<BufPool>, len: usize) -> AlignedBuf {
        let cap = len.div_ceil(pool.align) * pool.align;
        let recycled = pool.bufs.lock().unwrap().pop();
        let raw = match recycled {
            Some(r) => {
                pool.reused.fetch_add(1, Ordering::Relaxed);
                if r.cap >= cap {
                    r
                } else {
                    // too small: drop it and regrow (still a pool take)
                    RawBuf::alloc_zeroed(cap, pool.align)
                }
            }
            None => {
                pool.fresh.fetch_add(1, Ordering::Relaxed);
                RawBuf::alloc_zeroed(cap, pool.align)
            }
        };
        AlignedBuf { raw, len, pool: Some(Arc::clone(pool)) }
    }

    fn put(&self, raw: RawBuf) {
        if raw.cap == 0 {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.max_idle {
            bufs.push(raw);
        }
    }

    /// Bytes held by idle free-listed buffers (charged by the engine's
    /// memory account — pooled capacity is real resident RAM).
    pub fn idle_bytes(&self) -> u64 {
        self.bufs.lock().unwrap().iter().map(|r| r.cap as u64).sum()
    }

    /// `(reused, fresh)` take counts.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.reused.load(Ordering::Relaxed),
            self.fresh.load(Ordering::Relaxed),
        )
    }
}

/// A byte buffer whose base address is aligned to a backend-declared
/// power of two (at least [`MIN_ALIGN`]), so `u32`/`f32` sections at
/// 4-byte offsets can be borrowed as typed slices, whole-buffer
/// operations start line-aligned, and — at 4096 — `O_DIRECT` reads can
/// land directly in it.
///
/// The allocation capacity is padded to the same alignment
/// ([`padded_capacity`](Self::padded_capacity)); block-granular direct
/// reads transfer into the padded slice
/// ([`as_padded_mut`](Self::as_padded_mut)) while the logical byte
/// length stays exact.  Buffers handed out by a [`BufPool`] return
/// their backing store to it on drop.
pub struct AlignedBuf {
    raw: RawBuf,
    len: usize,
    pool: Option<Arc<BufPool>>,
}

// SAFETY: AlignedBuf owns its allocation exclusively (see RawBuf).
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        let raw = RawBuf::alloc_zeroed(self.raw.cap, self.raw.align);
        if self.len > 0 {
            // SAFETY: both allocations cover >= len bytes and don't
            // overlap.
            unsafe {
                std::ptr::copy_nonoverlapping(self.raw.ptr.as_ptr(), raw.ptr.as_ptr(), self.len)
            };
        }
        AlignedBuf { raw, len: self.len, pool: self.pool.clone() }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            let empty = RawBuf { ptr: NonNull::new(self.raw.align as *mut u8).unwrap(), cap: 0, align: self.raw.align };
            pool.put(std::mem::replace(&mut self.raw, empty));
        }
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedBuf")
            .field("len", &self.len)
            .field("align", &self.raw.align)
            .finish()
    }
}

impl AlignedBuf {
    /// A zero-filled buffer of `len` bytes at the default [`MIN_ALIGN`]
    /// (fill via [`as_bytes_mut`](Self::as_bytes_mut)).
    pub fn with_len(len: usize) -> AlignedBuf {
        Self::with_alignment(len, MIN_ALIGN)
    }

    /// A zero-filled buffer of `len` bytes whose base and capacity
    /// padding honor `align` (clamped up to [`MIN_ALIGN`]; power of
    /// two).
    pub fn with_alignment(len: usize, align: usize) -> AlignedBuf {
        let align = align.max(MIN_ALIGN);
        assert!(align.is_power_of_two(), "buffer alignment must be a power of two");
        let cap = len.div_ceil(align) * align;
        AlignedBuf { raw: RawBuf::alloc_zeroed(cap, align), len, pool: None }
    }

    /// Copy `b` into a fresh aligned buffer.
    pub fn from_bytes(b: &[u8]) -> AlignedBuf {
        let mut buf = AlignedBuf::with_len(b.len());
        buf.as_bytes_mut().copy_from_slice(b);
        buf
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The base/padding alignment this buffer was allocated at.
    pub fn align(&self) -> usize {
        self.raw.align
    }

    /// Allocation size: [`len`](Self::len) rounded up to
    /// [`align`](Self::align) (possibly larger for a recycled pool
    /// buffer).  Block-granular direct reads transfer up to this much.
    pub fn padded_capacity(&self) -> usize {
        self.raw.cap
    }

    pub fn as_bytes(&self) -> &[u8] {
        // SAFETY: the allocation covers >= len bytes and u8 has no
        // alignment or validity requirements.
        unsafe { std::slice::from_raw_parts(self.raw.ptr.as_ptr(), self.len) }
    }

    pub fn as_bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: as for `as_bytes`, plus `&mut self` guarantees
        // exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.raw.ptr.as_ptr(), self.len) }
    }

    /// The whole padded allocation
    /// ([`padded_capacity`](Self::padded_capacity) bytes) as a mutable
    /// slice — the destination of block-granular `O_DIRECT` transfers.
    /// Bytes past [`len`](Self::len) are unspecified and never exposed
    /// through [`as_bytes`](Self::as_bytes).
    pub fn as_padded_mut(&mut self) -> &mut [u8] {
        // SAFETY: the allocation covers exactly `cap` bytes.
        unsafe { std::slice::from_raw_parts_mut(self.raw.ptr.as_ptr(), self.raw.cap) }
    }

    /// Borrow `n` little-endian `u32`s starting at `byte_off`.
    fn u32s(&self, byte_off: usize, n: usize) -> &[u32] {
        assert!(byte_off % 4 == 0, "unaligned u32 view at {byte_off}");
        assert!(byte_off + n * 4 <= self.len, "u32 view out of bounds");
        // SAFETY: in bounds (asserted), 4-byte aligned (base is at
        // least 64-aligned and byte_off % 4 == 0), and every bit
        // pattern is a valid u32.
        unsafe { std::slice::from_raw_parts(self.raw.ptr.as_ptr().add(byte_off).cast::<u32>(), n) }
    }

    /// Borrow `n` little-endian `f32`s starting at `byte_off`.
    fn f32s(&self, byte_off: usize, n: usize) -> &[f32] {
        assert!(byte_off % 4 == 0, "unaligned f32 view at {byte_off}");
        assert!(byte_off + n * 4 <= self.len, "f32 view out of bounds");
        // SAFETY: as for `u32s`; every bit pattern is a valid f32 (NaN
        // payloads included).
        unsafe { std::slice::from_raw_parts(self.raw.ptr.as_ptr().add(byte_off).cast::<f32>(), n) }
    }
}

/// A parsed-but-not-copied shard: header fields decoded once, the CSR
/// sections borrowed straight out of the owned [`AlignedBuf`].
///
/// Layout (must match `storage::shard`):
/// ```text
/// header  24B   magic/id/start/rows/edges/flags
/// row_offsets   (rows+1) * u32
/// col           num_edges * u32
/// weights       num_edges * f32   (if weighted)
/// crc32         4B
/// ```
pub struct ShardView {
    buf: AlignedBuf,
    id: u32,
    start_vertex: VertexId,
    rows: usize,
    num_edges: usize,
    weighted: bool,
}

impl std::fmt::Debug for ShardView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardView")
            .field("id", &self.id)
            .field("start_vertex", &self.start_vertex)
            .field("rows", &self.rows)
            .field("num_edges", &self.num_edges)
            .field("weighted", &self.weighted)
            .finish()
    }
}

impl ShardView {
    /// Parse the header, validate the structure **and verify the CRC** —
    /// the once-per-shard verification of the decode-once lifecycle.
    pub fn parse(buf: AlignedBuf) -> Result<ShardView> {
        Self::parse_impl(buf, true)
    }

    /// Parse with structural validation only, skipping the CRC pass.
    /// For buffers whose bytes were already verified (cache admission /
    /// first load) — re-hashing them on every decode is pure waste.
    pub fn parse_unverified(buf: AlignedBuf) -> Result<ShardView> {
        Self::parse_impl(buf, false)
    }

    fn parse_impl(buf: AlignedBuf, verify_crc: bool) -> Result<ShardView> {
        // The backend-declared alignment contract must hold by
        // construction for every buffer that reaches a view.
        debug_assert!(buf.align() >= MIN_ALIGN);
        debug_assert_eq!(
            buf.as_bytes().as_ptr() as usize % buf.align(),
            0,
            "shard buffer base must honor its declared alignment"
        );
        let b = buf.as_bytes();
        anyhow::ensure!(b.len() >= 28, "shard file too small ({}B)", b.len());
        anyhow::ensure!(&b[..4] == MAGIC, "bad shard magic");
        if verify_crc {
            let body = &b[..b.len() - 4];
            let stored = u32::from_le_bytes(b[b.len() - 4..].try_into().unwrap());
            let crc = crc32fast::hash(body);
            anyhow::ensure!(crc == stored, "shard CRC mismatch: {crc:08x} != {stored:08x}");
        }
        let rd = |off: usize| u32::from_le_bytes(b[off..off + 4].try_into().unwrap());
        let id = rd(4);
        let start_vertex = rd(8);
        let rows = rd(12) as usize;
        let num_edges = rd(16) as usize;
        let weighted = rd(20) != 0;
        let expect = 24 + (rows + 1) * 4 + num_edges * 4 * (1 + weighted as usize) + 4;
        anyhow::ensure!(b.len() == expect, "shard length {} != expected {}", b.len(), expect);
        let view = ShardView { buf, id, start_vertex, rows, num_edges, weighted };
        anyhow::ensure!(
            *view.row_offsets().last().unwrap() as usize == view.num_edges,
            "row_offsets end {} != num_edges {}",
            view.row_offsets().last().unwrap(),
            view.num_edges
        );
        Ok(view)
    }

    pub fn id(&self) -> u32 {
        self.id
    }

    /// Destination interval is `[start_vertex, start_vertex + rows)`.
    pub fn start_vertex(&self) -> VertexId {
        self.start_vertex
    }

    pub fn end_vertex(&self) -> VertexId {
        self.start_vertex + self.rows as u32
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    pub fn weighted(&self) -> bool {
        self.weighted
    }

    /// The whole on-disk image (header + sections + CRC): what the cache
    /// compresses and what the memory accounting charges.
    pub fn bytes(&self) -> &[u8] {
        self.buf.as_bytes()
    }

    pub fn size_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Row-offset section, borrowed in place.
    pub fn row_offsets(&self) -> &[u32] {
        self.buf.u32s(24, self.rows + 1)
    }

    /// Column (source id) section, borrowed in place.
    pub fn col(&self) -> &[u32] {
        self.buf.u32s(24 + (self.rows + 1) * 4, self.num_edges)
    }

    /// Weight section, borrowed in place (weighted shards only).
    pub fn weights(&self) -> Option<&[f32]> {
        if self.weighted {
            Some(
                self.buf
                    .f32s(24 + (self.rows + 1) * 4 + self.num_edges * 4, self.num_edges),
            )
        } else {
            None
        }
    }

    /// The borrowed-CSR form the kernel hot loops consume.
    pub fn csr_ref(&self) -> CsrRef<'_> {
        CsrRef {
            row_offsets: self.row_offsets(),
            col: self.col(),
            weights: self.weights(),
        }
    }

    /// Deep-copy into the owned [`Shard`] form (tests / compatibility;
    /// the hot path never calls this).
    pub fn to_shard(&self) -> Shard {
        Shard {
            id: self.id,
            start_vertex: self.start_vertex,
            csr: Csr {
                row_offsets: self.row_offsets().to_vec(),
                col: self.col().to_vec(),
                weights: self.weights().map(|w| w.to_vec()),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn sample(weighted: bool) -> Shard {
        let edges = vec![
            Edge::weighted(5, 10, 2.0),
            Edge::weighted(7, 10, 3.0),
            Edge::weighted(1, 11, 1.0),
        ];
        Shard {
            id: 3,
            start_vertex: 10,
            csr: Csr::from_edges(&edges, 10, 2, weighted),
        }
    }

    #[test]
    fn aligned_buf_round_trips_bytes() {
        for len in [0usize, 1, 3, 4, 5, 28, 1027] {
            let data: Vec<u8> = (0..len).map(|i| (i * 7) as u8).collect();
            let buf = AlignedBuf::from_bytes(&data);
            assert_eq!(buf.len(), len);
            assert_eq!(buf.as_bytes(), &data[..]);
        }
    }

    #[test]
    fn aligned_buf_honors_requested_alignment() {
        for align in [64usize, 512, 4096] {
            for len in [0usize, 1, 4095, 4096, 4097, 10_000] {
                let buf = AlignedBuf::with_alignment(len, align);
                assert_eq!(buf.align(), align);
                assert_eq!(buf.as_bytes().as_ptr() as usize % align, 0, "align={align} len={len}");
                assert_eq!(buf.padded_capacity() % align, 0);
                assert!(buf.padded_capacity() >= len);
                assert!(buf.as_bytes().iter().all(|&b| b == 0), "fresh buffers are zeroed");
            }
        }
    }

    #[test]
    fn padded_slice_covers_capacity() {
        let mut buf = AlignedBuf::with_alignment(5000, 4096);
        assert_eq!(buf.padded_capacity(), 8192);
        buf.as_padded_mut().fill(3);
        assert_eq!(buf.as_bytes(), &[3u8; 5000][..], "logical view stays len-bounded");
        let cloned = buf.clone();
        assert_eq!(cloned.as_bytes(), buf.as_bytes());
        assert_eq!(cloned.align(), 4096);
    }

    #[test]
    fn buf_pool_recycles_backing_stores() {
        let pool = BufPool::new(4);
        {
            let mut a = BufPool::take(&pool, 100);
            a.as_bytes_mut()[0] = 7;
            assert_eq!(a.len(), 100);
        } // drop returns the allocation
        assert!(pool.idle_bytes() >= 100);
        let mut b = BufPool::take(&pool, 60);
        assert_eq!(b.len(), 60);
        // recycled contents are unspecified: the caller fills them
        b.as_bytes_mut().fill(9);
        assert_eq!(b.as_bytes(), &[9u8; 60][..]);
        let (reused, fresh) = pool.stats();
        assert_eq!((reused, fresh), (1, 1));
        assert_eq!(pool.idle_bytes(), 0, "the only idle buffer was taken");
        drop(b);

        // a pooled buffer behaves exactly like a plain one
        let data: Vec<u8> = (0..97u8).collect();
        let mut c = BufPool::take(&pool, data.len());
        c.as_bytes_mut().copy_from_slice(&data);
        assert_eq!(c.as_bytes(), &data[..]);
        assert_eq!(
            c.as_bytes().as_ptr() as usize % 64,
            0,
            "pooled buffers keep the 64-byte base alignment"
        );
    }

    #[test]
    fn buf_pool_bounds_idle_buffers() {
        let pool = BufPool::new(2);
        let bufs: Vec<AlignedBuf> = (0..5).map(|_| BufPool::take(&pool, 64)).collect();
        drop(bufs);
        assert!(pool.idle_bytes() <= 2 * 64 + 8, "idle list must stay bounded");
        let n_idle = { pool.bufs.lock().unwrap().len() };
        assert_eq!(n_idle, 2);
    }

    #[test]
    fn block_aligned_pool_serves_direct_io_contract() {
        let pool = BufPool::with_alignment(4, 4096);
        assert_eq!(pool.align(), 4096);
        let a = BufPool::take(&pool, 5000);
        assert_eq!(a.as_bytes().as_ptr() as usize % 4096, 0);
        assert_eq!(a.align(), 4096);
        assert_eq!(a.padded_capacity(), 8192);
        drop(a);
        // recycled buffers keep the pool's alignment
        let b = BufPool::take(&pool, 100);
        assert_eq!(b.as_bytes().as_ptr() as usize % 4096, 0);
        assert_eq!(pool.stats(), (1, 1));
    }

    #[test]
    fn pooled_shard_view_round_trips() {
        let pool = BufPool::new(4);
        let s = sample(true);
        let bytes = s.to_bytes();
        let mut buf = BufPool::take(&pool, bytes.len());
        buf.as_bytes_mut().copy_from_slice(&bytes);
        let v = ShardView::parse(buf).unwrap();
        assert_eq!(v.to_shard(), s);
        drop(v);
        assert!(pool.idle_bytes() > 0, "view drop must return the buffer");
    }

    #[test]
    fn base_is_line_aligned_sections_are_4_byte_aligned() {
        let s = sample(true);
        let v = ShardView::parse(AlignedBuf::from_bytes(&s.to_bytes())).unwrap();
        // buffer base: one cache line (the 24-byte header then shifts
        // the sections off the line, so they only guarantee 4 bytes)
        assert_eq!(v.bytes().as_ptr() as usize % 64, 0, "buffer base must be line-aligned");
        assert_eq!(v.row_offsets().as_ptr() as usize % 4, 0);
        assert_eq!(v.col().as_ptr() as usize % 4, 0);
        assert_eq!(v.weights().unwrap().as_ptr() as usize % 4, 0);
    }

    #[test]
    fn round_trips_match_deep_parse() {
        for weighted in [false, true] {
            let s = sample(weighted);
            let b = s.to_bytes();
            let v = ShardView::parse(AlignedBuf::from_bytes(&b)).unwrap();
            assert_eq!(v.to_shard(), Shard::from_bytes(&b).unwrap());
            assert_eq!(v.id(), s.id);
            assert_eq!(v.start_vertex(), s.start_vertex);
            assert_eq!(v.end_vertex(), s.end_vertex());
            assert_eq!(v.rows(), s.rows());
            assert_eq!(v.num_edges(), s.num_edges());
            assert_eq!(v.weighted(), weighted);
            assert_eq!(v.row_offsets(), &s.csr.row_offsets[..]);
            assert_eq!(v.col(), &s.csr.col[..]);
            assert_eq!(v.weights().map(|w| w.to_vec()), s.csr.weights);
        }
    }

    #[test]
    fn crc_detects_corruption_when_verifying() {
        let mut b = sample(true).to_bytes();
        b[30] ^= 0xff;
        let err = ShardView::parse(AlignedBuf::from_bytes(&b))
            .unwrap_err()
            .to_string();
        assert!(err.contains("CRC"), "{err}");
        // unverified parse accepts payload corruption (caller verified at
        // admission) but the structure is still checked
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b)).is_ok());
    }

    #[test]
    fn rejects_truncation_even_unverified() {
        let b = sample(false).to_bytes();
        assert!(ShardView::parse(AlignedBuf::from_bytes(&b[..b.len() - 8])).is_err());
        assert!(
            ShardView::parse_unverified(AlignedBuf::from_bytes(&b[..b.len() - 8])).is_err()
        );
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b[..10])).is_err());
    }

    #[test]
    fn rejects_bad_magic_and_header_lies() {
        let good = sample(false).to_bytes();
        let mut b = good.clone();
        b[0] = b'X';
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b)).is_err());
        // inflate the claimed edge count: length check must fire before
        // any section is borrowed
        let mut b = good.clone();
        b[16] = b[16].wrapping_add(1);
        assert!(ShardView::parse_unverified(AlignedBuf::from_bytes(&b)).is_err());
    }

    #[test]
    fn csr_ref_matches_sections() {
        let s = sample(true);
        let v = ShardView::parse(AlignedBuf::from_bytes(&s.to_bytes())).unwrap();
        let r = v.csr_ref();
        assert_eq!(r.rows(), 2);
        assert_eq!(r.num_edges(), 3);
        assert_eq!(r.row_offsets, v.row_offsets());
        assert_eq!(r.col, v.col());
        assert_eq!(r.weights.unwrap(), v.weights().unwrap());
    }
}
