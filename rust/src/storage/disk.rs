//! Throttled disk model.
//!
//! The paper's testbed is 4×4TB HDD RAID5 (~310MB/s sequential read shared
//! by all cores).  At sim scale the host page cache would hide all I/O, so
//! every engine in this repo routes file access through [`Disk`], which
//! (a) meters exact byte counts (the quantity Table 3 models) and
//! (b) optionally *simulates* HDD timing with a shared token bucket
//! (bandwidth) plus per-open seek latency.  Simulated seconds are accounted
//! in `IoStats::sim_nanos` rather than slept away, so benches stay fast
//! while reporting disk-bound timings — `elapsed = wall + sim` is what the
//! bench harness prints.

use std::fs;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

/// Bandwidth/latency profile of the simulated storage device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiskProfile {
    pub name: &'static str,
    /// Sequential read bandwidth in bytes/s (shared across threads).
    pub read_bw: u64,
    /// Sequential write bandwidth in bytes/s.
    pub write_bw: u64,
    /// Seek + request overhead charged per file open, in nanoseconds.
    pub seek_nanos: u64,
}

impl DiskProfile {
    /// The paper's RAID5 HDD array: 310MB/s read, 180MB/s write, ~5ms seek.
    pub fn hdd_raid5() -> Self {
        DiskProfile {
            name: "hdd-raid5",
            read_bw: 310 * 1024 * 1024,
            write_bw: 180 * 1024 * 1024,
            seek_nanos: 5_000_000,
        }
    }

    /// The per-core *share* of the RAID5 array on the paper's 12-core box
    /// (§2.4.2: "the available disk bandwidth is shared by all CPU cores",
    /// while decompression runs per-core).  Our bench host has one core,
    /// so charging each worker the full 310MB/s would make the simulated
    /// disk 12× faster *relative to compute* than the paper's testbed —
    /// this profile restores the paper's disk/compute balance.
    pub fn hdd_raid5_shared(cores: u64) -> Self {
        let full = Self::hdd_raid5();
        DiskProfile {
            name: "hdd-raid5/core-share",
            read_bw: full.read_bw / cores.max(1),
            write_bw: full.write_bw / cores.max(1),
            seek_nanos: full.seek_nanos,
        }
    }

    /// A SATA SSD profile (for the FlashGraph-adjacent ablation).
    pub fn ssd() -> Self {
        DiskProfile {
            name: "ssd",
            read_bw: 2 * 1024 * 1024 * 1024,
            write_bw: 1024 * 1024 * 1024,
            seek_nanos: 60_000,
        }
    }

    /// No simulation: byte metering only (used by unit tests).
    pub fn unthrottled() -> Self {
        DiskProfile { name: "unthrottled", read_bw: 0, write_bw: 0, seek_nanos: 0 }
    }
}

/// Cumulative I/O counters.  All atomic: engines hit the disk from worker
/// threads.
#[derive(Debug, Default)]
pub struct IoStats {
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub read_ops: AtomicU64,
    pub write_ops: AtomicU64,
    /// Simulated device time in nanoseconds (0 when unthrottled).
    pub sim_nanos: AtomicU64,
}

/// Point-in-time snapshot of [`IoStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IoSnapshot {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_ops: u64,
    pub write_ops: u64,
    pub sim_nanos: u64,
}

impl IoSnapshot {
    pub fn sim_seconds(&self) -> f64 {
        self.sim_nanos as f64 / 1e9
    }

    /// Delta between two snapshots (self - earlier).
    pub fn since(&self, earlier: &IoSnapshot) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.bytes_read - earlier.bytes_read,
            bytes_written: self.bytes_written - earlier.bytes_written,
            read_ops: self.read_ops - earlier.read_ops,
            write_ops: self.write_ops - earlier.write_ops,
            sim_nanos: self.sim_nanos - earlier.sim_nanos,
        }
    }
}

/// The shared disk handle: all file I/O of every engine goes through here.
#[derive(Clone)]
pub struct Disk {
    profile: DiskProfile,
    stats: Arc<IoStats>,
}

impl Disk {
    pub fn new(profile: DiskProfile) -> Self {
        Disk { profile, stats: Arc::new(IoStats::default()) }
    }

    pub fn unthrottled() -> Self {
        Disk::new(DiskProfile::unthrottled())
    }

    pub fn profile(&self) -> DiskProfile {
        self.profile
    }

    pub fn snapshot(&self) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.stats.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.stats.bytes_written.load(Ordering::Relaxed),
            read_ops: self.stats.read_ops.load(Ordering::Relaxed),
            write_ops: self.stats.write_ops.load(Ordering::Relaxed),
            sim_nanos: self.stats.sim_nanos.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.stats.bytes_read.store(0, Ordering::Relaxed);
        self.stats.bytes_written.store(0, Ordering::Relaxed);
        self.stats.read_ops.store(0, Ordering::Relaxed);
        self.stats.write_ops.store(0, Ordering::Relaxed);
        self.stats.sim_nanos.store(0, Ordering::Relaxed);
    }

    /// Read a whole file, metering + simulating device time.
    pub fn read_file(&self, path: &Path) -> Result<Vec<u8>> {
        let data = fs::read(path).with_context(|| format!("read {}", path.display()))?;
        self.account_read(data.len() as u64);
        Ok(data)
    }

    /// Read a whole file into a 4-byte-aligned buffer (zero-copy shard
    /// views borrow typed sections straight out of it).  Metered exactly
    /// like [`read_file`](Self::read_file).
    pub fn read_file_aligned(&self, path: &Path) -> Result<super::view::AlignedBuf> {
        self.read_file_aligned_with(path, super::view::AlignedBuf::with_len)
    }

    /// [`read_file_aligned`](Self::read_file_aligned) into a buffer
    /// leased from `pool`: mode-0 runs re-read every shard per iteration,
    /// and the pool recycles the buffers across iterations instead of
    /// allocating one per shard (PR-3 follow-up).
    pub fn read_file_aligned_pooled(
        &self,
        path: &Path,
        pool: &Arc<super::view::BufPool>,
    ) -> Result<super::view::AlignedBuf> {
        self.read_file_aligned_with(path, |len| super::view::BufPool::take(pool, len))
    }

    /// The one metered aligned-read path: `alloc` supplies the
    /// destination buffer (fresh or pooled) for the file's length.
    fn read_file_aligned_with(
        &self,
        path: &Path,
        alloc: impl FnOnce(usize) -> super::view::AlignedBuf,
    ) -> Result<super::view::AlignedBuf> {
        use std::io::Read;
        let mut f =
            fs::File::open(path).with_context(|| format!("read {}", path.display()))?;
        let len = f.metadata()?.len() as usize;
        let mut buf = alloc(len);
        f.read_exact(buf.as_bytes_mut())
            .with_context(|| format!("read {}", path.display()))?;
        self.account_read(len as u64);
        Ok(buf)
    }

    /// Write a whole file.
    pub fn write_file(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, bytes).with_context(|| format!("write {}", path.display()))?;
        self.account_write(bytes.len() as u64);
        Ok(())
    }

    /// Append to a file (preprocessing step 2 writes shard scratch files
    /// this way). Charged as one op.
    pub fn append_file(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        use std::io::Write;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(bytes)?;
        self.account_write(bytes.len() as u64);
        Ok(())
    }

    /// Meter a read that bypassed the filesystem (e.g. a baseline engine
    /// streaming from an in-memory copy to model pure sequential I/O).
    pub fn account_read(&self, bytes: u64) {
        self.stats.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.stats.read_ops.fetch_add(1, Ordering::Relaxed);
        self.charge(bytes, self.profile.read_bw);
    }

    pub fn account_write(&self, bytes: u64) {
        self.stats.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.stats.write_ops.fetch_add(1, Ordering::Relaxed);
        self.charge(bytes, self.profile.write_bw);
    }

    fn charge(&self, bytes: u64, bw: u64) {
        if bw == 0 {
            return;
        }
        let nanos = self.profile.seek_nanos + bytes.saturating_mul(1_000_000_000) / bw;
        self.stats.sim_nanos.fetch_add(nanos, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meters_bytes() {
        let dir = std::env::temp_dir().join("graphmp_disk_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("x.bin");
        disk.write_file(&p, &[0u8; 1000]).unwrap();
        let b = disk.read_file(&p).unwrap();
        assert_eq!(b.len(), 1000);
        let s = disk.snapshot();
        assert_eq!(s.bytes_written, 1000);
        assert_eq!(s.bytes_read, 1000);
        assert_eq!(s.read_ops, 1);
        assert_eq!(s.sim_nanos, 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn aligned_read_matches_plain_read() {
        let dir = std::env::temp_dir().join("graphmp_disk_aligned_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("a.bin");
        let data: Vec<u8> = (0..1001u32).map(|i| (i % 251) as u8).collect();
        disk.write_file(&p, &data).unwrap();
        let buf = disk.read_file_aligned(&p).unwrap();
        assert_eq!(buf.as_bytes(), &data[..]);
        assert_eq!(buf.as_bytes().as_ptr() as usize % 4, 0);
        let s = disk.snapshot();
        assert_eq!(s.bytes_read, 1001);
        assert_eq!(s.read_ops, 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pooled_aligned_read_matches_and_recycles() {
        let dir = std::env::temp_dir().join("graphmp_disk_pooled_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("a.bin");
        let data: Vec<u8> = (0..777u32).map(|i| (i % 253) as u8).collect();
        disk.write_file(&p, &data).unwrap();
        let pool = crate::storage::view::BufPool::new(4);
        let buf = disk.read_file_aligned_pooled(&p, &pool).unwrap();
        assert_eq!(buf.as_bytes(), &data[..]);
        drop(buf);
        let buf2 = disk.read_file_aligned_pooled(&p, &pool).unwrap();
        assert_eq!(buf2.as_bytes(), &data[..]);
        assert_eq!(pool.stats().0, 1, "second read must reuse the buffer");
        assert_eq!(disk.snapshot().bytes_read, 2 * 777, "metering unchanged");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hdd_simulated_time_scales_with_bytes() {
        let disk = Disk::new(DiskProfile::hdd_raid5());
        disk.account_read(310 * 1024 * 1024); // exactly 1 second of reads
        let s = disk.snapshot();
        let secs = s.sim_seconds();
        assert!((secs - 1.005).abs() < 0.01, "simulated {secs}s");
    }

    #[test]
    fn seek_charged_per_op() {
        let disk = Disk::new(DiskProfile::hdd_raid5());
        for _ in 0..10 {
            disk.account_read(0);
        }
        assert_eq!(disk.snapshot().sim_nanos, 50_000_000);
    }

    #[test]
    fn snapshot_delta() {
        let disk = Disk::unthrottled();
        disk.account_read(100);
        let a = disk.snapshot();
        disk.account_read(50);
        let d = disk.snapshot().since(&a);
        assert_eq!(d.bytes_read, 50);
        assert_eq!(d.read_ops, 1);
    }

    #[test]
    fn reset_clears() {
        let disk = Disk::unthrottled();
        disk.account_write(10);
        disk.reset();
        assert_eq!(disk.snapshot(), IoSnapshot::default());
    }

    #[test]
    fn append_accumulates() {
        let dir = std::env::temp_dir().join("graphmp_disk_append_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("a.bin");
        disk.append_file(&p, b"ab").unwrap();
        disk.append_file(&p, b"cd").unwrap();
        assert_eq!(disk.read_file(&p).unwrap(), b"abcd");
        fs::remove_dir_all(&dir).unwrap();
    }
}
