//! The shared disk handle: byte metering, the throttled cost model, and
//! the pluggable I/O backend behind it.
//!
//! The paper's testbed is 4×4TB HDD RAID5 (~310MB/s sequential read shared
//! by all cores).  At sim scale the host page cache would hide all I/O, so
//! every engine in this repo routes file access through [`Disk`], which
//! (a) meters exact byte counts (the quantity Table 3 models) and
//! (b) optionally *simulates* HDD timing with a shared token bucket
//! (bandwidth) plus per-open seek latency.  Simulated seconds are accounted
//! in `IoStats::sim_nanos` rather than slept away, so benches stay fast
//! while reporting disk-bound timings — `elapsed = wall + sim` is what the
//! bench harness prints.
//!
//! Since PR 9 the *mechanics* of each read are delegated to an
//! [`IoBackend`] (see `storage::io_backend`): the default [`SimBackend`]
//! keeps the behaviour above exactly, while
//! [`DirectIoBackend`](super::io_backend::DirectIoBackend) reads through
//! `O_DIRECT` + a batched submission ring against real storage.  On a
//! real backend `sim_nanos` stays 0 (I/O cost is genuine wall time) and
//! per-read latency histograms are recorded instead
//! ([`IoSnapshot::read_lat_shard`] / [`IoSnapshot::read_lat_meta`]);
//! byte/op metering and the fault-injection + retry machinery are
//! backend-independent.

use std::fs;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::io_backend::{
    with_read_retries, with_write_retries, FaultPlan, FaultRule, IoBackend, LatHistogram,
    ReadClass, SimBackend,
};
// Re-exported here for compatibility: `RetryPolicy` predates the backend
// split and is addressed as `storage::disk::RetryPolicy` throughout.
pub use super::io_backend::{IoBackendKind, LatencySummary, RetryPolicy};

/// Bandwidth/latency profile of the simulated storage device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiskProfile {
    pub name: &'static str,
    /// Sequential read bandwidth in bytes/s (shared across threads).
    pub read_bw: u64,
    /// Sequential write bandwidth in bytes/s.
    pub write_bw: u64,
    /// Seek + request overhead charged per file open, in nanoseconds.
    pub seek_nanos: u64,
}

impl DiskProfile {
    /// The paper's RAID5 HDD array: 310MB/s read, 180MB/s write, ~5ms seek.
    pub fn hdd_raid5() -> Self {
        DiskProfile {
            name: "hdd-raid5",
            read_bw: 310 * 1024 * 1024,
            write_bw: 180 * 1024 * 1024,
            seek_nanos: 5_000_000,
        }
    }

    /// The per-core *share* of the RAID5 array on the paper's 12-core box
    /// (§2.4.2: "the available disk bandwidth is shared by all CPU cores",
    /// while decompression runs per-core).  Our bench host has one core,
    /// so charging each worker the full 310MB/s would make the simulated
    /// disk 12× faster *relative to compute* than the paper's testbed —
    /// this profile restores the paper's disk/compute balance.
    pub fn hdd_raid5_shared(cores: u64) -> Self {
        let full = Self::hdd_raid5();
        DiskProfile {
            name: "hdd-raid5/core-share",
            read_bw: full.read_bw / cores.max(1),
            write_bw: full.write_bw / cores.max(1),
            seek_nanos: full.seek_nanos,
        }
    }

    /// A SATA SSD profile (for the FlashGraph-adjacent ablation).
    pub fn ssd() -> Self {
        DiskProfile {
            name: "ssd",
            read_bw: 2 * 1024 * 1024 * 1024,
            write_bw: 1024 * 1024 * 1024,
            seek_nanos: 60_000,
        }
    }

    /// No simulation: byte metering only (used by unit tests).
    pub fn unthrottled() -> Self {
        DiskProfile { name: "unthrottled", read_bw: 0, write_bw: 0, seek_nanos: 0 }
    }
}

/// Cumulative I/O counters.  All atomic: engines hit the disk from worker
/// threads.
#[derive(Debug, Default)]
pub struct IoStats {
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub read_ops: AtomicU64,
    pub write_ops: AtomicU64,
    /// Simulated device time in nanoseconds (0 when unthrottled).
    pub sim_nanos: AtomicU64,
    /// Read attempts that failed and were retried (transient-error model).
    pub read_retries: AtomicU64,
    /// Write attempts that failed and were retried (transient-error model;
    /// only the durable checkpoint write path retries).
    pub write_retries: AtomicU64,
    /// Measured per-read wall-latency histograms, one per [`ReadClass`]
    /// (shard payload / metadata).  Only real backends record here —
    /// on the sim backend wall latency is a page-cache artifact and the
    /// histograms stay empty.
    pub read_lat: [LatHistogram; 2],
}

/// Point-in-time snapshot of [`IoStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IoSnapshot {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_ops: u64,
    pub write_ops: u64,
    pub sim_nanos: u64,
    pub read_retries: u64,
    pub write_retries: u64,
    /// Measured latency percentiles for aligned shard reads (real
    /// backends only; all-zero on sim).
    pub read_lat_shard: LatencySummary,
    /// Measured latency percentiles for buffered metadata reads (real
    /// backends only; all-zero on sim).
    pub read_lat_meta: LatencySummary,
}

impl IoSnapshot {
    pub fn sim_seconds(&self) -> f64 {
        self.sim_nanos as f64 / 1e9
    }

    /// Delta between two snapshots (self - earlier).  Latency summaries
    /// are percentile digests, not counters: the delta carries `self`'s
    /// cumulative summaries unchanged.
    pub fn since(&self, earlier: &IoSnapshot) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.bytes_read - earlier.bytes_read,
            bytes_written: self.bytes_written - earlier.bytes_written,
            read_ops: self.read_ops - earlier.read_ops,
            write_ops: self.write_ops - earlier.write_ops,
            sim_nanos: self.sim_nanos - earlier.sim_nanos,
            read_retries: self.read_retries - earlier.read_retries,
            write_retries: self.write_retries - earlier.write_retries,
            read_lat_shard: self.read_lat_shard,
            read_lat_meta: self.read_lat_meta,
        }
    }
}

/// The shared disk handle: all file I/O of every engine goes through here.
#[derive(Clone)]
pub struct Disk {
    profile: DiskProfile,
    backend: Arc<dyn IoBackend>,
    stats: Arc<IoStats>,
    faults: Arc<FaultPlan>,
}

impl Disk {
    /// A disk on the default [`SimBackend`] (profiled cost model).
    pub fn new(profile: DiskProfile) -> Self {
        Disk::with_backend(profile, Arc::new(SimBackend))
    }

    /// A disk reading through `backend`.  On a real backend the profile
    /// only labels the device: `sim_nanos` is never charged (I/O cost is
    /// genuine wall time) and per-read latency histograms are recorded
    /// instead.
    pub fn with_backend(profile: DiskProfile, backend: Arc<dyn IoBackend>) -> Self {
        Disk {
            profile,
            backend,
            stats: Arc::new(IoStats::default()),
            faults: Arc::new(FaultPlan::default()),
        }
    }

    pub fn unthrottled() -> Self {
        Disk::new(DiskProfile::unthrottled())
    }

    pub fn profile(&self) -> DiskProfile {
        self.profile
    }

    /// The I/O backend serving aligned reads.
    pub fn backend(&self) -> &Arc<dyn IoBackend> {
        &self.backend
    }

    /// Buffer alignment the backend requires — what `BufPool`s feeding
    /// this disk must allocate at (64 sim, 4096 direct).
    pub fn alignment(&self) -> usize {
        self.backend.alignment()
    }

    /// The backend's sustained submission depth; the prefetcher clamps
    /// its I/O fan-in to this.
    pub fn submission_depth(&self) -> usize {
        self.backend.submission_depth()
    }

    /// True when reads hit real storage (no simulated time, measured
    /// latency histograms instead).
    pub fn is_real_io(&self) -> bool {
        self.backend.is_real()
    }

    pub fn snapshot(&self) -> IoSnapshot {
        IoSnapshot {
            bytes_read: self.stats.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.stats.bytes_written.load(Ordering::Relaxed),
            read_ops: self.stats.read_ops.load(Ordering::Relaxed),
            write_ops: self.stats.write_ops.load(Ordering::Relaxed),
            sim_nanos: self.stats.sim_nanos.load(Ordering::Relaxed),
            read_retries: self.stats.read_retries.load(Ordering::Relaxed),
            write_retries: self.stats.write_retries.load(Ordering::Relaxed),
            read_lat_shard: self.stats.read_lat[ReadClass::Shard as usize].summary(),
            read_lat_meta: self.stats.read_lat[ReadClass::Meta as usize].summary(),
        }
    }

    pub fn reset(&self) {
        self.stats.bytes_read.store(0, Ordering::Relaxed);
        self.stats.bytes_written.store(0, Ordering::Relaxed);
        self.stats.read_ops.store(0, Ordering::Relaxed);
        self.stats.write_ops.store(0, Ordering::Relaxed);
        self.stats.sim_nanos.store(0, Ordering::Relaxed);
        self.stats.read_retries.store(0, Ordering::Relaxed);
        self.stats.write_retries.store(0, Ordering::Relaxed);
        for h in &self.stats.read_lat {
            h.reset();
        }
    }

    /// Arm a transient fault: after `skip` successful read attempts of any
    /// path containing `substr`, the next `count` attempts fail.  With the
    /// default [`RetryPolicy`] a job survives up to `max_retries` failures
    /// per read.
    pub fn inject_read_fault(&self, substr: &str, skip: u32, count: u32) {
        assert!(count > 0, "transient fault needs count >= 1");
        self.faults.rules.lock().unwrap().push(FaultRule {
            substr: substr.to_string(),
            skip,
            remaining: Some(count),
        });
    }

    /// Arm a hard fault: after `skip` successful attempts, every read of a
    /// matching path fails — exceeding any retry budget.
    pub fn inject_hard_read_fault(&self, substr: &str, skip: u32) {
        self.faults.rules.lock().unwrap().push(FaultRule {
            substr: substr.to_string(),
            skip,
            remaining: None,
        });
    }

    pub fn clear_read_faults(&self) {
        self.faults.rules.lock().unwrap().clear();
    }

    /// Arm a transient *write* fault: after `skip` successful write
    /// attempts of any path containing `substr`, the next `count` attempts
    /// fail.  The durable checkpoint write path retries under the same
    /// [`RetryPolicy`] as reads, counted in [`IoStats::write_retries`].
    pub fn inject_write_fault(&self, substr: &str, skip: u32, count: u32) {
        assert!(count > 0, "transient fault needs count >= 1");
        self.faults.write_rules.lock().unwrap().push(FaultRule {
            substr: substr.to_string(),
            skip,
            remaining: Some(count),
        });
    }

    /// Arm a hard write fault: after `skip` successful attempts, every
    /// write of a matching path fails — exceeding any retry budget.  The
    /// checkpoint writer absorbs this by skipping that checkpoint
    /// ([`crate::runtime::checkpoint::CheckpointWriter`] bumps its
    /// `checkpoints_failed` counter); the batch itself survives.
    pub fn inject_hard_write_fault(&self, substr: &str, skip: u32) {
        self.faults.write_rules.lock().unwrap().push(FaultRule {
            substr: substr.to_string(),
            skip,
            remaining: None,
        });
    }

    pub fn clear_write_faults(&self) {
        self.faults.write_rules.lock().unwrap().clear();
    }

    pub fn set_retry_policy(&self, policy: RetryPolicy) {
        *self.faults.policy.lock().unwrap() = policy;
    }

    pub fn retry_policy(&self) -> RetryPolicy {
        *self.faults.policy.lock().unwrap()
    }

    /// The latency histogram reads of `class` record into — real
    /// backends only (sim wall time is a page-cache artifact).
    fn lat_for(&self, class: ReadClass) -> Option<&LatHistogram> {
        if self.backend.is_real() {
            Some(&self.stats.read_lat[class as usize])
        } else {
            None
        }
    }

    /// Read a whole file (buffered on every backend — metadata files are
    /// tiny), metering + simulating device time.
    pub fn read_file(&self, path: &Path) -> Result<Vec<u8>> {
        let lat = self.lat_for(ReadClass::Meta);
        let data = with_read_retries(&self.faults, &self.stats.read_retries, path, || {
            let t0 = Instant::now();
            let data = fs::read(path).with_context(|| format!("read {}", path.display()))?;
            if let Some(h) = lat {
                h.record(t0.elapsed().as_nanos() as u64);
            }
            Ok(data)
        })?;
        self.account_read(data.len() as u64);
        Ok(data)
    }

    /// Read a whole file into an aligned buffer (zero-copy shard views
    /// borrow typed sections straight out of it), at the backend's
    /// declared alignment.  Metered exactly like
    /// [`read_file`](Self::read_file).
    pub fn read_file_aligned(&self, path: &Path) -> Result<super::view::AlignedBuf> {
        let align = self.backend.alignment();
        self.read_file_aligned_with(path, |len| {
            super::view::AlignedBuf::with_alignment(len, align)
        })
    }

    /// [`read_file_aligned`](Self::read_file_aligned) into a buffer
    /// leased from `pool`: mode-0 runs re-read every shard per iteration,
    /// and the pool recycles the buffers across iterations instead of
    /// allocating one per shard (PR-3 follow-up).  The pool's alignment
    /// should match [`alignment`](Self::alignment) so direct backends
    /// read copy-free.
    pub fn read_file_aligned_pooled(
        &self,
        path: &Path,
        pool: &Arc<super::view::BufPool>,
    ) -> Result<super::view::AlignedBuf> {
        self.read_file_aligned_with(path, |len| super::view::BufPool::take(pool, len))
    }

    /// The one metered aligned-read path: `alloc` supplies the
    /// destination buffer (fresh or pooled) for the file's length; the
    /// backend moves the bytes under the shared fault/retry machinery.
    fn read_file_aligned_with(
        &self,
        path: &Path,
        mut alloc: impl FnMut(usize) -> super::view::AlignedBuf,
    ) -> Result<super::view::AlignedBuf> {
        let buf = self.backend.read_aligned(
            &self.faults,
            &self.stats.read_retries,
            self.lat_for(ReadClass::Shard),
            path,
            &mut alloc,
        )?;
        self.account_read(buf.as_bytes().len() as u64);
        Ok(buf)
    }

    /// Write a whole file.
    pub fn write_file(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, bytes).with_context(|| format!("write {}", path.display()))?;
        self.account_write(bytes.len() as u64);
        Ok(())
    }

    /// Durable write for checkpoint artifacts: write, fsync the file, then
    /// fsync the parent directory so the new entry itself survives a crash.
    /// Transient failures (injected or real) are retried with backoff under
    /// the [`RetryPolicy`]; a hard failure surfaces to the caller (the
    /// checkpoint writer skips that checkpoint and keeps serving).
    pub fn write_file_durable(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        use std::io::Write;
        with_write_retries(&self.faults, &self.stats.write_retries, path, || {
            if let Some(parent) = path.parent() {
                fs::create_dir_all(parent)?;
            }
            let mut f =
                fs::File::create(path).with_context(|| format!("write {}", path.display()))?;
            f.write_all(bytes).with_context(|| format!("write {}", path.display()))?;
            f.sync_all().with_context(|| format!("fsync {}", path.display()))?;
            Ok(())
        })?;
        self.account_write(bytes.len() as u64);
        if let Some(parent) = path.parent() {
            sync_dir(parent)?;
        }
        Ok(())
    }

    /// Append to a file (preprocessing step 2 writes shard scratch files
    /// this way). Charged as one op.
    pub fn append_file(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        use std::io::Write;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(bytes)?;
        self.account_write(bytes.len() as u64);
        Ok(())
    }

    /// Meter a read that bypassed the filesystem (e.g. a baseline engine
    /// streaming from an in-memory copy to model pure sequential I/O).
    pub fn account_read(&self, bytes: u64) {
        self.stats.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.stats.read_ops.fetch_add(1, Ordering::Relaxed);
        self.charge(bytes, self.profile.read_bw);
    }

    pub fn account_write(&self, bytes: u64) {
        self.stats.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.stats.write_ops.fetch_add(1, Ordering::Relaxed);
        self.charge(bytes, self.profile.write_bw);
    }

    fn charge(&self, bytes: u64, bw: u64) {
        // Real backends pay genuine wall time — charging simulated device
        // time on top would double-count the cost.
        if bw == 0 || self.backend.is_real() {
            return;
        }
        let nanos = self.profile.seek_nanos + bytes.saturating_mul(1_000_000_000) / bw;
        self.stats.sim_nanos.fetch_add(nanos, Ordering::Relaxed);
    }
}

/// fsync a directory, making renames/creations inside it durable.
pub fn sync_dir(path: &Path) -> Result<()> {
    let f = fs::File::open(path).with_context(|| format!("open dir {}", path.display()))?;
    f.sync_all().with_context(|| format!("fsync dir {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn meters_bytes() {
        let dir = std::env::temp_dir().join("graphmp_disk_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("x.bin");
        disk.write_file(&p, &[0u8; 1000]).unwrap();
        let b = disk.read_file(&p).unwrap();
        assert_eq!(b.len(), 1000);
        let s = disk.snapshot();
        assert_eq!(s.bytes_written, 1000);
        assert_eq!(s.bytes_read, 1000);
        assert_eq!(s.read_ops, 1);
        assert_eq!(s.sim_nanos, 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn aligned_read_matches_plain_read() {
        let dir = std::env::temp_dir().join("graphmp_disk_aligned_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("a.bin");
        let data: Vec<u8> = (0..1001u32).map(|i| (i % 251) as u8).collect();
        disk.write_file(&p, &data).unwrap();
        let buf = disk.read_file_aligned(&p).unwrap();
        assert_eq!(buf.as_bytes(), &data[..]);
        assert_eq!(buf.as_bytes().as_ptr() as usize % 4, 0);
        let s = disk.snapshot();
        assert_eq!(s.bytes_read, 1001);
        assert_eq!(s.read_ops, 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pooled_aligned_read_matches_and_recycles() {
        let dir = std::env::temp_dir().join("graphmp_disk_pooled_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("a.bin");
        let data: Vec<u8> = (0..777u32).map(|i| (i % 253) as u8).collect();
        disk.write_file(&p, &data).unwrap();
        let pool = crate::storage::view::BufPool::new(4);
        let buf = disk.read_file_aligned_pooled(&p, &pool).unwrap();
        assert_eq!(buf.as_bytes(), &data[..]);
        drop(buf);
        let buf2 = disk.read_file_aligned_pooled(&p, &pool).unwrap();
        assert_eq!(buf2.as_bytes(), &data[..]);
        assert_eq!(pool.stats().0, 1, "second read must reuse the buffer");
        assert_eq!(disk.snapshot().bytes_read, 2 * 777, "metering unchanged");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hdd_simulated_time_scales_with_bytes() {
        let disk = Disk::new(DiskProfile::hdd_raid5());
        disk.account_read(310 * 1024 * 1024); // exactly 1 second of reads
        let s = disk.snapshot();
        let secs = s.sim_seconds();
        assert!((secs - 1.005).abs() < 0.01, "simulated {secs}s");
    }

    #[test]
    fn seek_charged_per_op() {
        let disk = Disk::new(DiskProfile::hdd_raid5());
        for _ in 0..10 {
            disk.account_read(0);
        }
        assert_eq!(disk.snapshot().sim_nanos, 50_000_000);
    }

    #[test]
    fn snapshot_delta() {
        let disk = Disk::unthrottled();
        disk.account_read(100);
        let a = disk.snapshot();
        disk.account_read(50);
        let d = disk.snapshot().since(&a);
        assert_eq!(d.bytes_read, 50);
        assert_eq!(d.read_ops, 1);
    }

    #[test]
    fn reset_clears() {
        let disk = Disk::unthrottled();
        disk.account_write(10);
        disk.reset();
        assert_eq!(disk.snapshot(), IoSnapshot::default());
    }

    fn fast_retry(disk: &Disk) {
        disk.set_retry_policy(RetryPolicy {
            max_retries: 3,
            backoff_base: Duration::from_micros(10),
        });
    }

    #[test]
    fn transient_fault_retried_then_succeeds() {
        let dir = std::env::temp_dir().join("graphmp_disk_transient_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        fast_retry(&disk);
        let p = dir.join("flaky.bin");
        disk.write_file(&p, b"payload").unwrap();
        disk.inject_read_fault("flaky.bin", 0, 2);
        let b = disk.read_file(&p).unwrap();
        assert_eq!(b, b"payload");
        assert_eq!(disk.snapshot().read_retries, 2);
        // rule exhausted: next read is clean
        disk.read_file(&p).unwrap();
        assert_eq!(disk.snapshot().read_retries, 2);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hard_fault_exhausts_retry_budget() {
        let dir = std::env::temp_dir().join("graphmp_disk_hard_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        fast_retry(&disk);
        let p = dir.join("dead.bin");
        disk.write_file(&p, b"x").unwrap();
        disk.inject_hard_read_fault("dead.bin", 1);
        disk.read_file(&p).unwrap(); // skip=1: first read passes
        let err = disk.read_file(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected hard read fault"), "{msg}");
        assert!(msg.contains("dead.bin"), "{msg}");
        assert!(msg.contains("after 4 attempt(s)"), "{msg}");
        assert_eq!(disk.snapshot().read_retries, 3);
        disk.clear_read_faults();
        disk.read_file(&p).unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_not_retried() {
        let disk = Disk::unthrottled();
        fast_retry(&disk);
        let err = disk.read_file(Path::new("/nonexistent/graphmp/x.bin")).unwrap_err();
        assert!(format!("{err:#}").contains("after 1 attempt(s)"));
        assert_eq!(disk.snapshot().read_retries, 0);
    }

    #[test]
    fn faults_shared_across_clones() {
        let dir = std::env::temp_dir().join("graphmp_disk_clone_fault_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        fast_retry(&disk);
        let p = dir.join("c.bin");
        disk.write_file(&p, b"y").unwrap();
        let clone = disk.clone();
        disk.inject_read_fault("c.bin", 0, 1);
        clone.read_file(&p).unwrap();
        assert_eq!(disk.snapshot().read_retries, 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn durable_write_round_trips() {
        let dir = std::env::temp_dir().join("graphmp_disk_durable_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("d.bin");
        disk.write_file_durable(&p, b"durable").unwrap();
        assert_eq!(disk.read_file(&p).unwrap(), b"durable");
        assert_eq!(disk.snapshot().bytes_written, 7);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn transient_write_fault_retried_then_succeeds() {
        let dir = std::env::temp_dir().join("graphmp_disk_wtransient_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        fast_retry(&disk);
        let p = dir.join("wflaky.bin");
        disk.inject_write_fault("wflaky.bin", 0, 2);
        disk.write_file_durable(&p, b"survives").unwrap();
        assert_eq!(disk.read_file(&p).unwrap(), b"survives");
        assert_eq!(disk.snapshot().write_retries, 2);
        // rule exhausted: next write is clean
        disk.write_file_durable(&p, b"clean").unwrap();
        assert_eq!(disk.snapshot().write_retries, 2);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hard_write_fault_exhausts_retry_budget() {
        let dir = std::env::temp_dir().join("graphmp_disk_whard_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        fast_retry(&disk);
        let p = dir.join("wdead.bin");
        disk.inject_hard_write_fault("wdead.bin", 0);
        let err = disk.write_file_durable(&p, b"x").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected hard write fault"), "{msg}");
        assert!(msg.contains("after 4 attempt(s)"), "{msg}");
        assert_eq!(disk.snapshot().write_retries, 3);
        assert_eq!(disk.snapshot().bytes_written, 0, "failed write not metered");
        disk.clear_write_faults();
        disk.write_file_durable(&p, b"x").unwrap();
        // write faults never bleed into the read side
        disk.inject_write_fault("wdead.bin", 0, 1);
        assert_eq!(disk.read_file(&p).unwrap(), b"x");
        assert_eq!(disk.snapshot().read_retries, 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn direct_backend_meters_without_sim_time() {
        use crate::storage::io_backend::DirectIoBackend;
        let dir = std::env::temp_dir().join("graphmp_disk_direct_test");
        let _ = fs::remove_dir_all(&dir);
        // A throttled profile on a real backend: bytes/ops are metered,
        // but no simulated nanos are charged and real latency lands in
        // the histograms instead.
        let disk = Disk::with_backend(DiskProfile::hdd_raid5(), DirectIoBackend::new(4, false));
        assert!(disk.is_real_io());
        assert_eq!(disk.alignment(), 4096);
        assert_eq!(disk.submission_depth(), 4);
        let p = dir.join("x.bin");
        let data: Vec<u8> = (0..9000u32).map(|i| (i % 251) as u8).collect();
        disk.write_file(&p, &data).unwrap();
        let pool = crate::storage::view::BufPool::with_alignment(4, disk.alignment());
        let buf = disk.read_file_aligned_pooled(&p, &pool).unwrap();
        assert_eq!(buf.as_bytes(), &data[..]);
        assert_eq!(buf.as_bytes().as_ptr() as usize % 4096, 0);
        let meta = disk.read_file(&p).unwrap();
        assert_eq!(meta, data);
        let s = disk.snapshot();
        assert_eq!(s.bytes_read, 2 * 9000);
        assert_eq!(s.read_ops, 2);
        assert_eq!(s.sim_nanos, 0, "real backend never charges simulated time");
        assert_eq!(s.read_lat_shard.count, 1);
        assert_eq!(s.read_lat_meta.count, 1);
        assert!(s.read_lat_shard.p50_nanos > 0);
        disk.reset();
        assert_eq!(disk.snapshot(), IoSnapshot::default());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn direct_backend_fault_injection_matches_sim() {
        use crate::storage::io_backend::DirectIoBackend;
        let dir = std::env::temp_dir().join("graphmp_disk_direct_fault_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::with_backend(DiskProfile::unthrottled(), DirectIoBackend::new(2, false));
        fast_retry(&disk);
        let p = dir.join("flaky.bin");
        disk.write_file(&p, b"payload").unwrap();
        disk.inject_read_fault("flaky.bin", 0, 2);
        let b = disk.read_file_aligned(&p).unwrap();
        assert_eq!(b.as_bytes(), b"payload");
        assert_eq!(disk.snapshot().read_retries, 2);
        disk.inject_hard_read_fault("flaky.bin", 0);
        let err = disk.read_file_aligned(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected hard read fault"), "{msg}");
        assert!(msg.contains("after 4 attempt(s)"), "{msg}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_accumulates() {
        let dir = std::env::temp_dir().join("graphmp_disk_append_test");
        let _ = fs::remove_dir_all(&dir);
        let disk = Disk::unthrottled();
        let p = dir.join("a.bin");
        disk.append_file(&p, b"ab").unwrap();
        disk.append_file(&p, b"cd").unwrap();
        assert_eq!(disk.read_file(&p).unwrap(), b"abcd");
        fs::remove_dir_all(&dir).unwrap();
    }
}
