//! Pluggable I/O backends: the simulated disk model vs. a real direct-I/O
//! read path.
//!
//! Everything the engines read flows through [`Disk`](super::disk::Disk),
//! which delegates the *mechanics* of each aligned read to an
//! [`IoBackend`]:
//!
//! * [`SimBackend`] — plain buffered reads; device timing comes from the
//!   [`DiskProfile`](super::disk::DiskProfile) cost model (token bucket +
//!   seek charge, accounted in `sim_nanos`, never slept).  Deterministic,
//!   page-cache-friendly, the default for tests and benches.
//! * [`DirectIoBackend`] — a real read path: shard files are opened with
//!   `O_DIRECT` and read into 4096-byte-aligned pooled buffers through a
//!   fixed-depth submission/completion ring drained by N I/O workers
//!   (io_uring-style batching, portable implementation).  When the
//!   filesystem refuses `O_DIRECT` (tmpfs, some network mounts) the
//!   backend falls back to buffered reads and drops the pages again with
//!   `posix_fadvise(DONTNEED)` so the host page cache cannot quietly turn
//!   the "real" path into a RAM benchmark.  With the off-by-default
//!   `uring` cargo feature the ring is serviced by a real `io_uring`
//!   instance (raw syscalls, runtime-probed, falls back to the portable
//!   workers when unavailable).
//!
//! The *semantics* around a read are backend-independent and implemented
//! exactly once here: fault injection ([`FaultPlan`]) and bounded
//! retry+backoff ([`RetryPolicy`], [`with_read_retries`]) wrap
//! [`IoBackend::read_once`] in the provided
//! [`IoBackend::read_aligned`] method, so the recovery gates run
//! identically on both backends.  Real backends additionally record
//! per-read wall latency into a [`LatHistogram`] (p50/p95/p99 per
//! [`ReadClass`]), surfaced through
//! [`IoSnapshot`](super::disk::IoSnapshot); simulated accounting
//! (`sim_nanos`) and measured histograms never mix — a backend reports
//! one or the other.

use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::view::AlignedBuf;

/// Buffer alignment every backend is allowed to assume as a floor (one
/// cache line, the historic `AlignedBuf` contract).
pub const MIN_IO_ALIGN: usize = 64;

/// The direct path's block alignment: buffer base, capacity padding and
/// file offsets are all multiples of this for `O_DIRECT` eligibility.
pub const DIRECT_IO_ALIGN: usize = 4096;

// ---------------------------------------------------------------------------
// Latency histograms
// ---------------------------------------------------------------------------

/// Which kind of read a latency sample belongs to.  Shard payload reads
/// (the prefetcher's aligned bulk reads) and small metadata reads
/// (property/vertex files, checkpoints) have wildly different size
/// distributions; folding them into one histogram would hide both.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadClass {
    /// Aligned whole-shard payload reads.
    Shard = 0,
    /// Small buffered metadata reads (`Disk::read_file`).
    Meta = 1,
}

/// Number of log2 buckets: covers 1ns .. ~550s, enough for any disk.
const LAT_BUCKETS: usize = 40;

/// A lock-free log2-bucketed latency histogram (nanoseconds).  Recording
/// is one relaxed `fetch_add`; summaries walk the buckets.
pub struct LatHistogram {
    buckets: [AtomicU64; LAT_BUCKETS],
    max: AtomicU64,
}

impl Default for LatHistogram {
    fn default() -> Self {
        LatHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            max: AtomicU64::new(0),
        }
    }
}

impl std::fmt::Debug for LatHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatHistogram").field("summary", &self.summary()).finish()
    }
}

impl LatHistogram {
    /// Record one sample (nanoseconds).
    pub fn record(&self, nanos: u64) {
        // clamp to 1ns so a sub-resolution clock sample still lands in a
        // bucket and keeps every percentile non-zero
        let nanos = nanos.max(1);
        let idx = (nanos.ilog2() as usize).min(LAT_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(nanos, Ordering::Relaxed);
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.max.store(0, Ordering::Relaxed);
    }

    /// Point-in-time percentile summary.  Bucket resolution is a factor
    /// of two, so percentiles are approximate: each is reported as the
    /// midpoint (1.5 × 2^i) of the bucket the rank falls into, clamped
    /// to the observed maximum.
    pub fn summary(&self) -> LatencySummary {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = counts.iter().sum();
        let max_nanos = self.max.load(Ordering::Relaxed);
        if count == 0 {
            return LatencySummary::default();
        }
        let pct = |p: u64| -> u64 {
            // rank = ceil(count * p / 100), 1-based
            let rank = (count * p).div_ceil(100).max(1);
            let mut seen = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                seen += c;
                if seen >= rank {
                    let mid = (1u64 << i) + (1u64 << i) / 2;
                    return mid.min(max_nanos);
                }
            }
            max_nanos
        };
        LatencySummary {
            count,
            p50_nanos: pct(50),
            p95_nanos: pct(95),
            p99_nanos: pct(99),
            max_nanos,
        }
    }
}

/// Percentile snapshot of one [`LatHistogram`] (all nanoseconds, zero
/// when no samples were recorded — i.e. always zero on the sim backend).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencySummary {
    pub count: u64,
    pub p50_nanos: u64,
    pub p95_nanos: u64,
    pub p99_nanos: u64,
    pub max_nanos: u64,
}

// ---------------------------------------------------------------------------
// Retry + fault-injection machinery (backend-independent, one copy)
// ---------------------------------------------------------------------------

/// Bounded-retry policy applied to every read that goes through `Disk`.
/// Transient failures (injected or real) are retried with exponential
/// backoff; `NotFound` is terminal immediately — retrying a missing file
/// cannot help.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub backoff_base: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 3, backoff_base: Duration::from_micros(500) }
    }
}

/// One injected failure rule (read or write side), matched by path
/// substring.
#[derive(Clone, Debug)]
pub(crate) struct FaultRule {
    pub(crate) substr: String,
    /// Matching attempts to let through before the rule starts firing.
    pub(crate) skip: u32,
    /// Remaining failures once firing; `None` = hard fault (fails forever).
    pub(crate) remaining: Option<u32>,
}

/// Injectable failure plan shared by all clones of a `Disk` handle, so a
/// test can arm faults on the handle it kept while the engine reads
/// through its own clone.  Lives at the backend-trait level: the plan is
/// consulted *before* each attempt reaches the backend, so recovery
/// behaviour is byte-identical on sim and direct I/O.
#[derive(Debug, Default)]
pub struct FaultPlan {
    pub(crate) rules: Mutex<Vec<FaultRule>>,
    /// Separate rule list for the write side: checkpoint writes are
    /// injectable independently of shard reads (PR 8 satellite).
    pub(crate) write_rules: Mutex<Vec<FaultRule>>,
    pub(crate) policy: Mutex<RetryPolicy>,
}

impl FaultPlan {
    /// Consult the plan for one read attempt of `path`.  Returns
    /// `Some(hard)` when the attempt must fail, updating rule state.
    pub(crate) fn take_fault(&self, path: &Path) -> Option<bool> {
        Self::take_from(&self.rules, path)
    }

    /// Same, for one write attempt of `path`.
    pub(crate) fn take_write_fault(&self, path: &Path) -> Option<bool> {
        Self::take_from(&self.write_rules, path)
    }

    pub(crate) fn policy(&self) -> RetryPolicy {
        *self.policy.lock().unwrap()
    }

    fn take_from(rules: &Mutex<Vec<FaultRule>>, path: &Path) -> Option<bool> {
        let s = path.to_string_lossy();
        let mut rules = rules.lock().unwrap();
        for i in 0..rules.len() {
            if !s.contains(&rules[i].substr) {
                continue;
            }
            if rules[i].skip > 0 {
                rules[i].skip -= 1;
                return None;
            }
            match &mut rules[i].remaining {
                None => return Some(true),
                Some(k) => {
                    *k -= 1;
                    if *k == 0 {
                        rules.remove(i);
                    }
                    return Some(false);
                }
            }
        }
        None
    }
}

/// Run one logical read of `path` under the retry policy: each attempt
/// first consults the fault plan, then runs `op`.  Failed attempts are
/// retried with exponential backoff up to `max_retries` times, counted in
/// `retries`; `NotFound` fails immediately.
pub(crate) fn with_read_retries<T>(
    faults: &FaultPlan,
    retries: &AtomicU64,
    path: &Path,
    mut op: impl FnMut() -> Result<T>,
) -> Result<T> {
    let policy = faults.policy();
    let mut attempt: u32 = 0;
    loop {
        let res = match faults.take_fault(path) {
            Some(hard) => Err(anyhow::anyhow!(
                "injected {} read fault: {}",
                if hard { "hard" } else { "transient" },
                path.display()
            )),
            None => op(),
        };
        match res {
            Ok(v) => return Ok(v),
            Err(e) => {
                let not_found = e
                    .root_cause()
                    .downcast_ref::<std::io::Error>()
                    .is_some_and(|io| io.kind() == std::io::ErrorKind::NotFound);
                if not_found || attempt >= policy.max_retries {
                    return Err(e.context(format!(
                        "read {} failed after {} attempt(s)",
                        path.display(),
                        attempt + 1
                    )));
                }
                std::thread::sleep(policy.backoff_base * 2u32.saturating_pow(attempt.min(10)));
                retries.fetch_add(1, Ordering::Relaxed);
                attempt += 1;
            }
        }
    }
}

/// The write mirror of [`with_read_retries`]: consults the write-fault
/// plan before each attempt, retries with backoff, counts in `retries`.
pub(crate) fn with_write_retries<T>(
    faults: &FaultPlan,
    retries: &AtomicU64,
    path: &Path,
    mut op: impl FnMut() -> Result<T>,
) -> Result<T> {
    let policy = faults.policy();
    let mut attempt: u32 = 0;
    loop {
        let res = match faults.take_write_fault(path) {
            Some(hard) => Err(anyhow::anyhow!(
                "injected {} write fault: {}",
                if hard { "hard" } else { "transient" },
                path.display()
            )),
            None => op(),
        };
        match res {
            Ok(v) => return Ok(v),
            Err(e) => {
                if attempt >= policy.max_retries {
                    return Err(e.context(format!(
                        "write {} failed after {} attempt(s)",
                        path.display(),
                        attempt + 1
                    )));
                }
                std::thread::sleep(policy.backoff_base * 2u32.saturating_pow(attempt.min(10)));
                retries.fetch_add(1, Ordering::Relaxed);
                attempt += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The backend trait
// ---------------------------------------------------------------------------

/// Which backend a `Disk` runs on — parsed from `--io-backend`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoBackendKind {
    /// Profiled cost model, buffered reads (`sim`).
    Sim,
    /// Real `O_DIRECT` + batched-submission read path (`direct`, or
    /// `direct,uring` to also probe for a real io_uring instance).
    Direct { uring: bool },
}

impl IoBackendKind {
    /// Parse a `--io-backend` value: `sim` | `direct` | `direct,uring`.
    pub fn parse(s: &str) -> Result<IoBackendKind> {
        match s {
            "sim" => Ok(IoBackendKind::Sim),
            "direct" => Ok(IoBackendKind::Direct { uring: false }),
            "direct,uring" => Ok(IoBackendKind::Direct { uring: true }),
            other => anyhow::bail!(
                "unknown io backend {other:?} (expected sim | direct | direct,uring)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IoBackendKind::Sim => "sim",
            IoBackendKind::Direct { uring: false } => "direct",
            IoBackendKind::Direct { uring: true } => "direct,uring",
        }
    }
}

/// Mechanics of one aligned whole-file read.  Object-safe (`Disk` holds
/// an `Arc<dyn IoBackend>`); semantics — fault injection, retry+backoff,
/// latency histograms, byte metering — live in the provided
/// [`read_aligned`](Self::read_aligned) and in `Disk`, not in
/// implementations.
pub trait IoBackend: Send + Sync {
    fn kind(&self) -> IoBackendKind;

    /// Buffer base/padding alignment this backend needs for copy-free
    /// reads (64 for sim, 4096 for direct).  `BufPool`s feeding this
    /// backend allocate at this alignment.
    fn alignment(&self) -> usize;

    /// Sustained queue depth the backend can keep in flight; the
    /// prefetcher clamps its I/O thread count and auto depth to this.
    fn submission_depth(&self) -> usize;

    /// True when reads hit real storage (wall latency is meaningful and
    /// recorded; simulated device time must *not* be charged on top).
    fn is_real(&self) -> bool {
        matches!(self.kind(), IoBackendKind::Direct { .. })
    }

    /// One read attempt of the whole file at `path` into a buffer from
    /// `alloc` (called with the file length).  No fault/retry logic here
    /// — implementations only move bytes.
    fn read_once(
        &self,
        path: &Path,
        alloc: &mut dyn FnMut(usize) -> AlignedBuf,
    ) -> Result<AlignedBuf>;

    /// One *logical* read: [`read_once`](Self::read_once) wrapped in the
    /// shared fault-injection + retry+backoff machinery, recording the
    /// successful attempt's wall latency into `lat` when given (real
    /// backends only — sim wall time is a page-cache artifact).
    fn read_aligned(
        &self,
        faults: &FaultPlan,
        retries: &AtomicU64,
        lat: Option<&LatHistogram>,
        path: &Path,
        alloc: &mut dyn FnMut(usize) -> AlignedBuf,
    ) -> Result<AlignedBuf> {
        with_read_retries(faults, retries, path, || {
            let t0 = Instant::now();
            let buf = self.read_once(path, alloc)?;
            if let Some(h) = lat {
                h.record(t0.elapsed().as_nanos() as u64);
            }
            Ok(buf)
        })
    }
}

// ---------------------------------------------------------------------------
// SimBackend
// ---------------------------------------------------------------------------

/// The existing profiled model: buffered `read_exact` into the aligned
/// buffer; device timing is charged by `Disk` from the `DiskProfile`.
#[derive(Debug, Default)]
pub struct SimBackend;

impl IoBackend for SimBackend {
    fn kind(&self) -> IoBackendKind {
        IoBackendKind::Sim
    }

    fn alignment(&self) -> usize {
        MIN_IO_ALIGN
    }

    fn submission_depth(&self) -> usize {
        // The cost model has no queue: token-bucket charging is
        // depth-independent, so any pipeline fan-in is fine.
        64
    }

    fn read_once(
        &self,
        path: &Path,
        alloc: &mut dyn FnMut(usize) -> AlignedBuf,
    ) -> Result<AlignedBuf> {
        let mut f = fs::File::open(path).with_context(|| format!("read {}", path.display()))?;
        let len = f.metadata()?.len() as usize;
        let mut buf = alloc(len);
        f.read_exact(buf.as_bytes_mut())
            .with_context(|| format!("read {}", path.display()))?;
        Ok(buf)
    }
}

// ---------------------------------------------------------------------------
// DirectIoBackend: O_DIRECT + fixed-depth submission ring
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::c_int;

    /// `O_DIRECT` differs per architecture (asm-generic vs x86).
    #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
    pub const O_DIRECT: i32 = 0o40000;
    #[cfg(not(any(target_arch = "x86_64", target_arch = "x86")))]
    pub const O_DIRECT: i32 = 0o200000;

    pub const POSIX_FADV_DONTNEED: c_int = 4;

    extern "C" {
        // glibc wrapper; declared here because the crate carries no libc
        // dependency.
        pub fn posix_fadvise(fd: c_int, offset: i64, len: i64, advice: c_int) -> c_int;
    }

    /// Drop `fd`'s pages from the page cache (best effort — advisory).
    pub fn drop_cache(fd: c_int) {
        // SAFETY: posix_fadvise only inspects the open fd; any result
        // (including EBADF on exotic fds) is ignored.
        unsafe {
            let _ = posix_fadvise(fd, 0, 0, POSIX_FADV_DONTNEED);
        }
    }
}

/// One queued read request travelling through the submission ring.
struct Request {
    path: PathBuf,
    file: fs::File,
    /// Destination; capacity is padded to the block size when `direct`.
    buf: AlignedBuf,
    /// Whether `file` was opened with `O_DIRECT` (and `buf` qualifies).
    direct: bool,
    done: Arc<Completion>,
}

#[derive(Default)]
struct Completion {
    slot: Mutex<Option<Result<AlignedBuf>>>,
    cv: Condvar,
}

impl Completion {
    fn complete(&self, res: Result<AlignedBuf>) {
        *self.slot.lock().unwrap() = Some(res);
        self.cv.notify_one();
    }

    fn wait(&self) -> Result<AlignedBuf> {
        let mut slot = self.slot.lock().unwrap();
        loop {
            if let Some(res) = slot.take() {
                return res;
            }
            slot = self.cv.wait(slot).unwrap();
        }
    }
}

struct RingState {
    queue: std::collections::VecDeque<Request>,
    inflight: usize,
    shutdown: bool,
}

/// The portable io_uring-style ring: a fixed-depth submission queue
/// (submitters block while `queued + inflight >= depth`, exactly like a
/// full SQ) drained by N I/O worker threads that complete requests out
/// of order.  Batching falls out naturally: concurrent prefetch threads
/// enqueue without waiting on each other's completions, and the device
/// sees up to `depth` requests in flight.
struct SubmitRing {
    state: Mutex<RingState>,
    /// Submitters wait here for SQ space.
    space: Condvar,
    /// Workers wait here for queued requests.
    work: Condvar,
    depth: usize,
    /// Transparent buffered fallbacks taken (O_DIRECT refused mid-read).
    fallbacks: AtomicU64,
}

impl SubmitRing {
    fn new(depth: usize) -> Arc<SubmitRing> {
        Arc::new(SubmitRing {
            state: Mutex::new(RingState {
                queue: std::collections::VecDeque::new(),
                inflight: 0,
                shutdown: false,
            }),
            space: Condvar::new(),
            work: Condvar::new(),
            depth,
            fallbacks: AtomicU64::new(0),
        })
    }

    fn submit(&self, req: Request) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        while !st.shutdown && st.queue.len() + st.inflight >= self.depth {
            st = self.space.wait(st).unwrap();
        }
        anyhow::ensure!(!st.shutdown, "io ring shut down");
        st.queue.push_back(req);
        drop(st);
        self.work.notify_one();
        Ok(())
    }

    /// Worker loop: pop → read → complete, until shutdown and drained.
    fn worker(&self) {
        loop {
            let req = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if let Some(req) = st.queue.pop_front() {
                        st.inflight += 1;
                        break req;
                    }
                    if st.shutdown {
                        return;
                    }
                    st = self.work.wait(st).unwrap();
                }
            };
            let res = self.perform(req.path, req.file, req.buf, req.direct);
            req.done.complete(res);
            let mut st = self.state.lock().unwrap();
            st.inflight -= 1;
            drop(st);
            self.space.notify_one();
        }
    }

    /// Execute one read.  `O_DIRECT` reads loop over the padded capacity
    /// until EOF; any direct-path error after the open (e.g. a filesystem
    /// that accepted the flag but rejects the transfer) falls back to a
    /// fresh buffered read of the same file.
    fn perform(
        &self,
        path: PathBuf,
        file: fs::File,
        mut buf: AlignedBuf,
        direct: bool,
    ) -> Result<AlignedBuf> {
        let len = buf.len();
        if direct {
            match Self::read_direct(&file, &mut buf) {
                Ok(()) => return Ok(buf),
                Err(_) => {
                    // Alignment/transfer refusal mid-read: redo buffered.
                    self.fallbacks.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        drop(file);
        let mut f = fs::File::open(&path).with_context(|| format!("read {}", path.display()))?;
        f.read_exact(&mut buf.as_bytes_mut()[..len])
            .with_context(|| format!("read {}", path.display()))?;
        #[cfg(target_os = "linux")]
        {
            use std::os::fd::AsRawFd;
            sys::drop_cache(f.as_raw_fd());
        }
        Ok(buf)
    }

    fn read_direct(mut file: &fs::File, buf: &mut AlignedBuf) -> std::io::Result<()> {
        let len = buf.len();
        // O_DIRECT transfers must start block-aligned in memory and on
        // disk; the padded capacity slice satisfies both, and the kernel
        // permits the short non-aligned tail read at EOF.
        let dst = buf.as_padded_mut();
        let mut total = 0usize;
        while total < len {
            let n = file.read(&mut dst[total..])?;
            if n == 0 {
                break;
            }
            total += n;
        }
        if total < len {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("direct read short: {total} of {len} bytes"),
            ));
        }
        Ok(())
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.work.notify_all();
        self.space.notify_all();
    }
}

/// The real read path: `O_DIRECT` opens, 4096-byte-aligned buffers, and
/// batched submission through a fixed-depth [`SubmitRing`].  See the
/// module docs for the fallback matrix.
pub struct DirectIoBackend {
    depth: usize,
    ring: Arc<SubmitRing>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Globally disabled after the first filesystem refusal (e.g. tmpfs
    /// returns `EINVAL` on open): every subsequent read goes buffered +
    /// `posix_fadvise(DONTNEED)` without re-probing per read.
    o_direct: AtomicBool,
    /// Reads that took the buffered-fallback path.
    fallback_reads: AtomicU64,
    /// Reads completed via O_DIRECT.
    direct_reads: AtomicU64,
    /// True when the `uring` feature is compiled in, was requested, and
    /// the runtime probe succeeded.
    uring_active: bool,
    #[cfg(all(feature = "uring", target_os = "linux"))]
    uring: Option<uring::UringRing>,
}

impl DirectIoBackend {
    /// A backend with `depth` submission slots (clamped to 1..=64),
    /// drained by `min(depth, 8)` I/O workers.  `want_uring` asks for a
    /// real io_uring instance; it is only honored when the `uring`
    /// feature is compiled in *and* the kernel probe succeeds, otherwise
    /// the portable ring serves identically.
    pub fn new(depth: usize, want_uring: bool) -> Arc<DirectIoBackend> {
        let depth = depth.clamp(1, 64);
        let ring = SubmitRing::new(depth);
        #[cfg(all(feature = "uring", target_os = "linux"))]
        let uring_ring = if want_uring { uring::UringRing::new(depth).ok() } else { None };
        #[cfg(all(feature = "uring", target_os = "linux"))]
        let uring_active = uring_ring.is_some();
        #[cfg(not(all(feature = "uring", target_os = "linux")))]
        let uring_active = {
            let _ = want_uring;
            false
        };
        let n_workers = if uring_active { 1 } else { depth.min(8) };
        let workers = (0..n_workers)
            .map(|i| {
                let ring = Arc::clone(&ring);
                #[cfg(all(feature = "uring", target_os = "linux"))]
                let uring_handle = if i == 0 { uring_ring.clone() } else { None };
                std::thread::Builder::new()
                    .name(format!("gmp-io-{i}"))
                    .spawn(move || {
                        #[cfg(all(feature = "uring", target_os = "linux"))]
                        if let Some(u) = uring_handle {
                            u.drain(&ring);
                            return;
                        }
                        ring.worker();
                    })
                    .expect("spawn io worker")
            })
            .collect();
        Arc::new(DirectIoBackend {
            depth,
            ring,
            workers,
            o_direct: AtomicBool::new(cfg!(target_os = "linux")),
            fallback_reads: AtomicU64::new(0),
            direct_reads: AtomicU64::new(0),
            uring_active,
            #[cfg(all(feature = "uring", target_os = "linux"))]
            uring: uring_ring,
        })
    }

    /// Whether the O_DIRECT open path is still live (flips off globally
    /// on the first filesystem refusal).
    pub fn o_direct_active(&self) -> bool {
        self.o_direct.load(Ordering::Relaxed)
    }

    /// True when a real io_uring instance services the ring.
    pub fn uring_active(&self) -> bool {
        self.uring_active
    }

    /// `(direct, buffered-fallback)` completed-read counts.
    pub fn read_counts(&self) -> (u64, u64) {
        (
            self.direct_reads.load(Ordering::Relaxed),
            self.fallback_reads.load(Ordering::Relaxed)
                + self.ring.fallbacks.load(Ordering::Relaxed),
        )
    }

    /// Open `path`, preferring `O_DIRECT`.  Returns `(file, direct)`.
    fn open(&self, path: &Path) -> Result<(fs::File, bool)> {
        #[cfg(target_os = "linux")]
        if self.o_direct.load(Ordering::Relaxed) {
            use std::os::unix::fs::OpenOptionsExt;
            match fs::OpenOptions::new().read(true).custom_flags(sys::O_DIRECT).open(path) {
                Ok(f) => return Ok((f, true)),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                    return Err(anyhow::Error::new(e).context(format!("read {}", path.display())));
                }
                Err(_) => {
                    // Filesystem refused the flag (tmpfs, overlayfs…):
                    // disable globally rather than paying a failed open
                    // per read.
                    self.o_direct.store(false, Ordering::Relaxed);
                }
            }
        }
        let f = fs::File::open(path).with_context(|| format!("read {}", path.display()))?;
        Ok((f, false))
    }
}

impl Drop for DirectIoBackend {
    fn drop(&mut self) {
        self.ring.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl IoBackend for DirectIoBackend {
    fn kind(&self) -> IoBackendKind {
        IoBackendKind::Direct { uring: self.uring_active }
    }

    fn alignment(&self) -> usize {
        DIRECT_IO_ALIGN
    }

    fn submission_depth(&self) -> usize {
        self.depth
    }

    fn read_once(
        &self,
        path: &Path,
        alloc: &mut dyn FnMut(usize) -> AlignedBuf,
    ) -> Result<AlignedBuf> {
        let (file, mut direct) = self.open(path)?;
        let len = file.metadata()?.len() as usize;
        let buf = alloc(len);
        // The pool normally hands out block-aligned buffers (alignment()
        // = 4096); a caller-supplied 64B buffer demotes just this read.
        if direct && !(buf.align() >= DIRECT_IO_ALIGN && buf.padded_capacity() % DIRECT_IO_ALIGN == 0)
        {
            direct = false;
        }
        if direct {
            self.direct_reads.fetch_add(1, Ordering::Relaxed);
        } else {
            self.fallback_reads.fetch_add(1, Ordering::Relaxed);
        }
        let done = Arc::new(Completion::default());
        self.ring.submit(Request {
            path: path.to_path_buf(),
            file,
            buf,
            direct,
            done: Arc::clone(&done),
        })?;
        done.wait()
    }
}

/// Construct the backend named by `kind`, with `depth` submission slots
/// (ignored by sim).
pub fn make_backend(kind: IoBackendKind, depth: usize) -> Arc<dyn IoBackend> {
    match kind {
        IoBackendKind::Sim => Arc::new(SimBackend),
        IoBackendKind::Direct { uring } => DirectIoBackend::new(depth, uring),
    }
}

// ---------------------------------------------------------------------------
// Real io_uring ring (off-by-default `uring` feature, raw syscalls)
// ---------------------------------------------------------------------------

#[cfg(all(feature = "uring", target_os = "linux"))]
mod uring {
    //! A minimal io_uring driver over raw syscalls (the crate carries no
    //! libc/io-uring dependency).  One drainer thread owns the ring
    //! exclusively: it collects queued [`Request`]s, writes one SQE per
    //! request (`IORING_OP_READ` over the padded buffer capacity), makes
    //! a single `io_uring_enter(submit = n, wait = n)` call, and reaps
    //! the CQE batch — true batched submission, one syscall per batch.
    //! Short or failed reads fall back to the portable buffered path.
    //! Probed at runtime; `UringRing::new` fails cleanly on kernels
    //! without io_uring and the portable workers take over.

    use super::{Request, SubmitRing};
    use std::os::fd::AsRawFd;
    use std::os::raw::{c_int, c_long, c_uint, c_void};
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    const SYS_IO_URING_SETUP: c_long = 425;
    const SYS_IO_URING_ENTER: c_long = 426;
    const IORING_OP_READ: u8 = 22;
    const IORING_ENTER_GETEVENTS: c_uint = 1;
    const IORING_OFF_SQ_RING: i64 = 0;
    const IORING_OFF_CQ_RING: i64 = 0x8000000;
    const IORING_OFF_SQES: i64 = 0x10000000;

    const PROT_READ: c_int = 1;
    const PROT_WRITE: c_int = 2;
    const MAP_SHARED: c_int = 1;
    const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        fn syscall(num: c_long, ...) -> c_long;
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    #[repr(C)]
    #[derive(Default, Clone, Copy)]
    struct SqringOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        flags: u32,
        dropped: u32,
        array: u32,
        resv1: u32,
        resv2: u64,
    }

    #[repr(C)]
    #[derive(Default, Clone, Copy)]
    struct CqringOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        overflow: u32,
        cqes: u32,
        flags: u32,
        resv1: u32,
        resv2: u64,
    }

    #[repr(C)]
    #[derive(Default, Clone, Copy)]
    struct UringParams {
        sq_entries: u32,
        cq_entries: u32,
        flags: u32,
        sq_thread_cpu: u32,
        sq_thread_idle: u32,
        features: u32,
        wq_fd: u32,
        resv: [u32; 3],
        sq_off: SqringOffsets,
        cq_off: CqringOffsets,
    }

    /// One 64-byte submission queue entry (fields we use only).
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Sqe {
        opcode: u8,
        flags: u8,
        ioprio: u16,
        fd: i32,
        off: u64,
        addr: u64,
        len: u32,
        rw_flags: u32,
        user_data: u64,
        pad: [u64; 3],
    }

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Cqe {
        user_data: u64,
        res: i32,
        flags: u32,
    }

    struct Mmap {
        ptr: *mut u8,
        len: usize,
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            // SAFETY: ptr/len came from a successful mmap.
            unsafe {
                munmap(self.ptr.cast(), self.len);
            }
        }
    }

    struct Inner {
        fd: c_int,
        sq: Mmap,
        cq: Mmap,
        sqes: Mmap,
        params: UringParams,
        entries: u32,
    }

    // SAFETY: the ring is only ever driven by the single drainer thread;
    // Send is needed to move it there.
    unsafe impl Send for Inner {}
    unsafe impl Sync for Inner {}

    impl Drop for Inner {
        fn drop(&mut self) {
            // SAFETY: fd came from io_uring_setup.
            unsafe {
                close(self.fd);
            }
        }
    }

    /// Cloneable handle; the single drainer thread takes one clone.
    #[derive(Clone)]
    pub(super) struct UringRing {
        inner: Arc<Inner>,
    }

    impl UringRing {
        pub(super) fn new(depth: usize) -> Result<UringRing, std::io::Error> {
            let entries = (depth.max(1) as u32).next_power_of_two();
            let mut params = UringParams::default();
            // SAFETY: params is a properly sized zeroed io_uring_params.
            let fd = unsafe {
                syscall(
                    SYS_IO_URING_SETUP,
                    entries as c_long,
                    &mut params as *mut UringParams,
                )
            };
            if fd < 0 {
                return Err(std::io::Error::last_os_error());
            }
            let fd = fd as c_int;
            let map = |len: usize, off: i64| -> Result<Mmap, std::io::Error> {
                // SAFETY: standard io_uring ring mapping.
                let p = unsafe {
                    mmap(std::ptr::null_mut(), len, PROT_READ | PROT_WRITE, MAP_SHARED, fd, off)
                };
                if p == MAP_FAILED {
                    return Err(std::io::Error::last_os_error());
                }
                Ok(Mmap { ptr: p.cast(), len })
            };
            let sq_len = params.sq_off.array as usize + params.sq_entries as usize * 4;
            let cq_len = params.cq_off.cqes as usize
                + params.cq_entries as usize * std::mem::size_of::<Cqe>();
            let sq = map(sq_len, IORING_OFF_SQ_RING).inspect_err(|_| unsafe {
                close(fd);
            })?;
            let cq = map(cq_len, IORING_OFF_CQ_RING).inspect_err(|_| unsafe {
                close(fd);
            })?;
            let sqes = map(
                params.sq_entries as usize * std::mem::size_of::<Sqe>(),
                IORING_OFF_SQES,
            )
            .inspect_err(|_| unsafe {
                close(fd);
            })?;
            Ok(UringRing {
                inner: Arc::new(Inner { fd, sq, cq, sqes, params, entries }),
            })
        }

        fn sq_atomic(&self, off: u32) -> &AtomicU32 {
            // SAFETY: offset is within the mapped SQ ring, u32-aligned.
            unsafe { &*self.inner.sq.ptr.add(off as usize).cast::<AtomicU32>() }
        }

        fn cq_atomic(&self, off: u32) -> &AtomicU32 {
            // SAFETY: offset is within the mapped CQ ring, u32-aligned.
            unsafe { &*self.inner.cq.ptr.add(off as usize).cast::<AtomicU32>() }
        }

        /// Drainer loop: replaces the portable workers when active.
        pub(super) fn drain(&self, ring: &Arc<SubmitRing>) {
            loop {
                // Collect up to `entries` queued requests (block for 1).
                let mut batch: Vec<Request> = Vec::new();
                {
                    let mut st = ring.state.lock().unwrap();
                    loop {
                        while batch.len() < self.inner.entries as usize {
                            match st.queue.pop_front() {
                                Some(r) => {
                                    st.inflight += 1;
                                    batch.push(r);
                                }
                                None => break,
                            }
                        }
                        if !batch.is_empty() {
                            break;
                        }
                        if st.shutdown {
                            return;
                        }
                        st = ring.work.wait(st).unwrap();
                    }
                }
                let n = batch.len();
                self.run_batch(&mut batch, ring);
                let mut st = ring.state.lock().unwrap();
                st.inflight -= n;
                drop(st);
                ring.space.notify_all();
            }
        }

        /// Submit the whole batch as one `io_uring_enter`, reap, complete.
        fn run_batch(&self, batch: &mut Vec<Request>, ring: &Arc<SubmitRing>) {
            let p = &self.inner.params;
            let mask = self.sq_atomic(p.sq_off.ring_mask).load(Ordering::Relaxed);
            let mut tail = self.sq_atomic(p.sq_off.tail).load(Ordering::Relaxed);
            for (i, req) in batch.iter_mut().enumerate() {
                let idx = tail & mask;
                let sqe = Sqe {
                    opcode: IORING_OP_READ,
                    flags: 0,
                    ioprio: 0,
                    fd: req.file.as_raw_fd(),
                    off: 0,
                    addr: req.buf.as_padded_mut().as_mut_ptr() as u64,
                    len: if req.direct {
                        req.buf.padded_capacity() as u32
                    } else {
                        req.buf.len() as u32
                    },
                    rw_flags: 0,
                    user_data: i as u64,
                    pad: [0; 3],
                };
                // SAFETY: idx < sq_entries; the SQE slot and index array
                // are inside the mapped regions and owned by us (single
                // drainer, no SQPOLL).
                unsafe {
                    let slot = self.inner.sqes.ptr.cast::<Sqe>().add(idx as usize);
                    std::ptr::write(slot, sqe);
                    let arr = self
                        .inner
                        .sq
                        .ptr
                        .add(p.sq_off.array as usize)
                        .cast::<u32>()
                        .add(idx as usize);
                    std::ptr::write(arr, idx);
                }
                tail = tail.wrapping_add(1);
            }
            self.sq_atomic(p.sq_off.tail).store(tail, Ordering::Release);
            let n = batch.len() as c_long;
            // SAFETY: valid ring fd; no sigset.
            let rc = unsafe {
                syscall(
                    SYS_IO_URING_ENTER,
                    self.inner.fd as c_long,
                    n,
                    n,
                    IORING_ENTER_GETEVENTS as c_long,
                    std::ptr::null::<c_void>(),
                    0 as c_long,
                )
            };
            let mut results: Vec<Option<i32>> = vec![None; batch.len()];
            if rc >= 0 {
                let mut head = self.cq_atomic(p.cq_off.head).load(Ordering::Relaxed);
                let cq_mask = self.cq_atomic(p.cq_off.ring_mask).load(Ordering::Relaxed);
                loop {
                    let cq_tail = self.cq_atomic(p.cq_off.tail).load(Ordering::Acquire);
                    if head == cq_tail {
                        break;
                    }
                    // SAFETY: head < tail means this CQE is published.
                    let cqe = unsafe {
                        *self
                            .inner
                            .cq
                            .ptr
                            .add(p.cq_off.cqes as usize)
                            .cast::<Cqe>()
                            .add((head & cq_mask) as usize)
                    };
                    if let Some(r) = results.get_mut(cqe.user_data as usize) {
                        *r = Some(cqe.res);
                    }
                    head = head.wrapping_add(1);
                }
                self.cq_atomic(p.cq_off.head).store(head, Ordering::Release);
            }
            for (req, res) in batch.drain(..).zip(results) {
                let want = req.buf.len();
                match res {
                    Some(r) if r >= 0 && r as usize >= want => {
                        req.done.complete(Ok(req.buf));
                    }
                    _ => {
                        // Missing/short/failed CQE: redo buffered via the
                        // portable path (never direct — avoids loops).
                        let res = ring.perform(req.path, req.file, req.buf, false);
                        req.done.complete(res);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::view::BufPool;

    #[test]
    fn histogram_percentiles_track_samples() {
        let h = LatHistogram::default();
        assert_eq!(h.summary(), LatencySummary::default());
        for _ in 0..90 {
            h.record(1_000); // bucket ~2^9
        }
        for _ in 0..10 {
            h.record(1_000_000); // bucket ~2^19
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert_eq!(s.max_nanos, 1_000_000);
        assert!(s.p50_nanos >= 512 && s.p50_nanos < 2048, "p50={}", s.p50_nanos);
        assert!(s.p99_nanos >= 524_288, "p99={}", s.p99_nanos);
        assert!(s.p50_nanos <= s.p95_nanos && s.p95_nanos <= s.p99_nanos);
        h.reset();
        assert_eq!(h.summary(), LatencySummary::default());
    }

    #[test]
    fn kind_parses_and_names() {
        assert_eq!(IoBackendKind::parse("sim").unwrap(), IoBackendKind::Sim);
        assert_eq!(
            IoBackendKind::parse("direct").unwrap(),
            IoBackendKind::Direct { uring: false }
        );
        assert_eq!(
            IoBackendKind::parse("direct,uring").unwrap(),
            IoBackendKind::Direct { uring: true }
        );
        assert!(IoBackendKind::parse("mmap").is_err());
        assert_eq!(IoBackendKind::Direct { uring: false }.name(), "direct");
    }

    #[test]
    fn direct_backend_reads_match_buffered() {
        let dir = std::env::temp_dir().join("graphmp_direct_backend_test");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        // Sizes straddling block boundaries: empty, sub-block, exact
        // block, block+tail.
        for (i, len) in [0usize, 1000, 4096, 5000, 81_931].into_iter().enumerate() {
            let data: Vec<u8> = (0..len).map(|j| (j * 31 + i) as u8).collect();
            let p = dir.join(format!("f{i}.bin"));
            fs::write(&p, &data).unwrap();
            let be = DirectIoBackend::new(4, false);
            let pool = BufPool::with_alignment(4, be.alignment());
            let buf = be
                .read_once(&p, &mut |len| BufPool::take(&pool, len))
                .unwrap();
            assert_eq!(buf.as_bytes(), &data[..], "len={len}");
            assert_eq!(buf.as_bytes().as_ptr() as usize % DIRECT_IO_ALIGN, 0);
            let (direct, fallback) = be.read_counts();
            assert_eq!(direct + fallback, 1);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn direct_backend_missing_file_is_not_found() {
        let be = DirectIoBackend::new(2, false);
        let err = be
            .read_once(Path::new("/nonexistent/graphmp/x.bin"), &mut AlignedBuf::with_len)
            .unwrap_err();
        let not_found = err
            .root_cause()
            .downcast_ref::<std::io::Error>()
            .is_some_and(|io| io.kind() == std::io::ErrorKind::NotFound);
        assert!(not_found, "{err:#}");
    }

    #[test]
    fn direct_backend_demotes_unaligned_buffers() {
        let dir = std::env::temp_dir().join("graphmp_direct_demote_test");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.bin");
        let data = vec![7u8; 10_000];
        fs::write(&p, &data).unwrap();
        let be = DirectIoBackend::new(2, false);
        // A 64B-aligned buffer is not O_DIRECT-eligible: the read must
        // still succeed via the per-request buffered fallback.
        let buf = be.read_once(&p, &mut AlignedBuf::with_len).unwrap();
        assert_eq!(buf.as_bytes(), &data[..]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ring_bounds_inflight_to_depth() {
        let dir = std::env::temp_dir().join("graphmp_ring_depth_test");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let be = DirectIoBackend::new(2, false);
        assert_eq!(be.submission_depth(), 2);
        // Hammer from more threads than slots: every read must complete
        // correctly with submissions blocking on SQ space.
        let mut paths = Vec::new();
        for i in 0..6 {
            let p = dir.join(format!("r{i}.bin"));
            fs::write(&p, vec![i as u8; 4096 + i * 13]).unwrap();
            paths.push(p);
        }
        std::thread::scope(|s| {
            for (i, p) in paths.iter().enumerate() {
                let be = &be;
                s.spawn(move || {
                    for _ in 0..8 {
                        let buf = be
                            .read_once(p, &mut |len| {
                                AlignedBuf::with_alignment(len, DIRECT_IO_ALIGN)
                            })
                            .unwrap();
                        assert_eq!(buf.len(), 4096 + i * 13);
                        assert!(buf.as_bytes().iter().all(|&b| b == i as u8));
                    }
                });
            }
        });
        fs::remove_dir_all(&dir).unwrap();
    }
}
