//! # GraphMP — I/O-efficient big graph analytics on a single machine
//!
//! A reproduction of *GraphMP: I/O-Efficient Big Graph Analytics on a
//! Single Commodity Machine* (Sun, Wen, Duong, Xiao; cs.DC 2018) as a
//! three-layer rust + JAX/Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's coordinator: VSW sliding-window
//!   engine, selective scheduling (Bloom filters), compressed edge cache,
//!   the preprocessing pipeline, every baseline engine and the analytical
//!   cost models.
//! - **Layer 2/1 (`python/compile`)** — the per-shard vertex update as a
//!   JAX function calling Pallas kernels, AOT-lowered to HLO text.
//! - **Runtime** — [`runtime`] loads the HLO artifacts through the PJRT C
//!   API (`xla` crate) so Python never runs on the iteration path.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use graphmp::graph::datasets::Dataset;
//! use graphmp::prep::{preprocess_into, PrepConfig};
//! use graphmp::storage::disk::{Disk, DiskProfile};
//! use graphmp::engine::{EngineConfig, VswEngine};
//! use graphmp::apps::PageRank;
//!
//! let g = Dataset::TwitterSim.generate_small();
//! let disk = Disk::new(DiskProfile::hdd_raid5());
//! let (dir, _) = preprocess_into(&g, "/tmp/g", &disk, PrepConfig::default()).unwrap();
//! let mut engine = VswEngine::open(&dir, &disk, EngineConfig::default()).unwrap();
//! let run = engine.run(&PageRank::new(), 10).unwrap();
//! println!("10 iterations in {:.2}s", run.total_seconds());
//! ```

pub mod apps;
pub mod baselines;
pub mod benchutil;
pub mod cli;
pub mod bloom;
pub mod cache;
pub mod cluster;
pub mod compress;
pub mod engine;
pub mod exec;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod prep;
pub mod runtime;
pub mod storage;
pub mod util;
