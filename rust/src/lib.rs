//! # GraphMP — I/O-efficient big graph analytics on a single machine
//!
//! A reproduction of *GraphMP: I/O-Efficient Big Graph Analytics on a
//! Single Commodity Machine* (Sun, Wen, Duong, Xiao; cs.DC 2018) as a
//! three-layer rust + JAX/Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's coordinator: VSW sliding-window
//!   engine, selective scheduling (Bloom filters), compressed edge cache,
//!   the preprocessing pipeline, every baseline engine and the analytical
//!   cost models.
//! - **Layer 2/1 (`python/compile`)** — the per-shard vertex update as a
//!   JAX function calling Pallas kernels, AOT-lowered to HLO text.
//! - **Runtime** — [`runtime`] loads the HLO artifacts through the PJRT C
//!   API (`xla` crate) so Python never runs on the iteration path.
//!
//! ## Module map
//!
//! Data flows `storage → cache → exec → engine / baselines → runtime`
//! (see `docs/ARCHITECTURE.md` for the full tour):
//!
//! - [`graph`] — edge lists, CSR, RMAT generators and the sim datasets.
//! - [`prep`] — one-time preprocessing: partition into shards, build
//!   Bloom filters, write the graph directory.
//! - [`storage`] — the on-disk graph directory, the simulated [`storage::disk::Disk`]
//!   (paper hardware profiles), and zero-copy [`storage::view::ShardView`]s.
//! - [`compress`] / [`cache`] — the five cache modes (§2.4.2) and the
//!   decode-once, verify-once compressed edge cache.
//! - [`bloom`] — per-shard Bloom filters for selective scheduling (§2.4.1).
//! - [`exec`] — the engine-agnostic execution core: one
//!   schedule→prefetch→compute pipeline ([`exec::ExecCore`]), scan-shared
//!   multi-job batches with interactive admission, (unit × job) fan-out
//!   and per-job metering.
//! - [`apps`] — vertex programs ([`apps::ShardKernel`]): PageRank, PPR,
//!   SSSP, BFS, CC, widest path.
//! - [`engine`] — the VSW engine ([`engine::VswEngine`]), GraphMP itself.
//! - [`baselines`] — GraphChi-PSW, X-Stream-ESG, GridGraph-DSW and the
//!   GraphMat-like in-memory engine on the same execution core.
//! - [`cluster`] — analytical models of the distributed baselines
//!   (Pregel+, PowerGraph/PowerLyra).
//! - [`runtime`] — the scan-shared job scheduler ([`runtime::JobSet`]),
//!   crash-safe checkpoint/recovery ([`runtime::checkpoint`]), the
//!   resident serving daemon ([`runtime::serve`], `graphmp serve`) with
//!   its newline-delimited JSON wire protocol ([`runtime::protocol`]),
//!   and the PJRT artifact executor.
//! - [`metrics`] / [`model`] / [`benchutil`] — run metrics (incl. per-job
//!   [`metrics::JobMetrics`] accounting), the paper's I/O cost models,
//!   and the bench harness behind `benches/fig*_*.rs`.
//!
//! ## Quickstart
//!
//! Library (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use graphmp::graph::datasets::Dataset;
//! use graphmp::prep::{preprocess_into, PrepConfig};
//! use graphmp::storage::disk::{Disk, DiskProfile};
//! use graphmp::engine::{EngineConfig, VswEngine};
//! use graphmp::apps::PageRank;
//!
//! let g = Dataset::TwitterSim.generate_small();
//! let disk = Disk::new(DiskProfile::hdd_raid5());
//! let (dir, _) = preprocess_into(&g, "/tmp/g", &disk, PrepConfig::default()).unwrap();
//! let mut engine = VswEngine::open(&dir, &disk, EngineConfig::default()).unwrap();
//! let run = engine.run(&PageRank::new(), 10).unwrap();
//! println!("10 iterations in {:.2}s", run.total_seconds());
//! ```
//!
//! CLI (see the `README.md` quickstart for the full tour):
//!
//! ```text
//! graphmp preprocess --dataset twitter-sim --dir /tmp/g --small
//! graphmp run --dir /tmp/g --app pagerank --iters 10
//! graphmp run --dir /tmp/g --app ppr --jobs 8 --arrivals every:2
//! ```

// The `simd` feature swaps the kernel's lane-add for `std::simd::f32x8`
// (see `exec::kernel::add_lanes`).  Portable SIMD is nightly-only, so
// the feature gate pulls in the unstable feature flag; stable builds
// never see it.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod apps;
pub mod baselines;
pub mod benchutil;
pub mod cli;
pub mod bloom;
pub mod cache;
pub mod cluster;
pub mod compress;
pub mod engine;
pub mod exec;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod prep;
pub mod runtime;
pub mod storage;
pub mod util;
