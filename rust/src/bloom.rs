//! Bloom filters for selective scheduling (paper §2.4.1).
//!
//! One filter per shard records the *source* vertices of the shard's edges.
//! When the active-vertex ratio is below the threshold, a shard whose
//! filter contains none of the active vertices is provably inactive (no
//! false negatives) and is skipped — no disk read, no compute.

use crate::util::rng::splitmix64;
use crate::util::bytes_as_u32s;

/// Double-hashing Bloom filter (Kirsch–Mitzenmacher: `h_i = h1 + i*h2`).
#[derive(Clone, Debug, PartialEq)]
pub struct BloomFilter {
    bits: Vec<u64>,
    num_bits: u64,
    num_hashes: u32,
}

/// Double-hashing base pair for an item.  Depends only on the item (not
/// the filter geometry), so callers probing one item against *many*
/// filters — [`BloomSet::probe_active`] — compute it once per item
/// instead of once per (item, filter) pair.
#[inline]
pub fn hash_pair(item: u32) -> (u64, u64) {
    let h1 = splitmix64(item as u64);
    let h2 = splitmix64(h1) | 1; // odd => full period
    (h1, h2)
}

impl BloomFilter {
    /// Size the filter for `expected_items` at `fp_rate` false positives.
    pub fn with_rate(expected_items: usize, fp_rate: f64) -> Self {
        let n = expected_items.max(1) as f64;
        let m = (-n * fp_rate.ln() / (std::f64::consts::LN_2.powi(2))).ceil() as u64;
        let m = m.max(64).next_multiple_of(64);
        let k = ((m as f64 / n) * std::f64::consts::LN_2).round().max(1.0) as u32;
        BloomFilter {
            bits: vec![0u64; (m / 64) as usize],
            num_bits: m,
            num_hashes: k.min(16),
        }
    }

    pub fn insert(&mut self, item: u32) {
        let (h1, h2) = hash_pair(item);
        for i in 0..self.num_hashes {
            let bit = h1.wrapping_add((i as u64).wrapping_mul(h2)) % self.num_bits;
            self.bits[(bit / 64) as usize] |= 1 << (bit % 64);
        }
    }

    /// May return false positives, never false negatives.
    pub fn contains(&self, item: u32) -> bool {
        let (h1, h2) = hash_pair(item);
        self.contains_hashed(h1, h2)
    }

    /// [`contains`](Self::contains) with a precomputed [`hash_pair`].
    #[inline]
    pub fn contains_hashed(&self, h1: u64, h2: u64) -> bool {
        (0..self.num_hashes).all(|i| {
            let bit = h1.wrapping_add((i as u64).wrapping_mul(h2)) % self.num_bits;
            self.bits[(bit / 64) as usize] & (1 << (bit % 64)) != 0
        })
    }

    /// True iff the filter (possibly) contains any of `items` — the shard
    /// activity test. Short-circuits on first hit.
    pub fn contains_any(&self, items: &[u32]) -> bool {
        items.iter().any(|&v| self.contains(v))
    }

    /// In-memory/serialized size: words + the 12-byte header of
    /// [`to_bytes`](Self::to_bytes) (`num_bits` u64 + `num_hashes` u32).
    pub fn size_bytes(&self) -> usize {
        self.bits.len() * 8 + 12
    }

    /// Serialise: `num_bits u64 | num_hashes u32 | words...` (LE u32 pairs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.bits.len() * 8);
        out.extend_from_slice(&self.num_bits.to_le_bytes());
        out.extend_from_slice(&self.num_hashes.to_le_bytes());
        for w in &self.bits {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> anyhow::Result<BloomFilter> {
        anyhow::ensure!(b.len() >= 12, "bloom too small");
        let num_bits = u64::from_le_bytes(b[..8].try_into().unwrap());
        let num_hashes = u32::from_le_bytes(b[8..12].try_into().unwrap());
        anyhow::ensure!(b.len() == 12 + (num_bits as usize / 64) * 8, "bloom truncated");
        let words = bytes_as_u32s(&b[12..]);
        let bits = words
            .chunks_exact(2)
            .map(|c| (c[0] as u64) | ((c[1] as u64) << 32))
            .collect();
        Ok(BloomFilter { bits, num_bits, num_hashes })
    }
}

/// The per-shard filter set, persisted as one file by preprocessing.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct BloomSet {
    pub filters: Vec<BloomFilter>,
}

impl BloomSet {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"GMPB");
        out.extend_from_slice(&(self.filters.len() as u32).to_le_bytes());
        for f in &self.filters {
            let fb = f.to_bytes();
            out.extend_from_slice(&(fb.len() as u32).to_le_bytes());
            out.extend_from_slice(&fb);
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> anyhow::Result<BloomSet> {
        anyhow::ensure!(b.len() >= 8 && &b[..4] == b"GMPB", "bad bloom set magic");
        let n = u32::from_le_bytes(b[4..8].try_into().unwrap()) as usize;
        let mut filters = Vec::with_capacity(n);
        let mut off = 8;
        for _ in 0..n {
            anyhow::ensure!(b.len() >= off + 4, "bloom set truncated");
            let len = u32::from_le_bytes(b[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            anyhow::ensure!(b.len() >= off + len, "bloom set truncated");
            filters.push(BloomFilter::from_bytes(&b[off..off + len])?);
            off += len;
        }
        Ok(BloomSet { filters })
    }

    pub fn size_bytes(&self) -> usize {
        self.filters.iter().map(|f| f.size_bytes()).sum()
    }

    /// Batched shard-activity probe: `out[s]` is true iff shard `s`'s
    /// filter (possibly) contains any of `active`.  One [`hash_pair`] per
    /// active vertex serves every filter, and the scan exits early once
    /// all shards are known active — strictly cheaper than calling
    /// [`BloomFilter::contains_any`] per shard.
    pub fn probe_active(&self, active: &[u32]) -> Vec<bool> {
        let mut hot = vec![false; self.filters.len()];
        if self.filters.is_empty() {
            return hot;
        }
        let mut cold = self.filters.len();
        for &v in active {
            let (h1, h2) = hash_pair(v);
            for (s, f) in self.filters.iter().enumerate() {
                if !hot[s] && f.contains_hashed(h1, h2) {
                    hot[s] = true;
                    cold -= 1;
                    if cold == 0 {
                        return hot;
                    }
                }
            }
        }
        hot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_false_negatives() {
        let mut f = BloomFilter::with_rate(1000, 0.01);
        for v in 0..1000u32 {
            f.insert(v * 7);
        }
        for v in 0..1000u32 {
            assert!(f.contains(v * 7));
        }
    }

    #[test]
    fn fp_rate_in_ballpark() {
        let mut f = BloomFilter::with_rate(10_000, 0.01);
        for v in 0..10_000u32 {
            f.insert(v);
        }
        let fps = (10_000u32..110_000).filter(|&v| f.contains(v)).count();
        let rate = fps as f64 / 100_000.0;
        assert!(rate < 0.03, "fp rate {rate}");
    }

    #[test]
    fn contains_any_short_circuit_semantics() {
        let mut f = BloomFilter::with_rate(10, 0.001);
        f.insert(42);
        assert!(f.contains_any(&[1, 2, 42]));
        // `contains_any` of an empty active list must be false: an
        // iteration with no active vertices activates no shard.
        assert!(!f.contains_any(&[]));
    }

    #[test]
    fn filter_round_trip() {
        let mut f = BloomFilter::with_rate(100, 0.01);
        for v in [3u32, 5, 800, 13] {
            f.insert(v);
        }
        let g = BloomFilter::from_bytes(&f.to_bytes()).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn set_round_trip() {
        let mut a = BloomFilter::with_rate(10, 0.01);
        a.insert(1);
        let mut b = BloomFilter::with_rate(1000, 0.001);
        b.insert(999);
        let set = BloomSet { filters: vec![a, b] };
        assert_eq!(BloomSet::from_bytes(&set.to_bytes()).unwrap(), set);
    }

    #[test]
    fn set_rejects_garbage() {
        assert!(BloomSet::from_bytes(b"XXXX____").is_err());
    }

    #[test]
    fn size_bytes_matches_serialised_len() {
        // Fig 11's memory account sums `size_bytes`; it must equal the
        // bytes actually persisted per filter (12-byte header + words).
        for n in [1usize, 10, 1000, 50_000] {
            let f = BloomFilter::with_rate(n, 0.01);
            assert_eq!(f.to_bytes().len(), f.size_bytes(), "n={n}");
        }
        // set framing adds the GMPB magic + count (8B) and a 4B length
        // prefix per filter on top of the per-filter account
        let set = BloomSet {
            filters: vec![
                BloomFilter::with_rate(10, 0.01),
                BloomFilter::with_rate(500, 0.001),
            ],
        };
        assert_eq!(set.to_bytes().len(), set.size_bytes() + 8 + 2 * 4);
    }

    #[test]
    fn probe_active_matches_per_filter_contains_any() {
        let mut filters = Vec::new();
        for s in 0..4u32 {
            let mut f = BloomFilter::with_rate(64, 0.001);
            for v in 0..32u32 {
                f.insert(s * 1000 + v);
            }
            filters.push(f);
        }
        let set = BloomSet { filters };
        for active in [
            vec![],
            vec![5u32],
            vec![5, 2007],
            vec![1, 2, 3, 1001, 3005],
            vec![9999],
        ] {
            let hot = set.probe_active(&active);
            for (s, f) in set.filters.iter().enumerate() {
                assert_eq!(
                    hot[s],
                    f.contains_any(&active),
                    "shard {s}, active {active:?}"
                );
            }
        }
    }

    #[test]
    fn probe_active_empty_set() {
        assert!(BloomSet::default().probe_active(&[1, 2, 3]).is_empty());
    }

    #[test]
    fn sizes_scale_with_items() {
        let small = BloomFilter::with_rate(100, 0.01);
        let big = BloomFilter::with_rate(100_000, 0.01);
        assert!(big.size_bytes() > 100 * small.size_bytes() / 2);
    }
}
