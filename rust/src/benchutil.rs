//! Minimal benchmark harness (criterion is not in the vendored crate set).
//!
//! Provides timing statistics and fixed-width table printing shared by all
//! `benches/*.rs` targets, which regenerate the paper's tables/figures as
//! text.

use std::time::Instant;

/// Run `f` `iters` times after `warmup` runs; returns per-run seconds.
pub fn time_n<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect()
}

/// Mean / stddev / min of a sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

pub fn stats(samples: &[f64]) -> Stats {
    if samples.is_empty() {
        return Stats::default();
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    Stats {
        mean,
        stddev: var.sqrt(),
        min: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max: samples.iter().copied().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Fixed-width text table writer for the bench outputs.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..cols {
                s.push_str(&format!("{:<w$}  ", cells[i], w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        print!("{}", self.render());
    }
}

/// One-line pipeline summary of a run: prefetched shards, ready-queue hit
/// ratio, decode counts and overlapped (hidden) simulated disk seconds —
/// the counters the fig7/fig8 benches and `perf_probe` report.
pub fn pipeline_summary(run: &crate::metrics::RunMetrics) -> String {
    let prefetched: u64 = run.iterations.iter().map(|m| m.shards_prefetched as u64).sum();
    let hits: u64 = run.iterations.iter().map(|m| m.ready_hits as u64).sum();
    let misses: u64 = run.iterations.iter().map(|m| m.ready_misses as u64).sum();
    let decodes: u64 = run.iterations.iter().map(|m| m.cache.decodes).sum();
    let skips: u64 = run.iterations.iter().map(|m| m.cache.decode_skips).sum();
    let crc_skips: u64 = run
        .iterations
        .iter()
        .map(|m| m.cache.crc_verifies_skipped)
        .sum();
    let ready_pct = if hits + misses == 0 {
        0.0
    } else {
        100.0 * hits as f64 / (hits + misses) as f64
    };
    format!(
        "pipeline: prefetched {prefetched}, ready-hit {ready_pct:.0}%, decodes {decodes} (memo-skipped {skips}, crc-skipped {crc_skips}), overlapped sim {:.3}s of {:.3}s",
        run.total_overlapped_sim_seconds, run.total_sim_disk_seconds
    )
}

/// One-line scan-sharing summary of a batch: loads vs job-servings,
/// the amortization factor and the per-job effective disk bytes — what
/// the `--jobs` CLI path and the Fig 12/13 benches report.  Interactive
/// batches append their admission and fan-out counters.
pub fn batch_summary(b: &crate::metrics::BatchMetrics) -> String {
    let mut s = format!(
        "scan sharing: {} jobs x {} passes, {} shard loads served {} job-consumptions ({:.2}x amortized), {:.1} KiB read/job effective",
        b.jobs,
        b.passes,
        b.shard_loads,
        b.shard_servings,
        b.shard_loads_amortized(),
        b.effective_bytes_read_per_job() / 1024.0
    );
    if b.admitted_mid_batch > 0 {
        s.push_str(&format!(
            ", {} admitted mid-batch ({} deferred)",
            b.admitted_mid_batch, b.admissions_deferred
        ));
    }
    if b.shard_servings_fanned > 0 {
        s.push_str(&format!(
            ", {} servings fanned to idle workers",
            b.shard_servings_fanned
        ));
    }
    s
}

/// One-line per-job accounting summary ([`crate::metrics::JobMetrics`]):
/// the attribution a serving scheduler would bill the query.
pub fn job_summary(j: &crate::metrics::JobMetrics) -> String {
    format!(
        "job: arrived pass {}, {} iters, {:.3}ms compute, {} shards served, {} edges, {:.1} KiB effective read",
        j.admitted_pass,
        j.iterations,
        j.compute.as_secs_f64() * 1e3,
        j.units_served,
        j.edges_processed,
        j.effective_bytes_read / 1024.0
    )
}

/// Shared bench banner so `cargo bench` output is self-describing.
pub fn banner(name: &str, paper_ref: &str) {
    println!("\n################################################################");
    println!("# bench: {name}");
    println!("# reproduces: {paper_ref}");
    println!("################################################################");
}

/// Shared bench-scale constants.
///
/// The sim datasets scale the paper's graphs down by ~3–4 decimal orders;
/// the RAM budgets below scale the testbed's memory the same way so every
/// memory-driven effect reproduces:
///
/// - paper: GraphMat (single 128GB box) loads Twitter (~1.5B·22B ≈ 33GB
///   peak) but OOMs on UK-2007 (5.5B edges).  Sim: 24MB budget sits between
///   twitter-sim's ~18MB and uk2007-sim's ~43MB loading peaks.
/// - paper: Pregel+/PowerGraph/PowerLyra (9 × 128GB) handle UK-2007 but
///   crash on UK-2014/EU-2015.  Sim: 16MB/machine sits between
///   uk2007-sim's ~5MB and uk2014-sim's ~28MB per-machine residency.
/// - paper: GraphMP's cache (128GB box) holds EU-2015 only zlib-compressed
///   (362GB raw → 62GB zlib-3 < ~68GB spare).  Sim: 40MB cache vs
///   eu2015-sim's ~95MB raw shards forces the same mode escalation.
pub mod scale {
    /// Single-machine edge-cache capacity for GraphMP (bytes).
    /// eu2015-sim is 86.5MiB raw / ~54MiB zlib (Table 2 bench), so 56MiB
    /// reproduces the paper's regime: raw caching holds ~65%, zlib holds
    /// everything — the same escalation EU-2015 forces at 128GB.
    pub const CACHE_CAPACITY: u64 = 56 * 1024 * 1024;
    /// GraphMat-like loading budget (bytes).
    pub const GRAPHMAT_RAM: u64 = 24 * 1024 * 1024;
    /// Distributed in-memory engines: RAM per machine (bytes).
    pub const CLUSTER_RAM_PER_MACHINE: u64 = 16 * 1024 * 1024;
    /// Shard size for the sim datasets (edges) — keeps tens of shards per
    /// graph, the paper's regime.
    pub const EDGES_PER_SHARD: u32 = 262_144;
    /// Row cap aligned with the `medium` AOT artifact (Rc = 16384).
    pub const MAX_ROWS: u32 = 8_192;

    /// The bench disk: the per-core share of the paper's RAID5 array
    /// (310MB/s ÷ 12 cores ≈ 26MB/s), since the bench host runs one
    /// worker where the paper ran twelve against the same array.
    pub fn bench_disk() -> crate::storage::disk::Disk {
        crate::storage::disk::Disk::new(
            crate::storage::disk::DiskProfile::hdd_raid5_shared(12),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 3.0).abs() < 1e-12);
        assert!(s.stddev > 0.0);
    }

    #[test]
    fn stats_empty() {
        assert_eq!(stats(&[]).mean, 0.0);
    }

    #[test]
    fn time_n_counts() {
        let mut calls = 0;
        let t = time_n(2, 3, || calls += 1);
        assert_eq!(calls, 5);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bbbb"]);
        t.row(vec!["xx", "y"]);
        let r = t.render();
        assert!(r.contains("a   bbbb"));
        assert!(r.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x", "y"]);
    }

    #[test]
    fn batch_summary_formats_amortization() {
        let b = crate::metrics::BatchMetrics {
            jobs: 8,
            passes: 10,
            shard_loads: 100,
            shard_servings: 800,
            bytes_read: 8 * 1024 * 100,
            ..Default::default()
        };
        let s = batch_summary(&b);
        assert!(s.contains("8 jobs"), "{s}");
        assert!(s.contains("8.00x amortized"), "{s}");
        assert!(s.contains("100.0 KiB read/job"), "{s}");
        assert!(!s.contains("mid-batch"), "plain batches omit admission info: {s}");
    }

    #[test]
    fn batch_summary_reports_interactive_counters() {
        let b = crate::metrics::BatchMetrics {
            jobs: 3,
            admitted_mid_batch: 2,
            admissions_deferred: 1,
            shard_loads: 10,
            shard_servings: 20,
            shard_servings_fanned: 6,
            ..Default::default()
        };
        let s = batch_summary(&b);
        assert!(s.contains("2 admitted mid-batch (1 deferred)"), "{s}");
        assert!(s.contains("6 servings fanned"), "{s}");
    }

    #[test]
    fn job_summary_formats_attribution() {
        let j = crate::metrics::JobMetrics {
            admitted_pass: 4,
            iterations: 7,
            compute: std::time::Duration::from_millis(12),
            units_served: 21,
            edges_processed: 1234,
            effective_bytes_read: 2048.0,
        };
        let s = job_summary(&j);
        assert!(s.contains("arrived pass 4"), "{s}");
        assert!(s.contains("7 iters"), "{s}");
        assert!(s.contains("12.000ms compute"), "{s}");
        assert!(s.contains("21 shards served"), "{s}");
        assert!(s.contains("2.0 KiB effective read"), "{s}");
    }

    #[test]
    fn pipeline_summary_formats_counters() {
        use crate::metrics::{IterationMetrics, RunMetrics};
        let mut run = RunMetrics {
            total_sim_disk_seconds: 2.0,
            total_overlapped_sim_seconds: 1.5,
            ..Default::default()
        };
        run.iterations.push(IterationMetrics {
            shards_prefetched: 10,
            ready_hits: 9,
            ready_misses: 1,
            ..Default::default()
        });
        let s = pipeline_summary(&run);
        assert!(s.contains("prefetched 10"), "{s}");
        assert!(s.contains("ready-hit 90%"), "{s}");
        assert!(s.contains("1.500s"), "{s}");
    }
}
