//! The compressed edge cache (paper §2.4.2).
//!
//! Capacity-bounded, shard-id-keyed.  On a hit the shard is decompressed
//! from RAM (throughput ≫ disk); on a miss the caller loads from disk and
//! offers the bytes back with [`EdgeCache::admit`].  No eviction policy is
//! needed: the shard set is fixed after preprocessing, so the cache simply
//! fills until capacity (matching the paper, which caches "as many shards
//! as possible") — an LRU would only churn identical-value entries.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::compress::CacheMode;
use crate::storage::shard::Shard;

/// Hit/miss counters (atomics: workers probe concurrently).
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub used_bytes: u64,
}

impl CacheSnapshot {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

enum Entry {
    /// Mode 1 stores the shard parsed once — a cache hit is an Arc clone
    /// (zero-copy), not a re-parse of ~MBs of CSR bytes (§Perf log).
    Parsed(Arc<Shard>),
    /// Compressed modes store bytes; hits decompress + parse.
    Compressed(Vec<u8>),
}

/// The cache proper.  `mode == M0None` disables it entirely.
pub struct EdgeCache {
    mode: CacheMode,
    capacity_bytes: u64,
    used_bytes: AtomicU64,
    entries: RwLock<HashMap<u32, Arc<Entry>>>,
    /// Shards already rejected on capacity — the shard set is static, so
    /// re-offering them would only repeat the (possibly expensive)
    /// compression; skip them permanently.
    rejected_ids: RwLock<HashSet<u32>>,
    stats: CacheStats,
}

impl EdgeCache {
    pub fn new(mode: CacheMode, capacity_bytes: u64) -> Self {
        EdgeCache {
            mode,
            capacity_bytes: if mode == CacheMode::M0None { 0 } else { capacity_bytes },
            used_bytes: AtomicU64::new(0),
            entries: RwLock::new(HashMap::new()),
            rejected_ids: RwLock::new(HashSet::new()),
            stats: CacheStats::default(),
        }
    }

    /// Auto-select the mode per §2.4.2 and build the cache.
    pub fn auto(graph_bytes: u64, capacity_bytes: u64) -> Self {
        let mode = crate::compress::select_mode(graph_bytes, capacity_bytes);
        EdgeCache::new(mode, capacity_bytes)
    }

    pub fn mode(&self) -> CacheMode {
        self.mode
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Probe for a shard; decompresses on hit (zero-copy for mode 1).
    pub fn get(&self, shard_id: u32) -> Result<Option<Arc<Shard>>> {
        if self.mode == CacheMode::M0None {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return Ok(None);
        }
        let entry = {
            let map = self.entries.read().unwrap();
            map.get(&shard_id).cloned()
        };
        match entry {
            Some(e) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                match &*e {
                    Entry::Parsed(shard) => Ok(Some(Arc::clone(shard))),
                    Entry::Compressed(bytes) => {
                        let raw = self.mode.decompress(bytes)?;
                        Ok(Some(Arc::new(Shard::from_bytes(&raw)?)))
                    }
                }
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
        }
    }

    /// Offer freshly-loaded shard bytes; stored if capacity allows.
    /// Returns whether the shard was admitted.
    pub fn admit(&self, shard_id: u32, raw_bytes: &[u8]) -> bool {
        if self.mode == CacheMode::M0None {
            return false;
        }
        {
            let map = self.entries.read().unwrap();
            if map.contains_key(&shard_id) {
                return true; // raced with another worker: already cached
            }
        }
        if self.rejected_ids.read().unwrap().contains(&shard_id) {
            return false; // don't recompress a known non-fit every miss
        }
        // cheap pre-check: even a best-case compression can't fit
        if self.used_bytes.load(Ordering::Relaxed) + raw_bytes.len() as u64 / 8
            > self.capacity_bytes
        {
            self.rejected_ids.write().unwrap().insert(shard_id);
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let entry = if self.mode == CacheMode::M1Raw {
            match Shard::from_bytes(raw_bytes) {
                Ok(sh) => Entry::Parsed(Arc::new(sh)),
                Err(_) => return false, // corrupt bytes never enter the cache
            }
        } else {
            Entry::Compressed(self.mode.compress(raw_bytes))
        };
        let sz = match &entry {
            Entry::Parsed(sh) => (sh.csr.size_bytes() + 32) as u64,
            Entry::Compressed(c) => c.len() as u64,
        };
        // optimistic reservation
        let prev = self.used_bytes.fetch_add(sz, Ordering::Relaxed);
        if prev + sz > self.capacity_bytes {
            self.used_bytes.fetch_sub(sz, Ordering::Relaxed);
            self.rejected_ids.write().unwrap().insert(shard_id);
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let mut map = self.entries.write().unwrap();
        if map.contains_key(&shard_id) {
            self.used_bytes.fetch_sub(sz, Ordering::Relaxed);
            return true;
        }
        map.insert(shard_id, Arc::new(entry));
        self.stats.admitted.fetch_add(1, Ordering::Relaxed);
        true
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            admitted: self.stats.admitted.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            used_bytes: self.used_bytes.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, Edge};

    fn mk_shard(id: u32, edges: usize) -> Shard {
        let es: Vec<Edge> = (0..edges)
            .map(|i| Edge::new((i % 97) as u32, 100 + (i % 8) as u32))
            .collect();
        Shard { id, start_vertex: 100, csr: Csr::from_edges(&es, 100, 8, false) }
    }

    #[test]
    fn hit_after_admit() {
        let cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        let s = mk_shard(0, 500);
        assert!(cache.get(0).unwrap().is_none());
        assert!(cache.admit(0, &s.to_bytes()));
        let got = cache.get(0).unwrap().unwrap();
        assert_eq!(*got, s);
        let snap = cache.snapshot();
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.misses, 1);
        assert!(snap.used_bytes > 0);
    }

    #[test]
    fn capacity_rejects() {
        let cache = EdgeCache::new(CacheMode::M1Raw, 100); // tiny
        let s = mk_shard(1, 500);
        assert!(!cache.admit(1, &s.to_bytes()));
        assert_eq!(cache.snapshot().rejected, 1);
        assert_eq!(cache.snapshot().used_bytes, 0); // reservation rolled back
    }

    #[test]
    fn mode0_never_caches() {
        let cache = EdgeCache::new(CacheMode::M0None, u64::MAX);
        let s = mk_shard(2, 100);
        assert!(!cache.admit(2, &s.to_bytes()));
        assert!(cache.get(2).unwrap().is_none());
    }

    #[test]
    fn compressed_fits_more() {
        let raw = EdgeCache::new(CacheMode::M1Raw, 40_000);
        let z = EdgeCache::new(CacheMode::M4Zlib3, 40_000);
        let mut raw_count = 0;
        let mut z_count = 0;
        for id in 0..32 {
            let b = mk_shard(id, 1000).to_bytes();
            raw_count += raw.admit(id, &b) as u32;
            z_count += z.admit(id, &b) as u32;
        }
        assert!(
            z_count > raw_count,
            "zlib cached {z_count} <= raw {raw_count}"
        );
    }

    #[test]
    fn double_admit_is_idempotent() {
        let cache = EdgeCache::new(CacheMode::M2Fast, 1 << 20);
        let b = mk_shard(3, 100).to_bytes();
        assert!(cache.admit(3, &b));
        let used = cache.snapshot().used_bytes;
        assert!(cache.admit(3, &b));
        assert_eq!(cache.snapshot().used_bytes, used);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn auto_picks_reasonably() {
        let c = EdgeCache::auto(1000, 10_000);
        assert_eq!(c.mode(), CacheMode::M1Raw);
        let c = EdgeCache::auto(1_000_000, 10_000);
        assert_eq!(c.mode(), CacheMode::M4Zlib3);
    }

    #[test]
    fn hit_ratio_math() {
        let snap = CacheSnapshot { hits: 3, misses: 1, ..Default::default() };
        assert!((snap.hit_ratio() - 0.75).abs() < 1e-9);
        assert_eq!(CacheSnapshot::default().hit_ratio(), 0.0);
    }
}
