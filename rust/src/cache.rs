//! The compressed edge cache (paper §2.4.2) with a decode-once,
//! verify-once, zero-copy hot path.
//!
//! Capacity-bounded, shard-id-keyed.  On a hit the shard is decompressed
//! from RAM (throughput ≫ disk); on a miss the caller loads from disk and
//! offers the bytes back with [`EdgeCache::admit`].  The *compressed*
//! entries need no eviction policy: the shard set is fixed after
//! preprocessing, so the cache simply fills until capacity (matching the
//! paper, which caches "as many shards as possible") — an LRU there
//! would only churn identical-value entries.
//!
//! Served shards are zero-copy [`ShardView`]s: mode 1 stores the view of
//! the aligned file image directly, and compressed entries memoize their
//! decoded view in the **decoded pool**, so a hit is an `Arc` clone —
//! no inflate, no parse, no allocation.  The pool is strictly
//! budget-bounded (it is real extra RAM, accounted as `memo_bytes` /
//! Fig 11's decoded pool) and — unlike the compressed entries —
//! **LRU-evicted**: when pinning a freshly decoded shard would exceed
//! the budget, the least-recently-hit pins are released first, so long
//! runs on small budgets keep the *hot* shards decoded instead of
//! freezing whichever shards happened to be touched first.  Beyond the
//! budget a hit decodes — at most once per scheduled shard per
//! iteration, because the execution core's prefetcher fetches each shard
//! exactly once and hands the decoded `Arc` to the compute worker
//! through the ready queue.
//!
//! **CRC lifecycle**: shard bytes are verified exactly once — on the
//! load path (the engine's disk read, recorded via
//! [`EdgeCache::note_crc_verified`]) or at admission when the caller
//! offers unverified bytes.  Every later serving (parsed entry, memo
//! hit, or memo-miss decode of admission-verified bytes) skips the hash
//! pass and counts `crc_verifies_skipped` instead.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::Result;

use crate::compress::CacheMode;
use crate::storage::view::{AlignedBuf, ShardView};

/// Hit/miss counters (atomics: workers probe concurrently).
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    /// Full decompress + parse passes on compressed entries.
    pub decodes: AtomicU64,
    /// Compressed-entry hits served from the parsed memo (no decode).
    pub decode_skips: AtomicU64,
    /// CRC passes actually performed (load path + unverified admissions).
    pub crc_verified: AtomicU64,
    /// Shard servings that skipped the CRC pass because the bytes were
    /// verified at admission / first load.
    pub crc_skipped: AtomicU64,
    /// Scan-sharing attribution: (unit, job) consumptions the execution
    /// core fanned each pass's probes out to — `job_servings / (hits +
    /// misses)` is how many jobs each cache probe (and admission) served.
    pub job_servings: AtomicU64,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub used_bytes: u64,
    pub decodes: u64,
    pub decode_skips: u64,
    /// CRC passes performed (admission / first load).
    pub crc_verifies: u64,
    /// Servings that skipped re-verification (decode-once lifecycle).
    pub crc_verifies_skipped: u64,
    /// Bytes of parsed shards pinned by the decode-memo budget.
    pub memo_bytes: u64,
    /// Per-job attribution of scan sharing: (unit, job) consumptions
    /// served out of this cache's shard passes (== servings solo).
    pub job_servings: u64,
}

impl CacheSnapshot {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

enum Entry {
    /// Mode 1 stores the zero-copy view of the shard's file image — a
    /// cache hit is an Arc clone, never a re-parse.
    Parsed(Arc<ShardView>),
    /// Compressed modes store bytes; a hit decodes unless the parsed
    /// view is pinned in the budget-bounded memo.  `raw_len` is the
    /// uncompressed size, so a decode can inflate straight into an
    /// exactly-sized [`AlignedBuf`] (no intermediate `Vec` copy).
    Compressed {
        bytes: Vec<u8>,
        raw_len: usize,
        memo: RwLock<Option<Arc<ShardView>>>,
    },
}

/// The cache proper.  `mode == M0None` disables it entirely.
pub struct EdgeCache {
    mode: CacheMode,
    capacity_bytes: u64,
    used_bytes: AtomicU64,
    /// Byte budget of the decoded pool (parsed shards pinned beside their
    /// compressed entries; 0 = no decode memo).
    memo_budget: u64,
    memo_used: AtomicU64,
    /// Pinned shard ids in hit order (front = least recently hit).  All
    /// pin/unpin/touch traffic serialises on this lock, which also
    /// orders the per-entry memo-slot writes it protects.
    memo_lru: Mutex<Vec<u32>>,
    entries: RwLock<HashMap<u32, Arc<Entry>>>,
    /// Shards already rejected on capacity — the shard set is static, so
    /// re-offering them would only repeat the (possibly expensive)
    /// compression; skip them permanently.
    rejected_ids: RwLock<HashSet<u32>>,
    stats: CacheStats,
}

impl EdgeCache {
    pub fn new(mode: CacheMode, capacity_bytes: u64) -> Self {
        EdgeCache {
            mode,
            capacity_bytes: if mode == CacheMode::M0None { 0 } else { capacity_bytes },
            used_bytes: AtomicU64::new(0),
            memo_budget: 0,
            memo_used: AtomicU64::new(0),
            memo_lru: Mutex::new(Vec::new()),
            entries: RwLock::new(HashMap::new()),
            rejected_ids: RwLock::new(HashSet::new()),
            stats: CacheStats::default(),
        }
    }

    /// Auto-select the mode per §2.4.2 and build the cache.
    pub fn auto(graph_bytes: u64, capacity_bytes: u64) -> Self {
        let mode = crate::compress::select_mode(graph_bytes, capacity_bytes);
        EdgeCache::new(mode, capacity_bytes)
    }

    /// Set the decode-once memo budget (bytes of parsed shards kept
    /// beside the compressed entries).  Call before sharing the cache.
    pub fn set_decode_memo_budget(&mut self, bytes: u64) {
        self.memo_budget = bytes;
    }

    pub fn decode_memo_budget(&self) -> u64 {
        self.memo_budget
    }

    pub fn mode(&self) -> CacheMode {
        self.mode
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Record a CRC verification performed by the caller on the load
    /// path — the once-per-shard "verify" of the decode-once lifecycle
    /// (every cache serving afterwards skips the hash pass).
    pub fn note_crc_verified(&self) {
        self.stats.crc_verified.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how many (unit, job) consumptions this pass's shard
    /// servings fanned out to — the execution core calls this once per
    /// scan-shared pass, so `job_servings / (hits + misses)` reports the
    /// per-job amortization of every probe and admission.
    pub fn note_job_servings(&self, servings: u64) {
        self.stats.job_servings.fetch_add(servings, Ordering::Relaxed);
    }

    /// Probe for a shard; a hit is an Arc clone when the entry is parsed
    /// (mode 1) or memoized; otherwise it decodes (and tries to memoize).
    /// Served bytes were CRC-verified at admission, so no serving re-runs
    /// the hash (`crc_verifies_skipped` counts them).
    pub fn get(&self, shard_id: u32) -> Result<Option<Arc<ShardView>>> {
        if self.mode == CacheMode::M0None {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return Ok(None);
        }
        let entry = {
            let map = self.entries.read().unwrap();
            map.get(&shard_id).cloned()
        };
        match entry {
            Some(e) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats.crc_skipped.fetch_add(1, Ordering::Relaxed);
                match &*e {
                    Entry::Parsed(view) => Ok(Some(Arc::clone(view))),
                    Entry::Compressed { bytes, raw_len, memo } => {
                        // clone out of the slot before touching the LRU:
                        // lock order is always memo_lru → slot
                        let pinned = memo.read().unwrap().clone();
                        if let Some(view) = pinned {
                            self.stats.decode_skips.fetch_add(1, Ordering::Relaxed);
                            self.touch_memo(shard_id);
                            return Ok(Some(view));
                        }
                        // inflate straight into the aligned buffer — the
                        // stored raw length sizes it exactly, so the old
                        // Vec<u8> → AlignedBuf copy is gone
                        let mut buf = AlignedBuf::with_len(*raw_len);
                        self.mode.decompress_into(bytes, buf.as_bytes_mut())?;
                        let view = Arc::new(ShardView::parse_unverified(buf)?);
                        self.stats.decodes.fetch_add(1, Ordering::Relaxed);
                        self.memoize(shard_id, memo, &view);
                        Ok(Some(view))
                    }
                }
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
        }
    }

    /// Offer freshly-loaded shard bytes; stored if capacity allows.
    /// Returns whether the shard was admitted.  Unverified bytes are
    /// CRC-checked once here (corrupt bytes never enter the cache), so
    /// every later serving can skip the hash pass.
    pub fn admit(&self, shard_id: u32, raw_bytes: &[u8]) -> bool {
        self.admit_impl(shard_id, raw_bytes, None)
    }

    /// [`admit`](Self::admit) when the caller already parsed (and
    /// CRC-verified) the bytes: mode 1 reuses the given `Arc` instead of
    /// re-parsing, compressed modes seed the decode memo with it.
    pub fn admit_with(&self, shard_id: u32, raw_bytes: &[u8], parsed: &Arc<ShardView>) -> bool {
        self.admit_impl(shard_id, raw_bytes, Some(parsed))
    }

    fn admit_impl(
        &self,
        shard_id: u32,
        raw_bytes: &[u8],
        parsed: Option<&Arc<ShardView>>,
    ) -> bool {
        if self.mode == CacheMode::M0None {
            return false;
        }
        {
            let map = self.entries.read().unwrap();
            if map.contains_key(&shard_id) {
                return true; // raced with another worker: already cached
            }
        }
        if self.rejected_ids.read().unwrap().contains(&shard_id) {
            return false; // don't recompress a known non-fit every miss
        }
        // cheap pre-check: even a best-case compression can't fit
        if self.used_bytes.load(Ordering::Relaxed) + raw_bytes.len() as u64 / 8
            > self.capacity_bytes
        {
            self.rejected_ids.write().unwrap().insert(shard_id);
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // the admission-time "verify once": bytes the caller did not
        // already parse are structurally validated + CRC-checked here
        let verified = match parsed {
            Some(view) => Some(Arc::clone(view)),
            None => match ShardView::parse(AlignedBuf::from_bytes(raw_bytes)) {
                Ok(view) => {
                    self.stats.crc_verified.fetch_add(1, Ordering::Relaxed);
                    Some(Arc::new(view))
                }
                Err(_) => return false, // corrupt bytes never enter the cache
            },
        };
        let entry = if self.mode == CacheMode::M1Raw {
            Entry::Parsed(verified.expect("verified above"))
        } else {
            Entry::Compressed {
                bytes: self.mode.compress(raw_bytes),
                raw_len: raw_bytes.len(),
                memo: RwLock::new(None),
            }
        };
        let sz = match &entry {
            Entry::Parsed(view) => (view.size_bytes() + 32) as u64,
            Entry::Compressed { bytes, .. } => bytes.len() as u64,
        };
        // optimistic reservation
        let prev = self.used_bytes.fetch_add(sz, Ordering::Relaxed);
        if prev + sz > self.capacity_bytes {
            self.used_bytes.fetch_sub(sz, Ordering::Relaxed);
            self.rejected_ids.write().unwrap().insert(shard_id);
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let entry = Arc::new(entry);
        {
            let mut map = self.entries.write().unwrap();
            if map.contains_key(&shard_id) {
                self.used_bytes.fetch_sub(sz, Ordering::Relaxed);
                return true;
            }
            map.insert(shard_id, Arc::clone(&entry));
            self.stats.admitted.fetch_add(1, Ordering::Relaxed);
        }
        // only caller-parsed views seed the decode memo: a plain `admit`
        // verifies and drops its parse, pinning nothing (the pool fills
        // on first hit instead)
        if let (Entry::Compressed { memo, .. }, Some(view)) = (&*entry, parsed) {
            self.memoize(shard_id, memo, view);
        }
        true
    }

    /// Move a pinned shard to the most-recently-hit end of the locked LRU.
    fn touch_locked(lru: &mut Vec<u32>, shard_id: u32) {
        if let Some(pos) = lru.iter().position(|&id| id == shard_id) {
            lru.remove(pos);
            lru.push(shard_id);
        }
    }

    /// Move a pinned shard to the most-recently-hit end of the LRU.
    fn touch_memo(&self, shard_id: u32) {
        Self::touch_locked(&mut self.memo_lru.lock().unwrap(), shard_id);
    }

    /// Pin `view` as the entry's decoded memo, LRU-evicting older pins
    /// until it fits the budget.  A shard larger than the whole budget is
    /// never pinned (it would evict everything for one entry); its hits
    /// simply stay decode-on-hit — anything else would hold the decoded
    /// graph in RAM unaccounted, defeating the compressed cache's memory
    /// bound.
    fn memoize(&self, shard_id: u32, slot: &RwLock<Option<Arc<ShardView>>>, view: &Arc<ShardView>) {
        if self.memo_budget == 0 {
            return;
        }
        let sz = (view.size_bytes() + 32) as u64;
        if sz > self.memo_budget {
            return;
        }
        let mut lru = self.memo_lru.lock().unwrap();
        {
            let mut w = slot.write().unwrap();
            if w.is_some() {
                // raced: another thread pinned it first — count the hit
                Self::touch_locked(&mut lru, shard_id);
                return;
            }
            // evict least-recently-hit pins until this one fits
            while self.memo_used.load(Ordering::Relaxed) + sz > self.memo_budget
                && !lru.is_empty()
            {
                let victim = lru.remove(0);
                let entry = self.entries.read().unwrap().get(&victim).cloned();
                if let Some(entry) = entry {
                    if let Entry::Compressed { memo, .. } = &*entry {
                        if let Some(evicted) = memo.write().unwrap().take() {
                            self.memo_used.fetch_sub(
                                (evicted.size_bytes() + 32) as u64,
                                Ordering::Relaxed,
                            );
                        }
                    }
                }
            }
            if self.memo_used.load(Ordering::Relaxed) + sz <= self.memo_budget {
                *w = Some(Arc::clone(view));
                self.memo_used.fetch_add(sz, Ordering::Relaxed);
                lru.push(shard_id);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            admitted: self.stats.admitted.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            used_bytes: self.used_bytes.load(Ordering::Relaxed),
            decodes: self.stats.decodes.load(Ordering::Relaxed),
            decode_skips: self.stats.decode_skips.load(Ordering::Relaxed),
            crc_verifies: self.stats.crc_verified.load(Ordering::Relaxed),
            crc_verifies_skipped: self.stats.crc_skipped.load(Ordering::Relaxed),
            memo_bytes: self.memo_used.load(Ordering::Relaxed),
            job_servings: self.stats.job_servings.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, Edge};
    use crate::storage::shard::Shard;

    fn mk_shard(id: u32, edges: usize) -> Shard {
        let es: Vec<Edge> = (0..edges)
            .map(|i| Edge::new((i % 97) as u32, 100 + (i % 8) as u32))
            .collect();
        Shard { id, start_vertex: 100, csr: Csr::from_edges(&es, 100, 8, false) }
    }

    fn mk_view(s: &Shard) -> Arc<ShardView> {
        Arc::new(ShardView::parse(AlignedBuf::from_bytes(&s.to_bytes())).unwrap())
    }

    #[test]
    fn hit_after_admit() {
        let cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        let s = mk_shard(0, 500);
        assert!(cache.get(0).unwrap().is_none());
        assert!(cache.admit(0, &s.to_bytes()));
        let got = cache.get(0).unwrap().unwrap();
        assert_eq!(got.to_shard(), s);
        let snap = cache.snapshot();
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.misses, 1);
        assert!(snap.used_bytes > 0);
    }

    #[test]
    fn crc_verified_once_at_admission_then_skipped() {
        let mut cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        cache.set_decode_memo_budget(1 << 20);
        let s = mk_shard(11, 400);
        assert!(cache.admit(11, &s.to_bytes()));
        assert_eq!(cache.snapshot().crc_verifies, 1, "admission verifies once");
        cache.get(11).unwrap().unwrap(); // decode (memo-miss), no re-verify
        cache.get(11).unwrap().unwrap(); // memo hit
        let snap = cache.snapshot();
        assert_eq!(snap.crc_verifies, 1, "no serving re-verifies");
        assert_eq!(snap.crc_verifies_skipped, 2);
        assert_eq!(snap.decodes, 1);
    }

    #[test]
    fn corrupt_bytes_rejected_at_admission_in_all_modes() {
        for mode in [CacheMode::M1Raw, CacheMode::M2Fast, CacheMode::M3Zlib1] {
            let cache = EdgeCache::new(mode, 1 << 20);
            let mut b = mk_shard(12, 200).to_bytes();
            b[40] ^= 0x5a; // payload corruption: only the CRC catches it
            assert!(!cache.admit(12, &b), "{}", mode.name());
            assert!(cache.get(12).unwrap().is_none(), "{}", mode.name());
        }
    }

    #[test]
    fn capacity_rejects() {
        let cache = EdgeCache::new(CacheMode::M1Raw, 100); // tiny
        let s = mk_shard(1, 500);
        assert!(!cache.admit(1, &s.to_bytes()));
        assert_eq!(cache.snapshot().rejected, 1);
        assert_eq!(cache.snapshot().used_bytes, 0); // reservation rolled back
    }

    #[test]
    fn mode0_never_caches() {
        let cache = EdgeCache::new(CacheMode::M0None, u64::MAX);
        let s = mk_shard(2, 100);
        assert!(!cache.admit(2, &s.to_bytes()));
        assert!(cache.get(2).unwrap().is_none());
    }

    #[test]
    fn compressed_fits_more() {
        let raw = EdgeCache::new(CacheMode::M1Raw, 40_000);
        let z = EdgeCache::new(CacheMode::M4Zlib3, 40_000);
        let mut raw_count = 0;
        let mut z_count = 0;
        for id in 0..32 {
            let b = mk_shard(id, 1000).to_bytes();
            raw_count += raw.admit(id, &b) as u32;
            z_count += z.admit(id, &b) as u32;
        }
        assert!(
            z_count > raw_count,
            "zlib cached {z_count} <= raw {raw_count}"
        );
    }

    #[test]
    fn double_admit_is_idempotent() {
        let cache = EdgeCache::new(CacheMode::M2Fast, 1 << 20);
        let b = mk_shard(3, 100).to_bytes();
        assert!(cache.admit(3, &b));
        let used = cache.snapshot().used_bytes;
        assert!(cache.admit(3, &b));
        assert_eq!(cache.snapshot().used_bytes, used);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn auto_picks_reasonably() {
        let c = EdgeCache::auto(1000, 10_000);
        assert_eq!(c.mode(), CacheMode::M1Raw);
        let c = EdgeCache::auto(1_000_000, 10_000);
        assert_eq!(c.mode(), CacheMode::M4Zlib3);
    }

    #[test]
    fn hit_ratio_math() {
        let snap = CacheSnapshot { hits: 3, misses: 1, ..Default::default() };
        assert!((snap.hit_ratio() - 0.75).abs() < 1e-9);
        assert_eq!(CacheSnapshot::default().hit_ratio(), 0.0);
    }

    #[test]
    fn no_memo_budget_decodes_every_hit_and_pins_nothing() {
        let cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        let s = mk_shard(5, 500);
        assert!(cache.admit(5, &s.to_bytes()));
        assert_eq!(cache.get(5).unwrap().unwrap().to_shard(), s);
        assert_eq!(cache.get(5).unwrap().unwrap().to_shard(), s);
        let snap = cache.snapshot();
        assert_eq!(snap.decodes, 2, "no budget: every hit re-decodes");
        assert_eq!(snap.decode_skips, 0);
        assert_eq!(snap.memo_bytes, 0, "no budget: nothing may be pinned");
    }

    #[test]
    fn memo_budget_pins_decoded_shards() {
        let mut cache = EdgeCache::new(CacheMode::M4Zlib3, 1 << 20);
        cache.set_decode_memo_budget(1 << 20);
        let s = mk_shard(6, 500);
        assert!(cache.admit(6, &s.to_bytes()));
        assert_eq!(cache.get(6).unwrap().unwrap().to_shard(), s);
        assert_eq!(cache.get(6).unwrap().unwrap().to_shard(), s);
        let snap = cache.snapshot();
        assert_eq!(snap.decodes, 1, "budgeted memo must decode exactly once");
        assert_eq!(snap.decode_skips, 1);
        assert!(snap.memo_bytes > 0);
    }

    #[test]
    fn exhausted_memo_budget_stops_pinning() {
        let mut cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        cache.set_decode_memo_budget(1); // smaller than any shard
        let s = mk_shard(9, 500);
        assert!(cache.admit(9, &s.to_bytes()));
        cache.get(9).unwrap().unwrap();
        cache.get(9).unwrap().unwrap();
        let snap = cache.snapshot();
        assert_eq!(snap.decodes, 2);
        assert_eq!(snap.memo_bytes, 0, "over-budget pin must roll back");
    }

    #[test]
    fn memo_lru_evicts_least_recently_hit() {
        let s1 = mk_shard(1, 500);
        let s2 = mk_shard(2, 500);
        let s3 = mk_shard(3, 500);
        let one = (s1.to_bytes().len() + 32) as u64;
        // budget fits exactly two pinned shards
        let mut cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        cache.set_decode_memo_budget(2 * one);
        for (id, s) in [(1u32, &s1), (2, &s2), (3, &s3)] {
            assert!(cache.admit(id, &s.to_bytes()));
        }
        // note: admit without a parsed handle pins nothing yet
        assert_eq!(cache.snapshot().memo_bytes, 0);
        cache.get(1).unwrap().unwrap(); // decode + pin 1
        cache.get(2).unwrap().unwrap(); // decode + pin 2 (pool full)
        cache.get(1).unwrap().unwrap(); // touch 1 → LRU order [2, 1]
        assert_eq!(cache.snapshot().decodes, 2);
        assert_eq!(cache.snapshot().decode_skips, 1);
        cache.get(3).unwrap().unwrap(); // decode + pin 3, evicting 2
        assert!(cache.snapshot().memo_bytes <= 2 * one, "pool over budget");
        // 1 and 3 are pinned (skip), 2 was evicted (re-decodes)
        cache.get(1).unwrap().unwrap();
        cache.get(3).unwrap().unwrap();
        assert_eq!(cache.snapshot().decode_skips, 3);
        let decodes_before = cache.snapshot().decodes;
        cache.get(2).unwrap().unwrap();
        assert_eq!(
            cache.snapshot().decodes,
            decodes_before + 1,
            "evicted shard must decode again"
        );
    }

    #[test]
    fn memo_lru_keeps_hot_shards_across_many_rounds() {
        // regression for the permanent-pin policy: with a pool smaller
        // than the shard set, the *recently hit* shards must stay pinned
        // instead of whichever were touched first
        let shards: Vec<Shard> = (0..6u32).map(|id| mk_shard(id, 400)).collect();
        let one = (shards[0].to_bytes().len() + 32) as u64;
        let mut cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        cache.set_decode_memo_budget(3 * one);
        for (id, s) in shards.iter().enumerate() {
            assert!(cache.admit(id as u32, &s.to_bytes()));
        }
        // several rounds over a hot subset {0,1,2} after touching all
        for s in 0..6u32 {
            cache.get(s).unwrap().unwrap();
        }
        let cold_decodes = cache.snapshot().decodes;
        for _ in 0..4 {
            for s in 0..3u32 {
                cache.get(s).unwrap().unwrap();
            }
        }
        let snap = cache.snapshot();
        assert!(snap.memo_bytes <= 3 * one);
        // the hot subset converges onto the pool: at most one round of
        // re-decodes before all three stay pinned
        assert!(
            snap.decodes - cold_decodes <= 3,
            "hot set kept thrashing: {} extra decodes",
            snap.decodes - cold_decodes
        );
        assert!(snap.decode_skips >= 9);
    }

    #[test]
    fn admit_with_seeds_the_memo() {
        let mut cache = EdgeCache::new(CacheMode::M3Zlib1, 1 << 20);
        cache.set_decode_memo_budget(1 << 20);
        let s = mk_shard(7, 300);
        let arc = mk_view(&s);
        assert!(cache.admit_with(7, &s.to_bytes(), &arc));
        let got = cache.get(7).unwrap().unwrap();
        assert!(Arc::ptr_eq(&got, &arc), "memoized hit must be the same Arc");
        assert_eq!(cache.snapshot().decodes, 0);
        // the caller verified (and accounts its own pass via
        // `note_crc_verified`); admission must not re-hash
        assert_eq!(cache.snapshot().crc_verifies, 0);
    }

    #[test]
    fn admit_with_reuses_parsed_for_mode1() {
        let cache = EdgeCache::new(CacheMode::M1Raw, 1 << 20);
        let s = mk_shard(8, 300);
        let arc = mk_view(&s);
        assert!(cache.admit_with(8, &s.to_bytes(), &arc));
        let got = cache.get(8).unwrap().unwrap();
        assert!(Arc::ptr_eq(&got, &arc));
    }
}
