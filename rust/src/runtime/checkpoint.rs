//! Crash-safe checkpoint/recovery for the scan-shared runtime.
//!
//! At every `checkpoint_interval`-th pass boundary the [`CheckpointWriter`]
//! (a [`crate::exec::PassObserver`]) persists the whole batch state — each
//! admitted lane's vertex values, active set, job-local iteration clock and
//! terminal flags, plus the roster of not-yet-admitted arrivals and the
//! results of jobs finished in earlier batches of the drain — into a
//! versioned, CRC-guarded checkpoint directory:
//!
//! ```text
//! <dir>/ckpt_000004/
//!   MANIFEST        text, modeled on runtime/manifest.rs; trailing
//!                   `end crc=<hex>` guards every byte above it
//!   job_000.bin     one GMPJ lane file per job record, its own
//!   job_001.bin     trailing CRC32 guarding the payload
//! ```
//!
//! Atomicity protocol: every file is written into a `.tmp_ckpt_*` staging
//! directory with [`Disk::write_file_durable`] (write + fsync + parent
//! fsync), the staging dir is renamed into place, and the checkpoint root
//! is fsynced — a crash at any point leaves either the previous complete
//! checkpoint or a staging dir the next write sweeps away, never a
//! half-visible one.  [`load_latest`] scans newest-first, rejects
//! truncated or bit-flipped candidates with a precise per-candidate
//! reason, and falls back to the last good checkpoint.
//!
//! Recovery contract: a batch resumed from a checkpoint replays exactly
//! the remainder of the interrupted run — resumed lanes continue their
//! own iteration clocks, so final values are bit-identical to the
//! uninterrupted run (`rust/tests/recovery.rs`).

use std::fmt;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::exec::{LaneSnapshot, LaneType, LaneVec, PassObserver, ResumeState};
use crate::storage::disk::{sync_dir, Disk};

/// Current checkpoint format version (the MANIFEST's first line).
pub const CKPT_VERSION: &str = "graphmp-ckpt v1";

/// Where, how often, and (for fault-injection tests) when to die.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Checkpoint root; one `ckpt_<pass>` subdirectory per checkpoint.
    pub dir: PathBuf,
    /// Persist every `every` pass boundaries (0 = never write on pass
    /// cadence; the kill hook below stays armed either way).
    pub every: u32,
    /// Wall-clock cadence (serving, `--checkpoint-secs`): also persist at
    /// the first pass boundary at least this many seconds after the last
    /// write, independent of `every` — a daemon crawling through long
    /// passes stays recoverable.  `None` = pass cadence only.
    pub every_secs: Option<f64>,
    /// Checkpoints to retain; older ones are pruned after each write.
    pub keep: usize,
    /// Fault injection: abort the batch at this (global) pass boundary,
    /// *after* any checkpoint due there — simulating a crash mid-run.
    pub kill_at_pass: Option<u32>,
}

impl CheckpointConfig {
    pub fn new(dir: impl Into<PathBuf>, every: u32) -> CheckpointConfig {
        CheckpointConfig {
            dir: dir.into(),
            every,
            every_secs: None,
            keep: 2,
            kill_at_pass: None,
        }
    }
}

/// Typed "nothing to resume from" error: `cfg.dir` is missing, empty, or
/// holds only rejected candidates (corrupt checkpoints, swept `.tmp_*`
/// staging dirs).  The CLI maps it to its own exit code so scripts can
/// tell "no checkpoint yet" from a genuine failure.
#[derive(Debug)]
pub struct NoValidCheckpoint {
    pub dir: PathBuf,
    /// Every candidate considered and why it was rejected.
    pub rejected: Vec<(PathBuf, String)>,
}

impl fmt::Display for NoValidCheckpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "no valid checkpoint found in {}", self.dir.display())?;
        if self.rejected.is_empty() {
            write!(f, " (no checkpoint candidates)")
        } else {
            write!(f, " ({} candidates rejected:", self.rejected.len())?;
            for (p, why) in &self.rejected {
                write!(f, "\n  {}: {why}", p.display())?;
            }
            write!(f, ")")
        }
    }
}

impl std::error::Error for NoValidCheckpoint {}

/// One job's persisted state: the [`crate::runtime::jobs::JobSet`] id it
/// maps back to, its batch-relative arrival pass, and the lane itself.
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub id: u32,
    pub arrive: u32,
    pub state: ResumeState,
}

/// Everything one checkpoint holds, decoded and CRC-verified.
#[derive(Clone, Debug, Default)]
pub struct CheckpointState {
    pub num_vertices: u32,
    pub num_edges: u64,
    /// Index of the interrupted batch within its drain.
    pub batch_index: u32,
    /// Global pass at which the interrupted batch began (0 for the first
    /// batch of a drain).  `pass - start` is the batch-local boundary,
    /// the clock [`JobRecord::arrive`] offsets are relative to.
    pub start: u32,
    /// The (global) pass boundary this checkpoint captured.
    pub pass: u32,
    /// Jobs that finished in earlier batches of the drain.
    pub finished: Vec<JobRecord>,
    /// Admitted lanes of the interrupted batch, in admission order.
    pub lanes: Vec<JobRecord>,
    /// Batch members not yet admitted: `(job id, arrival pass)`.
    pub pending: Vec<(u32, u32)>,
}

/// Identity of the batch a [`CheckpointWriter`] persists: the graph
/// fingerprint, the batch's position in its drain, the full member roster
/// `(job id, arrival pass)` in admission order, and carried-forward
/// results of jobs finished in earlier batches.
#[derive(Clone, Debug, Default)]
pub struct BatchMeta {
    pub num_vertices: u32,
    pub num_edges: u64,
    pub batch_index: u32,
    /// Global pass at which this batch began (its local pass 0).
    pub start: u32,
    pub roster: Vec<(u32, u32)>,
    pub finished: Vec<JobRecord>,
}

/// The pass-boundary observer that writes checkpoints (and hosts the
/// kill-at-iteration fault hook).  Plug into
/// [`crate::exec::BatchOptions::observer`] or use the
/// [`crate::runtime::jobs::JobSet`] front door.
pub struct CheckpointWriter {
    cfg: CheckpointConfig,
    disk: Disk,
    meta: BatchMeta,
    /// Pass offset of a resumed batch: the observer sees batch-local
    /// passes, checkpoints are numbered globally across interruptions.
    base_pass: u32,
    /// Wall clock of the last persisted checkpoint (or writer creation),
    /// driving [`CheckpointConfig::every_secs`].
    last_write: Instant,
    /// One-shot flush request: the next boundary writes regardless of
    /// cadence (serving: shutdown checkpoint-and-stop).
    force: bool,
    /// Checkpoints persisted by this writer.
    pub checkpoints_written: u32,
    /// Bytes those checkpoints cost on disk.
    pub checkpoint_bytes: u64,
    /// Wall seconds spent writing them (boundary work, on the critical
    /// path).
    pub checkpoint_seconds: f64,
    /// Checkpoints that failed to persist and were skipped (the batch
    /// kept running on the previous good one).
    pub checkpoints_failed: u32,
}

impl CheckpointWriter {
    pub fn new(cfg: CheckpointConfig, disk: Disk, meta: BatchMeta) -> CheckpointWriter {
        CheckpointWriter {
            cfg,
            disk,
            meta,
            base_pass: 0,
            last_write: Instant::now(),
            force: false,
            checkpoints_written: 0,
            checkpoint_bytes: 0,
            checkpoint_seconds: 0.0,
            checkpoints_failed: 0,
        }
    }

    /// Continue the global pass numbering of an interrupted run: the
    /// resumed batch's local pass 0 is global pass `pass`.
    pub fn with_base_pass(mut self, pass: u32) -> CheckpointWriter {
        self.base_pass = pass;
        self
    }

    /// Ask for a checkpoint at the next pass boundary regardless of
    /// cadence (one-shot) — serving uses it to freeze the in-flight batch
    /// on shutdown.
    pub fn request_flush(&mut self) {
        self.force = true;
    }

    /// Mutable batch identity, for callers whose roster grows while the
    /// batch runs (serving admits jobs from a socket mid-batch).
    pub fn meta_mut(&mut self) -> &mut BatchMeta {
        &mut self.meta
    }

    /// Persist one checkpoint at (global) pass `global`: stage every file
    /// durably in a temp dir, rename it into place, fsync the root, prune
    /// old checkpoints.
    fn write(&mut self, global: u32, lanes: &[LaneSnapshot<'_>]) -> Result<()> {
        let t0 = Instant::now();
        let written_before = self.disk.snapshot().bytes_written;
        let name = format!("ckpt_{global:06}");
        let tmp = self.cfg.dir.join(format!(".tmp_{name}"));
        let final_dir = self.cfg.dir.join(&name);
        let _ = std::fs::remove_dir_all(&tmp);

        let mut man = String::new();
        man.push_str(CKPT_VERSION);
        man.push('\n');
        man.push_str(&format!(
            "graph vertices={} edges={}\n",
            self.meta.num_vertices, self.meta.num_edges
        ));
        man.push_str(&format!(
            "batch index={} start={} pass={} members={}\n",
            self.meta.batch_index,
            self.meta.start,
            global,
            self.meta.roster.len()
        ));
        let mut slot = 0usize;
        for rec in &self.meta.finished {
            let file = format!("job_{slot:03}.bin");
            let bytes = encode_lane(&rec.state);
            self.disk.write_file_durable(&tmp.join(&file), &bytes)?;
            man.push_str(&format!(
                "job kind=finished id={} arrive={} bytes={} file={file}\n",
                rec.id,
                rec.arrive,
                bytes.len()
            ));
            slot += 1;
        }
        anyhow::ensure!(
            lanes.len() <= self.meta.roster.len(),
            "{} lanes at the boundary, roster holds {} members",
            lanes.len(),
            self.meta.roster.len()
        );
        for (lane, &(id, arrive)) in lanes.iter().zip(&self.meta.roster) {
            let file = format!("job_{slot:03}.bin");
            let bytes = encode_lane(&snapshot_state(lane));
            self.disk.write_file_durable(&tmp.join(&file), &bytes)?;
            man.push_str(&format!(
                "job kind=lane id={id} arrive={arrive} bytes={} file={file}\n",
                bytes.len()
            ));
            slot += 1;
        }
        for &(id, arrive) in self.meta.roster.iter().skip(lanes.len()) {
            man.push_str(&format!("job kind=pending id={id} arrive={arrive}\n"));
        }
        man.push_str(&format!("end crc={:08x}\n", crc32fast::hash(man.as_bytes())));
        self.disk.write_file_durable(&tmp.join("MANIFEST"), man.as_bytes())?;

        if final_dir.exists() {
            std::fs::remove_dir_all(&final_dir)
                .with_context(|| format!("replace stale {}", final_dir.display()))?;
        }
        std::fs::rename(&tmp, &final_dir).with_context(|| {
            format!("publish {} -> {}", tmp.display(), final_dir.display())
        })?;
        sync_dir(&self.cfg.dir)?;
        self.prune()?;

        self.checkpoints_written += 1;
        self.checkpoint_bytes += self.disk.snapshot().bytes_written - written_before;
        self.checkpoint_seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Keep the newest `keep` checkpoints, drop the rest, and sweep any
    /// staging dirs a crashed write left behind.
    fn prune(&self) -> Result<()> {
        let mut kept: Vec<(u32, PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(&self.cfg.dir)
            .with_context(|| format!("checkpoint dir {}", self.cfg.dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(pass) = name.strip_prefix("ckpt_").and_then(|s| s.parse::<u32>().ok())
            {
                kept.push((pass, entry.path()));
            } else if name.starts_with(".tmp_") {
                let _ = std::fs::remove_dir_all(entry.path());
            }
        }
        kept.sort_by(|a, b| b.0.cmp(&a.0));
        for (_, path) in kept.into_iter().skip(self.cfg.keep.max(1)) {
            std::fs::remove_dir_all(&path)
                .with_context(|| format!("prune {}", path.display()))?;
        }
        Ok(())
    }
}

impl PassObserver for CheckpointWriter {
    fn at_boundary(&mut self, pass: u32, lanes: &[LaneSnapshot<'_>]) -> Result<()> {
        let global = self.base_pass + pass;
        // `global > base_pass` skips re-writing the checkpoint a resumed
        // batch just restored from (its local pass 0).
        let on_pass_cadence =
            self.cfg.every > 0 && global % self.cfg.every == 0;
        let on_wall_cadence = self
            .cfg
            .every_secs
            .is_some_and(|s| self.last_write.elapsed().as_secs_f64() >= s);
        if global > self.base_pass && (on_pass_cadence || on_wall_cadence || self.force) {
            // a failed write is skipped, not fatal: the run keeps going on
            // the previous good checkpoint (it only loses recovery
            // granularity), which is what a resident daemon needs
            match self.write(global, lanes) {
                Ok(()) => self.force = false,
                Err(e) => {
                    self.checkpoints_failed += 1;
                    eprintln!(
                        "warning: checkpoint at pass {global} failed (skipped, \
                         {} so far): {e:#}",
                        self.checkpoints_failed
                    );
                }
            }
            // either way the cadence clock restarts: a hard-faulted dir
            // skips *this* checkpoint instead of re-failing every boundary
            self.last_write = Instant::now();
        }
        if self.cfg.kill_at_pass == Some(global) {
            anyhow::bail!("injected crash at pass boundary {global}");
        }
        Ok(())
    }
}

/// What a newest-first scan of the checkpoint root found.
#[derive(Debug, Default)]
pub struct LoadOutcome {
    /// The newest checkpoint that decoded and CRC-verified cleanly.
    pub loaded: Option<(PathBuf, CheckpointState)>,
    /// Newer candidates rejected on the way, each with the precise reason
    /// (truncated manifest, CRC mismatch, bad version, …).
    pub rejected: Vec<(PathBuf, String)>,
}

/// Scan `dir` for checkpoints, newest first, and load the first one that
/// verifies; corrupt candidates land in [`LoadOutcome::rejected`] instead
/// of failing the scan.  Reads go through `disk`, so they are metered and
/// retried like every other read.
pub fn load_latest(dir: &Path, disk: &Disk) -> Result<LoadOutcome> {
    let mut candidates: Vec<(u32, PathBuf)> = Vec::new();
    let mut rejected = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            // missing root is "nothing to resume from", not an I/O failure
            return Err(NoValidCheckpoint { dir: dir.to_path_buf(), rejected }.into());
        }
        Err(e) => {
            return Err(e).with_context(|| format!("checkpoint dir {}", dir.display()))
        }
    };
    for entry in entries {
        let entry = entry.with_context(|| format!("checkpoint dir {}", dir.display()))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(pass) = name.strip_prefix("ckpt_").and_then(|s| s.parse::<u32>().ok()) {
            candidates.push((pass, entry.path()));
        } else if name.starts_with(".tmp_") {
            rejected.push((
                entry.path(),
                "unpublished staging dir (crashed before rename)".to_string(),
            ));
        }
    }
    candidates.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in candidates {
        match load_checkpoint(&path, disk) {
            Ok(state) => return Ok(LoadOutcome { loaded: Some((path, state)), rejected }),
            Err(e) => rejected.push((path, format!("{e:#}"))),
        }
    }
    Ok(LoadOutcome { loaded: None, rejected })
}

/// Load and fully verify one `ckpt_*` directory: manifest trailer CRC,
/// format version, per-record fields (line-numbered errors), and each
/// lane file's declared length + trailing CRC.
pub fn load_checkpoint(dir: &Path, disk: &Disk) -> Result<CheckpointState> {
    let mpath = dir.join("MANIFEST");
    let raw = disk.read_file(&mpath)?;
    let text = String::from_utf8(raw)
        .map_err(|_| anyhow::anyhow!("{}: not UTF-8", mpath.display()))?;

    // integrity trailer: the last line `end crc=<hex>` guards every byte
    // before it — a truncated or bit-flipped manifest fails here
    let idx = text
        .rfind("\nend crc=")
        .with_context(|| format!("{}: missing `end crc=` integrity trailer", mpath.display()))?;
    let body = &text[..idx + 1];
    let tail = text[idx + 1..].trim_end();
    anyhow::ensure!(
        !tail.contains('\n'),
        "{}: trailing data after the integrity trailer",
        mpath.display()
    );
    let hex = tail.strip_prefix("end crc=").expect("rfind matched this prefix");
    let stored = u32::from_str_radix(hex, 16)
        .with_context(|| format!("{}: bad trailer crc '{hex}'", mpath.display()))?;
    let computed = crc32fast::hash(body.as_bytes());
    anyhow::ensure!(
        stored == computed,
        "{}: CRC mismatch (stored {stored:08x}, computed {computed:08x}) — truncated or corrupt",
        mpath.display()
    );

    let mut num_vertices: Option<u32> = None;
    let mut num_edges = 0u64;
    let mut batch_index = 0u32;
    let mut start = 0u32;
    let mut pass: Option<u32> = None;
    let mut members = 0usize;
    let mut finished: Vec<JobRecord> = Vec::new();
    let mut lanes: Vec<JobRecord> = Vec::new();
    let mut pending: Vec<(u32, u32)> = Vec::new();

    for (ln0, line) in body.lines().enumerate() {
        let ln = ln0 + 1;
        let line = line.trim();
        if ln == 1 {
            anyhow::ensure!(
                line == CKPT_VERSION,
                "{}: unsupported checkpoint version '{line}' (want '{CKPT_VERSION}')",
                mpath.display()
            );
            continue;
        }
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let tag = it.next().expect("non-empty line");
        let mut kv: Vec<(&str, &str)> = Vec::new();
        for field in it {
            let (k, v) = field.split_once('=').with_context(|| {
                format!("{}: line {ln}: bad field '{field}'", mpath.display())
            })?;
            kv.push((k, v));
        }
        let get = |key: &str| -> Result<&str> {
            kv.iter()
                .find(|(k, _)| *k == key)
                .map(|&(_, v)| v)
                .with_context(|| format!("{}: line {ln}: missing {key}=", mpath.display()))
        };
        match tag {
            "graph" => {
                num_vertices = Some(parse_num(get("vertices")?, "vertices", ln, &mpath)?);
                num_edges = parse_num(get("edges")?, "edges", ln, &mpath)?;
            }
            "batch" => {
                batch_index = parse_num(get("index")?, "index", ln, &mpath)?;
                start = parse_num(get("start")?, "start", ln, &mpath)?;
                pass = Some(parse_num(get("pass")?, "pass", ln, &mpath)?);
                members = parse_num(get("members")?, "members", ln, &mpath)?;
            }
            "job" => {
                let id: u32 = parse_num(get("id")?, "id", ln, &mpath)?;
                let arrive: u32 = parse_num(get("arrive")?, "arrive", ln, &mpath)?;
                match get("kind")? {
                    "pending" => pending.push((id, arrive)),
                    kind @ ("finished" | "lane") => {
                        let file = get("file")?;
                        let declared: usize = parse_num(get("bytes")?, "bytes", ln, &mpath)?;
                        let fpath = dir.join(file);
                        let data = disk.read_file(&fpath)?;
                        anyhow::ensure!(
                            data.len() == declared,
                            "{}: {} bytes on disk, manifest declares {declared}",
                            fpath.display(),
                            data.len()
                        );
                        let state = decode_lane(&data)
                            .with_context(|| fpath.display().to_string())?;
                        let rec = JobRecord { id, arrive, state };
                        if kind == "finished" {
                            finished.push(rec);
                        } else {
                            lanes.push(rec);
                        }
                    }
                    other => anyhow::bail!(
                        "{}: line {ln}: unknown job kind '{other}'",
                        mpath.display()
                    ),
                }
            }
            other => {
                anyhow::bail!("{}: line {ln}: unknown record '{other}'", mpath.display())
            }
        }
    }

    let num_vertices = num_vertices
        .with_context(|| format!("{}: missing graph record", mpath.display()))?;
    let pass = pass.with_context(|| format!("{}: missing batch record", mpath.display()))?;
    anyhow::ensure!(
        lanes.len() + pending.len() == members,
        "{}: batch declares {members} members, found {} lanes + {} pending",
        mpath.display(),
        lanes.len(),
        pending.len()
    );
    for rec in &lanes {
        anyhow::ensure!(
            rec.state.values.len() == num_vertices as usize,
            "{}: lane of job {} holds {} values, graph has {num_vertices}",
            mpath.display(),
            rec.id,
            rec.state.values.len()
        );
    }
    let pass = pass.max(start);
    Ok(CheckpointState {
        num_vertices,
        num_edges,
        batch_index,
        start,
        pass,
        finished,
        lanes,
        pending,
    })
}

fn parse_num<T: std::str::FromStr>(v: &str, key: &str, ln: usize, path: &Path) -> Result<T>
where
    T::Err: std::error::Error + Send + Sync + 'static,
{
    v.parse()
        .with_context(|| format!("{}: line {ln}: bad {key}='{v}'", path.display()))
}

/// Own a boundary snapshot so it can be encoded (and later restored).
pub fn snapshot_state(lane: &LaneSnapshot<'_>) -> ResumeState {
    ResumeState {
        values: lane.values.to_lane_vec(),
        active: lane.active.to_vec(),
        iters_done: lane.iters_done,
        done: lane.done,
        converged: lane.converged,
        failed: lane.failed.map(str::to_string),
    }
}

const LANE_MAGIC: &[u8; 4] = b"GMPJ";
const LANE_VERSION: u32 = 2; // v2: lane_tag field, lane-typed value width
const LANE_HEADER: usize = 32; // magic + version + iters + flags + 3 lengths + lane tag

/// Serialize one lane: fixed header (including the lane-type tag), values
/// as raw LE bits at the lane's native width (exact round-trip — the
/// bit-identity gate depends on it), active ids, the failure message, and
/// a trailing CRC32 over everything before it.
pub fn encode_lane(rs: &ResumeState) -> Vec<u8> {
    let failed = rs.failed.as_deref().unwrap_or("");
    let lt = rs.values.lane_type();
    let mut out = Vec::with_capacity(
        LANE_HEADER + rs.values.len() * lt.bytes() + rs.active.len() * 4 + failed.len() + 4,
    );
    out.extend_from_slice(LANE_MAGIC);
    out.extend_from_slice(&LANE_VERSION.to_le_bytes());
    out.extend_from_slice(&rs.iters_done.to_le_bytes());
    let flags = u32::from(rs.done)
        | (u32::from(rs.converged) << 1)
        | (u32::from(rs.failed.is_some()) << 2);
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&(rs.values.len() as u32).to_le_bytes());
    out.extend_from_slice(&(rs.active.len() as u32).to_le_bytes());
    out.extend_from_slice(&(failed.len() as u32).to_le_bytes());
    out.extend_from_slice(&lt.tag().to_le_bytes());
    match &rs.values {
        LaneVec::F32(vs) => {
            for v in vs {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        LaneVec::U32(vs) => {
            for v in vs {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        LaneVec::U64(vs) => {
            for v in vs {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    for a in &rs.active {
        out.extend_from_slice(&a.to_le_bytes());
    }
    out.extend_from_slice(failed.as_bytes());
    out.extend_from_slice(&crc32fast::hash(&out).to_le_bytes());
    out
}

/// Decode + verify one lane file (magic, version, declared lengths,
/// trailing CRC).
pub fn decode_lane(bytes: &[u8]) -> Result<ResumeState> {
    anyhow::ensure!(
        bytes.len() >= LANE_HEADER + 4,
        "lane file truncated: {} bytes",
        bytes.len()
    );
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(tail.try_into().expect("4-byte tail"));
    let computed = crc32fast::hash(body);
    anyhow::ensure!(
        stored == computed,
        "lane file CRC mismatch (stored {stored:08x}, computed {computed:08x}) — corrupt"
    );
    anyhow::ensure!(body[..4] == *LANE_MAGIC, "bad lane file magic");
    let rd = |off: usize| u32::from_le_bytes(body[off..off + 4].try_into().expect("in bounds"));
    let version = rd(4);
    anyhow::ensure!(version == LANE_VERSION, "unsupported lane file version {version}");
    let iters_done = rd(8);
    let flags = rd(12);
    let nv = rd(16) as usize;
    let na = rd(20) as usize;
    let nf = rd(24) as usize;
    let tag = rd(28);
    let lt = LaneType::from_tag(tag)
        .with_context(|| format!("unknown lane type tag {tag} in lane file"))?;
    let need = LANE_HEADER + nv * lt.bytes() + na * 4 + nf;
    anyhow::ensure!(
        body.len() == need,
        "lane file holds {} payload bytes, header declares {need}",
        body.len()
    );
    let mut off = LANE_HEADER;
    let values = match lt {
        LaneType::F32 => {
            let mut vs = Vec::with_capacity(nv);
            for _ in 0..nv {
                vs.push(f32::from_bits(rd(off)));
                off += 4;
            }
            LaneVec::from(vs)
        }
        LaneType::U32 => {
            let mut vs = Vec::with_capacity(nv);
            for _ in 0..nv {
                vs.push(rd(off));
                off += 4;
            }
            LaneVec::from(vs)
        }
        LaneType::U64 => {
            let mut vs = Vec::with_capacity(nv);
            for _ in 0..nv {
                vs.push(u64::from_le_bytes(
                    body[off..off + 8].try_into().expect("in bounds"),
                ));
                off += 8;
            }
            LaneVec::from(vs)
        }
    };
    let mut active = Vec::with_capacity(na);
    for _ in 0..na {
        active.push(rd(off));
        off += 4;
    }
    let msg = std::str::from_utf8(&body[off..off + nf])
        .context("lane failure message is not UTF-8")?;
    Ok(ResumeState {
        values,
        active,
        iters_done,
        done: flags & 1 != 0,
        converged: flags & 2 != 0,
        failed: (flags & 4 != 0).then(|| msg.to_string()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("graphmp_ckpt_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn lane(values: Vec<f32>, active: Vec<u32>, iters: u32) -> ResumeState {
        ResumeState { values: values.into(), active, iters_done: iters, ..Default::default() }
    }

    fn snaps(states: &[ResumeState]) -> Vec<LaneSnapshot<'_>> {
        states
            .iter()
            .map(|s| LaneSnapshot {
                values: s.values.as_slice(),
                active: &s.active,
                iters_done: s.iters_done,
                done: s.done,
                converged: s.converged,
                failed: s.failed.as_deref(),
            })
            .collect()
    }

    fn writer(dir: &Path, every: u32, n: u32, roster: Vec<(u32, u32)>) -> CheckpointWriter {
        CheckpointWriter::new(
            CheckpointConfig::new(dir, every),
            Disk::unthrottled(),
            BatchMeta {
                num_vertices: n,
                num_edges: 9,
                batch_index: 0,
                roster,
                ..Default::default()
            },
        )
    }

    #[test]
    fn lane_round_trips_bit_exact() {
        let mut rs = lane(vec![0.5, f32::INFINITY, -0.0, 1.0e-39], vec![0, 3], 7);
        rs.done = true;
        rs.converged = true;
        rs.failed = Some("load unit 2: boom".to_string());
        let enc = encode_lane(&rs);
        let dec = decode_lane(&enc).unwrap();
        assert_eq!(
            dec.values.f32s().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            rs.values.f32s().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(dec.active, rs.active);
        assert_eq!((dec.iters_done, dec.done, dec.converged), (7, true, true));
        assert_eq!(dec.failed.as_deref(), Some("load unit 2: boom"));
    }

    #[test]
    fn integer_lanes_round_trip_bit_exact() {
        let u32_lane = ResumeState {
            values: vec![0u32, u32::MAX, 7, 42].into(),
            active: vec![1, 3],
            iters_done: 3,
            ..Default::default()
        };
        let dec = decode_lane(&encode_lane(&u32_lane)).unwrap();
        assert_eq!(dec.values.u32s(), u32_lane.values.u32s());
        assert_eq!(dec.values.lane_type(), crate::exec::LaneType::U32);
        assert_eq!(dec.active, u32_lane.active);

        let u64_lane = ResumeState {
            values: vec![u64::MAX, 0, 1 << 40].into(),
            active: vec![],
            iters_done: 1,
            ..Default::default()
        };
        let dec = decode_lane(&encode_lane(&u64_lane)).unwrap();
        assert_eq!(dec.values.u64s(), u64_lane.values.u64s());
        assert_eq!(dec.values.lane_type(), crate::exec::LaneType::U64);
    }

    #[test]
    fn unknown_lane_tag_rejected() {
        let mut enc = encode_lane(&lane(vec![1.0], vec![], 0));
        // corrupt the lane tag (offset 28) and re-seal the CRC so the tag
        // check itself is what rejects it
        enc[28] = 9;
        let n = enc.len();
        let crc = crc32fast::hash(&enc[..n - 4]);
        enc[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_lane(&enc).unwrap_err().to_string();
        assert!(err.contains("unknown lane type tag 9"), "{err}");
    }

    #[test]
    fn lane_bitflip_detected() {
        let mut enc = encode_lane(&lane(vec![1.0, 2.0], vec![1], 1));
        enc[LANE_HEADER + 2] ^= 0x40;
        let err = decode_lane(&enc).unwrap_err().to_string();
        assert!(err.contains("CRC mismatch"), "{err}");
        let whole = encode_lane(&lane(vec![1.0], vec![], 0));
        let err = decode_lane(&whole[..10]).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn write_load_round_trip_with_pending_and_finished() {
        let dir = tdir("round_trip");
        let states =
            vec![lane(vec![1.0, 2.0, 3.0], vec![0, 2], 4), lane(vec![4.0, 5.0, 6.0], vec![1], 4)];
        let mut w = writer(&dir, 2, 3, vec![(0, 0), (2, 1), (5, 6)]);
        w.meta.finished = vec![JobRecord {
            id: 9,
            arrive: 0,
            state: ResumeState {
                values: vec![7.0f32, 8.0, 9.0].into(),
                done: true,
                ..Default::default()
            },
        }];
        w.at_boundary(4, &snaps(&states)).unwrap();
        assert_eq!(w.checkpoints_written, 1);
        assert!(w.checkpoint_bytes > 0);

        let out = load_latest(&dir, &Disk::unthrottled()).unwrap();
        assert!(out.rejected.is_empty());
        let (path, st) = out.loaded.unwrap();
        assert!(path.ends_with("ckpt_000004"));
        assert_eq!((st.num_vertices, st.num_edges, st.pass), (3, 9, 4));
        assert_eq!((st.batch_index, st.start), (0, 0));
        assert_eq!(st.lanes.len(), 2);
        assert_eq!((st.lanes[0].id, st.lanes[1].id), (0, 2));
        assert_eq!(st.lanes[1].state.values, vec![4.0, 5.0, 6.0]);
        assert_eq!(st.pending, vec![(5, 6)]);
        assert_eq!(st.finished.len(), 1);
        assert_eq!(st.finished[0].state.values, vec![7.0, 8.0, 9.0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cadence_and_kill_hook() {
        let dir = tdir("cadence");
        let states = vec![lane(vec![0.0], vec![0], 0)];
        let mut w = writer(&dir, 3, 1, vec![(0, 0)]);
        w.cfg.kill_at_pass = Some(6);
        w.at_boundary(0, &snaps(&states)).unwrap(); // pass 0: never written
        w.at_boundary(3, &snaps(&states)).unwrap();
        w.at_boundary(4, &snaps(&states)).unwrap(); // off-cadence
        let err = w.at_boundary(6, &snaps(&states)).unwrap_err().to_string();
        assert!(err.contains("injected crash at pass boundary 6"), "{err}");
        assert_eq!(w.checkpoints_written, 2, "pass 6 checkpointed before the kill");
        assert!(dir.join("ckpt_000006").join("MANIFEST").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resumed_writer_skips_its_base_pass() {
        let dir = tdir("base_pass");
        let states = vec![lane(vec![0.0], vec![0], 4)];
        let mut w = writer(&dir, 2, 1, vec![(0, 0)]).with_base_pass(4);
        w.at_boundary(0, &snaps(&states)).unwrap(); // global 4 == base: skip
        assert_eq!(w.checkpoints_written, 0);
        w.at_boundary(2, &snaps(&states)).unwrap(); // global 6
        assert_eq!(w.checkpoints_written, 1);
        assert!(dir.join("ckpt_000006").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn retention_keeps_newest_two() {
        let dir = tdir("retention");
        let states = vec![lane(vec![0.0], vec![0], 0)];
        let mut w = writer(&dir, 1, 1, vec![(0, 0)]);
        for pass in 1..=3 {
            w.at_boundary(pass, &snaps(&states)).unwrap();
        }
        assert!(!dir.join("ckpt_000001").exists(), "oldest pruned");
        assert!(dir.join("ckpt_000002").exists());
        assert!(dir.join("ckpt_000003").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous_good() {
        let dir = tdir("fallback");
        let states = vec![lane(vec![1.0, 2.0], vec![0], 0)];
        let mut w = writer(&dir, 1, 2, vec![(0, 0)]);
        w.at_boundary(1, &snaps(&states)).unwrap();
        w.at_boundary(2, &snaps(&states)).unwrap();
        // bit-flip a value byte inside the newest checkpoint's lane file
        let victim = dir.join("ckpt_000002").join("job_000.bin");
        let mut data = std::fs::read(&victim).unwrap();
        let n = data.len();
        data[LANE_HEADER + 1] ^= 0x01;
        std::fs::write(&victim, &data).unwrap();
        assert_eq!(std::fs::read(&victim).unwrap().len(), n);

        let out = load_latest(&dir, &Disk::unthrottled()).unwrap();
        let (path, st) = out.loaded.unwrap();
        assert!(path.ends_with("ckpt_000001"), "fell back to the previous good one");
        assert_eq!(st.pass, 1);
        assert_eq!(out.rejected.len(), 1);
        assert!(out.rejected[0].1.contains("CRC mismatch"), "{}", out.rejected[0].1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_manifest_rejected_with_reason() {
        let dir = tdir("truncated");
        let states = vec![lane(vec![1.0], vec![0], 0)];
        let mut w = writer(&dir, 1, 1, vec![(0, 0)]);
        w.at_boundary(1, &snaps(&states)).unwrap();
        let mpath = dir.join("ckpt_000001").join("MANIFEST");
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, &text[..text.len() / 2]).unwrap();
        let out = load_latest(&dir, &Disk::unthrottled()).unwrap();
        assert!(out.loaded.is_none());
        assert_eq!(out.rejected.len(), 1);
        let why = &out.rejected[0].1;
        assert!(
            why.contains("integrity trailer") || why.contains("CRC mismatch"),
            "{why}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wrong_version_rejected() {
        let dir = tdir("version");
        let states = vec![lane(vec![1.0], vec![0], 0)];
        let mut w = writer(&dir, 1, 1, vec![(0, 0)]);
        w.at_boundary(1, &snaps(&states)).unwrap();
        let mpath = dir.join("ckpt_000001").join("MANIFEST");
        let text = std::fs::read_to_string(&mpath).unwrap();
        // rewrite with a bumped version and a *valid* trailer, so the
        // version check itself is what rejects it
        let body = text[..text.rfind("\nend crc=").unwrap() + 1]
            .replacen("graphmp-ckpt v1", "graphmp-ckpt v9", 1);
        let tampered = format!("{body}end crc={:08x}\n", crc32fast::hash(body.as_bytes()));
        std::fs::write(&mpath, tampered).unwrap();
        let err = load_checkpoint(&dir.join("ckpt_000001"), &Disk::unthrottled())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unsupported checkpoint version"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_dir_loads_nothing() {
        let dir = tdir("empty");
        let out = load_latest(&dir, &Disk::unthrottled()).unwrap();
        assert!(out.loaded.is_none() && out.rejected.is_empty());
        assert!(load_latest(&dir.join("missing"), &Disk::unthrottled()).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
