//! Wire protocol of the `graphmp serve` daemon: newline-delimited JSON
//! over a local Unix socket.
//!
//! Every request is one JSON object on one line with an `"op"` field
//! (`submit` / `status` / `result` / `cancel` / `drain` / `metrics` /
//! `ping`); every response is one JSON object on one line with an
//! `"ok"` field.  The daemon side lives in [`super::serve`]; this module
//! holds the protocol types ([`Request`], [`SubmitSpec`], [`Priority`])
//! and a small self-contained JSON value ([`Json`]) — the vendored crate
//! set has no serde, so both directions are hand-rolled here and gated
//! by round-trip tests below.
//!
//! ```text
//! -> {"op":"submit","app":"ppr","source":3,"iters":10,"priority":"high"}
//! <- {"ok":true,"id":0}
//! -> {"op":"status","id":0}
//! <- {"ok":true,"id":0,"status":"running"}
//! -> {"op":"result","id":0}
//! <- {"ok":true,"id":0,"status":"converged","iters":7,"values_crc":"9f3a01c2"}
//! ```

use anyhow::{Context, Result};

use crate::apps::VertexProgram;
use crate::exec::LaneVec;

/// A JSON value: the minimal tree both sides of the protocol share.
/// Objects keep insertion order (they are rendered as written and probed
/// by key on read; duplicate keys resolve to the first).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON value (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing data after JSON value at byte {}", p.i);
        Ok(v)
    }

    /// Render compactly (no whitespace) — one value per protocol line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // integral values render without the ".0" f64 Display adds
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Object field lookup (first occurrence); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64()
            .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= 9.007199254740992e15)
            .map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\r' | b'\n') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().context("unexpected end of JSON")
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.obj(),
            b'[' => self.arr(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => {
                self.lit("true")?;
                Ok(Json::Bool(true))
            }
            b'f' => {
                self.lit("false")?;
                Ok(Json::Bool(false))
            }
            b'n' => {
                self.lit("null")?;
                Ok(Json::Null)
            }
            _ => self.num(),
        }
    }

    fn lit(&mut self, s: &str) -> Result<()> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad JSON literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(())
    }

    fn num(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii digits");
        let n: f64 = s
            .parse()
            .with_context(|| format!("bad JSON number '{s}' at byte {start}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        anyhow::ensure!(self.peek()? == b'"', "expected string at byte {}", self.i);
        self.i += 1;
        let mut out = String::new();
        loop {
            match self.peek()? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let esc = self.peek()?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            anyhow::ensure!(
                                self.i + 4 <= self.b.len(),
                                "truncated \\u escape"
                            );
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .ok()
                                .context("bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).context("bad \\u escape")?;
                            self.i += 4;
                            // surrogate halves degrade to the replacement
                            // character — protocol strings are plain labels
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => anyhow::bail!("bad escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // copy the next UTF-8 scalar whole (input came from a
                    // &str, so boundaries line up)
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .context("invalid UTF-8 inside JSON string")?;
                    let ch = rest.chars().next().context("unexpected end of JSON")?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn arr(&mut self) -> Result<Json> {
        self.i += 1; // '['
        self.ws();
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => anyhow::bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn obj(&mut self) -> Result<Json> {
        self.i += 1; // '{'
        self.ws();
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            anyhow::ensure!(self.peek()? == b':', "expected ':' at byte {}", self.i);
            self.i += 1;
            self.ws();
            let val = self.value()?;
            fields.push((key, val));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                c => anyhow::bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }
}

/// Admission priority class of a submitted job.  The daemon pops
/// founders high-before-normal-before-low; within a class, FIFO.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    /// Index into per-class arrays ([`crate::metrics::ServeMetrics::per_class`]).
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    pub fn parse(s: &str) -> Result<Priority> {
        Ok(match s {
            "high" => Priority::High,
            "normal" => Priority::Normal,
            "low" => Priority::Low,
            other => anyhow::bail!("unknown priority '{other}' (high|normal|low)"),
        })
    }

    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];
}

/// One job submission: what to run plus the admission-control knobs.
/// This is plain data (no trait objects), so it crosses threads and
/// persists to the serve sidecar as-is; the daemon builds the actual
/// [`VertexProgram`] with [`build_app`](Self::build_app) at admission.
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitSpec {
    /// App name: `pagerank|ppr|sssp|cc|bfs|widest|wcc|bfs_levels|kcore`.
    pub app: String,
    /// Seed/source vertex of seeded apps (ignored by pagerank/cc/wcc/kcore).
    pub source: u32,
    pub damping: f32,
    /// Core order of `kcore` (ignored by every other app).
    pub k: u32,
    pub max_iters: u32,
    pub priority: Priority,
    /// Deadline in pass boundaries since admission: once this many passes
    /// ran, the job is evicted and reported
    /// [`crate::runtime::JobStatus::Expired`].
    pub deadline_passes: Option<u32>,
    /// Wall-clock deadline since admission, enforced at pass boundaries.
    pub timeout_ms: Option<u64>,
    pub label: Option<String>,
}

impl Default for SubmitSpec {
    fn default() -> Self {
        SubmitSpec {
            app: "pagerank".to_string(),
            source: 0,
            damping: 0.85,
            k: 2,
            max_iters: 10,
            priority: Priority::Normal,
            deadline_passes: None,
            timeout_ms: None,
            label: None,
        }
    }
}

impl SubmitSpec {
    /// Instantiate the vertex program this spec names (same mapping as
    /// `graphmp run --app`).
    pub fn build_app(&self) -> Result<Box<dyn VertexProgram>> {
        use crate::apps::{Bfs, BfsLevels, Cc, KCore, PageRank, Ppr, Sssp, Wcc, Widest};
        Ok(match self.app.as_str() {
            "pagerank" => Box::new(PageRank { damping: self.damping }),
            "ppr" => Box::new(Ppr { damping: self.damping, seed: self.source }),
            "sssp" => Box::new(Sssp::new(self.source)),
            "cc" => Box::new(Cc),
            "bfs" => Box::new(Bfs::new(self.source)),
            "widest" => Box::new(Widest::new(self.source)),
            "wcc" => Box::new(Wcc),
            "bfs_levels" => Box::new(BfsLevels::new(self.source)),
            "kcore" => Box::new(KCore::new(self.k)),
            other => anyhow::bail!(
                "unknown app '{other}' \
                 (pagerank|ppr|sssp|cc|bfs|widest|wcc|bfs_levels|kcore)"
            ),
        })
    }

    /// Display label: the submitted one, or `app#source`.
    pub fn display_label(&self) -> String {
        self.label
            .clone()
            .unwrap_or_else(|| format!("{}#{}", self.app, self.source))
    }

    /// Decode from a request/sidecar object (absent fields default).
    pub fn from_json(v: &Json) -> Result<SubmitSpec> {
        let d = SubmitSpec::default();
        Ok(SubmitSpec {
            app: v
                .get("app")
                .and_then(Json::as_str)
                .unwrap_or(&d.app)
                .to_string(),
            source: v
                .get("source")
                .and_then(Json::as_u64)
                .map_or(d.source, |x| x as u32),
            damping: v
                .get("damping")
                .and_then(Json::as_f64)
                .map_or(d.damping, |x| x as f32),
            k: v.get("k").and_then(Json::as_u64).map_or(d.k, |x| x as u32),
            max_iters: v
                .get("iters")
                .and_then(Json::as_u64)
                .map_or(d.max_iters, |x| x as u32),
            priority: match v.get("priority").and_then(Json::as_str) {
                Some(p) => Priority::parse(p)?,
                None => Priority::Normal,
            },
            deadline_passes: v
                .get("deadline_passes")
                .and_then(Json::as_u64)
                .map(|x| x as u32),
            timeout_ms: v.get("timeout_ms").and_then(Json::as_u64),
            label: v.get("label").and_then(Json::as_str).map(str::to_string),
        })
    }

    /// Encode as a submit-request object (also the sidecar format).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("op".to_string(), Json::Str("submit".to_string())),
            ("app".to_string(), Json::Str(self.app.clone())),
            ("source".to_string(), Json::Num(f64::from(self.source))),
            ("damping".to_string(), Json::Num(f64::from(self.damping))),
            ("k".to_string(), Json::Num(f64::from(self.k))),
            ("iters".to_string(), Json::Num(f64::from(self.max_iters))),
            (
                "priority".to_string(),
                Json::Str(self.priority.name().to_string()),
            ),
        ];
        if let Some(d) = self.deadline_passes {
            fields.push(("deadline_passes".to_string(), Json::Num(f64::from(d))));
        }
        if let Some(t) = self.timeout_ms {
            fields.push(("timeout_ms".to_string(), Json::Num(t as f64)));
        }
        if let Some(l) = &self.label {
            fields.push(("label".to_string(), Json::Str(l.clone())));
        }
        Json::Obj(fields)
    }
}

/// One decoded protocol request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Submit(SubmitSpec),
    /// One job's status, or (with no id) a summary of every job.
    Status { job: Option<u32> },
    /// A finished job's result; `values` asks for the full vertex array
    /// (the compact `values_crc` fingerprint is always included).
    Result { job: u32, values: bool },
    Cancel { job: u32 },
    /// Stop admitting, run the accepted queue dry, then exit.
    Drain,
    Metrics,
    Ping,
}

impl Request {
    /// Parse one protocol line.
    pub fn parse_line(line: &str) -> Result<Request> {
        let v = Json::parse(line)?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .context("missing \"op\" field")?;
        let job_id = |v: &Json| -> Result<u32> {
            Ok(v.get("id")
                .and_then(Json::as_u64)
                .with_context(|| format!("op \"{op}\" needs a numeric \"id\""))?
                as u32)
        };
        Ok(match op {
            "submit" => Request::Submit(SubmitSpec::from_json(&v)?),
            "status" => Request::Status {
                job: v.get("id").and_then(Json::as_u64).map(|x| x as u32),
            },
            "result" => Request::Result {
                job: job_id(&v)?,
                values: v.get("values").and_then(Json::as_bool).unwrap_or(false),
            },
            "cancel" => Request::Cancel { job: job_id(&v)? },
            "drain" => Request::Drain,
            "metrics" => Request::Metrics,
            "ping" => Request::Ping,
            other => anyhow::bail!(
                "unknown op '{other}' (submit|status|result|cancel|drain|metrics|ping)"
            ),
        })
    }
}

/// CRC32 fingerprint of a vertex array's exact bits at the lane's native
/// width (LE) — the protocol's compact bit-identity check (two runs agree
/// iff their crc agrees).  The f32 path is byte-identical to the historic
/// f32-only fingerprint.
pub fn values_crc(values: &LaneVec) -> u32 {
    let mut h = crc32fast::Hasher::new();
    match values {
        LaneVec::F32(vs) => {
            for v in vs {
                h.update(&v.to_bits().to_le_bytes());
            }
        }
        LaneVec::U32(vs) => {
            for v in vs {
                h.update(&v.to_le_bytes());
            }
        }
        LaneVec::U64(vs) => {
            for v in vs {
                h.update(&v.to_le_bytes());
            }
        }
    }
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips() {
        let text = r#"{"op":"submit","n":3,"neg":-2.5,"ok":true,"none":null,"arr":[1,2,3],"s":"a b"}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("op").and_then(Json::as_str), Some("submit"));
        assert_eq!(v.get("n").and_then(Json::as_u64), Some(3));
        assert_eq!(v.get("neg").and_then(Json::as_f64), Some(-2.5));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        assert_eq!(v.get("arr").and_then(Json::as_array).map(<[Json]>::len), Some(3));
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn json_escapes_round_trip() {
        let v = Json::Obj(vec![(
            "s".to_string(),
            Json::Str("quote\" slash\\ nl\n tab\t unicode é".to_string()),
        )]);
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
        let parsed = Json::parse(r#""aA\n""#).unwrap();
        assert_eq!(parsed.as_str(), Some("aA\n"));
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn submit_spec_round_trips() {
        let spec = SubmitSpec {
            app: "ppr".to_string(),
            source: 7,
            damping: 0.9,
            k: 4,
            max_iters: 25,
            priority: Priority::High,
            deadline_passes: Some(3),
            timeout_ms: Some(1500),
            label: Some("hot query".to_string()),
        };
        let back = SubmitSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // damping survives the f32 -> f64 -> text -> f64 -> f32 trip exactly
        assert_eq!(back.damping.to_bits(), spec.damping.to_bits());
    }

    #[test]
    fn requests_parse() {
        let r = Request::parse_line(r#"{"op":"submit","app":"sssp","source":4}"#).unwrap();
        match r {
            Request::Submit(s) => {
                assert_eq!(s.app, "sssp");
                assert_eq!(s.source, 4);
                assert_eq!(s.max_iters, SubmitSpec::default().max_iters);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            Request::parse_line(r#"{"op":"status"}"#).unwrap(),
            Request::Status { job: None }
        );
        assert_eq!(
            Request::parse_line(r#"{"op":"result","id":2,"values":true}"#).unwrap(),
            Request::Result { job: 2, values: true }
        );
        assert_eq!(
            Request::parse_line(r#"{"op":"cancel","id":1}"#).unwrap(),
            Request::Cancel { job: 1 }
        );
        assert_eq!(Request::parse_line(r#"{"op":"drain"}"#).unwrap(), Request::Drain);
        assert!(Request::parse_line(r#"{"op":"result"}"#).is_err(), "result needs id");
        assert!(Request::parse_line(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse_line("not json").is_err());
    }

    #[test]
    fn build_app_matches_names() {
        for app in
            ["pagerank", "ppr", "sssp", "cc", "bfs", "widest", "wcc", "bfs_levels", "kcore"]
        {
            let spec = SubmitSpec { app: app.to_string(), ..Default::default() };
            assert_eq!(spec.build_app().unwrap().name(), app);
        }
        let bad = SubmitSpec { app: "zap".to_string(), ..Default::default() };
        let err = bad.build_app().unwrap_err().to_string();
        // the error names the full valid set, new apps included
        for app in ["pagerank", "wcc", "bfs_levels", "kcore"] {
            assert!(err.contains(app), "error should name '{app}': {err}");
        }
    }

    #[test]
    fn new_apps_round_trip_with_their_knobs() {
        let kcore = SubmitSpec { app: "kcore".to_string(), k: 5, ..Default::default() };
        let back = SubmitSpec::from_json(&kcore.to_json()).unwrap();
        assert_eq!(back, kcore);
        assert_eq!(back.build_app().unwrap().kernel().lane, crate::exec::LaneType::U32);

        let bl = SubmitSpec { app: "bfs_levels".to_string(), source: 9, ..Default::default() };
        let back = SubmitSpec::from_json(&bl.to_json()).unwrap();
        assert_eq!(back, bl);

        // a spec without "k" (an old client) still builds kcore at the default
        let v = Json::parse(r#"{"op":"submit","app":"kcore"}"#).unwrap();
        assert_eq!(SubmitSpec::from_json(&v).unwrap().k, 2);
    }

    #[test]
    fn priority_round_trips() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("urgent").is_err());
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::High.index(), 0);
    }

    #[test]
    fn values_crc_is_bit_exact() {
        let a = LaneVec::from(vec![0.1f32, -0.0, f32::INFINITY]);
        let b = LaneVec::from(vec![0.1f32, 0.0, f32::INFINITY]); // -0.0 vs 0.0 differ bitwise
        assert_ne!(values_crc(&a), values_crc(&b));
        assert_eq!(values_crc(&a), values_crc(&a.clone()));
    }

    #[test]
    fn values_crc_covers_integer_lanes() {
        let a = LaneVec::from(vec![1u32, 2, 3]);
        let b = LaneVec::from(vec![1u32, 2, 4]);
        assert_ne!(values_crc(&a), values_crc(&b));
        // a u32 lane and an f32 lane with the same bytes fingerprint alike
        // (the lane type travels in the result object, not the crc)
        let bits = LaneVec::from(vec![f32::from_bits(1), f32::from_bits(2), f32::from_bits(3)]);
        assert_eq!(values_crc(&a), values_crc(&bits));
        let w = LaneVec::from(vec![u64::MAX, 7]);
        assert_eq!(values_crc(&w), values_crc(&w.clone()));
    }
}
