//! `graphmp serve`: a resident serving daemon over one preprocessed
//! graph (PR 8).
//!
//! The daemon wraps the scan-shared interactive batch runtime
//! ([`crate::engine::VswEngine::run_jobs_with`]) in a long-running
//! admission loop: jobs arrive over a local Unix socket (or in-process
//! through a [`ServeHandle`]), wait in a bounded priority queue, and run
//! in scan-shared batches that stay open to mid-batch admission — the
//! engine's [`crate::storage::EdgeCache`] and decode memos stay warm
//! across batches, so a resident daemon amortizes where a per-query CLI
//! would re-pay cold I/O every time.
//!
//! Lifecycle of one submission:
//!
//! ```text
//! submit ──▶ [bounded queue, 3 priority classes]
//!    │              │ admitted (founder or mid-batch intake)
//!    │ queue full   ▼
//!    ▼         Running ──▶ Converged | IterLimit      (completed)
//!  Busy{retry}      │ ──▶ Failed                      (isolated fault)
//!                   │ ──▶ Expired                     (deadline/timeout evict)
//!                   │ ──▶ Cancelled                   (cancel request)
//!                   └──▶ Evicted                      (shutdown froze the
//!                                                      batch; resumable)
//! ```
//!
//! Failure matrix: a full queue *rejects* with a retry-after hint
//! (backpressure, never unbounded growth); a missed per-job deadline or
//! wall-clock timeout *evicts* that lane at a pass boundary (the PR 6
//! lane-snapshot state is surfaced as partial values, other lanes are
//! bit-identical to a run without the evicted member); SIGINT/SIGTERM or
//! [`ServeHandle::request_shutdown`] *stops admitting* and — when
//! checkpointing is on — freezes the in-flight batch into a forced
//! checkpoint at the next pass boundary, so `graphmp serve --resume`
//! restores the queue and continues every frozen lane bit-identically.
//!
//! Durable state lives in the checkpoint dir: `ckpt_*` directories from
//! [`super::checkpoint`] hold lane values; a `serve_state.jsonl` sidecar
//! (one JSON object per job: id, status, submit spec) holds the queue
//! roster.  The sidecar is rewritten via temp-file + rename on every
//! state change; unlike checkpoints it deliberately bypasses the
//! fault-injectable [`Disk`](crate::storage::disk::Disk) write path so a
//! checkpoint write fault cannot also take out the queue roster.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::{BufRead, Write as IoWrite};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::checkpoint::{self, BatchMeta, CheckpointConfig, CheckpointWriter};
use super::jobs::JobStatus;
use super::protocol::{self, Json, Priority, Request, SubmitSpec};
use crate::apps::VertexProgram;
use crate::engine::VswEngine;
use crate::exec::{
    BatchJob, BatchOptions, LaneArbiter, LaneSnapshot, LaneVec, LaneVerdict, PassObserver,
    ResumeState, MAX_BATCH_JOBS,
};
use crate::metrics::ServeMetrics;

/// Queue-roster sidecar file, kept next to the `ckpt_*` directories.
pub const SIDECAR_FILE: &str = "serve_state.jsonl";

/// Backpressure hint returned with [`SubmitOutcome::Busy`].
const RETRY_AFTER_MS: u64 = 100;

/// How long the serving loop sleeps between shutdown-flag polls when the
/// queue is empty.
const IDLE_WAIT: Duration = Duration::from_millis(200);

/// Process-global shutdown flag, set by the SIGINT/SIGTERM handler (the
/// only thing an async-signal context can safely do).
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_sig: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Route SIGINT and SIGTERM into the daemon's graceful-shutdown flag:
/// stop admitting, freeze or finish the in-flight batch, flush state,
/// exit 0.  Call once from the CLI before [`ServeDaemon::run`].
pub fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    // SAFETY: installing a handler that only stores to an AtomicBool is
    // async-signal-safe; 2/15 are SIGINT/SIGTERM on every Linux ABI.
    unsafe {
        signal(2, on_signal);
        signal(15, on_signal);
    }
}

/// Daemon configuration (CLI: `graphmp serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Unix socket path to listen on (`--socket`); `None` = in-process
    /// submissions through [`ServeDaemon::handle`] only.
    pub socket: Option<PathBuf>,
    /// Bounded admission-queue capacity; submissions beyond it get
    /// [`SubmitOutcome::Busy`] (`--queue-cap`).
    pub queue_cap: usize,
    /// Jobs per scan-shared batch, clamped to `1..=`[`MAX_BATCH_JOBS`]
    /// (`--batch-cap`).
    pub batch_cap: usize,
    /// Background checkpointing of in-flight batches plus the
    /// `serve_state.jsonl` queue sidecar (`--checkpoint-dir`,
    /// `--checkpoint-every`, `--checkpoint-secs`).
    pub checkpoint: Option<CheckpointConfig>,
    /// Restore queue + in-flight batch from `checkpoint` before serving
    /// (`--resume`).
    pub resume: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            socket: None,
            queue_cap: 256,
            batch_cap: MAX_BATCH_JOBS,
            checkpoint: None,
            resume: false,
        }
    }
}

/// What [`ServeHandle::submit`] did with a submission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Queued; poll this job id for status/results.
    Accepted(u32),
    /// Backpressure: the bounded queue is full — retry after the hint.
    Busy { retry_after_ms: u64 },
    /// Invalid submission (unknown app) or a draining/stopping daemon.
    Rejected(String),
}

/// One job the daemon knows about (index in [`Inner::jobs`] == job id).
struct ServeJob {
    spec: SubmitSpec,
    status: JobStatus,
    submitted: Instant,
    /// Submit→terminal wall latency, set once terminal.
    latency: Option<Duration>,
    /// Final (or partial, on evict) vertex values in the app's lane type.
    values: Option<LaneVec>,
    iters: u32,
    /// Cancellation requested while running; the arbiter evicts the lane
    /// at the next pass boundary.
    cancel: bool,
    /// Failure or eviction reason.
    note: Option<String>,
    /// Restored lane state (`--resume`): re-admitted as a warm-started
    /// founder of the next batch.
    resume: Option<ResumeState>,
}

impl ServeJob {
    fn new(spec: SubmitSpec) -> ServeJob {
        ServeJob {
            spec,
            status: JobStatus::Queued,
            submitted: Instant::now(),
            latency: None,
            values: None,
            iters: 0,
            cancel: false,
            note: None,
            resume: None,
        }
    }
}

/// Mutable daemon state behind the [`ServeShared`] mutex.
#[derive(Default)]
struct Inner {
    jobs: Vec<ServeJob>,
    /// Admission queues by [`Priority::index`] (high, normal, low).
    queue: [VecDeque<u32>; 3],
    /// Restored mid-batch lanes, re-admitted (in checkpoint lane order)
    /// as warm-started founders of the next batch.
    resume_front: Vec<u32>,
    /// Stop admitting new submissions, run the queue dry, then exit.
    draining: bool,
    /// Stop admitting and stop starting batches; freeze or finish the
    /// in-flight one, then exit.
    shutdown: bool,
    metrics: ServeMetrics,
}

impl Inner {
    fn depth(&self) -> usize {
        self.queue.iter().map(VecDeque::len).sum()
    }

    fn pop_next(&mut self) -> Option<u32> {
        for q in &mut self.queue {
            if let Some(id) = q.pop_front() {
                return Some(id);
            }
        }
        None
    }

    fn queued_ids(&self) -> Vec<u32> {
        self.queue.iter().flatten().copied().collect()
    }
}

/// State shared between the daemon loop, socket threads, and handles.
struct ServeShared {
    inner: Mutex<Inner>,
    cv: Condvar,
    /// The daemon loop has exited; handles reject, the listener unwinds.
    stopped: AtomicBool,
    queue_cap: usize,
    sidecar: Option<PathBuf>,
}

impl ServeShared {
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().expect("serve state poisoned")
    }

    fn shutdown_requested(&self) -> bool {
        SHUTDOWN.load(Ordering::Relaxed) || self.lock().shutdown
    }
}

/// Mark job `id` failed in place (admission-time validation failures).
fn fail_job(inner: &mut Inner, id: u32, msg: String) {
    let job = &mut inner.jobs[id as usize];
    job.status = JobStatus::Failed;
    job.note = Some(msg);
    job.latency = Some(job.submitted.elapsed());
    inner.metrics.failed += 1;
}

/// Validate + instantiate job `id` at admission.  On failure the job is
/// marked [`JobStatus::Failed`] in place and `None` comes back.
fn build_admission(
    inner: &mut Inner,
    id: u32,
    weighted: bool,
) -> Option<Box<dyn VertexProgram>> {
    let built = inner.jobs[id as usize].spec.build_app();
    match built {
        Ok(app) if !app.needs_weights() || weighted => {
            inner.jobs[id as usize].status = JobStatus::Running;
            inner.metrics.admitted += 1;
            Some(app)
        }
        Ok(app) => {
            fail_job(inner, id, format!("{} needs a weighted graph dir", app.name()));
            None
        }
        Err(e) => {
            fail_job(inner, id, format!("{e:#}"));
            None
        }
    }
}

/// Rewrite the queue-roster sidecar: one JSON line per job (id, status,
/// submit spec), staged to a temp file and renamed into place.  Plain
/// `std::fs` on purpose — a fault injected into the checkpoint write
/// path must not also corrupt the roster.
fn write_sidecar(shared: &ServeShared, inner: &Inner) {
    let Some(path) = &shared.sidecar else { return };
    let mut text = String::new();
    for (id, job) in inner.jobs.iter().enumerate() {
        text.push_str(&sidecar_line(id as u32, job));
        text.push('\n');
    }
    let tmp = path.with_extension("jsonl.tmp");
    let wrote = (|| -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&tmp, text.as_bytes())?;
        std::fs::rename(&tmp, path)
    })();
    if let Err(e) = wrote {
        eprintln!("warning: serve sidecar write failed ({}): {e}", path.display());
    }
}

fn sidecar_line(id: u32, job: &ServeJob) -> String {
    let mut fields = vec![
        ("id".to_string(), Json::Num(f64::from(id))),
        ("status".to_string(), Json::Str(job.status.name().to_string())),
    ];
    if let Json::Obj(rest) = job.spec.to_json() {
        fields.extend(rest);
    }
    Json::Obj(fields).render()
}

fn status_of_name(name: &str) -> Option<JobStatus> {
    Some(match name {
        "queued" => JobStatus::Queued,
        "running" => JobStatus::Running,
        "converged" => JobStatus::Converged,
        "iter_limit" => JobStatus::IterLimit,
        "failed" => JobStatus::Failed,
        "expired" => JobStatus::Expired,
        "cancelled" => JobStatus::Cancelled,
        "evicted" => JobStatus::Evicted,
        _ => return None,
    })
}

/// Clonable client handle: submit/inspect/cancel against a running (or
/// about-to-run) daemon, from any thread.  Socket connections are served
/// through the same handle ([`handle_line`](Self::handle_line)).
#[derive(Clone)]
pub struct ServeHandle {
    shared: Arc<ServeShared>,
}

impl ServeHandle {
    /// Admission control: validate, then enqueue under the bounded cap.
    pub fn submit(&self, spec: SubmitSpec) -> SubmitOutcome {
        let mut inner = self.shared.lock();
        inner.metrics.submitted += 1;
        if inner.draining || inner.shutdown || self.shared.stopped.load(Ordering::Relaxed) {
            inner.metrics.rejected_invalid += 1;
            return SubmitOutcome::Rejected(
                "daemon is draining; not accepting new jobs".to_string(),
            );
        }
        if let Err(e) = spec.build_app() {
            inner.metrics.rejected_invalid += 1;
            return SubmitOutcome::Rejected(format!("{e:#}"));
        }
        if inner.depth() >= self.shared.queue_cap {
            inner.metrics.rejected += 1;
            return SubmitOutcome::Busy { retry_after_ms: RETRY_AFTER_MS };
        }
        let id = inner.jobs.len() as u32;
        let class = spec.priority.index();
        inner.jobs.push(ServeJob::new(spec));
        inner.queue[class].push_back(id);
        inner.metrics.per_class[class].submitted += 1;
        let depth = inner.depth();
        inner.metrics.queue_depth = depth;
        write_sidecar(&self.shared, &inner);
        self.shared.cv.notify_all();
        SubmitOutcome::Accepted(id)
    }

    pub fn status(&self, id: u32) -> Option<JobStatus> {
        self.shared.lock().jobs.get(id as usize).map(|j| j.status)
    }

    /// A job's vertex values, once set (finished, or partial on evict).
    pub fn values(&self, id: u32) -> Option<LaneVec> {
        self.shared.lock().jobs.get(id as usize).and_then(|j| j.values.clone())
    }

    /// A job's failure/eviction reason, if any.
    pub fn note(&self, id: u32) -> Option<String> {
        self.shared.lock().jobs.get(id as usize).and_then(|j| j.note.clone())
    }

    /// Cancel a job: queued → [`JobStatus::Cancelled`] immediately;
    /// running → evicted at the next pass boundary.  Returns the status
    /// after the request, `None` for unknown ids.
    pub fn cancel(&self, id: u32) -> Option<JobStatus> {
        let mut inner = self.shared.lock();
        let current = inner.jobs.get(id as usize).map(|j| j.status)?;
        match current {
            JobStatus::Queued => {
                for q in &mut inner.queue {
                    q.retain(|&x| x != id);
                }
                let job = &mut inner.jobs[id as usize];
                job.status = JobStatus::Cancelled;
                job.latency = Some(job.submitted.elapsed());
                inner.metrics.cancelled += 1;
                let depth = inner.depth();
                inner.metrics.queue_depth = depth;
                write_sidecar(&self.shared, &inner);
                Some(JobStatus::Cancelled)
            }
            JobStatus::Running => {
                inner.jobs[id as usize].cancel = true;
                Some(JobStatus::Running)
            }
            other => Some(other),
        }
    }

    /// Stop admitting new submissions; the daemon runs the accepted
    /// queue dry and then exits.
    pub fn drain(&self) {
        self.shared.lock().draining = true;
        self.shared.cv.notify_all();
    }

    /// Graceful shutdown, same path as SIGINT/SIGTERM: stop admitting,
    /// freeze (checkpointing) or finish the in-flight batch, exit.
    pub fn request_shutdown(&self) {
        self.shared.lock().shutdown = true;
        self.shared.cv.notify_all();
    }

    /// Snapshot of the daemon's lifetime counters.
    pub fn metrics(&self) -> ServeMetrics {
        let mut inner = self.shared.lock();
        let depth = inner.depth();
        inner.metrics.queue_depth = depth;
        inner.metrics.clone()
    }

    /// Serve one wire-protocol line → one response object.
    pub fn handle_line(&self, line: &str) -> Json {
        match Request::parse_line(line) {
            Ok(req) => self.handle_request(req),
            Err(e) => err_json(&format!("{e:#}")),
        }
    }

    /// Serve one decoded request → one response object.
    pub fn handle_request(&self, req: Request) -> Json {
        match req {
            Request::Submit(spec) => match self.submit(spec) {
                SubmitOutcome::Accepted(id) => Json::Obj(vec![
                    field("ok", Json::Bool(true)),
                    field("id", Json::Num(f64::from(id))),
                ]),
                SubmitOutcome::Busy { retry_after_ms } => Json::Obj(vec![
                    field("ok", Json::Bool(false)),
                    field("busy", Json::Bool(true)),
                    field("retry_after_ms", Json::Num(retry_after_ms as f64)),
                    field(
                        "error",
                        Json::Str("admission queue full (backpressure)".to_string()),
                    ),
                ]),
                SubmitOutcome::Rejected(msg) => err_json(&msg),
            },
            Request::Status { job: Some(id) } => {
                let inner = self.shared.lock();
                match inner.jobs.get(id as usize) {
                    None => err_json(&format!("unknown job {id}")),
                    Some(j) => {
                        let mut fields = vec![
                            field("ok", Json::Bool(true)),
                            field("id", Json::Num(f64::from(id))),
                            field("status", Json::Str(j.status.name().to_string())),
                            field("label", Json::Str(j.spec.display_label())),
                            field("iters", Json::Num(f64::from(j.iters))),
                        ];
                        if let Some(note) = &j.note {
                            fields.push(field("note", Json::Str(note.clone())));
                        }
                        Json::Obj(fields)
                    }
                }
            }
            Request::Status { job: None } => {
                let inner = self.shared.lock();
                let jobs: Vec<Json> = inner
                    .jobs
                    .iter()
                    .enumerate()
                    .map(|(id, j)| {
                        Json::Obj(vec![
                            field("id", Json::Num(id as f64)),
                            field("status", Json::Str(j.status.name().to_string())),
                            field("label", Json::Str(j.spec.display_label())),
                        ])
                    })
                    .collect();
                Json::Obj(vec![
                    field("ok", Json::Bool(true)),
                    field("queue_depth", Json::Num(inner.depth() as f64)),
                    field("jobs", Json::Arr(jobs)),
                ])
            }
            Request::Result { job, values } => {
                let inner = self.shared.lock();
                let Some(j) = inner.jobs.get(job as usize) else {
                    return err_json(&format!("unknown job {job}"));
                };
                if !j.status.is_terminal() {
                    return err_json(&format!(
                        "job {job} is not finished (status {})",
                        j.status.name()
                    ));
                }
                let mut fields = vec![
                    field("ok", Json::Bool(true)),
                    field("id", Json::Num(f64::from(job))),
                    field("status", Json::Str(j.status.name().to_string())),
                    field("iters", Json::Num(f64::from(j.iters))),
                ];
                if let Some(note) = &j.note {
                    fields.push(field("note", Json::Str(note.clone())));
                }
                if let Some(vals) = &j.values {
                    fields.push(field(
                        "values_crc",
                        Json::Str(format!("{:08x}", protocol::values_crc(vals))),
                    ));
                    fields.push(field(
                        "lane",
                        Json::Str(vals.lane_type().name().to_string()),
                    ));
                    if values {
                        fields.push(field(
                            "values",
                            Json::Arr(
                                (0..vals.len()).map(|i| Json::Num(vals.get_f64(i))).collect(),
                            ),
                        ));
                    }
                }
                Json::Obj(fields)
            }
            Request::Cancel { job } => match self.cancel(job) {
                None => err_json(&format!("unknown job {job}")),
                Some(status) => Json::Obj(vec![
                    field("ok", Json::Bool(true)),
                    field("id", Json::Num(f64::from(job))),
                    field("status", Json::Str(status.name().to_string())),
                ]),
            },
            Request::Drain => {
                self.drain();
                Json::Obj(vec![
                    field("ok", Json::Bool(true)),
                    field("draining", Json::Bool(true)),
                ])
            }
            Request::Metrics => metrics_json(&self.metrics()),
            Request::Ping => Json::Obj(vec![
                field("ok", Json::Bool(true)),
                field("pong", Json::Bool(true)),
            ]),
        }
    }

    fn stopped(&self) -> bool {
        self.shared.stopped.load(Ordering::Relaxed)
    }
}

fn field(k: &str, v: Json) -> (String, Json) {
    (k.to_string(), v)
}

fn err_json(msg: &str) -> Json {
    Json::Obj(vec![
        field("ok", Json::Bool(false)),
        field("error", Json::Str(msg.to_string())),
    ])
}

fn metrics_json(m: &ServeMetrics) -> Json {
    let classes: Vec<Json> = Priority::ALL
        .iter()
        .map(|p| {
            let c = &m.per_class[p.index()];
            Json::Obj(vec![
                field("class", Json::Str(p.name().to_string())),
                field("submitted", Json::Num(c.submitted as f64)),
                field("completed", Json::Num(c.completed as f64)),
                field(
                    "mean_latency_ms",
                    Json::Num(c.mean_latency().as_secs_f64() * 1e3),
                ),
                field("max_latency_ms", Json::Num(c.max_latency.as_secs_f64() * 1e3)),
            ])
        })
        .collect();
    Json::Obj(vec![
        field("ok", Json::Bool(true)),
        field("submitted", Json::Num(m.submitted as f64)),
        field("admitted", Json::Num(m.admitted as f64)),
        field("completed", Json::Num(m.completed as f64)),
        field("rejected", Json::Num(m.rejected as f64)),
        field("rejected_invalid", Json::Num(m.rejected_invalid as f64)),
        field("expired", Json::Num(m.expired as f64)),
        field("cancelled", Json::Num(m.cancelled as f64)),
        field("evicted", Json::Num(m.evicted as f64)),
        field("failed", Json::Num(m.failed as f64)),
        field("batches", Json::Num(m.batches as f64)),
        field("checkpoints_written", Json::Num(m.checkpoints_written as f64)),
        field("checkpoints_failed", Json::Num(m.checkpoints_failed as f64)),
        field("queue_depth", Json::Num(m.queue_depth as f64)),
        field("per_class", Json::Arr(classes)),
    ])
}

/// Final report of one daemon life ([`ServeDaemon::run`]).
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub metrics: ServeMetrics,
}

/// Owns per-batch admission bookkeeping: one entry per admitted lane,
/// in lane (= admission) order.
struct LaneCtl {
    id: u32,
    admit_pass: u32,
    admitted_at: Instant,
    /// Absolute batch-local pass at which the lane expires.
    deadline_pass: Option<u32>,
    timeout: Option<Duration>,
    /// Terminal status decided at eviction (Cancelled/Expired); `None`
    /// for shutdown-freeze evictions, which stay resumable.
    verdict: Option<JobStatus>,
}

/// Leases `Box<dyn VertexProgram>`s out as `'static` references for the
/// duration of one batch (the engine's `BatchJob` lifetime wants one
/// lifetime for founders and mid-batch intake arrivals alike).
#[derive(Default)]
struct AppArena {
    leased: Vec<*mut (dyn VertexProgram + 'static)>,
}

impl AppArena {
    /// The `'static` is a scoped lie: the boxed program has a stable heap
    /// address and is only reclaimed by [`reset`](Self::reset)/drop,
    /// which the daemon calls strictly after the batch (and every
    /// `BatchJob` borrowing a lease) is gone.
    fn lease(&mut self, app: Box<dyn VertexProgram>) -> &'static dyn VertexProgram {
        let p = Box::into_raw(app);
        self.leased.push(p);
        // SAFETY: `p` came from Box::into_raw above (valid, aligned,
        // uniquely owned by this arena); the shared reference is
        // read-only and dies with the batch, before reclamation.
        unsafe { &*p }
    }

    fn reset(&mut self) {
        for p in self.leased.drain(..) {
            // SAFETY: every pointer came from Box::into_raw and is
            // reclaimed exactly once; no lease outlives the batch.
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

impl Drop for AppArena {
    fn drop(&mut self) {
        self.reset();
    }
}

/// The per-batch [`LaneArbiter`]: evicts cancelled / past-deadline /
/// timed-out lanes at pass boundaries, and stops the whole batch when a
/// shutdown wants it frozen into a checkpoint.
struct ServeArbiter {
    shared: Arc<ServeShared>,
    ctl: Rc<RefCell<Vec<LaneCtl>>>,
    /// Checkpointing is on: shutdown freezes the batch via `stop_batch`
    /// (without it the batch just runs to completion).
    stop_mode: bool,
}

impl LaneArbiter for ServeArbiter {
    fn decide(&mut self, pass: u32, lane: usize, snap: &LaneSnapshot<'_>) -> LaneVerdict {
        let mut ctl = self.ctl.borrow_mut();
        let c = &mut ctl[lane];
        let cancelled = self.shared.lock().jobs[c.id as usize].cancel;
        if cancelled {
            c.verdict = Some(JobStatus::Cancelled);
            return LaneVerdict::Evict("cancelled by request".to_string());
        }
        if let Some(d) = c.deadline_pass {
            if pass >= d {
                c.verdict = Some(JobStatus::Expired);
                return LaneVerdict::Evict(format!(
                    "deadline of {} passes exceeded ({} iterations done)",
                    d - c.admit_pass,
                    snap.iters_done
                ));
            }
        }
        if let Some(t) = c.timeout {
            if c.admitted_at.elapsed() >= t {
                c.verdict = Some(JobStatus::Expired);
                return LaneVerdict::Evict(format!(
                    "wall-clock timeout of {} ms exceeded",
                    t.as_millis()
                ));
            }
        }
        LaneVerdict::Continue
    }

    fn stop_batch(&mut self, _pass: u32) -> bool {
        self.stop_mode && self.shared.shutdown_requested()
    }
}

/// The per-batch [`PassObserver`]: keeps the checkpoint writer's roster
/// in sync with mid-batch admissions and forces a final checkpoint at
/// the boundary a shutdown freezes the batch.
struct ServeObserver {
    writer: Option<CheckpointWriter>,
    shared: Arc<ServeShared>,
    ctl: Rc<RefCell<Vec<LaneCtl>>>,
}

impl PassObserver for ServeObserver {
    fn at_boundary(&mut self, pass: u32, lanes: &[LaneSnapshot<'_>]) -> Result<()> {
        let Some(w) = self.writer.as_mut() else { return Ok(()) };
        let mut roster: Vec<(u32, u32)> =
            self.ctl.borrow().iter().map(|c| (c.id, c.admit_pass)).collect();
        let shutdown = {
            let inner = self.shared.lock();
            for id in inner.queued_ids() {
                roster.push((id, pass.saturating_add(1)));
            }
            inner.shutdown || SHUTDOWN.load(Ordering::Relaxed)
        };
        w.meta_mut().roster = roster;
        if shutdown {
            // the forced write lands at this same boundary, right before
            // the arbiter's stop_batch freezes every unfinished lane —
            // the checkpoint captures them mid-flight, resumable
            w.request_flush();
        }
        w.at_boundary(pass, lanes)
    }
}

/// Carried-forward results of finished jobs, persisted into every
/// checkpoint so `--resume` hands them back without re-running.
fn finished_records(inner: &Inner) -> Vec<checkpoint::JobRecord> {
    inner
        .jobs
        .iter()
        .enumerate()
        .filter(|(_, j)| {
            matches!(
                j.status,
                JobStatus::Converged | JobStatus::IterLimit | JobStatus::Failed
            )
        })
        .map(|(id, j)| checkpoint::JobRecord {
            id: id as u32,
            arrive: 0,
            state: ResumeState {
                values: j.values.clone().unwrap_or_default(),
                active: Vec::new(),
                iters_done: j.iters,
                done: true,
                converged: j.status == JobStatus::Converged,
                failed: (j.status == JobStatus::Failed).then(|| {
                    j.note.clone().unwrap_or_else(|| "failed".to_string())
                }),
            },
        })
        .collect()
}

/// The resident serving daemon.  Construct with a [`ServeConfig`], hand
/// out [`ServeHandle`]s, then [`run`](Self::run) on the thread that owns
/// the engine until drain/shutdown.
pub struct ServeDaemon {
    cfg: ServeConfig,
    shared: Arc<ServeShared>,
    /// Global pass clock across the daemon's batches (checkpoints are
    /// numbered by it, and it survives `--resume`).
    pass_base: u32,
}

impl ServeDaemon {
    pub fn new(cfg: ServeConfig) -> ServeDaemon {
        let sidecar = cfg.checkpoint.as_ref().map(|c| c.dir.join(SIDECAR_FILE));
        let shared = Arc::new(ServeShared {
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
            stopped: AtomicBool::new(false),
            queue_cap: cfg.queue_cap.max(1),
            sidecar,
        });
        ServeDaemon { cfg, shared, pass_base: 0 }
    }

    pub fn handle(&self) -> ServeHandle {
        ServeHandle { shared: Arc::clone(&self.shared) }
    }

    /// Serve until drained or shut down.  Returns the lifetime counters;
    /// an `Err` is a crash (e.g. the fault-injection kill hook) — state
    /// up to the last checkpoint + sidecar write is recoverable with
    /// `--resume`.
    pub fn run(&mut self, engine: &mut VswEngine) -> Result<ServeSummary> {
        if self.cfg.resume {
            self.restore(engine)?;
        } else if let Some(ckpt) = &self.cfg.checkpoint {
            // fresh daemon: a stale roster from a previous life would
            // confuse a later --resume of *this* life
            let _ = std::fs::remove_file(ckpt.dir.join(SIDECAR_FILE));
        }
        let listener = match self.cfg.socket.clone() {
            Some(path) => Some(spawn_listener(&path, self.handle())?),
            None => None,
        };
        let served = self.serve_loop(engine);
        self.shared.stopped.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(jh) = listener {
            let _ = jh.join();
        }
        {
            let mut inner = self.shared.lock();
            let depth = inner.depth();
            inner.metrics.queue_depth = depth;
            write_sidecar(&self.shared, &inner);
        }
        served?;
        Ok(ServeSummary { metrics: self.handle().metrics() })
    }

    fn serve_loop(&mut self, engine: &mut VswEngine) -> Result<()> {
        enum Wake {
            Work,
            Exit,
        }
        loop {
            let wake = {
                let mut inner = self.shared.lock();
                loop {
                    if SHUTDOWN.load(Ordering::Relaxed) {
                        inner.shutdown = true;
                    }
                    if inner.shutdown {
                        break Wake::Exit;
                    }
                    if !inner.resume_front.is_empty() || inner.depth() > 0 {
                        break Wake::Work;
                    }
                    if inner.draining {
                        break Wake::Exit;
                    }
                    inner = self
                        .shared
                        .cv
                        .wait_timeout(inner, IDLE_WAIT)
                        .expect("serve state poisoned")
                        .0;
                }
            };
            match wake {
                Wake::Exit => return Ok(()),
                Wake::Work => self.run_batch(engine)?,
            }
        }
    }

    /// Run one scan-shared batch: founders from the queue (resumed lanes
    /// first), mid-batch intake from later submissions, deadlines and
    /// cancellations enforced by the arbiter, checkpoints by the
    /// observer.
    fn run_batch(&mut self, engine: &mut VswEngine) -> Result<()> {
        let weighted = engine.property().weighted;
        let batch_cap = self.cfg.batch_cap.clamp(1, MAX_BATCH_JOBS);

        // the arena outlives `specs` (locals drop in reverse order), so
        // every leased program outlives every BatchJob borrowing it
        let arena = RefCell::new(AppArena::default());
        let ctl: Rc<RefCell<Vec<LaneCtl>>> = Rc::new(RefCell::new(Vec::new()));
        let mut specs: Vec<BatchJob<'static>> = Vec::new();
        let mut resumes: Vec<Option<ResumeState>> = Vec::new();
        let (batch_index, finished) = {
            let mut inner = self.shared.lock();
            let batch_index = inner.metrics.batches as u32;
            let mut cands: Vec<(u32, Option<ResumeState>)> = Vec::new();
            let front = std::mem::take(&mut inner.resume_front);
            for id in front {
                let rs = inner.jobs[id as usize].resume.take();
                cands.push((id, rs));
            }
            while cands.len() < batch_cap {
                let Some(id) = inner.pop_next() else { break };
                cands.push((id, None));
            }
            for (id, rs) in cands {
                let Some(app) = build_admission(&mut inner, id, weighted) else { continue };
                let spec = &inner.jobs[id as usize].spec;
                let (max_iters, deadline, timeout) =
                    (spec.max_iters, spec.deadline_passes, spec.timeout_ms);
                specs.push(BatchJob { app: arena.borrow_mut().lease(app), max_iters });
                resumes.push(rs);
                ctl.borrow_mut().push(LaneCtl {
                    id,
                    admit_pass: 0,
                    admitted_at: Instant::now(),
                    deadline_pass: deadline,
                    timeout: timeout.map(Duration::from_millis),
                    verdict: None,
                });
            }
            let finished = finished_records(&inner);
            let depth = inner.depth();
            inner.metrics.queue_depth = depth;
            write_sidecar(&self.shared, &inner);
            (batch_index, finished)
        };
        if specs.is_empty() {
            return Ok(());
        }

        let writer = self.cfg.checkpoint.as_ref().map(|cfg| {
            let prop = engine.property();
            let roster: Vec<(u32, u32)> =
                ctl.borrow().iter().map(|c| (c.id, c.admit_pass)).collect();
            let meta = BatchMeta {
                num_vertices: prop.num_vertices,
                num_edges: prop.num_edges,
                batch_index,
                start: self.pass_base,
                roster,
                finished: finished.clone(),
            };
            CheckpointWriter::new(cfg.clone(), engine.disk().clone(), meta)
                .with_base_pass(self.pass_base)
        });
        let mut observer = ServeObserver {
            writer,
            shared: Arc::clone(&self.shared),
            ctl: Rc::clone(&ctl),
        };
        let stop_mode = observer.writer.is_some();
        let mut arbiter = ServeArbiter {
            shared: Arc::clone(&self.shared),
            ctl: Rc::clone(&ctl),
            stop_mode,
        };

        let shared = Arc::clone(&self.shared);
        let ctl_in = Rc::clone(&ctl);
        let arena_ref = &arena;
        let intake = move |pass: u32, _running: usize| {
            let mut out: Vec<BatchJob<'static>> = Vec::new();
            let mut inner = shared.lock();
            if inner.shutdown || SHUTDOWN.load(Ordering::Relaxed) {
                return out;
            }
            let mut admitted = false;
            while ctl_in.borrow().len() < batch_cap {
                let Some(id) = inner.pop_next() else { break };
                let Some(app) = build_admission(&mut inner, id, weighted) else { continue };
                let spec = &inner.jobs[id as usize].spec;
                let (max_iters, deadline, timeout) =
                    (spec.max_iters, spec.deadline_passes, spec.timeout_ms);
                out.push(BatchJob { app: arena_ref.borrow_mut().lease(app), max_iters });
                ctl_in.borrow_mut().push(LaneCtl {
                    id,
                    admit_pass: pass,
                    admitted_at: Instant::now(),
                    deadline_pass: deadline.map(|d| pass.saturating_add(d)),
                    timeout: timeout.map(Duration::from_millis),
                    verdict: None,
                });
                admitted = true;
            }
            if admitted {
                let depth = inner.depth();
                inner.metrics.queue_depth = depth;
                write_sidecar(&shared, &inner);
            }
            out
        };

        let opts = BatchOptions {
            resume: resumes,
            observer: Some(&mut observer),
            arbiter: Some(&mut arbiter),
        };
        let ran = engine.run_jobs_with(&specs, intake, opts);
        drop(specs);
        arena.borrow_mut().reset();
        let (outs, metrics) = ran.context("serve batch execution")?;

        {
            let mut inner = self.shared.lock();
            let ctl_b = ctl.borrow();
            debug_assert_eq!(ctl_b.len(), outs.len());
            for (c, (values, run)) in ctl_b.iter().zip(outs) {
                let status = if run.failed.is_some() {
                    JobStatus::Failed
                } else if run.evicted.is_some() {
                    c.verdict.unwrap_or(JobStatus::Evicted)
                } else if run.converged {
                    JobStatus::Converged
                } else {
                    JobStatus::IterLimit
                };
                let job = &mut inner.jobs[c.id as usize];
                job.iters = run.job.iterations;
                job.note = run.failed.clone().or_else(|| run.evicted.clone());
                job.values = Some(values);
                job.status = status;
                job.cancel = false;
                let latency = job.submitted.elapsed();
                job.latency = Some(latency);
                let class = job.spec.priority.index();
                match status {
                    JobStatus::Failed => inner.metrics.failed += 1,
                    JobStatus::Cancelled => inner.metrics.cancelled += 1,
                    JobStatus::Expired => inner.metrics.expired += 1,
                    JobStatus::Evicted => inner.metrics.evicted += 1,
                    _ => {
                        inner.metrics.completed += 1;
                        let pc = &mut inner.metrics.per_class[class];
                        pc.completed += 1;
                        pc.total_latency += latency;
                        pc.max_latency = pc.max_latency.max(latency);
                    }
                }
            }
            inner.metrics.batches += 1;
            if let Some(w) = &observer.writer {
                inner.metrics.checkpoints_written += u64::from(w.checkpoints_written);
                inner.metrics.checkpoints_failed += u64::from(w.checkpoints_failed);
            }
            write_sidecar(&self.shared, &inner);
        }
        self.pass_base = self.pass_base.saturating_add(metrics.passes);
        Ok(())
    }

    /// `--resume`: rebuild the job table from the sidecar, reattach lane
    /// state from the newest valid checkpoint (unfinished lanes resume
    /// mid-batch, bit-identically), and requeue everything else that
    /// never finished.
    fn restore(&mut self, engine: &mut VswEngine) -> Result<()> {
        let Some(ckpt) = self.cfg.checkpoint.clone() else {
            anyhow::bail!("serve --resume requires --checkpoint-dir");
        };
        let sidecar = ckpt.dir.join(SIDECAR_FILE);
        let text = match std::fs::read_to_string(&sidecar) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                eprintln!(
                    "warning: no serve state at {} — starting fresh",
                    sidecar.display()
                );
                return Ok(());
            }
            Err(e) => return Err(e).with_context(|| format!("read {}", sidecar.display())),
        };
        let outcome = checkpoint::load_latest(&ckpt.dir, engine.disk())?;
        let num_vertices = engine.property().num_vertices;
        let num_edges = engine.property().num_edges;

        let mut inner = self.shared.lock();
        anyhow::ensure!(
            inner.jobs.is_empty(),
            "serve --resume on a daemon that already holds jobs"
        );
        for (ln0, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let (id, status, spec) = (|| -> Result<(u32, JobStatus, SubmitSpec)> {
                let v = Json::parse(line)?;
                let id = v.get("id").and_then(Json::as_u64).context("missing id")? as u32;
                let name = v.get("status").and_then(Json::as_str).unwrap_or("queued");
                let status = status_of_name(name)
                    .with_context(|| format!("unknown status '{name}'"))?;
                Ok((id, status, SubmitSpec::from_json(&v)?))
            })()
            .with_context(|| format!("{}:{}", sidecar.display(), ln0 + 1))?;
            anyhow::ensure!(
                id as usize == inner.jobs.len(),
                "{}: job ids out of order (found {id}, expected {})",
                sidecar.display(),
                inner.jobs.len()
            );
            let mut job = ServeJob::new(spec);
            job.status = status;
            inner.jobs.push(job);
        }

        let restored = inner.jobs.len();
        let mut resuming = 0usize;
        if let Some((path, state)) = outcome.loaded {
            anyhow::ensure!(
                state.num_vertices == num_vertices && state.num_edges == num_edges,
                "{}: checkpoint is for a {}-vertex/{}-edge graph, this dir has \
                 {num_vertices}/{num_edges}",
                path.display(),
                state.num_vertices,
                state.num_edges
            );
            self.pass_base = state.pass;
            // results of jobs that finished before the interrupted batch
            for rec in &state.finished {
                if let Some(job) = inner.jobs.get_mut(rec.id as usize) {
                    if job.status.is_terminal() {
                        job.values = Some(rec.state.values.clone());
                        job.iters = rec.state.iters_done;
                    }
                }
            }
            for rec in state.lanes {
                let Some(job) = inner.jobs.get_mut(rec.id as usize) else {
                    anyhow::bail!(
                        "{}: checkpoint lane for unknown job {}",
                        path.display(),
                        rec.id
                    );
                };
                if rec.state.done {
                    // finished inside the interrupted batch
                    job.status = if rec.state.failed.is_some() {
                        JobStatus::Failed
                    } else if rec.state.converged {
                        JobStatus::Converged
                    } else {
                        JobStatus::IterLimit
                    };
                    job.note = rec.state.failed.clone();
                    job.iters = rec.state.iters_done;
                    job.values = Some(rec.state.values);
                } else {
                    job.status = JobStatus::Running;
                    job.iters = rec.state.iters_done;
                    job.resume = Some(rec.state);
                    inner.resume_front.push(rec.id);
                    resuming += 1;
                }
            }
        } else if !outcome.rejected.is_empty() {
            let err = checkpoint::NoValidCheckpoint {
                dir: ckpt.dir.clone(),
                rejected: outcome.rejected,
            };
            eprintln!("warning: {err} — continuing from the serve sidecar alone");
        }

        // everything else that never reached a keepable terminal state
        // (queued, running without a lane, or shutdown-evicted with no
        // checkpoint) starts over from the queue
        let mut requeued = 0usize;
        for id in 0..inner.jobs.len() {
            if inner.jobs[id].resume.is_some() {
                continue;
            }
            let st = inner.jobs[id].status;
            if !matches!(st, JobStatus::Queued | JobStatus::Running | JobStatus::Evicted) {
                continue;
            }
            let class = inner.jobs[id].spec.priority.index();
            inner.jobs[id].status = JobStatus::Queued;
            inner.jobs[id].cancel = false;
            inner.queue[class].push_back(id as u32);
            requeued += 1;
        }
        let depth = inner.depth();
        inner.metrics.queue_depth = depth;
        write_sidecar(&self.shared, &inner);
        eprintln!(
            "serve: restored {restored} job(s) — {resuming} resuming mid-batch, \
             {requeued} requeued"
        );
        Ok(())
    }
}

/// Accept loop on the daemon's Unix socket: one thread per connection,
/// newline-delimited JSON in, one response line out per request.  Exits
/// (and removes the socket file) shortly after the daemon stops.
fn spawn_listener(
    path: &Path,
    handle: ServeHandle,
) -> Result<std::thread::JoinHandle<()>> {
    if path.exists() {
        std::fs::remove_file(path)
            .with_context(|| format!("remove stale socket {}", path.display()))?;
    }
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create socket dir {}", parent.display()))?;
        }
    }
    let listener = UnixListener::bind(path)
        .with_context(|| format!("bind serve socket {}", path.display()))?;
    listener
        .set_nonblocking(true)
        .context("serve socket nonblocking")?;
    let path = path.to_path_buf();
    Ok(std::thread::spawn(move || {
        loop {
            if handle.stopped() {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(false);
                    let conn_handle = handle.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, conn_handle);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => {
                    eprintln!("warning: serve socket accept failed: {e}");
                    break;
                }
            }
        }
        let _ = std::fs::remove_file(&path);
    }))
}

fn serve_conn(stream: UnixStream, handle: ServeHandle) -> std::io::Result<()> {
    let reader = std::io::BufReader::new(stream.try_clone()?);
    let mut out = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle.handle_line(&line);
        out.write_all(resp.render().as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn daemon(queue_cap: usize) -> ServeDaemon {
        ServeDaemon::new(ServeConfig { queue_cap, ..Default::default() })
    }

    fn spec(app: &str) -> SubmitSpec {
        SubmitSpec { app: app.to_string(), ..Default::default() }
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let d = daemon(2);
        let h = d.handle();
        assert_eq!(h.submit(spec("pagerank")), SubmitOutcome::Accepted(0));
        assert_eq!(h.submit(spec("pagerank")), SubmitOutcome::Accepted(1));
        match h.submit(spec("pagerank")) {
            SubmitOutcome::Busy { retry_after_ms } => assert!(retry_after_ms > 0),
            other => panic!("expected backpressure, got {other:?}"),
        }
        let m = h.metrics();
        assert_eq!((m.submitted, m.rejected), (3, 1));
        assert_eq!(m.queue_depth, 2);
        assert_eq!(m.per_class[Priority::Normal.index()].submitted, 2);
    }

    #[test]
    fn invalid_app_rejected_without_queueing() {
        let d = daemon(8);
        let h = d.handle();
        match h.submit(spec("zap")) {
            SubmitOutcome::Rejected(msg) => assert!(msg.contains("unknown app"), "{msg}"),
            other => panic!("{other:?}"),
        }
        let m = h.metrics();
        assert_eq!((m.rejected_invalid, m.queue_depth), (1, 0));
    }

    #[test]
    fn cancel_queued_job_immediately() {
        let d = daemon(8);
        let h = d.handle();
        assert_eq!(h.submit(spec("pagerank")), SubmitOutcome::Accepted(0));
        assert_eq!(h.cancel(0), Some(JobStatus::Cancelled));
        assert_eq!(h.status(0), Some(JobStatus::Cancelled));
        assert!(JobStatus::Cancelled.is_terminal());
        let m = h.metrics();
        assert_eq!((m.cancelled, m.queue_depth), (1, 0));
        assert_eq!(h.cancel(99), None, "unknown id");
    }

    #[test]
    fn drain_rejects_new_submissions() {
        let d = daemon(8);
        let h = d.handle();
        h.drain();
        match h.submit(spec("pagerank")) {
            SubmitOutcome::Rejected(msg) => assert!(msg.contains("draining"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn priority_classes_pop_high_first() {
        let d = daemon(8);
        let h = d.handle();
        let mut low = spec("pagerank");
        low.priority = Priority::Low;
        let mut high = spec("pagerank");
        high.priority = Priority::High;
        assert_eq!(h.submit(low), SubmitOutcome::Accepted(0));
        assert_eq!(h.submit(spec("pagerank")), SubmitOutcome::Accepted(1));
        assert_eq!(h.submit(high), SubmitOutcome::Accepted(2));
        let mut inner = d.shared.lock();
        assert_eq!(inner.pop_next(), Some(2), "high first");
        assert_eq!(inner.pop_next(), Some(1), "then normal");
        assert_eq!(inner.pop_next(), Some(0), "then low");
        assert_eq!(inner.pop_next(), None);
    }

    #[test]
    fn wire_protocol_round_trip() {
        let d = daemon(8);
        let h = d.handle();
        let resp = h.handle_line(r#"{"op":"submit","app":"pagerank","iters":3}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("id").and_then(Json::as_u64), Some(0));
        let resp = h.handle_line(r#"{"op":"status","id":0}"#);
        assert_eq!(resp.get("status").and_then(Json::as_str), Some("queued"));
        let resp = h.handle_line(r#"{"op":"result","id":0}"#);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        let resp = h.handle_line(r#"{"op":"ping"}"#);
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        let resp = h.handle_line("not json");
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        let resp = h.handle_line(r#"{"op":"metrics"}"#);
        assert_eq!(resp.get("submitted").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn sidecar_lines_round_trip_status() {
        for st in [
            JobStatus::Queued,
            JobStatus::Running,
            JobStatus::Converged,
            JobStatus::IterLimit,
            JobStatus::Failed,
            JobStatus::Expired,
            JobStatus::Cancelled,
            JobStatus::Evicted,
        ] {
            assert_eq!(status_of_name(st.name()), Some(st));
        }
        assert_eq!(status_of_name("nope"), None);
        let mut job = ServeJob::new(spec("ppr"));
        job.status = JobStatus::Evicted;
        let line = sidecar_line(7, &job);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("id").and_then(Json::as_u64), Some(7));
        assert_eq!(v.get("status").and_then(Json::as_str), Some("evicted"));
        assert_eq!(SubmitSpec::from_json(&v).unwrap().app, "ppr");
    }

    #[test]
    fn app_arena_leases_and_resets() {
        let mut arena = AppArena::default();
        let spec = spec("pagerank");
        let app = arena.lease(spec.build_app().unwrap());
        assert_eq!(app.name(), "pagerank");
        assert_eq!(arena.leased.len(), 1);
        arena.reset();
        assert!(arena.leased.is_empty());
    }
}
