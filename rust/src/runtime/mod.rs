//! Runtime services: the multi-job scheduler ([`jobs`]), crash-safe
//! checkpoint/recovery ([`checkpoint`]), the resident serving daemon
//! ([`serve`] + its wire [`protocol`]) and the PJRT backend (below).
//!
//! # PJRT backend
//!
//! `make artifacts` runs `python/compile/aot.py` once; after that the rust
//! binary is self-contained — this module compiles the HLO text with the
//! PJRT CPU client at startup and executes from the iteration hot path
//! without ever touching Python.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits serialized protos with
//! 64-bit instruction ids, which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate only exists in the vendored toolchain, so the whole
//! PJRT path is gated behind the `pjrt` cargo feature; without it,
//! [`ShardExecutor::load`] returns an error and the engine's native
//! backend (the default) is unaffected.

pub mod checkpoint;
pub mod jobs;
pub mod manifest;
pub mod protocol;
pub mod serve;

use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

pub use checkpoint::{CheckpointConfig, CheckpointState, CheckpointWriter, NoValidCheckpoint};
pub use jobs::{BatchReport, Job, JobId, JobSet, JobSpec, JobStatus};
pub use manifest::{Artifact, Manifest};
pub use protocol::{Priority, Request, SubmitSpec};
pub use serve::{ServeConfig, ServeDaemon, ServeHandle, SubmitOutcome};

/// A compiled pair of shard-update executables for one size variant.
///
/// Shapes are static: `vc` (padded vertex capacity), `ec` (edge capacity
/// per call), `rc` (row capacity per call).  The executor pads every call
/// with reduction identities (w=0 for sums, w=+inf for mins), so any shard
/// chunk with `rows ≤ rc` and `edges ≤ ec` computes exactly.
pub struct ShardExecutor {
    pub variant: String,
    pub vc: usize,
    pub ec: usize,
    pub rc: usize,
    // Both executables share one PJRT client via non-atomic `Rc`s inside
    // the xla crate, so they are neither Send nor Sync.  A single Mutex
    // serialises *all* access (execute + drop paths) to everything that
    // touches those Rcs.
    #[allow(dead_code)]
    inner: Mutex<Inner>,
}

#[cfg(feature = "pjrt")]
struct Inner {
    pagerank: xla::PjRtLoadedExecutable,
    relax: xla::PjRtLoadedExecutable,
}

#[cfg(not(feature = "pjrt"))]
struct Inner;

// SAFETY: the only non-Send/Sync state is the Rc-shared PJRT client inside
// `Inner`.  `Inner` is accessible exclusively through the Mutex, so no two
// threads ever manipulate those Rcs concurrently, and `Arc<ShardExecutor>`
// guarantees a single drop (which happens while no other handle exists).
// The engine additionally runs a single worker on the PJRT backend, so the
// lock is uncontended in practice.  (Without the `pjrt` feature `Inner` is
// a unit struct and these impls are trivially sound.)
unsafe impl Send for ShardExecutor {}
unsafe impl Sync for ShardExecutor {}

impl ShardExecutor {
    /// Load + compile the two shard executables of `variant` from the
    /// artifact directory.
    #[cfg(feature = "pjrt")]
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<ShardExecutor> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        let pr = manifest
            .find(&format!("pagerank_shard_{variant}"))
            .with_context(|| format!("no pagerank_shard artifact for variant {variant}"))?;
        let rx = manifest
            .find(&format!("relax_min_shard_{variant}"))
            .with_context(|| format!("no relax_min_shard artifact for variant {variant}"))?;
        anyhow::ensure!(
            (pr.vc, pr.ec, pr.rc) == (rx.vc, rx.ec, rx.rc),
            "variant {variant} artifacts disagree on shapes"
        );
        let compile = |art: &Artifact| -> Result<xla::PjRtLoadedExecutable> {
            let path = artifacts_dir.join(&art.path);
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(to_anyhow)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(to_anyhow)
        };
        Ok(ShardExecutor {
            variant: variant.to_string(),
            vc: pr.vc,
            ec: pr.ec,
            rc: pr.rc,
            inner: Mutex::new(Inner { pagerank: compile(pr)?, relax: compile(rx)? }),
        })
    }

    /// Stub without the `pjrt` feature: always errors (the CLI and tests
    /// fall back to / stay on the native backend).
    #[cfg(not(feature = "pjrt"))]
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<ShardExecutor> {
        let _ = (artifacts_dir, variant);
        anyhow::bail!(
            "PJRT backend unavailable: graphmp was built without the `pjrt` \
             feature (rebuild with `--features pjrt` and the vendored `xla` crate)"
        )
    }

    /// PageRank shard call: returns `base + damping·Σ src[col]·inv_deg[col]·w`
    /// for the first `rows` destination rows.
    ///
    /// `src`/`inv_deg` are the full vertex arrays (len ≤ vc); `col`/`seg`/`w`
    /// one edge chunk (len ≤ ec); padding is appended here.
    #[cfg(feature = "pjrt")]
    pub fn pagerank(
        &self,
        src: &[f32],
        inv_deg: &[f32],
        col: &[u32],
        seg: &[u32],
        w: &[f32],
        base: f32,
        rows: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(src.len() <= self.vc, "src {} > vc {}", src.len(), self.vc);
        anyhow::ensure!(col.len() <= self.ec, "edges {} > ec {}", col.len(), self.ec);
        anyhow::ensure!(rows <= self.rc, "rows {} > rc {}", rows, self.rc);
        let src_l = lit_f32_padded(src, self.vc, 0.0);
        let deg_l = lit_f32_padded(inv_deg, self.vc, 0.0);
        let col_l = lit_i32_padded(col, self.ec);
        let seg_l = lit_i32_padded(seg, self.ec);
        let w_l = lit_f32_padded(w, self.ec, 0.0); // w=0 ⇒ padding contributes 0
        let base_l = xla::Literal::vec1(&[base]);
        let inner = self.inner.lock().unwrap();
        let out = execute1(&inner.pagerank, &[src_l, deg_l, col_l, seg_l, w_l, base_l])?;
        Ok(out[..rows].to_vec())
    }

    #[cfg(not(feature = "pjrt"))]
    #[allow(clippy::too_many_arguments)]
    pub fn pagerank(
        &self,
        _src: &[f32],
        _inv_deg: &[f32],
        _col: &[u32],
        _seg: &[u32],
        _w: &[f32],
        _base: f32,
        _rows: usize,
    ) -> Result<Vec<f32>> {
        anyhow::bail!("PJRT backend unavailable (built without the `pjrt` feature)")
    }

    /// Min-relaxation shard call: `min(cur, min src[col]+w)` per row.
    #[cfg(feature = "pjrt")]
    pub fn relax_min(
        &self,
        src: &[f32],
        col: &[u32],
        seg: &[u32],
        w: &[f32],
        cur: &[f32],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(src.len() <= self.vc, "src {} > vc {}", src.len(), self.vc);
        anyhow::ensure!(col.len() <= self.ec, "edges {} > ec {}", col.len(), self.ec);
        anyhow::ensure!(cur.len() <= self.rc, "rows {} > rc {}", cur.len(), self.rc);
        let rows = cur.len();
        let src_l = lit_f32_padded(src, self.vc, f32::INFINITY);
        let col_l = lit_i32_padded(col, self.ec);
        let seg_l = lit_i32_padded(seg, self.ec);
        let w_l = lit_f32_padded(w, self.ec, f32::INFINITY); // +inf ⇒ min identity
        let cur_l = lit_f32_padded(cur, self.rc, f32::INFINITY);
        let inner = self.inner.lock().unwrap();
        let out = execute1(&inner.relax, &[src_l, col_l, seg_l, w_l, cur_l])?;
        Ok(out[..rows].to_vec())
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn relax_min(
        &self,
        _src: &[f32],
        _col: &[u32],
        _seg: &[u32],
        _w: &[f32],
        _cur: &[f32],
    ) -> Result<Vec<f32>> {
        anyhow::bail!("PJRT backend unavailable (built without the `pjrt` feature)")
    }
}

/// Run a compiled executable whose HLO returns a 1-tuple of f32[_].
#[cfg(feature = "pjrt")]
fn execute1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<f32>> {
    let result = exe.execute::<xla::Literal>(args).map_err(to_anyhow)?;
    let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
    // lowered with return_tuple=True → unwrap the 1-tuple
    let out = lit.to_tuple1().map_err(to_anyhow)?;
    out.to_vec::<f32>().map_err(to_anyhow)
}

#[cfg(feature = "pjrt")]
fn lit_f32_padded(v: &[f32], len: usize, pad: f32) -> xla::Literal {
    let mut buf = Vec::with_capacity(len);
    buf.extend_from_slice(v);
    buf.resize(len, pad);
    xla::Literal::vec1(&buf)
}

#[cfg(feature = "pjrt")]
fn lit_i32_padded(v: &[u32], len: usize) -> xla::Literal {
    let mut buf: Vec<i32> = Vec::with_capacity(len);
    buf.extend(v.iter().map(|&x| x as i32));
    buf.resize(len, 0);
    xla::Literal::vec1(&buf)
}

#[cfg(feature = "pjrt")]
fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        cfg!(feature = "pjrt") && artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn load_tiny_and_run_pagerank() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let ex = ShardExecutor::load(&artifacts_dir(), "tiny").unwrap();
        assert_eq!(ex.vc, 2048);
        // graph: edges 1->0 and 2->0 with out-degree 1 each; base = 0.05
        let mut src = vec![0.0f32; 3];
        src[1] = 0.4;
        src[2] = 0.2;
        let inv = vec![1.0f32; 3];
        let out = ex
            .pagerank(&src, &inv, &[1, 2], &[0, 0], &[1.0, 1.0], 0.05, 4)
            .unwrap();
        // row 0: 0.05 + 0.85*(0.4+0.2) = 0.56 ; rows 1..: 0.05
        assert!((out[0] - 0.56).abs() < 1e-6, "{out:?}");
        assert!((out[1] - 0.05).abs() < 1e-6);
    }

    #[test]
    fn load_tiny_and_run_relax() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let ex = ShardExecutor::load(&artifacts_dir(), "tiny").unwrap();
        let src = vec![0.0f32, f32::INFINITY, f32::INFINITY];
        // edges 0->1 (w=2), 0->2 (w=5): shard rows = vertices 1,2
        let out = ex
            .relax_min(
                &src,
                &[0, 0],
                &[0, 1],
                &[2.0, 5.0],
                &[f32::INFINITY, f32::INFINITY],
            )
            .unwrap();
        assert_eq!(out, vec![2.0, 5.0]);
    }

    #[test]
    fn relax_keeps_cur_on_untouched_rows() {
        if !have_artifacts() {
            return;
        }
        let ex = ShardExecutor::load(&artifacts_dir(), "tiny").unwrap();
        let src = vec![f32::INFINITY; 4];
        let out = ex
            .relax_min(&src, &[0], &[0], &[1.0], &[7.0, 9.0])
            .unwrap();
        assert_eq!(out, vec![7.0, 9.0]);
    }

    #[test]
    fn rejects_oversized() {
        if !have_artifacts() {
            return;
        }
        let ex = ShardExecutor::load(&artifacts_dir(), "tiny").unwrap();
        let big = vec![0.0f32; ex.vc + 1];
        assert!(ex
            .pagerank(&big, &big, &[], &[], &[], 0.0, 1)
            .is_err());
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn stub_load_reports_missing_feature() {
        let err = ShardExecutor::load(std::path::Path::new("/nonexistent"), "tiny")
            .unwrap_err()
            .to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
