//! Job submission and lifecycle for the scan-shared multi-job runtime.
//!
//! A production deployment of GraphMP serves many queries over one
//! preprocessed graph: without sharing, every query re-scans the same
//! shards and the engine's whole I/O discipline (VSW + selective
//! scheduling + compressed cache, §2.4) is paid once *per query*.
//! [`JobSet`] is the front door to scan sharing: callers submit jobs
//! (app + iteration budget), and [`run_all`](JobSet::run_all) drains the
//! queue in batches through
//! [`crate::engine::VswEngine::run_jobs_interactive`], so one shard pass
//! per iteration serves every member job.  A job's lifecycle is
//! `Queued → Running → Converged | IterLimit`; per-job results are
//! bit-identical to solo runs (`rust/tests/scan_sharing.rs`).
//!
//! Interactive arrivals (PR 5): [`submit_at`](JobSet::submit_at) tags a
//! job with an arrival pass; when its batch runs, the job is admitted at
//! that pass boundary — warm-started mid-batch without disturbing
//! running jobs — replaying a staggered arrival schedule (CLI:
//! `graphmp run --jobs N --arrivals <spec>`).  If every running job
//! finishes before an arrival's pass, the batch fast-forwards to it
//! rather than ending with work still queued.
//!
//! Crash safety (PR 6): [`run_all_checkpointed`](JobSet::run_all_checkpointed)
//! persists the whole drain state every K pass boundaries through
//! [`super::checkpoint`]; [`resume`](JobSet::resume) restores an
//! interrupted drain from the newest valid checkpoint and replays
//! exactly the remainder — final values are bit-identical to the
//! uninterrupted run (`rust/tests/recovery.rs`).  A job whose I/O fails
//! hard under failure isolation ends [`JobStatus::Failed`] without
//! poisoning its batch.

use anyhow::{Context, Result};

use super::checkpoint::{self, BatchMeta, CheckpointConfig, CheckpointWriter};
use crate::apps::VertexProgram;
use crate::engine::VswEngine;
use crate::exec::{BatchJob, BatchOptions, LaneVec, ResumeState, MAX_BATCH_JOBS};
use crate::metrics::{BatchMetrics, JobMetrics, RunMetrics};

pub type JobId = u32;

/// Lifecycle of a submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Submitted, not yet part of a batch.
    Queued,
    /// Member of the batch currently executing (or of one that failed).
    Running,
    /// Finished with an empty active set within its iteration budget.
    Converged,
    /// Finished by exhausting `max_iters` with vertices still active
    /// (normal for PageRank-family fixed-iteration queries).
    IterLimit,
    /// Failed in isolation: a hard load/compute error was contained to
    /// this job ([`crate::exec::ExecConfig::isolate_failures`]) while the
    /// rest of its batch completed unperturbed.  The first failure is in
    /// [`crate::metrics::RunMetrics::failed`].
    Failed,
    /// Evicted at a pass boundary because its deadline or wall-clock
    /// timeout passed (serving: [`super::serve`]).  Partial values are
    /// surfaced; the reason is in [`crate::metrics::RunMetrics::evicted`].
    Expired,
    /// Cancelled by the submitter before finishing (serving).  A queued
    /// job cancels immediately; a running one is evicted at the next
    /// pass boundary.
    Cancelled,
    /// Evicted by the runtime itself — typically a shutdown freezing the
    /// in-flight batch into a checkpoint.  Unlike [`Expired`](Self::Expired)
    /// the job is still resumable (`graphmp serve --resume`).
    Evicted,
}

impl JobStatus {
    /// Wire/display name (lowercase, stable across releases).
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Converged => "converged",
            JobStatus::IterLimit => "iter_limit",
            JobStatus::Failed => "failed",
            JobStatus::Expired => "expired",
            JobStatus::Cancelled => "cancelled",
            JobStatus::Evicted => "evicted",
        }
    }

    /// True once the job will never run again (results, if any, final).
    pub fn is_terminal(self) -> bool {
        !matches!(self, JobStatus::Queued | JobStatus::Running)
    }
}

/// What to run: the vertex program plus its per-job iteration budget.
pub struct JobSpec {
    /// Display label (CLI/bench output); not interpreted.
    pub label: String,
    pub app: Box<dyn VertexProgram>,
    pub max_iters: u32,
}

/// A submitted job with its lifecycle state and (once finished) results.
pub struct Job {
    pub id: JobId,
    pub spec: JobSpec,
    pub status: JobStatus,
    /// Batch pass boundary the job asks to arrive at (0 = founding
    /// member of its batch; set by [`JobSet::submit_at`]).
    pub arrive_pass: u32,
    /// Final vertex values in the app's lane type (f32 mass/distances,
    /// u32 labels/levels).
    pub values: Option<LaneVec>,
    pub run: Option<RunMetrics>,
}

/// Aggregate of one [`JobSet::run_all`] drain: one [`BatchMetrics`] per
/// executed batch.
#[derive(Clone, Debug, Default)]
pub struct BatchReport {
    pub batches: Vec<BatchMetrics>,
}

impl BatchReport {
    /// Fold the per-batch records into one aggregate [`BatchMetrics`]
    /// (batches run back-to-back, so counters and times add) — the one
    /// definition of the drain-wide amortization numbers.
    pub fn aggregate(&self) -> BatchMetrics {
        let mut agg = BatchMetrics::default();
        for b in &self.batches {
            agg.jobs += b.jobs;
            agg.admitted_mid_batch += b.admitted_mid_batch;
            agg.admissions_deferred += b.admissions_deferred;
            agg.passes += b.passes;
            agg.shard_loads += b.shard_loads;
            agg.shard_servings += b.shard_servings;
            agg.shard_servings_fanned += b.shard_servings_fanned;
            agg.bytes_read += b.bytes_read;
            agg.total_wall += b.total_wall;
            agg.total_sim_disk_seconds += b.total_sim_disk_seconds;
            agg.checkpoints_written += b.checkpoints_written;
            agg.checkpoint_bytes += b.checkpoint_bytes;
            agg.checkpoint_seconds += b.checkpoint_seconds;
            agg.checkpoints_failed += b.checkpoints_failed;
            if agg.resumed_from_pass.is_none() {
                agg.resumed_from_pass = b.resumed_from_pass;
            }
            if agg.stopped_at_pass.is_none() {
                agg.stopped_at_pass = b.stopped_at_pass;
            }
            agg.jobs_failed += b.jobs_failed;
            agg.jobs_evicted += b.jobs_evicted;
            agg.per_job.extend(b.per_job.iter().copied());
        }
        agg
    }

    pub fn shard_loads(&self) -> u64 {
        self.aggregate().shard_loads
    }

    pub fn shard_servings(&self) -> u64 {
        self.aggregate().shard_servings
    }

    pub fn bytes_read(&self) -> u64 {
        self.aggregate().bytes_read
    }

    /// Servings per load across all batches (~N for N overlapping jobs).
    pub fn shard_loads_amortized(&self) -> f64 {
        self.aggregate().shard_loads_amortized()
    }
}

/// The job queue: submit many, run them batched.
pub struct JobSet {
    jobs: Vec<Job>,
    batch_cap: usize,
}

impl Default for JobSet {
    fn default() -> Self {
        Self::new()
    }
}

impl JobSet {
    pub fn new() -> JobSet {
        JobSet { jobs: Vec::new(), batch_cap: MAX_BATCH_JOBS }
    }

    /// Cap the number of jobs per batch (clamped to `1..=MAX_BATCH_JOBS`);
    /// larger queues drain as successive batches.
    pub fn with_batch_cap(batch_cap: usize) -> JobSet {
        JobSet { jobs: Vec::new(), batch_cap: batch_cap.clamp(1, MAX_BATCH_JOBS) }
    }

    /// Enqueue a job; it runs on the next [`run_all`](Self::run_all) as a
    /// founding member of its batch.
    pub fn submit(&mut self, spec: JobSpec) -> JobId {
        self.submit_at(0, spec)
    }

    /// Enqueue a job that *arrives* at batch pass `arrive_pass`: when its
    /// batch runs, the job is admitted mid-batch at that pass boundary
    /// (warm-started, running jobs undisturbed).  Arrival passes are
    /// relative *within the batch*: the earliest arrival anchors pass 0
    /// (so `3,5` behaves as `0,2`), and if all running jobs finish before
    /// an arrival is due, the batch fast-forwards and admits it early.
    pub fn submit_at(&mut self, arrive_pass: u32, spec: JobSpec) -> JobId {
        let id = self.jobs.len() as JobId;
        self.jobs.push(Job {
            id,
            spec,
            status: JobStatus::Queued,
            arrive_pass,
            values: None,
            run: None,
        });
        id
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(id as usize)
    }

    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.job(id).map(|j| j.status)
    }

    /// Jobs still waiting for a batch.
    pub fn queued(&self) -> usize {
        self.jobs.iter().filter(|j| j.status == JobStatus::Queued).count()
    }

    /// Take a finished job's vertex values (leaves metrics in place).
    pub fn take_values(&mut self, id: JobId) -> Option<LaneVec> {
        self.jobs.get_mut(id as usize).and_then(|j| j.values.take())
    }

    /// Drain the queue: batches of at most `batch_cap` queued jobs run
    /// scan-shared through `engine` until none remain.  Queues larger
    /// than the cap split into successive batches (never truncated).
    /// Within a batch, jobs submitted with [`submit_at`](Self::submit_at)
    /// are admitted mid-batch at their arrival pass.  A batch whose
    /// members fail pre-validation (e.g. a weighted app on an unweighted
    /// dir) errors before anything runs, leaving its jobs `Queued`; an
    /// execution error leaves the current batch's jobs `Running` (their
    /// results unset) and is returned.
    pub fn run_all(&mut self, engine: &mut VswEngine) -> Result<BatchReport> {
        self.drain(engine, None, 0)
    }

    /// [`run_all`](Self::run_all) with crash safety: every batch runs
    /// under a [`CheckpointWriter`] that atomically persists the full
    /// drain state (per-job lanes, pending arrivals, earlier results)
    /// into `cfg.dir` every `cfg.every` pass boundaries.  After a crash,
    /// rebuild the same job set and call [`resume`](Self::resume).
    pub fn run_all_checkpointed(
        &mut self,
        engine: &mut VswEngine,
        cfg: &CheckpointConfig,
    ) -> Result<BatchReport> {
        self.drain(engine, Some(cfg), 0)
    }

    /// Carried-forward results of already-finished jobs, persisted into
    /// every checkpoint so a resumed drain hands them back without
    /// re-running anything.
    fn finished_records(&self) -> Vec<checkpoint::JobRecord> {
        self.jobs
            .iter()
            .filter(|j| {
                matches!(
                    j.status,
                    JobStatus::Converged | JobStatus::IterLimit | JobStatus::Failed
                )
            })
            .map(|j| checkpoint::JobRecord {
                id: j.id,
                arrive: 0,
                state: ResumeState {
                    values: j
                        .values
                        .clone()
                        .unwrap_or_else(|| LaneVec::from(Vec::<f32>::new())),
                    active: Vec::new(),
                    iters_done: j.run.as_ref().map_or(0, |r| r.job.iterations),
                    done: true,
                    converged: j.status == JobStatus::Converged,
                    failed: j.run.as_ref().and_then(|r| r.failed.clone()),
                },
            })
            .collect()
    }

    /// `pass_base` numbers checkpoints *drain-globally*: each batch's
    /// writer continues where the previous batch's passes ended, so
    /// retention always keeps the genuinely newest checkpoints (per-batch
    /// numbering would collide across batches and prune fresh ones).
    fn drain(
        &mut self,
        engine: &mut VswEngine,
        ckpt: Option<&CheckpointConfig>,
        mut pass_base: u32,
    ) -> Result<BatchReport> {
        let mut report = BatchReport::default();
        loop {
            let batch: Vec<usize> = self
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| j.status == JobStatus::Queued)
                .map(|(i, _)| i)
                .take(self.batch_cap)
                .collect();
            if batch.is_empty() {
                break;
            }
            // pre-validate every member against the graph dir *before*
            // anything runs: a mid-batch arrival failing admission would
            // otherwise burn (and then discard) the whole batch's work
            for &i in &batch {
                let app = self.jobs[i].spec.app.as_ref();
                anyhow::ensure!(
                    !app.needs_weights() || engine.property().weighted,
                    "{} (job {}) needs a weighted graph dir",
                    app.name(),
                    self.jobs[i].id
                );
            }
            for &i in &batch {
                self.jobs[i].status = JobStatus::Running;
            }
            // Arrival passes are *relative within the batch*: rebase on
            // the earliest member so the batch always has founders — a
            // founderless schedule (`--arrivals 3,5`) or an overflow
            // chunk whose members all carry large absolute passes would
            // otherwise drip in serially with no scan sharing.  The
            // earliest arrivals start at pass 0; the rest join at their
            // offset, in (arrive_pass, id) order.
            let base = batch
                .iter()
                .map(|&i| self.jobs[i].arrive_pass)
                .min()
                .unwrap_or(0);
            let founders: Vec<usize> = batch
                .iter()
                .copied()
                .filter(|&i| self.jobs[i].arrive_pass == base)
                .collect();
            let mut arrivals: Vec<usize> = batch
                .iter()
                .copied()
                .filter(|&i| self.jobs[i].arrive_pass > base)
                .collect();
            arrivals.sort_by_key(|&i| (self.jobs[i].arrive_pass, i));

            // the checkpoint writer snapshots membership up front: the
            // roster (id, relative arrival) of every batch member plus
            // the carried results of jobs finished in earlier batches
            let mut writer = match ckpt {
                Some(cfg) => {
                    let roster: Vec<(u32, u32)> = founders
                        .iter()
                        .map(|&i| (self.jobs[i].id, 0))
                        .chain(
                            arrivals
                                .iter()
                                .map(|&i| (self.jobs[i].id, self.jobs[i].arrive_pass - base)),
                        )
                        .collect();
                    let prop = engine.property();
                    let meta = BatchMeta {
                        num_vertices: prop.num_vertices,
                        num_edges: prop.num_edges,
                        batch_index: report.batches.len() as u32,
                        start: pass_base,
                        roster,
                        finished: self.finished_records(),
                    };
                    Some(
                        CheckpointWriter::new(cfg.clone(), engine.disk().clone(), meta)
                            .with_base_pass(pass_base),
                    )
                }
                None => None,
            };

            let jobs_ref: &[Job] = &self.jobs;
            let as_batch_job = |i: usize| BatchJob {
                app: jobs_ref[i].spec.app.as_ref(),
                max_iters: jobs_ref[i].spec.max_iters,
            };
            let specs: Vec<BatchJob<'_>> = founders.iter().map(|&i| as_batch_job(i)).collect();
            let mut cursor = 0usize;
            let intake = |pass: u32, running: usize| {
                let mut out = Vec::new();
                while cursor < arrivals.len() {
                    let i = arrivals[cursor];
                    let due = jobs_ref[i].arrive_pass - base <= pass;
                    // fast-forward: nothing running and nothing due —
                    // release the earliest arrival so the batch doesn't
                    // end with work still queued
                    if due || (running == 0 && out.is_empty()) {
                        out.push(as_batch_job(i));
                        cursor += 1;
                    } else {
                        break;
                    }
                }
                out
            };
            // no staggered arrivals and no checkpointing → the closed
            // batch path (skips the interactive-only degree-array
            // materialization)
            let (outs, mut metrics) = match writer.as_mut() {
                Some(w) => {
                    let opts = BatchOptions {
                        resume: Vec::new(),
                        observer: Some(w),
                        arbiter: None,
                    };
                    engine.run_jobs_with(&specs, intake, opts)?
                }
                None if arrivals.is_empty() => engine.run_jobs(&specs)?,
                None => engine.run_jobs_interactive(&specs, intake)?,
            };
            drop(specs);
            if let Some(w) = &writer {
                metrics.checkpoints_written = w.checkpoints_written;
                metrics.checkpoint_bytes = w.checkpoint_bytes;
                metrics.checkpoint_seconds = w.checkpoint_seconds;
                metrics.checkpoints_failed = w.checkpoints_failed;
            }
            // outputs come back in admission order: founders first, then
            // arrivals in the order the intake released them
            let order: Vec<usize> = founders.iter().chain(&arrivals).copied().collect();
            debug_assert_eq!(order.len(), outs.len());
            for (&i, (values, run)) in order.iter().zip(outs) {
                let job = &mut self.jobs[i];
                job.status = if run.failed.is_some() {
                    JobStatus::Failed
                } else if run.converged {
                    JobStatus::Converged
                } else {
                    JobStatus::IterLimit
                };
                job.values = Some(values);
                job.run = Some(run);
            }
            pass_base = pass_base.saturating_add(metrics.passes);
            report.batches.push(metrics);
        }
        Ok(report)
    }

    /// Restore an interrupted
    /// [`run_all_checkpointed`](Self::run_all_checkpointed) drain from
    /// the newest valid checkpoint in `cfg.dir`.  Call it on a freshly
    /// rebuilt job set holding the *same* submissions in the same order:
    /// jobs that finished before the crash get their persisted results
    /// back without re-running, the interrupted batch's admitted lanes
    /// pick up exactly where the checkpoint captured them (the remainder
    /// of the drain is bit-identical to the uninterrupted run),
    /// not-yet-admitted members re-arrive at their remaining offset, and
    /// batches that never started run afterwards — all under continued
    /// checkpointing with globally continuing pass numbers.
    ///
    /// Corrupt or truncated checkpoints are rejected individually
    /// (CRC/version/structure checks in [`super::checkpoint`]) and the
    /// newest *valid* one wins; if none survives, the error lists every
    /// candidate with its rejection reason.
    pub fn resume(
        &mut self,
        engine: &mut VswEngine,
        cfg: &CheckpointConfig,
    ) -> Result<BatchReport> {
        let disk = engine.disk().clone();
        let outcome = checkpoint::load_latest(&cfg.dir, &disk)?;
        let Some((path, state)) = outcome.loaded else {
            return Err(checkpoint::NoValidCheckpoint {
                dir: cfg.dir.clone(),
                rejected: outcome.rejected,
            }
            .into());
        };
        {
            let prop = engine.property();
            anyhow::ensure!(
                state.num_vertices == prop.num_vertices && state.num_edges == prop.num_edges,
                "{}: checkpoint is for a {}-vertex/{}-edge graph, this dir has {}/{}",
                path.display(),
                state.num_vertices,
                state.num_edges,
                prop.num_vertices,
                prop.num_edges
            );
        }
        // hand back the results of jobs that finished before the crash
        for rec in &state.finished {
            let job = self.jobs.get_mut(rec.id as usize).with_context(|| {
                format!("{}: finished job {} is not in this job set", path.display(), rec.id)
            })?;
            anyhow::ensure!(
                job.status == JobStatus::Queued,
                "job {} already ran in this job set",
                rec.id
            );
            job.status = if rec.state.failed.is_some() {
                JobStatus::Failed
            } else if rec.state.converged {
                JobStatus::Converged
            } else {
                JobStatus::IterLimit
            };
            job.values = Some(rec.state.values.clone());
            job.run = Some(RunMetrics {
                converged: rec.state.converged,
                failed: rec.state.failed.clone(),
                job: JobMetrics { iterations: rec.state.iters_done, ..Default::default() },
                ..Default::default()
            });
        }
        let mut report = BatchReport::default();
        let mut next_base = state.pass;
        if !state.lanes.is_empty() {
            let members: Vec<u32> = state
                .lanes
                .iter()
                .map(|r| r.id)
                .chain(state.pending.iter().map(|&(id, _)| id))
                .collect();
            for id in members {
                let job = self.jobs.get_mut(id as usize).with_context(|| {
                    format!("{}: batch member {id} is not in this job set", path.display())
                })?;
                anyhow::ensure!(
                    job.status == JobStatus::Queued,
                    "job {id} already ran in this job set"
                );
                anyhow::ensure!(
                    !job.spec.app.needs_weights() || engine.property().weighted,
                    "{} (job {id}) needs a weighted graph dir",
                    job.spec.app.name()
                );
                job.status = JobStatus::Running;
            }
            let roster: Vec<(u32, u32)> = state
                .lanes
                .iter()
                .map(|r| (r.id, r.arrive))
                .chain(state.pending.iter().copied())
                .collect();
            let meta = BatchMeta {
                num_vertices: state.num_vertices,
                num_edges: state.num_edges,
                batch_index: state.batch_index,
                start: state.start,
                roster,
                finished: state.finished.clone(),
            };
            let mut writer =
                CheckpointWriter::new(cfg.clone(), disk, meta).with_base_pass(state.pass);

            let jobs_ref: &[Job] = &self.jobs;
            let as_batch_job = |id: u32| BatchJob {
                app: jobs_ref[id as usize].spec.app.as_ref(),
                max_iters: jobs_ref[id as usize].spec.max_iters,
            };
            let specs: Vec<BatchJob<'_>> =
                state.lanes.iter().map(|r| as_batch_job(r.id)).collect();
            let resume_states: Vec<Option<ResumeState>> =
                state.lanes.iter().map(|r| Some(r.state.clone())).collect();
            // members the checkpoint had not yet admitted re-arrive at
            // their *remaining* offset past the restored pass clock;
            // arrivals are batch-local, so rebase on the batch-local
            // checkpoint boundary (not the drain-global pass number)
            let local_ckpt = state.pass - state.start;
            let pending = &state.pending;
            let mut cursor = 0usize;
            let intake = |pass: u32, running: usize| {
                let mut out = Vec::new();
                while cursor < pending.len() {
                    let (id, arrive) = pending[cursor];
                    let due = arrive.saturating_sub(local_ckpt) <= pass;
                    if due || (running == 0 && out.is_empty()) {
                        out.push(as_batch_job(id));
                        cursor += 1;
                    } else {
                        break;
                    }
                }
                out
            };
            let opts = BatchOptions {
                resume: resume_states,
                observer: Some(&mut writer),
                arbiter: None,
            };
            let (outs, mut metrics) = engine.run_jobs_with(&specs, intake, opts)?;
            drop(specs);
            metrics.resumed_from_pass = Some(state.pass);
            metrics.checkpoints_written = writer.checkpoints_written;
            metrics.checkpoint_bytes = writer.checkpoint_bytes;
            metrics.checkpoint_seconds = writer.checkpoint_seconds;
            metrics.checkpoints_failed = writer.checkpoints_failed;
            let order: Vec<u32> = state
                .lanes
                .iter()
                .map(|r| r.id)
                .chain(state.pending.iter().map(|&(id, _)| id))
                .collect();
            debug_assert_eq!(order.len(), outs.len());
            for (&id, (values, run)) in order.iter().zip(outs) {
                let job = &mut self.jobs[id as usize];
                job.status = if run.failed.is_some() {
                    JobStatus::Failed
                } else if run.converged {
                    JobStatus::Converged
                } else {
                    JobStatus::IterLimit
                };
                job.values = Some(values);
                job.run = Some(run);
            }
            next_base = state.pass.saturating_add(metrics.passes);
            report.batches.push(metrics);
        }
        // batches the crash never reached drain normally, still
        // checkpointed under the same directory — with pass numbering
        // continuing where the resumed batch ended, exactly as it would
        // have in the uninterrupted drain
        let rest = self.drain(engine, Some(cfg), next_base)?;
        report.batches.extend(rest.batches);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{PageRank, Ppr, Sssp};

    fn spec(label: &str, app: Box<dyn VertexProgram>, iters: u32) -> JobSpec {
        JobSpec { label: label.to_string(), app, max_iters: iters }
    }

    #[test]
    fn submit_tracks_lifecycle_metadata() {
        let mut set = JobSet::new();
        let a = set.submit(spec("pr", Box::new(PageRank::new()), 5));
        let b = set.submit(spec("ppr", Box::new(Ppr::new(3)), 5));
        assert_eq!((a, b), (0, 1));
        assert_eq!(set.status(a), Some(JobStatus::Queued));
        assert_eq!(set.queued(), 2);
        assert_eq!(set.job(b).unwrap().spec.label, "ppr");
        assert_eq!(set.status(99), None);
        assert!(set.take_values(a).is_none(), "no values before running");
    }

    #[test]
    fn submit_at_records_arrival_pass() {
        let mut set = JobSet::new();
        let a = set.submit(spec("pr", Box::new(PageRank::new()), 5));
        let b = set.submit_at(3, spec("ppr", Box::new(Ppr::new(1)), 5));
        assert_eq!(set.job(a).unwrap().arrive_pass, 0, "submit is arrival 0");
        assert_eq!(set.job(b).unwrap().arrive_pass, 3);
        assert_eq!(set.status(b), Some(JobStatus::Queued));
        assert_eq!(set.queued(), 2, "arrivals count as queued until their batch runs");
    }

    #[test]
    fn report_aggregates_interactive_counters() {
        let mut r = BatchReport::default();
        r.batches.push(BatchMetrics {
            jobs: 3,
            admitted_mid_batch: 2,
            admissions_deferred: 1,
            shard_servings_fanned: 4,
            per_job: vec![Default::default(); 3],
            ..Default::default()
        });
        r.batches.push(BatchMetrics {
            jobs: 1,
            per_job: vec![Default::default()],
            ..Default::default()
        });
        let agg = r.aggregate();
        assert_eq!(agg.jobs, 4);
        assert_eq!(agg.admitted_mid_batch, 2);
        assert_eq!(agg.admissions_deferred, 1);
        assert_eq!(agg.shard_servings_fanned, 4);
        assert_eq!(agg.per_job.len(), 4);
    }

    #[test]
    fn batch_cap_is_clamped() {
        assert_eq!(JobSet::with_batch_cap(0).batch_cap, 1);
        assert_eq!(JobSet::with_batch_cap(7).batch_cap, 7);
        assert_eq!(JobSet::with_batch_cap(1000).batch_cap, MAX_BATCH_JOBS);
    }

    #[test]
    fn report_amortization_math() {
        let mut r = BatchReport::default();
        r.batches.push(BatchMetrics {
            jobs: 2,
            shard_loads: 10,
            shard_servings: 20,
            bytes_read: 100,
            ..Default::default()
        });
        r.batches.push(BatchMetrics {
            jobs: 1,
            shard_loads: 10,
            shard_servings: 10,
            bytes_read: 50,
            ..Default::default()
        });
        assert_eq!(r.shard_loads(), 20);
        assert_eq!(r.shard_servings(), 30);
        assert_eq!(r.bytes_read(), 150);
        assert!((r.shard_loads_amortized() - 1.5).abs() < 1e-12);
        assert_eq!(BatchReport::default().shard_loads_amortized(), 0.0);
    }

    // end-to-end JobSet × engine runs live in rust/tests/scan_sharing.rs
    #[test]
    fn sssp_spec_type_erases() {
        let s = spec("sssp", Box::new(Sssp::new(0)), 10);
        assert_eq!(s.app.name(), "sssp");
    }
}
