//! Job submission and lifecycle for the scan-shared multi-job runtime.
//!
//! A production deployment of GraphMP serves many queries over one
//! preprocessed graph: without sharing, every query re-scans the same
//! shards and the engine's whole I/O discipline (VSW + selective
//! scheduling + compressed cache, §2.4) is paid once *per query*.
//! [`JobSet`] is the front door to scan sharing: callers submit jobs
//! (app + iteration budget), and [`run_all`](JobSet::run_all) drains the
//! queue in batches through [`crate::engine::VswEngine::run_jobs`], so
//! one shard pass per iteration serves every member job.  A job's
//! lifecycle is `Queued → Running → Converged | IterLimit`; per-job
//! results are bit-identical to solo runs (`rust/tests/scan_sharing.rs`).

use anyhow::Result;

use crate::apps::VertexProgram;
use crate::engine::VswEngine;
use crate::exec::{BatchJob, MAX_BATCH_JOBS};
use crate::metrics::{BatchMetrics, RunMetrics};

pub type JobId = u32;

/// Lifecycle of a submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Submitted, not yet part of a batch.
    Queued,
    /// Member of the batch currently executing (or of one that failed).
    Running,
    /// Finished with an empty active set within its iteration budget.
    Converged,
    /// Finished by exhausting `max_iters` with vertices still active
    /// (normal for PageRank-family fixed-iteration queries).
    IterLimit,
}

/// What to run: the vertex program plus its per-job iteration budget.
pub struct JobSpec {
    /// Display label (CLI/bench output); not interpreted.
    pub label: String,
    pub app: Box<dyn VertexProgram>,
    pub max_iters: u32,
}

/// A submitted job with its lifecycle state and (once finished) results.
pub struct Job {
    pub id: JobId,
    pub spec: JobSpec,
    pub status: JobStatus,
    pub values: Option<Vec<f32>>,
    pub run: Option<RunMetrics>,
}

/// Aggregate of one [`JobSet::run_all`] drain: one [`BatchMetrics`] per
/// executed batch.
#[derive(Clone, Debug, Default)]
pub struct BatchReport {
    pub batches: Vec<BatchMetrics>,
}

impl BatchReport {
    /// Fold the per-batch records into one aggregate [`BatchMetrics`]
    /// (batches run back-to-back, so counters and times add) — the one
    /// definition of the drain-wide amortization numbers.
    pub fn aggregate(&self) -> BatchMetrics {
        let mut agg = BatchMetrics::default();
        for b in &self.batches {
            agg.jobs += b.jobs;
            agg.passes += b.passes;
            agg.shard_loads += b.shard_loads;
            agg.shard_servings += b.shard_servings;
            agg.bytes_read += b.bytes_read;
            agg.total_wall += b.total_wall;
            agg.total_sim_disk_seconds += b.total_sim_disk_seconds;
        }
        agg
    }

    pub fn shard_loads(&self) -> u64 {
        self.aggregate().shard_loads
    }

    pub fn shard_servings(&self) -> u64 {
        self.aggregate().shard_servings
    }

    pub fn bytes_read(&self) -> u64 {
        self.aggregate().bytes_read
    }

    /// Servings per load across all batches (~N for N overlapping jobs).
    pub fn shard_loads_amortized(&self) -> f64 {
        self.aggregate().shard_loads_amortized()
    }
}

/// The job queue: submit many, run them batched.
pub struct JobSet {
    jobs: Vec<Job>,
    batch_cap: usize,
}

impl Default for JobSet {
    fn default() -> Self {
        Self::new()
    }
}

impl JobSet {
    pub fn new() -> JobSet {
        JobSet { jobs: Vec::new(), batch_cap: MAX_BATCH_JOBS }
    }

    /// Cap the number of jobs per batch (clamped to `1..=MAX_BATCH_JOBS`);
    /// larger queues drain as successive batches.
    pub fn with_batch_cap(batch_cap: usize) -> JobSet {
        JobSet { jobs: Vec::new(), batch_cap: batch_cap.clamp(1, MAX_BATCH_JOBS) }
    }

    /// Enqueue a job; it runs on the next [`run_all`](Self::run_all).
    pub fn submit(&mut self, spec: JobSpec) -> JobId {
        let id = self.jobs.len() as JobId;
        self.jobs.push(Job { id, spec, status: JobStatus::Queued, values: None, run: None });
        id
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(id as usize)
    }

    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.job(id).map(|j| j.status)
    }

    /// Jobs still waiting for a batch.
    pub fn queued(&self) -> usize {
        self.jobs.iter().filter(|j| j.status == JobStatus::Queued).count()
    }

    /// Take a finished job's vertex values (leaves metrics in place).
    pub fn take_values(&mut self, id: JobId) -> Option<Vec<f32>> {
        self.jobs.get_mut(id as usize).and_then(|j| j.values.take())
    }

    /// Drain the queue: batches of at most `batch_cap` queued jobs run
    /// scan-shared through `engine` until none remain.  On error the
    /// current batch's jobs are left `Running` (their results unset) and
    /// the error is returned.
    pub fn run_all(&mut self, engine: &mut VswEngine) -> Result<BatchReport> {
        let mut report = BatchReport::default();
        loop {
            let batch: Vec<usize> = self
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| j.status == JobStatus::Queued)
                .map(|(i, _)| i)
                .take(self.batch_cap)
                .collect();
            if batch.is_empty() {
                break;
            }
            for &i in &batch {
                self.jobs[i].status = JobStatus::Running;
            }
            let specs: Vec<BatchJob<'_>> = batch
                .iter()
                .map(|&i| BatchJob {
                    app: self.jobs[i].spec.app.as_ref(),
                    max_iters: self.jobs[i].spec.max_iters,
                })
                .collect();
            let (outs, metrics) = engine.run_jobs(&specs)?;
            drop(specs);
            for (&i, (values, run)) in batch.iter().zip(outs) {
                let job = &mut self.jobs[i];
                job.status = if run.converged {
                    JobStatus::Converged
                } else {
                    JobStatus::IterLimit
                };
                job.values = Some(values);
                job.run = Some(run);
            }
            report.batches.push(metrics);
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{PageRank, Ppr, Sssp};

    fn spec(label: &str, app: Box<dyn VertexProgram>, iters: u32) -> JobSpec {
        JobSpec { label: label.to_string(), app, max_iters: iters }
    }

    #[test]
    fn submit_tracks_lifecycle_metadata() {
        let mut set = JobSet::new();
        let a = set.submit(spec("pr", Box::new(PageRank::new()), 5));
        let b = set.submit(spec("ppr", Box::new(Ppr::new(3)), 5));
        assert_eq!((a, b), (0, 1));
        assert_eq!(set.status(a), Some(JobStatus::Queued));
        assert_eq!(set.queued(), 2);
        assert_eq!(set.job(b).unwrap().spec.label, "ppr");
        assert_eq!(set.status(99), None);
        assert!(set.take_values(a).is_none(), "no values before running");
    }

    #[test]
    fn batch_cap_is_clamped() {
        assert_eq!(JobSet::with_batch_cap(0).batch_cap, 1);
        assert_eq!(JobSet::with_batch_cap(7).batch_cap, 7);
        assert_eq!(JobSet::with_batch_cap(1000).batch_cap, MAX_BATCH_JOBS);
    }

    #[test]
    fn report_amortization_math() {
        let mut r = BatchReport::default();
        r.batches.push(BatchMetrics {
            jobs: 2,
            shard_loads: 10,
            shard_servings: 20,
            bytes_read: 100,
            ..Default::default()
        });
        r.batches.push(BatchMetrics {
            jobs: 1,
            shard_loads: 10,
            shard_servings: 10,
            bytes_read: 50,
            ..Default::default()
        });
        assert_eq!(r.shard_loads(), 20);
        assert_eq!(r.shard_servings(), 30);
        assert_eq!(r.bytes_read(), 150);
        assert!((r.shard_loads_amortized() - 1.5).abs() < 1e-12);
        assert_eq!(BatchReport::default().shard_loads_amortized(), 0.0);
    }

    // end-to-end JobSet × engine runs live in rust/tests/scan_sharing.rs
    #[test]
    fn sssp_spec_type_erases() {
        let s = spec("sssp", Box::new(Sssp::new(0)), 10);
        assert_eq!(s.app.name(), "sssp");
    }
}
