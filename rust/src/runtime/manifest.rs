//! Parser for `artifacts/manifest.txt` written by `python/compile/aot.py`.
//!
//! Line format:
//! `artifact <name> variant=<v> vc=<n> ec=<n> rc=<n> [iters=<n>] path=<file>`

use std::path::Path;

use anyhow::{Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Artifact {
    pub name: String,
    pub variant: String,
    pub vc: usize,
    pub ec: usize,
    pub rc: usize,
    pub iters: Option<usize>,
    pub path: String,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            anyhow::ensure!(
                it.next() == Some("artifact"),
                "manifest line {}: expected 'artifact'",
                ln + 1
            );
            let name = it
                .next()
                .with_context(|| format!("manifest line {}: missing name", ln + 1))?
                .to_string();
            let mut variant = String::new();
            let mut vc = 0;
            let mut ec = 0;
            let mut rc = 0;
            let mut iters = None;
            let mut path = String::new();
            for field in it {
                let (k, v) = field
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad field {field}", ln + 1))?;
                match k {
                    "variant" => variant = v.to_string(),
                    "vc" => vc = v.parse()?,
                    "ec" => ec = v.parse()?,
                    "rc" => rc = v.parse()?,
                    "iters" => iters = Some(v.parse()?),
                    "path" => path = v.to_string(),
                    _ => anyhow::bail!(
                        "manifest line {}: unknown key '{k}' in field '{field}'",
                        ln + 1
                    ),
                }
            }
            anyhow::ensure!(
                !path.is_empty() && vc > 0 && ec > 0 && rc > 0,
                "manifest line {}: incomplete artifact record",
                ln + 1
            );
            anyhow::ensure!(
                !artifacts.iter().any(|a: &Artifact| a.name == name),
                "manifest line {}: duplicate artifact name '{name}'",
                ln + 1
            );
            artifacts.push(Artifact { name, variant, vc, ec, rc, iters, path });
        }
        Ok(Manifest { artifacts })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let p = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("read {} (run `make artifacts`)", p.display()))?;
        Manifest::parse(&text)
    }

    pub fn find(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest variant whose capacities cover `(vertices, max shard rows)`.
    /// Ties on `vc` prefer the smaller edge capacity: oversized `ec` only
    /// adds gather padding per call (shards wider than `ec` are chunked).
    pub fn pick_variant(&self, num_vertices: usize, max_rows: usize) -> Option<&str> {
        self.artifacts
            .iter()
            .filter(|a| a.name.starts_with("pagerank_shard_"))
            .filter(|a| a.vc >= num_vertices && a.rc >= max_rows)
            .min_by_key(|a| (a.vc, a.ec))
            .map(|a| a.variant.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact pagerank_shard_tiny variant=tiny vc=2048 ec=8192 rc=512 path=pr.hlo.txt
artifact relax_min_shard_tiny variant=tiny vc=2048 ec=8192 rc=512 path=rx.hlo.txt
artifact pagerank_shard_small variant=small vc=65536 ec=262144 rc=8192 path=prs.hlo.txt
artifact pagerank_power_tiny variant=tiny vc=2048 ec=8192 rc=512 iters=10 path=pp.hlo.txt
";

    #[test]
    fn parses_all_lines() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        let a = m.find("pagerank_shard_tiny").unwrap();
        assert_eq!((a.vc, a.ec, a.rc), (2048, 8192, 512));
        assert_eq!(a.iters, None);
        assert_eq!(m.find("pagerank_power_tiny").unwrap().iters, Some(10));
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# hi\n\nartifact x variant=v vc=1 ec=1 rc=1 path=p\n").unwrap();
        assert_eq!(m.artifacts.len(), 1);
    }

    #[test]
    fn rejects_incomplete() {
        assert!(Manifest::parse("artifact x variant=v vc=1\n").is_err());
        assert!(Manifest::parse("nonsense\n").is_err());
    }

    #[test]
    fn pick_variant_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.pick_variant(1000, 100), Some("tiny"));
        assert_eq!(m.pick_variant(4000, 100), Some("small"));
        assert_eq!(m.pick_variant(100_000, 100), None);
        // rows exceeding tiny's rc push to small
        assert_eq!(m.pick_variant(1000, 600), Some("small"));
    }

    #[test]
    fn rejects_unknown_keys_with_line_number() {
        let err = Manifest::parse(
            "artifact a variant=v vc=1 ec=1 rc=1 path=p\n\
             artifact x variant=v vc=1 ec=1 rc=1 newkey=3 path=p\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("newkey"), "{err}");
    }

    #[test]
    fn rejects_duplicate_names_with_line_number() {
        let err = Manifest::parse(
            "artifact x variant=v vc=1 ec=1 rc=1 path=p\n\
             # comment\n\
             artifact x variant=w vc=2 ec=2 rc=2 path=q\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("duplicate artifact name 'x'"), "{err}");
    }
}
