//! Preprocessing: CSV edge list → partitioned graph directory (paper §2.2).
//!
//! Three steps, exactly as the paper describes:
//! 1. scan the graph to count in-degrees, then compute the vertex
//!    intervals with Algorithm 1;
//! 2. sequentially read edges and append each to its owning shard's
//!    scratch file (by destination interval);
//! 3. transform each scratch file to CSR and persist the final shard,
//!    plus the property file, the vertex information file, and the
//!    per-shard Bloom filters for selective scheduling.
//!
//! The preprocessing is application-agnostic: PageRank, SSSP and CC all
//! reuse the same partitioned directory (unlike GraphChi, §2.2).

use std::path::Path;

use anyhow::{Context, Result};

use crate::bloom::{BloomFilter, BloomSet};
use crate::graph::{Csr, Edge, EdgeList, VertexId};
use crate::storage::disk::Disk;
use crate::storage::shard::Shard;
use crate::storage::{GraphDir, Property, VertexInfo};

/// Tuning knobs for preprocessing.
#[derive(Clone, Copy, Debug)]
pub struct PrepConfig {
    /// Max edges per shard (paper: ~20M edges ≈ 80MB; we scale down with
    /// the sim datasets — 256Ki edges ≈ 1MiB keeps tens of shards per
    /// graph, the same shard-count regime).
    pub edges_per_shard: u32,
    /// Bloom filter false-positive rate.
    pub bloom_fp_rate: f64,
    /// Store edge weights (needed by SSSP; PageRank/CC inputs skip the val
    /// array, paper §2.2).
    pub weighted: bool,
    /// Cap on an interval's vertex count.  The paper's policy only bounds
    /// edges; bounding rows too keeps every shard within the AOT
    /// artifacts' static row capacity Rc (and bounds the per-worker write
    /// window).  Low-degree tail regions otherwise produce arbitrarily
    /// wide intervals.
    pub max_rows_per_shard: u32,
}

impl Default for PrepConfig {
    fn default() -> Self {
        PrepConfig {
            edges_per_shard: 262_144,
            bloom_fp_rate: 0.01,
            weighted: false,
            max_rows_per_shard: 8_192,
        }
    }
}

/// Algorithm 1: greedy in-degree-prefix partitioning of vertices into
/// intervals so that each shard holds ≈`threshold` edges (and at most
/// `max_rows` vertices) and any shard fits in memory.
pub fn compute_intervals(
    in_degrees: &[u32],
    threshold: u32,
    max_rows: u32,
) -> Vec<(VertexId, VertexId)> {
    let n = in_degrees.len() as u32;
    let max_rows = max_rows.max(1);
    if n == 0 {
        return Vec::new();
    }
    let mut intervals = Vec::new();
    let mut start = 0u32;
    let mut edge_num = 0u64;
    for v in 0..n {
        edge_num += in_degrees[v as usize] as u64;
        if (edge_num > threshold as u64 || v - start >= max_rows) && v > start {
            // close [start, v) and start a new interval at v
            intervals.push((start, v));
            start = v;
            edge_num = in_degrees[v as usize] as u64;
        }
    }
    intervals.push((start, n));
    intervals
}

/// Result of a preprocessing run (timings feed Table 8).
#[derive(Clone, Debug)]
pub struct PrepReport {
    pub num_shards: u32,
    pub num_vertices: u32,
    pub num_edges: u64,
    /// Total shard bytes on disk (the "S" of the cache-mode selection).
    pub shard_bytes: u64,
    pub step_seconds: [f64; 3],
}

/// Run the full 3-step pipeline from an in-memory edge list, writing the
/// partitioned graph into `dir`.  The edge list plays the role of the CSV
/// file on disk; step 1/2 read it sequentially through `disk` accounting
/// so preprocessing I/O matches the paper's 5D|E| cost model.
pub fn preprocess(
    g: &EdgeList,
    dir: &GraphDir,
    disk: &Disk,
    cfg: PrepConfig,
) -> Result<PrepReport> {
    std::fs::create_dir_all(&dir.root)
        .with_context(|| format!("create {}", dir.root.display()))?;
    let edge_rec = 8u64; // D: binary edge record (src,dst) — weights excluded per model

    // ---- step 1: degree scan + Algorithm 1 --------------------------------
    let t0 = std::time::Instant::now();
    disk.account_read(g.num_edges() * edge_rec); // sequential CSV scan
    let in_deg = g.in_degrees();
    let out_deg = g.out_degrees();
    let intervals = compute_intervals(&in_deg, cfg.edges_per_shard, cfg.max_rows_per_shard);
    let s1 = t0.elapsed().as_secs_f64();

    // ---- step 2: bucket edges by destination interval ---------------------
    let t1 = std::time::Instant::now();
    disk.account_read(g.num_edges() * edge_rec); // re-read edges
    let mut buckets: Vec<Vec<Edge>> = vec![Vec::new(); intervals.len()];
    // interval lookup table: vertex -> shard id
    let mut owner = vec![0u32; g.num_vertices as usize];
    for (s, &(a, b)) in intervals.iter().enumerate() {
        for v in a..b {
            owner[v as usize] = s as u32;
        }
    }
    for e in &g.edges {
        buckets[owner[e.dst as usize] as usize].push(*e);
    }
    // canonical in-shard layout: ascending source id within each CSR row,
    // so every engine folds a destination's in-edges in the same order
    // and f32 sums agree bit-for-bit across engines (cross_engine.rs)
    for bucket in &mut buckets {
        bucket.sort_unstable_by_key(|e| e.src);
    }
    disk.account_write(g.num_edges() * edge_rec); // scratch file append
    let s2 = t1.elapsed().as_secs_f64();

    // ---- step 3: scratch -> CSR shards + metadata + blooms ----------------
    let t2 = std::time::Instant::now();
    disk.account_read(g.num_edges() * edge_rec); // re-read scratch files
    let mut blooms = BloomSet::default();
    let mut shard_bytes = 0u64;
    for (s, bucket) in buckets.iter().enumerate() {
        let (a, b) = intervals[s];
        let csr = Csr::from_edges(bucket, a, (b - a) as usize, cfg.weighted);
        let shard = Shard { id: s as u32, start_vertex: a, csr };
        let bytes = shard.to_bytes();
        shard_bytes += bytes.len() as u64;
        disk.write_file(&dir.shard_path(s as u32), &bytes)?;
        let mut bf = BloomFilter::with_rate(bucket.len().max(16), cfg.bloom_fp_rate);
        for e in bucket {
            bf.insert(e.src);
        }
        blooms.filters.push(bf);
    }
    let prop = Property {
        num_vertices: g.num_vertices,
        num_edges: g.num_edges(),
        num_shards: intervals.len() as u32,
        weighted: cfg.weighted,
        intervals: intervals.clone(),
    };
    dir.write_property(disk, &prop)?;
    dir.write_vertex_info(disk, &VertexInfo { in_degree: in_deg, out_degree: out_deg })?;
    disk.write_file(&dir.bloom_path(), &blooms.to_bytes())?;
    let s3 = t2.elapsed().as_secs_f64();

    Ok(PrepReport {
        num_shards: intervals.len() as u32,
        num_vertices: g.num_vertices,
        num_edges: g.num_edges(),
        shard_bytes,
        step_seconds: [s1, s2, s3],
    })
}

/// Convenience: preprocess into a fresh temp-style directory path.
pub fn preprocess_into<P: AsRef<Path>>(
    g: &EdgeList,
    root: P,
    disk: &Disk,
    cfg: PrepConfig,
) -> Result<(GraphDir, PrepReport)> {
    let dir = GraphDir::new(root);
    let report = preprocess(g, &dir, disk, cfg)?;
    Ok((dir, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};

    #[test]
    fn intervals_cover_all_vertices_disjointly() {
        let deg = vec![3u32, 0, 5, 2, 2, 8, 1, 0, 4, 4];
        let iv = compute_intervals(&deg, 6, u32::MAX);
        assert_eq!(iv.first().unwrap().0, 0);
        assert_eq!(iv.last().unwrap().1, 10);
        for w in iv.windows(2) {
            assert_eq!(w[0].1, w[1].0, "gap/overlap between intervals");
        }
        for &(a, b) in &iv {
            assert!(a < b);
        }
    }

    #[test]
    fn intervals_respect_threshold_where_possible() {
        let deg = vec![1u32; 100];
        let iv = compute_intervals(&deg, 10, u32::MAX);
        // 100 edges at threshold 10: each interval carries <= 11 edges
        for &(a, b) in &iv {
            let edges: u64 = deg[a as usize..b as usize].iter().map(|&d| d as u64).sum();
            assert!(edges <= 11);
        }
        assert!(iv.len() >= 9);
    }

    #[test]
    fn hub_vertex_gets_own_interval() {
        // one vertex with in-degree far above threshold must still land in
        // exactly one interval (shards can exceed threshold only when a
        // single vertex does)
        let deg = vec![1u32, 100, 1, 1];
        let iv = compute_intervals(&deg, 10, u32::MAX);
        assert_eq!(iv.first().unwrap().0, 0);
        assert_eq!(iv.last().unwrap().1, 4);
        for w in iv.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn row_cap_bounds_interval_width() {
        let deg = vec![0u32; 1000]; // all-zero degrees: widest possible tail
        let iv = compute_intervals(&deg, 10, 64);
        assert_eq!(iv.first().unwrap().0, 0);
        assert_eq!(iv.last().unwrap().1, 1000);
        for &(a, b) in &iv {
            assert!(b - a <= 64, "interval [{a},{b}) wider than cap");
        }
    }

    #[test]
    fn empty_graph() {
        assert!(compute_intervals(&[], 5, u32::MAX).is_empty());
    }

    #[test]
    fn preprocess_round_trips_all_edges() {
        let g = rmat(10, 20_000, 17, RmatParams::default());
        let root = std::env::temp_dir().join("graphmp_prep_test");
        let _ = std::fs::remove_dir_all(&root);
        let disk = Disk::unthrottled();
        let cfg = PrepConfig { edges_per_shard: 4096, weighted: true, ..Default::default() };
        let (dir, report) = preprocess_into(&g, &root, &disk, cfg).unwrap();
        assert_eq!(report.num_edges, 20_000);
        assert!(report.num_shards > 1);

        let prop = dir.read_property(&disk).unwrap();
        assert_eq!(prop.num_shards, report.num_shards);

        // every edge appears in exactly the shard owning its destination
        let mut total = 0usize;
        for s in 0..prop.num_shards {
            let shard = Shard::read(&disk, &dir.shard_path(s)).unwrap();
            let (a, b) = prop.intervals[s as usize];
            assert_eq!(shard.start_vertex, a);
            assert_eq!(shard.end_vertex(), b);
            for (r, src, w) in shard.csr.iter_edges() {
                let dst = a + r;
                assert!(dst < b);
                assert!(src < prop.num_vertices);
                assert!((1.0..=16.0).contains(&w));
            }
            total += shard.num_edges();
        }
        assert_eq!(total, 20_000);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn blooms_cover_shard_sources() {
        let g = rmat(9, 5_000, 23, RmatParams::default());
        let root = std::env::temp_dir().join("graphmp_prep_bloom_test");
        let _ = std::fs::remove_dir_all(&root);
        let disk = Disk::unthrottled();
        let (dir, _) =
            preprocess_into(&g, &root, &disk, PrepConfig { edges_per_shard: 1024, ..Default::default() })
                .unwrap();
        let prop = dir.read_property(&disk).unwrap();
        let blooms = BloomSet::from_bytes(&disk.read_file(&dir.bloom_path()).unwrap()).unwrap();
        assert_eq!(blooms.filters.len(), prop.num_shards as usize);
        for s in 0..prop.num_shards {
            let shard = Shard::read(&disk, &dir.shard_path(s)).unwrap();
            for (_, src, _) in shard.csr.iter_edges() {
                assert!(blooms.filters[s as usize].contains(src), "missing src {src}");
            }
        }
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn prep_io_matches_5de_model() {
        // paper Table 3: GraphMP preprocessing I/O = 5 D |E|
        let g = rmat(9, 8_000, 29, RmatParams::default());
        let disk = Disk::unthrottled();
        let root = std::env::temp_dir().join("graphmp_prep_io_test");
        let _ = std::fs::remove_dir_all(&root);
        preprocess_into(&g, &root, &disk, PrepConfig::default()).unwrap();
        let snap = disk.snapshot();
        let de = 8 * 8_000u64;
        // metered streaming I/O (3 reads + 1 write of D|E|) plus the final
        // shard/metadata files ≈ 1 more D|E|
        assert_eq!(snap.bytes_read, 3 * de);
        assert!(snap.bytes_written >= de, "writes {}", snap.bytes_written);
        assert!(snap.bytes_written < 3 * de, "writes {}", snap.bytes_written);
        std::fs::remove_dir_all(&root).unwrap();
    }
}
