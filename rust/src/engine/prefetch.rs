//! Bounded shard prefetcher: the pipeline half of the VSW engine.
//!
//! Dedicated I/O threads walk the iteration's scheduled worklist, read +
//! decompress + parse each shard (cache or disk) and push the decoded
//! `Arc<Shard>` into a small bounded ready queue ahead of the compute
//! workers.  Simulated disk time thereby overlaps compute instead of
//! serialising with it (NXgraph-style streaming, PAPERS.md), and workers
//! never decode on the critical path.
//!
//! The queue is a `sync_channel`: its depth bounds how many decoded
//! shards can be in flight, which bounds the pipeline's extra memory to
//! `depth + workers` shards.  The producer side never blocks
//! indefinitely — [`io_thread`] polls the abort flag while the queue is
//! full, so a dead consumer (worker error *or panic*, flagged by
//! [`AbortOnPanic`]) lets `thread::scope` join and propagate instead of
//! hanging.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex, TryLockError};
use std::time::Duration;

use anyhow::Result;

use crate::storage::shard::Shard;

/// One fetched shard travelling from an I/O thread to a compute worker:
/// the scheduled shard id plus the load result (errors ride the queue so
/// the first failure reaches the iteration barrier).
pub type Fetched = (u32, Result<Arc<Shard>>);

/// Shared counters of one iteration's pipeline (atomics: touched from
/// both I/O and compute threads).
#[derive(Debug, Default)]
pub struct PipelineCounters {
    /// Shards fetched (cache or disk) by the I/O threads.
    pub prefetched: AtomicU32,
    /// Worker requests served without waiting (item staged, queue lock
    /// uncontended).
    pub ready_hits: AtomicU32,
    /// Worker requests that waited — on the prefetcher directly, or on a
    /// sibling worker that was itself parked waiting for the prefetcher.
    pub ready_misses: AtomicU32,
}

/// Sets the abort flag when dropped during a panic.  Compute workers hold
/// one so an unwinding worker releases the I/O threads (which poll the
/// flag) — otherwise `thread::scope` would wait forever on producers
/// blocked against a queue nobody drains.
pub struct AbortOnPanic<'a>(pub &'a AtomicBool);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Relaxed);
        }
    }
}

/// The consumer side of the ready queue, shareable across workers.
pub struct ReadyQueue {
    rx: Mutex<Receiver<Fetched>>,
}

impl ReadyQueue {
    /// Build a queue of the given depth (≥ 1) and return it with the
    /// producer handle; clone the sender once per I/O thread and drop the
    /// original so the queue closes when the last thread finishes.
    pub fn with_sender(depth: usize) -> (ReadyQueue, SyncSender<Fetched>) {
        let (tx, rx) = sync_channel(depth.max(1));
        (ReadyQueue { rx: Mutex::new(rx) }, tx)
    }

    /// Next fetched shard for a compute worker, recording whether it was
    /// already staged (ready hit) or the worker had to wait (miss).
    /// Contention on the queue lock counts as a miss too: it means a
    /// sibling worker is parked inside `recv`, i.e. the prefetcher is
    /// behind for everyone.  `None` once the queue is closed and drained.
    pub fn next(&self, counters: &PipelineCounters) -> Option<Fetched> {
        let (rx, waited) = match self.rx.try_lock() {
            Ok(guard) => (guard, false),
            Err(TryLockError::WouldBlock) => (self.rx.lock().unwrap(), true),
            Err(TryLockError::Poisoned(e)) => (e.into_inner(), true),
        };
        match rx.try_recv() {
            Ok(item) => {
                if waited {
                    counters.ready_misses.fetch_add(1, Ordering::Relaxed);
                } else {
                    counters.ready_hits.fetch_add(1, Ordering::Relaxed);
                }
                Some(item)
            }
            Err(TryRecvError::Empty) => match rx.recv() {
                Ok(item) => {
                    counters.ready_misses.fetch_add(1, Ordering::Relaxed);
                    Some(item)
                }
                Err(_) => None,
            },
            Err(TryRecvError::Disconnected) => None,
        }
    }
}

/// Fetch loop run by each dedicated I/O thread: claim the next worklist
/// index, load the shard, push it to the ready queue.  Stops at worklist
/// end, on the abort signal (a shard failed or a worker died), or when
/// the queue closes (all consumers gone).
pub fn io_thread<L>(
    load: L,
    worklist: &[u32],
    next: &AtomicUsize,
    abort: &AtomicBool,
    tx: SyncSender<Fetched>,
    counters: &PipelineCounters,
) where
    L: Fn(u32) -> Result<Arc<Shard>>,
{
    loop {
        if abort.load(Ordering::Relaxed) {
            return;
        }
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= worklist.len() {
            return;
        }
        let id = worklist[i];
        let res = load(id);
        counters.prefetched.fetch_add(1, Ordering::Relaxed);
        // bounded-blocking send: poll the abort flag while the queue is
        // full so a vanished consumer can't strand this thread in `send`
        let mut item = (id, res);
        loop {
            match tx.try_send(item) {
                Ok(()) => break,
                Err(TrySendError::Full(back)) => {
                    if abort.load(Ordering::Relaxed) {
                        return;
                    }
                    item = back;
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(TrySendError::Disconnected(_)) => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, Edge};

    fn mk_shard(id: u32) -> Arc<Shard> {
        let edges = vec![Edge::new(0, 5), Edge::new(1, 6)];
        Arc::new(Shard { id, start_vertex: 5, csr: Csr::from_edges(&edges, 5, 2, false) })
    }

    #[test]
    fn io_threads_deliver_every_scheduled_shard_once() {
        let worklist: Vec<u32> = (0..37).collect();
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let counters = PipelineCounters::default();
        let (queue, tx) = ReadyQueue::with_sender(4);
        let mut got = Vec::new();
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let tx = tx.clone();
                let (worklist, next, abort, counters) = (&worklist, &next, &abort, &counters);
                scope.spawn(move || {
                    io_thread(|id| Ok(mk_shard(id)), worklist, next, abort, tx, counters);
                });
            }
            drop(tx);
            while let Some((id, res)) = queue.next(&counters) {
                assert_eq!(res.unwrap().id, id);
                got.push(id);
            }
        });
        got.sort_unstable();
        assert_eq!(got, worklist);
        assert_eq!(counters.prefetched.load(Ordering::Relaxed), 37);
        let hits = counters.ready_hits.load(Ordering::Relaxed);
        let misses = counters.ready_misses.load(Ordering::Relaxed);
        assert_eq!(hits + misses, 37, "every delivery counts exactly once");
    }

    #[test]
    fn errors_ride_the_queue() {
        let worklist = vec![0u32, 1, 2];
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let counters = PipelineCounters::default();
        let (queue, tx) = ReadyQueue::with_sender(2);
        std::thread::scope(|scope| {
            let (worklist, next, abort, counters) = (&worklist, &next, &abort, &counters);
            scope.spawn(move || {
                io_thread(
                    |id| {
                        if id == 1 {
                            anyhow::bail!("boom on shard {id}")
                        } else {
                            Ok(mk_shard(id))
                        }
                    },
                    worklist,
                    next,
                    abort,
                    tx,
                    counters,
                );
            });
            let mut errs = 0;
            let mut oks = 0;
            while let Some((_, res)) = queue.next(counters) {
                match res {
                    Ok(_) => oks += 1,
                    Err(e) => {
                        assert!(e.to_string().contains("boom"));
                        errs += 1;
                    }
                }
            }
            assert_eq!((oks, errs), (2, 1));
        });
    }

    #[test]
    fn abort_stops_fetching() {
        let worklist: Vec<u32> = (0..1000).collect();
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(true); // pre-aborted
        let counters = PipelineCounters::default();
        let (_queue, tx) = ReadyQueue::with_sender(1);
        io_thread(|id| Ok(mk_shard(id)), &worklist, &next, &abort, tx, &counters);
        assert_eq!(counters.prefetched.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn abort_unblocks_a_full_queue() {
        // a producer stuck against a full queue with no consumer must
        // exit once abort is raised — this is what keeps a panicking
        // worker from deadlocking thread::scope
        let worklist: Vec<u32> = (0..100).collect();
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let counters = PipelineCounters::default();
        let (queue, tx) = ReadyQueue::with_sender(1);
        std::thread::scope(|scope| {
            let (worklist, next, abort, counters) = (&worklist, &next, &abort, &counters);
            scope.spawn(move || {
                io_thread(|id| Ok(mk_shard(id)), worklist, next, abort, tx, counters);
            });
            // let it fill the depth-1 queue, then abort without consuming
            std::thread::sleep(Duration::from_millis(20));
            abort.store(true, Ordering::Relaxed);
            // scope joins here: hangs if the producer ignores abort
        });
        assert!(counters.prefetched.load(Ordering::Relaxed) >= 1);
        drop(queue);
    }

    #[test]
    fn abort_on_panic_fires_only_during_unwind() {
        let flag = AtomicBool::new(false);
        {
            let _g = AbortOnPanic(&flag);
        }
        assert!(!flag.load(Ordering::Relaxed), "normal drop must not abort");
        let flag2 = std::sync::Arc::new(AtomicBool::new(false));
        let f2 = std::sync::Arc::clone(&flag2);
        let res = std::thread::spawn(move || {
            let _g = AbortOnPanic(&f2);
            panic!("boom");
        })
        .join();
        assert!(res.is_err());
        assert!(flag2.load(Ordering::Relaxed), "panic must raise the flag");
    }
}
