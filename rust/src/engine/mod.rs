//! The VSW (vertex-centric sliding window) engine — paper §2.3/§2.4.
//!
//! All vertices live in RAM for the whole run; edges stream from disk
//! shard-by-shard through the compressed edge cache; inactive shards are
//! skipped via per-shard Bloom filters once the active ratio drops below
//! the threshold.  Workers write disjoint `DstVertexArray` intervals with
//! no locks or atomics ([`crate::exec::SharedDst`]).
//!
//! Since the unified-execution refactor this module is only the VSW
//! *plug-in* for the shared execution core: [`VswEngine`] owns the
//! graph directory, the Bloom set and the edge cache, and implements
//! [`ShardSource`] —
//!
//! - **schedule**: the active-shard worklist via one batched Bloom pass
//!   ([`crate::exec::schedule::shard_worklist`], §2.4.1);
//! - **load**: cache probe (decode-once) or disk read + parse + cache
//!   admission, on the core's I/O threads;
//! - **compute**: the shard's exclusive interval of the dst array,
//!   executed by a [`Backend`] (native rust loops or the AOT-compiled
//!   JAX+Pallas artifacts via PJRT).
//!
//! The iteration loop itself — prefetch pipeline, active-set rebuild,
//! overlap accounting, adaptive depth — lives in [`crate::exec::ExecCore`]
//! and is shared verbatim with every baseline engine, so Figs 9/10 and
//! Tables 5–7 compare I/O schedules, not execution loops.  Results are
//! bit-identical to the sequential (`workers = 1`, `prefetch_depth = 0`)
//! reference for every app — see `rust/tests/determinism.rs` and
//! `rust/tests/cross_engine.rs`.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::apps::{Apply, Combine, VertexProgram};
use crate::bloom::BloomSet;
use crate::cache::EdgeCache;
use crate::compress::CacheMode;
use crate::exec::{
    schedule, BatchJob, ExecConfig, ExecCore, IterCtx, LaneSliceMut, LaneVec, RangeMarker,
    Scratch, ShardSource, SharedDst, UnitOutput,
};
use crate::graph::{CsrRef, VertexId};
use crate::metrics::{BatchMetrics, MemoryAccount, RunMetrics};
use crate::runtime::ShardExecutor;
use crate::storage::disk::Disk;
use crate::storage::view::{BufPool, ShardView};
use crate::storage::{GraphDir, Property, VertexInfo};

/// Shard-update execution backend.
#[derive(Clone)]
pub enum Backend {
    /// Hand-written rust compute.
    Native,
    /// AOT JAX+Pallas artifacts through PJRT.
    Pjrt(Arc<ShardExecutor>),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Native"),
            Backend::Pjrt(e) => write!(f, "Pjrt({})", e.variant),
        }
    }
}

/// Engine configuration (defaults follow the paper's settings).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads (paper: one shard per CPU core at a time).
    pub workers: usize,
    /// Edge-cache capacity in bytes (the spare-RAM budget, §2.4.2).
    pub cache_capacity: u64,
    /// Cache mode; `None` = automatic selection (§2.4.2).
    pub cache_mode: Option<CacheMode>,
    /// Enable selective scheduling (§2.4.1).
    pub selective: bool,
    /// Active-ratio threshold below which selective scheduling kicks in
    /// (paper: 0.001).
    pub active_threshold: f64,
    /// Ready-queue depth of the shard prefetcher: how many decoded shards
    /// the I/O threads may stage ahead of the compute workers.  0 turns
    /// the pipeline off (shards load inline on the worker, the pre-PR
    /// behaviour and the determinism baseline).
    pub prefetch_depth: usize,
    /// Resize the ready queue each iteration from the measured
    /// decode-vs-compute rate (CLI: `--prefetch-depth auto`);
    /// `prefetch_depth` then only seeds the first iteration.
    pub prefetch_auto: bool,
    /// Dedicated I/O threads feeding the ready queue.  1–2 keeps the
    /// simulated disk busy; real backends (`--io-backend direct`) profit
    /// from more, up to the backend's submission depth.
    pub prefetch_threads: usize,
    /// In-flight read budget for the shard pipeline (CLI: `--io-depth`).
    /// 0 inherits the disk backend's submission depth (64 for the
    /// simulated disk, the configured ring depth for direct I/O).
    pub io_depth: usize,
    /// Byte budget for the decoded pool: parsed shards of compressed
    /// cache entries memoized under LRU eviction (decode-once hot path).
    /// 0 disables the memo; the prefetcher still decodes each scheduled
    /// shard only once per iteration, on the I/O threads.
    pub decode_memo_budget: u64,
    /// Split (unit × job) sub-tasks of a scan-shared batch pass across
    /// idle workers when the union worklist is shorter than the worker
    /// pool (CLI: `--no-fanout` turns it off).  Bit-identical results
    /// either way; off reproduces the PR-4 serial member compute.
    pub fan_out: bool,
    /// Contain hard per-shard I/O or compute errors to the member jobs
    /// they hit (those jobs end `Failed`) instead of aborting the whole
    /// batch.  Off by default — solo runs and historical callers keep
    /// first-error-aborts semantics.
    pub isolate_failures: bool,
    pub backend: Backend,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let exec = ExecConfig::default();
        EngineConfig {
            workers: exec.workers,
            cache_capacity: 256 * 1024 * 1024,
            cache_mode: None,
            selective: true,
            active_threshold: 0.001,
            prefetch_depth: exec.prefetch_depth,
            prefetch_auto: exec.prefetch_auto,
            prefetch_threads: exec.prefetch_threads,
            io_depth: 0,
            decode_memo_budget: 256 * 1024 * 1024,
            fan_out: exec.fan_out,
            isolate_failures: exec.isolate_failures,
            backend: Backend::Native,
        }
    }
}

/// A VSW engine bound to one partitioned graph directory.
pub struct VswEngine {
    dir: GraphDir,
    disk: Disk,
    cfg: EngineConfig,
    prop: Property,
    info: VertexInfo,
    blooms: BloomSet,
    cache: EdgeCache,
    shard_bytes: u64,
    /// Recycles shard read buffers across iterations (mode-0 runs
    /// otherwise allocate one per shard per iteration).
    buf_pool: Arc<BufPool>,
}

impl VswEngine {
    /// Open a preprocessed graph directory.
    pub fn open(dir: &GraphDir, disk: &Disk, cfg: EngineConfig) -> Result<VswEngine> {
        let prop = dir.read_property(disk).context("open property file")?;
        let info = dir.read_vertex_info(disk).context("open vertex info")?;
        let blooms = BloomSet::from_bytes(&disk.read_file(&dir.bloom_path())?)?;
        anyhow::ensure!(
            blooms.filters.len() == prop.num_shards as usize,
            "bloom count mismatch"
        );
        // Total shard bytes (the S of the mode-selection rule) from file
        // metadata — free, like stat(2).
        let mut shard_bytes = 0u64;
        for s in 0..prop.num_shards {
            let p = dir.shard_path(s);
            shard_bytes += std::fs::metadata(&p)
                .with_context(|| format!("stat {}", p.display()))?
                .len();
        }
        let mut cache = match cfg.cache_mode {
            Some(mode) => EdgeCache::new(mode, cfg.cache_capacity),
            None => EdgeCache::auto(shard_bytes, cfg.cache_capacity),
        };
        cache.set_decode_memo_budget(cfg.decode_memo_budget);
        // steady state keeps ≤ workers + prefetch_depth shard buffers in
        // flight; idle capacity beyond that would be dead RAM.  The pool
        // inherits the disk backend's alignment so direct-I/O reads get
        // block-aligned recycled buffers for free.
        let buf_pool = BufPool::with_alignment(
            cfg.workers + cfg.prefetch_depth.max(1),
            disk.alignment(),
        );
        Ok(VswEngine {
            dir: dir.clone(),
            disk: disk.clone(),
            cfg,
            prop,
            info,
            blooms,
            cache,
            shard_bytes,
            buf_pool,
        })
    }

    pub fn property(&self) -> &Property {
        &self.prop
    }

    pub fn cache(&self) -> &EdgeCache {
        &self.cache
    }

    /// The disk handle this engine reads through (checkpoint writers
    /// share it so checkpoint I/O is metered with everything else).
    pub fn disk(&self) -> &Disk {
        &self.disk
    }

    pub fn shard_bytes(&self) -> u64 {
        self.shard_bytes
    }

    /// Widest shard interval (drives PJRT variant selection).
    pub fn max_rows(&self) -> usize {
        self.prop
            .intervals
            .iter()
            .map(|&(a, b)| (b - a) as usize)
            .max()
            .unwrap_or(0)
    }

    /// Structural memory account (Fig 11 / Table 3's memory column).
    pub fn memory_account(&self) -> MemoryAccount {
        let n = self.prop.num_vertices as u64;
        let cache_snap = self.cache.snapshot();
        MemoryAccount {
            vertex_arrays: 2 * 4 * n,           // Src + Dst f32 arrays
            degree_arrays: 2 * 4 * n,           // in/out degree u32 arrays
            blooms: self.blooms.size_bytes() as u64,
            cache: cache_snap.used_bytes,
            decoded_pool: cache_snap.memo_bytes,
            // one in-flight shard per worker plus the prefetcher's ready
            // queue, sized by the average shard
            inflight_shards: ((self.cfg.workers + self.cfg.prefetch_depth) as u64)
                * (self.shard_bytes / self.prop.num_shards.max(1) as u64),
            // idle recycled read buffers are resident RAM too
            other: self.buf_pool.idle_bytes(),
        }
    }

    /// The shard-buffer recycling pool (observability: `(reused, fresh)`
    /// take counts via [`BufPool::stats`]).
    pub fn buf_pool(&self) -> &Arc<BufPool> {
        &self.buf_pool
    }

    /// Run `app` for at most `max_iters` iterations (stops early when no
    /// vertex is active, Algorithm 2 line 2).
    pub fn run(&mut self, app: &dyn VertexProgram, max_iters: u32) -> Result<RunMetrics> {
        Ok(self.run_impl(app, max_iters)?.1)
    }

    /// Final values convenience: run and return the vertex array (typed
    /// by the app's lane — f32 mass/distances, u32 labels/levels).
    pub fn run_to_values(
        &mut self,
        app: &dyn VertexProgram,
        max_iters: u32,
    ) -> Result<(LaneVec, RunMetrics)> {
        self.run_impl(app, max_iters)
    }

    /// Run a scan-shared batch of jobs over this graph: every iteration
    /// loads the union of the member jobs' active shards exactly once
    /// and hands each decoded `Arc<ShardView>` to every job whose own
    /// Bloom-filtered worklist selected it.  Per-job results are
    /// bit-identical to back-to-back solo runs while per-job disk I/O
    /// falls as ~1/N (`rust/tests/scan_sharing.rs`, Fig 12 bench).
    pub fn run_jobs(
        &mut self,
        jobs: &[BatchJob<'_>],
    ) -> Result<(Vec<crate::exec::JobOutput>, BatchMetrics)> {
        // closed batches can't fill via an intake, so empty means a bug
        anyhow::ensure!(!jobs.is_empty(), "empty job batch");
        self.run_jobs_inner(jobs, |_, _| Vec::new(), false, crate::exec::BatchOptions::default())
    }

    /// [`run_jobs`](Self::run_jobs) plus interactive admission: `intake`
    /// is polled at every pass boundary with `(pass, running_jobs)` and
    /// may return newly arrived jobs, which warm-start at that boundary
    /// without disturbing running jobs (see
    /// [`ExecCore::run_batch_interactive`]).  This is how
    /// [`crate::runtime::JobSet`] replays staggered arrival schedules
    /// (`graphmp run --jobs N --arrivals …`).
    pub fn run_jobs_interactive<'j, F>(
        &mut self,
        jobs: &[BatchJob<'j>],
        intake: F,
    ) -> Result<(Vec<crate::exec::JobOutput>, BatchMetrics)>
    where
        F: FnMut(u32, usize) -> Vec<BatchJob<'j>>,
    {
        self.run_jobs_inner(jobs, intake, true, crate::exec::BatchOptions::default())
    }

    /// [`run_jobs_interactive`](Self::run_jobs_interactive) plus crash
    /// recovery plumbing: founding jobs may warm-start from checkpointed
    /// [`crate::exec::ResumeState`], and a [`crate::exec::PassObserver`]
    /// (the checkpoint writer) is called at every pass boundary.
    pub fn run_jobs_with<'j, F>(
        &mut self,
        jobs: &[BatchJob<'j>],
        intake: F,
        opts: crate::exec::BatchOptions<'_>,
    ) -> Result<(Vec<crate::exec::JobOutput>, BatchMetrics)>
    where
        F: FnMut(u32, usize) -> Vec<BatchJob<'j>>,
    {
        self.run_jobs_inner(jobs, intake, true, opts)
    }

    fn run_jobs_inner<'j, F>(
        &mut self,
        jobs: &[BatchJob<'j>],
        intake: F,
        interactive: bool,
        opts: crate::exec::BatchOptions<'_>,
    ) -> Result<(Vec<crate::exec::JobOutput>, BatchMetrics)>
    where
        F: FnMut(u32, usize) -> Vec<BatchJob<'j>>,
    {
        let mut degrees_needed = false;
        for job in jobs {
            if job.app.needs_weights() {
                anyhow::ensure!(
                    self.prop.weighted,
                    "{} needs a weighted graph dir",
                    job.app.name()
                );
            }
            degrees_needed |= job.app.uses_out_degrees();
        }
        // mid-batch admissions can't re-check degree needs here, so
        // interactive batches always materialize the degree array —
        // admitted sum-kernel jobs then find it in place.  Closed
        // batches keep the cheap gate: no sum kernel, no O(|V|) pass.
        let inv_out_deg: Vec<f32> = if degrees_needed || interactive {
            self.info
                .out_degree
                .iter()
                .map(|&d| if d > 0 { 1.0 / d as f32 } else { 0.0 })
                .collect()
        } else {
            Vec::new()
        };
        let workers = match &self.cfg.backend {
            // PJRT executions serialise on the executable lock; extra
            // workers would only contend.
            Backend::Pjrt(_) => 1,
            Backend::Native => self.cfg.workers.max(1),
        };
        let exec_cfg = ExecConfig {
            workers,
            prefetch_depth: self.cfg.prefetch_depth,
            prefetch_auto: self.cfg.prefetch_auto,
            prefetch_threads: self.cfg.prefetch_threads,
            io_depth: if self.cfg.io_depth == 0 {
                self.disk.submission_depth()
            } else {
                self.cfg.io_depth
            },
            fan_out: self.cfg.fan_out,
            isolate_failures: self.cfg.isolate_failures,
        };
        // Backstop for direct API callers: arrivals bypass the up-front
        // weights check above, so re-check them at admission and surface
        // the error once the batch drains.  (`JobSet::run_all`
        // pre-validates its whole queue against the graph dir before
        // starting a batch, so the scheduler path never burns a batch's
        // work on an invalid arrival.)
        let weighted = self.prop.weighted;
        let mut intake = intake;
        let mut admission_err: Option<anyhow::Error> = None;
        let wrapped = |pass: u32, running: usize| {
            if admission_err.is_some() {
                return Vec::new();
            }
            let arrivals = intake(pass, running);
            for job in &arrivals {
                if job.app.needs_weights() && !weighted {
                    admission_err = Some(anyhow::anyhow!(
                        "{} needs a weighted graph dir",
                        job.app.name()
                    ));
                    return Vec::new();
                }
            }
            arrivals
        };
        let this = &*self;
        let source = VswSource { eng: this };
        let mut core = ExecCore::new(exec_cfg, &this.disk, Some(&this.cache));
        let out = core.run_batch_with(
            &source,
            jobs,
            this.prop.num_vertices,
            &inv_out_deg,
            wrapped,
            opts,
        );
        if let Some(e) = admission_err {
            return Err(e);
        }
        out
    }

    /// Build the VSW shard source and hand the run to the shared
    /// execution core ([`ExecCore`]) — the single-job special case of
    /// [`run_jobs`](Self::run_jobs).
    fn run_impl(
        &mut self,
        app: &dyn VertexProgram,
        max_iters: u32,
    ) -> Result<(LaneVec, RunMetrics)> {
        let (mut outs, _) = self.run_jobs(&[BatchJob { app, max_iters }])?;
        let out = outs.pop().expect("one job in, one result out");
        // a solo run has no batch to protect: an isolated failure is the
        // run's failure
        if let Some(msg) = &out.1.failed {
            anyhow::bail!("{} failed: {msg}", app.name());
        }
        Ok(out)
    }

    /// Load one shard: cache hit (decode-once, zero-copy), else an
    /// aligned disk read + one header parse + one CRC pass + cache
    /// admission.  Runs on the core's I/O threads when the pipeline is
    /// on, inline on workers otherwise.
    fn load_shard(&self, shard_id: u32) -> Result<Arc<ShardView>> {
        // every failure names the shard and its file: under failure
        // isolation one bad shard fails its jobs, not the process, and
        // the operator needs to know which file to look at
        let path = self.dir.shard_path(shard_id);
        (|| -> Result<Arc<ShardView>> {
            if let Some(v) = self.cache.get(shard_id)? {
                return Ok(v);
            }
            let buf = self.disk.read_file_aligned_pooled(&path, &self.buf_pool)?;
            // the decode-once lifecycle's single CRC verification
            let view = Arc::new(ShardView::parse(buf)?);
            self.cache.note_crc_verified();
            // hand the parsed view over so mode 1 doesn't re-parse and
            // compressed modes seed their decode memo
            self.cache.admit_with(shard_id, view.bytes(), &view);
            Ok(view)
        })()
        .with_context(|| format!("shard {shard_id} ({})", path.display()))
    }
}

/// The [`ShardSource`] plug-in exposing a [`VswEngine`] to the shared
/// execution core.
struct VswSource<'e> {
    eng: &'e VswEngine,
}

impl ShardSource for VswSource<'_> {
    type Item = Arc<ShardView>;

    fn schedule(&self, _iteration: u32, active: &[VertexId]) -> (Vec<u32>, u32) {
        let eng = self.eng;
        let n = eng.prop.num_vertices as usize;
        let active_ratio = active.len() as f64 / n.max(1) as f64;
        // Algorithm 2 line 5: only pay the Bloom probes when the active
        // set is small enough for skipping to plausibly win.
        let selective_on = eng.cfg.selective && active_ratio < eng.cfg.active_threshold;
        schedule::shard_worklist(
            &eng.blooms,
            eng.prop.num_shards as usize,
            active,
            selective_on,
        )
    }

    fn load(&self, id: u32) -> Result<Arc<ShardView>> {
        self.eng.load_shard(id)
    }

    fn unit_edges(&self, _id: u32, item: &Arc<ShardView>) -> u64 {
        item.num_edges() as u64
    }

    fn unit_bytes(&self, _id: u32, item: &Arc<ShardView>) -> u64 {
        item.size_bytes() as u64
    }

    /// Execute one decoded shard: write its interval of dst and mark
    /// activated vertices in the shared bitset.
    fn compute(
        &self,
        id: u32,
        shard: Arc<ShardView>,
        ctx: &IterCtx<'_>,
        dst: &SharedDst,
        marker: &mut RangeMarker<'_>,
        _scratch: &mut Scratch<'_>,
    ) -> Result<UnitOutput> {
        let (a, b) = self.eng.prop.intervals[id as usize];
        debug_assert_eq!(shard.start_vertex(), a);
        let rows = (b - a) as usize;
        // SAFETY: shard intervals are disjoint (prep::compute_intervals
        // invariant, verified by its tests + the debug registry).
        let mut out = unsafe { dst.claim(a as usize, rows) };
        match &self.eng.cfg.backend {
            Backend::Native => native_update(ctx, shard.csr_ref(), a, out.rb()),
            Backend::Pjrt(exe) => match out.rb() {
                LaneSliceMut::F32(o) => pjrt_update(ctx, exe, &shard, o)?,
                other => anyhow::bail!(
                    "PJRT backend supports f32 lanes only (got {}); use --backend native",
                    other.lane_type().name()
                ),
            },
        }
        crate::exec::mark_interval(ctx, a, out.shared(), marker);
        Ok(UnitOutput::InPlace)
    }

    fn residency_bytes(&self) -> u64 {
        self.eng.memory_account().total()
    }
}

/// Native shard update: the paper's `Update` loop over the shard CSR,
/// generalized over [`crate::apps::ShardKernel`] and monomorphized by
/// [`crate::exec::kernel::fold_csr`] — the (combine × gather) pair is
/// dispatched once per shard, so the per-edge loop is branch-free and
/// row combines run through the chunked multi-lane accumulators (PR 7).
/// `out` must enter holding the current values of the shard's interval
/// `[start_vertex, ..)`.
///
/// Sum kernels read the iteration's pre-folded `contrib` array (one
/// gather + one add per edge); monotone kernels fold from the old value.
/// Bit-identical to [`crate::exec::fold_edges_interval`] over the same
/// per-destination edge order (canonically: ascending source id) — both
/// use the same fixed chunked-reduction scheme, which is also why the
/// cross-engine gates stay exact while comparisons against *sequential*
/// references (dense sweeps) need a small epsilon for sum kernels.
pub fn native_update(ctx: &IterCtx<'_>, csr: CsrRef<'_>, start_vertex: u32, out: LaneSliceMut<'_>) {
    crate::exec::kernel::fold_csr(ctx, csr, start_vertex, out);
}

/// PJRT shard update: expand CSR to (col, seg, w) chunks within the
/// artifact's static capacities and combine partial results.  Affine sum
/// kernels run the `pagerank` artifact (base mass added natively at the
/// end, so PPR's reset vector works unchanged); min-relaxations run
/// `relax_min`.  Max kernels (widest path) have no AOT artifact yet.
pub fn pjrt_update(
    ctx: &IterCtx<'_>,
    exe: &ShardExecutor,
    shard: &ShardView,
    out: &mut [f32],
) -> Result<()> {
    let kernel = ctx.kernel;
    let rows = shard.rows();
    let ro = shard.row_offsets();
    let col = shard.col();
    let weights = shard.weights();

    // For affine sum kernels we accumulate raw scaled Σ terms (base
    // passed as 0) and add the per-vertex base mass once at the end.
    let base = match kernel.apply {
        Apply::Affine { base, .. } => {
            out.fill(0.0);
            Some(base)
        }
        Apply::MeetOld => {
            anyhow::ensure!(
                kernel.combine == Combine::Min,
                "no AOT artifact for {:?} relaxations; use --backend native",
                kernel.combine
            );
            None
        }
        Apply::Threshold { .. } => {
            anyhow::bail!("no AOT artifact for k-core thresholds; use --backend native")
        }
    };

    // Chunk rows so each call fits (rc rows, ec edges).  A single row
    // wider than ec is split across calls (partials combine exactly for
    // both sum and min).
    let mut row_start = 0usize;
    while row_start < rows {
        let mut row_end = row_start;
        // grow the row window up to rc rows / ec edges
        while row_end < rows
            && row_end - row_start < exe.rc
            && (ro[row_end + 1] - ro[row_start]) as usize <= exe.ec
        {
            row_end += 1;
        }
        if row_end == row_start {
            // single row with more than ec edges: stream it in ec slices
            let lo = ro[row_start] as usize;
            let hi = ro[row_start + 1] as usize;
            let mut off = lo;
            while off < hi {
                let take = (hi - off).min(exe.ec);
                let cols: Vec<u32> = col[off..off + take].to_vec();
                let segs = vec![0u32; take];
                run_chunk(
                    ctx, exe, &cols, &segs, weights.map(|w| &w[off..off + take]),
                    &mut out[row_start..row_start + 1],
                )?;
                off += take;
            }
            row_start += 1;
            continue;
        }
        let lo = ro[row_start] as usize;
        let hi = ro[row_end] as usize;
        let cols: Vec<u32> = col[lo..hi].to_vec();
        let mut segs: Vec<u32> = Vec::with_capacity(hi - lo);
        for r in row_start..row_end {
            for _ in ro[r] as usize..ro[r + 1] as usize {
                segs.push((r - row_start) as u32);
            }
        }
        run_chunk(
            ctx, exe, &cols, &segs, weights.map(|w| &w[lo..hi]),
            &mut out[row_start..row_end],
        )?;
        row_start = row_end;
    }

    if let Some(base) = base {
        for (r, o) in out.iter_mut().enumerate() {
            *o += base.at(shard.start_vertex() + r as u32, ctx.num_vertices);
        }
    }
    Ok(())
}

fn run_chunk(
    ctx: &IterCtx<'_>,
    exe: &ShardExecutor,
    cols: &[u32],
    segs: &[u32],
    weights: Option<&[f32]>,
    out: &mut [f32],
) -> Result<()> {
    match ctx.kernel.apply {
        Apply::Affine { .. } => {
            let w = vec![1.0f32; cols.len()];
            let part =
                exe.pagerank(ctx.src.f32s(), ctx.inv_out_deg, cols, segs, &w, 0.0, out.len())?;
            for (o, p) in out.iter_mut().zip(part) {
                *o += p;
            }
        }
        Apply::MeetOld => {
            let cost = match ctx.kernel.gather {
                crate::apps::EdgeGather::AddCost(c) => c,
                g => anyhow::bail!("unsupported PJRT gather {g:?}"),
            };
            let w: Vec<f32> = match weights {
                Some(ws) => ws.iter().map(|&x| cost.apply(x)).collect(),
                None => vec![cost.apply(1.0); cols.len()],
            };
            let part = exe.relax_min(ctx.src.f32s(), cols, segs, &w, out)?;
            out.copy_from_slice(&part);
        }
        Apply::Threshold { .. } => {
            anyhow::bail!("no AOT artifact for k-core thresholds; use --backend native")
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Cc, PageRank, Ppr, ShardKernel, Sssp, Widest};
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::{Csr, Edge, EdgeList};
    use crate::prep::{preprocess_into, PrepConfig};
    use crate::storage::disk::DiskProfile;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("graphmp_engine_{name}"))
    }

    fn open_engine(
        g: &EdgeList,
        name: &str,
        cfg: EngineConfig,
        weighted: bool,
    ) -> (VswEngine, Disk) {
        let root = tmp(name);
        let _ = std::fs::remove_dir_all(&root);
        let disk = Disk::unthrottled();
        let prep = PrepConfig { edges_per_shard: 2048, weighted, ..Default::default() };
        let (dir, _) = preprocess_into(g, &root, &disk, prep).unwrap();
        let e = VswEngine::open(&dir, &disk, cfg).unwrap();
        (e, disk)
    }

    fn dense_pagerank(g: &EdgeList, iters: u32) -> Vec<f32> {
        let n = g.num_vertices as usize;
        let outd = g.out_degrees();
        let mut ranks = vec![1.0f32 / n as f32; n];
        for _ in 0..iters {
            let mut next = vec![0.15f32 / n as f32; n];
            for e in &g.edges {
                next[e.dst as usize] +=
                    0.85 * ranks[e.src as usize] / outd[e.src as usize] as f32;
            }
            ranks = next;
        }
        ranks
    }

    #[test]
    fn pagerank_matches_dense_reference() {
        let g = rmat(9, 6_000, 31, RmatParams::default());
        let (mut e, _) = open_engine(&g, "pr_ref", EngineConfig::default(), false);
        let (vals, run) = e.run_to_values(&PageRank::new(), 10).unwrap();
        let want = dense_pagerank(&g, 10);
        // relative gate: the engine's chunked row sums reassociate f32
        // adds, so high-degree vertices drift from the sequential dense
        // reference by a few ulps per iteration (see exec::kernel docs)
        for (i, (a, b)) in vals.f32s().iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1e-3), "vertex {i}: {a} vs {b}");
        }
        assert_eq!(run.iterations.len(), 10);
    }

    #[test]
    fn sssp_matches_bellman_ford() {
        let g = rmat(8, 3_000, 37, RmatParams::default());
        let (mut e, _) = open_engine(&g, "sssp_ref", EngineConfig::default(), true);
        let (vals, run) = e.run_to_values(&Sssp::new(0), 100).unwrap();
        // reference
        let n = g.num_vertices as usize;
        let mut ref_d = vec![f32::INFINITY; n];
        ref_d[0] = 0.0;
        loop {
            let mut changed = false;
            for edge in &g.edges {
                let cand = ref_d[edge.src as usize] + edge.weight;
                if cand < ref_d[edge.dst as usize] {
                    ref_d[edge.dst as usize] = cand;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        assert_eq!(vals, ref_d);
        assert!(run.converged, "SSSP should converge");
    }

    #[test]
    fn cc_converges_to_min_labels() {
        let g = rmat(8, 2_000, 41, RmatParams::default()).to_undirected();
        let (mut e, _) = open_engine(&g, "cc_ref", EngineConfig::default(), false);
        let (vals, run) = e.run_to_values(&Cc, 200).unwrap();
        assert!(run.converged);
        // union-find reference
        let n = g.num_vertices as usize;
        let mut parent: Vec<usize> = (0..n).collect();
        fn find(p: &mut Vec<usize>, x: usize) -> usize {
            let mut x = x;
            while p[x] != x {
                p[x] = p[p[x]];
                x = p[x];
            }
            x
        }
        for edge in &g.edges {
            let (a, b) = (
                find(&mut parent, edge.src as usize),
                find(&mut parent, edge.dst as usize),
            );
            if a != b {
                parent[a.max(b)] = a.min(b);
            }
        }
        // min label within each component
        let mut min_label = vec![u32::MAX; n];
        for v in 0..n {
            let root = find(&mut parent, v);
            min_label[root] = min_label[root].min(v as u32);
        }
        for v in 0..n {
            let root = find(&mut parent, v);
            assert_eq!(vals.f32s()[v] as u32, min_label[root], "vertex {v}");
        }
    }

    #[test]
    fn ppr_mass_concentrates_near_seed() {
        let g = rmat(9, 5_000, 33, RmatParams::default());
        let (mut e, _) = open_engine(&g, "ppr_ref", EngineConfig::default(), false);
        let seed = 3u32;
        let (vals, _) = e.run_to_values(&Ppr::new(seed), 20).unwrap();
        // dense reference
        let n = g.num_vertices as usize;
        let outd = g.out_degrees();
        let mut ranks = vec![0.0f32; n];
        ranks[seed as usize] = 1.0;
        for _ in 0..20 {
            let mut next = vec![0.0f32; n];
            next[seed as usize] = 0.15;
            // dangling vertices drop their mass, as in the engine
            for edge in &g.edges {
                next[edge.dst as usize] +=
                    0.85 * ranks[edge.src as usize] / outd[edge.src as usize].max(1) as f32;
            }
            ranks = next;
        }
        // relative gate for the same reason as pagerank_matches_dense_reference:
        // chunked row sums vs a sequential edge-order reference
        for (i, (a, b)) in vals.f32s().iter().zip(&ranks).enumerate() {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1e-3), "vertex {i}: {a} vs {b}");
        }
        // the seed holds the teleport mass
        assert!(vals.f32s()[seed as usize] >= 0.15 - 1e-6);
    }

    #[test]
    fn widest_path_matches_dense_relaxation() {
        let g = rmat(8, 3_000, 39, RmatParams::default());
        let (mut e, _) = open_engine(&g, "widest_ref", EngineConfig::default(), true);
        let (vals, run) = e.run_to_values(&Widest::new(0), 200).unwrap();
        assert!(run.converged);
        let n = g.num_vertices as usize;
        let mut width = vec![0.0f32; n];
        width[0] = f32::INFINITY;
        loop {
            let mut changed = false;
            for edge in &g.edges {
                let cand = width[edge.src as usize].min(edge.weight);
                if cand > width[edge.dst as usize] {
                    width[edge.dst as usize] = cand;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        assert_eq!(vals, width);
    }

    #[test]
    fn selective_scheduling_skips_shards_and_preserves_results() {
        let g = rmat(9, 5_000, 43, RmatParams::default());
        // 512-vertex test graph: the paper's 1e-3 threshold would never
        // trigger (it means "<0.5 active vertices"), so scale it up.
        let sel = EngineConfig { selective: true, active_threshold: 0.05, ..Default::default() };
        let nsel = EngineConfig { selective: false, ..Default::default() };
        let (mut e1, _) = open_engine(&g, "sel_on", sel, true);
        let (mut e2, _) = open_engine(&g, "sel_off", nsel, true);
        let (v1, r1) = e1.run_to_values(&Sssp::new(0), 60).unwrap();
        let (v2, _) = e2.run_to_values(&Sssp::new(0), 60).unwrap();
        assert_eq!(v1, v2, "selective scheduling changed results");
        let skipped: u32 = r1.iterations.iter().map(|m| m.shards_skipped).sum();
        assert!(skipped > 0, "expected some skipped shards in SSSP");
    }

    #[test]
    fn cache_hits_eliminate_disk_reads() {
        let g = rmat(9, 5_000, 47, RmatParams::default());
        let cfg = EngineConfig {
            cache_mode: Some(CacheMode::M3Zlib1),
            cache_capacity: 64 << 20,
            selective: false,
            ..Default::default()
        };
        let (mut e, disk) = open_engine(&g, "cache_hits", cfg, false);
        disk.reset();
        let run = e.run(&PageRank::new(), 5).unwrap();
        // iteration 0 loads everything from disk; afterwards all hits
        let first = &run.iterations[0];
        assert!(first.io.bytes_read > 0);
        let later_reads: u64 = run.iterations[1..].iter().map(|m| m.io.bytes_read).sum();
        assert_eq!(later_reads, 0, "cached run must not re-read shards");
        let later_hits: u64 = run.iterations[1..].iter().map(|m| m.cache.hits).sum();
        assert!(later_hits > 0);
    }

    #[test]
    fn mode0_reads_every_iteration() {
        let g = rmat(8, 3_000, 53, RmatParams::default());
        let cfg = EngineConfig {
            cache_mode: Some(CacheMode::M0None),
            selective: false,
            ..Default::default()
        };
        let (mut e, disk) = open_engine(&g, "mode0", cfg, false);
        disk.reset();
        let run = e.run(&PageRank::new(), 3).unwrap();
        for m in &run.iterations {
            assert!(m.io.bytes_read > 0, "mode0 must hit disk each iteration");
        }
    }

    #[test]
    fn mode0_recycles_pooled_read_buffers() {
        let g = rmat(8, 3_000, 57, RmatParams::default());
        let cfg = EngineConfig {
            cache_mode: Some(CacheMode::M0None),
            selective: false,
            ..Default::default()
        };
        let (mut e, _) = open_engine(&g, "mode0_pool", cfg, false);
        e.run(&PageRank::new(), 4).unwrap();
        let (reused, _fresh) = e.buf_pool().stats();
        assert!(reused > 0, "steady-state mode-0 reads must reuse buffers");
        // idle pooled capacity is charged to the memory account
        assert!(e.memory_account().other > 0, "idle pool bytes must be accounted");
    }

    #[test]
    fn scan_shared_batch_matches_solo_runs_and_amortizes_loads() {
        let g = rmat(9, 5_000, 97, RmatParams::default());
        let mk = |name: &str| open_engine(&g, name, EngineConfig::default(), false).0;
        let (v_pr_solo, r_pr_solo) =
            mk("batch_solo_pr").run_to_values(&PageRank::new(), 5).unwrap();
        let (v_ppr_solo, _) = mk("batch_solo_ppr").run_to_values(&Ppr::new(3), 5).unwrap();
        let mut e = mk("batch_both");
        let (mut outs, batch) = e
            .run_jobs(&[
                BatchJob { app: &PageRank::new(), max_iters: 5 },
                BatchJob { app: &Ppr::new(3), max_iters: 5 },
            ])
            .unwrap();
        assert_eq!(outs.len(), 2);
        let (v_ppr, r_ppr) = outs.pop().unwrap();
        let (v_pr, r_pr) = outs.pop().unwrap();
        assert_eq!(v_pr, v_pr_solo, "batched PageRank diverged from solo");
        assert_eq!(v_ppr, v_ppr_solo, "batched PPR diverged from solo");
        assert_eq!(r_pr.iterations.len(), r_pr_solo.iterations.len());
        assert_eq!(r_ppr.iterations.len(), 5);
        // both jobs sweep every shard, so each load serves both
        assert!(
            (batch.shard_loads_amortized() - 2.0).abs() < 1e-9,
            "expected 2x amortization, got {}",
            batch.shard_loads_amortized()
        );
        for m in &r_pr.iterations {
            assert_eq!(m.jobs_in_pass, 2);
            assert_eq!(m.shard_servings, 2 * m.shards_processed);
        }
    }

    #[test]
    fn multi_worker_equals_single_worker() {
        let g = rmat(9, 6_000, 59, RmatParams::default());
        let one = EngineConfig { workers: 1, ..Default::default() };
        let four = EngineConfig { workers: 4, ..Default::default() };
        let (mut e1, _) = open_engine(&g, "w1", one, false);
        let (mut e4, _) = open_engine(&g, "w4", four, false);
        let (v1, _) = e1.run_to_values(&PageRank::new(), 5).unwrap();
        let (v4, _) = e4.run_to_values(&PageRank::new(), 5).unwrap();
        assert_eq!(v1, v4, "worker count changed results (lock-free claim bug?)");
    }

    #[test]
    fn pipelined_equals_inline_loading() {
        let g = rmat(9, 6_000, 67, RmatParams::default());
        let seq = EngineConfig { workers: 1, prefetch_depth: 0, ..Default::default() };
        let pipe = EngineConfig {
            workers: 4,
            prefetch_depth: 3,
            prefetch_threads: 2,
            ..Default::default()
        };
        let (mut e1, _) = open_engine(&g, "pipe_seq", seq, false);
        let (mut e2, _) = open_engine(&g, "pipe_on", pipe, false);
        let (v1, _) = e1.run_to_values(&PageRank::new(), 6).unwrap();
        let (v2, _) = e2.run_to_values(&PageRank::new(), 6).unwrap();
        assert_eq!(v1, v2, "prefetch pipeline changed results");
    }

    #[test]
    fn adaptive_prefetch_matches_fixed_depth_results() {
        let g = rmat(9, 6_000, 69, RmatParams::default());
        let fixed = EngineConfig { prefetch_depth: 4, ..Default::default() };
        let auto = EngineConfig { prefetch_auto: true, ..Default::default() };
        let (mut e1, _) = open_engine(&g, "auto_fixed", fixed, false);
        let (mut e2, _) = open_engine(&g, "auto_on", auto, false);
        let (v1, _) = e1.run_to_values(&PageRank::new(), 6).unwrap();
        let (v2, r2) = e2.run_to_values(&PageRank::new(), 6).unwrap();
        assert_eq!(v1, v2, "adaptive depth changed results");
        for m in &r2.iterations {
            assert!(
                (1..=crate::exec::MAX_AUTO_DEPTH as u32).contains(&m.prefetch_depth_used),
                "iter {}: depth {} out of bounds",
                m.iteration,
                m.prefetch_depth_used
            );
        }
    }

    #[test]
    fn pipeline_counters_are_consistent() {
        let g = rmat(9, 5_000, 71, RmatParams::default());
        let cfg = EngineConfig {
            selective: false,
            cache_mode: Some(CacheMode::M0None),
            ..Default::default()
        };
        let (mut e, _) = open_engine(&g, "pipe_ctr", cfg, false);
        let run = e.run(&PageRank::new(), 3).unwrap();
        for m in &run.iterations {
            assert!(m.shards_processed > 0);
            assert_eq!(m.shards_prefetched, m.shards_processed);
            assert_eq!(m.ready_hits + m.ready_misses, m.shards_processed);
            assert_eq!(m.shards_skipped, 0);
        }
    }

    #[test]
    fn overlap_accounting_matches_prefetch_mode() {
        let g = rmat(9, 5_000, 73, RmatParams::default());
        let mk = |prefetch_depth: usize, name: &str| {
            let root = tmp(name);
            let _ = std::fs::remove_dir_all(&root);
            let disk = Disk::new(DiskProfile::hdd_raid5());
            let prep = PrepConfig { edges_per_shard: 2048, weighted: false, ..Default::default() };
            let (dir, _) = preprocess_into(&g, &root, &disk, prep).unwrap();
            let cfg = EngineConfig {
                cache_mode: Some(CacheMode::M0None),
                selective: false,
                prefetch_depth,
                ..Default::default()
            };
            VswEngine::open(&dir, &disk, cfg).unwrap()
        };
        let run_on = mk(4, "ov_on").run(&PageRank::new(), 2).unwrap();
        for m in &run_on.iterations {
            assert!(m.sim_disk_seconds > 0.0, "HDD profile must charge sim time");
            assert!(m.overlapped_sim_seconds > 0.0, "pipeline must overlap sim disk");
            assert!(m.overlapped_sim_seconds <= m.sim_disk_seconds + 1e-12);
            assert!(m.elapsed_seconds() >= m.wall.as_secs_f64() - 1e-12);
        }
        assert!(run_on.total_overlapped_sim_seconds > 0.0);
        let run_off = mk(0, "ov_off").run(&PageRank::new(), 2).unwrap();
        for m in &run_off.iterations {
            assert_eq!(m.overlapped_sim_seconds, 0.0, "no overlap without prefetch");
            assert_eq!(m.shards_prefetched, 0);
        }
    }

    #[test]
    fn compressed_hits_decode_at_most_once_per_iteration() {
        let g = rmat(9, 5_000, 79, RmatParams::default());
        // generous memo budget: steady-state hits must not decode at all
        let cfg = EngineConfig {
            cache_mode: Some(CacheMode::M3Zlib1),
            cache_capacity: 64 << 20,
            selective: false,
            ..Default::default()
        };
        let (mut e, _) = open_engine(&g, "decode_once", cfg, false);
        let run = e.run(&PageRank::new(), 4).unwrap();
        for m in &run.iterations {
            assert!(
                m.cache.decodes <= m.shards_processed as u64,
                "iter {}: {} decodes for {} shards",
                m.iteration,
                m.cache.decodes,
                m.shards_processed
            );
        }
        let steady: u64 = run.iterations[1..].iter().map(|m| m.cache.decodes).sum();
        assert_eq!(steady, 0, "memo budget must eliminate steady-state re-parses");
        let skips: u64 = run.iterations[1..].iter().map(|m| m.cache.decode_skips).sum();
        assert!(skips > 0);

        // without a memo budget the decode count is still bounded by one
        // per scheduled shard per iteration (prefetcher decodes, worker
        // reuses)
        let cfg0 = EngineConfig {
            cache_mode: Some(CacheMode::M3Zlib1),
            cache_capacity: 64 << 20,
            selective: false,
            decode_memo_budget: 0,
            ..Default::default()
        };
        let (mut e0, _) = open_engine(&g, "decode_once0", cfg0, false);
        let run0 = e0.run(&PageRank::new(), 4).unwrap();
        for m in &run0.iterations[1..] {
            assert_eq!(m.cache.decodes, m.shards_processed as u64);
        }
    }

    #[test]
    fn steady_state_decode_path_is_allocation_and_verify_free() {
        // The zero-copy acceptance gate: with a compressed cache and a
        // generous decode memo, every steady-state shard serving must be
        // an Arc clone — zero decodes (no inflate, no parse, no fresh
        // Vecs) and zero CRC passes.  The counters are the proxy: a
        // decode or a verify is exactly where the old path allocated.
        let g = rmat(9, 5_000, 91, RmatParams::default());
        let cfg = EngineConfig {
            cache_mode: Some(CacheMode::M3Zlib1),
            cache_capacity: 64 << 20,
            selective: false,
            ..Default::default()
        };
        let (mut e, _) = open_engine(&g, "zero_decode", cfg, false);
        let run = e.run(&PageRank::new(), 4).unwrap();
        let fill = &run.iterations[0];
        assert_eq!(
            fill.cache.crc_verifies, fill.shards_processed as u64,
            "first load verifies each shard exactly once"
        );
        for m in &run.iterations[1..] {
            assert_eq!(m.cache.decodes, 0, "iter {}: decoded on the hot path", m.iteration);
            assert_eq!(
                m.cache.crc_verifies, 0,
                "iter {}: re-verified on the hot path",
                m.iteration
            );
            assert_eq!(
                m.cache.crc_verifies_skipped, m.shards_processed as u64,
                "iter {}: every serving must be a verified-bytes Arc clone",
                m.iteration
            );
            assert_eq!(m.io.bytes_read, 0);
        }
    }

    #[test]
    fn integer_apps_match_their_oracles_on_vsw() {
        use crate::apps::{oracle, BfsLevels, KCore, Wcc};
        let g = rmat(8, 3_000, 101, RmatParams::default()).to_undirected();
        let n = g.num_vertices;
        let (mut e, _) = open_engine(&g, "int_apps", EngineConfig::default(), false);
        let (wcc, r) = e.run_to_values(&Wcc, 200).unwrap();
        assert!(r.converged);
        assert_eq!(wcc.u32s(), oracle::wcc_labels(&g.edges, n).as_slice());
        let (lv, r) = e.run_to_values(&BfsLevels::new(0), 200).unwrap();
        assert!(r.converged);
        assert_eq!(lv.u32s(), oracle::bfs_levels(&g.edges, n, 0).as_slice());
        let (kc, r) = e.run_to_values(&KCore::new(3), 200).unwrap();
        assert!(r.converged);
        assert_eq!(kc.u32s(), oracle::kcore(&g.edges, n, 3).as_slice());
        // the decomposition actually discriminates on this graph
        let inside = kc.u32s().iter().filter(|&&x| x != 0).count();
        assert!(inside > 0 && inside < n as usize, "degenerate 3-core: {inside}/{n}");
    }

    #[test]
    fn rejects_weighted_app_on_unweighted_dir() {
        let g = rmat(8, 1_000, 61, RmatParams::default());
        let (mut e, _) = open_engine(&g, "wreject", EngineConfig::default(), false);
        assert!(e.run(&Sssp::new(0), 5).is_err());
        assert!(e.run(&Widest::new(0), 5).is_err());
    }

    #[test]
    fn run_and_run_to_values_report_identical_metrics() {
        let g = rmat(9, 4_000, 83, RmatParams::default());
        let (mut e1, _) = open_engine(&g, "dedup_run", EngineConfig::default(), false);
        let (mut e2, _) = open_engine(&g, "dedup_rtv", EngineConfig::default(), false);
        let r1 = e1.run(&PageRank::new(), 4).unwrap();
        let (_, r2) = e2.run_to_values(&PageRank::new(), 4).unwrap();
        assert_eq!(r1.iterations.len(), r2.iterations.len());
        assert_eq!(
            r1.iterations
                .iter()
                .map(|m| m.shards_processed)
                .collect::<Vec<_>>(),
            r2.iterations
                .iter()
                .map(|m| m.shards_processed)
                .collect::<Vec<_>>()
        );
        assert!((r1.total_sim_disk_seconds - r2.total_sim_disk_seconds).abs() < 1e-9);
    }

    #[test]
    fn native_update_pagerank_basic() {
        // 2 vertices, edges 0->1 and 1->0
        let edges = vec![Edge::new(0, 1), Edge::new(1, 0)];
        let csr = Csr::from_edges(&edges, 0, 2, false);
        let src = vec![0.5f32, 0.5];
        let inv = vec![1.0f32, 1.0];
        let contrib: Vec<f32> = src.iter().zip(&inv).map(|(&v, &d)| v * d).collect();
        let ctx = IterCtx {
            kernel: ShardKernel::pagerank(0.85),
            num_vertices: 2,
            src: (&src).into(),
            inv_out_deg: &inv,
            contrib: &contrib,
            iteration: 0,
        };
        let mut out = src.clone();
        native_update(&ctx, csr.slices(), 0, (&mut out).into());
        let base = 0.15 / 2.0;
        assert!((out[0] - (base + 0.85 * 0.5)).abs() < 1e-6);
        assert!((out[1] - (base + 0.85 * 0.5)).abs() < 1e-6);
    }
}
