//! ESG — X-Stream's edge-centric scatter-gather engine (§3.2).
//!
//! Vertices are split into P partitions; the edge list of a partition
//! holds all edges whose *source* lies in it.  Each iteration runs two
//! phases: (1) scatter — stream out-edges, generate updates to disk
//! (read `C|V| + D|E|`, write `C|E|`); (2) gather — stream updates, apply
//! to vertex values (read `C|E|`, write `C|V|`).  Only one partition's
//! vertices are resident: `C|V|/P`.

use std::time::Instant;

use anyhow::Result;

use crate::apps::{ShardCompute, VertexProgram};
use crate::graph::{Edge, EdgeList};
use crate::metrics::{IterationMetrics, RunMetrics};
use crate::storage::disk::Disk;

use super::{count_updates, inv_out_degrees, BaselineConfig, BaselineEngine, C_VERTEX, D_EDGE};

/// An in-flight update record (dst, value) — the C-sized "update" of §3.2.
#[derive(Clone, Copy, Debug)]
struct Update {
    dst: u32,
    val: f32,
}

pub struct EsgEngine {
    cfg: BaselineConfig,
    /// Partition p holds edges with source in its vertex range.
    partitions: Vec<Vec<Edge>>,
    num_vertices: u32,
    num_edges: u64,
    inv_out_deg: Vec<f32>,
    values: Vec<f32>,
}

impl EsgEngine {
    pub fn new(cfg: BaselineConfig) -> Self {
        EsgEngine {
            cfg,
            partitions: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            inv_out_deg: Vec::new(),
            values: Vec::new(),
        }
    }
}

impl BaselineEngine for EsgEngine {
    fn name(&self) -> &'static str {
        "xstream-esg"
    }

    fn preprocess(&mut self, g: &EdgeList, disk: &Disk) -> Result<f64> {
        let t = Instant::now();
        let sim0 = disk.snapshot().sim_nanos;
        // one streaming pass: read edges, append to partition files — no
        // sorting, no index (X-Stream's whole preprocessing, 2D|E|)
        let de = D_EDGE * g.num_edges();
        disk.account_read(de);
        disk.account_write(de);
        let p = self.cfg.p.max(1);
        let span = g.num_vertices.div_ceil(p);
        let mut partitions: Vec<Vec<Edge>> = vec![Vec::new(); p as usize];
        for e in &g.edges {
            partitions[(e.src / span) as usize].push(*e);
        }
        self.partitions = partitions;
        self.num_vertices = g.num_vertices;
        self.num_edges = g.num_edges();
        self.inv_out_deg = inv_out_degrees(g);
        let sim = (disk.snapshot().sim_nanos - sim0) as f64 / 1e9;
        Ok(t.elapsed().as_secs_f64() + sim)
    }

    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics> {
        anyhow::ensure!(!self.partitions.is_empty(), "preprocess first");
        let n = self.num_vertices;
        let (mut vals, _) = app.init(n);
        let mut run = RunMetrics::default();
        let start = Instant::now();
        let sim_start = disk.snapshot().sim_nanos;
        for iter in 0..iters {
            let t0 = Instant::now();
            let io0 = disk.snapshot();
            // ---- phase 1: scatter (stream edges, emit updates) ----------
            let mut updates: Vec<Update> = Vec::new();
            for part in &self.partitions {
                disk.account_read(C_VERTEX * n as u64 / self.partitions.len() as u64);
                disk.account_read(D_EDGE * part.len() as u64);
                match app.compute() {
                    ShardCompute::PageRankSum { .. } => {
                        for e in part {
                            updates.push(Update {
                                dst: e.dst,
                                val: vals[e.src as usize] * self.inv_out_deg[e.src as usize],
                            });
                        }
                    }
                    ShardCompute::RelaxMin { cost } => {
                        for e in part {
                            updates.push(Update {
                                dst: e.dst,
                                val: vals[e.src as usize] + cost.apply(e.weight),
                            });
                        }
                    }
                }
                disk.account_write(C_VERTEX * part.len() as u64); // update stream
            }
            // ---- phase 2: gather (stream updates, fold into vertices) ---
            disk.account_read(C_VERTEX * updates.len() as u64);
            let dst = match app.compute() {
                ShardCompute::PageRankSum { damping } => {
                    let base = (1.0 - damping) / n as f32;
                    let mut sum = vec![0.0f32; n as usize];
                    for u in &updates {
                        sum[u.dst as usize] += u.val;
                    }
                    sum.iter().map(|s| base + damping * s).collect::<Vec<f32>>()
                }
                ShardCompute::RelaxMin { .. } => {
                    let mut out = vals.clone();
                    for u in &updates {
                        if u.val < out[u.dst as usize] {
                            out[u.dst as usize] = u.val;
                        }
                    }
                    out
                }
            };
            disk.account_write(C_VERTEX * n as u64);
            let active = count_updates(app, &vals, &dst);
            vals = dst;
            let io1 = disk.snapshot();
            run.iterations.push(IterationMetrics {
                iteration: iter,
                wall: t0.elapsed(),
                sim_disk_seconds: (io1.sim_nanos - io0.sim_nanos) as f64 / 1e9,
                active_vertices: active,
                active_ratio: active as f64 / n.max(1) as f64,
                shards_processed: self.partitions.len() as u32,
                shards_skipped: 0,
                io: io1.since(&io0),
                cache: Default::default(),
                ..Default::default()
            });
            if active == 0 {
                run.converged = true;
                break;
            }
        }
        run.total_wall = start.elapsed();
        run.total_sim_disk_seconds = (disk.snapshot().sim_nanos - sim_start) as f64 / 1e9;
        run.memory_bytes = self.memory_bytes();
        self.values = vals;
        Ok(run)
    }

    fn values(&self) -> &[f32] {
        &self.values
    }

    fn memory_bytes(&self) -> u64 {
        // C|V|/P — only one partition's vertex set resident
        C_VERTEX * self.num_vertices as u64 / self.partitions.len().max(1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::PageRank;
    use crate::graph::rmat::{rmat, RmatParams};

    #[test]
    fn esg_io_matches_table3() {
        let g = rmat(9, 4_000, 79, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = EsgEngine::new(BaselineConfig { p: 8, ..Default::default() });
        e.preprocess(&g, &disk).unwrap();
        disk.reset();
        let run = e.run(&PageRank::new(), 1, &disk).unwrap();
        let m = &run.iterations[0];
        let v = g.num_vertices as u64;
        let ed = g.num_edges();
        // read = C|V| + (C+D)|E| ; write = C|V| + C|E|
        let want_read = C_VERTEX * v + (C_VERTEX + D_EDGE) * ed;
        let want_write = C_VERTEX * v + C_VERTEX * ed;
        assert!(
            (m.io.bytes_read as i64 - want_read as i64).unsigned_abs() < C_VERTEX * v,
            "read {} vs {}",
            m.io.bytes_read,
            want_read
        );
        assert_eq!(m.io.bytes_written, want_write);
    }

    #[test]
    fn esg_prep_is_2de() {
        let g = rmat(8, 2_000, 83, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = EsgEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        let s = disk.snapshot();
        assert_eq!(s.bytes_read + s.bytes_written, 2 * D_EDGE * g.num_edges());
    }

    #[test]
    fn esg_pagerank_matches_sweep_reference() {
        let g = rmat(8, 2_000, 89, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = EsgEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        e.run(&PageRank::new(), 5, &disk).unwrap();
        // reference via shared sweep
        let inv = super::super::inv_out_degrees(&g);
        let (mut src, _) = PageRank::new().init(g.num_vertices);
        for _ in 0..5 {
            src = super::super::sweep(
                PageRank::new().compute(),
                &g.edges,
                g.num_vertices,
                &inv,
                &src,
            );
        }
        for (a, b) in e.values().iter().zip(&src) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
