//! ESG — X-Stream's edge-centric scatter-gather engine (§3.2).
//!
//! Vertices are split into P partitions; the edge list of a partition
//! holds all edges whose *source* lies in it.  Each iteration runs two
//! phases: (1) scatter — stream out-edges, generate updates to disk
//! (read `C|V| + D|E|`, write `C|E|`); (2) gather — stream updates, apply
//! to vertex values (read `C|E|`, write `C|V|`).  Only one partition's
//! vertices are resident: `C|V|/P`.
//!
//! Runs through the shared execution core: one pipeline unit per
//! partition whose compute is the scatter (producing an
//! [`UnitOutput::Updates`] stream); the core folds all streams at the
//! barrier in partition order — X-Stream's gather — and
//! [`ShardSource::end_iteration`] charges the gather's I/O.  Partitions
//! are sorted by source at preprocessing so the folded per-destination
//! order is the repo-wide canonical ascending-source order.

use std::time::Instant;

use anyhow::Result;

use crate::apps::VertexProgram;
use crate::exec::{
    ExecCore, IterCtx, LaneVec, RangeMarker, Scratch, ShardSource, SharedDst, UnitOutput,
};
use crate::graph::{Edge, EdgeList, VertexId};
use crate::metrics::RunMetrics;
use crate::storage::disk::Disk;

use super::{inv_out_degrees, BaselineConfig, BaselineEngine, C_VERTEX, D_EDGE};

pub struct EsgEngine {
    cfg: BaselineConfig,
    /// Partition p holds edges with source in its vertex range.
    partitions: Vec<Vec<Edge>>,
    num_vertices: u32,
    num_edges: u64,
    inv_out_deg: Vec<f32>,
    values: LaneVec,
}

impl EsgEngine {
    pub fn new(cfg: BaselineConfig) -> Self {
        EsgEngine {
            cfg,
            partitions: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            inv_out_deg: Vec::new(),
            values: LaneVec::from(Vec::<f32>::new()),
        }
    }
}

impl BaselineEngine for EsgEngine {
    fn name(&self) -> &'static str {
        "xstream-esg"
    }

    fn preprocess(&mut self, g: &EdgeList, disk: &Disk) -> Result<f64> {
        let t = Instant::now();
        let sim0 = disk.snapshot().sim_nanos;
        // one streaming pass: read edges, append to partition files — no
        // index (X-Stream's whole preprocessing, 2D|E|)
        let de = D_EDGE * g.num_edges();
        disk.account_read(de);
        disk.account_write(de);
        let p = self.cfg.p.max(1);
        let span = g.num_vertices.div_ceil(p);
        let mut partitions: Vec<Vec<Edge>> = vec![Vec::new(); p as usize];
        for e in &g.edges {
            partitions[(e.src / span) as usize].push(*e);
        }
        // canonical per-destination order for cross-engine bit-identity:
        // partitions cover ascending source ranges and are gathered in
        // partition order, so an in-partition source sort makes every
        // destination's updates arrive in ascending source order
        for part in &mut partitions {
            part.sort_unstable_by_key(|e| e.src);
        }
        self.partitions = partitions;
        self.num_vertices = g.num_vertices;
        self.num_edges = g.num_edges();
        self.inv_out_deg = inv_out_degrees(g);
        let sim = (disk.snapshot().sim_nanos - sim0) as f64 / 1e9;
        Ok(t.elapsed().as_secs_f64() + sim)
    }

    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics> {
        anyhow::ensure!(!self.partitions.is_empty(), "preprocess first");
        let source = EsgSource { eng: self, disk };
        let mut core = ExecCore::new(self.cfg.exec(), disk, None);
        let (vals, run) =
            core.run(&source, app, self.num_vertices, &self.inv_out_deg, iters)?;
        self.values = vals;
        Ok(run)
    }

    fn values_lane(&self) -> &LaneVec {
        &self.values
    }

    fn memory_bytes(&self) -> u64 {
        // C|V|/P — only one partition's vertex set resident
        C_VERTEX * self.num_vertices as u64 / self.partitions.len().max(1) as u64
    }
}

struct EsgSource<'e> {
    eng: &'e EsgEngine,
    disk: &'e Disk,
}

impl ShardSource for EsgSource<'_> {
    type Item = ();

    fn schedule(&self, _iteration: u32, _active: &[VertexId]) -> (Vec<u32>, u32) {
        // X-Stream streams every partition every iteration
        ((0..self.eng.partitions.len() as u32).collect(), 0)
    }

    fn load(&self, id: u32) -> Result<()> {
        // scatter phase input: the partition's vertex chunk + its edges
        let eng = self.eng;
        self.disk
            .account_read(C_VERTEX * eng.num_vertices as u64 / eng.partitions.len() as u64);
        self.disk
            .account_read(D_EDGE * eng.partitions[id as usize].len() as u64);
        Ok(())
    }

    fn unit_edges(&self, id: u32, _item: &()) -> u64 {
        self.eng.partitions[id as usize].len() as u64
    }

    /// Scatter: stream the partition's out-edges into an update stream —
    /// monomorphized gather, buffer reused through the scratch arena.
    fn compute(
        &self,
        id: u32,
        _item: (),
        ctx: &IterCtx<'_>,
        _dst: &SharedDst,
        _marker: &mut RangeMarker<'_>,
        scratch: &mut Scratch<'_>,
    ) -> Result<UnitOutput> {
        let part = &self.eng.partitions[id as usize];
        let mut updates = scratch.take_updates();
        crate::exec::kernel::scatter_list(ctx, part, &mut updates);
        self.disk.account_write(C_VERTEX * part.len() as u64); // update stream
        Ok(UnitOutput::Updates(updates))
    }

    /// Gather: the core folded the update streams; charge their re-read
    /// plus the vertex write-back.
    fn end_iteration(&self, _ctx: &IterCtx<'_>, updates_folded: u64) {
        self.disk.account_read(C_VERTEX * updates_folded);
        self.disk.account_write(C_VERTEX * self.eng.num_vertices as u64);
    }

    fn residency_bytes(&self) -> u64 {
        self.eng.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::PageRank;
    use crate::graph::rmat::{rmat, RmatParams};

    #[test]
    fn esg_io_matches_table3() {
        let g = rmat(9, 4_000, 79, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = EsgEngine::new(BaselineConfig { p: 8, ..Default::default() });
        e.preprocess(&g, &disk).unwrap();
        disk.reset();
        let run = e.run(&PageRank::new(), 1, &disk).unwrap();
        let m = &run.iterations[0];
        let v = g.num_vertices as u64;
        let ed = g.num_edges();
        // read = C|V| + (C+D)|E| ; write = C|V| + C|E|
        let want_read = C_VERTEX * v + (C_VERTEX + D_EDGE) * ed;
        let want_write = C_VERTEX * v + C_VERTEX * ed;
        assert!(
            (m.io.bytes_read as i64 - want_read as i64).unsigned_abs() < C_VERTEX * v,
            "read {} vs {}",
            m.io.bytes_read,
            want_read
        );
        assert_eq!(m.io.bytes_written, want_write);
    }

    #[test]
    fn esg_prep_is_2de() {
        let g = rmat(8, 2_000, 83, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = EsgEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        let s = disk.snapshot();
        assert_eq!(s.bytes_read + s.bytes_written, 2 * D_EDGE * g.num_edges());
    }

    #[test]
    fn esg_pagerank_matches_sweep_reference() {
        let g = rmat(8, 2_000, 89, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = EsgEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        e.run(&PageRank::new(), 5, &disk).unwrap();
        // reference via shared sweep
        let inv = super::super::inv_out_degrees(&g);
        let (init, _) = PageRank::new().init(g.num_vertices);
        let mut src = init.f32s().to_vec();
        for _ in 0..5 {
            src = super::super::sweep(
                PageRank::new().kernel(),
                &g.edges,
                g.num_vertices,
                &inv,
                &src,
            );
        }
        // relative gate: the barrier's chunked update sums reassociate
        // f32 adds vs the sequential sweep (see exec::kernel docs)
        for (a, b) in e.values().iter().zip(&src) {
            assert!((a - b).abs() <= 1e-5 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }
}
