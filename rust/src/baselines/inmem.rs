//! In-memory SpMV engine — the GraphMat stand-in (§4.3).
//!
//! Loads the whole graph into memory at application start (GraphMat sorts
//! edges and builds its SpMV structures during this loading phase — the
//! expensive step Fig 9 shows), then iterates entirely in RAM with zero
//! per-iteration disk I/O.  If the resident model (`C|V| + (C+D)|E|`
//! with construction overhead) exceeds the configured RAM budget, the run
//! fails with OOM — reproducing GraphMat's crashes on UK-2007/UK-2014/
//! EU-2015 under 128GB.
//!
//! Runs through the shared execution core as a single whole-graph unit:
//! the same pipeline, kernels and CSR row loop as the VSW engine
//! (`engine::native_update`), with edges sorted `(dst, src)` at load so
//! the per-destination fold order is the repo-wide canonical
//! ascending-source order.

use std::time::Instant;

use anyhow::Result;

use crate::apps::VertexProgram;
use crate::exec::{
    mark_interval, ExecCore, IterCtx, LaneVec, RangeMarker, Scratch, ShardSource, SharedDst,
    UnitOutput,
};
use crate::graph::{Csr, EdgeList, VertexId};
use crate::metrics::RunMetrics;
use crate::storage::disk::Disk;

use super::{inv_out_degrees, BaselineConfig, BaselineEngine, C_VERTEX, D_EDGE};

pub struct InMemEngine {
    cfg: BaselineConfig,
    csr: Option<Csr>,
    num_vertices: u32,
    num_edges: u64,
    inv_out_deg: Vec<f32>,
    values: LaneVec,
    /// Loading-phase seconds (Fig 9's data-loading bar).
    pub load_seconds: f64,
    /// Peak memory of the loading phase (GraphMat's sort roughly doubles
    /// the edge footprint transiently, Fig 9 shows 122GB for Twitter).
    pub load_peak_bytes: u64,
}

impl InMemEngine {
    pub fn new(cfg: BaselineConfig) -> Self {
        InMemEngine {
            cfg,
            csr: None,
            num_vertices: 0,
            num_edges: 0,
            inv_out_deg: Vec::new(),
            values: LaneVec::from(Vec::<f32>::new()),
            load_seconds: 0.0,
            load_peak_bytes: 0,
        }
    }

    /// The loading-phase residency model: raw edge list + sort scratch +
    /// final CSR, all live at the peak (this is what OOMs, not the steady
    /// state).
    fn loading_peak(num_vertices: u64, num_edges: u64) -> u64 {
        let raw = D_EDGE * num_edges;
        let scratch = D_EDGE * num_edges; // sort buffer
        let csr = D_EDGE * num_edges + C_VERTEX * num_vertices;
        raw + scratch + csr
    }
}

impl BaselineEngine for InMemEngine {
    fn name(&self) -> &'static str {
        "graphmat-inmem"
    }

    /// GraphMat has no separate preprocessing: loading happens at app
    /// start (§4.3).  `preprocess` therefore only records the CSV read.
    fn preprocess(&mut self, _g: &EdgeList, _disk: &Disk) -> Result<f64> {
        Ok(0.0)
    }

    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics> {
        anyhow::ensure!(self.csr.is_some(), "load first (InMemEngine::load)");
        let source = InMemSource { eng: self };
        let mut core = ExecCore::new(self.cfg.exec(), disk, None);
        let (vals, run) =
            core.run(&source, app, self.num_vertices, &self.inv_out_deg, iters)?;
        self.values = vals;
        Ok(run)
    }

    fn values_lane(&self) -> &LaneVec {
        &self.values
    }

    fn memory_bytes(&self) -> u64 {
        // steady state: CSR + vertex arrays
        D_EDGE * self.num_edges + 2 * C_VERTEX * self.num_vertices as u64
    }
}

impl InMemEngine {
    /// The loading phase (Fig 9): read the CSV, sort edges by destination,
    /// build CSR.  Fails with OOM when the peak residency model exceeds
    /// the RAM budget.
    pub fn load(&mut self, g: &EdgeList, disk: &Disk) -> Result<()> {
        let peak = Self::loading_peak(g.num_vertices as u64, g.num_edges());
        self.load_peak_bytes = peak;
        anyhow::ensure!(
            peak <= self.cfg.ram_budget,
            "OOM: loading needs {} bytes, budget {} (GraphMat cannot load this graph)",
            peak,
            self.cfg.ram_budget
        );
        let t = Instant::now();
        let sim0 = disk.snapshot().sim_nanos;
        // read the CSV once
        disk.account_read(D_EDGE * g.num_edges());
        // GraphMat's expensive in-memory sort + structure build
        let mut edges = g.edges.clone();
        edges.sort_unstable_by_key(|e| (e.dst, e.src));
        let csr = Csr::from_edges(&edges, 0, g.num_vertices as usize, true);
        self.csr = Some(csr);
        self.num_vertices = g.num_vertices;
        self.num_edges = g.num_edges();
        self.inv_out_deg = inv_out_degrees(g);
        self.load_seconds =
            t.elapsed().as_secs_f64() + (disk.snapshot().sim_nanos - sim0) as f64 / 1e9;
        Ok(())
    }
}

struct InMemSource<'e> {
    eng: &'e InMemEngine,
}

impl ShardSource for InMemSource<'_> {
    type Item = ();

    fn schedule(&self, _iteration: u32, _active: &[VertexId]) -> (Vec<u32>, u32) {
        // one whole-graph unit; everything is already resident
        (vec![0], 0)
    }

    fn load(&self, _id: u32) -> Result<()> {
        Ok(()) // zero per-iteration disk I/O by design
    }

    fn unit_edges(&self, _id: u32, _item: &()) -> u64 {
        // the single unit is the whole resident graph
        self.eng.num_edges
    }

    fn compute(
        &self,
        _id: u32,
        _item: (),
        ctx: &IterCtx<'_>,
        dst: &SharedDst,
        marker: &mut RangeMarker<'_>,
        _scratch: &mut Scratch<'_>,
    ) -> Result<UnitOutput> {
        let csr = self.eng.csr.as_ref().expect("run checks csr");
        let n = self.eng.num_vertices as usize;
        // SAFETY: the single unit owns the whole vertex range.
        let mut out = unsafe { dst.claim(0, n) };
        crate::engine::native_update(ctx, csr.slices(), 0, out.rb());
        mark_interval(ctx, 0, out.shared(), marker);
        Ok(UnitOutput::InPlace)
    }

    fn residency_bytes(&self) -> u64 {
        self.eng.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::PageRank;
    use crate::graph::rmat::{rmat, RmatParams};

    #[test]
    fn inmem_oom_when_budget_too_small() {
        let g = rmat(8, 2_000, 107, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = InMemEngine::new(BaselineConfig { ram_budget: 1000, ..Default::default() });
        let err = e.load(&g, &disk).unwrap_err().to_string();
        assert!(err.contains("OOM"), "{err}");
    }

    #[test]
    fn inmem_no_disk_io_after_load() {
        let g = rmat(8, 2_000, 109, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = InMemEngine::new(BaselineConfig::default());
        e.load(&g, &disk).unwrap();
        disk.reset();
        let run = e.run(&PageRank::new(), 5, &disk).unwrap();
        for m in &run.iterations {
            assert_eq!(m.io.bytes_read, 0);
            assert_eq!(m.io.bytes_written, 0);
        }
    }

    #[test]
    fn inmem_matches_sweep_reference() {
        let g = rmat(8, 2_000, 113, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = InMemEngine::new(BaselineConfig::default());
        e.load(&g, &disk).unwrap();
        e.run(&PageRank::new(), 5, &disk).unwrap();
        let inv = inv_out_degrees(&g);
        let (init, _) = PageRank::new().init(g.num_vertices);
        let mut src = init.f32s().to_vec();
        for _ in 0..5 {
            src = super::super::sweep(
                PageRank::new().kernel(),
                &g.edges,
                g.num_vertices,
                &inv,
                &src,
            );
        }
        // relative gate: the engine's chunked row sums reassociate f32
        // adds vs the sequential sweep reference (see exec::kernel docs)
        for (a, b) in e.values().iter().zip(&src) {
            assert!((a - b).abs() <= 1e-5 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn loading_peak_exceeds_steady_state() {
        let peak = InMemEngine::loading_peak(100, 1000);
        let mut e = InMemEngine::new(BaselineConfig::default());
        e.num_vertices = 100;
        e.num_edges = 1000;
        assert!(peak > e.memory_bytes());
    }
}
