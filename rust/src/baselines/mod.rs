//! Baseline graph engines — the systems the paper compares against.
//!
//! [`psw`] (GraphChi), [`esg`] (X-Stream), [`dsw`] (GridGraph) are
//! faithful re-implementations of each paper's *I/O schedule*: the same
//! partitioning, the same per-iteration reads and writes (§3.1–3.4 of
//! the GraphMP paper, matching Table 3's closed forms), the same memory
//! residency.  [`inmem`] is the GraphMat-like in-memory SpMV engine
//! (crashes by design when the RAM budget is exceeded).
//!
//! Since the unified-execution refactor every baseline is a
//! [`crate::exec::ShardSource`] plug-in for the shared
//! [`crate::exec::ExecCore`] — the *same* schedule→prefetch→compute
//! pipeline, active-set tracking and iteration accounting the VSW engine
//! uses.  An engine contributes only:
//!
//! - its **unit decomposition** (PSW: destination-interval shards; ESG:
//!   source partitions; DSW: grid columns; inmem: the whole graph);
//! - its **per-unit I/O charges** on the load/compute paths (so
//!   simulated disk time overlaps compute exactly as it does for VSW,
//!   making Figs 9/10 and Tables 5–7 like-for-like);
//! - its **residency model** (Fig 11).
//!
//! The vertex *math* is the shared [`crate::apps::ShardKernel`] algebra
//! (the paper's premise: all systems run the same vertex programs and
//! differ only in I/O), and every engine keeps each destination's
//! in-edges in the canonical ascending-source order — so all five
//! engines agree **bit-identically** on every app, enforced by
//! `rust/tests/cross_engine.rs`.

pub mod dsw;
pub mod esg;
pub mod inmem;
pub mod psw;

use anyhow::Result;

use crate::apps::{Combine, ShardKernel, VertexProgram};
use crate::exec::lane::{with_lane, Lane, LaneType, LaneVec};
use crate::exec::ExecConfig;
use crate::graph::EdgeList;
use crate::metrics::RunMetrics;
use crate::storage::disk::Disk;

/// Record sizes shared with `model::ModelParams` (C and D in Table 3).
pub const C_VERTEX: u64 = 8; // paper: double rank values
pub const D_EDGE: u64 = 8; // (src, dst) pair

/// Common baseline knobs.
#[derive(Clone, Copy, Debug)]
pub struct BaselineConfig {
    /// Partition / shard count (P).
    pub p: u32,
    /// Simulated RAM budget in bytes; engines whose residency model
    /// exceeds it fail with an OOM error (reproducing the paper's crashes
    /// of in-memory systems on the big graphs).
    pub ram_budget: u64,
    /// Compute workers of the shared execution pipeline.
    pub workers: usize,
    /// Ready-queue depth of the shared prefetcher (0 = sequential
    /// reference path, as for the VSW engine).
    pub prefetch_depth: usize,
    /// Dedicated I/O threads of the shared prefetcher.
    pub prefetch_threads: usize,
    /// Enable the engine's *native* selective scheduling, where the
    /// modelled system has one (GraphChi-PSW skips intervals with no
    /// active in-edge source — its "scheduler"; X-Stream/GridGraph sweep
    /// everything and ignore this flag).  Off by default: the paper's
    /// baseline tables run the systems in their default full-sweep mode.
    pub selective: bool,
    /// Active-ratio threshold below which the skip pass runs (same rule
    /// as `EngineConfig::active_threshold`; sim graphs want ~0.02).
    pub active_threshold: f64,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        let exec = ExecConfig::default();
        BaselineConfig {
            p: 16,
            ram_budget: u64::MAX,
            workers: exec.workers,
            prefetch_depth: exec.prefetch_depth,
            prefetch_threads: exec.prefetch_threads,
            selective: false,
            active_threshold: 0.02,
        }
    }
}

impl BaselineConfig {
    /// The shared-core pipeline configuration this baseline runs with.
    pub fn exec(&self) -> ExecConfig {
        ExecConfig {
            workers: self.workers,
            prefetch_depth: self.prefetch_depth,
            prefetch_auto: false,
            prefetch_threads: self.prefetch_threads,
            io_depth: 64,
            // the baselines model batch-free systems; the fan-out only
            // engages in scan-shared batches, which they never run
            fan_out: false,
            isolate_failures: false,
        }
    }
}

/// The baseline engine interface: preprocess once, run many.
pub trait BaselineEngine {
    fn name(&self) -> &'static str;

    /// One-time data preprocessing (Table 8): performs the engine's real
    /// layout work and charges its model I/O. Returns elapsed seconds
    /// (wall + simulated disk).
    fn preprocess(&mut self, g: &EdgeList, disk: &Disk) -> Result<f64>;

    /// Run `app` for `iters` iterations through the shared execution
    /// core, charging the model's I/O per iteration. Engines do the real
    /// vertex math.
    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics>;

    /// Final vertex values of the last `run`, in the app's lane type.
    fn values_lane(&self) -> &LaneVec;

    /// f32 convenience view of [`Self::values_lane`] (float apps only;
    /// panics on integer lanes).
    fn values(&self) -> &[f32] {
        self.values_lane().f32s()
    }

    /// Resident-memory model in bytes (Fig 11).
    fn memory_bytes(&self) -> u64;
}

/// One push-style sweep over a destination-grouped edge list: the simple
/// reference implementation of a [`ShardKernel`] iteration, used by
/// tests and the simulated distributed engines.  When each destination's
/// edges arrive in the same order, min/max kernels match the engines
/// bit-for-bit; sum kernels agree only to a small relative epsilon,
/// because this sweep adds sequentially while the engines fold rows
/// through chunked multi-lane accumulators (see `exec::kernel`).
/// Destinations with ≤ 3 in-edges stay bit-identical even for sums.
pub fn sweep_t<T: Lane>(
    kernel: ShardKernel,
    edges_by_dst: &[crate::graph::Edge],
    num_vertices: u32,
    inv_out_deg: &[f32],
    src: &[T],
) -> Vec<T> {
    let n = num_vertices as usize;
    match kernel.combine {
        Combine::Sum => {
            let mut acc = vec![T::ZERO; n];
            for e in edges_by_dst {
                let u = e.src as usize;
                let inv = inv_out_deg.get(u).copied().unwrap_or(0.0);
                acc[e.dst as usize] =
                    acc[e.dst as usize].add(kernel.edge_value_t(src[u], inv, e.weight));
            }
            acc.iter()
                .enumerate()
                .map(|(v, &a)| kernel.apply_t(v as u32, num_vertices, src[v], a))
                .collect()
        }
        Combine::Min | Combine::Max => {
            let mut out = src.to_vec();
            for e in edges_by_dst {
                let u = e.src as usize;
                let cand = kernel.edge_value_t(src[u], 0.0, e.weight);
                out[e.dst as usize] = kernel.combine_t(out[e.dst as usize], cand);
            }
            out
        }
    }
}

/// f32 convenience over [`sweep_t`] — the historical single-lane API.
pub fn sweep(
    kernel: ShardKernel,
    edges_by_dst: &[crate::graph::Edge],
    num_vertices: u32,
    inv_out_deg: &[f32],
    src: &[f32],
) -> Vec<f32> {
    sweep_t::<f32>(kernel, edges_by_dst, num_vertices, inv_out_deg, src)
}

/// Lane-erased [`sweep_t`]: dispatch on the kernel's lane tag.
pub fn sweep_lane(
    kernel: ShardKernel,
    edges_by_dst: &[crate::graph::Edge],
    num_vertices: u32,
    inv_out_deg: &[f32],
    src: &LaneVec,
) -> LaneVec {
    with_lane!(kernel.lane, T => T::wrap(sweep_t::<T>(
        kernel,
        edges_by_dst,
        num_vertices,
        inv_out_deg,
        T::of_slice(src.as_slice()),
    )))
}

/// Count active vertices after a sweep (the app's update semantics).
pub fn count_updates(app: &dyn VertexProgram, src: &[f32], dst: &[f32]) -> u64 {
    src.iter()
        .zip(dst)
        .filter(|&(&a, &b)| app.is_update(a, b))
        .count() as u64
}

/// Lane-erased [`count_updates`]: f32 lanes keep the app's (overridable)
/// activation predicate; integer lanes use the kernel's exactly.
pub fn count_updates_lane(app: &dyn VertexProgram, src: &LaneVec, dst: &LaneVec) -> u64 {
    let kernel = app.kernel();
    if kernel.lane == LaneType::F32 {
        return count_updates(app, src.f32s(), dst.f32s());
    }
    with_lane!(kernel.lane, T => {
        T::of_slice(src.as_slice())
            .iter()
            .zip(T::of_slice(dst.as_slice()))
            .filter(|&(&a, &b)| kernel.is_update_t(a, b))
            .count() as u64
    })
}

/// Shared out-degree inverse used by the sum kernels.
pub fn inv_out_degrees(g: &EdgeList) -> Vec<f32> {
    g.out_degrees()
        .iter()
        .map(|&d| if d > 0 { 1.0 / d as f32 } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{EdgeCost, PageRank};
    use crate::graph::Edge;

    #[test]
    fn sweep_pagerank_basic() {
        // 0 -> 1, out_deg(0)=1
        let g = EdgeList { num_vertices: 2, edges: vec![Edge::new(0, 1)] };
        let inv = inv_out_degrees(&g);
        let src = vec![0.5f32, 0.5];
        let out = sweep(ShardKernel::pagerank(0.85), &g.edges, 2, &inv, &src);
        let base = 0.15 / 2.0;
        assert!((out[0] - base).abs() < 1e-7);
        assert!((out[1] - (base + 0.85 * 0.5)).abs() < 1e-7);
    }

    #[test]
    fn sweep_relax_min() {
        let edges = vec![Edge::weighted(0, 1, 3.0)];
        let src = vec![0.0f32, f32::INFINITY];
        let out = sweep(ShardKernel::relax_min(EdgeCost::Weights), &edges, 2, &[], &src);
        assert_eq!(out, vec![0.0, 3.0]);
    }

    #[test]
    fn sweep_widest_path() {
        let edges = vec![Edge::weighted(0, 1, 3.0), Edge::weighted(0, 2, 7.0)];
        let src = vec![f32::INFINITY, 0.0, 0.0];
        let out = sweep(ShardKernel::widest_path(EdgeCost::Weights), &edges, 3, &[], &src);
        assert_eq!(out, vec![f32::INFINITY, 3.0, 7.0]);
    }

    #[test]
    fn sweep_personalized_pagerank_base_at_seed() {
        let g = EdgeList { num_vertices: 3, edges: vec![Edge::new(0, 1)] };
        let inv = inv_out_degrees(&g);
        let src = vec![1.0f32, 0.0, 0.0];
        let out = sweep(
            ShardKernel::personalized_pagerank(0.85, 0),
            &g.edges,
            3,
            &inv,
            &src,
        );
        assert!((out[0] - 0.15).abs() < 1e-7, "seed keeps the teleport mass");
        assert!((out[1] - 0.85).abs() < 1e-7);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn count_updates_uses_app_semantics() {
        let pr = PageRank::new();
        assert_eq!(count_updates(&pr, &[1.0, 2.0], &[1.0, 3.0]), 1);
    }
}
