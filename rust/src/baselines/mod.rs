//! Baseline graph engines — the systems the paper compares against.
//!
//! [`psw`] (GraphChi), [`esg`] (X-Stream), [`dsw`] (GridGraph) are
//! faithful re-implementations of each paper's *computation model*: the
//! same partitioning, the same per-iteration I/O schedule (§3.1–3.4 of the
//! GraphMP paper, matching Table 3's closed forms), the same memory
//! residency — executed against the shared [`Disk`] so measured I/O
//! volumes and simulated device time are directly comparable with
//! GraphMP's VSW engine.  [`inmem`] is the GraphMat-like in-memory SpMV
//! engine (crashes by design when the RAM budget is exceeded).
//!
//! The vertex *math* is identical across engines (the paper's premise:
//! all run the same vertex programs; the systems differ in I/O), so all
//! engines must agree on results — tested in `rust/tests/`.

pub mod dsw;
pub mod esg;
pub mod inmem;
pub mod psw;

use anyhow::Result;

use crate::apps::{ShardCompute, VertexProgram};
use crate::graph::EdgeList;
use crate::metrics::RunMetrics;
use crate::storage::disk::Disk;

/// Record sizes shared with `model::ModelParams` (C and D in Table 3).
pub const C_VERTEX: u64 = 8; // paper: double rank values
pub const D_EDGE: u64 = 8; // (src, dst) pair

/// Common baseline knobs.
#[derive(Clone, Copy, Debug)]
pub struct BaselineConfig {
    /// Partition / shard count (P).
    pub p: u32,
    /// Simulated RAM budget in bytes; engines whose residency model
    /// exceeds it fail with an OOM error (reproducing the paper's crashes
    /// of in-memory systems on the big graphs).
    pub ram_budget: u64,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig { p: 16, ram_budget: u64::MAX }
    }
}

/// The baseline engine interface: preprocess once, run many.
pub trait BaselineEngine {
    fn name(&self) -> &'static str;

    /// One-time data preprocessing (Table 8): performs the engine's real
    /// layout work and charges its model I/O. Returns elapsed seconds
    /// (wall + simulated disk).
    fn preprocess(&mut self, g: &EdgeList, disk: &Disk) -> Result<f64>;

    /// Run `app` for `iters` iterations, charging the model's I/O per
    /// iteration. Engines do the real vertex math.
    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics>;

    /// Final vertex values of the last `run`.
    fn values(&self) -> &[f32];

    /// Resident-memory model in bytes (Fig 11).
    fn memory_bytes(&self) -> u64;
}

/// One push-style sweep over an edge list: the shared vertex math all
/// baselines execute (identical numerics to the VSW native backend when
/// edges are destination-ordered).
pub fn sweep(
    kind: ShardCompute,
    edges_by_dst: &[crate::graph::Edge],
    num_vertices: u32,
    inv_out_deg: &[f32],
    src: &[f32],
) -> Vec<f32> {
    let n = num_vertices as usize;
    match kind {
        ShardCompute::PageRankSum { damping } => {
            let base = (1.0 - damping) / n as f32;
            let mut sum = vec![0.0f32; n];
            for e in edges_by_dst {
                sum[e.dst as usize] += src[e.src as usize] * inv_out_deg[e.src as usize];
            }
            sum.iter().map(|s| base + damping * s).collect()
        }
        ShardCompute::RelaxMin { cost } => {
            let mut out = src.to_vec();
            for e in edges_by_dst {
                let cand = src[e.src as usize] + cost.apply(e.weight);
                if cand < out[e.dst as usize] {
                    out[e.dst as usize] = cand;
                }
            }
            out
        }
    }
}

/// Count active vertices after a sweep (the app's update semantics).
pub fn count_updates(app: &dyn VertexProgram, src: &[f32], dst: &[f32]) -> u64 {
    src.iter()
        .zip(dst)
        .filter(|&(&a, &b)| app.is_update(a, b))
        .count() as u64
}

/// Shared out-degree inverse used by PageRank.
pub fn inv_out_degrees(g: &EdgeList) -> Vec<f32> {
    g.out_degrees()
        .iter()
        .map(|&d| if d > 0 { 1.0 / d as f32 } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{EdgeCost, PageRank};
    use crate::graph::Edge;

    #[test]
    fn sweep_pagerank_basic() {
        // 0 -> 1, out_deg(0)=1
        let g = EdgeList { num_vertices: 2, edges: vec![Edge::new(0, 1)] };
        let inv = inv_out_degrees(&g);
        let src = vec![0.5f32, 0.5];
        let out = sweep(
            ShardCompute::PageRankSum { damping: 0.85 },
            &g.edges,
            2,
            &inv,
            &src,
        );
        let base = 0.15 / 2.0;
        assert!((out[0] - base).abs() < 1e-7);
        assert!((out[1] - (base + 0.85 * 0.5)).abs() < 1e-7);
    }

    #[test]
    fn sweep_relax_min() {
        let edges = vec![Edge::weighted(0, 1, 3.0)];
        let src = vec![0.0f32, f32::INFINITY];
        let out = sweep(
            ShardCompute::RelaxMin { cost: EdgeCost::Weights },
            &edges,
            2,
            &[],
            &src,
        );
        assert_eq!(out, vec![0.0, 3.0]);
    }

    #[test]
    fn count_updates_uses_app_semantics() {
        let pr = PageRank::new();
        assert_eq!(count_updates(&pr, &[1.0, 2.0], &[1.0, 3.0]), 1);
    }
}
