//! DSW — GridGraph's dual-sliding-windows engine (§3.4).
//!
//! Vertices are cut into √P chunks; edges land in a √P×√P grid of blocks
//! by (source row, destination column).  Processing goes column by column:
//! for column j, stream every block in that column (reading each source
//! chunk: `C√P|V|` over the iteration, plus `D|E|` of edges) and keep the
//! destination chunk resident, writing it once per column.  Memory: two
//! vertex chunks, `2C|V|/√P`.
//!
//! Runs through the shared execution core: one pipeline unit per grid
//! *column* — loading a column streams its √P blocks (reads charged on
//! the load path, overlapping compute when prefetched), compute owns the
//! destination chunk exclusively.  Blocks are sorted by source at
//! preprocessing and concatenated in ascending row order, so each
//! destination folds its in-edges in the repo-wide canonical
//! ascending-source order — through the same chunked multi-lane
//! combines as every other engine, keeping cross-engine comparisons
//! bit-identical (see `exec::kernel`).

use std::time::Instant;

use anyhow::Result;

use crate::apps::VertexProgram;
use crate::exec::{
    fold_edges_interval, mark_interval, ExecCore, IterCtx, LaneVec, RangeMarker, Scratch,
    ShardSource, SharedDst, UnitOutput,
};
use crate::graph::{Edge, EdgeList, VertexId};
use crate::metrics::RunMetrics;
use crate::storage::disk::Disk;

use super::{inv_out_degrees, BaselineConfig, BaselineEngine, C_VERTEX, D_EDGE};

pub struct DswEngine {
    cfg: BaselineConfig,
    /// blocks[i][j]: edges with src in chunk i, dst in chunk j.
    blocks: Vec<Vec<Vec<Edge>>>,
    sqrt_p: u32,
    chunk_span: u32,
    num_vertices: u32,
    num_edges: u64,
    inv_out_deg: Vec<f32>,
    values: LaneVec,
}

impl DswEngine {
    pub fn new(cfg: BaselineConfig) -> Self {
        DswEngine {
            cfg,
            blocks: Vec::new(),
            sqrt_p: 0,
            chunk_span: 0,
            num_vertices: 0,
            num_edges: 0,
            inv_out_deg: Vec::new(),
            values: LaneVec::from(Vec::<f32>::new()),
        }
    }
}

impl BaselineEngine for DswEngine {
    fn name(&self) -> &'static str {
        "gridgraph-dsw"
    }

    fn preprocess(&mut self, g: &EdgeList, disk: &Disk) -> Result<f64> {
        let t = Instant::now();
        let sim0 = disk.snapshot().sim_nanos;
        let de = D_EDGE * g.num_edges();
        let sqrt_p = (self.cfg.p as f64).sqrt().ceil().max(1.0) as u32;
        let span = g.num_vertices.div_ceil(sqrt_p);
        // step 1: read edges, append to block files (read D|E|, write D|E|)
        disk.account_read(de);
        disk.account_write(de);
        let mut blocks: Vec<Vec<Vec<Edge>>> =
            vec![vec![Vec::new(); sqrt_p as usize]; sqrt_p as usize];
        for e in &g.edges {
            blocks[(e.src / span) as usize][(e.dst / span) as usize].push(*e);
        }
        // steps 2+3: merge blocks into column- and row-oriented files
        // (2 × (read D|E| + write D|E|)) ⇒ total 6D|E|
        disk.account_read(de);
        disk.account_write(de);
        disk.account_read(de);
        disk.account_write(de);
        // canonical per-destination order: column sweeps concatenate
        // blocks in ascending source-chunk order; sorting within a block
        // makes the full column ascending by source
        for row in &mut blocks {
            for block in row {
                block.sort_unstable_by_key(|e| e.src);
            }
        }
        self.blocks = blocks;
        self.sqrt_p = sqrt_p;
        self.chunk_span = span;
        self.num_vertices = g.num_vertices;
        self.num_edges = g.num_edges();
        self.inv_out_deg = inv_out_degrees(g);
        let sim = (disk.snapshot().sim_nanos - sim0) as f64 / 1e9;
        Ok(t.elapsed().as_secs_f64() + sim)
    }

    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics> {
        anyhow::ensure!(!self.blocks.is_empty(), "preprocess first");
        let source = DswSource { eng: self, disk };
        let mut core = ExecCore::new(self.cfg.exec(), disk, None);
        let (vals, run) =
            core.run(&source, app, self.num_vertices, &self.inv_out_deg, iters)?;
        self.values = vals;
        Ok(run)
    }

    fn values_lane(&self) -> &LaneVec {
        &self.values
    }

    fn memory_bytes(&self) -> u64 {
        // 2C|V|/√P — one source + one destination chunk
        2 * C_VERTEX * self.num_vertices as u64 / self.sqrt_p.max(1) as u64
    }
}

struct DswSource<'e> {
    eng: &'e DswEngine,
    disk: &'e Disk,
}

impl ShardSource for DswSource<'_> {
    /// The column's concatenated edge stream (ascending source order).
    type Item = Vec<Edge>;

    fn schedule(&self, _iteration: u32, _active: &[VertexId]) -> (Vec<u32>, u32) {
        // one unit per grid column; GridGraph sweeps all of them
        ((0..self.eng.sqrt_p).collect(), 0)
    }

    fn load(&self, j: u32) -> Result<Vec<Edge>> {
        // stream every block of column j: each source chunk + its edges
        let eng = self.eng;
        let chunk_bytes = C_VERTEX * eng.chunk_span as u64;
        let mut col_edges = Vec::new();
        for row in eng.blocks.iter() {
            let block = &row[j as usize];
            self.disk.account_read(chunk_bytes); // source chunk i
            self.disk.account_read(D_EDGE * block.len() as u64);
            col_edges.extend_from_slice(block);
        }
        Ok(col_edges)
    }

    fn unit_edges(&self, _id: u32, col_edges: &Vec<Edge>) -> u64 {
        col_edges.len() as u64
    }

    fn compute(
        &self,
        j: u32,
        col_edges: Vec<Edge>,
        ctx: &IterCtx<'_>,
        dst: &SharedDst,
        marker: &mut RangeMarker<'_>,
        scratch: &mut Scratch<'_>,
    ) -> Result<UnitOutput> {
        let eng = self.eng;
        let n = eng.num_vertices;
        let lo = (j * eng.chunk_span).min(n);
        let hi = ((j + 1) * eng.chunk_span).min(n);
        if lo < hi {
            // SAFETY: destination chunks are disjoint by construction.
            let mut out = unsafe { dst.claim(lo as usize, (hi - lo) as usize) };
            fold_edges_interval(ctx, &col_edges, lo, out.rb(), scratch);
            mark_interval(ctx, lo, out.shared(), marker);
        }
        let chunk_bytes = C_VERTEX * eng.chunk_span as u64;
        self.disk.account_write(chunk_bytes); // destination chunk j
        Ok(UnitOutput::InPlace)
    }

    fn residency_bytes(&self) -> u64 {
        self.eng.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Cc, PageRank};
    use crate::baselines::sweep;
    use crate::graph::rmat::{rmat, RmatParams};

    #[test]
    fn dsw_io_matches_table3() {
        let g = rmat(9, 4_000, 97, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = DswEngine::new(BaselineConfig { p: 16, ..Default::default() });
        e.preprocess(&g, &disk).unwrap();
        disk.reset();
        let run = e.run(&PageRank::new(), 1, &disk).unwrap();
        let m = &run.iterations[0];
        let ed = g.num_edges();
        let sqrt_p = e.sqrt_p as u64;
        let chunk = C_VERTEX * e.chunk_span as u64;
        // read = C√P|V| + D|E| ; write = C√P|V| (in chunk granularity)
        let want_read = chunk * sqrt_p * sqrt_p + D_EDGE * ed;
        let want_write = chunk * sqrt_p;
        assert_eq!(m.io.bytes_read, want_read);
        assert_eq!(m.io.bytes_written, want_write);
    }

    #[test]
    fn dsw_prep_is_6de() {
        let g = rmat(8, 2_000, 101, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = DswEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        let s = disk.snapshot();
        assert_eq!(s.bytes_read + s.bytes_written, 6 * D_EDGE * g.num_edges());
    }

    #[test]
    fn dsw_cc_matches_reference_sweeps() {
        let g = rmat(8, 2_000, 103, RmatParams::default()).to_undirected();
        let disk = Disk::unthrottled();
        let mut e = DswEngine::new(BaselineConfig { p: 9, ..Default::default() });
        e.preprocess(&g, &disk).unwrap();
        e.run(&Cc, 30, &disk).unwrap();
        let (init, _) = Cc.init(g.num_vertices);
        let mut src = init.f32s().to_vec();
        for _ in 0..30 {
            let next = sweep(Cc.kernel(), &g.edges, g.num_vertices, &[], &src);
            if next == src {
                break;
            }
            src = next;
        }
        assert_eq!(e.values(), &src[..]);
    }
}
