//! PSW — GraphChi's parallel-sliding-windows engine (§3.1).
//!
//! Vertices are split into P intervals; each shard stores the interval's
//! in-edges sorted by *source* (GraphChi's layout, enabling the sliding
//! window over out-edges).  Vertex values live **on the edges**: each
//! iteration loads an interval's vertices, in-edges *and* out-edges
//! (reading `C|V| + 2(C+D)|E|`), updates, and writes everything back
//! (another `C|V| + 2(C+D)|E|`).  Memory holds one interval's subgraph:
//! `(C|V| + 2(C+D)|E|)/P`.
//!
//! Runs through the shared execution core: one pipeline unit per shard,
//! reads charged on the load path (overlapping compute when prefetched),
//! the interval's rows computed in place via the shared kernel fold —
//! the same chunked multi-lane combines as every other engine, so
//! cross-engine comparisons stay bit-identical (see `exec::kernel`).
//!
//! GraphChi has *native* selective scheduling (its "scheduler": skip an
//! interval when nothing scheduled touches it).  With
//! `BaselineConfig::selective` on, the schedule stage consults exact
//! per-shard source bitsets built at preprocessing — a shard is skipped
//! iff no active vertex has an in-edge into its interval, so results
//! stay bit-identical (same rule the VSW engine's Bloom pass
//! approximates) while Fig 7's effect reproduces under a non-VSW layout.

use std::time::Instant;

use anyhow::Result;

use crate::apps::VertexProgram;
use crate::exec::{
    fold_edges_interval, mark_interval, ExecCore, IterCtx, LaneVec, RangeMarker, Scratch,
    ShardSource, SharedDst, UnitOutput,
};
use crate::graph::{Edge, EdgeList, VertexId};
use crate::metrics::RunMetrics;
use crate::storage::disk::Disk;

use super::{inv_out_degrees, BaselineConfig, BaselineEngine, C_VERTEX, D_EDGE};

pub struct PswEngine {
    cfg: BaselineConfig,
    /// Edges of shard `s` (destination in interval `s`), sorted by source.
    shards: Vec<Vec<Edge>>,
    /// Destination interval of each shard (disjoint, covering `[0, n)`).
    intervals: Vec<(u32, u32)>,
    /// Per-shard bitset over the vertex space: bit `v` set iff `v` has an
    /// out-edge into the shard's interval (exact, unlike VSW's Blooms —
    /// GraphChi keeps this as per-interval scheduling state).
    src_bits: Vec<Vec<u64>>,
    num_vertices: u32,
    num_edges: u64,
    inv_out_deg: Vec<f32>,
    values: LaneVec,
}

impl PswEngine {
    pub fn new(cfg: BaselineConfig) -> Self {
        PswEngine {
            cfg,
            shards: Vec::new(),
            intervals: Vec::new(),
            src_bits: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            inv_out_deg: Vec::new(),
            values: LaneVec::from(Vec::<f32>::new()),
        }
    }
}

impl BaselineEngine for PswEngine {
    fn name(&self) -> &'static str {
        "graphchi-psw"
    }

    fn preprocess(&mut self, g: &EdgeList, disk: &Disk) -> Result<f64> {
        let t = Instant::now();
        let sim0 = disk.snapshot().sim_nanos;
        let de = D_EDGE * g.num_edges();
        // step 1: count in-degrees, choose intervals (read D|E|)
        disk.account_read(de);
        let in_deg = g.in_degrees();
        let per = (g.num_edges() / self.cfg.p as u64).max(1);
        let mut bounds = vec![0u32];
        let mut acc = 0u64;
        for (v, &d) in in_deg.iter().enumerate() {
            acc += d as u64;
            if acc > per && (v as u32) > *bounds.last().unwrap() {
                bounds.push(v as u32);
                acc = d as u64;
            }
        }
        bounds.push(g.num_vertices);
        // step 2: shard scratch files (read D|E|, write D|E|)
        disk.account_read(de);
        disk.account_write(de);
        let mut shards: Vec<Vec<Edge>> = vec![Vec::new(); bounds.len() - 1];
        let owner = |v: u32| -> usize {
            match bounds.binary_search(&v) {
                Ok(i) => i.min(shards.len() - 1),
                Err(i) => i - 1,
            }
        };
        let mut shard_of = vec![0u32; g.num_vertices as usize];
        for v in 0..g.num_vertices {
            shard_of[v as usize] = owner(v) as u32;
        }
        for e in &g.edges {
            shards[shard_of[e.dst as usize] as usize].push(*e);
        }
        // step 3: sort each shard by source, write compact (read D|E|,
        // write (C+D)|E| — GraphChi attaches vertex data to edges).  The
        // source sort is also the repo-wide canonical per-destination
        // edge order, so results agree bit-for-bit with every engine.
        disk.account_read(de);
        disk.account_write((C_VERTEX + D_EDGE) * g.num_edges());
        for s in &mut shards {
            s.sort_unstable_by_key(|e| e.src);
        }
        // per-shard source-presence bitsets for the native scheduler
        // (built during the same layout pass).  Only built when the
        // scheduler is on: they cost P·|V|/8 bytes of *resident* RAM —
        // GraphChi keeps this scheduling state live — and the residency
        // model below charges them, so Fig 11 stays honest for
        // selective PSW.
        self.src_bits = if self.cfg.selective {
            let words = (g.num_vertices as usize).div_ceil(64);
            let mut src_bits = vec![vec![0u64; words]; shards.len()];
            for (s, edges) in shards.iter().enumerate() {
                let bits = &mut src_bits[s];
                for e in edges {
                    bits[(e.src / 64) as usize] |= 1 << (e.src % 64);
                }
            }
            src_bits
        } else {
            Vec::new()
        };
        self.intervals = bounds.windows(2).map(|w| (w[0], w[1])).collect();
        self.shards = shards;
        self.num_vertices = g.num_vertices;
        self.num_edges = g.num_edges();
        self.inv_out_deg = inv_out_degrees(g);
        let sim = (disk.snapshot().sim_nanos - sim0) as f64 / 1e9;
        Ok(t.elapsed().as_secs_f64() + sim)
    }

    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics> {
        anyhow::ensure!(!self.shards.is_empty(), "preprocess first");
        let source = PswSource { eng: self, disk };
        let mut core = ExecCore::new(self.cfg.exec(), disk, None);
        let (vals, run) =
            core.run(&source, app, self.num_vertices, &self.inv_out_deg, iters)?;
        self.values = vals;
        Ok(run)
    }

    fn values_lane(&self) -> &LaneVec {
        &self.values
    }

    fn memory_bytes(&self) -> u64 {
        // (C|V| + 2(C+D)|E|) / P, plus the native scheduler's resident
        // per-shard source bitsets (P·|V|/8 bytes) when selective is on
        let scheduler_state: u64 =
            self.src_bits.iter().map(|b| 8 * b.len() as u64).sum();
        (C_VERTEX * self.num_vertices as u64 + 2 * (C_VERTEX + D_EDGE) * self.num_edges)
            / self.shards.len().max(1) as u64
            + scheduler_state
    }
}

struct PswSource<'e> {
    eng: &'e PswEngine,
    disk: &'e Disk,
}

impl ShardSource for PswSource<'_> {
    type Item = ();

    fn schedule(&self, _iteration: u32, active: &[VertexId]) -> (Vec<u32>, u32) {
        let eng = self.eng;
        let p = eng.shards.len() as u32;
        let n = eng.num_vertices as usize;
        let active_ratio = active.len() as f64 / n.max(1) as f64;
        // default GraphChi sweeps every shard every iteration; with its
        // native scheduler on, skip intervals none of whose in-edge
        // sources are active (exact — a skipped interval's fold would
        // reproduce its current values bit-for-bit)
        if !eng.cfg.selective || active_ratio >= eng.cfg.active_threshold {
            return ((0..p).collect(), 0);
        }
        // fold the (sorted) active list into word/mask pairs once, then
        // AND word-wise against each shard's source bitset: O(|active|)
        // build + O(P · touched_words) probes instead of O(P · |active|)
        // single-bit tests
        let mut active_words: Vec<(usize, u64)> = Vec::new();
        for &v in active {
            let w = (v / 64) as usize;
            let m = 1u64 << (v % 64);
            match active_words.last_mut() {
                Some((lw, lm)) if *lw == w => *lm |= m,
                _ => active_words.push((w, m)),
            }
        }
        let worklist: Vec<u32> = (0..p)
            .filter(|&s| {
                let bits = &eng.src_bits[s as usize];
                active_words.iter().any(|&(w, m)| bits[w] & m != 0)
            })
            .collect();
        let skipped = p - worklist.len() as u32;
        (worklist, skipped)
    }

    fn load(&self, id: u32) -> Result<()> {
        // load interval vertices + in-edges + the sliding windows of
        // out-edges from all other shards
        let eng = self.eng;
        let p = eng.shards.len() as u64;
        self.disk.account_read(C_VERTEX * eng.num_vertices as u64 / p);
        self.disk
            .account_read(2 * (C_VERTEX + D_EDGE) * eng.shards[id as usize].len() as u64);
        Ok(())
    }

    fn unit_edges(&self, id: u32, _item: &()) -> u64 {
        self.eng.shards[id as usize].len() as u64
    }

    fn compute(
        &self,
        id: u32,
        _item: (),
        ctx: &IterCtx<'_>,
        dst: &SharedDst,
        marker: &mut RangeMarker<'_>,
        scratch: &mut Scratch<'_>,
    ) -> Result<UnitOutput> {
        let eng = self.eng;
        let (lo, hi) = eng.intervals[id as usize];
        let edges = &eng.shards[id as usize];
        // SAFETY: shard intervals are disjoint by construction (bounds
        // are strictly increasing).
        let mut out = unsafe { dst.claim(lo as usize, (hi - lo) as usize) };
        fold_edges_interval(ctx, edges, lo, out.rb(), scratch);
        mark_interval(ctx, lo, out.shared(), marker);
        // write back vertices + updated edge values (both directions,
        // §3.1)
        let p = eng.shards.len() as u64;
        self.disk.account_write(C_VERTEX * eng.num_vertices as u64 / p);
        self.disk.account_write(2 * (C_VERTEX + D_EDGE) * edges.len() as u64);
        Ok(UnitOutput::InPlace)
    }

    fn residency_bytes(&self) -> u64 {
        self.eng.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::PageRank;
    use crate::graph::rmat::{rmat, RmatParams};

    #[test]
    fn psw_io_matches_table3() {
        let g = rmat(9, 4_000, 71, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = PswEngine::new(BaselineConfig { p: 8, ..Default::default() });
        e.preprocess(&g, &disk).unwrap();
        disk.reset();
        let run = e.run(&PageRank::new(), 1, &disk).unwrap();
        let m = &run.iterations[0];
        let v = g.num_vertices as u64;
        let ed = g.num_edges();
        let expect = C_VERTEX * (v / e.shards.len() as u64) * e.shards.len() as u64
            + 2 * (C_VERTEX + D_EDGE) * ed;
        // reads and writes both ≈ C|V| + 2(C+D)|E| (integer division slack)
        assert!(
            (m.io.bytes_read as i64 - expect as i64).unsigned_abs() < v * C_VERTEX,
            "read {} vs {}",
            m.io.bytes_read,
            expect
        );
        assert!(
            (m.io.bytes_written as i64 - expect as i64).unsigned_abs() < v * C_VERTEX,
            "write {} vs {}",
            m.io.bytes_written,
            expect
        );
    }

    #[test]
    fn psw_prep_io_matches_c_plus_5d() {
        let g = rmat(8, 2_000, 73, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = PswEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        let s = disk.snapshot();
        let de = D_EDGE * g.num_edges();
        let ce = C_VERTEX * g.num_edges();
        assert_eq!(s.bytes_read, 3 * de);
        assert_eq!(s.bytes_written, de + ce + de);
        // total = (C+5D)|E|
        assert_eq!(s.bytes_read + s.bytes_written, ce + 5 * de);
    }

    #[test]
    fn psw_reports_pipeline_counters() {
        let g = rmat(8, 2_000, 75, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = PswEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        let run = e.run(&PageRank::new(), 2, &disk).unwrap();
        for m in &run.iterations {
            assert!(m.shards_processed > 0);
            assert_eq!(m.shards_prefetched, m.shards_processed);
            assert_eq!(m.ready_hits + m.ready_misses, m.shards_processed);
        }
    }

    #[test]
    fn psw_requires_preprocess() {
        let disk = Disk::unthrottled();
        let mut e = PswEngine::new(BaselineConfig::default());
        assert!(e.run(&PageRank::new(), 1, &disk).is_err());
    }

    #[test]
    fn selective_scheduler_state_is_charged_to_residency() {
        let g = rmat(8, 2_000, 79, RmatParams::default());
        let disk = Disk::unthrottled();
        let mk = |selective: bool| {
            let mut e = PswEngine::new(BaselineConfig { p: 8, selective, ..Default::default() });
            e.preprocess(&g, &disk).unwrap();
            e
        };
        let off = mk(false);
        let on = mk(true);
        let words = (g.num_vertices as usize).div_ceil(64) as u64;
        assert_eq!(
            on.memory_bytes() - off.memory_bytes(),
            on.shards.len() as u64 * words * 8,
            "selective PSW must charge its P·|V|/8 scheduler bitsets"
        );
        assert!(off.src_bits.is_empty(), "no scheduler state without selective");
    }

    #[test]
    fn psw_selective_skips_shards_and_preserves_results() {
        use crate::apps::Sssp;
        let g = rmat(9, 5_000, 77, RmatParams::default());
        let run_with = |selective: bool| {
            let disk = Disk::unthrottled();
            let mut e = PswEngine::new(BaselineConfig {
                p: 16,
                selective,
                active_threshold: 0.2,
                ..Default::default()
            });
            e.preprocess(&g, &disk).unwrap();
            let run = e.run(&Sssp::new(0), 100, &disk).unwrap();
            (e.values().to_vec(), run)
        };
        let (v_on, r_on) = run_with(true);
        let (v_off, r_off) = run_with(false);
        assert_eq!(v_on, v_off, "native scheduler changed results");
        assert_eq!(r_on.iterations.len(), r_off.iterations.len());
        let skipped: u32 = r_on.iterations.iter().map(|m| m.shards_skipped).sum();
        assert!(skipped > 0, "SSSP frontier must let PSW skip intervals");
        // skipped shards also skip their modelled I/O
        let read_on: u64 = r_on.iterations.iter().map(|m| m.io.bytes_read).sum();
        let read_off: u64 = r_off.iterations.iter().map(|m| m.io.bytes_read).sum();
        assert!(read_on < read_off, "skips must save modelled reads");
        // and the activation trajectories stay identical
        for (a, b) in r_on.iterations.iter().zip(&r_off.iterations) {
            assert_eq!(a.active_vertices, b.active_vertices);
        }
    }
}
