//! PSW — GraphChi's parallel-sliding-windows engine (§3.1).
//!
//! Vertices are split into P intervals; each shard stores the interval's
//! in-edges sorted by *source* (GraphChi's layout, enabling the sliding
//! window over out-edges).  Vertex values live **on the edges**: each
//! iteration loads an interval's vertices, in-edges *and* out-edges
//! (reading `C|V| + 2(C+D)|E|`), updates, and writes everything back
//! (another `C|V| + 2(C+D)|E|`).  Memory holds one interval's subgraph:
//! `(C|V| + 2(C+D)|E|)/P`.

use std::time::Instant;

use anyhow::Result;

use crate::apps::VertexProgram;
use crate::graph::{Edge, EdgeList};
use crate::metrics::{IterationMetrics, RunMetrics};
use crate::storage::disk::Disk;

use super::{count_updates, inv_out_degrees, sweep, BaselineConfig, BaselineEngine, C_VERTEX, D_EDGE};

pub struct PswEngine {
    cfg: BaselineConfig,
    /// Edges of shard `s` (destination in interval `s`), sorted by source.
    shards: Vec<Vec<Edge>>,
    num_vertices: u32,
    num_edges: u64,
    inv_out_deg: Vec<f32>,
    values: Vec<f32>,
}

impl PswEngine {
    pub fn new(cfg: BaselineConfig) -> Self {
        PswEngine {
            cfg,
            shards: Vec::new(),
            num_vertices: 0,
            num_edges: 0,
            inv_out_deg: Vec::new(),
            values: Vec::new(),
        }
    }
}

impl BaselineEngine for PswEngine {
    fn name(&self) -> &'static str {
        "graphchi-psw"
    }

    fn preprocess(&mut self, g: &EdgeList, disk: &Disk) -> Result<f64> {
        let t = Instant::now();
        let sim0 = disk.snapshot().sim_nanos;
        let de = D_EDGE * g.num_edges();
        // step 1: count in-degrees, choose intervals (read D|E|)
        disk.account_read(de);
        let in_deg = g.in_degrees();
        let per = (g.num_edges() / self.cfg.p as u64).max(1);
        let mut bounds = vec![0u32];
        let mut acc = 0u64;
        for (v, &d) in in_deg.iter().enumerate() {
            acc += d as u64;
            if acc > per && (v as u32) > *bounds.last().unwrap() {
                bounds.push(v as u32);
                acc = d as u64;
            }
        }
        bounds.push(g.num_vertices);
        // step 2: shard scratch files (read D|E|, write D|E|)
        disk.account_read(de);
        disk.account_write(de);
        let mut shards: Vec<Vec<Edge>> = vec![Vec::new(); bounds.len() - 1];
        let owner = |v: u32| -> usize {
            match bounds.binary_search(&v) {
                Ok(i) => i.min(shards.len() - 1),
                Err(i) => i - 1,
            }
        };
        let mut shard_of = vec![0u32; g.num_vertices as usize];
        for v in 0..g.num_vertices {
            shard_of[v as usize] = owner(v) as u32;
        }
        for e in &g.edges {
            shards[shard_of[e.dst as usize] as usize].push(*e);
        }
        // step 3: sort each shard by source, write compact (read D|E|,
        // write (C+D)|E| — GraphChi attaches vertex data to edges)
        disk.account_read(de);
        disk.account_write((C_VERTEX + D_EDGE) * g.num_edges());
        for s in &mut shards {
            s.sort_unstable_by_key(|e| e.src);
        }
        self.shards = shards;
        self.num_vertices = g.num_vertices;
        self.num_edges = g.num_edges();
        self.inv_out_deg = inv_out_degrees(g);
        let sim = (disk.snapshot().sim_nanos - sim0) as f64 / 1e9;
        Ok(t.elapsed().as_secs_f64() + sim)
    }

    fn run(&mut self, app: &dyn VertexProgram, iters: u32, disk: &Disk) -> Result<RunMetrics> {
        anyhow::ensure!(!self.shards.is_empty(), "preprocess first");
        let n = self.num_vertices;
        let (mut src, _) = app.init(n);
        let mut run = RunMetrics::default();
        let start = Instant::now();
        let sim_start = disk.snapshot().sim_nanos;
        for iter in 0..iters {
            let t0 = Instant::now();
            let io0 = disk.snapshot();
            let mut dst = vec![0.0f32; n as usize];
            let mut first = true;
            for shard in &self.shards {
                // load interval vertices + in-edges + the sliding windows
                // of out-edges from all other shards
                disk.account_read(C_VERTEX * n as u64 / self.shards.len() as u64);
                disk.account_read(2 * (C_VERTEX + D_EDGE) * shard.len() as u64);
                let part = sweep(app.compute(), shard, n, &self.inv_out_deg, &src);
                if first {
                    dst = part;
                    first = false;
                } else {
                    // merge the interval's rows (each shard owns its
                    // destination rows exclusively)
                    for e in shard.iter() {
                        dst[e.dst as usize] = part[e.dst as usize];
                    }
                }
                // write back vertices + updated edge values (both
                // directions, §3.1)
                disk.account_write(C_VERTEX * n as u64 / self.shards.len() as u64);
                disk.account_write(2 * (C_VERTEX + D_EDGE) * shard.len() as u64);
            }
            let active = count_updates(app, &src, &dst);
            src = dst;
            let io1 = disk.snapshot();
            run.iterations.push(IterationMetrics {
                iteration: iter,
                wall: t0.elapsed(),
                sim_disk_seconds: (io1.sim_nanos - io0.sim_nanos) as f64 / 1e9,
                active_vertices: active,
                active_ratio: active as f64 / n.max(1) as f64,
                shards_processed: self.shards.len() as u32,
                shards_skipped: 0,
                io: io1.since(&io0),
                cache: Default::default(),
                ..Default::default()
            });
            if active == 0 {
                run.converged = true;
                break;
            }
        }
        run.total_wall = start.elapsed();
        run.total_sim_disk_seconds = (disk.snapshot().sim_nanos - sim_start) as f64 / 1e9;
        run.memory_bytes = self.memory_bytes();
        self.values = src;
        Ok(run)
    }

    fn values(&self) -> &[f32] {
        &self.values
    }

    fn memory_bytes(&self) -> u64 {
        // (C|V| + 2(C+D)|E|) / P
        (C_VERTEX * self.num_vertices as u64 + 2 * (C_VERTEX + D_EDGE) * self.num_edges)
            / self.shards.len().max(1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::PageRank;
    use crate::graph::rmat::{rmat, RmatParams};

    #[test]
    fn psw_io_matches_table3() {
        let g = rmat(9, 4_000, 71, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = PswEngine::new(BaselineConfig { p: 8, ..Default::default() });
        e.preprocess(&g, &disk).unwrap();
        disk.reset();
        let run = e.run(&PageRank::new(), 1, &disk).unwrap();
        let m = &run.iterations[0];
        let v = g.num_vertices as u64;
        let ed = g.num_edges();
        let expect = C_VERTEX * (v / e.shards.len() as u64) * e.shards.len() as u64
            + 2 * (C_VERTEX + D_EDGE) * ed;
        // reads and writes both ≈ C|V| + 2(C+D)|E| (integer division slack)
        assert!(
            (m.io.bytes_read as i64 - expect as i64).unsigned_abs() < v * C_VERTEX,
            "read {} vs {}",
            m.io.bytes_read,
            expect
        );
        assert!(
            (m.io.bytes_written as i64 - expect as i64).unsigned_abs() < v * C_VERTEX,
            "write {} vs {}",
            m.io.bytes_written,
            expect
        );
    }

    #[test]
    fn psw_prep_io_matches_c_plus_5d() {
        let g = rmat(8, 2_000, 73, RmatParams::default());
        let disk = Disk::unthrottled();
        let mut e = PswEngine::new(BaselineConfig::default());
        e.preprocess(&g, &disk).unwrap();
        let s = disk.snapshot();
        let de = D_EDGE * g.num_edges();
        let ce = C_VERTEX * g.num_edges();
        assert_eq!(s.bytes_read, 3 * de);
        assert_eq!(s.bytes_written, de + ce + de);
        // total = (C+5D)|E|
        assert_eq!(s.bytes_read + s.bytes_written, ce + 5 * de);
    }

    #[test]
    fn psw_requires_preprocess() {
        let disk = Disk::unthrottled();
        let mut e = PswEngine::new(BaselineConfig::default());
        assert!(e.run(&PageRank::new(), 1, &disk).is_err());
    }
}
