//! GraphMP command-line launcher.
//!
//! ```text
//! graphmp generate   --dataset twitter-sim --out /tmp/g.csv
//! graphmp preprocess --dataset twitter-sim --dir /tmp/g [--weighted]
//! graphmp run        --dir /tmp/g --app pagerank --iters 10
//!                    [--backend native|pjrt] [--cache-mode cache-3]
//!                    [--cache-mb 256] [--no-selective] [--disk hdd|ssd|none]
//! graphmp serve      --dir /tmp/g --socket /tmp/graphmp.sock
//! graphmp info       --dir /tmp/g
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use graphmp::apps::{Bfs, BfsLevels, Cc, KCore, PageRank, Ppr, Sssp, VertexProgram, Wcc, Widest};
use graphmp::cli::Args;
use graphmp::compress::CacheMode;
use graphmp::engine::{Backend, EngineConfig, VswEngine};
use graphmp::graph::datasets::Dataset;
use graphmp::prep::{preprocess_into, PrepConfig};
use graphmp::runtime::{CheckpointConfig, Manifest, NoValidCheckpoint, ShardExecutor};
use graphmp::storage::disk::{Disk, DiskProfile, IoBackendKind};
use graphmp::storage::io_backend::{make_backend, IoBackend};
use graphmp::storage::GraphDir;
use graphmp::util::{human_bytes, human_count, human_duration};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(&args),
        Some("preprocess") => cmd_preprocess(&args),
        Some("run") => cmd_run(&args),
        Some("resume") => cmd_resume(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            usage();
            return;
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        // "nothing to resume from" gets its own exit code so scripts can
        // tell it apart from a genuine failure
        let code = if e.downcast_ref::<NoValidCheckpoint>().is_some() { 3 } else { 1 };
        std::process::exit(code);
    }
}

fn usage() {
    eprintln!(
        "graphmp — I/O-efficient big graph analytics (GraphMP reproduction)

USAGE:
  graphmp generate   --dataset <name> --out <file.csv>
  graphmp preprocess --dataset <name> --dir <graphdir> [--weighted] [--undirected]
                     [--edges-per-shard N] [--small]
  graphmp run        --dir <graphdir>
                     --app pagerank|ppr|sssp|cc|bfs|widest|wcc|bfs_levels|kcore
                     [--iters N] [--source V] [--damping F] [--k N]
                                 (wcc/bfs_levels/kcore run on u32 value
                                  lanes: component labels, hop levels, and
                                  k-core membership; --k sets the core
                                  order for kcore, default 2)
                     [--jobs N]  (scan-shared batch: N concurrent queries
                                  share every shard pass; seeded apps offset
                                  --source by the job index, e.g. N PPR
                                  reset vectors — disk I/O per job ~1/N;
                                  N > 64 drains as multiple batches)
                     [--arrivals a0,a1,..|every:K]
                                 (staggered arrival schedule: job j joins
                                  its batch at pass a_j — admitted mid-batch
                                  without disturbing running jobs; every:K
                                  means job j arrives at pass j*K)
                     [--no-fanout] (keep member jobs serial per shard even
                                  when the worklist is shorter than the
                                  worker pool)
                     [--backend native|pjrt] [--artifacts DIR]
                     [--cache-mode cache-0..4] [--cache-mb N] [--no-selective]
                     [--workers N] [--disk hdd|ssd|none] [--no-prefetch]
                     [--prefetch-depth N|auto] [--prefetch-threads N]
                     [--io-backend sim|direct|direct,uring]
                                 (sim replays the profiled disk model —
                                  the default and the paper's regime;
                                  direct reads shards through O_DIRECT
                                  with batched submission, falling back
                                  to buffered + fadvise(DONTNEED) where
                                  the filesystem refuses O_DIRECT;
                                  direct,uring additionally drives the
                                  ring through io_uring when the binary
                                  was built with `--features uring`)
                     [--io-depth N] (in-flight read budget of the direct
                                  backend's submission ring and the shard
                                  pipeline; default 8 for direct)
                     [--memo-mb N]
                     [--checkpoint-dir D] [--checkpoint-every K]
                                 (crash safety: atomically persist the whole
                                  batch state into D every K pass boundaries;
                                  an interrupted run is picked up by
                                  `graphmp resume --checkpoint-dir D`)
  graphmp resume     --checkpoint-dir <D>
                                 (restore an interrupted checkpointed run:
                                  re-reads the original run arguments from
                                  D/run_args.txt, warm-starts from the newest
                                  valid checkpoint, finishes the drain —
                                  final values bit-identical to an
                                  uninterrupted run; exits 3 when D holds no
                                  valid checkpoint)
  graphmp serve      --dir <graphdir> --socket <path.sock>
                     [--queue-cap N] [--batch-cap N]
                     [--checkpoint-dir D] [--checkpoint-every K]
                     [--checkpoint-secs S] [--resume]
                                 (resident serving daemon: newline-delimited
                                  JSON over the Unix socket — ops submit /
                                  status / result / cancel / drain / metrics
                                  / ping.  Bounded admission queue with
                                  high|normal|low priorities; a full queue
                                  answers busy + retry_after_ms
                                  (backpressure); per-job deadline_passes /
                                  timeout_ms evict at pass boundaries as
                                  `expired`.  --checkpoint-dir adds
                                  background checkpointing of the in-flight
                                  batch (wall cadence via --checkpoint-secs)
                                  plus a durable queue roster; SIGINT or
                                  SIGTERM stops admitting, checkpoints or
                                  finishes the batch, and exits 0;
                                  `serve --resume` restores the queue and
                                  resumes the batch bit-identically)
  graphmp info       --dir <graphdir>

datasets: twitter-sim uk2007-sim uk2014-sim eu2015-sim"
    );
}

fn dataset(args: &Args) -> Result<Dataset> {
    let name = args.opt("dataset").context("--dataset required")?;
    Dataset::parse(name).with_context(|| format!("unknown dataset {name}"))
}

fn disk(args: &Args) -> Result<Disk> {
    let profile = match args.opt_or("disk", "hdd") {
        "ssd" => DiskProfile::ssd(),
        "none" => DiskProfile::unthrottled(),
        _ => DiskProfile::hdd_raid5(),
    };
    let kind = match args.opt("io-backend") {
        Some(spec) => {
            IoBackendKind::parse(spec).with_context(|| format!("bad --io-backend {spec}"))?
        }
        None => IoBackendKind::Sim,
    };
    let depth: usize = args.parse_opt_or("io-depth", 8usize)?;
    anyhow::ensure!(depth >= 1, "--io-depth must be at least 1");
    Ok(Disk::with_backend(profile, make_backend(kind, depth)))
}

fn cmd_generate(args: &Args) -> Result<()> {
    let ds = dataset(args)?;
    let out = PathBuf::from(args.opt("out").context("--out required")?);
    let g = if args.flag("small") { ds.generate_small() } else { ds.generate() };
    std::fs::write(&out, g.to_csv())?;
    println!(
        "wrote {}: |V|={} |E|={} -> {}",
        ds.name(),
        human_count(g.num_vertices as u64),
        human_count(g.num_edges()),
        out.display()
    );
    Ok(())
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let ds = dataset(args)?;
    let dir = PathBuf::from(args.opt("dir").context("--dir required")?);
    let disk = disk(args)?;
    let mut g = if args.flag("small") { ds.generate_small() } else { ds.generate() };
    if args.flag("undirected") {
        g = g.to_undirected();
    }
    let cfg = PrepConfig {
        edges_per_shard: args.parse_opt_or("edges-per-shard", 262_144u32)?,
        weighted: args.flag("weighted"),
        max_rows_per_shard: args.parse_opt_or("max-rows", 8_192u32)?,
        ..Default::default()
    };
    let t = std::time::Instant::now();
    let (_, report) = preprocess_into(&g, &dir, &disk, cfg)?;
    println!(
        "preprocessed {} into {} shards ({} edges, {} on disk) in {}",
        ds.name(),
        report.num_shards,
        human_count(report.num_edges),
        human_bytes(report.shard_bytes),
        human_duration(t.elapsed())
    );
    Ok(())
}

fn app_of(args: &Args) -> Result<Box<dyn VertexProgram>> {
    app_of_job(args, 0)
}

/// The app for batch member `job`: seeded apps (ppr/sssp/bfs/widest/
/// bfs_levels) offset their source vertex by the job index, so `--jobs N`
/// submits N distinct queries (e.g. N PPR reset vectors) over one graph.
fn app_of_job(args: &Args, job: u32) -> Result<Box<dyn VertexProgram>> {
    let source: u32 = args.parse_opt_or("source", 0u32)? + job;
    let damping: f32 = args.parse_opt_or("damping", 0.85f32)?;
    let k: u32 = args.parse_opt_or("k", 2u32)?;
    Ok(match args.opt_or("app", "pagerank") {
        "pagerank" => Box::new(PageRank { damping }),
        "ppr" => Box::new(Ppr { damping, seed: source }),
        "sssp" => Box::new(Sssp::new(source)),
        "cc" => Box::new(Cc),
        "bfs" => Box::new(Bfs::new(source)),
        "widest" => Box::new(Widest::new(source)),
        "wcc" => Box::new(Wcc),
        "bfs_levels" => Box::new(BfsLevels::new(source)),
        "kcore" => Box::new(KCore::new(k)),
        other => anyhow::bail!(
            "unknown app {other} (pagerank|ppr|sssp|cc|bfs|widest|wcc|bfs_levels|kcore)"
        ),
    })
}

/// Open the VSW engine exactly as `graphmp run` configures it (also the
/// path `graphmp resume` uses to rebuild the engine from the persisted
/// run arguments).
fn open_engine(args: &Args) -> Result<VswEngine> {
    let dir = GraphDir::new(args.opt("dir").context("--dir required")?);
    let disk = disk(args)?;

    let backend = match args.opt_or("backend", "native") {
        "native" => Backend::Native,
        "pjrt" => {
            let art = PathBuf::from(args.opt_or("artifacts", "artifacts"));
            let manifest = Manifest::load(&art)?;
            let prop = dir.read_property(&disk)?;
            let max_rows = prop
                .intervals
                .iter()
                .map(|&(a, b)| (b - a) as usize)
                .max()
                .unwrap_or(0);
            let variant = manifest
                .pick_variant(prop.num_vertices as usize, max_rows)
                .context("no AOT variant large enough; run `make artifacts`")?
                .to_string();
            println!("pjrt backend: variant={variant}");
            Backend::Pjrt(Arc::new(ShardExecutor::load(&art, &variant)?))
        }
        other => anyhow::bail!("unknown backend {other}"),
    };

    let defaults = EngineConfig::default();
    let prefetch_depth_opt = args.parse_auto_or("prefetch-depth", defaults.prefetch_depth)?;
    let cfg = EngineConfig {
        workers: args.parse_opt_or("workers", defaults.workers)?,
        cache_capacity: args.parse_opt_or("cache-mb", 256u64)? * 1024 * 1024,
        cache_mode: match args.opt("cache-mode") {
            Some(m) => Some(CacheMode::parse(m).with_context(|| format!("bad cache mode {m}"))?),
            None => None,
        },
        selective: !args.flag("no-selective"),
        active_threshold: args.parse_opt_or("active-threshold", 0.001f64)?,
        // `--prefetch-depth auto` self-tunes (None from parse_auto_or);
        // the fixed default then only seeds the first iteration
        prefetch_depth: if args.flag("no-prefetch") {
            0
        } else {
            prefetch_depth_opt.unwrap_or(defaults.prefetch_depth)
        },
        prefetch_auto: !args.flag("no-prefetch") && prefetch_depth_opt.is_none(),
        prefetch_threads: args.parse_opt_or("prefetch-threads", defaults.prefetch_threads)?,
        // 0 = inherit the disk backend's submission depth; an explicit
        // `--io-depth N` bounds both the backend ring (via `disk()`) and
        // the pipeline's in-flight read budget
        io_depth: if args.opt("io-depth").is_some() {
            args.parse_opt_or("io-depth", 0usize)?
        } else {
            0
        },
        decode_memo_budget: args
            .parse_opt_or("memo-mb", defaults.decode_memo_budget / (1024 * 1024))?
            * 1024
            * 1024,
        fan_out: !args.flag("no-fanout"),
        backend,
    };
    let engine = VswEngine::open(&dir, &disk, cfg)?;
    println!(
        "graph: |V|={} |E|={} shards={} cache={} io={}",
        human_count(engine.property().num_vertices as u64),
        human_count(engine.property().num_edges),
        engine.property().num_shards,
        engine.cache().mode().name(),
        engine.disk().backend().kind().name(),
    );
    Ok(engine)
}

fn cmd_run(args: &Args) -> Result<()> {
    let app = app_of(args)?;
    let iters: u32 = args.parse_opt_or("iters", 10u32)?;
    let mut engine = open_engine(args)?;
    let jobs: u32 = args.parse_opt_or("jobs", 1u32)?;
    anyhow::ensure!(jobs >= 1, "--jobs must be at least 1 (got 0)");
    let ckpt = match args.opt("checkpoint-dir") {
        Some(d) => {
            let every: u32 = args.parse_opt_or("checkpoint-every", 4u32)?;
            Some(CheckpointConfig::new(d, every))
        }
        None => None,
    };
    if jobs > 1 || args.opt("arrivals").is_some() || ckpt.is_some() {
        return run_batched(args, &mut engine, jobs, iters, BatchMode::Run(ckpt));
    }
    let run = engine.run(app.as_ref(), iters)?;
    for m in &run.iterations {
        println!(
            "iter {:>3}: {:>9.3}s  active={:<9} processed={:<4} skipped={:<4} overlap={:>6.3}s read={}",
            m.iteration,
            m.elapsed_seconds(),
            m.active_vertices,
            m.shards_processed,
            m.shards_skipped,
            m.overlapped_sim_seconds,
            human_bytes(m.io.bytes_read),
        );
    }
    println!(
        "total: {:.3}s ({} iterations{}), memory {}",
        run.total_seconds(),
        run.iterations.len(),
        if run.converged { ", converged" } else { "" },
        human_bytes(run.memory_bytes),
    );
    println!("{}", graphmp::benchutil::pipeline_summary(&run));
    Ok(())
}

/// Parse `--arrivals`: either a comma-separated list of per-job arrival
/// passes (length must equal `--jobs`) or `every:K` for a uniform
/// stagger (job j arrives at pass j·K).
fn parse_arrivals(spec: &str, jobs: u32) -> Result<Vec<u32>> {
    if let Some(step) = spec.strip_prefix("every:") {
        let k: u32 = step
            .parse()
            .with_context(|| format!("bad --arrivals stagger step {step}"))?;
        return Ok((0..jobs).map(|j| j.saturating_mul(k)).collect());
    }
    let passes: Vec<u32> = spec
        .split(',')
        .map(|p| {
            p.trim()
                .parse::<u32>()
                .with_context(|| format!("bad --arrivals entry {p}"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        passes.len() == jobs as usize,
        "--arrivals lists {} passes for --jobs {jobs}",
        passes.len()
    );
    Ok(passes)
}

/// How a batched run executes: plain, checkpointed, or resumed from a
/// checkpoint directory.
enum BatchMode {
    Run(Option<CheckpointConfig>),
    Resume(CheckpointConfig),
}

/// `graphmp resume --checkpoint-dir D`: restore an interrupted
/// checkpointed run.  The original `run` invocation's arguments were
/// persisted into `D/run_args.txt`; resume re-parses them, rebuilds the
/// same engine and job set, and warm-starts from the newest valid
/// checkpoint — the remainder of the run is bit-identical to the
/// uninterrupted one.
fn cmd_resume(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.opt("checkpoint-dir").context("--checkpoint-dir required")?);
    let path = dir.join("run_args.txt");
    let text = match std::fs::read_to_string(&path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            // No persisted run args.  Probe the directory to tell "nothing
            // was ever checkpointed here" (typed NoValidCheckpoint, exit 3,
            // listing any swept .tmp_* candidates) apart from a checkpoint
            // that lost its run_args.txt.
            let outcome = graphmp::runtime::checkpoint::load_latest(&dir, &Disk::unthrottled())?;
            return match outcome.loaded {
                Some(_) => Err(e).with_context(|| {
                    format!(
                        "read {} (checkpoint found, but the run arguments are gone)",
                        path.display()
                    )
                }),
                None => Err(NoValidCheckpoint { dir, rejected: outcome.rejected }.into()),
            };
        }
        Err(e) => {
            return Err(e).with_context(|| format!("read {}", path.display()));
        }
    };
    let stored = Args::parse(text.lines().map(str::to_string))?;
    let every: u32 = stored.parse_opt_or("checkpoint-every", 4u32)?;
    let cfg = CheckpointConfig::new(dir, every);
    let mut engine = open_engine(&stored)?;
    let jobs: u32 = stored.parse_opt_or("jobs", 1u32)?;
    let iters: u32 = stored.parse_opt_or("iters", 10u32)?;
    run_batched(&stored, &mut engine, jobs, iters, BatchMode::Resume(cfg))
}

/// `graphmp run --jobs N`: submit N concurrent queries through the
/// scan-shared job runtime — one shard pass per iteration serves the
/// whole batch, so effective disk I/O per query falls as ~1/N.  With
/// `--arrivals`, jobs join mid-batch at their scheduled pass instead of
/// all starting together.
fn run_batched(
    args: &Args,
    engine: &mut VswEngine,
    jobs: u32,
    iters: u32,
    mode: BatchMode,
) -> Result<()> {
    use graphmp::exec::MAX_BATCH_JOBS;
    use graphmp::runtime::{JobSet, JobSpec, JobStatus};
    if jobs as usize > MAX_BATCH_JOBS {
        println!(
            "note: {jobs} jobs exceed the {MAX_BATCH_JOBS}-job batch cap; \
             draining as {} scan-shared batches",
            (jobs as usize).div_ceil(MAX_BATCH_JOBS)
        );
    }
    let arrivals = match args.opt("arrivals") {
        Some(spec) => parse_arrivals(spec, jobs)?,
        None => vec![0; jobs as usize],
    };
    let mut set = JobSet::new();
    for j in 0..jobs {
        let app = app_of_job(args, j)?;
        let label = format!("{}#{j}", app.name());
        set.submit_at(arrivals[j as usize], JobSpec { label, app, max_iters: iters });
    }
    // persist the run's arguments next to the checkpoints so `graphmp
    // resume --checkpoint-dir D` can rebuild the same engine and job set
    if let BatchMode::Run(Some(cfg)) = &mode {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("create checkpoint dir {}", cfg.dir.display()))?;
        let argv: Vec<String> = std::env::args().skip(1).collect();
        std::fs::write(cfg.dir.join("run_args.txt"), argv.join("\n"))
            .with_context(|| format!("persist run args into {}", cfg.dir.display()))?;
    }
    let report = match &mode {
        BatchMode::Run(None) => set.run_all(engine)?,
        BatchMode::Run(Some(cfg)) => set.run_all_checkpointed(engine, cfg)?,
        BatchMode::Resume(cfg) => set.resume(engine, cfg)?,
    };
    for job in set.jobs() {
        let run = job.run.as_ref().expect("run_all fills every job");
        println!(
            "job {:>3} {:<12} {:>9} arrive={:<3} iters={:<3} compute={:>8.3}ms \
             shards={:<5} edges={:<9} read/job={}",
            job.id,
            job.spec.label,
            match job.status {
                JobStatus::Converged => "converged",
                JobStatus::IterLimit => "iter-limit",
                JobStatus::Failed => "failed",
                _ => "unfinished",
            },
            run.job.admitted_pass,
            run.iterations.len(),
            run.job.compute.as_secs_f64() * 1e3,
            run.job.units_served,
            human_count(run.job.edges_processed),
            human_bytes(run.job.effective_bytes_read as u64),
        );
    }
    for b in &report.batches {
        println!("{}", graphmp::benchutil::batch_summary(b));
    }
    println!(
        "batch total: {} read for {} jobs ({:.2}x shard-load amortization)",
        human_bytes(report.bytes_read()),
        jobs,
        report.shard_loads_amortized(),
    );
    let agg = report.aggregate();
    if agg.checkpoints_written > 0 || matches!(mode, BatchMode::Resume(_)) {
        println!(
            "checkpoints: {} written ({}){}",
            agg.checkpoints_written,
            human_bytes(agg.checkpoint_bytes),
            match agg.resumed_from_pass {
                Some(p) => format!(", resumed from pass {p}"),
                None => String::new(),
            }
        );
    }
    if agg.jobs_failed > 0 {
        println!("jobs failed in isolation: {}", agg.jobs_failed);
    }
    Ok(())
}

/// `graphmp serve`: run the resident serving daemon over one
/// preprocessed graph dir.  Requests arrive over the Unix socket as
/// newline-delimited JSON; the daemon exits 0 on drain or on a graceful
/// SIGINT/SIGTERM shutdown.
fn cmd_serve(args: &Args) -> Result<()> {
    use graphmp::runtime::serve::{install_signal_handlers, ServeConfig, ServeDaemon};
    let socket = PathBuf::from(args.opt("socket").context("--socket required")?);
    let checkpoint = match args.opt("checkpoint-dir") {
        Some(d) => {
            let mut cfg = CheckpointConfig::new(d, args.parse_opt_or("checkpoint-every", 4u32)?);
            cfg.every_secs = args.parse_opt::<f64>("checkpoint-secs")?;
            Some(cfg)
        }
        None => {
            anyhow::ensure!(
                !args.flag("resume"),
                "serve --resume requires --checkpoint-dir"
            );
            None
        }
    };
    let cfg = ServeConfig {
        socket: Some(socket.clone()),
        queue_cap: args.parse_opt_or("queue-cap", 256usize)?,
        batch_cap: args.parse_opt_or("batch-cap", graphmp::exec::MAX_BATCH_JOBS)?,
        checkpoint,
        resume: args.flag("resume"),
    };
    let mut engine = open_engine(args)?;
    install_signal_handlers();
    let mut daemon = ServeDaemon::new(cfg);
    println!("serving on {}", socket.display());
    let summary = daemon.run(&mut engine)?;
    let m = &summary.metrics;
    println!(
        "serve: {} submitted, {} completed, {} expired, {} cancelled, {} failed, \
         {} rejected (backpressure) over {} batches; {} checkpoints written, {} failed",
        m.submitted,
        m.completed,
        m.expired,
        m.cancelled,
        m.failed,
        m.rejected,
        m.batches,
        m.checkpoints_written,
        m.checkpoints_failed,
    );
    for p in graphmp::runtime::Priority::ALL {
        let c = &m.per_class[p.index()];
        if c.submitted > 0 {
            println!(
                "  class {:<6} submitted={:<4} completed={:<4} mean latency {:.1} ms, max {:.1} ms",
                p.name(),
                c.submitted,
                c.completed,
                c.mean_latency().as_secs_f64() * 1e3,
                c.max_latency.as_secs_f64() * 1e3,
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_parse_list_and_stagger() {
        assert_eq!(parse_arrivals("0,2,5", 3).unwrap(), vec![0, 2, 5]);
        assert_eq!(parse_arrivals("every:3", 4).unwrap(), vec![0, 3, 6, 9]);
        assert!(parse_arrivals("0,2", 3).is_err(), "length must match --jobs");
        assert!(parse_arrivals("every:x", 2).is_err());
        assert!(parse_arrivals("1,zap", 2).is_err());
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = GraphDir::new(args.opt("dir").context("--dir required")?);
    let disk = Disk::unthrottled();
    let prop = dir.read_property(&disk)?;
    let info = dir.read_vertex_info(&disk)?;
    println!("graph dir: {}", dir.root.display());
    println!("  vertices: {}", human_count(prop.num_vertices as u64));
    println!("  edges:    {}", human_count(prop.num_edges));
    println!("  shards:   {}", prop.num_shards);
    println!("  weighted: {}", prop.weighted);
    let max_in = info.in_degree.iter().copied().max().unwrap_or(0);
    let max_out = info.out_degree.iter().copied().max().unwrap_or(0);
    println!("  max in-degree: {max_in}, max out-degree: {max_out}");
    let widths: Vec<u32> = prop.intervals.iter().map(|&(a, b)| b - a).collect();
    println!(
        "  interval width: min={} max={}",
        widths.iter().min().unwrap(),
        widths.iter().max().unwrap()
    );
    Ok(())
}
