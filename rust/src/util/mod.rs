//! Small shared utilities: seeded RNG, varints, byte casts, formatting.
//!
//! The vendored crate set has no `rand`, `serde` or `byteorder`, so the
//! pieces we need are implemented here and unit-tested below.

pub mod rng;
pub mod varint;

use std::time::Duration;

/// Reinterpret a `u32` slice as little-endian bytes (all targets we build
/// for are little-endian; asserted in `storage::shard`).
pub fn u32s_as_bytes(v: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Inverse of [`u32s_as_bytes`].
pub fn bytes_as_u32s(b: &[u8]) -> Vec<u32> {
    assert!(b.len() % 4 == 0, "byte length {} not a multiple of 4", b.len());
    b.chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

pub fn f32s_as_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_as_f32s(b: &[u8]) -> Vec<f32> {
    assert!(b.len() % 4 == 0);
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// `1234567` -> `"1.23M"` — used by the bench tables.
pub fn human_count(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.2}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        format!("{}", n)
    }
}

/// `1536` -> `"1.5KiB"`.
pub fn human_bytes(n: u64) -> String {
    const U: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = n as f64;
    let mut i = 0;
    while x >= 1024.0 && i < U.len() - 1 {
        x /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{}B", n)
    } else {
        format!("{:.2}{}", x, U[i])
    }
}

pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 60.0 {
        format!("{:.2}min", s / 60.0)
    } else if s >= 1.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.2}ms", s * 1e3)
    }
}

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u32_bytes_round_trip() {
        let v = vec![0u32, 1, 0xdead_beef, u32::MAX];
        assert_eq!(bytes_as_u32s(&u32s_as_bytes(&v)), v);
    }

    #[test]
    fn f32_bytes_round_trip() {
        let v = vec![0.0f32, -1.5, f32::INFINITY, 3.25e9];
        assert_eq!(bytes_as_f32s(&f32s_as_bytes(&v)), v);
    }

    #[test]
    #[should_panic(expected = "multiple of 4")]
    fn bytes_as_u32s_rejects_ragged() {
        bytes_as_u32s(&[1, 2, 3]);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1_500_000), "1.50M");
        assert_eq!(human_count(2_000_000_000), "2.00B");
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(1536), "1.50KiB");
    }

    #[test]
    fn round_up_cases() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }
}
