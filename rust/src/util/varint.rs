//! LEB128 varints — used by the delta-varint edge codec (`compress::delta`).

/// Append `x` as LEB128 to `out`.
#[inline]
pub fn write_u64(out: &mut Vec<u8>, mut x: u64) {
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Read one LEB128 varint from `buf[*pos..]`, advancing `pos`.
/// Returns `None` on truncated or >10-byte input.
#[inline]
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Option<u64> {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos)?;
        *pos += 1;
        if shift >= 64 {
            return None;
        }
        x |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some(x);
        }
        shift += 7;
    }
}

/// ZigZag-encode a signed delta so small negatives stay small.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_edge_values() {
        let cases = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &c in &cases {
            let mut buf = Vec::new();
            write_u64(&mut buf, c);
            let mut pos = 0;
            assert_eq!(read_u64(&buf, &mut pos), Some(c));
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn truncated_returns_none() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 1u64 << 40);
        buf.pop();
        let mut pos = 0;
        assert_eq!(read_u64(&buf, &mut pos), None);
    }

    #[test]
    fn zigzag_round_trip() {
        for v in [-5i64, -1, 0, 1, 1 << 40, i64::MIN, i64::MAX] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn zigzag_small_negatives_small() {
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
    }

    #[test]
    fn stream_of_varints() {
        let vals: Vec<u64> = (0..1000).map(|i| i * 37 % 9973).collect();
        let mut buf = Vec::new();
        for &v in &vals {
            write_u64(&mut buf, v);
        }
        let mut pos = 0;
        let got: Vec<u64> = (0..1000)
            .map(|_| read_u64(&buf, &mut pos).unwrap())
            .collect();
        assert_eq!(got, vals);
    }
}
