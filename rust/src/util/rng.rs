//! Seeded xoshiro256** RNG — deterministic graph generation without `rand`.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, bound)`; bound > 0. Lemire-style rejection-free
    /// multiply-shift (tiny bias is irrelevant for graph generation).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn next_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_f64() as f32) * (hi - lo)
    }
}

/// One-shot splitmix64 hash, used by the Bloom filter's double hashing.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn below_covers_range_roughly_uniformly() {
        let mut r = Xoshiro256::new(11);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.next_below(8) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }
}
