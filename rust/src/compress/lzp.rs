//! A fast byte-oriented LZ codec — the snappy stand-in for cache mode 2.
//!
//! Greedy LZ77 with a 64Ki-entry hash table over 4-byte windows, emitting a
//! token stream in a snappy-like framing:
//!
//! ```text
//! header: varint decompressed_len
//! tokens: literal  = 0x00, varint len, bytes
//!         match    = 0x01, varint len, varint distance
//! ```
//!
//! Like snappy it trades ratio for speed: single pass, no entropy coding.

use anyhow::Result;

use crate::util::varint;

const MIN_MATCH: usize = 4;
const HASH_BITS: u32 = 16;

#[inline]
fn hash4(b: &[u8]) -> usize {
    let x = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (x.wrapping_mul(0x9e37_79b1) >> (32 - HASH_BITS)) as usize
}

pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    varint::write_u64(&mut out, data.len() as u64);
    if data.is_empty() {
        return out;
    }
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i + MIN_MATCH <= data.len() {
        let h = hash4(&data[i..]);
        let cand = table[h];
        table[h] = i;
        if cand != usize::MAX && data[cand..cand + MIN_MATCH] == data[i..i + MIN_MATCH] {
            // extend the match
            let mut len = MIN_MATCH;
            while i + len < data.len() && data[cand + len] == data[i + len] {
                len += 1;
            }
            flush_literals(&mut out, &data[lit_start..i]);
            out.push(0x01);
            varint::write_u64(&mut out, len as u64);
            varint::write_u64(&mut out, (i - cand) as u64);
            // index a few positions inside the match so later data can
            // reference it (snappy skips this; indexing every 4th position
            // is a cheap ratio win on shard byte streams)
            let end = i + len;
            let mut j = i + 1;
            while j + MIN_MATCH <= end.min(data.len()) {
                table[hash4(&data[j..])] = j;
                j += 4;
            }
            i = end;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    flush_literals(&mut out, &data[lit_start..]);
    out
}

fn flush_literals(out: &mut Vec<u8>, lits: &[u8]) {
    if lits.is_empty() {
        return;
    }
    out.push(0x00);
    varint::write_u64(out, lits.len() as u64);
    out.extend_from_slice(lits);
}

pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    let mut pos = 0usize;
    let total = varint::read_u64(data, &mut pos)
        .ok_or_else(|| anyhow::anyhow!("lzp: bad header"))? as usize;
    let mut out = Vec::with_capacity(total);
    while pos < data.len() {
        let tag = data[pos];
        pos += 1;
        match tag {
            0x00 => {
                let len = varint::read_u64(data, &mut pos)
                    .ok_or_else(|| anyhow::anyhow!("lzp: bad literal len"))?
                    as usize;
                anyhow::ensure!(pos + len <= data.len(), "lzp: literal overrun");
                out.extend_from_slice(&data[pos..pos + len]);
                pos += len;
            }
            0x01 => {
                let len = varint::read_u64(data, &mut pos)
                    .ok_or_else(|| anyhow::anyhow!("lzp: bad match len"))?
                    as usize;
                let dist = varint::read_u64(data, &mut pos)
                    .ok_or_else(|| anyhow::anyhow!("lzp: bad match dist"))?
                    as usize;
                anyhow::ensure!(dist > 0 && dist <= out.len(), "lzp: bad distance {dist}");
                // overlapping copy (dist may be < len)
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            t => anyhow::bail!("lzp: unknown tag {t}"),
        }
    }
    anyhow::ensure!(out.len() == total, "lzp: length {} != header {}", out.len(), total);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(data: &[u8]) {
        let c = compress(data);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn empty() {
        round_trip(&[]);
    }

    #[test]
    fn short_literals() {
        round_trip(b"abc");
    }

    #[test]
    fn repetitive_compresses_well() {
        let data = b"abcdabcdabcdabcdabcdabcdabcdabcd".repeat(100);
        let c = compress(&data);
        assert!(c.len() < data.len() / 4, "{} vs {}", c.len(), data.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn overlapping_match_rle() {
        let data = vec![7u8; 10_000];
        let c = compress(&data);
        assert!(c.len() < 100);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn csr_like_stream_compresses() {
        // CSR col arrays repeat hub ids — byte-level matches LZ can find.
        // (A pure arithmetic progression is *not* LZ-compressible; that
        // case belongs to the delta codec.)
        let mut data = Vec::new();
        for row in 0..5_000u32 {
            for j in 0..10u32 {
                let hub = (row % 16) * 1000 + j; // repeating neighbour sets
                data.extend_from_slice(&hub.to_le_bytes());
            }
        }
        let c = compress(&data);
        assert!(c.len() < data.len() / 2, "{} vs {}", c.len(), data.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn random_bytes_round_trip() {
        let mut x = 1u64;
        let data: Vec<u8> = (0..10_000)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                (x >> 33) as u8
            })
            .collect();
        round_trip(&data);
    }

    #[test]
    fn rejects_corrupt_stream() {
        let c = compress(b"hello world hello world hello world");
        // corrupt the header length
        let mut bad = c.clone();
        bad[0] ^= 0x7f;
        assert!(decompress(&bad).is_err());
        // truncate mid-token
        assert!(decompress(&c[..c.len() - 2]).is_err());
    }

    #[test]
    fn rejects_unknown_tag() {
        let mut buf = Vec::new();
        crate::util::varint::write_u64(&mut buf, 4);
        buf.push(0x99);
        assert!(decompress(&buf).is_err());
    }
}
