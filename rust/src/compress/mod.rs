//! Compression codecs for the compressed edge cache (paper §2.4.2).
//!
//! The paper uses snappy, zlib-1 and zlib-3 (cache modes 2/3/4).  snappy is
//! not in the vendored crate set; on CSR shard bytes the graph-aware
//! delta-varint codec ([`delta`]) lands in exactly snappy's class (ratio ≈
//! 1.7–2.2, decompression ≈ 2–4× zlib's speed — Table 2 bench), so mode 2
//! uses it (with the byte-LZ [`lzp`] as fallback for non-u32-aligned
//! payloads).  Modes 3/4 are the real zlib via `flate2`.

pub mod delta;
pub mod lzp;

use anyhow::Result;

/// The five cache modes of §2.4.2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CacheMode {
    /// Mode 0: no edge cache (system page cache only).
    M0None,
    /// Mode 1: cache uncompressed shards.
    M1Raw,
    /// Mode 2: fast LZ (snappy stand-in).
    M2Fast,
    /// Mode 3: zlib level 1.
    M3Zlib1,
    /// Mode 4: zlib level 3.
    M4Zlib3,
}

pub const ALL_MODES: [CacheMode; 5] = [
    CacheMode::M0None,
    CacheMode::M1Raw,
    CacheMode::M2Fast,
    CacheMode::M3Zlib1,
    CacheMode::M4Zlib3,
];

impl CacheMode {
    pub fn name(&self) -> &'static str {
        match self {
            CacheMode::M0None => "cache-0",
            CacheMode::M1Raw => "cache-1",
            CacheMode::M2Fast => "cache-2",
            CacheMode::M3Zlib1 => "cache-3",
            CacheMode::M4Zlib3 => "cache-4",
        }
    }

    pub fn parse(s: &str) -> Option<CacheMode> {
        ALL_MODES.into_iter().find(|m| m.name() == s)
    }

    /// Estimated compression ratios γᵢ for the §2.4.2 selection rule.
    /// The paper uses γ = 1,2,4,5 (measured on its web crawls); RMAT sim
    /// shards are less locality-rich, so these are calibrated from the
    /// Table 2 bench on the sim datasets instead.
    pub fn estimated_ratio(&self) -> f64 {
        match self {
            CacheMode::M0None => 1.0,
            CacheMode::M1Raw => 1.0,
            CacheMode::M2Fast => 1.7,
            CacheMode::M3Zlib1 => 1.9,
            CacheMode::M4Zlib3 => 2.0,
        }
    }

    pub fn compress(&self, data: &[u8]) -> Vec<u8> {
        match self {
            CacheMode::M0None | CacheMode::M1Raw => data.to_vec(),
            CacheMode::M2Fast => {
                // delta-varint for u32-aligned shard payloads (tag 1),
                // byte-LZ fallback otherwise (tag 0)
                if data.len() % 4 == 0 {
                    let mut out = delta::compress_bytes(data).expect("aligned");
                    out.push(1);
                    out
                } else {
                    let mut out = lzp::compress(data);
                    out.push(0);
                    out
                }
            }
            CacheMode::M3Zlib1 => zlib_compress(data, 1),
            CacheMode::M4Zlib3 => zlib_compress(data, 3),
        }
    }

    pub fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        match self {
            CacheMode::M0None | CacheMode::M1Raw => Ok(data.to_vec()),
            CacheMode::M2Fast => {
                let (tag, body) = data
                    .split_last()
                    .ok_or_else(|| anyhow::anyhow!("fast codec: empty payload"))?;
                match tag {
                    1 => delta::decompress_bytes(body),
                    0 => lzp::decompress(body),
                    t => anyhow::bail!("fast codec: unknown tag {t}"),
                }
            }
            CacheMode::M3Zlib1 | CacheMode::M4Zlib3 => zlib_decompress(data),
        }
    }

    /// Inflate `data` directly into `out`, whose exact uncompressed size
    /// the caller knows (the edge cache stores each entry's raw length).
    /// This is the decompress path of the decode-once lifecycle: the
    /// zlib modes stream into the aligned buffer and the delta codec
    /// writes its u32s in place, so the old inflate-to-`Vec`-then-copy
    /// double pass is gone.  The byte-LZ fallback of mode 2 still
    /// routes through a `Vec` — shard payloads are always u32-aligned,
    /// so that branch never serves shards.
    pub fn decompress_into(&self, data: &[u8], out: &mut [u8]) -> Result<()> {
        match self {
            CacheMode::M0None | CacheMode::M1Raw => {
                anyhow::ensure!(
                    data.len() == out.len(),
                    "raw entry length {} != expected {}",
                    data.len(),
                    out.len()
                );
                out.copy_from_slice(data);
                Ok(())
            }
            CacheMode::M2Fast => {
                let (tag, body) = data
                    .split_last()
                    .ok_or_else(|| anyhow::anyhow!("fast codec: empty payload"))?;
                match tag {
                    1 => delta::decompress_bytes_into(body, out),
                    0 => {
                        let raw = lzp::decompress(body)?;
                        anyhow::ensure!(
                            raw.len() == out.len(),
                            "lzp entry length {} != expected {}",
                            raw.len(),
                            out.len()
                        );
                        out.copy_from_slice(&raw);
                        Ok(())
                    }
                    t => anyhow::bail!("fast codec: unknown tag {t}"),
                }
            }
            CacheMode::M3Zlib1 | CacheMode::M4Zlib3 => {
                use flate2::read::ZlibDecoder;
                use std::io::Read;
                let mut dec = ZlibDecoder::new(data);
                dec.read_exact(out).map_err(|e| {
                    anyhow::anyhow!("zlib entry shorter than expected {}: {e}", out.len())
                })?;
                anyhow::ensure!(
                    dec.read(&mut [0u8; 1])? == 0,
                    "zlib entry longer than expected {}",
                    out.len()
                );
                Ok(())
            }
        }
    }
}

fn zlib_compress(data: &[u8], level: u32) -> Vec<u8> {
    use flate2::write::ZlibEncoder;
    use std::io::Write;
    let mut enc = ZlibEncoder::new(
        Vec::with_capacity(data.len() / 2),
        flate2::Compression::new(level),
    );
    enc.write_all(data).expect("in-memory zlib write");
    enc.finish().expect("in-memory zlib finish")
}

fn zlib_decompress(data: &[u8]) -> Result<Vec<u8>> {
    use flate2::read::ZlibDecoder;
    use std::io::Read;
    let mut out = Vec::with_capacity(data.len() * 3);
    ZlibDecoder::new(data).read_to_end(&mut out)?;
    Ok(out)
}

/// §2.4.2 automatic mode selection: the smallest `i` with `S/γᵢ ≤ C`,
/// falling back to the highest-ratio mode when nothing fits.
pub fn select_mode(graph_bytes: u64, cache_capacity: u64) -> CacheMode {
    if cache_capacity == 0 {
        return CacheMode::M0None;
    }
    for mode in [
        CacheMode::M1Raw,
        CacheMode::M2Fast,
        CacheMode::M3Zlib1,
        CacheMode::M4Zlib3,
    ] {
        if (graph_bytes as f64 / mode.estimated_ratio()) <= cache_capacity as f64 {
            return mode;
        }
    }
    CacheMode::M4Zlib3
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_like_payload() -> Vec<u8> {
        // Sorted-ish u32 ids: realistic shard bytes, compressible.
        let mut out = Vec::new();
        let mut x = 0u32;
        for i in 0..20_000u32 {
            x += i % 7;
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    #[test]
    fn all_modes_round_trip() {
        let data = shard_like_payload();
        for m in ALL_MODES {
            let c = m.compress(&data);
            assert_eq!(m.decompress(&c).unwrap(), data, "{}", m.name());
        }
    }

    #[test]
    fn zlib_compresses_shard_bytes() {
        let data = shard_like_payload();
        let c3 = CacheMode::M3Zlib1.compress(&data);
        let c4 = CacheMode::M4Zlib3.compress(&data);
        assert!(c3.len() < data.len() / 2);
        assert!(c4.len() <= c3.len() + c3.len() / 10);
    }

    #[test]
    fn decompress_into_matches_vec_path_in_every_mode() {
        let data = shard_like_payload();
        for m in ALL_MODES {
            let c = m.compress(&data);
            let mut out = vec![0u8; data.len()];
            m.decompress_into(&c, &mut out).unwrap();
            assert_eq!(out, data, "{}", m.name());
            // a wrong expected size is an error in every mode
            let mut short = vec![0u8; data.len() - 4];
            assert!(m.decompress_into(&c, &mut short).is_err(), "{}", m.name());
            let mut long = vec![0u8; data.len() + 4];
            assert!(m.decompress_into(&c, &mut long).is_err(), "{}", m.name());
        }
    }

    #[test]
    fn mode_names_round_trip() {
        for m in ALL_MODES {
            assert_eq!(CacheMode::parse(m.name()), Some(m));
        }
    }

    #[test]
    fn select_mode_prefers_lowest_fitting() {
        // Graph 100 bytes: capacity 200 fits raw (γ=1)
        assert_eq!(select_mode(100, 200), CacheMode::M1Raw);
        // capacity 55: needs γ >= 1.82 => zlib-1 (γ=1.9)
        assert_eq!(select_mode(100, 55), CacheMode::M3Zlib1);
        // capacity 59: fast codec (γ=1.7) fits
        assert_eq!(select_mode(100, 59), CacheMode::M2Fast);
        // capacity 10: nothing fits => highest ratio
        assert_eq!(select_mode(100, 10), CacheMode::M4Zlib3);
        // zero capacity => no cache
        assert_eq!(select_mode(100, 0), CacheMode::M0None);
    }

    #[test]
    fn empty_input_ok() {
        for m in ALL_MODES {
            assert_eq!(m.decompress(&m.compress(&[])).unwrap(), Vec::<u8>::new());
        }
    }

    #[test]
    fn incompressible_data_round_trips() {
        let mut x = 0x12345678u32;
        let data: Vec<u8> = (0..4096)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect();
        for m in ALL_MODES {
            assert_eq!(m.decompress(&m.compress(&data)).unwrap(), data);
        }
    }
}
