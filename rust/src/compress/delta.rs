//! Graph-aware delta-varint codec for CSR shard payloads (Table 2
//! ablation).
//!
//! CSR `row_offsets` are non-decreasing and `col` ids cluster by locality;
//! zigzag-delta + LEB128 exploits both, beating byte-oriented codecs on
//! ratio for unweighted shards at near-memcpy speed.  Operates on u32
//! streams (the shard serialisation), not arbitrary bytes.

use anyhow::Result;

use crate::util::varint;

/// Encode a u32 slice as zigzag deltas.
pub fn encode_u32s(vals: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() + 8);
    varint::write_u64(&mut out, vals.len() as u64);
    let mut prev = 0i64;
    for &v in vals {
        let d = v as i64 - prev;
        varint::write_u64(&mut out, varint::zigzag(d));
        prev = v as i64;
    }
    out
}

pub fn decode_u32s(data: &[u8]) -> Result<Vec<u32>> {
    let mut pos = 0usize;
    let n = varint::read_u64(data, &mut pos)
        .ok_or_else(|| anyhow::anyhow!("delta: bad header"))? as usize;
    let mut out = Vec::with_capacity(n);
    let mut prev = 0i64;
    for _ in 0..n {
        let z = varint::read_u64(data, &mut pos)
            .ok_or_else(|| anyhow::anyhow!("delta: truncated"))?;
        let v = prev + varint::unzigzag(z);
        anyhow::ensure!((0..=u32::MAX as i64).contains(&v), "delta: value {v} out of range");
        out.push(v as u32);
        prev = v;
    }
    anyhow::ensure!(pos == data.len(), "delta: {} trailing bytes", data.len() - pos);
    Ok(out)
}

/// Whole-byte-buffer adapter (reinterprets as u32s): lets the delta codec
/// plug into the same bench harness as the byte codecs. Input length must
/// be a multiple of 4 — shard files always are.
pub fn compress_bytes(data: &[u8]) -> Result<Vec<u8>> {
    anyhow::ensure!(data.len() % 4 == 0, "delta: payload not u32-aligned");
    Ok(encode_u32s(&crate::util::bytes_as_u32s(data)))
}

pub fn decompress_bytes(data: &[u8]) -> Result<Vec<u8>> {
    Ok(crate::util::u32s_as_bytes(&decode_u32s(data)?))
}

/// Decode directly into a caller-sized output buffer (the cache knows
/// every entry's raw length): each u32 is written to its final position
/// as it is decoded, with no intermediate `Vec` allocation or copy.
pub fn decompress_bytes_into(data: &[u8], out: &mut [u8]) -> Result<()> {
    anyhow::ensure!(out.len() % 4 == 0, "delta: output not u32-aligned");
    let mut pos = 0usize;
    let n = varint::read_u64(data, &mut pos)
        .ok_or_else(|| anyhow::anyhow!("delta: bad header"))? as usize;
    anyhow::ensure!(
        n == out.len() / 4,
        "delta: entry holds {n} u32s, expected {}",
        out.len() / 4
    );
    let mut prev = 0i64;
    for slot in out.chunks_exact_mut(4) {
        let z = varint::read_u64(data, &mut pos)
            .ok_or_else(|| anyhow::anyhow!("delta: truncated"))?;
        let v = prev + varint::unzigzag(z);
        anyhow::ensure!((0..=u32::MAX as i64).contains(&v), "delta: value {v} out of range");
        slot.copy_from_slice(&(v as u32).to_le_bytes());
        prev = v;
    }
    anyhow::ensure!(pos == data.len(), "delta: {} trailing bytes", data.len() - pos);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_sorted() {
        let vals: Vec<u32> = (0..10_000).map(|i| i * 2 + 5).collect();
        let enc = encode_u32s(&vals);
        assert_eq!(decode_u32s(&enc).unwrap(), vals);
        // sorted deltas are tiny: ~1 byte each
        assert!(enc.len() < vals.len() * 2, "{} bytes", enc.len());
    }

    #[test]
    fn round_trip_unsorted() {
        let vals = vec![5u32, 0, u32::MAX, 17, 17, 3];
        assert_eq!(decode_u32s(&encode_u32s(&vals)).unwrap(), vals);
    }

    #[test]
    fn empty() {
        assert_eq!(decode_u32s(&encode_u32s(&[])).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn beats_raw_on_csr_like_data() {
        // CSR col array of a power-lawish shard: clustered ascending runs.
        let mut vals = Vec::new();
        for row in 0..200u32 {
            for j in 0..50u32 {
                vals.push(row * 37 + j * 3);
            }
        }
        let enc = encode_u32s(&vals);
        assert!(enc.len() * 2 < vals.len() * 4, "ratio {}", vals.len() * 4 / enc.len());
    }

    #[test]
    fn byte_adapter_round_trip() {
        let vals: Vec<u32> = (0..1000).rev().collect();
        let bytes = crate::util::u32s_as_bytes(&vals);
        let enc = compress_bytes(&bytes).unwrap();
        assert_eq!(decompress_bytes(&enc).unwrap(), bytes);
    }

    #[test]
    fn byte_adapter_rejects_ragged() {
        assert!(compress_bytes(&[1, 2, 3]).is_err());
    }

    #[test]
    fn into_variant_matches_vec_variant() {
        let vals: Vec<u32> = (0..5_000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let bytes = crate::util::u32s_as_bytes(&vals);
        let enc = compress_bytes(&bytes).unwrap();
        let mut out = vec![0u8; bytes.len()];
        decompress_bytes_into(&enc, &mut out).unwrap();
        assert_eq!(out, bytes);
        assert_eq!(out, decompress_bytes(&enc).unwrap());
        // wrong output size is an error, not a partial write
        let mut short = vec![0u8; bytes.len() - 4];
        assert!(decompress_bytes_into(&enc, &mut short).is_err());
        let mut ragged = vec![0u8; 3];
        assert!(decompress_bytes_into(&enc, &mut ragged).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let enc = encode_u32s(&[1, 2, 3]);
        assert!(decode_u32s(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut enc = encode_u32s(&[1, 2, 3]);
        enc.push(0);
        assert!(decode_u32s(&enc).is_err());
    }
}
