//! Run metrics: per-iteration timing, I/O deltas, memory accounting.
//!
//! Memory is *accounted* (structural sizes of the arrays each engine keeps
//! live), not sampled from the OS: at sim scale RSS is dominated by noise,
//! while the accounted number is exactly the quantity Table 3's "Memory
//! Usage" column models and Fig 11 plots.

use std::time::Duration;

use crate::cache::CacheSnapshot;
use crate::storage::disk::IoSnapshot;

/// One iteration's record (drives Figs 7, 8, 10).
#[derive(Clone, Debug, Default)]
pub struct IterationMetrics {
    pub iteration: u32,
    /// Wall-clock compute time of the iteration.
    pub wall: Duration,
    /// Simulated disk seconds charged during the iteration.
    pub sim_disk_seconds: f64,
    pub active_vertices: u64,
    pub active_ratio: f64,
    pub shards_processed: u32,
    pub shards_skipped: u32,
    pub io: IoSnapshot,
    pub cache: CacheSnapshot,
}

impl IterationMetrics {
    /// The reported per-iteration time: wall compute + simulated device
    /// time (what the run would have cost on the paper's HDD box).
    pub fn elapsed_seconds(&self) -> f64 {
        self.wall.as_secs_f64() + self.sim_disk_seconds
    }
}

/// Whole-run summary.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub iterations: Vec<IterationMetrics>,
    /// Accounted peak memory in bytes (vertex arrays + blooms + cache +
    /// in-flight shards).
    pub memory_bytes: u64,
    pub converged: bool,
    pub total_wall: Duration,
    pub total_sim_disk_seconds: f64,
}

impl RunMetrics {
    pub fn total_seconds(&self) -> f64 {
        self.total_wall.as_secs_f64() + self.total_sim_disk_seconds
    }

    pub fn total_minutes(&self) -> f64 {
        self.total_seconds() / 60.0
    }

    /// Sum of the first `n` iterations (the paper reports first-10-iteration
    /// times in Tables 5–7).
    pub fn first_n_seconds(&self, n: usize) -> f64 {
        self.iterations.iter().take(n).map(|m| m.elapsed_seconds()).sum()
    }

    pub fn edges_per_second(&self, edges_per_iter: u64) -> f64 {
        let s = self.total_seconds();
        if s <= 0.0 {
            return 0.0;
        }
        edges_per_iter as f64 * self.iterations.len() as f64 / s
    }
}

/// Structural memory accounting helper.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryAccount {
    pub vertex_arrays: u64,
    pub degree_arrays: u64,
    pub blooms: u64,
    pub cache: u64,
    pub inflight_shards: u64,
    pub other: u64,
}

impl MemoryAccount {
    pub fn total(&self) -> u64 {
        self.vertex_arrays
            + self.degree_arrays
            + self.blooms
            + self.cache
            + self.inflight_shards
            + self.other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_adds_sim_time() {
        let m = IterationMetrics {
            wall: Duration::from_millis(500),
            sim_disk_seconds: 1.5,
            ..Default::default()
        };
        assert!((m.elapsed_seconds() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn first_n() {
        let mut r = RunMetrics::default();
        for i in 0..5 {
            r.iterations.push(IterationMetrics {
                iteration: i,
                sim_disk_seconds: 1.0,
                ..Default::default()
            });
        }
        assert!((r.first_n_seconds(3) - 3.0).abs() < 1e-9);
        assert!((r.first_n_seconds(10) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn memory_total() {
        let m = MemoryAccount { vertex_arrays: 10, cache: 5, ..Default::default() };
        assert_eq!(m.total(), 15);
    }

    #[test]
    fn edges_per_second_zero_safe() {
        let r = RunMetrics::default();
        assert_eq!(r.edges_per_second(100), 0.0);
    }
}
