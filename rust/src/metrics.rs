//! Run metrics: per-iteration timing, I/O deltas, memory accounting.
//!
//! Memory is *accounted* (structural sizes of the arrays each engine keeps
//! live), not sampled from the OS: at sim scale RSS is dominated by noise,
//! while the accounted number is exactly the quantity Table 3's "Memory
//! Usage" column models and Fig 11 plots.

use std::time::Duration;

use crate::cache::CacheSnapshot;
use crate::storage::disk::IoSnapshot;

/// One iteration's record (drives Figs 7, 8, 10).
#[derive(Clone, Debug, Default)]
pub struct IterationMetrics {
    pub iteration: u32,
    /// Wall-clock compute time of the iteration.
    pub wall: Duration,
    /// Simulated disk seconds charged during the iteration.
    pub sim_disk_seconds: f64,
    /// The share of `sim_disk_seconds` hidden behind compute by the shard
    /// pipeline (dedicated I/O threads); 0 when prefetching is off.
    pub overlapped_sim_seconds: f64,
    pub active_vertices: u64,
    pub active_ratio: f64,
    pub shards_processed: u32,
    pub shards_skipped: u32,
    /// Shards fetched ahead by the pipeline's I/O threads.
    pub shards_prefetched: u32,
    /// Worker shard requests served without blocking on the ready queue.
    pub ready_hits: u32,
    /// Worker shard requests that had to wait for the prefetcher.
    pub ready_misses: u32,
    /// Ready-queue depth the pipeline ran with this iteration (varies
    /// under adaptive prefetch; 0 = sequential reference path).
    pub prefetch_depth_used: u32,
    /// Jobs that participated in this shard pass (1 outside scan-shared
    /// batches).  In a batch, `wall`/`io`/`cache` below are the *shared*
    /// pass costs — every member job's record carries the same values,
    /// while `shards_processed`/`active_*` stay job-specific.
    pub jobs_in_pass: u32,
    /// (unit, job) computes this pass: each loaded unit counts once per
    /// member job it was handed to (== `shards_processed` solo).
    pub shard_servings: u32,
    /// (unit, job) sub-tasks this pass that were split out to idle
    /// workers instead of running serially on the claiming worker (PR 5
    /// fan-out; 0 outside short-worklist batch passes).
    pub shard_servings_fanned: u32,
    /// *This job's* compute seconds inside the pass — the sum of its
    /// per-(unit, job) kernel times.  Unlike `wall` (shared across the
    /// batch), this is per-job attribution: the basis for billing heavy
    /// queries fairly.
    pub job_compute_seconds: f64,
    pub io: IoSnapshot,
    pub cache: CacheSnapshot,
}

impl IterationMetrics {
    /// The reported per-iteration time: wall compute + the *non-overlapped*
    /// simulated device time (what the run would have cost on the paper's
    /// HDD box, where prefetched reads proceed while workers compute).
    pub fn elapsed_seconds(&self) -> f64 {
        self.wall.as_secs_f64() + (self.sim_disk_seconds - self.overlapped_sim_seconds)
    }

    /// Fraction of worker shard requests the ready queue served without
    /// blocking (1.0 = the prefetcher always stayed ahead).
    pub fn ready_hit_ratio(&self) -> f64 {
        let total = self.ready_hits + self.ready_misses;
        if total == 0 {
            0.0
        } else {
            self.ready_hits as f64 / total as f64
        }
    }
}

/// Per-job accounting of a scan-shared batch (PR 5): what *this* job
/// consumed out of the shared passes.  Pass-level `wall`/`io` records
/// are shared by every member; this is the per-job attribution a
/// serving scheduler can bill — compute seconds actually spent in the
/// job's kernels, units and edges served to it, and its servings-weighted
/// share of the batch's disk bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct JobMetrics {
    /// Batch pass at which the job was admitted (0 = founding member;
    /// > 0 = admitted mid-batch at that pass boundary).
    pub admitted_pass: u32,
    /// Job-local iterations run (its own count, not the batch's).
    pub iterations: u32,
    /// Wall time spent inside this job's per-(unit, job) kernel computes,
    /// summed across all passes.
    pub compute: Duration,
    /// Units (shards) served to this job across all passes.
    pub units_served: u64,
    /// Edges processed for this job (0 when the engine doesn't track
    /// per-unit edge counts).
    pub edges_processed: u64,
    /// This job's servings-weighted share of the batch's disk bytes —
    /// the per-job effective I/O cost under scan sharing.
    pub effective_bytes_read: f64,
}

impl JobMetrics {
    /// Edges per compute second — the job's kernel throughput.
    pub fn edges_per_compute_second(&self) -> f64 {
        let s = self.compute.as_secs_f64();
        if s <= 0.0 {
            0.0
        } else {
            self.edges_processed as f64 / s
        }
    }
}

/// Whole-run summary.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub iterations: Vec<IterationMetrics>,
    /// Accounted peak memory in bytes (vertex arrays + blooms + cache +
    /// in-flight shards).
    pub memory_bytes: u64,
    pub converged: bool,
    pub total_wall: Duration,
    pub total_sim_disk_seconds: f64,
    /// Simulated disk seconds hidden behind compute across all iterations.
    pub total_overlapped_sim_seconds: f64,
    /// Per-job attribution of this run within its batch (solo runs are
    /// the N=1 batch, so the meter is filled there too).
    pub job: JobMetrics,
    /// Set when the job was failed in isolation (a hard I/O or compute
    /// error contained to this job under `isolate_failures`): the first
    /// failure, naming the unit and file.  `None` = the job ran clean.
    pub failed: Option<String>,
    /// Set when the job was evicted from its batch at a pass boundary by a
    /// [`crate::exec::LaneArbiter`] (deadline exceeded, wall-clock timeout,
    /// cancellation, or a shutdown-checkpoint stop): the eviction reason.
    /// The values carried alongside are the lane state at the eviction
    /// boundary, not a finished result.
    pub evicted: Option<String>,
}

impl RunMetrics {
    pub fn total_seconds(&self) -> f64 {
        self.total_wall.as_secs_f64()
            + (self.total_sim_disk_seconds - self.total_overlapped_sim_seconds)
    }

    pub fn total_minutes(&self) -> f64 {
        self.total_seconds() / 60.0
    }

    /// Sum of the first `n` iterations (the paper reports first-10-iteration
    /// times in Tables 5–7).
    pub fn first_n_seconds(&self, n: usize) -> f64 {
        self.iterations.iter().take(n).map(|m| m.elapsed_seconds()).sum()
    }

    pub fn edges_per_second(&self, edges_per_iter: u64) -> f64 {
        let s = self.total_seconds();
        if s <= 0.0 {
            return 0.0;
        }
        edges_per_iter as f64 * self.iterations.len() as f64 / s
    }
}

/// Aggregate record of one scan-shared batch (PR 4): N jobs sharing
/// every shard pass.  The headline quantity is the amortization — how
/// many job-servings each loaded unit (and its disk bytes) paid for.
#[derive(Clone, Debug, Default)]
pub struct BatchMetrics {
    /// Jobs in the batch (founding members + mid-batch admissions).
    pub jobs: u32,
    /// Of those, jobs admitted at a pass boundary > 0 (PR 5 interactive
    /// admission).
    pub admitted_mid_batch: u32,
    /// Admissions that had to wait at least one pass boundary because the
    /// batch was already at [`crate::exec::MAX_BATCH_JOBS`] running jobs.
    pub admissions_deferred: u32,
    /// Shard passes run (the max over member jobs' iteration spans).
    pub passes: u32,
    /// Union-worklist units loaded across all passes (each unit's I/O —
    /// real or modelled — was charged exactly once per pass).
    pub shard_loads: u64,
    /// (unit, job) computes across all passes: what N back-to-back solo
    /// runs would have loaded.
    pub shard_servings: u64,
    /// Of those, sub-tasks split out to idle workers by the (unit × job)
    /// fan-out (PR 5); the rest ran serially on the claiming worker.
    pub shard_servings_fanned: u64,
    /// Disk bytes read by the whole batch.
    pub bytes_read: u64,
    pub total_wall: Duration,
    pub total_sim_disk_seconds: f64,
    /// Checkpoints persisted during the batch (0 when checkpointing off).
    pub checkpoints_written: u32,
    /// Bytes the persisted checkpoints cost on disk.
    pub checkpoint_bytes: u64,
    /// Wall seconds spent writing checkpoints (on the boundary, so fully
    /// on the critical path).
    pub checkpoint_seconds: f64,
    /// Pass boundary this batch was resumed from (`None` = fresh run).
    pub resumed_from_pass: Option<u32>,
    /// Jobs that ended [`crate::runtime::jobs::JobStatus::Failed`] under
    /// failure isolation.
    pub jobs_failed: u32,
    /// Jobs evicted at a pass boundary by the batch's
    /// [`crate::exec::LaneArbiter`] (deadlines, timeouts, cancellations,
    /// shutdown stops).
    pub jobs_evicted: u32,
    /// Checkpoints that could not be written (hard write fault): skipped
    /// with a warning while the batch kept running.
    pub checkpoints_failed: u32,
    /// Set when the batch was stopped early at this pass boundary by
    /// [`crate::exec::LaneArbiter::stop_batch`] (graceful daemon shutdown
    /// with an in-flight batch): unfinished lanes were frozen, not run to
    /// completion.
    pub stopped_at_pass: Option<u32>,
    /// Per-job attribution, in admission order (founding members in
    /// submission order, then mid-batch admissions as they arrived).
    pub per_job: Vec<JobMetrics>,
}

impl BatchMetrics {
    /// Servings per load: ~N when the member worklists overlap fully,
    /// 1.0 for a solo run (no sharing to be had).
    pub fn shard_loads_amortized(&self) -> f64 {
        if self.shard_loads == 0 {
            0.0
        } else {
            self.shard_servings as f64 / self.shard_loads as f64
        }
    }

    /// Effective disk bytes each job paid — the per-job I/O that falls
    /// as ~1/N with batch size (Fig 12).
    pub fn effective_bytes_read_per_job(&self) -> f64 {
        if self.jobs == 0 {
            0.0
        } else {
            self.bytes_read as f64 / self.jobs as f64
        }
    }
}

/// Structural memory accounting helper.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryAccount {
    pub vertex_arrays: u64,
    pub degree_arrays: u64,
    pub blooms: u64,
    pub cache: u64,
    /// Parsed shards pinned by the decode-once memo budget.
    pub decoded_pool: u64,
    pub inflight_shards: u64,
    pub other: u64,
}

impl MemoryAccount {
    pub fn total(&self) -> u64 {
        self.vertex_arrays
            + self.degree_arrays
            + self.blooms
            + self.cache
            + self.decoded_pool
            + self.inflight_shards
            + self.other
    }
}

/// Per-priority-class accounting of a `graphmp serve` daemon: how many
/// jobs of this class were submitted/finished and their submit→terminal
/// latency profile.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassMetrics {
    pub submitted: u64,
    pub completed: u64,
    /// Sum of submit→terminal wall latencies of completed jobs.
    pub total_latency: Duration,
    pub max_latency: Duration,
}

impl ClassMetrics {
    /// Mean submit→terminal latency of this class.
    pub fn mean_latency(&self) -> Duration {
        if self.completed == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.completed as u32
        }
    }
}

/// Counters of one `graphmp serve` daemon (PR 8): admission control,
/// backpressure, evictions and checkpoint health across the daemon's
/// whole lifetime.  A snapshot is served on the wire protocol's
/// `metrics` request.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Submissions received (accepted + rejected).
    pub submitted: u64,
    /// Jobs admitted into a running batch.
    pub admitted: u64,
    /// Jobs that reached a finished state (converged / iteration limit).
    pub completed: u64,
    /// Submissions rejected by backpressure (bounded queue full).
    pub rejected: u64,
    /// Submissions rejected as invalid (unknown app, weight requirements,
    /// draining daemon).
    pub rejected_invalid: u64,
    /// Jobs evicted mid-batch for a missed deadline or wall-clock timeout
    /// ([`crate::runtime::JobStatus::Expired`]).
    pub expired: u64,
    /// Jobs cancelled by request (queued or evicted mid-batch).
    pub cancelled: u64,
    /// Jobs evicted resumable by a shutdown checkpoint
    /// ([`crate::runtime::JobStatus::Evicted`]).
    pub evicted: u64,
    /// Jobs failed in isolation.
    pub failed: u64,
    /// Scan-shared batches the daemon ran.
    pub batches: u64,
    pub checkpoints_written: u64,
    /// Checkpoints skipped on a hard write fault (the daemon kept serving).
    pub checkpoints_failed: u64,
    /// Current admission-queue depth (gauge, not a counter).
    pub queue_depth: usize,
    /// Per-priority-class latency accounting, indexed by
    /// `Priority::index()` (high / normal / low).
    pub per_class: [ClassMetrics; 3],
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_adds_sim_time() {
        let m = IterationMetrics {
            wall: Duration::from_millis(500),
            sim_disk_seconds: 1.5,
            ..Default::default()
        };
        assert!((m.elapsed_seconds() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn elapsed_subtracts_overlapped_sim_time() {
        let m = IterationMetrics {
            wall: Duration::from_millis(500),
            sim_disk_seconds: 1.5,
            overlapped_sim_seconds: 0.5,
            ..Default::default()
        };
        assert!((m.elapsed_seconds() - 1.5).abs() < 1e-9);
        let mut r = RunMetrics {
            total_wall: Duration::from_secs(1),
            total_sim_disk_seconds: 3.0,
            total_overlapped_sim_seconds: 2.0,
            ..Default::default()
        };
        assert!((r.total_seconds() - 2.0).abs() < 1e-9);
        r.total_overlapped_sim_seconds = 0.0;
        assert!((r.total_seconds() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ready_hit_ratio_math() {
        let m = IterationMetrics { ready_hits: 3, ready_misses: 1, ..Default::default() };
        assert!((m.ready_hit_ratio() - 0.75).abs() < 1e-9);
        assert_eq!(IterationMetrics::default().ready_hit_ratio(), 0.0);
    }

    #[test]
    fn first_n() {
        let mut r = RunMetrics::default();
        for i in 0..5 {
            r.iterations.push(IterationMetrics {
                iteration: i,
                sim_disk_seconds: 1.0,
                ..Default::default()
            });
        }
        assert!((r.first_n_seconds(3) - 3.0).abs() < 1e-9);
        assert!((r.first_n_seconds(10) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn batch_amortization_math() {
        let b = BatchMetrics {
            jobs: 4,
            shard_loads: 10,
            shard_servings: 40,
            bytes_read: 1000,
            ..Default::default()
        };
        assert!((b.shard_loads_amortized() - 4.0).abs() < 1e-12);
        assert!((b.effective_bytes_read_per_job() - 250.0).abs() < 1e-12);
        let z = BatchMetrics::default();
        assert_eq!(z.shard_loads_amortized(), 0.0);
        assert_eq!(z.effective_bytes_read_per_job(), 0.0);
    }

    #[test]
    fn job_metrics_throughput_math() {
        let j = JobMetrics {
            compute: Duration::from_secs(2),
            edges_processed: 1000,
            ..Default::default()
        };
        assert!((j.edges_per_compute_second() - 500.0).abs() < 1e-9);
        assert_eq!(JobMetrics::default().edges_per_compute_second(), 0.0);
    }

    #[test]
    fn memory_total() {
        let m = MemoryAccount { vertex_arrays: 10, cache: 5, ..Default::default() };
        assert_eq!(m.total(), 15);
    }

    #[test]
    fn class_latency_math() {
        let c = ClassMetrics {
            completed: 4,
            total_latency: Duration::from_millis(200),
            ..Default::default()
        };
        assert_eq!(c.mean_latency(), Duration::from_millis(50));
        assert_eq!(ClassMetrics::default().mean_latency(), Duration::ZERO);
    }

    #[test]
    fn edges_per_second_zero_safe() {
        let r = RunMetrics::default();
        assert_eq!(r.edges_per_second(100), 0.0);
    }
}
